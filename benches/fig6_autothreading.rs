//! E8 / Figure 6: auto-threading scaling, ours vs graphite-analog.
use latticetile::experiments::{fig6, harness};

fn main() {
    let n = 256i64;
    let threads = [1usize, 2, 4, 8, 12, 16, 20];
    let (og, gg) = fig6::parallel_grain(n);
    println!("=== Figure 6: auto-threading (n={n}; bands: ours={og}, graphite={gg}) ===");
    println!(
        "{:>7} {:>12} {:>9} {:>12} {:>9}",
        "threads", "ours wall", "speedup*", "graphite", "speedup*"
    );
    for r in fig6::run(n, &threads, 1) {
        println!(
            "{:>7} {:>12} {:>8.2}x {:>12} {:>8.2}x",
            r.threads,
            harness::fmt_dur(r.ours),
            r.ours_modeled,
            harness::fmt_dur(r.graphite),
            r.graphite_modeled
        );
    }
    println!("* load-balance speedup (see EXPERIMENTS.md: single-core host)");
}
