//! E6 / §4.0.2: best rectangular vs best lattice tiling.
use latticetile::experiments::{fig4, harness};

fn main() {
    println!("=== §4.0.2: best rect vs best lattice ===");
    println!("{:<6} {:<22} {:>12} {:>10} {:>9}", "n", "strategy", "L1 misses", "wall", "GFLOP/s");
    for n in [96i64, 128, 192, 256] {
        for r in fig4::run_rect_vs_lattice(n, 2) {
            println!(
                "{:<6} {:<22} {:>12} {:>10} {:>9.2}",
                r.n, r.strategy, r.l1_misses, harness::fmt_dur(r.wall), r.gflops
            );
        }
    }
}
