//! E5 / Figure 4: lattice tiling vs compiler analogs. `cargo bench --bench fig4_compilers`
//! Env: FIG4_SIZES="96,128" to override sizes; FIG4_REPS=n.
use latticetile::experiments::{fig4, harness};

fn main() {
    let sizes: Vec<i64> = std::env::var("FIG4_SIZES")
        .map(|s| s.split(',').filter_map(|v| v.parse().ok()).collect())
        .unwrap_or_else(|_| vec![96, 128, 192, 256]);
    let reps: usize = std::env::var("FIG4_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(2);
    println!("=== Figure 4: lattice tiling vs compiler analogs ===");
    for &n in &sizes {
        let rows = fig4::run_size(n, reps);
        let sp = fig4::speedups_vs(&rows, "gcc-O0(analog)");
        println!("\nn = {n}:");
        println!(
            "{:<22} {:>12} {:>10} {:>9} {:>10}",
            "strategy", "L1 misses", "wall", "GFLOP/s", "vs O0"
        );
        for (i, r) in rows.iter().enumerate() {
            println!(
                "{:<22} {:>12} {:>10} {:>9.2} {:>9.2}x",
                r.strategy,
                r.l1_misses,
                harness::fmt_dur(r.wall),
                r.gflops,
                sp[i].1
            );
        }
    }
}
