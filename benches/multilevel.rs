//! E12 extension: two-level hierarchy behaviour of the plans.
use latticetile::experiments::multilevel;

fn main() {
    println!("=== extension: L1+L2 hierarchy behaviour ===");
    println!("{:>5} {:<22} {:>12} {:>12} {:>12}", "n", "strategy", "L1 misses", "L2 misses", "est cycles");
    for r in multilevel::run(&[96, 128]) {
        println!(
            "{:>5} {:<22} {:>12} {:>12} {:>12}",
            r.n, r.strategy, r.l1_misses, r.l2_misses, r.est_cycles
        );
    }
}
