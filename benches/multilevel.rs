//! E12 extension: three-level hierarchy behaviour of the plans,
//! including the macro-kernel rows (L3-slice misses are what the
//! super-band schedule is sized against). Besides the console table,
//! results are written machine-readably to `BENCH_multilevel.json`
//! (strategy → per-level misses + Mops/s), mirroring
//! `BENCH_hot_paths.json` so the perf trajectory can be tracked across
//! PRs — and gated by `python/check_bench.py` in CI.
use latticetile::experiments::multilevel;

fn main() {
    // BENCH_QUICK=1 (CI smoke): reduced sizes so the binary can't bit-rot
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let sizes: &[i64] = if quick { &[64, 96] } else { &[96, 128, 160] };
    println!("=== extension: L1+L2+L3 hierarchy behaviour ===");
    println!(
        "{:>5} {:<22} {:>12} {:>12} {:>12} {:>12} {:>10}",
        "n", "strategy", "L1 misses", "L2 misses", "L3 misses", "est cycles", "Mops/s"
    );
    let rows = multilevel::run(sizes);
    for r in &rows {
        println!(
            "{:>5} {:<22} {:>12} {:>12} {:>12} {:>12} {:>10.1}",
            r.n, r.strategy, r.l1_misses, r.l2_misses, r.l3_misses, r.est_cycles, r.mops
        );
    }
    let body: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "  \"n{} {}\": {{\"l1_misses\": {}, \"l2_misses\": {}, \"l3_misses\": {}, \
                 \"est_cycles\": {}, \"mops\": {:.1}}}",
                r.n, r.strategy, r.l1_misses, r.l2_misses, r.l3_misses, r.est_cycles, r.mops
            )
        })
        .collect();
    let json = format!("{{\n{}\n}}\n", body.join(",\n"));
    // anchor at the workspace root (cargo runs benches with cwd set to
    // the package root, rust/)
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_multilevel.json");
    match std::fs::write(path, json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncannot write {path}: {e}"),
    }
}
