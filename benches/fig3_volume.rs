//! E4 / Figure 3: exact tile-volume comparison. `cargo bench --bench fig3_volume`
use latticetile::experiments::fig3;

fn main() {
    let r = fig3::run();
    println!("=== Figure 3: tile volume (lattice gen (5,61),(7,-17)) ===");
    println!("lattice fundamental parallelepiped : {}", r.lattice_volume);
    println!(
        "best translation-safe rectangle     : {} ({}x{})",
        r.best_rect_volume, r.best_rect.0, r.best_rect.1
    );
    println!(
        "best practical rectangle (>=8 dims) : {} ({}x{})",
        r.best_practical_rect_volume, r.best_practical_rect.0, r.best_practical_rect.1
    );
    println!("paper-cited best rectangle [GMM99]  : {}", r.paper_best_rect_volume);
    println!("paper-cited chosen rect [GMM99]     : {}", r.paper_chosen_rect_volume);
    println!("lattice advantage vs practical rect : {:.2}x", r.advantage_vs_best_rect);
    let l = fig3::paper_lattice();
    let (mn, mx) = fig3::rect_point_count_varies(&l, 24, 20, 6);
    println!("regularity: rect 24x20 tiles hold {mn}..{mx} points; lattice tiles always 1");
    assert_eq!(r.lattice_volume, 512);
}
