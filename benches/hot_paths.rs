//! Microbenchmarks of the hot paths (EXPERIMENTS.md §Perf): cache-sim
//! access rate, tile scanning, the packed microkernel engine, miss-model
//! throughput.
//!
//! Besides the console table, results are written machine-readably to
//! `BENCH_hot_paths.json` (label → Mops/s) so the perf trajectory can be
//! tracked across PRs.
use std::time::Instant;

use latticetile::cache::{CacheSim, CacheSpec, Policy};
use latticetile::codegen::autotune;
use latticetile::codegen::executor::{max_abs_diff, prototile_points, KernelBuffers, TiledExecutor};
use latticetile::codegen::microkernel::{mkernel_full, MR, NR};
use latticetile::conflict::MissModel;
use latticetile::domain::{ops, IterOrder};
use latticetile::lattice::IMat;
use latticetile::tiling::{TileBasis, TiledSchedule};

/// Collects (label, Mops/s) pairs while printing the console table.
#[derive(Default)]
struct Results {
    rows: Vec<(String, f64)>,
}

impl Results {
    fn rate(&mut self, label: &str, ops_done: u64, t: std::time::Duration) {
        let mops = ops_done as f64 / t.as_secs_f64() / 1e6;
        println!("{label:<46} {mops:>10.1} Mops/s  ({ops_done} ops in {t:?})");
        self.rows.push((label.to_string(), mops));
    }

    fn write_json(&self, path: &str) {
        let body: Vec<String> = self
            .rows
            .iter()
            .map(|(label, mops)| format!("  \"{label}\": {mops:.1}"))
            .collect();
        let json = format!("{{\n{}\n}}\n", body.join(",\n"));
        match std::fs::write(path, json) {
            Ok(()) => println!("\nwrote {path}"),
            Err(e) => eprintln!("\ncannot write {path}: {e}"),
        }
    }
}

/// Time `rounds` bursts of serve jobs (submit the whole burst, then
/// drain it) through a native-backend [`Service`] planned for
/// `max_batch`-wide coalescing. Returns the drained wall time.
///
/// [`Service`]: latticetile::coordinator::Service
fn serve_burst_bench(
    y: Vec<f32>,
    xs: &[Vec<f32>],
    (m, k, n): (usize, usize, usize),
    max_batch: usize,
    rounds: u64,
    precision: latticetile::codegen::Precision,
) -> std::time::Duration {
    use latticetile::coordinator::{Backend, Service, ServiceConfig};
    let svc = Service::start(
        std::path::Path::new("bench-no-artifacts"),
        y,
        ServiceConfig {
            m,
            k,
            n,
            batch_window: std::time::Duration::from_millis(5),
            max_batch,
            queue_cap: 1024,
            backend: Backend::Native,
            precision,
            ..ServiceConfig::default()
        },
    )
    .expect("native bench service");
    let burst = || {
        let rxs: Vec<_> = xs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
    };
    for _ in 0..2 {
        burst(); // warm the engine and the panels
    }
    let t0 = Instant::now();
    for _ in 0..rounds {
        burst();
    }
    let t = t0.elapsed();
    svc.stop();
    t
}

fn main() {
    println!("=== hot-path microbenchmarks ===");
    // BENCH_QUICK=1 (CI smoke): shrink the macro-kernel comparison size
    // so the bench binary stays exercised without a long runtime
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let mut res = Results::default();

    // cache sim raw access rate
    let mut sim = CacheSim::new(CacheSpec::HASWELL_L1D, Policy::Lru).without_classification();
    let n_acc = 20_000_000u64;
    let t0 = Instant::now();
    for i in 0..n_acc {
        sim.access(((i * 72) % (1 << 20)) as usize);
    }
    res.rate("cache sim access (no classification)", n_acc, t0.elapsed());

    let mut sim = CacheSim::new(CacheSpec::HASWELL_L1D, Policy::Lru);
    let n_acc = 2_000_000u64;
    let t0 = Instant::now();
    for i in 0..n_acc {
        sim.access(((i * 72) % (1 << 20)) as usize);
    }
    res.rate("cache sim access (3-C classification)", n_acc, t0.elapsed());

    // raw register-tiled microkernel over packed panels
    let kc = 256usize;
    let bp = vec![1.000_000_1f64; kc * MR];
    let cp = vec![0.999_999_9f64; kc * NR];
    let mut acc_buf = vec![0f64; (NR - 1) * MR + MR];
    let reps = 40_000u64;
    let t0 = Instant::now();
    for _ in 0..reps {
        mkernel_full(kc, &bp, &cp, &mut acc_buf, MR);
    }
    res.rate("microkernel", reps * (kc * MR * NR) as u64, t0.elapsed());
    assert!(acc_buf[0].is_finite());

    // tile scanning: skewed basis, packed panel replay vs filter scan
    let basis = TileBasis::from_cols(IMat::from_rows(&[
        &[32, 0, 8],
        &[0, 16, 0],
        &[-8, 0, 16],
    ]));
    let sched = TiledSchedule::new(basis.clone());
    let kernel = ops::matmul(256, 256, 256, 8, 0);
    use latticetile::domain::order::Scanner;
    let t0 = Instant::now();
    let mut cnt = 0u64;
    sched.scan_points(kernel.extents(), &mut |_: &[i64]| cnt += 1);
    res.rate("skewed tile scan_points (filter scan)", cnt, t0.elapsed());

    let proto = prototile_points(&basis);
    println!("prototile size: {} points", proto.len());

    let exec = TiledExecutor::new(TiledSchedule::new(basis));
    let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
    let t0 = Instant::now();
    exec.run(&mut bufs, &kernel);
    res.rate("packed tile replay", (256u64).pow(3), t0.elapsed());

    // rect tiles through the same pack + microkernel engine
    let exec = TiledExecutor::new(TiledSchedule::new(TileBasis::rect(&[64, 64, 64])));
    let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
    let t0 = Instant::now();
    exec.run(&mut bufs, &kernel);
    res.rate("rect tiled executor (packed microkernel)", (256u64).pow(3), t0.elapsed());

    // the two-level macro-kernel vs the single-level per-tile engine at
    // an L2-exceeding size (same L1 tile for both, so the delta is the
    // macro blocking alone)
    let big = if quick { 192i64 } else { 512 };
    let kernel = ops::matmul(big, big, big, 8, 0);
    let exec = TiledExecutor::new(TiledSchedule::new(TileBasis::rect(&[64, 64, 64])));
    let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
    let t0 = Instant::now();
    exec.run_l1_only(&mut bufs, &kernel);
    res.rate(
        &format!("per-tile packed engine matmul n={big}"),
        (big as u64).pow(3),
        t0.elapsed(),
    );
    let want = bufs.output();
    let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
    let t0 = Instant::now();
    exec.run(&mut bufs, &kernel); // macro-kernel path
    // quick (CI) runs use a different n — key the row separately so the
    // tracked "macro-kernel matmul" trajectory only ever compares n=512
    let macro_label = if quick {
        format!("macro-kernel matmul n={big}")
    } else {
        "macro-kernel matmul".to_string()
    };
    res.rate(&macro_label, (big as u64).pow(3), t0.elapsed());
    assert!(
        max_abs_diff(&want, &bufs.output()) < 1e-9,
        "macro-kernel diverged from the per-tile engine"
    );

    // the L3 super-band parallel scheduler: whole super-bands per worker
    // with thread-local row-slice packing — the threaded row tracked
    // across PRs next to the serial macro-kernel row
    let threads = 4usize;
    let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
    let sched = TiledSchedule::new(TileBasis::rect(&[64, 64, 64]));
    let t0 = Instant::now();
    latticetile::codegen::run_parallel_macro(
        &mut bufs,
        &kernel,
        &sched,
        threads,
        None,
        latticetile::codegen::MicroShape::Mr8Nr4,
    );
    let par_label = if quick {
        format!("parallel super-band matmul n={big} t={threads}")
    } else {
        format!("parallel super-band matmul t={threads}")
    };
    res.rate(&par_label, (big as u64).pow(3), t0.elapsed());
    assert!(
        max_abs_diff(&want, &bufs.output()) < 1e-9,
        "parallel super-band path diverged from the serial engine"
    );

    // the same schedule with the pack-ahead pipeline (and stealing)
    // switched off: each worker packs its stage, then computes it, in
    // strict alternation. The tracked ratio between the pipelined row
    // above and this one is the parallel efficiency the software
    // pipeline buys at t=4 (check_bench ratchets it as a ratio floor).
    let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
    let t0 = Instant::now();
    latticetile::codegen::run_parallel_macro_tuned(
        &mut bufs,
        &kernel,
        &sched,
        threads,
        None,
        latticetile::codegen::MicroShape::Mr8Nr4,
        latticetile::codegen::ParallelTuning::synchronous(),
    );
    let sync_label = if quick {
        format!("parallel super-band matmul sync n={big} t={threads}")
    } else {
        format!("parallel super-band matmul sync t={threads}")
    };
    res.rate(&sync_label, (big as u64).pow(3), t0.elapsed());
    assert!(
        max_abs_diff(&want, &bufs.output()) < 1e-9,
        "synchronous parallel path diverged from the serial engine"
    );

    // Table-1 workload diversity: convolution and Kronecker through the
    // same packed micro/macro engine (kernel-agnostic RunPlan path) —
    // tracked from day one so the generalized engine can't regress
    // silently. BENCH_QUICK shrinks the sizes (different label keys, so
    // the full-size trajectories stay comparable across PRs).
    let conv_n = if quick { 1i64 << 15 } else { 1 << 20 };
    let kernel = ops::convolution(conv_n, 8, 0);
    let exec = TiledExecutor::new(TiledSchedule::new(TileBasis::rect(&[256])));
    let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
    let t0 = Instant::now();
    exec.run(&mut bufs, &kernel);
    res.rate(
        &format!("packed engine convolution n={conv_n}"),
        conv_n as u64,
        t0.elapsed(),
    );
    assert!(bufs.output()[0].is_finite());

    let kb = if quick { 12i64 } else { 24 };
    let kernel = ops::kronecker(kb, kb, kb, kb, 8, 0);
    let exec = TiledExecutor::new(TiledSchedule::new(TileBasis::rect(&[8, 8, 8, 8])));
    let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
    let t0 = Instant::now();
    exec.run(&mut bufs, &kernel);
    res.rate(
        &format!("packed engine kronecker {kb}^4"),
        (kb as u64).pow(4),
        t0.elapsed(),
    );
    assert!(bufs.output()[0].is_finite());

    // the element-generic engine at f32: the same macro-kernel matmul
    // and packed convolution as above, at half the element size and
    // twice the register-tile width — the f32/f64 throughput ratio is
    // what the tracked BENCH_hot_paths.json rows expose across PRs.
    // Both matmul rows run the *narrow* width class (8x4 vs 8x8, no
    // autotune) so the ratio isolates the dtype, not the calibrator.
    let kernel = ops::matmul(big, big, big, 4, 0);
    let exec = TiledExecutor::new(TiledSchedule::new(TileBasis::rect(&[64, 64, 64])));
    let mut bufs = KernelBuffers::<f32>::from_kernel(&kernel);
    let t0 = Instant::now();
    exec.run(&mut bufs, &kernel);
    let f32_label = if quick {
        format!("macro-kernel matmul f32 n={big}")
    } else {
        "macro-kernel matmul f32".to_string()
    };
    res.rate(&f32_label, (big as u64).pow(3), t0.elapsed());
    assert!(bufs.output()[0].is_finite());

    // the new 2-D grid geometries at f32, pinned (no autotune) next to
    // the 8x8 default row above: the wide 8x12 and tall 16x6 register
    // tiles. The tracked ratio of 16x6 against the default is a
    // structural gate — a tall arm that falls off the packed path (or a
    // pack layer that mis-handles 16-row panels) craters it.
    use latticetile::codegen::MicroShape;
    for (micro, tag) in [(MicroShape::Mr8Nr6, "8x12"), (MicroShape::Mr16Nr6, "16x6")] {
        let exec = TiledExecutor::new(TiledSchedule::new(TileBasis::rect(&[64, 64, 64])))
            .with_micro_shape(micro);
        let mut bufs = KernelBuffers::<f32>::from_kernel(&kernel);
        let t0 = Instant::now();
        exec.run(&mut bufs, &kernel);
        let label = if quick {
            format!("macro-kernel matmul f32 {tag} n={big}")
        } else {
            format!("macro-kernel matmul f32 {tag}")
        };
        res.rate(&label, (big as u64).pow(3), t0.elapsed());
        assert!(bufs.output()[0].is_finite());
    }

    let kernel = ops::convolution(conv_n, 4, 0);
    let exec = TiledExecutor::new(TiledSchedule::new(TileBasis::rect(&[256])));
    let mut bufs = KernelBuffers::<f32>::from_kernel(&kernel);
    let t0 = Instant::now();
    exec.run(&mut bufs, &kernel);
    res.rate(
        &format!("packed engine convolution f32 n={conv_n}"),
        conv_n as u64,
        t0.elapsed(),
    );
    assert!(bufs.output()[0].is_finite());

    // native serving: one-at-a-time dispatch vs the coalescing batcher
    // over the same prepacked weights. Each round submits a burst of 8
    // jobs and drains it; at max_batch=1 that is 8 dispatches, at
    // max_batch=8 one widened GEMM. The tracked ratio between the two
    // rows is the win coalescing buys (check_bench ratchets it).
    let (sm, sk, sn) = if quick {
        (8usize, 96usize, 96usize)
    } else {
        (8, 192, 192)
    };
    let rounds = if quick { 20u64 } else { 50 };
    let burst = 8usize;
    let mut sseed = 0x5EED5EEDu64;
    let mut srnd = move || {
        sseed ^= sseed << 13;
        sseed ^= sseed >> 7;
        sseed ^= sseed << 17;
        ((sseed % 1000) as f32 / 1000.0) - 0.5
    };
    let sy: Vec<f32> = (0..sk * sn).map(|_| srnd()).collect();
    let sxs: Vec<Vec<f32>> = (0..burst)
        .map(|_| (0..sm * sk).map(|_| srnd()).collect())
        .collect();
    use latticetile::codegen::Precision;
    let t_single = serve_burst_bench(sy.clone(), &sxs, (sm, sk, sn), 1, rounds, Precision::F32);
    let t_batch = serve_burst_bench(sy.clone(), &sxs, (sm, sk, sn), burst, rounds, Precision::F32);
    // the mixed mode over the same burst: f32 panels, f64 register
    // accumulation. The tracked ratio against the pure-f32 coalesced row
    // bounds what the extra precision costs — a collapse means the wide
    // arms fell off the register-tile path.
    let t_wide = serve_burst_bench(sy, &sxs, (sm, sk, sn), burst, rounds, Precision::F32ACC64);
    let serve_flops = rounds * burst as u64 * 2 * (sm * sk * sn) as u64;
    let (one_label, coal_label, wide_label) = if quick {
        (
            format!("native serve one-at-a-time {sm}x{sk}x{sn}"),
            format!("native serve coalesced batch B=8 {sm}x{sk}x{sn}"),
            format!("native serve coalesced batch B=8 f32acc64 {sm}x{sk}x{sn}"),
        )
    } else {
        (
            "native serve one-at-a-time".to_string(),
            "native serve coalesced batch B=8".to_string(),
            "native serve coalesced batch B=8 f32acc64".to_string(),
        )
    };
    res.rate(&one_label, serve_flops, t_single);
    res.rate(&coal_label, serve_flops, t_batch);
    res.rate(&wide_label, serve_flops, t_wide);

    // strategy dispatch on the serve GEMM: the auto-raced winner's macro
    // blocking vs the parameter-free flat fallback, on the coalesced
    // batch shape at f32. The tracked ratio (auto / flat) is the
    // strategy race's payoff gate — auto dispatch must never serve
    // slower than the degraded plan (check_bench holds the floor).
    {
        use latticetile::tiling::{strategy_impl, LevelPlan};
        let (gm, gk, gn) = (sm * burst, sk, sn);
        let kernel = ops::matmul(gm as i64, gk as i64, gn as i64, 4, 0);
        let micro = MicroShape::Mr8Nr4;
        let winner = autotune::calibrate_strategies::<f32>(&kernel, micro, 8, 2);
        println!("strategy race winner on the serve shape: {}", winner.name());
        let gf = latticetile::codegen::GemmForm::of(&kernel).expect("matmul is GEMM-form");
        let auto_lp = strategy_impl(winner).propose(
            &kernel,
            (gf.m, gf.n, gf.k),
            (8, 8, 8),
            &CacheSpec::HASWELL_L2,
            Some(&CacheSpec::HASWELL_L3_SLICE),
            8,
        );
        let flat_lp = LevelPlan::flat((8, 8, 8), 64, 64, 48);
        let plan_reps = if quick { 10u32 } else { 5 };
        let gemm_flops = plan_reps as u64 * 2 * (gm * gk * gn) as u64;
        for (lp, kind) in [(auto_lp, "auto"), (flat_lp, "flat")] {
            let exec = TiledExecutor::new(TiledSchedule::new(TileBasis::rect(&[64, 64, 64])))
                .with_micro_shape(micro)
                .with_level_plan(lp);
            let mut bufs = KernelBuffers::<f32>::from_kernel(&kernel);
            exec.run(&mut bufs, &kernel); // warm the panels
            let t0 = Instant::now();
            for _ in 0..plan_reps {
                bufs.reset_output();
                exec.run(&mut bufs, &kernel);
            }
            res.rate(
                &format!("serve plan {kind} strategy {gm}x{gk}x{gn}"),
                gemm_flops,
                t0.elapsed(),
            );
            assert!(bufs.output()[0].is_finite());
        }
    }

    // startup register-tile calibration (one-shot cost report, per dtype)
    let t0 = Instant::now();
    let shape = autotune::calibrate(2_000);
    let shape32 = autotune::calibrate_dtype::<f32>(2_000);
    println!(
        "autotune: f64 {} / f32 {} win in {:?} (the packed engine dispatches the winners)",
        shape.name(),
        shape32.label_for(latticetile::codegen::DType::F32),
        t0.elapsed()
    );

    // miss model throughput
    let small = ops::matmul(32, 32, 32, 8, 0);
    let model = MissModel::new(&small, &CacheSpec::HASWELL_L1D);
    let t0 = Instant::now();
    let c = model.exact(&IterOrder::lex(3));
    res.rate("miss model exact (accesses)", c.points * 3, t0.elapsed());
    let classes: Vec<i64> = (0..64).step_by(8).collect();
    let t0 = Instant::now();
    let c = model.sampled(&IterOrder::lex(3), &classes);
    res.rate("miss model sampled 8/64 (accesses)", c.points * 3, t0.elapsed());

    // anchor at the workspace root (cargo runs benches with cwd set to the
    // package root, rust/)
    res.write_json(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hot_paths.json"));
}
