//! Microbenchmarks of the hot paths (EXPERIMENTS.md §Perf): cache-sim
//! access rate, tile scanning, prototile replay, miss-model throughput.
use std::time::Instant;

use latticetile::cache::{CacheSim, CacheSpec, Policy};
use latticetile::codegen::executor::{prototile_points, MatmulBuffers, TiledExecutor};
use latticetile::conflict::MissModel;
use latticetile::domain::{ops, IterOrder};
use latticetile::lattice::IMat;
use latticetile::tiling::{TileBasis, TiledSchedule};

fn rate(label: &str, ops_done: u64, t: std::time::Duration) {
    println!(
        "{label:<42} {:>10.1} Mops/s  ({ops_done} ops in {t:?})",
        ops_done as f64 / t.as_secs_f64() / 1e6
    );
}

fn main() {
    println!("=== hot-path microbenchmarks ===");

    // cache sim raw access rate
    let mut sim = CacheSim::new(CacheSpec::HASWELL_L1D, Policy::Lru).without_classification();
    let n_acc = 20_000_000u64;
    let t0 = Instant::now();
    for i in 0..n_acc {
        sim.access(((i * 72) % (1 << 20)) as usize);
    }
    rate("cache sim access (no classification)", n_acc, t0.elapsed());

    let mut sim = CacheSim::new(CacheSpec::HASWELL_L1D, Policy::Lru);
    let n_acc = 2_000_000u64;
    let t0 = Instant::now();
    for i in 0..n_acc {
        sim.access(((i * 72) % (1 << 20)) as usize);
    }
    rate("cache sim access (3-C classification)", n_acc, t0.elapsed());

    // tile scanning: skewed basis, interior replay vs filter scan
    let basis = TileBasis::from_cols(IMat::from_rows(&[
        &[32, 0, 8],
        &[0, 16, 0],
        &[-8, 0, 16],
    ]));
    let sched = TiledSchedule::new(basis.clone());
    let kernel = ops::matmul(256, 256, 256, 8, 0);
    use latticetile::domain::order::Scanner;
    let t0 = Instant::now();
    let mut cnt = 0u64;
    sched.scan_points(kernel.extents(), &mut |_: &[i64]| cnt += 1);
    rate("skewed tile scan_points (filter scan)", cnt, t0.elapsed());

    let proto = prototile_points(&basis);
    println!("prototile size: {} points", proto.len());

    let exec = TiledExecutor::new(TiledSchedule::new(basis));
    let mut bufs = MatmulBuffers::from_kernel(&kernel);
    let t0 = Instant::now();
    exec.run(&mut bufs, &kernel);
    rate(
        "TiledExecutor (interior replay) matmul pts",
        (256u64).pow(3),
        t0.elapsed(),
    );

    // miss model throughput
    let small = ops::matmul(32, 32, 32, 8, 0);
    let model = MissModel::new(&small, &CacheSpec::HASWELL_L1D);
    let t0 = Instant::now();
    let c = model.exact(&IterOrder::lex(3));
    rate("miss model exact (accesses)", c.points * 3, t0.elapsed());
    let classes: Vec<i64> = (0..64).step_by(8).collect();
    let t0 = Instant::now();
    let c = model.sampled(&IterOrder::lex(3), &classes);
    rate("miss model sampled 8/64 (accesses)", c.points * 3, t0.elapsed());
}
