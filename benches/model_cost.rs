//! E9 / §4.0.4: analysis/model cost — exact vs sampled vs K−1 closed form.
use latticetile::experiments::{harness, model_cost};

fn main() {
    println!("=== §4.0.4: model evaluation cost ===");
    println!(
        "{:>5} {:>14} {:>14} {:>16} {:>16}",
        "n", "exact Eq.(4)", "paper Δ-rule", "sampled(8)", "K−1 closed form"
    );
    for r in model_cost::run(&[16, 24, 32, 48, 64], 2) {
        println!(
            "{:>5} {:>14} {:>14} {:>16} {:>16}",
            r.n,
            harness::fmt_dur(r.exact),
            harness::fmt_dur(r.exact_paper),
            harness::fmt_dur(r.sampled),
            harness::fmt_dur(r.k_minus_one)
        );
    }
}
