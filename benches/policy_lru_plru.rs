//! E10 / §1.1.4: LRU vs tree-PLRU miss counts over the same schedules.
use latticetile::experiments::policy;

fn main() {
    println!("=== §1.1.4: LRU vs PLRU ===");
    println!("{:>5} {:<22} {:>12} {:>12} {:>8}", "n", "strategy", "LRU", "PLRU", "Δrel");
    for r in policy::run(&[96, 128]) {
        println!(
            "{:>5} {:<22} {:>12} {:>12} {:>8.3}",
            r.n, r.strategy, r.lru, r.plru, r.rel_delta
        );
    }
}
