//! E7 / Figure 5: spatial-reuse (cacheline utilization) of rect vs lattice tiles.
use latticetile::experiments::fig5;

fn main() {
    println!("=== Figure 5: cacheline utilization (interior tiles) ===");
    for n in [128i64, 256, 512] {
        let (rect, lattice) = fig5::run(n);
        println!(
            "n={n:<5} rect: mean {:.3} [{:.3},{:.3}] ({} tiles)   lattice: mean {:.3} [{:.3},{:.3}] ({} tiles)",
            rect.mean, rect.min, rect.max, rect.tiles_measured,
            lattice.mean, lattice.min, lattice.max, lattice.tiles_measured
        );
        assert!(rect.mean >= lattice.mean, "Fig.5 claim violated");
    }
    println!("(lattice tiles trade spatial reuse for per-set volume — the paper's Fig.5)");
}
