//! Strategy race bench — lattice model vs cache-oblivious vs
//! latency-curve tiling, per Table-1 kernel and dtype, with the
//! parameter-free flat fallback as the degradation baseline. Besides the
//! console table, results are written machine-readably to
//! `BENCH_strategy_race.json` (label → GFLOP/s), mirroring
//! `BENCH_hot_paths.json`, and gated by `python/check_bench.py` in CI
//! through the committed ratio floors (auto ≥ flat, lattice vs rivals).

use latticetile::experiments::strategy_race;
use latticetile::tiling::StrategyKind;

fn main() {
    // BENCH_QUICK=1 (CI smoke): reduced sizes so the binary can't bit-rot
    let quick = std::env::var("BENCH_QUICK").is_ok();
    println!("=== tiling-strategy race: model-driven lattice vs rivals ===");
    println!(
        "{:<16} {:>5} {:>12} {:>12} {:>12} {:>12} {:>12} {:>10} {:>12}",
        "kernel",
        "dtype",
        "lattice",
        "oblivious",
        "latency",
        "flat",
        "auto",
        "winner",
        "model miss"
    );
    let cells = strategy_race::run(quick);
    for c in &cells {
        println!(
            "{:<16} {:>5} {:>12.2} {:>12.2} {:>12.2} {:>12.2} {:>12.2} {:>10} {:>12}",
            c.kernel,
            c.dtype.name(),
            c.rate_of(StrategyKind::Lattice),
            c.rate_of(StrategyKind::Oblivious),
            c.rate_of(StrategyKind::Latency),
            c.flat,
            c.auto,
            c.winner.name(),
            c.predicted_misses
                .map(|m| m.to_string())
                .unwrap_or_else(|| "-".to_string()),
        );
    }
    let (wins, total, misses) = strategy_race::win_summary(&cells);
    println!(
        "\nmodel-vs-empirical: lattice won {wins}/{total} cells ({misses} model misses)"
    );
    // the invariant the committed ratio floors also gate: auto dispatch
    // (the recorded race winner) must never serve slower than the
    // parameter-free flat fallback — machine-independent because both
    // sides are measured in the same run
    // (0.75 here is a loose in-run tripwire; the committed baseline's
    // ratio floor is the tighter CI gate)
    for c in &cells {
        assert!(
            c.auto >= c.flat * 0.75,
            "{} {}: auto winner ({:.2} GFLOP/s) fell below the flat fallback ({:.2} GFLOP/s)",
            c.kernel,
            c.dtype.name(),
            c.auto,
            c.flat
        );
    }
    // anchor at the workspace root (cargo runs benches with cwd set to
    // the package root, rust/)
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_strategy_race.json");
    match std::fs::write(path, strategy_race::to_json(&cells)) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncannot write {path}: {e}"),
    }
}
