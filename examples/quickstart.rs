//! Quickstart: the whole pipeline on one page.
//!
//! 1. Describe a computation (matmul) and a cache (Haswell L1d).
//! 2. Build the associativity lattice `L(C, φ)` for each operand (§2.3).
//! 3. Evaluate the actual-miss model, Eq. (1) (§2.4).
//! 4. Select a tiling with the paper's `K−1` rule + model search (§4.0.4).
//! 5. Execute the tiled schedule, verify numerics, compare simulated
//!    misses against the naive loop nest.
//!
//! Run: `cargo run --release --example quickstart`

use latticetile::cache::{CacheSim, CacheSpec, Policy};
use latticetile::codegen::executor::{KernelBuffers, TiledExecutor};
use latticetile::codegen::{max_abs_diff, run_trace_only};
use latticetile::conflict::MissModel;
use latticetile::domain::{ops, IterOrder, JointDomain};
use latticetile::tiling;

fn main() {
    // -- 1. computation + cache ------------------------------------------
    let n = 128i64;
    let kernel = ops::matmul(n, n, n, 8, 0);
    let spec = CacheSpec::HASWELL_L1D;
    println!(
        "matmul {n}³ (f64, column-major), cache: {} KiB, {}B lines, {}-way → {} sets\n",
        spec.capacity / 1024,
        spec.line,
        spec.ways,
        spec.n_sets()
    );

    // Table 1, operationally: the joint iteration domain of the paper is
    // equivalent to the loop nest + access functions we use everywhere.
    let jd = JointDomain::of_kernel(&kernel);
    println!(
        "joint iteration domain: {} coordinates, {} H-constraints (Table 1)",
        jd.extents.len(),
        jd.constraints.len()
    );

    // -- 2. conflict lattices ---------------------------------------------
    let model = MissModel::new(&kernel, &spec);
    for (i, oc) in model.analysis().operands.iter().enumerate() {
        println!(
            "operand {}: L(C,φ) det={} — every {}th element shares a set-class",
            kernel.operand(i).table.name(),
            oc.operand_lattice.det_abs(),
            model.analysis().period
        );
    }

    // -- 3. miss model on the naive order ---------------------------------
    // (exact evaluation is O(|D|); use a smaller instance for the demo)
    let demo = ops::matmul_padded(32, 32, 32, n, n, n, 8, 0);
    let demo_model = MissModel::new(&demo, &spec);
    let naive_counts = demo_model.exact(&IterOrder::lex(3));
    println!(
        "\nmodel, naive ijk on 32³ slice: {} misses ({} cold) / {} points",
        naive_counts.misses, naive_counts.cold, naive_counts.points
    );

    // -- 4. tile selection --------------------------------------------------
    let ranked = tiling::select(&demo, &spec, 8);
    println!("\ntop-3 plans from the §4.0.4 selector:");
    for p in ranked.iter().take(3) {
        println!(
            "  {:<28} predicted misses {:>8}",
            p.name,
            p.predicted.as_ref().map(|c| c.misses).unwrap_or(0)
        );
    }
    let best = &ranked[0];

    // -- 5. execute + verify ------------------------------------------------
    let schedule = latticetile::tiling::TiledSchedule::new(best.schedule.basis().clone());
    let mut sim_naive = CacheSim::new(spec, Policy::Lru).without_classification();
    run_trace_only(&kernel, &IterOrder::lex(3), &mut sim_naive);
    let mut sim_tiled = CacheSim::new(spec, Policy::Lru).without_classification();
    run_trace_only(&kernel, &schedule, &mut sim_tiled);

    let exec = TiledExecutor::new(schedule);
    let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
    let want = bufs.reference();
    let t0 = std::time::Instant::now();
    exec.run(&mut bufs, &kernel);
    let wall = t0.elapsed();
    assert!(max_abs_diff(&want, &bufs.output()) < 1e-9, "numerics!");

    println!(
        "\nfull {n}³ run with plan '{}': result verified against reference",
        best.name
    );
    println!(
        "simulated L1 misses: naive ijk = {}, tiled = {} ({:.1}x fewer), wall {:?}",
        sim_naive.stats().misses(),
        sim_tiled.stats().misses(),
        sim_naive.stats().misses() as f64 / sim_tiled.stats().misses() as f64,
        wall
    );
}
