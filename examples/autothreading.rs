//! Auto-threading (§4.0.3 / Figure 6): parallel tiled matmul over
//! footpoint column bands, scaling with thread count, vs the
//! graphite-analog whose coarse fixed tiles cap its parallel grain.
//!
//! Run: `cargo run --release --example autothreading`

use latticetile::codegen::executor::KernelBuffers;
use latticetile::codegen::{max_abs_diff, run_parallel};
use latticetile::domain::ops;
use latticetile::experiments::fig6;

fn main() {
    let n = 256i64;
    let threads = [1usize, 2, 4, 8];

    let (ours_grain, graphite_grain) = fig6::parallel_grain(n);
    println!(
        "matmul {n}³ — parallel grain: ours {ours_grain} bands, graphite-analog {graphite_grain} bands\n"
    );

    // correctness under parallelism first
    let kernel = ops::matmul(64, 64, 64, 8, 0);
    let sched = latticetile::tiling::TiledSchedule::new(latticetile::tiling::TileBasis::rect(&[
        16, 16, 16,
    ]));
    let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
    let want = bufs.reference();
    run_parallel(&mut bufs, &kernel, &sched, 4, 1);
    assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    println!("parallel correctness: verified (4 threads, 64³)\n");

    println!("threads  ours(wall)   speedup*  graphite(wall)  speedup*");
    for row in fig6::run(n, &threads, 1) {
        println!(
            "{:>7}  {:>10.3?}  {:>6.2}x  {:>12.3?}  {:>6.2}x",
            row.threads, row.ours, row.ours_modeled, row.graphite, row.graphite_modeled
        );
    }
    println!(
        "\n* structural load-balance speedup — this host has {} core(s), so the\n\
         wall columns cannot scale; the bands are what a multicore host exploits.",
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
    );
    println!(
        "\n(the graphite-analog flattens once threads exceed its {graphite_grain} bands —\n\
         the Figure 6 mechanism; `latticetile bench fig6 --full` runs to 20 threads)"
    );
}
