//! End-to-end driver (DESIGN.md E11): the full three-layer system serving
//! a real batched workload.
//!
//! Layer 1/2 (build time): the Pallas tiled-matmul kernel inside the JAX
//! model, AOT-lowered to HLO text by `make artifacts`.
//! Layer 3 (this binary): the Rust coordinator loads the artifacts via
//! PJRT, plans the shape with the associativity-lattice model, batches
//! incoming jobs, executes, and reports latency/throughput. Python never
//! runs here.
//!
//! Run: `make artifacts && cargo run --release --example serve_matmul`

use std::time::{Duration, Instant};

use latticetile::cache::CacheSpec;
use latticetile::codegen::DType;
use latticetile::coordinator::{Backend, Planner, Service, ServiceConfig};
use latticetile::runtime::Registry;

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.tsv").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let (m, k, n) = (128usize, 128, 128);
    let jobs = 64usize;

    // planner trace: show what the lattice model decided for this shape
    let registry = Registry::load(&dir)?;
    let planner = Planner::new(CacheSpec::HASWELL_L1D);
    let plan = planner.plan(&registry, m, k, n, DType::F32);
    println!(
        "planner: shape {m}x{k}x{n} → plan '{}' (model tile {:?}, predicted misses {}) → artifact {}",
        plan.plan_name, plan.model_tile, plan.predicted_misses, plan.artifact
    );
    println!("planner: two-level blocking → {}", plan.describe());

    // deterministic inputs
    let mut seed = 0xDEADBEEFu64;
    let mut rnd = move || {
        seed ^= seed << 13;
        seed ^= seed >> 7;
        seed ^= seed << 17;
        ((seed % 2000) as f32 / 1000.0) - 1.0
    };
    let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();

    let svc = Service::start(
        &dir,
        y.clone(),
        ServiceConfig {
            m,
            k,
            n,
            batch_window: Duration::from_millis(2),
            spec: CacheSpec::HASWELL_L1D,
            backend: Backend::Pjrt,
            ..ServiceConfig::default()
        },
    )?;

    // submit a burst of jobs, verify a sample against a CPU oracle
    let xs: Vec<Vec<f32>> = (0..jobs).map(|_| (0..m * k).map(|_| rnd()).collect()).collect();
    let t0 = Instant::now();
    let rxs: Vec<_> = xs
        .iter()
        .map(|x| svc.submit(x.clone()).expect("submit"))
        .collect();
    let mut results = Vec::with_capacity(jobs);
    for rx in rxs {
        results.push(rx.recv()?);
    }
    let wall = t0.elapsed();

    // verify job 0 and job jobs-1 numerically
    for &idx in &[0usize, jobs - 1] {
        let mut want = vec![0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let xv = xs[idx][i * k + kk];
                for j in 0..n {
                    want[i * n + j] += xv * y[kk * n + j];
                }
            }
        }
        let maxd = results[idx]
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(maxd < 1e-2, "job {idx} numerics off by {maxd}");
    }
    println!("numerics: sampled job results verified against CPU oracle");

    let (metrics, _worker_wall) = svc.stop();
    println!("\nserved {jobs} jobs of {m}x{k}x{n} f32 matmul in {wall:?}");
    println!("{}", metrics.report(wall));
    println!(
        "\nall layers composed: Pallas kernel → JAX model → HLO text → PJRT → rust coordinator"
    );
    Ok(())
}
