//! Lattice vs rectangular tiles, the §4.0.2 story: lattice tiles maximize
//! per-set addressable volume (Fig. 3) but lose spatial reuse (Fig. 5);
//! the two families end up close on real caches, with lattice winning on
//! pathological power-of-two strides.
//!
//! Run: `cargo run --release --example lattice_vs_rect`

use latticetile::cache::{CacheSim, CacheSpec, Policy};
use latticetile::codegen::run_trace_only;
use latticetile::domain::ops;
use latticetile::experiments::{fig3, fig4, fig5};
use latticetile::tiling::{plan_with_kappa, TiledSchedule};

fn main() {
    // --- volume (Figure 3): exact integers, no measurement noise --------
    let r = fig3::run();
    println!("Fig.3 volumes — lattice {}, best practical rect {} ({}x{}), paper-cited 453/416",
        r.lattice_volume,
        r.best_practical_rect_volume,
        r.best_practical_rect.0,
        r.best_practical_rect.1
    );
    let (mn, mx) = fig3::rect_point_count_varies(&fig3::paper_lattice(), 24, 20, 6);
    println!(
        "Fig.3 regularity — 24x20 rect tiles hold {mn}..{mx} lattice points; lattice tiles always 1\n"
    );

    // --- spatial reuse (Figure 5) ----------------------------------------
    let (rect_u, lat_u) = fig5::run(256);
    println!(
        "Fig.5 spatial reuse — mean cacheline utilization: rect {:.3}, lattice {:.3}\n",
        rect_u.mean, lat_u.mean
    );

    // --- end to end: misses on pathological vs benign sizes -------------
    let spec = CacheSpec::HASWELL_L1D;
    println!("simulated Haswell-L1 misses (K−1 lattice plan vs best rect plan):");
    for n in [96i64, 128, 192, 256] {
        let kernel = ops::matmul(n, n, n, 8, 0);
        let (rect_name, rect) = fig4::best_rect_plan_for(n, &spec);
        let small = ops::matmul_padded(48.min(n), 48.min(n), 48.min(n), n, n, n, 8, 0);
        let lat = plan_with_kappa(&small, &spec, 1, spec.ways as i128 - 1)
            .expect("lattice plan");
        let lat = TiledSchedule::new(lat.schedule.basis().clone());
        let mut s1 = CacheSim::new(spec, Policy::Lru).without_classification();
        run_trace_only(&kernel, &rect, &mut s1);
        let mut s2 = CacheSim::new(spec, Policy::Lru).without_classification();
        run_trace_only(&kernel, &lat, &mut s2);
        println!(
            "  n={n:<4} rect[{rect_name}] = {:>9}   lattice[K-1 on B] = {:>9}",
            s1.stats().misses(),
            s2.stats().misses()
        );
    }
    println!("\n(expected shape per the paper: close overall; neither dominates — the");
    println!(" volume win of Fig.3 is offset by the spatial-reuse loss of Fig.5)");
}
