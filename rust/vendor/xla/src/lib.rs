//! Minimal offline stand-in for the `xla-rs` PJRT bindings.
//!
//! This build environment has no crates.io access and no PJRT shared
//! library, so the subset of the `xla` API the workspace's
//! `runtime::registry` module uses is reimplemented here as a typed stub:
//! everything compiles and links, and every operation that would need a
//! real PJRT runtime fails at *runtime* with a clear error instead.
//! Host-side [`Literal`] plumbing (construction, reshape, extraction) is
//! real, so code paths up to the device boundary stay testable. Swap this
//! path dependency for the real crate when building networked.

use std::fmt;

/// Error type for all stubbed operations. Implements `std::error::Error`
/// so `?` converts into the workspace's `anyhow::Error`.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what} unavailable: offline `xla` stub (no PJRT runtime in this build)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can be extracted into.
pub trait ElementType: Copy {
    fn from_f32(v: f32) -> Self;
    fn to_f32(self) -> f32;
}

impl ElementType for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
    fn to_f32(self) -> f32 {
        self
    }
}

/// A host-side tensor of `f32` data with a shape (the only dtype the
/// workspace moves across the PJRT boundary).
#[derive(Clone, Debug)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: ElementType>(v: &[T]) -> Literal {
        Literal {
            data: v.iter().map(|x| x.to_f32()).collect(),
            dims: vec![v.len() as i64],
        }
    }

    /// Reshape to `dims` (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n < 0 || n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Unwrap a 1-tuple result (XLA computations lowered with
    /// `return_tuple=True` wrap the root in a tuple; the stub models the
    /// tuple as identity).
    pub fn to_tuple1(&self) -> Result<Literal> {
        Ok(self.clone())
    }

    /// Extract the flat data.
    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }
}

/// Parsed HLO module (stub: never constructible offline).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HLO text parsing"))
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-side buffer handle (stub: never constructible offline).
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("device-to-host transfer"))
    }
}

/// Compiled executable handle (stub: never constructible offline).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PJRT execution"))
    }
}

/// PJRT client handle. `cpu()` fails offline, so no executable, buffer or
/// HLO module can ever exist behind this stub.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("XLA compilation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.dims(), &[6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 2]).is_err());
        assert_eq!(r.to_tuple1().unwrap().to_vec::<f32>().unwrap().len(), 6);
    }

    #[test]
    fn runtime_operations_fail_cleanly() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("offline"), "{e}");
        assert!(HloModuleProto::from_text_file("/nope").is_err());
    }
}
