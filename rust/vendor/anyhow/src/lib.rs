//! Minimal offline stand-in for the `anyhow` crate.
//!
//! This build environment has no crates.io access, so the subset of the
//! `anyhow` API the workspace uses is reimplemented here on top of plain
//! `String` messages: [`Error`], [`Result`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros. Error chains are
//! flattened into `"context: cause"` strings at attachment time, so both
//! `{}` and `{:#}` render the full chain. Swap this path dependency for
//! the real crate when building networked.

use std::fmt;

/// A string-backed error value. Like `anyhow::Error`, it deliberately does
/// NOT implement `std::error::Error` so the blanket
/// `From<E: std::error::Error>` impl below stays coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }

    /// Prepend a context layer (`"context: cause"`).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: format!("{context}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        Error::msg(&err)
    }
}

/// `anyhow::Result<T>` — a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context()` / `.with_context()` to `Result`
/// and `Option`, mirroring `anyhow::Context`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built as by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<usize> {
        let n: usize = s.parse().context("not a number")?;
        ensure!(n < 100, "{n} too large");
        Ok(n)
    }

    #[test]
    fn happy_path() {
        assert_eq!(parse("42").unwrap(), 42);
    }

    #[test]
    fn context_prepends() {
        let e = parse("abc").unwrap_err();
        assert!(e.to_string().starts_with("not a number:"), "{e}");
    }

    #[test]
    fn ensure_formats() {
        let e = parse("105").unwrap_err();
        assert_eq!(e.to_string(), "105 too large");
    }

    #[test]
    fn expr_arm_accepts_trailing_comma() {
        let msg = String::from("boom");
        let e = crate::anyhow!(msg,);
        assert_eq!(e.to_string(), "boom");
    }

    #[test]
    fn bare_ensure_and_option_context() {
        fn f(cond: bool) -> Result<()> {
            ensure!(cond);
            Ok(())
        }
        assert!(f(false).unwrap_err().to_string().contains("condition failed"));
        let none: Option<u32> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
