//! Tables and affine index maps `φ` — §2.1.1 of the paper (DESIGN.md S3).

pub mod map;
pub mod table;

pub use map::{IndexMap, Layout};
pub use table::Table;
