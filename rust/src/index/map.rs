//! Affine index maps `φ : Q(A) → a(A)` — Definition 1.
//!
//! A bijection from the `d`-dimensional table index set onto the linear
//! array. We support the affine family `φ(x) = Σ w_r x_r + offset`, which
//! covers column-major, row-major, and padded layouts; the weights also
//! feed directly into the conflict-lattice construction
//! (`Lattice::from_congruence`).

/// Memory layout convention for constructing standard maps.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Layout {
    /// `φ_c(i_1,…,i_d) = i_1 + m_1(i_2 + m_2(…))` — first index fastest.
    ColumnMajor,
    /// `φ_r(i_1,…,i_d) = i_d + m_d(i_{d−1} + …)` — last index fastest.
    RowMajor,
}

/// An affine index map with explicit per-dimension weights (strides, in
/// elements) and an affine offset (the linearized base address of the
/// table, `φ(q_A)` in the paper's terms).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct IndexMap {
    /// Logical dims `(m_1, …, m_d)` of the table.
    dims: Vec<i64>,
    /// Strides `w_r` in elements: `φ(x) = Σ w_r x_r + offset`.
    weights: Vec<i64>,
    /// Affine offset in elements.
    offset: i64,
}

impl IndexMap {
    /// Standard dense layout (no padding).
    pub fn dense(dims: &[i64], layout: Layout) -> IndexMap {
        Self::padded(dims, dims, layout)
    }

    /// Layout with padded physical dims (`padded[r] ≥ dims[r]`): pad rows /
    /// leading dimensions the way `lda` does in BLAS. Padding is one of the
    /// paper's levers for reshaping the conflict lattice.
    pub fn padded(dims: &[i64], padded: &[i64], layout: Layout) -> IndexMap {
        assert_eq!(dims.len(), padded.len());
        assert!(!dims.is_empty());
        assert!(
            dims.iter().zip(padded).all(|(&m, &p)| m >= 1 && p >= m),
            "padded dims must dominate logical dims"
        );
        let d = dims.len();
        let mut weights = vec![0i64; d];
        match layout {
            Layout::ColumnMajor => {
                let mut w = 1i64;
                for r in 0..d {
                    weights[r] = w;
                    w = w.checked_mul(padded[r]).expect("table too large");
                }
            }
            Layout::RowMajor => {
                let mut w = 1i64;
                for r in (0..d).rev() {
                    weights[r] = w;
                    w = w.checked_mul(padded[r]).expect("table too large");
                }
            }
        }
        IndexMap {
            dims: dims.to_vec(),
            weights,
            offset: 0,
        }
    }

    /// Arbitrary affine map (caller asserts bijectivity on the index set).
    pub fn from_weights(dims: &[i64], weights: &[i64], offset: i64) -> IndexMap {
        assert_eq!(dims.len(), weights.len());
        IndexMap {
            dims: dims.to_vec(),
            weights: weights.to_vec(),
            offset,
        }
    }

    /// Shift the affine offset (elements): models the table's base address,
    /// i.e. the paper's translate `q_A` of the conflict lattice.
    pub fn with_offset(mut self, offset: i64) -> IndexMap {
        self.offset = offset;
        self
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    pub fn weights(&self) -> &[i64] {
        &self.weights
    }

    pub fn offset(&self) -> i64 {
        self.offset
    }

    /// Apply: `φ(x)` in elements. Panics (debug) if out of the index set.
    pub fn apply(&self, x: &[i64]) -> i64 {
        debug_assert_eq!(x.len(), self.dims.len());
        debug_assert!(
            self.in_bounds(x),
            "index {x:?} out of table bounds {:?}",
            self.dims
        );
        self.offset
            + x.iter()
                .zip(&self.weights)
                .map(|(&xi, &wi)| xi * wi)
                .sum::<i64>()
    }

    /// Apply without the bounds debug-check (tile-boundary math may
    /// legitimately evaluate φ outside Q(A)).
    pub fn apply_unchecked(&self, x: &[i64]) -> i64 {
        self.offset
            + x.iter()
                .zip(&self.weights)
                .map(|(&xi, &wi)| xi * wi)
                .sum::<i64>()
    }

    pub fn in_bounds(&self, x: &[i64]) -> bool {
        x.iter().zip(&self.dims).all(|(&xi, &m)| xi >= 0 && xi < m)
    }

    /// Inverse `φ⁻¹(e)` via successive div/mod — valid for maps built by
    /// [`IndexMap::dense`]/[`IndexMap::padded`]. Returns `None` if `e` does
    /// not correspond to a point of the (unpadded) index set.
    pub fn invert(&self, e: i64) -> Option<Vec<i64>> {
        let mut rem = e - self.offset;
        if rem < 0 {
            return None;
        }
        // sort dims by descending weight, peel off with div/mod
        let d = self.dims.len();
        let mut order: Vec<usize> = (0..d).collect();
        order.sort_by_key(|&r| std::cmp::Reverse(self.weights[r]));
        let mut x = vec![0i64; d];
        for &r in &order {
            let w = self.weights[r];
            assert!(w > 0, "invert requires positive weights");
            x[r] = rem / w;
            rem -= x[r] * w;
        }
        if rem == 0 && self.in_bounds(&x) {
            Some(x)
        } else {
            None
        }
    }

    /// Number of elements in the (logical) index set.
    pub fn size(&self) -> i64 {
        self.dims.iter().product()
    }

    /// The weights as `i128` for lattice construction.
    pub fn weights_i128(&self) -> Vec<i128> {
        self.weights.iter().map(|&w| w as i128).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn column_major_matches_paper_formula() {
        // φ_c(i1,i2,i3) = i1 + m1*(i2 + m2*i3)
        let m = IndexMap::dense(&[3, 4, 5], Layout::ColumnMajor);
        for i1 in 0..3 {
            for i2 in 0..4 {
                for i3 in 0..5 {
                    assert_eq!(m.apply(&[i1, i2, i3]), i1 + 3 * (i2 + 4 * i3));
                }
            }
        }
    }

    #[test]
    fn row_major_matches_paper_formula() {
        let m = IndexMap::dense(&[3, 4, 5], Layout::RowMajor);
        for i1 in 0..3 {
            for i2 in 0..4 {
                for i3 in 0..5 {
                    assert_eq!(m.apply(&[i1, i2, i3]), i3 + 5 * (i2 + 4 * i1));
                }
            }
        }
    }

    #[test]
    fn bijective_on_index_set() {
        for layout in [Layout::ColumnMajor, Layout::RowMajor] {
            let m = IndexMap::dense(&[4, 6], layout);
            let mut seen = std::collections::HashSet::new();
            for i in 0..4 {
                for j in 0..6 {
                    assert!(seen.insert(m.apply(&[i, j])));
                }
            }
            assert_eq!(seen.len(), 24);
            assert_eq!(*seen.iter().min().unwrap(), 0);
            assert_eq!(*seen.iter().max().unwrap(), 23);
        }
    }

    #[test]
    fn invert_roundtrip() {
        let m = IndexMap::dense(&[7, 5, 3], Layout::ColumnMajor);
        for e in 0..m.size() {
            let x = m.invert(e).expect("in range");
            assert_eq!(m.apply(&x), e);
        }
        assert_eq!(m.invert(m.size()), None);
        assert_eq!(m.invert(-1), None);
    }

    #[test]
    fn padded_layout_gaps() {
        // logical 3x3 inside physical 5x3 (column-major, lda=5)
        let m = IndexMap::padded(&[3, 3], &[5, 3], Layout::ColumnMajor);
        assert_eq!(m.apply(&[0, 1]), 5);
        assert_eq!(m.apply(&[2, 2]), 12);
        // linear index 3 (padding row) is not the image of any point
        assert_eq!(m.invert(3), None);
        assert_eq!(m.invert(5), Some(vec![0, 1]));
    }

    #[test]
    fn offset_translates() {
        let m = IndexMap::dense(&[4, 4], Layout::ColumnMajor).with_offset(100);
        assert_eq!(m.apply(&[0, 0]), 100);
        assert_eq!(m.invert(100), Some(vec![0, 0]));
        assert_eq!(m.invert(99), None);
    }
}
