//! Tables (`(m_1,…,m_d)`-tables in the paper's vocabulary): a named,
//! shaped operand bound to an index map and a byte base address.

use super::map::{IndexMap, Layout};

/// One operand array: logical shape + layout + element size + base address.
///
/// The byte base address matters: the paper's conflict lattices are
/// *translated* by the base point `q_A` (§2.1.1), which is determined by
/// where the array starts relative to the cache's set period.
#[derive(Clone, Debug)]
pub struct Table {
    name: String,
    map: IndexMap,
    /// Element size in bytes (e.g. 8 for f64).
    elem: usize,
    /// Base address in bytes of element `(0,…,0)`.
    base: usize,
}

impl Table {
    pub fn new(name: &str, dims: &[i64], layout: Layout, elem: usize, base: usize) -> Table {
        Table {
            name: name.to_string(),
            map: IndexMap::dense(dims, layout),
            elem,
            base,
        }
    }

    pub fn with_map(name: &str, map: IndexMap, elem: usize, base: usize) -> Table {
        Table {
            name: name.to_string(),
            map,
            elem,
            base,
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn map(&self) -> &IndexMap {
        &self.map
    }

    pub fn dims(&self) -> &[i64] {
        self.map.dims()
    }

    pub fn rank(&self) -> usize {
        self.map.rank()
    }

    pub fn elem(&self) -> usize {
        self.elem
    }

    pub fn base(&self) -> usize {
        self.base
    }

    /// Byte address of the element at table index `x`.
    pub fn addr(&self, x: &[i64]) -> usize {
        let e = self.map.apply(x);
        debug_assert!(e >= 0);
        self.base + (e as usize) * self.elem
    }

    /// Byte address without bounds checking.
    pub fn addr_unchecked(&self, x: &[i64]) -> usize {
        let e = self.map.apply_unchecked(x);
        (self.base as i64 + e * self.elem as i64) as usize
    }

    /// Total bytes spanned by the (possibly padded) table: the linear span
    /// `Σ w_r (m_r − 1) + 1` elements for a monotone affine map.
    pub fn bytes(&self) -> usize {
        let span: i64 = self
            .map
            .weights()
            .iter()
            .zip(self.map.dims())
            .map(|(&w, &m)| w.abs() * (m - 1))
            .sum::<i64>()
            + 1;
        (span as usize) * self.elem
    }

    /// The table's *base point* `q_A` relative to a cache with a set period
    /// of `period_elems` elements: the lattice translate `φ(q_A) mod period`
    /// (§2.1.1). Returned as the element-offset residue.
    pub fn base_residue_elems(&self, period_elems: i64) -> i64 {
        let base_elems = (self.base / self.elem) as i64 + self.map.offset();
        base_elems.rem_euclid(period_elems)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addresses() {
        let t = Table::new("A", &[8, 5], Layout::ColumnMajor, 8, 0x1000);
        assert_eq!(t.addr(&[0, 0]), 0x1000);
        assert_eq!(t.addr(&[1, 0]), 0x1008);
        assert_eq!(t.addr(&[0, 1]), 0x1000 + 8 * 8);
        assert_eq!(t.bytes(), 8 * 5 * 8);
    }

    #[test]
    fn base_residue() {
        // period of 64 elements; base at element 100 → residue 36
        let t = Table::new("A", &[4, 4], Layout::ColumnMajor, 8, 100 * 8);
        assert_eq!(t.base_residue_elems(64), 36);
        let t0 = Table::new("A", &[4, 4], Layout::ColumnMajor, 8, 0);
        assert_eq!(t0.base_residue_elems(64), 0);
    }
}
