//! PJRT runtime — loads and executes the AOT-compiled JAX/Pallas
//! artifacts from the Rust hot path (DESIGN.md S13).
//!
//! Python runs only at build time (`make artifacts`); this module makes
//! the binary self-contained afterwards: HLO **text** → `HloModuleProto`
//! → `XlaComputation` → PJRT CPU executable, cached per variant.

pub mod registry;

pub use registry::{ArtifactKind, ArtifactMeta, Engine, Registry};
