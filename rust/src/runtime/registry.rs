//! Artifact registry + PJRT execution engine.
//!
//! `manifest.tsv` (written by `python/compile/aot.py`) lists every lowered
//! HLO-text artifact with its shapes and block sizes. [`Registry`] parses
//! it; [`Engine`] owns the PJRT CPU client and a cache of compiled
//! executables, and runs matmuls with plain `f32` slices in/out.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, PoisonError};

use anyhow::{bail, Context, Result};

/// What a lowered artifact computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// The Pallas tiled-matmul kernel wrapped in the L2 model.
    PallasTiledMatmul,
    /// The pure-jnp reference graph (numeric cross-check).
    JnpRefMatmul,
    /// vmapped batch-of-left-operands variant for the serve path.
    PallasTiledMatmulBatched,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<ArtifactKind> {
        Ok(match s {
            "pallas_tiled_matmul" => ArtifactKind::PallasTiledMatmul,
            "jnp_ref_matmul" => ArtifactKind::JnpRefMatmul,
            "pallas_tiled_matmul_batched" => ArtifactKind::PallasTiledMatmulBatched,
            other => bail!("unknown artifact kind {other:?}"),
        })
    }
}

/// One row of the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    pub kind: ArtifactKind,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub bm: usize,
    pub bk: usize,
    pub bn: usize,
    pub batch: usize,
}

/// The per-dtype autotune winner slots, sharded the way the planner
/// shards its plan cache: one lock **per dtype slot** (indexed by
/// [`DType::index`](crate::codegen::DType::index)), so concurrent serve
/// clients recording or reading different dtypes' winners never contend
/// on a shared lock, and same-dtype reads hold their shard's lock only
/// for a `Copy` load. Interior mutability keeps the recording path
/// `&self` — a shared registry behind the serve supervisor can accept
/// late calibration results without an exclusive borrow.
#[derive(Debug, Default)]
struct MicroShapeSlots {
    slots: [Mutex<Option<crate::codegen::MicroShape>>; 2],
}

impl MicroShapeSlots {
    fn get(&self, dtype: crate::codegen::DType) -> Option<crate::codegen::MicroShape> {
        // the slot is plain Copy data: a lock poisoned by an unwinding
        // writer loses nothing
        *self.slots[dtype.index()]
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
    }

    fn set(&self, dtype: crate::codegen::DType, shape: crate::codegen::MicroShape) {
        *self.slots[dtype.index()]
            .lock()
            .unwrap_or_else(PoisonError::into_inner) = Some(shape);
    }
}

/// The per-dtype **tiling-strategy** winner maps, sharded exactly like
/// [`MicroShapeSlots`] (one lock per dtype), keyed by
/// `(kernel name, shape class)` — the granularity the strategy race
/// measures at ([`crate::codegen::autotune::race_strategy_rates`]). Both
/// kinds of autotune result (register geometries and strategy winners)
/// thus live behind one `*_for` lookup shape on the registry.
#[derive(Debug, Default)]
struct StrategySlots {
    slots: [Mutex<HashMap<(String, crate::tiling::ShapeClass), crate::tiling::StrategyKind>>; 2],
}

impl StrategySlots {
    fn get(
        &self,
        dtype: crate::codegen::DType,
        kernel: &str,
        class: crate::tiling::ShapeClass,
    ) -> Option<crate::tiling::StrategyKind> {
        self.slots[dtype.index()]
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .get(&(kernel.to_string(), class))
            .copied()
    }

    fn set(
        &self,
        dtype: crate::codegen::DType,
        kernel: &str,
        class: crate::tiling::ShapeClass,
        kind: crate::tiling::StrategyKind,
    ) {
        self.slots[dtype.index()]
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .insert((kernel.to_string(), class), kind);
    }

    fn snapshot(&self) -> StrategySlots {
        let fresh = StrategySlots::default();
        for (i, slot) in self.slots.iter().enumerate() {
            let src = slot.lock().unwrap_or_else(PoisonError::into_inner);
            *fresh.slots[i].lock().unwrap_or_else(PoisonError::into_inner) = src.clone();
        }
        fresh
    }
}

/// Parsed manifest of all shipped artifacts.
#[derive(Debug, Default)]
pub struct Registry {
    dir: PathBuf,
    artifacts: Vec<ArtifactMeta>,
    /// Startup-calibrated register-tile geometry class, **per dtype**
    /// ([`crate::codegen::autotune::calibrate_dtype`]); `None` until a
    /// host has run the one-shot grid race for that dtype. Sharded —
    /// see [`MicroShapeSlots`].
    micro_shape: Arc<MicroShapeSlots>,
    /// Startup-raced tiling-strategy winners, per (dtype, kernel,
    /// shape-class) ([`crate::codegen::autotune::calibrate_strategies`]);
    /// empty until a host has raced the strategies. Sharded — see
    /// [`StrategySlots`].
    strategies: Arc<StrategySlots>,
}

impl Clone for Registry {
    fn clone(&self) -> Registry {
        // snapshot the winner slots instead of sharing the Arc: a clone
        // is an independent registry (the pre-sharding value semantics),
        // not another handle onto the same calibration state
        let micro_shape = Arc::new(MicroShapeSlots::default());
        for dtype in [crate::codegen::DType::F32, crate::codegen::DType::F64] {
            if let Some(shape) = self.micro_shape.get(dtype) {
                micro_shape.set(dtype, shape);
            }
        }
        Registry {
            dir: self.dir.clone(),
            artifacts: self.artifacts.clone(),
            micro_shape,
            strategies: Arc::new(self.strategies.snapshot()),
        }
    }
}

impl Registry {
    /// Load `<dir>/manifest.tsv`.
    pub fn load(dir: &Path) -> Result<Registry> {
        let manifest = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {}", manifest.display()))?;
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 10 {
                bail!("manifest line {} has {} cols", lineno + 1, cols.len());
            }
            let u = |i: usize| -> Result<usize> {
                cols[i]
                    .parse()
                    .with_context(|| format!("manifest line {} col {i}", lineno + 1))
            };
            artifacts.push(ArtifactMeta {
                name: cols[0].to_string(),
                file: dir.join(cols[1]),
                kind: ArtifactKind::parse(cols[2])?,
                m: u(3)?,
                k: u(4)?,
                n: u(5)?,
                bm: u(6)?,
                bk: u(7)?,
                bn: u(8)?,
                batch: u(9)?,
            });
        }
        Ok(Registry {
            dir: dir.to_path_buf(),
            artifacts,
            micro_shape: Arc::new(MicroShapeSlots::default()),
            strategies: Arc::new(StrategySlots::default()),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Record the startup-calibrated register-tile geometry for one
    /// dtype — each dtype races its own (MR, NR) candidate grid
    /// ([`crate::codegen::autotune::calibrate_dtype`]). Takes `&self`:
    /// the slot is behind its dtype's shard lock, so concurrent serve
    /// clients can record or read winners without an exclusive borrow
    /// (and without serializing across dtypes).
    pub fn set_micro_shape_for(
        &self,
        dtype: crate::codegen::DType,
        shape: crate::codegen::MicroShape,
    ) {
        self.micro_shape.set(dtype, shape);
    }

    /// The calibrated register-tile geometry of `dtype`, if that
    /// dtype's calibration has run.
    pub fn micro_shape_for(
        &self,
        dtype: crate::codegen::DType,
    ) -> Option<crate::codegen::MicroShape> {
        self.micro_shape.get(dtype)
    }

    /// Record the startup-raced tiling-strategy winner for one
    /// (dtype, kernel, shape-class) cell
    /// ([`crate::codegen::autotune::calibrate_strategies`]). `&self`
    /// like [`Registry::set_micro_shape_for`]: the map is behind its
    /// dtype's shard lock, so late race results land without an
    /// exclusive borrow.
    pub fn set_strategy_for(
        &self,
        dtype: crate::codegen::DType,
        kernel: &str,
        class: crate::tiling::ShapeClass,
        kind: crate::tiling::StrategyKind,
    ) {
        self.strategies.set(dtype, kernel, class, kind);
    }

    /// The raced strategy winner of a (dtype, kernel, shape-class)
    /// cell, if that cell's race has run. The planner's `auto` choice
    /// falls back to the lattice selector when this is `None`.
    pub fn strategy_for(
        &self,
        dtype: crate::codegen::DType,
        kernel: &str,
        class: crate::tiling::ShapeClass,
    ) -> Option<crate::tiling::StrategyKind> {
        self.strategies.get(dtype, kernel, class)
    }

    pub fn artifacts(&self) -> &[ArtifactMeta] {
        &self.artifacts
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Kernel variants matching a problem size.
    pub fn variants_for(&self, m: usize, k: usize, n: usize) -> Vec<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.kind == ArtifactKind::PallasTiledMatmul && a.m == m && a.k == k && a.n == n
            })
            .collect()
    }

    /// The variant whose block shape is closest (L1 distance) to a
    /// requested tile shape — how the coordinator maps a lattice-model
    /// tile choice onto the shipped kernel set.
    pub fn closest_variant(
        &self,
        m: usize,
        k: usize,
        n: usize,
        want: (usize, usize, usize),
    ) -> Option<&ArtifactMeta> {
        self.variants_for(m, k, n).into_iter().min_by_key(|a| {
            a.bm.abs_diff(want.0) + a.bk.abs_diff(want.1) + a.bn.abs_diff(want.2)
        })
    }
}

/// PJRT CPU execution engine with an executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    registry: Registry,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    pub fn new(registry: Registry) -> Result<Engine> {
        Ok(Engine {
            client: xla::PjRtClient::cpu()?,
            registry,
            compiled: HashMap::new(),
        })
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) an artifact by name.
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let meta = self
            .registry
            .by_name(name)
            .with_context(|| format!("unknown artifact {name:?}"))?
            .clone();
        let proto = xla::HloModuleProto::from_text_file(
            meta.file.to_str().context("non-utf8 path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.compiled.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute a (possibly batched) matmul artifact on row-major `f32`
    /// data: `x` is `[batch? ×] m×k`, `y` is `k×n`; returns `[batch ×] m×n`
    /// row-major.
    pub fn run_matmul(&mut self, name: &str, x: &[f32], y: &[f32]) -> Result<Vec<f32>> {
        self.prepare(name)?;
        let meta = self.registry.by_name(name).unwrap().clone();
        let (m, k, n, b) = (meta.m, meta.k, meta.n, meta.batch.max(1));
        anyhow::ensure!(x.len() == b * m * k, "x size {} != {}", x.len(), b * m * k);
        anyhow::ensure!(y.len() == k * n, "y size {} != {}", y.len(), k * n);

        let x_shape: Vec<i64> = if meta.batch > 1 {
            vec![b as i64, m as i64, k as i64]
        } else {
            vec![m as i64, k as i64]
        };
        let xl = xla::Literal::vec1(x).reshape(&x_shape)?;
        let yl = xla::Literal::vec1(y).reshape(&[k as i64, n as i64])?;

        let exe = self.compiled.get(name).unwrap();
        let result = exe.execute::<xla::Literal>(&[xl, yl])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True → 1-tuple
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.tsv").exists()
    }

    #[test]
    fn micro_shapes_are_recorded_per_dtype() {
        use crate::codegen::{DType, MicroShape};
        let r = Registry::default();
        assert_eq!(r.micro_shape_for(DType::F32), None);
        assert_eq!(r.micro_shape_for(DType::F64), None);
        r.set_micro_shape_for(DType::F32, MicroShape::Mr8Nr6);
        assert_eq!(r.micro_shape_for(DType::F32), Some(MicroShape::Mr8Nr6));
        assert_eq!(r.micro_shape_for(DType::F64), None, "dtypes must not alias");
        r.set_micro_shape_for(DType::F64, MicroShape::Mr8Nr4);
        assert_eq!(r.micro_shape_for(DType::F64), Some(MicroShape::Mr8Nr4));
        assert_eq!(r.micro_shape_for(DType::F32), Some(MicroShape::Mr8Nr6));
        // a clone snapshots the winners — it is not another handle onto
        // the same slots
        let snap = r.clone();
        r.set_micro_shape_for(DType::F32, MicroShape::Mr16Nr6);
        assert_eq!(snap.micro_shape_for(DType::F32), Some(MicroShape::Mr8Nr6));
        assert_eq!(r.micro_shape_for(DType::F32), Some(MicroShape::Mr16Nr6));
    }

    #[test]
    fn strategy_winners_are_recorded_per_dtype_kernel_and_class() {
        use crate::codegen::DType;
        use crate::tiling::{ShapeClass, StrategyKind};
        let r = Registry::default();
        let big = ShapeClass::of((512, 512, 512));
        let small = ShapeClass::of((64, 64, 64));
        assert_eq!(r.strategy_for(DType::F32, "matmul", big), None);
        r.set_strategy_for(DType::F32, "matmul", big, StrategyKind::Oblivious);
        assert_eq!(
            r.strategy_for(DType::F32, "matmul", big),
            Some(StrategyKind::Oblivious)
        );
        // dtype, kernel and shape class all namespace the slot
        assert_eq!(r.strategy_for(DType::F64, "matmul", big), None);
        assert_eq!(r.strategy_for(DType::F32, "convolution", big), None);
        assert_eq!(r.strategy_for(DType::F32, "matmul", small), None);
        r.set_strategy_for(DType::F64, "matmul", big, StrategyKind::Latency);
        assert_eq!(
            r.strategy_for(DType::F64, "matmul", big),
            Some(StrategyKind::Latency)
        );
        // clones snapshot strategy winners exactly like micro shapes
        let snap = r.clone();
        r.set_strategy_for(DType::F32, "matmul", big, StrategyKind::Lattice);
        assert_eq!(
            snap.strategy_for(DType::F32, "matmul", big),
            Some(StrategyKind::Oblivious)
        );
        assert_eq!(
            r.strategy_for(DType::F32, "matmul", big),
            Some(StrategyKind::Lattice)
        );
    }

    #[test]
    fn micro_shape_slots_are_shared_nothing_across_dtypes() {
        // the sharding contract: writers on different dtypes (and racing
        // writers on the same dtype) go through &self concurrently; the
        // last write per dtype wins and reads never see a torn value
        use crate::codegen::{DType, MicroShape};
        let r = Registry::default();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for _ in 0..100 {
                        r.set_micro_shape_for(DType::F32, MicroShape::Mr16Nr6);
                        r.set_micro_shape_for(DType::F64, MicroShape::Mr8Nr6);
                        let got = r.micro_shape_for(DType::F32);
                        assert!(got.is_some());
                    }
                });
            }
        });
        assert!(MicroShape::CANDIDATES.contains(&r.micro_shape_for(DType::F64).unwrap()));
    }

    #[test]
    fn registry_parses_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let r = Registry::load(&artifacts_dir()).unwrap();
        assert!(!r.artifacts().is_empty());
        assert!(!r.variants_for(256, 256, 256).is_empty());
        let v = r.closest_variant(256, 256, 256, (60, 60, 60)).unwrap();
        assert_eq!((v.bm, v.bk, v.bn), (64, 64, 64));
    }

    #[test]
    fn engine_runs_pallas_kernel_and_matches_ref() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let r = Registry::load(&artifacts_dir()).unwrap();
        let mut eng = Engine::new(r).unwrap();
        let (m, k, n) = (128usize, 128, 128);
        // deterministic input
        let mut s = 0x12345678u64;
        let mut rnd = move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            ((s >> 11) as f32 / (1u64 << 53) as f32) - 0.5e-16 as f32
        };
        let x: Vec<f32> = (0..m * k).map(|_| rnd()).collect();
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let got = eng
            .run_matmul("matmul_128x128x128_b64x64x64", &x, &y)
            .unwrap();
        // CPU-side oracle (row-major)
        let mut want = vec![0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let xv = x[i * k + kk];
                for j in 0..n {
                    want[i * n + j] += xv * y[kk * n + j];
                }
            }
        }
        let max_diff = got
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_diff < 1e-3, "pallas artifact numerics off: {max_diff}");
    }
}
