//! `latticetile` — CLI for the associativity-lattice tiling framework.
//!
//! Subcommands:
//!   analyze  — print conflict-lattice analysis for a matmul shape
//!   plan     — run the §4.0.4 selector, print ranked tiling plans
//!   run      — execute a matmul under the chosen plan, report misses+time
//!   bench    — regenerate a paper figure (fig3|fig4|fig4-rect|fig5|fig6|
//!              model-cost|policy)
//!   serve    — start the batching coordinator and run a demo workload
//!
//! (clap is unavailable in this offline build; parsing is hand-rolled.)

use std::collections::HashMap;
use std::time::{Duration, Instant};

use latticetile::baseline::CompilerAnalog;
use latticetile::cache::{CacheSim, CacheSpec, Policy};
use latticetile::codegen::executor::{KernelBuffers, TiledExecutor};
use latticetile::codegen::{autotune, run_trace_only, DType, GemmForm, MicroShape, Precision, Scalar};
use latticetile::conflict::MissModel;
use latticetile::coordinator::{Backend, Planner, Service, ServiceConfig};
use latticetile::domain::ops;
use latticetile::experiments::{self, harness::Table};
use latticetile::runtime::Registry;
use latticetile::tiling;
use latticetile::tiling::TiledSchedule;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(|s| s.as_str()) {
        Some("analyze") => cmd_analyze(&parse_flags(&args[1..])),
        Some("plan") => cmd_plan(&parse_flags(&args[1..])),
        Some("run") => cmd_run(&parse_flags(&args[1..])),
        Some("bench") => cmd_bench(&args[1..]),
        Some("serve") => cmd_serve(&parse_flags(&args[1..])),
        Some("help") | None => {
            print_usage();
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand {other:?}\n");
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "latticetile — model-driven automatic tiling with cache associativity lattices

USAGE:
  latticetile analyze [--n N | --m M --k K --nn N] [--lda L]
  latticetile plan    [--n N] [--samples S] [--dtype f32|f64|f32acc64]
                      [--strategy lattice|oblivious|latency|auto]
  latticetile run     [--n N] [--strategy lattice|oblivious|latency|auto|
                                           rect|O0|O2|O3|graphite|icc|pgi]
                      [--dtype f32|f64|f32acc64]
  latticetile bench   <fig3|fig4|fig4-rect|fig5|fig6|model-cost|policy|
                       multilevel|strategy-race> [--full]
  latticetile serve   [--artifacts DIR] [--jobs J] [--shape MxKxN]
                      [--backend pjrt|native] [--dtype f32|f32acc64]
                      [--strategy lattice|oblivious|latency|auto]
                      [--max-batch B] [--queue-cap Q]
                      [--threads T] [--clients C] [--window-ms W]
                      [--deadline-ms D] [--inject-faults]

--dtype selects the precision the model and the packed engine run at
(f32 halves the element size, so plans get twice the elements per line
and twice the register-tile width; compiler-analog strategies are
f64-only). f32acc64 is the mixed mode: f32 storage, panels and plan
geometry with f64 register accumulation, rounding once per kc slice —
native execution paths only. --backend native serves f32 through the
in-process packed macro-kernel, no AOT artifacts needed; it coalesces
up to --max-batch jobs per dispatch into one widened GEMM over the
prepacked weights.
--strategy selects the tiling strategy for the macro-block shape:
lattice (the associativity-lattice model), oblivious (cache-oblivious
recursive halving, no cache parameters), latency (blocks from measured
latency-curve knee points), or auto (race all three once and dispatch
the recorded winner — the default). run also accepts the compiler
analogs and the rect ablation in the same flag.
--queue-cap bounds in-flight jobs (over-capacity submits are rejected),
--clients runs that many concurrent client threads, and --window-ms is
the batch window measured from the first job of a batch. --deadline-ms
sheds jobs whose queue wait exceeds D before compute (0 = no deadline);
--inject-faults arms a deterministic chaos schedule (worker panics,
batch errors, transient queue rejections) to demo the fault-tolerant
runtime — it needs a build with --features fault-injection.

The cache spec defaults to Intel Haswell L1d (32 KiB, 64 B lines, 8-way)."
    );
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                out.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                out.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            out.insert(format!("arg{}", out.len()), args[i].clone());
            i += 1;
        }
    }
    out
}

fn geti(flags: &HashMap<String, String>, key: &str, default: i64) -> i64 {
    flags
        .get(key)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn cmd_analyze(flags: &HashMap<String, String>) -> i32 {
    let n = geti(flags, "n", 128);
    let m = geti(flags, "m", n);
    let k = geti(flags, "k", n);
    let nn = geti(flags, "nn", n);
    let lda = geti(flags, "lda", m);
    let spec = CacheSpec::HASWELL_L1D;
    let kernel = ops::matmul_padded(m, k, nn, lda, lda, k, 8, 0);
    let model = MissModel::new(&kernel, &spec);
    let a = model.analysis();
    println!(
        "cache: c={} l={} K={} → N={} sets, element period P={}",
        spec.capacity,
        spec.line,
        spec.ways,
        spec.n_sets(),
        a.period
    );
    for (i, oc) in a.operands.iter().enumerate() {
        let name = kernel.operand(i).table.name();
        println!(
            "\noperand {name} (dims {:?}):",
            kernel.operand(i).table.dims()
        );
        println!(
            "  φ weights: {:?}  offset: {}",
            kernel.operand(i).table.map().weights(),
            oc.offset
        );
        println!(
            "  L(C,φ) det = {} (index in Z^d)",
            oc.operand_lattice.det_abs()
        );
        println!("  basis (HNF cols): {:?}", oc.operand_lattice.basis());
        println!("  LLL-reduced: {:?}", oc.operand_lattice.lll().basis());
        println!("  loop-space weights (φ∘access): {:?}", oc.loop_weights);
    }
    0
}

fn parse_precision(flags: &HashMap<String, String>) -> Option<Precision> {
    match flags.get("dtype") {
        None => Some(Precision::F64),
        Some(s) => {
            let p = Precision::parse(s);
            if p.is_none() {
                eprintln!("--dtype must be f32, f64 or f32acc64 (got {s:?})");
            }
            p
        }
    }
}

fn parse_strategy_choice(flags: &HashMap<String, String>) -> Option<tiling::StrategyChoice> {
    match flags.get("strategy").map(|s| s.as_str()) {
        None => Some(tiling::StrategyChoice::Auto),
        Some(s) => {
            let c = tiling::StrategyChoice::parse(s);
            if c.is_none() {
                eprintln!("--strategy must be lattice, oblivious, latency or auto (got {s:?})");
            }
            c
        }
    }
}

/// Race the three tiling strategies at `dtype` on a size-capped model
/// instance and return the winner (the lattice incumbent keeps ties).
fn race_strategies_at(dtype: DType, cap: i64, micro: MicroShape) -> tiling::StrategyKind {
    let race = ops::matmul(cap, cap, cap, dtype.elem(), 0);
    match dtype {
        DType::F64 => autotune::calibrate_strategies::<f64>(&race, micro, 8, 2),
        DType::F32 => autotune::calibrate_strategies::<f32>(&race, micro, 8, 2),
    }
}

fn cmd_plan(flags: &HashMap<String, String>) -> i32 {
    let n = geti(flags, "n", 128);
    let samples = geti(flags, "samples", 8) as usize;
    let Some(precision) = parse_precision(flags) else {
        return 2;
    };
    let Some(strategy) = parse_strategy_choice(flags) else {
        return 2;
    };
    let dtype = precision.store;
    let spec = CacheSpec::HASWELL_L1D;
    let cap = 64i64.min(n);
    let kernel = ops::matmul_padded(cap, cap, cap, n, n, n, dtype.elem(), 0);
    let t0 = Instant::now();
    let ranked = tiling::select(&kernel, &spec, samples);
    println!(
        "ranked {} candidate plans in {:?} (model sampled on a {cap}³ {} instance, true lda={n}):\n",
        ranked.len(),
        t0.elapsed(),
        dtype.name(),
    );
    let mut tab = Table::new(&["rank", "plan", "predicted misses", "volume"]);
    for (i, p) in ranked.iter().enumerate() {
        tab.row(vec![
            (i + 1).to_string(),
            p.name.clone(),
            p.predicted
                .as_ref()
                .map(|c| c.misses.to_string())
                .unwrap_or_default(),
            p.schedule.basis().volume().to_string(),
        ]);
    }
    tab.print();
    // the full resolved plan (two-level macro shape + the per-dtype
    // autotuned 2-D register-tile geometry) through the coordinator's
    // planner — a mixed precision plans at its storage dtype and rides
    // the accumulate mode on the plan
    let reg = Registry::default();
    reg.set_micro_shape_for(DType::F64, autotune::calibrate_dtype::<f64>(500));
    reg.set_micro_shape_for(DType::F32, autotune::calibrate_dtype::<f32>(500));
    if strategy == tiling::StrategyChoice::Auto {
        // race the strategies once on the capped instance and record the
        // winner under the true shape's class — the planner's auto
        // dispatch below resolves exactly this slot
        let micro = reg.micro_shape_for(dtype).unwrap_or(MicroShape::Mr8Nr4);
        let winner = race_strategies_at(dtype, cap, micro);
        let class = tiling::ShapeClass::of((n as usize, n as usize, n as usize));
        reg.set_strategy_for(dtype, "matmul", class, winner);
        println!("\nstrategy race winner for this shape class: {}", winner.name());
    }
    let planner = Planner::new(spec)
        .with_sample_classes(samples)
        .with_strategy(strategy);
    let full = if precision.wide_acc() {
        planner.plan_with_precision(&reg, n as usize, n as usize, n as usize, precision)
    } else {
        planner.plan_kernel(&reg, &ops::matmul(n, n, n, dtype.elem(), 0))
    };
    println!("\nresolved plan: {}", full.describe());
    0
}

/// Execute `kernel` under `plan` at storage type `T` with the dtype's
/// freshly calibrated register-tile geometry, accumulating wide when
/// `precision` asks for it; returns the wall time.
fn timed_packed_run<T: Scalar>(
    kernel: &latticetile::domain::Kernel,
    plan: TiledSchedule,
    precision: Precision,
    level: Option<tiling::LevelPlan>,
) -> Duration {
    // one-shot startup calibration races the 2-D (MR, NR) grid and picks
    // the geometry the packed engine dispatches for this dtype
    // (8×4/8×6/16×4/16×6 at f64, 8×8/8×12/16×4/16×6 at f32)
    let mut exec = TiledExecutor::new(plan)
        .with_micro_shape(autotune::calibrate_dtype::<T>(500))
        .with_precision(precision);
    if let Some(lp) = level {
        exec = exec.with_level_plan(lp);
    }
    let mut bufs = KernelBuffers::<T>::from_kernel(kernel);
    let t0 = Instant::now();
    exec.run(&mut bufs, kernel);
    t0.elapsed()
}

fn cmd_run(flags: &HashMap<String, String>) -> i32 {
    let n = geti(flags, "n", 256);
    let strategy = flags
        .get("strategy")
        .map(|s| s.as_str())
        .unwrap_or("lattice");
    let Some(precision) = parse_precision(flags) else {
        return 2;
    };
    let spec = CacheSpec::HASWELL_L1D;
    let flops = 2.0 * (n as f64).powi(3);

    let analog = match strategy {
        "O0" => Some(CompilerAnalog::GccO0),
        "O2" => Some(CompilerAnalog::GccO2),
        "O3" => Some(CompilerAnalog::GccO3),
        "graphite" => Some(CompilerAnalog::GccGraphite),
        "icc" => Some(CompilerAnalog::IccO3),
        "pgi" => Some(CompilerAnalog::Pgi),
        _ => None,
    };
    // compiler analogs model f64 compiler output only: force the
    // effective precision so the summary line reports what actually ran
    let precision = if analog.is_some() && precision != Precision::F64 {
        eprintln!("compiler-analog strategies are f64-only; running f64");
        Precision::F64
    } else {
        precision
    };
    let dtype = precision.store;

    let (misses, wall) = match analog {
        Some(a) => {
            let kernel = ops::matmul(n, n, n, 8, 0);
            let sched = a.schedule(&kernel);
            let mut sim = CacheSim::new(spec, Policy::Lru).without_classification();
            run_trace_only(&kernel, sched.as_scanner(), &mut sim);
            let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
            let t0 = Instant::now();
            a.execute(&mut bufs, &kernel);
            (sim.stats().misses(), t0.elapsed())
        }
        None => {
            // the kernel carries the element size: f32 instances halve
            // every byte address, so the simulated misses below reflect
            // the doubled elements-per-line for free
            let kernel = ops::matmul(n, n, n, dtype.elem(), 0);
            let plan = match (strategy, dtype) {
                ("rect", DType::F64) => experiments::fig4::best_rect_plan_for(n, &spec).1,
                (_, DType::F64) => experiments::fig4::lattice_plan_for(n, &spec),
                // f32: select against the f32 kernel's own conflict
                // lattices on a size-capped model instance
                _ => {
                    let cap = 64i64.min(n);
                    let model = ops::matmul_padded(cap, cap, cap, n, n, n, 4, 0);
                    let ranked = tiling::select(&model, &spec, 8);
                    let keep_rect = strategy == "rect";
                    ranked
                        .into_iter()
                        .find(|p| !keep_rect || p.lattice_operand.is_none())
                        .map(|p| p.schedule)
                        .unwrap_or_else(|| {
                            TiledSchedule::new(tiling::TileBasis::rect(&[32, 32, 32]))
                        })
                }
            };
            // tiling-strategy overrides ride on the lattice L1 schedule
            // and swap only the macro-block LevelPlan — blocking changes,
            // never arithmetic, so results stay bitwise-identical
            let level = match strategy {
                "oblivious" | "latency" | "auto" => {
                    let kind = match tiling::StrategyKind::parse(strategy) {
                        Some(kind) => kind,
                        None => {
                            let micro = match dtype {
                                DType::F64 => autotune::calibrate_dtype::<f64>(500),
                                DType::F32 => autotune::calibrate_dtype::<f32>(500),
                            };
                            let winner = race_strategies_at(dtype, 64i64.min(n), micro);
                            println!("auto strategy resolved to {}", winner.name());
                            winner
                        }
                    };
                    let gf = GemmForm::of(&kernel).expect("matmul is GEMM-form");
                    // per-axis tile extents from the basis row sums (as
                    // the planner does) — works for lattice bases too,
                    // where `GemmForm::l1_tile` would demand a rectangle
                    let b = plan.basis();
                    let ext = |i: usize| -> usize {
                        (0..b.dim())
                            .map(|j| b.basis()[(i, j)].unsigned_abs() as usize)
                            .sum::<usize>()
                            .max(1)
                    };
                    let group = |axes: &[usize]| -> usize {
                        axes.iter().map(|&t| ext(t)).product::<usize>().max(1)
                    };
                    let l1 = (
                        group(&gf.row_axes),
                        group(&gf.col_axes),
                        group(&gf.red_axes),
                    );
                    Some(tiling::strategy_impl(kind).propose(
                        &kernel,
                        (gf.m, gf.n, gf.k),
                        l1,
                        &CacheSpec::HASWELL_L2,
                        Some(&CacheSpec::HASWELL_L3_SLICE),
                        8,
                    ))
                }
                _ => None,
            };
            let mut sim = CacheSim::new(spec, Policy::Lru).without_classification();
            run_trace_only(&kernel, &plan, &mut sim);
            let wall = match dtype {
                DType::F64 => timed_packed_run::<f64>(&kernel, plan, precision, level),
                DType::F32 => timed_packed_run::<f32>(&kernel, plan, precision, level),
            };
            (sim.stats().misses(), wall)
        }
    };
    println!(
        "n={n} strategy={strategy} dtype={}: simulated L1 misses={misses} wall={:?} ({:.2} GFLOP/s)",
        precision.name(),
        wall,
        flops / wall.as_secs_f64() / 1e9
    );
    0
}

fn cmd_bench(args: &[String]) -> i32 {
    let which = args.first().map(|s| s.as_str()).unwrap_or("");
    let flags = parse_flags(if args.is_empty() { args } else { &args[1..] });
    let full = flags.contains_key("full");
    match which {
        "fig3" => bench_fig3(),
        "fig4" => bench_fig4(full),
        "fig4-rect" => bench_fig4_rect(full),
        "fig5" => bench_fig5(),
        "fig6" => bench_fig6(full),
        "model-cost" => bench_model_cost(),
        "policy" => bench_policy(),
        "multilevel" => bench_multilevel(),
        "strategy-race" => bench_strategy_race(full),
        other => {
            eprintln!(
                "unknown bench {other:?} (fig3|fig4|fig4-rect|fig5|fig6|model-cost|policy|multilevel|strategy-race)"
            );
            return 2;
        }
    }
    0
}

fn bench_fig3() {
    let r = experiments::fig3::run();
    println!("Figure 3 — tile volume, lattice gen ((5,61),(7,−17)):\n");
    let mut t = Table::new(&["tile family", "volume", "source"]);
    t.row(vec![
        "lattice fundamental parallelepiped".into(),
        r.lattice_volume.to_string(),
        "ours (=|det|, exact)".into(),
    ]);
    t.row(vec![
        format!(
            "best translation-safe rectangle {}x{}",
            r.best_rect.0, r.best_rect.1
        ),
        r.best_rect_volume.to_string(),
        "ours (exhaustive)".into(),
    ]);
    t.row(vec![
        format!(
            "best practical rectangle (dims>=8) {}x{}",
            r.best_practical_rect.0, r.best_practical_rect.1
        ),
        r.best_practical_rect_volume.to_string(),
        "ours (exhaustive)".into(),
    ]);
    t.row(vec![
        "best rectangle [GMM99 A7]".into(),
        r.paper_best_rect_volume.to_string(),
        "paper-cited".into(),
    ]);
    t.row(vec![
        "rectangle chosen by [GMM99]".into(),
        r.paper_chosen_rect_volume.to_string(),
        "paper-cited".into(),
    ]);
    t.print();
    println!(
        "\nlattice advantage vs best practical rectangle: {:.2}x",
        r.advantage_vs_best_rect
    );
    let l = experiments::fig3::paper_lattice();
    let (mn, mx) = experiments::fig3::rect_point_count_varies(&l, 24, 20, 6);
    println!(
        "regularity: 24x20 rect tiles contain {mn}..{mx} lattice points (varies); \
         whole lattice tiles always contain exactly 1"
    );
}

fn bench_fig4(full: bool) {
    let sizes: &[i64] = if full {
        &[96, 128, 192, 256, 384, 512]
    } else {
        &[96, 128, 192, 256]
    };
    println!("Figure 4 — lattice tiling vs compiler analogs (Haswell L1d sim + wallclock):\n");
    for &n in sizes {
        let rows = experiments::fig4::run_size(n, if full { 3 } else { 1 });
        let mut t = Table::new(&[
            "strategy",
            "L1 misses",
            "wall",
            "GFLOP/s",
            "speedup vs O0",
            "miss ratio vs O0",
        ]);
        let sp = experiments::fig4::speedups_vs(&rows, "gcc-O0(analog)");
        let mr = experiments::fig4::miss_ratios_vs(&rows, "gcc-O0(analog)");
        for (i, r) in rows.iter().enumerate() {
            t.row(vec![
                r.strategy.clone(),
                r.l1_misses.to_string(),
                experiments::harness::fmt_dur(r.wall),
                format!("{:.2}", r.gflops),
                format!("{:.2}x", sp[i].1),
                format!("{:.2}x", mr[i].1),
            ]);
        }
        println!("n = {n}:");
        t.print();
        println!();
    }
}

fn bench_fig4_rect(full: bool) {
    let sizes: &[i64] = if full {
        &[96, 128, 192, 256, 384]
    } else {
        &[96, 128, 256]
    };
    println!("§4.0.2 — best rectangular vs best lattice tiling:\n");
    let mut t = Table::new(&["n", "strategy", "L1 misses", "wall", "GFLOP/s"]);
    for &n in sizes {
        for r in experiments::fig4::run_rect_vs_lattice(n, if full { 3 } else { 1 }) {
            t.row(vec![
                r.n.to_string(),
                r.strategy.clone(),
                r.l1_misses.to_string(),
                experiments::harness::fmt_dur(r.wall),
                format!("{:.2}", r.gflops),
            ]);
        }
    }
    t.print();
}

fn bench_fig5() {
    println!("Figure 5 — spatial reuse (cacheline utilization, interior tiles):\n");
    let mut t = Table::new(&["n", "tile family", "mean util", "min", "max"]);
    for n in [128i64, 256] {
        let (rect, lattice) = experiments::fig5::run(n);
        t.row(vec![
            n.to_string(),
            "rect 16x8".into(),
            format!("{:.3}", rect.mean),
            format!("{:.3}", rect.min),
            format!("{:.3}", rect.max),
        ]);
        t.row(vec![
            n.to_string(),
            "lattice (skewed, equal volume)".into(),
            format!("{:.3}", lattice.mean),
            format!("{:.3}", lattice.min),
            format!("{:.3}", lattice.max),
        ]);
    }
    t.print();
    println!("\n(The paper's Fig.5 point: lattice tiles trade spatial reuse for volume.)");
}

fn bench_fig6(full: bool) {
    let n = if full { 512 } else { 256 };
    let threads: Vec<usize> = if full {
        vec![1, 2, 4, 8, 12, 16, 20]
    } else {
        vec![1, 2, 4, 8]
    };
    let (og, gg) = experiments::fig6::parallel_grain(n);
    println!(
        "Figure 6 — auto-threading, n={n} (parallel grain: ours={og} bands, \
         graphite-analog={gg} bands):\n"
    );
    let rows = experiments::fig6::run(n, &threads, if full { 3 } else { 1 });
    let mut t = Table::new(&[
        "threads",
        "ours wall",
        "ours speedup*",
        "graphite wall",
        "graphite speedup*",
    ]);
    for r in rows {
        t.row(vec![
            r.threads.to_string(),
            experiments::harness::fmt_dur(r.ours),
            format!("{:.2}x", r.ours_modeled),
            experiments::harness::fmt_dur(r.graphite),
            format!("{:.2}x", r.graphite_modeled),
        ]);
    }
    t.print();
    println!(
        "\n* load-balance speedup (total work / max per-thread work) — this host has\n\
         {} core(s), so measured wallclock cannot scale; the band structure is exact.",
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
    );
}

fn bench_model_cost() {
    println!("§4.0.4 — analysis/model cost:\n");
    let rows = experiments::model_cost::run(&[16, 24, 32, 48], 2);
    let mut t = Table::new(&[
        "n",
        "exact Eq.(4)",
        "paper Δ-rule",
        "sampled (8 classes)",
        "K−1 closed form",
    ]);
    for r in rows {
        t.row(vec![
            r.n.to_string(),
            experiments::harness::fmt_dur(r.exact),
            experiments::harness::fmt_dur(r.exact_paper),
            experiments::harness::fmt_dur(r.sampled),
            experiments::harness::fmt_dur(r.k_minus_one),
        ]);
    }
    t.print();
}

fn bench_multilevel() {
    println!("extension — three-level hierarchy behaviour of the plans:\n");
    let rows = experiments::multilevel::run(&[96, 128]);
    let mut t = Table::new(&[
        "n",
        "strategy",
        "L1 misses",
        "L2 misses",
        "L3 misses",
        "est cycles",
        "Mops/s",
    ]);
    for r in rows {
        t.row(vec![
            r.n.to_string(),
            r.strategy.clone(),
            r.l1_misses.to_string(),
            r.l2_misses.to_string(),
            r.l3_misses.to_string(),
            r.est_cycles.to_string(),
            format!("{:.1}", r.mops),
        ]);
    }
    t.print();
}

fn bench_strategy_race(full: bool) {
    println!("tiling-strategy race — model-driven lattice vs rivals:\n");
    let cells = experiments::strategy_race::run(!full);
    let mut t = Table::new(&[
        "kernel",
        "dtype",
        "lattice",
        "oblivious",
        "latency",
        "flat",
        "auto",
        "winner",
        "model miss",
    ]);
    for c in &cells {
        t.row(vec![
            c.kernel.clone(),
            c.dtype.name().to_string(),
            format!("{:.2}", c.rate_of(tiling::StrategyKind::Lattice)),
            format!("{:.2}", c.rate_of(tiling::StrategyKind::Oblivious)),
            format!("{:.2}", c.rate_of(tiling::StrategyKind::Latency)),
            format!("{:.2}", c.flat),
            format!("{:.2}", c.auto),
            c.winner.name().to_string(),
            c.predicted_misses
                .map(|m| m.to_string())
                .unwrap_or_else(|| "-".to_string()),
        ]);
    }
    t.print();
    let (wins, total, misses) = experiments::strategy_race::win_summary(&cells);
    println!("\nmodel-vs-empirical: lattice won {wins}/{total} cells ({misses} model misses)");
}

fn bench_policy() {
    println!("§1.1.4 — LRU vs tree-PLRU miss counts:\n");
    let rows = experiments::policy::run(&[96, 128]);
    let mut t = Table::new(&["n", "strategy", "LRU", "PLRU", "Δ rel"]);
    for r in rows {
        t.row(vec![
            r.n.to_string(),
            r.strategy.clone(),
            r.lru.to_string(),
            r.plru.to_string(),
            format!("{:.3}", r.rel_delta),
        ]);
    }
    t.print();
}

/// The demo chaos schedule behind `serve --inject-faults`: occasional
/// worker panics (mid-batch and mid-pack) plus transient queue
/// rejections, on a fixed seed so runs replay exactly.
#[cfg(feature = "fault-injection")]
fn chaos_faults() -> Option<latticetile::coordinator::Faults> {
    use latticetile::coordinator::{FaultMode, FaultPoint, Faults};
    Some(
        Faults::seeded(0xC4A0_5EED)
            .fail(FaultPoint::BatchCompute, FaultMode::Panic, 1, 8)
            .fail(FaultPoint::Pack, FaultMode::Panic, 1, 16)
            .fail(FaultPoint::QueueAccept, FaultMode::Error, 1, 8)
            .build(),
    )
}

#[cfg(not(feature = "fault-injection"))]
fn chaos_faults() -> Option<latticetile::coordinator::Faults> {
    eprintln!("--inject-faults needs a build with --features fault-injection");
    None
}

fn cmd_serve(flags: &HashMap<String, String>) -> i32 {
    let dir = flags
        .get("artifacts")
        .cloned()
        .unwrap_or_else(|| "artifacts".to_string());
    let jobs = geti(flags, "jobs", 64) as usize;
    let shape = flags
        .get("shape")
        .cloned()
        .unwrap_or_else(|| "128x128x128".to_string());
    let dims: Vec<usize> = shape.split('x').filter_map(|v| v.parse().ok()).collect();
    if dims.len() != 3 {
        eprintln!("--shape must be MxKxN");
        return 2;
    }
    let (m, k, n) = (dims[0], dims[1], dims[2]);
    let max_batch = geti(flags, "max-batch", 8).max(1) as usize;
    let queue_cap = geti(flags, "queue-cap", 256).max(1) as usize;
    let threads = geti(flags, "threads", 1).max(1) as usize;
    let clients = geti(flags, "clients", 1).max(1) as usize;
    let window_ms = geti(flags, "window-ms", 2).max(0) as u64;
    let deadline_ms = geti(flags, "deadline-ms", 0).max(0) as u64;
    let faults = if flags.contains_key("inject-faults") {
        match chaos_faults() {
            Some(f) => f,
            None => return 2,
        }
    } else {
        latticetile::coordinator::Faults::none()
    };
    let backend = match flags.get("backend").map(|s| s.as_str()) {
        None | Some("pjrt") => Backend::Pjrt,
        Some("native") => Backend::Native,
        Some(other) => {
            eprintln!("--backend must be pjrt or native (got {other:?})");
            return 2;
        }
    };
    let Some(strategy) = parse_strategy_choice(flags) else {
        return 2;
    };
    // serving stores f32 job buffers either way; f32acc64 widens the
    // native backend's register accumulation to f64
    let precision = match flags.get("dtype").map(|s| s.as_str()) {
        None | Some("f32") => Precision::F32,
        Some("f32acc64") if backend == Backend::Native => Precision::F32ACC64,
        Some("f32acc64") => {
            eprintln!("--dtype f32acc64 needs --backend native");
            return 2;
        }
        Some(other) => {
            eprintln!("serve --dtype must be f32 or f32acc64 (got {other:?})");
            return 2;
        }
    };

    match (backend, Registry::load(std::path::Path::new(&dir))) {
        (_, Ok(r)) => println!("loaded {} artifacts from {dir}", r.artifacts().len()),
        (Backend::Native, Err(_)) => {
            println!("no artifacts in {dir} — native backend needs none")
        }
        (Backend::Pjrt, Err(e)) => {
            eprintln!("cannot load artifacts from {dir}: {e:#}\nrun `make artifacts` first");
            return 1;
        }
    };

    let mut seed = 0x243F6A88u64;
    let mut rnd = move || {
        seed ^= seed << 13;
        seed ^= seed >> 7;
        seed ^= seed << 17;
        ((seed % 1000) as f32 / 1000.0) - 0.5
    };
    let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
    let svc = Service::start(
        std::path::Path::new(&dir),
        y,
        ServiceConfig {
            m,
            k,
            n,
            batch_window: Duration::from_millis(window_ms),
            max_batch,
            queue_cap,
            threads,
            spec: CacheSpec::HASWELL_L1D,
            backend,
            precision,
            deadline: (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms)),
            faults,
            strategy,
            ..ServiceConfig::default()
        },
    )
    .expect("service start");
    println!("serving with {}", svc.plan().describe());
    println!("health: {}", svc.health());

    // each client submits its share as a burst (so the batcher has
    // something to coalesce), retrying politely when the bounded queue
    // pushes back, then drains its responses
    let per_client = jobs.div_ceil(clients);
    let total = per_client * clients;
    let t0 = Instant::now();
    let mut ok_total = 0u64;
    let mut failed_total = 0u64;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..clients {
            let client = svc.client();
            handles.push(scope.spawn(move || {
                let mut seed = 0x243F6A88u64 ^ ((c as u64 + 1) << 32);
                let mut rnd = move || {
                    seed ^= seed << 13;
                    seed ^= seed >> 7;
                    seed ^= seed << 17;
                    ((seed % 1000) as f32 / 1000.0) - 0.5
                };
                let mut rxs = Vec::new();
                for _ in 0..per_client {
                    let x: Vec<f32> = (0..m * k).map(|_| rnd()).collect();
                    // queue pushback (real or injected) heals through
                    // bounded jittered backoff
                    match client.submit_with_retry(x, 16, Duration::from_micros(200)) {
                        Ok(rx) => rxs.push(rx),
                        Err(e) => panic!("submit failed: {e}"),
                    }
                }
                let (mut ok, mut failed) = (0u64, 0u64);
                for rx in rxs {
                    match rx.recv() {
                        Ok(_) => ok += 1,
                        // typed failures (shed deadlines, contained
                        // panics under --inject-faults) are the expected
                        // degraded outcomes, not client crashes
                        Err(e) => {
                            failed += 1;
                            eprintln!("client {c}: job failed: {e}");
                        }
                    }
                }
                (ok, failed)
            }));
        }
        for h in handles {
            let (ok, failed) = h.join().unwrap_or((0, 0));
            ok_total += ok;
            failed_total += failed;
        }
    });
    let wall = t0.elapsed();
    println!("health: {}", svc.health());
    let (metrics, _) = svc.stop();
    println!(
        "served {ok_total}/{total} jobs ({m}x{k}x{n}) from {clients} client(s) in {wall:?}\
         {}",
        if failed_total > 0 {
            format!(" — {failed_total} resolved with typed errors")
        } else {
            String::new()
        }
    );
    println!("{}", metrics.report(wall));
    0
}
