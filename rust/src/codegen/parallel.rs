//! Auto-threading — §4.0.3 (DESIGN.md S11; OpenMP substitute).
//!
//! Rect schedules run the two-level macro-kernel with parallelism over
//! whole `nc` **column bands**: the packed B k-slice ([`PackedB`]) is
//! built once and shared read-only across all workers — B is never
//! re-packed thread-locally — while each worker packs the C block of its
//! own band and writes a disjoint column range of `A`, so no write races
//! occur. This is the same decomposition the paper's generated
//! `omp parallel for` over the outer tile loop produces when `j` is the
//! outer tile dimension, lifted from L1 tiles to macro blocks.
//!
//! Skewed schedules keep the footpoint partition: tile interiors run
//! through the same packing + microkernel engine as the serial
//! [`TiledExecutor`](super::executor::TiledExecutor); every worker owns
//! thread-local [`PackBuffers`] / scratch so the hot loop performs no
//! shared allocation.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::cache::CacheSpec;
use crate::domain::Kernel;
use crate::tiling::{LevelPlan, TiledSchedule};

use super::executor::{MatmulBuffers, ReplayScratch, TiledExecutor};
use super::pack::{run_macro_block, PackBuffers, PackedB, PackedC};

/// Execute the tiled matmul with `threads` worker threads. Footpoints are
/// grouped by their footpoint coordinate along `partition_var` (loop-space
/// dimension index; use 1 = `j` for matmul plans built by this crate);
/// groups are handed to workers round-robin. Panics if the tile basis
/// couples `partition_var` with other dimensions (the column band would
/// not be disjoint).
pub fn run_parallel(
    bufs: &mut MatmulBuffers,
    kernel: &Kernel,
    schedule: &TiledSchedule,
    threads: usize,
    partition_var: usize,
) {
    assert!(threads >= 1);
    let basis = schedule.basis();
    let d = basis.dim();
    // safety: partition_var must be decoupled — its row/col in the basis
    // touches only the diagonal
    for t in 0..d {
        if t != partition_var {
            assert_eq!(
                basis.basis()[(partition_var, t)],
                0,
                "partition var is coupled by the tile basis"
            );
            assert_eq!(
                basis.basis()[(t, partition_var)],
                0,
                "partition var is coupled by the tile basis"
            );
        }
    }

    // Rect bases partitioned over j take the macro-kernel band path: the
    // packed B slice is shared across workers instead of re-packed
    // thread-locally, and each worker owns whole nc column bands.
    if basis.is_rect() && basis.dim() == 3 && partition_var == 1 {
        run_parallel_macro(bufs, kernel, schedule, threads, None);
        return;
    }

    // collect footpoints, grouped by the partition coordinate
    let mut groups: std::collections::BTreeMap<i128, Vec<Vec<i128>>> =
        std::collections::BTreeMap::new();
    schedule.scan_feet(kernel.extents(), |foot| {
        groups
            .entry(foot[partition_var])
            .or_default()
            .push(foot.to_vec());
    });
    let groups: Vec<Vec<Vec<i128>>> = groups.into_values().collect();

    let extents = kernel.extents().to_vec();
    let geom = bufs.geom();

    // The shared tile engine: rect tiles pack + microkernel per clipped
    // tile box, skewed tiles replay packed panels (TiledExecutor::run_tile).
    let exec = TiledExecutor::new(schedule.clone());
    let is_rect = basis.is_rect();

    // Work queue: group index counter.
    let next = AtomicUsize::new(0);
    let arena_ptr = SendPtr(bufs.arena.as_mut_ptr());
    let arena_len = bufs.arena.len();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            let groups = &groups;
            let next = &next;
            let extents = &extents;
            let arena_ptr = &arena_ptr;
            let exec = &exec;
            scope.spawn(move || {
                let (m, n, k) = (extents[0], extents[1], extents[2]);
                // thread-local pack buffers + replay scratch; packed
                // blocks are reused across consecutive tiles via their
                // keys (run_rect_box), so nothing is re-packed when only
                // one tile coordinate advances
                let mut packs = PackBuffers::new();
                let mut scratch = ReplayScratch::default();
                loop {
                    let g = next.fetch_add(1, Ordering::Relaxed);
                    if g >= groups.len() {
                        break;
                    }
                    // SAFETY: groups are disjoint column bands of A, and
                    // B/C are read-only here; each element of the arena is
                    // written by at most one thread.
                    let arena: &mut [f64] =
                        unsafe { std::slice::from_raw_parts_mut(arena_ptr.0, arena_len) };
                    for foot in &groups[g] {
                        if is_rect {
                            // pack + microkernel over the clipped tile box
                            let basis = exec.schedule().basis();
                            let origin = basis.basis().mul_vec(foot);
                            let (oi, oj, ok) =
                                (origin[0] as i64, origin[1] as i64, origin[2] as i64);
                            let (ti, tj, tk) = (
                                basis.basis()[(0, 0)] as i64,
                                basis.basis()[(1, 1)] as i64,
                                basis.basis()[(2, 2)] as i64,
                            );
                            let (ilo, ihi) = (oi.max(0).min(m), (oi + ti).max(0).min(m));
                            let (jlo, jhi) = (oj.max(0).min(n), (oj + tj).max(0).min(n));
                            let (klo, khi) = (ok.max(0).min(k), (ok + tk).max(0).min(k));
                            if ilo >= ihi || jlo >= jhi || klo >= khi {
                                continue;
                            }
                            super::executor::run_rect_box(
                                arena,
                                geom,
                                (ilo as usize, (ihi - ilo) as usize),
                                (jlo as usize, (jhi - jlo) as usize),
                                (klo as usize, (khi - klo) as usize),
                                &mut packs,
                            );
                        } else {
                            exec.run_tile(arena, geom, extents, foot, &mut scratch);
                        }
                    }
                }
            });
        }
    });
}

/// The macro-kernel parallel path: for each `kc` k-slice the whole
/// packed B ([`PackedB`]) is built once by the calling thread and shared
/// **read-only** by all workers; workers then claim `nc`-wide output
/// column bands from an atomic counter, pack their band's C block
/// thread-locally ([`PackedC`]) and drive the L1 tiles of every B block
/// from the shared panels. Bands are disjoint `A` column ranges, so
/// writes never race. `level` overrides the derived macro shape.
pub fn run_parallel_macro(
    bufs: &mut MatmulBuffers,
    kernel: &Kernel,
    schedule: &TiledSchedule,
    threads: usize,
    level: Option<LevelPlan>,
) {
    assert!(threads >= 1);
    let basis = schedule.basis();
    assert!(
        basis.is_rect() && basis.dim() == 3,
        "macro-kernel path needs a 3-D rect L1 basis"
    );
    let l1 = (
        basis.basis()[(0, 0)] as usize,
        basis.basis()[(1, 1)] as usize,
        basis.basis()[(2, 2)] as usize,
    );
    let extents = kernel.extents();
    let (m, n, k) = (
        extents[0] as usize,
        extents[1] as usize,
        extents[2] as usize,
    );
    let lp = level.unwrap_or_else(|| {
        LevelPlan::heuristic(
            l1,
            (m, n, k),
            &CacheSpec::HASWELL_L2,
            Some(&CacheSpec::HASWELL_L3_SLICE),
        )
    });
    let mc = lp.mc.max(1);
    let kc = lp.kc.max(1);
    let nc = lp.nc.max(1);
    let geom = bufs.geom();
    let n_bands = n.div_ceil(nc);
    let arena_len = bufs.arena.len();
    let mut packed_b = PackedB::new();
    for k0 in (0..k).step_by(kc) {
        let kcc = (k0 + kc).min(k) - k0;
        packed_b.pack_slice(&bufs.arena, geom.b_off, geom.ldb, m, mc, k0, kcc);
        let pb = &packed_b;
        let next = AtomicUsize::new(0);
        let arena_ptr = SendPtr(bufs.arena.as_mut_ptr());
        std::thread::scope(|scope| {
            for _ in 0..threads.min(n_bands) {
                let next = &next;
                let arena_ptr = &arena_ptr;
                scope.spawn(move || {
                    let mut packed_c = PackedC::new();
                    loop {
                        let band = next.fetch_add(1, Ordering::Relaxed);
                        if band >= n_bands {
                            break;
                        }
                        let j0 = band * nc;
                        let ncc = (j0 + nc).min(n) - j0;
                        // SAFETY: bands are disjoint A column ranges; B/C
                        // and the shared packed B are read-only here, so
                        // each arena element is written by at most one
                        // thread.
                        let arena: &mut [f64] =
                            unsafe { std::slice::from_raw_parts_mut(arena_ptr.0, arena_len) };
                        packed_c.pack_block(arena, geom.c_off, geom.ldc, k0, kcc, j0, ncc);
                        for bi in 0..pb.n_blocks() {
                            let (bp, i0, mcc) = pb.block(bi);
                            run_macro_block(
                                bp,
                                mcc,
                                packed_c.panels(),
                                ncc,
                                kcc,
                                (l1.0, l1.1),
                                arena,
                                geom.a_off,
                                geom.lda,
                                i0,
                                j0,
                            );
                        }
                    }
                });
            }
        });
    }
}

struct SendPtr(*mut f64);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::executor::{max_abs_diff, MatmulBuffers};
    use crate::domain::ops;
    use crate::lattice::IMat;
    use crate::tiling::TileBasis;

    #[test]
    fn parallel_matches_reference_rect() {
        let k = ops::matmul(24, 20, 28, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        for threads in [1, 2, 4] {
            let mut bufs = MatmulBuffers::from_kernel(&k);
            let want = bufs.reference();
            run_parallel(&mut bufs, &k, &s, threads, 1);
            assert!(
                max_abs_diff(&want, &bufs.output()) < 1e-9,
                "threads={threads}"
            );
        }
    }

    #[test]
    fn parallel_matches_reference_rect_non_multiple() {
        // extents not multiples of the tile → boundary tiles exercise the
        // edge microkernel in every dimension
        let k = ops::matmul(23, 19, 17, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let mut bufs = MatmulBuffers::from_kernel(&k);
        let want = bufs.reference();
        run_parallel(&mut bufs, &k, &s, 3, 1);
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    }

    #[test]
    fn parallel_matches_reference_lattice() {
        let k = ops::matmul(16, 16, 16, 8, 0);
        let basis = TileBasis::from_cols(IMat::from_rows(&[
            &[3, 0, 1],
            &[0, 4, 0],
            &[1, 0, 4],
        ]));
        let s = TiledSchedule::new(basis);
        let mut bufs = MatmulBuffers::from_kernel(&k);
        let want = bufs.reference();
        run_parallel(&mut bufs, &k, &s, 4, 1);
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    }

    #[test]
    fn parallel_macro_explicit_shape_matches_reference() {
        // multiple macro blocks in every dimension, bands narrower than
        // the L1 tile, threads > bands
        let k = ops::matmul(29, 23, 26, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let lp = LevelPlan {
            l1_tile: (8, 8, 8),
            mc: 12,
            kc: 7,
            nc: 5,
        };
        for threads in [1, 3, 8] {
            let mut bufs = MatmulBuffers::from_kernel(&k);
            let want = bufs.reference();
            run_parallel_macro(&mut bufs, &k, &s, threads, Some(lp));
            assert!(
                max_abs_diff(&want, &bufs.output()) < 1e-9,
                "threads={threads}"
            );
        }
    }

    #[test]
    #[should_panic(expected = "coupled")]
    fn coupled_partition_var_rejected() {
        let k = ops::matmul(8, 8, 8, 8, 0);
        // tile couples j with i
        let basis = TileBasis::from_cols(IMat::from_rows(&[
            &[2, 1, 0],
            &[1, 2, 0],
            &[0, 0, 2],
        ]));
        let s = TiledSchedule::new(basis);
        let mut bufs = MatmulBuffers::from_kernel(&k);
        run_parallel(&mut bufs, &k, &s, 2, 1);
    }
}
