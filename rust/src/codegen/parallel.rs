//! Auto-threading — §4.0.3 (DESIGN.md S11; OpenMP substitute),
//! kernel-agnostic since the `RunPlan` refactor and element-generic since
//! the `Scalar` refactor (every entry point is `T: Scalar`; the dtype's
//! autotuned register width is dispatched per call).
//!
//! Rect schedules of GEMM-form kernels run the two-level macro-kernel
//! with parallelism over whole `nc` **column bands** (GEMM columns, i.e.
//! the loop axes the output shares with the column operand): the packed
//! row slice ([`PackedRows`]) is built once and shared read-only across
//! all workers — rows are never re-packed thread-locally — while each
//! worker packs the column band of its own output range and writes a
//! disjoint set of output elements (the kernel's output map is injective
//! per (row, column)), so no write races occur. This is the same
//! decomposition the paper's generated `omp parallel for` over the outer
//! tile loop produces, lifted from L1 tiles to macro blocks.
//!
//! Skewed schedules keep the footpoint partition: tile interiors run
//! through the same packing + microkernel engine as the serial
//! [`TiledExecutor`](super::executor::TiledExecutor) — per-tile
//! [`RunPlan`] boxes for rect bases, [`ReplayPlan`] panel replay for
//! skewed ones; every worker owns thread-local [`PackBuffers`] / scratch
//! so the hot loop performs no shared allocation. Kernels whose output
//! does not stride along the partition variable (e.g. convolution's
//! scalar output) degrade to one worker instead of racing — and their
//! degenerate `m = n = 1` boxes run the dot microkernel, not the panel
//! engine.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::cache::CacheSpec;
use crate::domain::Kernel;
use crate::tiling::{LevelPlan, TiledSchedule};

use super::autotune::MicroShape;
use super::executor::{box_key, run_rect_box, KernelBuffers, ReplayPlan, ReplayScratch};
use super::pack::{run_macro_block, PackBuffers, PackedCols, PackedRows};
use super::runplan::{kernel_views, view_injective, GemmForm, RunPlan};
use super::scalar::Scalar;

/// Execute the tiled kernel with `threads` worker threads, dispatching
/// the dtype's default (narrow) register tile. See [`run_parallel_micro`].
pub fn run_parallel<T: Scalar>(
    bufs: &mut KernelBuffers<T>,
    kernel: &Kernel,
    schedule: &TiledSchedule,
    threads: usize,
    partition_var: usize,
) {
    run_parallel_micro(
        bufs,
        kernel,
        schedule,
        threads,
        partition_var,
        MicroShape::Mr8Nr4,
    );
}

/// Execute the tiled kernel with `threads` worker threads and an explicit
/// register-tile width class (pass the dtype's autotuned winner from
/// [`Registry::micro_shape_for`](crate::runtime::Registry::micro_shape_for) /
/// [`Plan::micro`](crate::coordinator::Plan)). Footpoints are grouped by
/// their footpoint coordinate along `partition_var` (loop-space dimension
/// index; use 1 = `j` for matmul plans built by this crate); groups are
/// handed to workers round-robin. Panics if the tile basis couples
/// `partition_var` with other dimensions (the bands would not be
/// disjoint). Kernels whose output map cannot be proven injective per
/// (row, column) — or does not stride along `partition_var` — degrade to
/// one worker instead of racing.
pub fn run_parallel_micro<T: Scalar>(
    bufs: &mut KernelBuffers<T>,
    kernel: &Kernel,
    schedule: &TiledSchedule,
    threads: usize,
    partition_var: usize,
    micro: MicroShape,
) {
    assert!(threads >= 1);
    let basis = schedule.basis();
    let d = basis.dim();
    // safety: partition_var must be decoupled — its row/col in the basis
    // touches only the diagonal
    for t in 0..d {
        if t != partition_var {
            assert_eq!(
                basis.basis()[(partition_var, t)],
                0,
                "partition var is coupled by the tile basis"
            );
            assert_eq!(
                basis.basis()[(t, partition_var)],
                0,
                "partition var is coupled by the tile basis"
            );
        }
    }

    let gf = GemmForm::of(kernel);
    let views = kernel_views(kernel);
    let extents_ref = kernel.extents();

    // Rect bases partitioned over a GEMM column axis take the
    // macro-kernel band path: the packed row slice is shared across
    // workers instead of re-packed thread-locally, and each worker owns
    // whole nc column bands. Requires a provably injective output map —
    // the write-disjointness of the bands (true for all Table-1 ops).
    if basis.is_rect() {
        if let Some(gf) = &gf {
            if gf.col_axes.contains(&partition_var)
                && gf.output_injective(&views, extents_ref)
            {
                run_parallel_macro(bufs, kernel, schedule, threads, None, micro);
                return;
            }
        }
    }

    // Partition groups write disjoint output ranges only when the output
    // strides along the partition variable AND the output map is provably
    // injective on its striding axes; reduction-style outputs
    // (convolution, scalar product) and unprovable maps degrade to one
    // worker instead of racing.
    let out_axes: Vec<usize> = (0..d).filter(|&t| views[0].w[t] != 0).collect();
    let threads = if views[0].w[partition_var] == 0
        || !view_injective(&views[0], extents_ref, &out_axes)
    {
        1
    } else {
        threads
    };

    // collect footpoints, grouped by the partition coordinate
    let mut groups: std::collections::BTreeMap<i128, Vec<Vec<i128>>> =
        std::collections::BTreeMap::new();
    schedule.scan_feet(kernel.extents(), |foot| {
        groups
            .entry(foot[partition_var])
            .or_default()
            .push(foot.to_vec());
    });
    let groups: Vec<Vec<Vec<i128>>> = groups.into_values().collect();

    let extents = kernel.extents().to_vec();
    let rect_gemm = basis.is_rect() && gf.is_some();
    // skewed (or non-GEMM) tiles share the serial replay engine
    let rp = if rect_gemm {
        None
    } else {
        Some(ReplayPlan::new(kernel, schedule))
    };
    let sizes: Vec<i64> = (0..d).map(|t| basis.basis()[(t, t)].max(1) as i64).collect();
    let (row_red_axes, col_red_axes): (Vec<usize>, Vec<usize>) = match &gf {
        Some(gf) => (
            gf.row_axes.iter().chain(&gf.red_axes).copied().collect(),
            gf.col_axes.iter().chain(&gf.red_axes).copied().collect(),
        ),
        None => (Vec::new(), Vec::new()),
    };

    // Work queue: group index counter.
    let next = AtomicUsize::new(0);
    let arena_ptr = SendPtr(bufs.arena.as_mut_ptr());
    let arena_len = bufs.arena.len();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            let groups = &groups;
            let next = &next;
            let extents = &extents;
            let arena_ptr = &arena_ptr;
            let rp = rp.as_ref();
            let gf = gf.as_ref();
            let views = &views;
            let sizes = &sizes;
            let row_red_axes = &row_red_axes;
            let col_red_axes = &col_red_axes;
            scope.spawn(move || {
                let d = extents.len();
                // thread-local pack buffers + replay/plan scratch; packed
                // boxes are reused across consecutive tiles via their box
                // keys (run_rect_box), so nothing is re-packed when only
                // the column coordinate advances, and the scratch RunPlan
                // keeps the per-tile loop allocation-free in steady state
                let mut packs = PackBuffers::<T>::new();
                let mut scratch = ReplayScratch::<T>::default();
                let mut plan = RunPlan::default();
                let mut lo = vec![0i64; d];
                let mut hi = vec![0i64; d];
                loop {
                    let g = next.fetch_add(1, Ordering::Relaxed);
                    if g >= groups.len() {
                        break;
                    }
                    // SAFETY: groups are disjoint output ranges (the
                    // output strides along the decoupled partition
                    // variable and its map is injective on the striding
                    // axes — all checked above) and the inputs are
                    // read-only here; each arena element is written by at
                    // most one thread.
                    let arena: &mut [T] =
                        unsafe { std::slice::from_raw_parts_mut(arena_ptr.0, arena_len) };
                    for foot in &groups[g] {
                        if let (true, Some(gf)) = (rect_gemm, gf) {
                            // pack + microkernel over the clipped tile box
                            let mut empty = false;
                            for t in 0..d {
                                let o = (foot[t] as i64) * sizes[t];
                                lo[t] = o.clamp(0, extents[t]);
                                hi[t] = (o + sizes[t]).clamp(0, extents[t]);
                                empty |= lo[t] >= hi[t];
                            }
                            if empty {
                                continue;
                            }
                            gf.plan_box_into(views, &lo, &hi, &mut plan);
                            run_rect_box(
                                arena,
                                &plan,
                                micro,
                                &mut packs,
                                box_key(row_red_axes, &lo, &hi),
                                box_key(col_red_axes, &lo, &hi),
                            );
                        } else {
                            rp.unwrap().run_tile(arena, extents, foot, &mut scratch);
                        }
                    }
                }
            });
        }
    });
}

/// The macro-kernel parallel path: for each `kc` reduction slice the
/// whole packed row slice ([`PackedRows`]) is built once by the calling
/// thread and shared **read-only** by all workers; workers then claim
/// `nc`-wide output column bands from an atomic counter, pack their
/// band's column block thread-locally ([`PackedCols`]) and drive the L1
/// tiles of every row block from the shared panels. Bands are disjoint
/// output element sets (the kernel's output map is injective per
/// (row, column)), so writes never race. `level` overrides the derived
/// macro shape; `micro` selects the register-tile width class (the
/// dtype's autotuned winner from
/// [`Registry::micro_shape_for`](crate::runtime::Registry::micro_shape_for)).
pub fn run_parallel_macro<T: Scalar>(
    bufs: &mut KernelBuffers<T>,
    kernel: &Kernel,
    schedule: &TiledSchedule,
    threads: usize,
    level: Option<LevelPlan>,
    micro: MicroShape,
) {
    assert!(threads >= 1);
    let basis = schedule.basis();
    assert!(basis.is_rect(), "macro-kernel path needs a rect L1 basis");
    let gf = GemmForm::of(kernel).expect("macro-kernel path needs a GEMM-form kernel");
    let views = kernel_views(kernel);
    let extents = kernel.extents();
    // bands write disjoint output element sets only when the output map
    // is injective per (row, column) — provable for every Table-1 op
    assert!(
        gf.output_injective(&views, extents),
        "macro-kernel bands need an injective output map"
    );
    let lo0 = vec![0i64; extents.len()];
    let plan = gf.plan_box(&views, &lo0, extents);
    if plan.m == 0 || plan.n == 0 || plan.k == 0 {
        return;
    }
    let l1 = gf.l1_tile(basis);
    let lp = level.unwrap_or_else(|| {
        LevelPlan::heuristic(
            l1,
            (gf.m, gf.n, gf.k),
            T::ELEM,
            &CacheSpec::HASWELL_L2,
            Some(&CacheSpec::HASWELL_L3_SLICE),
        )
    });
    if plan.m == 1 && plan.n == 1 {
        // degenerate dot (n_bands = 1 anyway): run serially through the
        // same path the serial macro-kernel takes
        super::executor::run_macro(
            &mut bufs.arena,
            &plan,
            &lp,
            micro,
            &mut PackedRows::<T>::new(),
            &mut PackedCols::<T>::new(),
        );
        return;
    }
    let mc = lp.mc.max(1);
    let kc = lp.kc.max(1);
    let nc = lp.nc.max(1);
    let l1 = (lp.l1_tile.0, lp.l1_tile.1);
    let n_bands = plan.n.div_ceil(nc);
    let arena_len = bufs.arena.len();
    let mut packed_rows = PackedRows::<T>::new();
    for k0 in (0..plan.k).step_by(kc) {
        let kcc = (k0 + kc).min(plan.k) - k0;
        packed_rows.pack_slice(&bufs.arena, &plan, mc, k0, kcc);
        let pr = &packed_rows;
        let plan = &plan;
        let next = AtomicUsize::new(0);
        let arena_ptr = SendPtr(bufs.arena.as_mut_ptr());
        std::thread::scope(|scope| {
            for _ in 0..threads.min(n_bands) {
                let next = &next;
                let arena_ptr = &arena_ptr;
                scope.spawn(move || {
                    let mut packed_cols = PackedCols::<T>::new();
                    loop {
                        let band = next.fetch_add(1, Ordering::Relaxed);
                        if band >= n_bands {
                            break;
                        }
                        let j0 = band * nc;
                        let ncc = (j0 + nc).min(plan.n) - j0;
                        // SAFETY: bands are disjoint output element sets;
                        // the inputs and the shared packed rows are
                        // read-only here, so each arena element is written
                        // by at most one thread.
                        let arena: &mut [T] =
                            unsafe { std::slice::from_raw_parts_mut(arena_ptr.0, arena_len) };
                        match T::nr(micro) {
                            4 => macro_band::<T, 4>(
                                arena, pr, &mut packed_cols, plan, k0, kcc, j0, ncc, l1,
                            ),
                            6 => macro_band::<T, 6>(
                                arena, pr, &mut packed_cols, plan, k0, kcc, j0, ncc, l1,
                            ),
                            8 => macro_band::<T, 8>(
                                arena, pr, &mut packed_cols, plan, k0, kcc, j0, ncc, l1,
                            ),
                            12 => macro_band::<T, 12>(
                                arena, pr, &mut packed_cols, plan, k0, kcc, j0, ncc, l1,
                            ),
                            w => unreachable!("unsupported register-tile width {w}"),
                        }
                    }
                });
            }
        });
    }
}

/// One worker's macro-kernel band: pack the `kc×nc` column block
/// thread-locally, then drive the L1 tiles of every shared row block.
#[allow(clippy::too_many_arguments)]
fn macro_band<T: Scalar, const NRW: usize>(
    arena: &mut [T],
    pr: &PackedRows<T>,
    packed_cols: &mut PackedCols<T>,
    plan: &RunPlan,
    k0: usize,
    kcc: usize,
    j0: usize,
    ncc: usize,
    l1: (usize, usize),
) {
    packed_cols.pack_band::<NRW>(arena, plan, k0, kcc, j0, ncc);
    for bi in 0..pr.n_blocks() {
        run_macro_block::<T, NRW>(pr.block(bi), packed_cols, plan, j0, l1, arena);
    }
}

struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::executor::{max_abs_diff, KernelBuffers};
    use crate::domain::ops;
    use crate::lattice::IMat;
    use crate::tiling::TileBasis;

    #[test]
    fn parallel_matches_reference_rect() {
        let k = ops::matmul(24, 20, 28, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        for threads in [1, 2, 4] {
            let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
            let want = bufs.reference();
            run_parallel(&mut bufs, &k, &s, threads, 1);
            assert!(
                max_abs_diff(&want, &bufs.output()) < 1e-9,
                "threads={threads}"
            );
        }
    }

    #[test]
    fn parallel_matches_reference_rect_non_multiple() {
        // extents not multiples of the tile → boundary tiles exercise the
        // edge microkernel in every dimension
        let k = ops::matmul(23, 19, 17, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        let want = bufs.reference();
        run_parallel(&mut bufs, &k, &s, 3, 1);
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    }

    #[test]
    fn parallel_matches_reference_lattice() {
        let k = ops::matmul(16, 16, 16, 8, 0);
        let basis = TileBasis::from_cols(IMat::from_rows(&[
            &[3, 0, 1],
            &[0, 4, 0],
            &[1, 0, 4],
        ]));
        let s = TiledSchedule::new(basis);
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        let want = bufs.reference();
        run_parallel(&mut bufs, &k, &s, 4, 1);
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    }

    #[test]
    fn parallel_row_partition_takes_tile_path() {
        // partitioning over the row axis (i): groups are row bands, each
        // tile box runs through the per-tile packed engine
        let k = ops::matmul(25, 14, 18, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 6, 7]));
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        let want = bufs.reference();
        run_parallel(&mut bufs, &k, &s, 3, 0);
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    }

    #[test]
    fn parallel_reduction_output_degrades_serially() {
        // convolution's output is a scalar: any partition var has output
        // weight 0, so the group path must degrade to one worker and
        // still be exact
        let k = ops::convolution(57, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8]));
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        let want = bufs.reference();
        run_parallel(&mut bufs, &k, &s, 4, 0);
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    }

    #[test]
    fn parallel_macro_explicit_shape_matches_reference() {
        // multiple macro blocks in every dimension, bands narrower than
        // the L1 tile, threads > bands
        let k = ops::matmul(29, 23, 26, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let lp = LevelPlan {
            l1_tile: (8, 8, 8),
            mc: 12,
            kc: 7,
            nc: 5,
        };
        for threads in [1, 3, 8] {
            for micro in [MicroShape::Mr8Nr4, MicroShape::Mr8Nr6] {
                let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
                let want = bufs.reference();
                run_parallel_macro(&mut bufs, &k, &s, threads, Some(lp), micro);
                assert!(
                    max_abs_diff(&want, &bufs.output()) < 1e-9,
                    "threads={threads} micro={micro:?}"
                );
            }
        }
    }

    #[test]
    fn parallel_macro_f32_both_widths_matches_reference() {
        // the f32 band path at both width classes (8×8 and 8×12 panels),
        // bitwise against the integer-filled oracle
        let k = ops::matmul(29, 23, 26, 4, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let lp = LevelPlan {
            l1_tile: (8, 8, 8),
            mc: 12,
            kc: 7,
            nc: 9,
        };
        for threads in [1, 3] {
            for micro in [MicroShape::Mr8Nr4, MicroShape::Mr8Nr6] {
                let mut bufs = KernelBuffers::<f32>::from_kernel(&k);
                bufs.fill_ints(3, 0x32F);
                let want = bufs.reference();
                run_parallel_macro(&mut bufs, &k, &s, threads, Some(lp), micro);
                assert_eq!(
                    bufs.output(),
                    want,
                    "threads={threads} micro={micro:?} (f32)"
                );
            }
        }
    }

    #[test]
    fn parallel_macro_runs_kronecker() {
        let k = ops::kronecker(5, 4, 6, 3, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[2, 2, 4, 3]));
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        let want = bufs.reference();
        run_parallel_macro(&mut bufs, &k, &s, 3, None, MicroShape::Mr8Nr4);
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
        // via run_parallel: loop axis 0 (i) is a GEMM column axis for
        // Kronecker, so this takes the band path
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        run_parallel(&mut bufs, &k, &s, 4, 0);
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    }

    #[test]
    fn non_injective_output_degrades_serially() {
        // out[i+j] += in1[i] · in2[j]: GEMM-classified, but the output
        // map collides across (i, j) — the band path must be refused and
        // the group path must degrade to one worker instead of racing
        use crate::domain::access::AffineAccess;
        use crate::domain::{Kernel, OpRole, Operand};
        use crate::index::{Layout, Table};
        let n = 6i64;
        let a = Table::new("A", &[2 * n - 1], Layout::ColumnMajor, 8, 0);
        let b = Table::new("B", &[n], Layout::ColumnMajor, 8, (2 * n - 1) as usize * 8);
        let c = Table::new("C", &[n], Layout::ColumnMajor, 8, (3 * n - 1) as usize * 8);
        let kernel = Kernel::new(
            "outer_sum",
            vec![n, n],
            vec![
                Operand {
                    table: a,
                    access: AffineAccess::new(vec![vec![1, 1]], vec![0]),
                    role: OpRole::ReadWrite,
                },
                Operand {
                    table: b,
                    access: AffineAccess::select(2, &[0]),
                    role: OpRole::Read,
                },
                Operand {
                    table: c,
                    access: AffineAccess::select(2, &[1]),
                    role: OpRole::Read,
                },
            ],
        );
        assert!(GemmForm::of(&kernel).is_some());
        assert!(!GemmForm::of(&kernel)
            .unwrap()
            .output_injective(&kernel_views(&kernel), kernel.extents()));
        let s = TiledSchedule::new(TileBasis::rect(&[2, 2]));
        for pv in [0usize, 1] {
            let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
            let want = bufs.reference();
            run_parallel(&mut bufs, &kernel, &s, 4, pv);
            assert!(max_abs_diff(&want, &bufs.output()) < 1e-9, "pv={pv}");
        }
    }

    #[test]
    #[should_panic(expected = "coupled")]
    fn coupled_partition_var_rejected() {
        let k = ops::matmul(8, 8, 8, 8, 0);
        // tile couples j with i
        let basis = TileBasis::from_cols(IMat::from_rows(&[
            &[2, 1, 0],
            &[1, 4, 0],
            &[0, 0, 2],
        ]));
        let s = TiledSchedule::new(basis);
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        run_parallel(&mut bufs, &k, &s, 2, 1);
    }
}
