//! Auto-threading — §4.0.3 (DESIGN.md S11; OpenMP substitute),
//! kernel-agnostic since the `RunPlan` refactor and element-generic since
//! the `Scalar` refactor (every entry point is `T: Scalar`; the dtype's
//! autotuned register width is dispatched per call).
//!
//! Rect schedules of GEMM-form kernels run the three-level macro-kernel
//! with parallelism over whole `m3×n3` **L3 super-bands** (mc-aligned
//! GEMM row ranges × nc-aligned column ranges sized against the L3
//! slice): workers claim super-bands from a shared claim board —
//! preferring bands adjacent to their last claim (sticky worker↔band
//! affinity, the NUMA-friendly ordering) — and each worker packs its
//! **own** row slice ([`PackedRows`]) for its band's row range per `kc`
//! step, plus its own column bands ([`PackedCols`]); both packed
//! operands stay local to the worker (and socket) that streams them,
//! which is what keeps them from ping-ponging across the last-level
//! cache on many-core hosts. Super-bands are disjoint output element
//! sets (the kernel's output map is injective per (row, column)), so no
//! write races occur; each worker runs its band's whole reduction,
//! preserving the serial per-element accumulation order. This is the
//! paper's `omp parallel for` over the outer tile loop, lifted from L1
//! tiles to L3-sized output blocks.
//!
//! Within one claimed band the default schedule is a **two-stage
//! software pipeline** ([`ParallelTuning::pipeline`]): each worker owns
//! two [`PackStage`] buffer sets and a companion pack thread; while the
//! microkernel streams stage `k0`'s panels, the companion fills stage
//! `k0+kc`'s row slice and column bands into the other set, so
//! steady-state `kc` steps never stall on packing
//! ([`ParallelMacroStats::pack_ahead_hits`] counts the steps whose
//! panels were ready on arrival). The handoff moves whole stage sets
//! through channels — the buffers are never aliased, and the pipeline
//! reorders *packing only*: every output element still accumulates its
//! `kc` slices in ascending-`k0` order, bitwise identical to the serial
//! nest. When the claim board drains, idle workers **steal `mc`-block
//! subranges** of a busy worker's band ([`ParallelTuning::steal`]): the
//! victim publishes the tail half of its remaining row blocks at a `kc`
//! stage boundary, the thief finishes those rows' remaining stages as an
//! independent sub-band (stages below the boundary are complete and
//! published under the offer lock, so per-element ascending-`k0` order
//! survives the handoff). A steal re-packs the stolen rows' panels on
//! the thief — the deliberate price for not serializing on a skewed
//! band's tail — so pack totals are exact schedule invariants only with
//! stealing off (see [`ParallelTuning::deterministic`]).
//!
//! Skewed schedules keep the footpoint partition: tile interiors run
//! through the same packing + microkernel engine as the serial
//! [`TiledExecutor`](super::executor::TiledExecutor) — per-tile
//! [`RunPlan`] boxes for rect bases, [`ReplayPlan`] panel replay for
//! skewed ones; every worker owns thread-local [`PackBuffers`] / scratch
//! so the hot loop performs no shared allocation. Kernels whose output
//! does not stride along the partition variable (e.g. convolution's
//! scalar output) degrade to one worker instead of racing — and their
//! degenerate `m = n = 1` boxes run the dot microkernel, not the panel
//! engine.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Mutex;

use crate::cache::CacheSpec;
use crate::coordinator::faults;
use crate::domain::Kernel;
use crate::tiling::{LevelPlan, TiledSchedule};

use super::executor::{
    box_key, compute_super_band_stage, pack_super_band_stage, run_rect_box_with, run_super_band,
    run_super_band_prepacked, KernelBuffers, ReplayPlan, ReplayScratch,
};
use super::pack::{PackBuffers, PackStage, PackedCols, PackedRows, StageKey};
use super::runplan::{kernel_views, view_injective, GemmForm, RunPlan};
use super::scalar::{MicroShape, Scalar};
use super::ExecOpts;

/// Execute the tiled kernel with `threads` worker threads, dispatching
/// the dtype's default (narrow) register tile. See [`run_parallel_micro`].
pub fn run_parallel<T: Scalar>(
    bufs: &mut KernelBuffers<T>,
    kernel: &Kernel,
    schedule: &TiledSchedule,
    threads: usize,
    partition_var: usize,
) {
    run_parallel_micro(
        bufs,
        kernel,
        schedule,
        threads,
        partition_var,
        MicroShape::Mr8Nr4,
    );
}

/// Execute the tiled kernel with `threads` worker threads and an explicit
/// register-tile width class (pass the dtype's autotuned winner from
/// [`Registry::micro_shape_for`](crate::runtime::Registry::micro_shape_for) /
/// [`Plan::micro`](crate::coordinator::Plan)). Footpoints are grouped by
/// their footpoint coordinate along `partition_var` (loop-space dimension
/// index; use 1 = `j` for matmul plans built by this crate); groups are
/// handed to workers round-robin. Panics if the tile basis couples
/// `partition_var` with other dimensions (the bands would not be
/// disjoint). Kernels whose output map cannot be proven injective per
/// (row, column) — or does not stride along `partition_var` — degrade to
/// one worker instead of racing.
pub fn run_parallel_micro<T: Scalar>(
    bufs: &mut KernelBuffers<T>,
    kernel: &Kernel,
    schedule: &TiledSchedule,
    threads: usize,
    partition_var: usize,
    micro: MicroShape,
) {
    run_parallel_micro_with(
        bufs,
        kernel,
        schedule,
        threads,
        partition_var,
        ExecOpts::new(micro),
    );
}

/// [`run_parallel_micro`]'s canonical entry point under one [`ExecOpts`]
/// params struct: geometry, precision (`acc64` =
/// [`Precision::wide_acc`](super::scalar::Precision::wide_acc) of the
/// execution's precision pair — every register tile and dot reduction
/// accumulates in `T::Acc` and rounds once per `kc` slice on writeback),
/// and pipeline tuning for the macro-kernel route.
pub fn run_parallel_micro_with<T: Scalar>(
    bufs: &mut KernelBuffers<T>,
    kernel: &Kernel,
    schedule: &TiledSchedule,
    threads: usize,
    partition_var: usize,
    opts: ExecOpts,
) {
    assert!(threads >= 1);
    let basis = schedule.basis();
    let d = basis.dim();
    // safety: partition_var must be decoupled — its row/col in the basis
    // touches only the diagonal
    for t in 0..d {
        if t != partition_var {
            assert_eq!(
                basis.basis()[(partition_var, t)],
                0,
                "partition var is coupled by the tile basis"
            );
            assert_eq!(
                basis.basis()[(t, partition_var)],
                0,
                "partition var is coupled by the tile basis"
            );
        }
    }

    let gf = GemmForm::of(kernel);
    let views = kernel_views(kernel);
    let extents_ref = kernel.extents();

    // Rect bases partitioned over a GEMM column axis take the
    // macro-kernel super-band path: workers claim whole L3-sized output
    // bands and pack their own row slices thread-locally. Requires a
    // provably injective output map — the write-disjointness of the
    // bands (true for all Table-1 ops).
    if basis.is_rect() {
        if let Some(gf) = &gf {
            if gf.col_axes.contains(&partition_var)
                && gf.output_injective(&views, extents_ref)
            {
                run_parallel_macro_with(bufs, kernel, schedule, threads, None, opts);
                return;
            }
        }
    }

    // Partition groups write disjoint output ranges only when the output
    // strides along the partition variable AND the output map is provably
    // injective on its striding axes; reduction-style outputs
    // (convolution, scalar product) and unprovable maps degrade to one
    // worker instead of racing.
    let out_axes: Vec<usize> = (0..d).filter(|&t| views[0].w[t] != 0).collect();
    let threads = if views[0].w[partition_var] == 0
        || !view_injective(&views[0], extents_ref, &out_axes)
    {
        1
    } else {
        threads
    };

    // collect footpoints, grouped by the partition coordinate
    let mut groups: std::collections::BTreeMap<i128, Vec<Vec<i128>>> =
        std::collections::BTreeMap::new();
    schedule.scan_feet(kernel.extents(), |foot| {
        groups
            .entry(foot[partition_var])
            .or_default()
            .push(foot.to_vec());
    });
    let groups: Vec<Vec<Vec<i128>>> = groups.into_values().collect();

    let extents = kernel.extents().to_vec();
    let rect_gemm = basis.is_rect() && gf.is_some();
    // skewed (or non-GEMM) tiles share the serial replay engine
    let rp = if rect_gemm {
        None
    } else {
        Some(ReplayPlan::new(kernel, schedule))
    };
    let sizes: Vec<i64> = (0..d).map(|t| basis.basis()[(t, t)].max(1) as i64).collect();
    let (row_red_axes, col_red_axes): (Vec<usize>, Vec<usize>) = match &gf {
        Some(gf) => (
            gf.row_axes.iter().chain(&gf.red_axes).copied().collect(),
            gf.col_axes.iter().chain(&gf.red_axes).copied().collect(),
        ),
        None => (Vec::new(), Vec::new()),
    };

    // Work queue: group index counter.
    let next = AtomicUsize::new(0);
    let arena_ptr = SendPtr(bufs.arena.as_mut_ptr());
    let arena_len = bufs.arena.len();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            let groups = &groups;
            let next = &next;
            let extents = &extents;
            let arena_ptr = &arena_ptr;
            let rp = rp.as_ref();
            let gf = gf.as_ref();
            let views = &views;
            let sizes = &sizes;
            let row_red_axes = &row_red_axes;
            let col_red_axes = &col_red_axes;
            scope.spawn(move || {
                let d = extents.len();
                // thread-local pack buffers + replay/plan scratch; packed
                // boxes are reused across consecutive tiles via their box
                // keys (run_rect_box_with), so nothing is re-packed when only
                // the column coordinate advances, and the scratch RunPlan
                // keeps the per-tile loop allocation-free in steady state
                let mut packs = PackBuffers::<T>::new();
                let mut scratch = ReplayScratch::<T>::default();
                let mut plan = RunPlan::default();
                let mut lo = vec![0i64; d];
                let mut hi = vec![0i64; d];
                loop {
                    let g = next.fetch_add(1, Ordering::Relaxed);
                    if g >= groups.len() {
                        break;
                    }
                    // SAFETY: groups are disjoint output ranges (the
                    // output strides along the decoupled partition
                    // variable and its map is injective on the striding
                    // axes — all checked above) and the inputs are
                    // read-only here; each arena element is written by at
                    // most one thread.
                    let arena: &mut [T] =
                        unsafe { std::slice::from_raw_parts_mut(arena_ptr.0, arena_len) };
                    for foot in &groups[g] {
                        if let (true, Some(gf)) = (rect_gemm, gf) {
                            // pack + microkernel over the clipped tile box
                            let mut empty = false;
                            for t in 0..d {
                                let o = (foot[t] as i64) * sizes[t];
                                lo[t] = o.clamp(0, extents[t]);
                                hi[t] = (o + sizes[t]).clamp(0, extents[t]);
                                empty |= lo[t] >= hi[t];
                            }
                            if empty {
                                continue;
                            }
                            gf.plan_box_into(views, &lo, &hi, &mut plan);
                            run_rect_box_with(
                                arena,
                                &plan,
                                &mut packs,
                                box_key(row_red_axes, &lo, &hi),
                                box_key(col_red_axes, &lo, &hi),
                                opts,
                            );
                        } else {
                            rp.unwrap().run_tile(arena, extents, foot, &mut scratch);
                        }
                    }
                }
            });
        }
    });
}

/// Execution counters of one [`run_parallel_macro_stats`] call — the
/// schedule-shape invariants the tests pin (claimed super-bands, pack
/// discipline) without reaching into thread-local buffers.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ParallelMacroStats {
    /// Super-bands in the claimed grid (row ranges × column ranges).
    pub super_bands: usize,
    /// Workers actually spawned (`min(threads, super_bands)`).
    pub workers: usize,
    /// Row-slice packs summed over workers: exactly one per claimed
    /// super-band per `kc` step, independent of the thread count.
    pub row_slice_packs: u64,
    /// Column-band packs summed over workers: one per `nc` band inside a
    /// claimed super-band per `kc` step (plus the stolen subranges'
    /// re-packs when stealing fired — see [`ParallelMacroStats::steals`]).
    pub col_band_packs: u64,
    /// Steady-state pipeline steps whose pack-ahead panels were already
    /// filled when the compute side finished the previous stage — the
    /// software pipeline's overlap wins. Always 0 with the pipeline off;
    /// timing-dependent (an upper bound of `kc` steps minus one per
    /// band-claim) with it on.
    pub pack_ahead_hits: u64,
    /// Sub-band steals executed: an idle worker took the tail half of a
    /// busy worker's remaining `mc` row blocks at a `kc` stage boundary.
    /// Deterministically 0 with one worker (nobody to steal from) or
    /// with [`ParallelTuning::steal`] off; each steal adds one extra
    /// pack region (the stolen rows' remaining stages re-pack on the
    /// thief).
    pub steals: u64,
}

/// Scheduler policy knobs of the parallel macro-kernel. The default is
/// the full pipelined scheduler (pack-ahead double buffering **and**
/// sub-band work stealing); [`ParallelTuning::deterministic`] keeps the
/// pipeline but disables stealing so pack totals stay exact schedule
/// invariants (what the serve path and the pack-discipline tests use);
/// [`ParallelTuning::synchronous`] is the legacy pack-then-compute
/// worker loop (the bench baseline the pipelined schedule is gated
/// against). Stealing requires the pipeline (steals hand off at its
/// stage boundaries), so `steal` is ignored when `pipeline` is off.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParallelTuning {
    /// Double-buffered pack-ahead: overlap stage `k0+kc` packing with
    /// stage `k0` compute on a companion pack thread per worker.
    pub pipeline: bool,
    /// Steal `mc`-block subranges of busy workers' bands once the claim
    /// board drains.
    pub steal: bool,
}

impl Default for ParallelTuning {
    fn default() -> ParallelTuning {
        ParallelTuning {
            pipeline: true,
            steal: true,
        }
    }
}

impl ParallelTuning {
    /// The legacy synchronous worker loop: pack, then compute, per `kc`
    /// step — no companion threads, no stealing.
    pub fn synchronous() -> ParallelTuning {
        ParallelTuning {
            pipeline: false,
            steal: false,
        }
    }

    /// Pipelined packing with stealing off: pack totals stay exact
    /// schedule invariants (one row slice per band per `kc` step, one
    /// column band per (band, `kc` step, `nc` band)) at every thread
    /// count.
    pub fn deterministic() -> ParallelTuning {
        ParallelTuning {
            pipeline: true,
            steal: false,
        }
    }

    /// Is sub-band stealing effectively on? (It rides the pipeline's
    /// stage boundaries.)
    fn steals_enabled(&self) -> bool {
        self.pipeline && self.steal
    }
}

/// The macro-kernel parallel path, scheduled at L3 granularity: the
/// output is partitioned into `m3×n3` **super-bands** (mc-aligned row
/// ranges × nc-aligned column ranges, sized by the [`LevelPlan`] against
/// the L3 slice), workers claim whole super-bands from an atomic work
/// queue, and each worker packs its **own** row slice for its band's row
/// range per `kc` step ([`PackedRows`], thread-local) alongside its own
/// column bands ([`PackedCols`]) — so both packed operands stay local to
/// the worker (and on NUMA hosts, to the socket) that streams them;
/// nothing packed is shared across threads. A worker runs its band's
/// whole reduction, so every output element still accumulates in
/// ascending `k0` order — the same schedule the serial [`run_macro`]
/// walks band by band.
///
/// Super-bands are disjoint output element sets (the kernel's output map
/// is injective per (row, column)), so writes never race. `level`
/// overrides the derived macro shape and is taken as-is; a *derived*
/// plan whose grid is coarser than the thread count is refined (rows
/// first) so shapes that fit one L3 super-band still parallelize.
/// `micro` selects the register-tile width class (the dtype's autotuned
/// winner from
/// [`Registry::micro_shape_for`](crate::runtime::Registry::micro_shape_for)).
///
/// [`run_macro`]: super::executor::run_macro
pub fn run_parallel_macro<T: Scalar>(
    bufs: &mut KernelBuffers<T>,
    kernel: &Kernel,
    schedule: &TiledSchedule,
    threads: usize,
    level: Option<LevelPlan>,
    micro: MicroShape,
) {
    run_parallel_macro_stats(bufs, kernel, schedule, threads, level, micro);
}

/// [`run_parallel_macro`], returning the schedule-shape counters.
pub fn run_parallel_macro_stats<T: Scalar>(
    bufs: &mut KernelBuffers<T>,
    kernel: &Kernel,
    schedule: &TiledSchedule,
    threads: usize,
    level: Option<LevelPlan>,
    micro: MicroShape,
) -> ParallelMacroStats {
    run_parallel_macro_tuned(
        bufs,
        kernel,
        schedule,
        threads,
        level,
        micro,
        ParallelTuning::default(),
    )
}

/// [`run_parallel_macro_stats`] with explicit scheduler policy — see
/// [`ParallelTuning`] for the modes (full pipelined default, pipelined
/// deterministic, legacy synchronous).
#[allow(clippy::too_many_arguments)]
pub fn run_parallel_macro_tuned<T: Scalar>(
    bufs: &mut KernelBuffers<T>,
    kernel: &Kernel,
    schedule: &TiledSchedule,
    threads: usize,
    level: Option<LevelPlan>,
    micro: MicroShape,
    tuning: ParallelTuning,
) -> ParallelMacroStats {
    run_parallel_macro_with(
        bufs,
        kernel,
        schedule,
        threads,
        level,
        ExecOpts::new(micro).with_tuning(tuning),
    )
}

/// The parallel macro-kernel's canonical entry point:
/// [`run_parallel_macro_tuned`] under one [`ExecOpts`] params struct —
/// geometry, precision (`acc64` widens every worker's register tiles to
/// `T::Acc`, rounding once per `kc` slice; the schedule is unchanged, so
/// the deterministic-tuning pack invariants still hold), and scheduler
/// policy.
pub fn run_parallel_macro_with<T: Scalar>(
    bufs: &mut KernelBuffers<T>,
    kernel: &Kernel,
    schedule: &TiledSchedule,
    threads: usize,
    level: Option<LevelPlan>,
    opts: ExecOpts,
) -> ParallelMacroStats {
    let (micro, tuning, acc64) = (opts.micro, opts.tuning, opts.acc64);
    assert!(threads >= 1);
    let basis = schedule.basis();
    assert!(basis.is_rect(), "macro-kernel path needs a rect L1 basis");
    let gf = GemmForm::of(kernel).expect("macro-kernel path needs a GEMM-form kernel");
    let views = kernel_views(kernel);
    let extents = kernel.extents();
    // bands write disjoint output element sets only when the output map
    // is injective per (row, column) — provable for every Table-1 op
    assert!(
        gf.output_injective(&views, extents),
        "macro-kernel bands need an injective output map"
    );
    let lo0 = vec![0i64; extents.len()];
    let plan = gf.plan_box(&views, &lo0, extents);
    if plan.m == 0 || plan.n == 0 || plan.k == 0 {
        return ParallelMacroStats::default();
    }
    if super::executor::is_dot_plan(&plan) {
        // degenerate dot: short-circuit into the dot microkernel exactly
        // like the serial path — no pack buffers, no threads
        super::executor::run_dot_acc(&mut bufs.arena, &plan, acc64);
        return ParallelMacroStats {
            super_bands: 1,
            workers: 1,
            ..ParallelMacroStats::default()
        };
    }
    let l1 = gf.l1_tile(basis);
    let mut lp = level.unwrap_or_else(|| {
        LevelPlan::heuristic(
            l1,
            (gf.m, gf.n, gf.k),
            T::ELEM,
            &CacheSpec::HASWELL_L2,
            Some(&CacheSpec::HASWELL_L3_SLICE),
        )
    });
    if level.is_none() && threads > 1 {
        // Parallel-grain guard for *derived* plans (explicit levels are
        // authoritative): a shape that fits one L3 super-band would
        // serialize, so refine the grid until it covers the thread count
        // — rows first (row-pack volume stays constant since row ranges
        // partition; each extra row band duplicates only the cheaper
        // kc×n3 column-band packs), then columns as the last resort
        // (each column split duplicates the m3×kc row-slice packs — the
        // expensive side).
        let (mut m3, mut n3) = super::executor::super_band_extents(&lp);
        let mc = lp.mc.max(1);
        let nc = lp.nc.max(1);
        let grid = |m3: usize, n3: usize| plan.m.div_ceil(m3) * plan.n.div_ceil(n3);
        while grid(m3, n3) < threads && m3 > mc {
            m3 = (m3 / mc).div_ceil(2).max(1) * mc;
        }
        while grid(m3, n3) < threads && n3 > nc {
            n3 = (n3 / nc).div_ceil(2).max(1) * nc;
        }
        lp.m3 = m3;
        lp.n3 = n3;
    }
    run_macro_workers(
        SendPtr(bufs.arena.as_mut_ptr()),
        bufs.arena.len(),
        &plan,
        &lp,
        micro,
        None,
        plan.n,
        threads,
        tuning,
        acc64,
    )
}

/// The pre-packed serve nest ([`run_macro_prepacked_cols`]) under the
/// super-band parallel scheduler: workers claim `m3×n3` super-bands of
/// the column prefix `[0, n_used)` from an atomic queue, read whole
/// mc-block subranges of the caller's **shared, resident** row slices
/// (packed once at startup — never re-packed, never duplicated per
/// worker), and pack only their own column bands into thread-local
/// buffers. This is the coalesced native serve path's route for batches
/// whose widened column extent spans more than one super-band: the
/// schedule per band is identical to the serial pre-packed nest, so
/// serial and parallel dispatch produce bit-identical outputs.
///
/// `kernel` must be the GEMM-form kernel `plan` was built from — its
/// output map is checked injective per (row, column), which is what makes
/// the concurrent band writes disjoint. `lp` and `rows` must match as in
/// [`run_macro_prepacked_cols`]. Returns the schedule counters; the
/// resident row slices contribute zero `row_slice_packs` by construction.
///
/// [`run_macro_prepacked_cols`]: super::executor::run_macro_prepacked_cols
#[allow(clippy::too_many_arguments)]
pub fn run_parallel_macro_prepacked<T: Scalar>(
    arena: &mut [T],
    kernel: &Kernel,
    plan: &RunPlan,
    lp: &LevelPlan,
    micro: MicroShape,
    rows: &[PackedRows<T>],
    threads: usize,
    n_used: usize,
) -> ParallelMacroStats {
    // the serve default: pipelined pack-ahead, stealing off — serving
    // keeps the exact per-band pack discipline (and so deterministic
    // per-request work) that the coalescing layer's tests pin
    run_parallel_macro_prepacked_with(
        arena,
        kernel,
        plan,
        lp,
        rows,
        threads,
        n_used,
        ExecOpts::serving(micro, false),
    )
}

/// The pre-packed parallel nest's canonical entry point:
/// [`run_parallel_macro_prepacked`] under one [`ExecOpts`] params struct
/// — geometry, precision (the `f32acc64` serve route streams resident
/// f32 panels through f64-accumulating register tiles, rounding once per
/// `kc` slice), and scheduler policy (the benches race synchronous vs
/// pipelined through this; the serve path passes
/// [`ExecOpts::serving`]'s deterministic tuning). Panics if the resident
/// slices were packed at a panel height other than `opts.micro.mr()` —
/// the pre-packed layout must match the dispatched register geometry.
#[allow(clippy::too_many_arguments)]
pub fn run_parallel_macro_prepacked_with<T: Scalar>(
    arena: &mut [T],
    kernel: &Kernel,
    plan: &RunPlan,
    lp: &LevelPlan,
    rows: &[PackedRows<T>],
    threads: usize,
    n_used: usize,
    opts: ExecOpts,
) -> ParallelMacroStats {
    let (micro, tuning, acc64) = (opts.micro, opts.tuning, opts.acc64);
    assert!(threads >= 1);
    assert!(
        rows.iter().all(|r| r.mr() == micro.mr()),
        "pre-packed slices were packed at a different panel height than the dispatched geometry"
    );
    assert!(n_used <= plan.n, "column prefix exceeds the plan");
    if plan.m == 0 || n_used == 0 || plan.k == 0 {
        return ParallelMacroStats::default();
    }
    if super::executor::is_dot_plan(plan) {
        super::executor::run_dot_acc(arena, plan, acc64);
        return ParallelMacroStats {
            super_bands: 1,
            workers: 1,
            ..ParallelMacroStats::default()
        };
    }
    let kc = lp.kc.max(1);
    assert_eq!(
        rows.len(),
        plan.k.div_ceil(kc),
        "pre-packed slices do not match the macro shape"
    );
    let gf = GemmForm::of(kernel).expect("prepacked parallel path needs a GEMM-form kernel");
    let views = kernel_views(kernel);
    assert!(
        gf.output_injective(&views, kernel.extents()),
        "prepacked parallel bands need an injective output map"
    );
    run_macro_workers(
        SendPtr(arena.as_mut_ptr()),
        arena.len(),
        plan,
        lp,
        micro,
        Some(rows),
        n_used,
        threads,
        tuning,
        acc64,
    )
}

struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}

// ---------------------------------------------------------------------
// The pipelined super-band engine shared by [`run_parallel_macro_tuned`]
// (workers pack their own row slices) and
// [`run_parallel_macro_prepacked_with`] (workers read shared resident
// slices): a claim board of super-bands with sticky affinity, a
// two-stage pack-ahead pipeline per worker, and sub-band steal offers
// resolved at `kc` stage boundaries.
// ---------------------------------------------------------------------

/// One published steal offer: the tail `mc`-block subrange of a busy
/// worker's band, up for grabs from reduction stage `from_stage` on.
/// Stages below `from_stage` are complete for these rows at publication
/// time, and publication/take both run under the offer lock, so the
/// thief observes every prior stage's writes — per-element ascending-`k0`
/// accumulation survives the handoff.
#[derive(Clone, Copy, Debug)]
struct StealOffer {
    /// First plan row of the stolen range (always `mc`-aligned: offers
    /// split at whole-block boundaries of an `mc`-aligned band start).
    r0: usize,
    /// Stolen row count.
    rows: usize,
    /// The band's column range (unchanged by the split).
    j3: usize,
    n3c: usize,
    /// First reduction stage the thief runs (`k0 = from_stage · kc`).
    from_stage: usize,
}

/// Shared state of one parallel macro-kernel run.
struct Shared<'a, T: Scalar> {
    plan: &'a RunPlan,
    lp: &'a LevelPlan,
    arena: SendPtr<T>,
    arena_len: usize,
    /// `Some` = read resident whole-extent row slices (prepacked serve
    /// path); `None` = each worker packs its own row slices.
    resident: Option<&'a [PackedRows<T>]>,
    /// Column extent actually executed (`n_used` prefix or `plan.n`).
    n_limit: usize,
    m3: usize,
    n3: usize,
    n_i3: usize,
    n_sb: usize,
    workers: usize,
    tuning: ParallelTuning,
    /// Register-tile panel height of the dispatched geometry
    /// (`micro.mr()`): worker-packed row slices adopt it, and the
    /// const-dispatch inside the block runner selects the matching
    /// kernel arm.
    mr: usize,
    /// Wide-accumulation flag: register tiles accumulate in `T::Acc`.
    acc64: bool,
    /// Claim board: one flag per super-band (sticky scan, not a FIFO).
    claimed: Vec<AtomicBool>,
    /// Bands not yet claimed — the steal trigger (drained ⇒ 0).
    unclaimed: AtomicUsize,
    /// Workers currently executing a band or stolen subrange.
    active: AtomicUsize,
    /// One offer slot per worker, guarded by a lock that doubles as the
    /// steal handoff's happens-before edge.
    offers: Mutex<Vec<Option<StealOffer>>>,
    row_packs: AtomicU64,
    col_packs: AtomicU64,
    hits: AtomicU64,
    steals: AtomicU64,
    /// The spawning thread's fault scope, re-entered by every worker and
    /// companion packer ([`faults::capture_scope`]) so `Pack` faults
    /// fire inside the parallel path too.
    faults: Option<faults::Faults>,
}

/// A worker's link to its companion pack thread: whole [`PackStage`]
/// sets circulate through the channel pair (requests carry an empty set
/// out, results bring it back filled), so exactly one side owns a buffer
/// at any time — the double-buffered handoff with no shared aliasing.
struct PipeLink<T: Scalar> {
    req: Sender<PackReq<T>>,
    done: Receiver<PackDone<T>>,
    /// Stage sets currently owned by the worker (2 between bands, 1
    /// while one request is in flight).
    free: Vec<PackStage<T>>,
}

struct PackReq<T: Scalar> {
    stage: PackStage<T>,
    key: StageKey,
    pack_rows: bool,
}

struct PackDone<T: Scalar> {
    stage: PackStage<T>,
    row_packs: u64,
    col_packs: u64,
}

/// Per-worker counter accumulator, flushed once at worker exit.
#[derive(Default)]
struct Local {
    rp: u64,
    cp: u64,
    hits: u64,
    steals: u64,
}

impl Local {
    fn flush<T: Scalar>(&self, sh: &Shared<'_, T>) {
        sh.row_packs.fetch_add(self.rp, Ordering::Relaxed);
        sh.col_packs.fetch_add(self.cp, Ordering::Relaxed);
        sh.hits.fetch_add(self.hits, Ordering::Relaxed);
        sh.steals.fetch_add(self.steals, Ordering::Relaxed);
    }
}

/// Decrement `active` on drop — unwind-safe, so a worker that panics
/// mid-band (an injected `Pack` fault) cannot wedge the other workers'
/// termination check.
struct ActiveGuard<'a>(&'a AtomicUsize);
impl Drop for ActiveGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

fn lock_offers<'a, T: Scalar>(
    sh: &'a Shared<'_, T>,
) -> std::sync::MutexGuard<'a, Vec<Option<StealOffer>>> {
    // offer slots are plain Copy data: a lock poisoned by an injected
    // unwind loses nothing
    sh.offers
        .lock()
        .unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Claim the first unclaimed band at or after `cursor` (wrapping) —
/// sticky affinity: a worker's cursor trails its last claim, so it
/// prefers the adjacent band (same row range, next column range in the
/// claim-index order) whose packed rows its caches are warm for.
fn claim_band<T: Scalar>(sh: &Shared<'_, T>, cursor: &mut usize) -> Option<usize> {
    if sh.unclaimed.load(Ordering::Relaxed) == 0 {
        return None;
    }
    for step in 0..sh.n_sb {
        let b = (*cursor + step) % sh.n_sb;
        if !sh.claimed[b].swap(true, Ordering::Relaxed) {
            sh.unclaimed.fetch_sub(1, Ordering::Relaxed);
            *cursor = (b + 1) % sh.n_sb;
            return Some(b);
        }
    }
    None
}

/// Take any published offer off the board (first found wins).
fn take_offer<T: Scalar>(sh: &Shared<'_, T>) -> Option<StealOffer> {
    let mut board = lock_offers(sh);
    board.iter_mut().find_map(|slot| slot.take())
}

/// The companion pack thread: fills requested stages from a read-only
/// arena view until the worker hangs up. An injected `Pack` fault
/// unwinds here; the worker sees the closed channel, stops, and the
/// panic propagates at scope join (the serve supervisor's
/// `catch_unwind` still contains it).
fn pack_worker<T: Scalar, const NRW: usize>(
    sh: &Shared<'_, T>,
    req: Receiver<PackReq<T>>,
    done: Sender<PackDone<T>>,
) {
    faults::with_scope_opt(sh.faults.as_ref(), || {
        while let Ok(mut r) = req.recv() {
            // SAFETY: packing reads input-operand bytes only, which no
            // thread writes during the run (compute writes go to the
            // disjoint output table), so this shared view never races
            // the workers' output stores.
            let arena: &[T] = unsafe { std::slice::from_raw_parts(sh.arena.0, sh.arena_len) };
            let (rp, cp) = pack_super_band_stage::<T, NRW>(
                arena,
                sh.plan,
                sh.lp,
                &mut r.stage,
                r.key,
                r.pack_rows,
                sh.mr,
            );
            if done
                .send(PackDone {
                    stage: r.stage,
                    row_packs: rp,
                    col_packs: cp,
                })
                .is_err()
            {
                break;
            }
        }
    });
}

/// Execute one band (or stolen subrange) `[r0, r0+rows_n) ×
/// [j3, j3+n3c)` from reduction stage `from_stage` on. Returns `false`
/// when the companion packer is gone (it panicked) — the worker should
/// stop and let scope join surface the unwind.
#[allow(clippy::too_many_arguments)]
fn run_band<T: Scalar, const NRW: usize>(
    sh: &Shared<'_, T>,
    wid: usize,
    link: &mut Option<PipeLink<T>>,
    sync_rows: &mut PackedRows<T>,
    sync_cols: &mut PackedCols<T>,
    (r0, rows_n): (usize, usize),
    (j3, n3c): (usize, usize),
    from_stage: usize,
    allow_offer: bool,
    c: &mut Local,
) -> bool {
    // SAFETY: this executor's output rows × columns are disjoint from
    // every other executor's (bands are disjoint through an injective
    // output map, checked by the entry points; stolen subranges split a
    // band by whole row blocks) and the inputs are read-only during the
    // run, so each arena element is written by at most one thread.
    let arena: &mut [T] = unsafe { std::slice::from_raw_parts_mut(sh.arena.0, sh.arena_len) };
    let Some(link) = link.as_mut() else {
        // synchronous mode: the legacy interleaved pack-then-compute nest
        let (rp, cp) = match sh.resident {
            Some(rows) => (
                0,
                run_super_band_prepacked::<T, NRW>(
                    arena,
                    sh.plan,
                    sh.lp,
                    rows,
                    sync_cols,
                    (r0, rows_n),
                    (j3, n3c),
                    sh.acc64,
                ),
            ),
            None => run_super_band::<T, NRW>(
                arena,
                sh.plan,
                sh.lp,
                sync_rows,
                sync_cols,
                (r0, rows_n),
                (j3, n3c),
                sh.acc64,
            ),
        };
        c.rp += rp;
        c.cp += cp;
        return true;
    };
    let kc = sh.lp.kc.max(1);
    let mc = sh.lp.mc.max(1);
    let n_stages = sh.plan.k.div_ceil(kc);
    let pack_rows = sh.resident.is_none();
    let key_for = |s: usize, rows_now: usize| {
        let k0 = s * kc;
        StageKey {
            k0,
            kcc: (k0 + kc).min(sh.plan.k) - k0,
            r0,
            rows: rows_now,
            j3,
            n3c,
            si: s,
        }
    };
    // rows still owned by this executor (steals shrink it from the tail)
    let mut committed = rows_n;
    // prime the pipeline: stage `from_stage` must be packed before any
    // compute — its wait is a startup stall, not a pack-ahead miss
    let Some(first) = link.free.pop() else {
        return false;
    };
    let mut expect = key_for(from_stage, committed);
    if link
        .req
        .send(PackReq {
            stage: first,
            key: expect,
            pack_rows,
        })
        .is_err()
    {
        return false;
    }
    for s in from_stage..n_stages {
        let got = match link.done.try_recv() {
            Ok(r) => {
                if s > from_stage {
                    c.hits += 1;
                }
                r
            }
            Err(TryRecvError::Empty) => match link.done.recv() {
                Ok(r) => r,
                Err(_) => return false,
            },
            Err(TryRecvError::Disconnected) => return false,
        };
        c.rp += got.row_packs;
        c.cp += got.col_packs;
        let stage = got.stage;
        let cur_key = expect;
        // publish a steal offer for the tail half of the remaining row
        // blocks — only once the claim board is drained (idle thieves
        // exist), and always resolved below before the next stage
        let blocks = committed.div_ceil(mc);
        let mut keep = committed;
        if allow_offer
            && sh.tuning.steals_enabled()
            && sh.workers > 1
            && blocks >= 2
            && sh.unclaimed.load(Ordering::Relaxed) == 0
        {
            let keep_rows = blocks.div_ceil(2) * mc;
            let offer = StealOffer {
                r0: r0 + keep_rows,
                rows: committed - keep_rows,
                j3,
                n3c,
                from_stage: s,
            };
            lock_offers(sh)[wid] = Some(offer);
            keep = keep_rows;
        }
        // pack-ahead: request stage s+1 before streaming stage s. The
        // request covers the pre-resolution range — a superset of what
        // stage s+1 will compute if the offer is taken, which is merely
        // wasted packing, never wrong data (compute clips to `committed`).
        if s + 1 < n_stages {
            let Some(spare) = link.free.pop() else {
                return false;
            };
            expect = key_for(s + 1, committed);
            if link
                .req
                .send(PackReq {
                    stage: spare,
                    key: expect,
                    pack_rows,
                })
                .is_err()
            {
                return false;
            }
        }
        // stream the blocks this executor certainly owns
        let (lo, hi) = match sh.resident {
            Some(_) => (r0 / mc, (r0 + keep).div_ceil(mc)),
            None => (0, keep.div_ceil(mc)),
        };
        compute_super_band_stage::<T, NRW>(
            arena,
            sh.plan,
            sh.lp,
            &stage,
            &cur_key,
            sh.resident,
            lo..hi,
            sh.acc64,
        );
        // resolve the offer: withdrawn → finish the tail from the same
        // panels (identical block order: 0..keep then keep..blocks);
        // taken → the thief owns those rows' remaining stages
        if keep < committed {
            let withdrawn = lock_offers(sh)[wid].take().is_some();
            if withdrawn {
                let (tlo, thi) = match sh.resident {
                    Some(_) => ((r0 + keep) / mc, (r0 + committed).div_ceil(mc)),
                    None => (keep / mc, committed.div_ceil(mc)),
                };
                compute_super_band_stage::<T, NRW>(
                    arena,
                    sh.plan,
                    sh.lp,
                    &stage,
                    &cur_key,
                    sh.resident,
                    tlo..thi,
                    sh.acc64,
                );
            } else {
                committed = keep;
            }
        }
        link.free.push(stage);
    }
    true
}

/// One worker's life: claim bands (sticky cursor) until the board
/// drains, then steal sub-band tails until nothing is active, then exit.
fn band_worker<T: Scalar, const NRW: usize>(
    sh: &Shared<'_, T>,
    wid: usize,
    mut link: Option<PipeLink<T>>,
) {
    faults::with_scope_opt(sh.faults.as_ref(), || {
        let mut sync_rows = PackedRows::<T>::new();
        sync_rows.set_mr(sh.mr);
        let mut sync_cols = PackedCols::<T>::new();
        // spread starting cursors so workers begin on distant bands
        let mut cursor = (wid * sh.n_sb) / sh.workers.max(1);
        let mut c = Local::default();
        loop {
            if let Some(b) = claim_band(sh, &mut cursor) {
                sh.active.fetch_add(1, Ordering::Relaxed);
                let guard = ActiveGuard(&sh.active);
                let i3 = (b % sh.n_i3) * sh.m3;
                let j3 = (b / sh.n_i3) * sh.n3;
                let m3c = sh.m3.min(sh.plan.m - i3);
                let n3c = sh.n3.min(sh.n_limit - j3);
                let ok = run_band::<T, NRW>(
                    sh,
                    wid,
                    &mut link,
                    &mut sync_rows,
                    &mut sync_cols,
                    (i3, m3c),
                    (j3, n3c),
                    0,
                    true,
                    &mut c,
                );
                drop(guard);
                if !ok {
                    break;
                }
                continue;
            }
            if !sh.tuning.steals_enabled() {
                break;
            }
            if let Some(of) = take_offer(sh) {
                sh.active.fetch_add(1, Ordering::Relaxed);
                let guard = ActiveGuard(&sh.active);
                c.steals += 1;
                // stolen subranges never re-offer: one level of splitting
                // is enough for tail latency, and it keeps the protocol
                // livelock-free
                let ok = run_band::<T, NRW>(
                    sh,
                    wid,
                    &mut link,
                    &mut sync_rows,
                    &mut sync_cols,
                    (of.r0, of.rows),
                    (of.j3, of.n3c),
                    of.from_stage,
                    false,
                    &mut c,
                );
                drop(guard);
                if !ok {
                    break;
                }
                continue;
            }
            // no bands, no offers: done once every owner has finished
            // (owners resolve their offers before finishing, so an empty
            // board + idle owners means no work can appear)
            if sh.unclaimed.load(Ordering::Relaxed) == 0 && sh.active.load(Ordering::Relaxed) == 0
            {
                break;
            }
            std::thread::yield_now();
        }
        c.flush(sh);
    });
}

/// Spawn the worker (and, in pipelined mode, companion packer) threads
/// for one monomorphized register width.
fn spawn_all<'scope, T: Scalar, const NRW: usize>(
    sh: &'scope Shared<'scope, T>,
    scope: &'scope std::thread::Scope<'scope, '_>,
) {
    for wid in 0..sh.workers {
        if sh.tuning.pipeline {
            let (req_tx, req_rx) = channel::<PackReq<T>>();
            let (done_tx, done_rx) = channel::<PackDone<T>>();
            scope.spawn(move || pack_worker::<T, NRW>(sh, req_rx, done_tx));
            let link = PipeLink {
                req: req_tx,
                done: done_rx,
                free: vec![PackStage::new(), PackStage::new()],
            };
            scope.spawn(move || band_worker::<T, NRW>(sh, wid, Some(link)));
        } else {
            scope.spawn(move || band_worker::<T, NRW>(sh, wid, None));
        }
    }
}

/// The engine entry: build the shared state, spawn, join, report.
#[allow(clippy::too_many_arguments)]
fn run_macro_workers<T: Scalar>(
    arena: SendPtr<T>,
    arena_len: usize,
    plan: &RunPlan,
    lp: &LevelPlan,
    micro: MicroShape,
    resident: Option<&[PackedRows<T>]>,
    n_limit: usize,
    threads: usize,
    tuning: ParallelTuning,
    acc64: bool,
) -> ParallelMacroStats {
    let (m3, n3) = super::executor::super_band_extents(lp);
    let n_i3 = plan.m.div_ceil(m3);
    let n_j3 = n_limit.div_ceil(n3);
    let n_sb = n_i3 * n_j3;
    let workers = threads.min(n_sb);
    let sh = Shared {
        plan,
        lp,
        arena,
        arena_len,
        resident,
        n_limit,
        m3,
        n3,
        n_i3,
        n_sb,
        workers,
        tuning,
        mr: micro.mr(),
        acc64,
        claimed: (0..n_sb).map(|_| AtomicBool::new(false)).collect(),
        unclaimed: AtomicUsize::new(n_sb),
        active: AtomicUsize::new(0),
        offers: Mutex::new(vec![None; workers]),
        row_packs: AtomicU64::new(0),
        col_packs: AtomicU64::new(0),
        hits: AtomicU64::new(0),
        steals: AtomicU64::new(0),
        faults: faults::capture_scope(),
    };
    std::thread::scope(|scope| match T::nr(micro) {
        4 => spawn_all::<T, 4>(&sh, scope),
        6 => spawn_all::<T, 6>(&sh, scope),
        8 => spawn_all::<T, 8>(&sh, scope),
        12 => spawn_all::<T, 12>(&sh, scope),
        w => unreachable!("unsupported register-tile width {w}"),
    });
    ParallelMacroStats {
        super_bands: n_sb,
        workers,
        row_slice_packs: sh.row_packs.load(Ordering::Relaxed),
        col_band_packs: sh.col_packs.load(Ordering::Relaxed),
        pack_ahead_hits: sh.hits.load(Ordering::Relaxed),
        steals: sh.steals.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::executor::{max_abs_diff, KernelBuffers};
    use crate::domain::ops;
    use crate::lattice::IMat;
    use crate::tiling::TileBasis;

    #[test]
    fn parallel_matches_reference_rect() {
        let k = ops::matmul(24, 20, 28, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        for threads in [1, 2, 4] {
            let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
            let want = bufs.reference();
            run_parallel(&mut bufs, &k, &s, threads, 1);
            assert!(
                max_abs_diff(&want, &bufs.output()) < 1e-9,
                "threads={threads}"
            );
        }
    }

    #[test]
    fn parallel_matches_reference_rect_non_multiple() {
        // extents not multiples of the tile → boundary tiles exercise the
        // edge microkernel in every dimension
        let k = ops::matmul(23, 19, 17, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        let want = bufs.reference();
        run_parallel(&mut bufs, &k, &s, 3, 1);
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    }

    #[test]
    fn parallel_matches_reference_lattice() {
        let k = ops::matmul(16, 16, 16, 8, 0);
        let basis = TileBasis::from_cols(IMat::from_rows(&[
            &[3, 0, 1],
            &[0, 4, 0],
            &[1, 0, 4],
        ]));
        let s = TiledSchedule::new(basis);
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        let want = bufs.reference();
        run_parallel(&mut bufs, &k, &s, 4, 1);
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    }

    #[test]
    fn parallel_row_partition_takes_tile_path() {
        // partitioning over the row axis (i): groups are row bands, each
        // tile box runs through the per-tile packed engine
        let k = ops::matmul(25, 14, 18, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 6, 7]));
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        let want = bufs.reference();
        run_parallel(&mut bufs, &k, &s, 3, 0);
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    }

    #[test]
    fn parallel_reduction_output_degrades_serially() {
        // convolution's output is a scalar: any partition var has output
        // weight 0, so the group path must degrade to one worker and
        // still be exact
        let k = ops::convolution(57, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8]));
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        let want = bufs.reference();
        run_parallel(&mut bufs, &k, &s, 4, 0);
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    }

    #[test]
    fn parallel_macro_explicit_shape_matches_reference() {
        // multiple macro blocks in every dimension, bands narrower than
        // the L1 tile, super-band extents dividing neither m nor n,
        // threads > super-bands (2×3 grid, 8 threads)
        let k = ops::matmul(29, 23, 26, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let lp = LevelPlan {
            l1_tile: (8, 8, 8),
            mc: 12,
            kc: 7,
            nc: 5,
            m3: 24,
            n3: 10,
        };
        for threads in [1, 3, 8] {
            for micro in MicroShape::CANDIDATES {
                let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
                let want = bufs.reference();
                run_parallel_macro(&mut bufs, &k, &s, threads, Some(lp), micro);
                assert!(
                    max_abs_diff(&want, &bufs.output()) < 1e-9,
                    "threads={threads} micro={micro:?}"
                );
            }
        }
    }

    #[test]
    fn parallel_macro_f32_both_widths_matches_reference() {
        // the f32 band path at both width classes (8×8 and 8×12 panels),
        // bitwise against the integer-filled oracle
        let k = ops::matmul(29, 23, 26, 4, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let lp = LevelPlan {
            l1_tile: (8, 8, 8),
            mc: 12,
            kc: 7,
            nc: 9,
            m3: 12,
            n3: 18,
        };
        for threads in [1, 3] {
            for micro in MicroShape::CANDIDATES {
                let mut bufs = KernelBuffers::<f32>::from_kernel(&k);
                bufs.fill_ints(3, 0x32F);
                let want = bufs.reference();
                run_parallel_macro(&mut bufs, &k, &s, threads, Some(lp), micro);
                assert_eq!(
                    bufs.output(),
                    want,
                    "threads={threads} micro={micro:?} (f32)"
                );
            }
        }
    }

    #[test]
    fn parallel_acc64_is_bitwise_the_serial_wide_schedule() {
        // the f32acc64 parallel path: every worker widens its register
        // tiles to f64 and rounds once per kc slice — band schedules are
        // identical to the serial wide nest, so outputs match bitwise at
        // every thread count and geometry
        use crate::codegen::executor::run_macro_acc;
        let k = ops::matmul(29, 23, 26, 4, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let lp = LevelPlan {
            l1_tile: (8, 8, 8),
            mc: 12,
            kc: 7,
            nc: 9,
            m3: 12,
            n3: 18,
        };
        for micro in [MicroShape::Mr8Nr4, MicroShape::Mr16Nr6] {
            let mut serial = KernelBuffers::<f32>::from_kernel(&k);
            serial.fill_ints(3, 0xACC);
            let gf = GemmForm::of(&k).unwrap();
            let plan = gf.plan_box(&kernel_views(&k), &[0, 0, 0], k.extents());
            run_macro_acc(
                &mut serial.arena,
                &plan,
                &lp,
                micro,
                &mut PackedRows::new(),
                &mut PackedCols::new(),
                true,
            );
            let want = serial.output();
            for threads in [1usize, 3] {
                let mut bufs = KernelBuffers::<f32>::from_kernel(&k);
                bufs.fill_ints(3, 0xACC);
                run_parallel_macro_with(
                    &mut bufs,
                    &k,
                    &s,
                    threads,
                    Some(lp),
                    ExecOpts::serving(micro, true),
                );
                assert_eq!(
                    bufs.output(),
                    want,
                    "threads={threads} micro={micro:?}: parallel acc64 must be bitwise serial acc64"
                );
            }
        }
    }

    #[test]
    fn parallel_macro_dot_short_circuits_without_packing() {
        // the degenerate m = n = 1 form must take the dot microkernel
        // directly — no pack buffers, no worker threads
        for kernel in [ops::convolution(57, 8, 0), ops::scalar_product(41, 8, 0)] {
            let s = TiledSchedule::new(TileBasis::rect(&[8]));
            let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
            let want = bufs.reference();
            let stats =
                run_parallel_macro_stats(&mut bufs, &kernel, &s, 4, None, MicroShape::Mr8Nr4);
            assert_eq!(stats.row_slice_packs, 0, "dot path must not pack rows");
            assert_eq!(stats.col_band_packs, 0, "dot path must not pack columns");
            assert_eq!((stats.super_bands, stats.workers), (1, 1));
            assert!(
                max_abs_diff(&want, &bufs.output()) < 1e-9,
                "{}",
                kernel.name()
            );
        }
    }

    #[test]
    fn parallel_macro_pack_counts_independent_of_threads() {
        // the pack-discipline invariant: each claimed super-band's row
        // slice is packed exactly once per kc step by its owning worker,
        // each column band once per (band, kc step) — totals must not
        // depend on the thread count, including oversubscription
        let k = ops::matmul(40, 14, 22, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let lp = LevelPlan {
            l1_tile: (8, 8, 8),
            mc: 8,
            kc: 7,
            nc: 5,
            m3: 16,
            n3: 10,
        };
        let kslices = 2u64; // ceil(14 / 7)
        let (n_i3, n_j3) = (3usize, 3usize); // ceil(40/16) × ceil(22/10)
        let col_bands_per_band: u64 = 2 + 2 + 1; // ceil(10/5), ceil(10/5), ceil(2/5)
        for threads in [1usize, 2, 5, 16] {
            let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
            bufs.fill_ints(3, 0x51);
            let want = bufs.reference();
            // deterministic tuning: pipelining on, stealing off — steals
            // re-pack stolen subranges, which is the one scheduler mode
            // whose pack totals are *not* thread-count invariants
            let stats = run_parallel_macro_tuned(
                &mut bufs,
                &k,
                &s,
                threads,
                Some(lp),
                MicroShape::Mr8Nr4,
                ParallelTuning::deterministic(),
            );
            assert_eq!(stats.steals, 0, "stealing disabled at threads={threads}");
            assert_eq!(stats.super_bands, n_i3 * n_j3);
            assert_eq!(stats.workers, threads.min(n_i3 * n_j3));
            assert_eq!(
                stats.row_slice_packs,
                (n_i3 * n_j3) as u64 * kslices,
                "row-slice pack discipline broken at threads={threads}"
            );
            assert_eq!(
                stats.col_band_packs,
                col_bands_per_band * n_i3 as u64 * kslices,
                "column-band pack discipline broken at threads={threads}"
            );
            assert_eq!(bufs.output(), want, "threads={threads}");
        }
    }

    #[test]
    fn derived_plan_refines_grain_for_threads() {
        // 192×256×64 f64: the derived heuristic gives mc = 64 and one
        // 192-row super-band — serial. With 4 threads the grain guard
        // must refine the rows down to mc, yielding the maximal 3-band
        // grid (ceil(192/64) × 1) and 3 workers
        let k = ops::matmul(192, 256, 64, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        let want = bufs.reference();
        let stats = run_parallel_macro_stats(&mut bufs, &k, &s, 4, None, MicroShape::Mr8Nr4);
        assert!(
            stats.super_bands >= 3,
            "derived grid must refine for the thread count: {stats:?}"
        );
        assert!(stats.workers >= 3, "{stats:?}");
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    }

    #[test]
    fn single_super_band_degenerates_to_flat_schedule() {
        // a plan with no super-band level (m3/n3 ≥ the GEMM extents) must
        // claim exactly one band on one worker and walk the identical
        // schedule as the serial macro-kernel — bitwise
        use crate::codegen::executor::run_macro;
        let k = ops::matmul(33, 17, 21, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let flat = LevelPlan::flat((8, 8, 8), 12, 6, 7);
        let mut par = KernelBuffers::<f64>::from_kernel(&k);
        par.fill_ints(3, 0x5F);
        let mut ser = par.clone();
        let want = par.reference();
        let stats = run_parallel_macro_stats(&mut par, &k, &s, 4, Some(flat), MicroShape::Mr8Nr4);
        assert_eq!(stats.super_bands, 1, "flat plan must be a single super-band");
        assert_eq!(stats.workers, 1);
        let gf = GemmForm::of(&k).unwrap();
        let plan = gf.plan_box(&kernel_views(&k), &[0, 0, 0], k.extents());
        run_macro(
            &mut ser.arena,
            &plan,
            &flat,
            MicroShape::Mr8Nr4,
            &mut PackedRows::new(),
            &mut PackedCols::new(),
        );
        assert_eq!(par.output(), want);
        assert_eq!(
            ser.output(),
            par.output(),
            "single-band parallel run must be bitwise the serial schedule"
        );
    }

    #[test]
    fn unaligned_super_band_extents_are_normalized() {
        // m3/n3 that are not mc/nc multiples are aligned down, never up:
        // the schedule stays correct and the grid reflects the aligned
        // extents (m3 19→16 with mc=8, n3 7→5 with nc=5)
        let k = ops::matmul(30, 11, 13, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let lp = LevelPlan {
            l1_tile: (8, 8, 8),
            mc: 8,
            kc: 6,
            nc: 5,
            m3: 19,
            n3: 7,
        };
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        let want = bufs.reference();
        let stats = run_parallel_macro_stats(&mut bufs, &k, &s, 3, Some(lp), MicroShape::Mr8Nr4);
        assert_eq!(stats.super_bands, 30usize.div_ceil(16) * 13usize.div_ceil(5));
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    }

    #[test]
    fn parallel_macro_runs_kronecker() {
        let k = ops::kronecker(5, 4, 6, 3, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[2, 2, 4, 3]));
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        let want = bufs.reference();
        run_parallel_macro(&mut bufs, &k, &s, 3, None, MicroShape::Mr8Nr4);
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
        // via run_parallel: loop axis 0 (i) is a GEMM column axis for
        // Kronecker, so this takes the band path
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        run_parallel(&mut bufs, &k, &s, 4, 0);
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    }

    #[test]
    fn non_injective_output_degrades_serially() {
        // out[i+j] += in1[i] · in2[j]: GEMM-classified, but the output
        // map collides across (i, j) — the band path must be refused and
        // the group path must degrade to one worker instead of racing
        use crate::domain::access::AffineAccess;
        use crate::domain::{Kernel, OpRole, Operand};
        use crate::index::{Layout, Table};
        let n = 6i64;
        let a = Table::new("A", &[2 * n - 1], Layout::ColumnMajor, 8, 0);
        let b = Table::new("B", &[n], Layout::ColumnMajor, 8, (2 * n - 1) as usize * 8);
        let c = Table::new("C", &[n], Layout::ColumnMajor, 8, (3 * n - 1) as usize * 8);
        let kernel = Kernel::new(
            "outer_sum",
            vec![n, n],
            vec![
                Operand {
                    table: a,
                    access: AffineAccess::new(vec![vec![1, 1]], vec![0]),
                    role: OpRole::ReadWrite,
                },
                Operand {
                    table: b,
                    access: AffineAccess::select(2, &[0]),
                    role: OpRole::Read,
                },
                Operand {
                    table: c,
                    access: AffineAccess::select(2, &[1]),
                    role: OpRole::Read,
                },
            ],
        );
        assert!(GemmForm::of(&kernel).is_some());
        assert!(!GemmForm::of(&kernel)
            .unwrap()
            .output_injective(&kernel_views(&kernel), kernel.extents()));
        let s = TiledSchedule::new(TileBasis::rect(&[2, 2]));
        for pv in [0usize, 1] {
            let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
            let want = bufs.reference();
            run_parallel(&mut bufs, &kernel, &s, 4, pv);
            assert!(max_abs_diff(&want, &bufs.output()) < 1e-9, "pv={pv}");
        }
    }

    #[test]
    fn parallel_prepacked_matches_serial_prefix_bitwise() {
        // the coalesced-serve contract: resident rows packed once at
        // startup are shared read-only across workers, and the parallel
        // column-prefix dispatch is bit-identical to the serial
        // pre-packed nest at every batch width and thread count
        use crate::codegen::executor::{pack_row_slices, run_macro_prepacked_cols};
        let k = ops::matmul(26, 19, 36, 8, 0);
        let views = kernel_views(&k);
        let gf = GemmForm::of(&k).unwrap();
        let plan = gf.plan_box(&views, &[0, 0, 0], k.extents());
        let lp = LevelPlan {
            l1_tile: (8, 8, 8),
            mc: 12,
            kc: 7,
            nc: 9,
            m3: 24,
            n3: 18,
        };
        let kslices = 3u64; // ceil(19 / 7)
        for n_used in [9usize, 20, 36] {
            // serial prefix run as the bitwise oracle
            let mut serial = KernelBuffers::<f64>::from_kernel(&k);
            serial.fill_ints(5, 0x9A7);
            let s_rows = pack_row_slices(&serial.arena, &plan, &lp);
            let mut s_cols = PackedCols::<f64>::new();
            run_macro_prepacked_cols(
                &mut serial.arena,
                &plan,
                &lp,
                MicroShape::Mr8Nr4,
                &s_rows,
                &mut s_cols,
                n_used,
            );
            let want = serial.output();
            for threads in [1usize, 2, 5, 16] {
                let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
                bufs.fill_ints(5, 0x9A7);
                let rows = pack_row_slices(&bufs.arena, &plan, &lp);
                let packed: u64 = rows.iter().map(|r| r.pack_count()).sum();
                let stats = run_parallel_macro_prepacked(
                    &mut bufs.arena,
                    &k,
                    &plan,
                    &lp,
                    MicroShape::Mr8Nr4,
                    &rows,
                    threads,
                    n_used,
                );
                assert_eq!(
                    bufs.output(),
                    want,
                    "n_used={n_used} threads={threads}: parallel prefix must be bitwise serial"
                );
                // shared resident rows: never packed by workers
                let repacked: u64 = rows.iter().map(|r| r.pack_count()).sum();
                assert_eq!(packed, repacked, "workers must not repack resident rows");
                assert_eq!(stats.row_slice_packs, 0);
                let n_j3 = n_used.div_ceil(18);
                assert_eq!(stats.super_bands, 2 * n_j3); // ceil(26/24) = 2 row bands
                assert_eq!(stats.workers, threads.min(2 * n_j3));
                // one column-band pack per (row band, kc slice, nc band)
                let nc_bands: u64 = (0..n_used as u64)
                    .step_by(18)
                    .map(|j3| (n_used as u64 - j3).min(18).div_ceil(9))
                    .sum();
                assert_eq!(
                    stats.col_band_packs,
                    2 * kslices * nc_bands,
                    "n_used={n_used} threads={threads}"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "coupled")]
    fn coupled_partition_var_rejected() {
        let k = ops::matmul(8, 8, 8, 8, 0);
        // tile couples j with i
        let basis = TileBasis::from_cols(IMat::from_rows(&[
            &[2, 1, 0],
            &[1, 4, 0],
            &[0, 0, 2],
        ]));
        let s = TiledSchedule::new(basis);
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        run_parallel(&mut bufs, &k, &s, 2, 1);
    }

    #[test]
    fn single_worker_never_steals() {
        // steal-counter determinism: one worker has nobody to steal from,
        // so even with the full default tuning (stealing ON) the counter
        // is pinned at exactly zero — and the pack totals match the
        // deterministic schedule's
        let k = ops::matmul(40, 14, 22, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let lp = LevelPlan {
            l1_tile: (8, 8, 8),
            mc: 8,
            kc: 7,
            nc: 5,
            m3: 16,
            n3: 10,
        };
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        bufs.fill_ints(3, 0x51);
        let want = bufs.reference();
        let stats = run_parallel_macro_tuned(
            &mut bufs,
            &k,
            &s,
            1,
            Some(lp),
            MicroShape::Mr8Nr4,
            ParallelTuning::default(),
        );
        assert_eq!(stats.steals, 0, "one worker must never steal");
        assert_eq!(stats.workers, 1);
        assert_eq!(stats.row_slice_packs, 9 * 2); // bands × kc slices
        assert_eq!(stats.col_band_packs, 5 * 3 * 2);
        assert_eq!(bufs.output(), want);
    }

    #[test]
    fn synchronous_tuning_is_the_legacy_loop() {
        // ParallelTuning::synchronous(): no companion threads → the
        // pipeline counters are structurally zero, and the result is
        // bitwise identical to the pipelined schedule (the pipeline
        // reorders packing, never accumulation)
        let k = ops::matmul(29, 23, 26, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let lp = LevelPlan {
            l1_tile: (8, 8, 8),
            mc: 12,
            kc: 7,
            nc: 5,
            m3: 24,
            n3: 10,
        };
        let mut sync = KernelBuffers::<f64>::from_kernel(&k);
        sync.fill_ints(3, 0x77);
        let mut piped = sync.clone();
        let want = sync.reference();
        let st = run_parallel_macro_tuned(
            &mut sync,
            &k,
            &s,
            4,
            Some(lp),
            MicroShape::Mr8Nr4,
            ParallelTuning::synchronous(),
        );
        assert_eq!(st.pack_ahead_hits, 0, "no pipeline, no pack-ahead hits");
        assert_eq!(st.steals, 0, "no pipeline, no stage boundaries to steal at");
        let pt = run_parallel_macro_tuned(
            &mut piped,
            &k,
            &s,
            4,
            Some(lp),
            MicroShape::Mr8Nr4,
            ParallelTuning::default(),
        );
        assert_eq!(sync.output(), want);
        assert_eq!(
            piped.output(),
            sync.output(),
            "pipelined and synchronous schedules must agree bitwise"
        );
        // identical claim grid either way
        assert_eq!((pt.super_bands, pt.workers), (st.super_bands, st.workers));
    }

    #[test]
    fn stealing_preserves_bitwise_results_on_skewed_grids() {
        // a tall skewed shape — few bands, many mc blocks per band — is
        // the steal-friendly worst case: with more workers than bands the
        // board drains instantly and idle workers depend on sub-band
        // steals for any overlap. Whether or not a steal fires on a given
        // run (it is timing-dependent), the output must stay bitwise the
        // serial reference.
        let k = ops::matmul(96, 21, 10, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let lp = LevelPlan {
            l1_tile: (8, 8, 8),
            mc: 8,
            kc: 7,
            nc: 5,
            m3: 48,
            n3: 10,
        };
        let mut oracle = KernelBuffers::<f64>::from_kernel(&k);
        oracle.fill_ints(3, 0xBEE);
        let want = oracle.reference();
        for round in 0..8 {
            let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
            bufs.fill_ints(3, 0xBEE);
            let stats = run_parallel_macro_tuned(
                &mut bufs,
                &k,
                &s,
                4,
                Some(lp),
                MicroShape::Mr8Nr4,
                ParallelTuning::default(),
            );
            assert_eq!(
                bufs.output(),
                want,
                "round={round} steals={} hits={}",
                stats.steals,
                stats.pack_ahead_hits
            );
        }
    }

    #[test]
    fn injected_pack_fault_crosses_into_parallel_workers() {
        // PR 7 left the fault-injection scope thread-local, so spawned
        // super-band workers never saw it. The engine now captures the
        // caller's scope and re-enters it in every worker and companion
        // packer: an armed Pack fault must fire inside the parallel path
        // (the shared fired counter proves where), unwind the packer,
        // and propagate at scope join — never hang the run.
        use crate::coordinator::faults::{FaultMode, FaultPoint, Faults};
        let k = ops::matmul(40, 14, 22, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let lp = LevelPlan {
            l1_tile: (8, 8, 8),
            mc: 8,
            kc: 7,
            nc: 5,
            m3: 16,
            n3: 10,
        };
        for tuning in [ParallelTuning::default(), ParallelTuning::synchronous()] {
            let armed = Faults::seeded(0xFA17)
                .fail(FaultPoint::Pack, FaultMode::Panic, 1, 1)
                .build();
            let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
            let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                faults::with_scope(&armed, || {
                    run_parallel_macro_tuned(
                        &mut bufs,
                        &k,
                        &s,
                        4,
                        Some(lp),
                        MicroShape::Mr8Nr4,
                        tuning,
                    )
                })
            }));
            assert!(
                run.is_err(),
                "{tuning:?}: the injected Pack fault must propagate at scope join"
            );
            assert!(
                armed.fired(FaultPoint::Pack) > 0,
                "{tuning:?}: the fault must fire inside a spawned worker"
            );
        }
    }

    // ----- loom-style model of the pack-ahead handoff -------------------
    //
    // The real handoff moves whole `PackStage` sets through mpsc channels;
    // its correctness rests on an ordering argument (a stage is computed
    // only after the packer's send of that exact stage, and a buffer is
    // owned by exactly one side at a time), not on timing. The vendored
    // dependency set has no `loom`, so this is a hand-rolled exhaustive
    // scheduler: the worker and packer are step functions over a shared
    // model state, and the test enumerates EVERY interleaving of their
    // steps (DFS over scheduling choices), asserting the pipeline
    // invariants in each.

    /// One circulating buffer of the model.
    #[derive(Clone, Copy, PartialEq, Debug)]
    enum Buf {
        /// Owned by the worker, contents stale.
        Free,
        /// In the request channel, tagged with the stage to pack.
        Requested(usize),
        /// In the done channel, holding the packed stage.
        Packed(usize),
    }

    /// The whole handoff state: two buffers, the worker's program counter
    /// over `n_stages` compute steps, and the compute log.
    #[derive(Clone, PartialEq, Debug)]
    struct Model {
        bufs: [Buf; 2],
        /// Next stage the worker will compute.
        next_compute: usize,
        /// Next stage the worker will request (prime + pack-ahead).
        next_request: usize,
        /// Stages computed, in order.
        log: Vec<usize>,
        n_stages: usize,
    }

    impl Model {
        fn new(n_stages: usize) -> Model {
            Model {
                bufs: [Buf::Free, Buf::Free],
                next_compute: 0,
                next_request: 0,
                log: Vec::new(),
                n_stages,
            }
        }

        /// Worker step: request the next stage into a free buffer if one
        /// is pending, else compute from a packed buffer. Returns false
        /// when no worker step is enabled (waiting on the packer).
        fn worker_step(&mut self) -> bool {
            // pack-ahead: issue the outstanding request first — this is
            // the "send before compute" order of the real loop
            if self.next_request < self.n_stages {
                if let Some(i) = self.bufs.iter().position(|b| *b == Buf::Free) {
                    self.bufs[i] = Buf::Requested(self.next_request);
                    self.next_request += 1;
                    return true;
                }
            }
            if self.next_compute < self.n_stages {
                if let Some(i) = self
                    .bufs
                    .iter()
                    .position(|b| *b == Buf::Packed(self.next_compute))
                {
                    self.log.push(self.next_compute);
                    self.next_compute += 1;
                    self.bufs[i] = Buf::Free;
                    return true;
                }
            }
            false
        }

        /// Packer step: fill the oldest requested buffer.
        fn packer_step(&mut self) -> bool {
            let req = self
                .bufs
                .iter()
                .enumerate()
                .filter_map(|(i, b)| match b {
                    Buf::Requested(s) => Some((*s, i)),
                    _ => None,
                })
                .min();
            match req {
                Some((s, i)) => {
                    self.bufs[i] = Buf::Packed(s);
                    true
                }
                None => false,
            }
        }

        fn done(&self) -> bool {
            self.next_compute == self.n_stages
        }

        /// The pipeline invariants, checked at every reachable state.
        fn check(&self) {
            // single ownership: at most one buffer holds any given stage
            if let (Buf::Requested(a) | Buf::Packed(a), Buf::Requested(b) | Buf::Packed(b)) =
                (self.bufs[0], self.bufs[1])
            {
                assert_ne!(a, b, "a stage may live in one buffer only");
            }
            // compute order: strictly ascending stages, no skips
            for (i, &s) in self.log.iter().enumerate() {
                assert_eq!(s, i, "stages must be computed in ascending k0 order");
            }
            // pack-ahead depth: never more than 2 stages ahead of compute
            assert!(self.next_request <= self.next_compute + 2);
        }
    }

    #[test]
    fn pack_ahead_handoff_model_all_interleavings() {
        // exhaustively schedule worker vs packer from every reachable
        // state; every maximal execution must terminate with all stages
        // computed in order (no deadlock, no skip, no reorder)
        fn explore(
            m: &Model,
            seen: &mut std::collections::HashSet<(Vec<u8>, usize, usize)>,
        ) {
            let fp = (
                m.bufs
                    .iter()
                    .map(|b| match b {
                        Buf::Free => 0u8,
                        Buf::Requested(s) => 1 + 2 * *s as u8,
                        Buf::Packed(s) => 2 + 2 * *s as u8,
                    })
                    .collect::<Vec<u8>>(),
                m.next_compute,
                m.next_request,
            );
            if !seen.insert(fp) {
                return;
            }
            m.check();
            let mut progressed = false;
            let mut w = m.clone();
            if w.worker_step() {
                progressed = true;
                explore(&w, seen);
            }
            let mut p = m.clone();
            if p.packer_step() {
                progressed = true;
                explore(&p, seen);
            }
            if !progressed {
                assert!(
                    m.done(),
                    "handoff deadlocked with stages left: {m:?}"
                );
                assert_eq!(m.log, (0..m.n_stages).collect::<Vec<_>>());
            }
        }
        for n_stages in 0..=6 {
            let mut seen = std::collections::HashSet::new();
            explore(&Model::new(n_stages), &mut seen);
            assert!(
                !seen.is_empty(),
                "model must reach at least the initial state"
            );
        }
    }
}
