//! Auto-threading — §4.0.3 (DESIGN.md S11; OpenMP substitute),
//! kernel-agnostic since the `RunPlan` refactor and element-generic since
//! the `Scalar` refactor (every entry point is `T: Scalar`; the dtype's
//! autotuned register width is dispatched per call).
//!
//! Rect schedules of GEMM-form kernels run the three-level macro-kernel
//! with parallelism over whole `m3×n3` **L3 super-bands** (mc-aligned
//! GEMM row ranges × nc-aligned column ranges sized against the L3
//! slice): workers claim super-bands from an atomic work queue and each
//! worker packs its **own** row slice ([`PackedRows`]) for its band's
//! row range per `kc` step, plus its own column bands ([`PackedCols`]) —
//! both packed operands stay local to the worker (and socket) that
//! streams them, which is what keeps them from ping-ponging across the
//! last-level cache on many-core hosts. Super-bands are disjoint output
//! element sets (the kernel's output map is injective per
//! (row, column)), so no write races occur; each worker runs its band's
//! whole reduction, preserving the serial per-element accumulation
//! order. This is the paper's `omp parallel for` over the outer tile
//! loop, lifted from L1 tiles to L3-sized output blocks.
//!
//! Skewed schedules keep the footpoint partition: tile interiors run
//! through the same packing + microkernel engine as the serial
//! [`TiledExecutor`](super::executor::TiledExecutor) — per-tile
//! [`RunPlan`] boxes for rect bases, [`ReplayPlan`] panel replay for
//! skewed ones; every worker owns thread-local [`PackBuffers`] / scratch
//! so the hot loop performs no shared allocation. Kernels whose output
//! does not stride along the partition variable (e.g. convolution's
//! scalar output) degrade to one worker instead of racing — and their
//! degenerate `m = n = 1` boxes run the dot microkernel, not the panel
//! engine.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::cache::CacheSpec;
use crate::domain::Kernel;
use crate::tiling::{LevelPlan, TiledSchedule};

use super::autotune::MicroShape;
use super::executor::{box_key, run_rect_box, KernelBuffers, ReplayPlan, ReplayScratch};
use super::pack::{PackBuffers, PackedCols, PackedRows};
use super::runplan::{kernel_views, view_injective, GemmForm, RunPlan};
use super::scalar::Scalar;

/// Execute the tiled kernel with `threads` worker threads, dispatching
/// the dtype's default (narrow) register tile. See [`run_parallel_micro`].
pub fn run_parallel<T: Scalar>(
    bufs: &mut KernelBuffers<T>,
    kernel: &Kernel,
    schedule: &TiledSchedule,
    threads: usize,
    partition_var: usize,
) {
    run_parallel_micro(
        bufs,
        kernel,
        schedule,
        threads,
        partition_var,
        MicroShape::Mr8Nr4,
    );
}

/// Execute the tiled kernel with `threads` worker threads and an explicit
/// register-tile width class (pass the dtype's autotuned winner from
/// [`Registry::micro_shape_for`](crate::runtime::Registry::micro_shape_for) /
/// [`Plan::micro`](crate::coordinator::Plan)). Footpoints are grouped by
/// their footpoint coordinate along `partition_var` (loop-space dimension
/// index; use 1 = `j` for matmul plans built by this crate); groups are
/// handed to workers round-robin. Panics if the tile basis couples
/// `partition_var` with other dimensions (the bands would not be
/// disjoint). Kernels whose output map cannot be proven injective per
/// (row, column) — or does not stride along `partition_var` — degrade to
/// one worker instead of racing.
pub fn run_parallel_micro<T: Scalar>(
    bufs: &mut KernelBuffers<T>,
    kernel: &Kernel,
    schedule: &TiledSchedule,
    threads: usize,
    partition_var: usize,
    micro: MicroShape,
) {
    assert!(threads >= 1);
    let basis = schedule.basis();
    let d = basis.dim();
    // safety: partition_var must be decoupled — its row/col in the basis
    // touches only the diagonal
    for t in 0..d {
        if t != partition_var {
            assert_eq!(
                basis.basis()[(partition_var, t)],
                0,
                "partition var is coupled by the tile basis"
            );
            assert_eq!(
                basis.basis()[(t, partition_var)],
                0,
                "partition var is coupled by the tile basis"
            );
        }
    }

    let gf = GemmForm::of(kernel);
    let views = kernel_views(kernel);
    let extents_ref = kernel.extents();

    // Rect bases partitioned over a GEMM column axis take the
    // macro-kernel super-band path: workers claim whole L3-sized output
    // bands and pack their own row slices thread-locally. Requires a
    // provably injective output map — the write-disjointness of the
    // bands (true for all Table-1 ops).
    if basis.is_rect() {
        if let Some(gf) = &gf {
            if gf.col_axes.contains(&partition_var)
                && gf.output_injective(&views, extents_ref)
            {
                run_parallel_macro(bufs, kernel, schedule, threads, None, micro);
                return;
            }
        }
    }

    // Partition groups write disjoint output ranges only when the output
    // strides along the partition variable AND the output map is provably
    // injective on its striding axes; reduction-style outputs
    // (convolution, scalar product) and unprovable maps degrade to one
    // worker instead of racing.
    let out_axes: Vec<usize> = (0..d).filter(|&t| views[0].w[t] != 0).collect();
    let threads = if views[0].w[partition_var] == 0
        || !view_injective(&views[0], extents_ref, &out_axes)
    {
        1
    } else {
        threads
    };

    // collect footpoints, grouped by the partition coordinate
    let mut groups: std::collections::BTreeMap<i128, Vec<Vec<i128>>> =
        std::collections::BTreeMap::new();
    schedule.scan_feet(kernel.extents(), |foot| {
        groups
            .entry(foot[partition_var])
            .or_default()
            .push(foot.to_vec());
    });
    let groups: Vec<Vec<Vec<i128>>> = groups.into_values().collect();

    let extents = kernel.extents().to_vec();
    let rect_gemm = basis.is_rect() && gf.is_some();
    // skewed (or non-GEMM) tiles share the serial replay engine
    let rp = if rect_gemm {
        None
    } else {
        Some(ReplayPlan::new(kernel, schedule))
    };
    let sizes: Vec<i64> = (0..d).map(|t| basis.basis()[(t, t)].max(1) as i64).collect();
    let (row_red_axes, col_red_axes): (Vec<usize>, Vec<usize>) = match &gf {
        Some(gf) => (
            gf.row_axes.iter().chain(&gf.red_axes).copied().collect(),
            gf.col_axes.iter().chain(&gf.red_axes).copied().collect(),
        ),
        None => (Vec::new(), Vec::new()),
    };

    // Work queue: group index counter.
    let next = AtomicUsize::new(0);
    let arena_ptr = SendPtr(bufs.arena.as_mut_ptr());
    let arena_len = bufs.arena.len();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            let groups = &groups;
            let next = &next;
            let extents = &extents;
            let arena_ptr = &arena_ptr;
            let rp = rp.as_ref();
            let gf = gf.as_ref();
            let views = &views;
            let sizes = &sizes;
            let row_red_axes = &row_red_axes;
            let col_red_axes = &col_red_axes;
            scope.spawn(move || {
                let d = extents.len();
                // thread-local pack buffers + replay/plan scratch; packed
                // boxes are reused across consecutive tiles via their box
                // keys (run_rect_box), so nothing is re-packed when only
                // the column coordinate advances, and the scratch RunPlan
                // keeps the per-tile loop allocation-free in steady state
                let mut packs = PackBuffers::<T>::new();
                let mut scratch = ReplayScratch::<T>::default();
                let mut plan = RunPlan::default();
                let mut lo = vec![0i64; d];
                let mut hi = vec![0i64; d];
                loop {
                    let g = next.fetch_add(1, Ordering::Relaxed);
                    if g >= groups.len() {
                        break;
                    }
                    // SAFETY: groups are disjoint output ranges (the
                    // output strides along the decoupled partition
                    // variable and its map is injective on the striding
                    // axes — all checked above) and the inputs are
                    // read-only here; each arena element is written by at
                    // most one thread.
                    let arena: &mut [T] =
                        unsafe { std::slice::from_raw_parts_mut(arena_ptr.0, arena_len) };
                    for foot in &groups[g] {
                        if let (true, Some(gf)) = (rect_gemm, gf) {
                            // pack + microkernel over the clipped tile box
                            let mut empty = false;
                            for t in 0..d {
                                let o = (foot[t] as i64) * sizes[t];
                                lo[t] = o.clamp(0, extents[t]);
                                hi[t] = (o + sizes[t]).clamp(0, extents[t]);
                                empty |= lo[t] >= hi[t];
                            }
                            if empty {
                                continue;
                            }
                            gf.plan_box_into(views, &lo, &hi, &mut plan);
                            run_rect_box(
                                arena,
                                &plan,
                                micro,
                                &mut packs,
                                box_key(row_red_axes, &lo, &hi),
                                box_key(col_red_axes, &lo, &hi),
                            );
                        } else {
                            rp.unwrap().run_tile(arena, extents, foot, &mut scratch);
                        }
                    }
                }
            });
        }
    });
}

/// Execution counters of one [`run_parallel_macro_stats`] call — the
/// schedule-shape invariants the tests pin (claimed super-bands, pack
/// discipline) without reaching into thread-local buffers.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ParallelMacroStats {
    /// Super-bands in the claimed grid (row ranges × column ranges).
    pub super_bands: usize,
    /// Workers actually spawned (`min(threads, super_bands)`).
    pub workers: usize,
    /// Row-slice packs summed over workers: exactly one per claimed
    /// super-band per `kc` step, independent of the thread count.
    pub row_slice_packs: u64,
    /// Column-band packs summed over workers: one per `nc` band inside a
    /// claimed super-band per `kc` step.
    pub col_band_packs: u64,
}

/// The macro-kernel parallel path, scheduled at L3 granularity: the
/// output is partitioned into `m3×n3` **super-bands** (mc-aligned row
/// ranges × nc-aligned column ranges, sized by the [`LevelPlan`] against
/// the L3 slice), workers claim whole super-bands from an atomic work
/// queue, and each worker packs its **own** row slice for its band's row
/// range per `kc` step ([`PackedRows`], thread-local) alongside its own
/// column bands ([`PackedCols`]) — so both packed operands stay local to
/// the worker (and on NUMA hosts, to the socket) that streams them;
/// nothing packed is shared across threads. A worker runs its band's
/// whole reduction, so every output element still accumulates in
/// ascending `k0` order — the same schedule the serial [`run_macro`]
/// walks band by band.
///
/// Super-bands are disjoint output element sets (the kernel's output map
/// is injective per (row, column)), so writes never race. `level`
/// overrides the derived macro shape and is taken as-is; a *derived*
/// plan whose grid is coarser than the thread count is refined (rows
/// first) so shapes that fit one L3 super-band still parallelize.
/// `micro` selects the register-tile width class (the dtype's autotuned
/// winner from
/// [`Registry::micro_shape_for`](crate::runtime::Registry::micro_shape_for)).
///
/// [`run_macro`]: super::executor::run_macro
pub fn run_parallel_macro<T: Scalar>(
    bufs: &mut KernelBuffers<T>,
    kernel: &Kernel,
    schedule: &TiledSchedule,
    threads: usize,
    level: Option<LevelPlan>,
    micro: MicroShape,
) {
    run_parallel_macro_stats(bufs, kernel, schedule, threads, level, micro);
}

/// [`run_parallel_macro`], returning the schedule-shape counters.
pub fn run_parallel_macro_stats<T: Scalar>(
    bufs: &mut KernelBuffers<T>,
    kernel: &Kernel,
    schedule: &TiledSchedule,
    threads: usize,
    level: Option<LevelPlan>,
    micro: MicroShape,
) -> ParallelMacroStats {
    assert!(threads >= 1);
    let basis = schedule.basis();
    assert!(basis.is_rect(), "macro-kernel path needs a rect L1 basis");
    let gf = GemmForm::of(kernel).expect("macro-kernel path needs a GEMM-form kernel");
    let views = kernel_views(kernel);
    let extents = kernel.extents();
    // bands write disjoint output element sets only when the output map
    // is injective per (row, column) — provable for every Table-1 op
    assert!(
        gf.output_injective(&views, extents),
        "macro-kernel bands need an injective output map"
    );
    let lo0 = vec![0i64; extents.len()];
    let plan = gf.plan_box(&views, &lo0, extents);
    if plan.m == 0 || plan.n == 0 || plan.k == 0 {
        return ParallelMacroStats::default();
    }
    if super::executor::is_dot_plan(&plan) {
        // degenerate dot: short-circuit into the dot microkernel exactly
        // like the serial path — no pack buffers, no threads
        super::executor::run_dot(&mut bufs.arena, &plan);
        return ParallelMacroStats {
            super_bands: 1,
            workers: 1,
            ..ParallelMacroStats::default()
        };
    }
    let l1 = gf.l1_tile(basis);
    let mut lp = level.unwrap_or_else(|| {
        LevelPlan::heuristic(
            l1,
            (gf.m, gf.n, gf.k),
            T::ELEM,
            &CacheSpec::HASWELL_L2,
            Some(&CacheSpec::HASWELL_L3_SLICE),
        )
    });
    if level.is_none() && threads > 1 {
        // Parallel-grain guard for *derived* plans (explicit levels are
        // authoritative): a shape that fits one L3 super-band would
        // serialize, so refine the grid until it covers the thread count
        // — rows first (row-pack volume stays constant since row ranges
        // partition; each extra row band duplicates only the cheaper
        // kc×n3 column-band packs), then columns as the last resort
        // (each column split duplicates the m3×kc row-slice packs — the
        // expensive side).
        let (mut m3, mut n3) = super::executor::super_band_extents(&lp);
        let mc = lp.mc.max(1);
        let nc = lp.nc.max(1);
        let grid = |m3: usize, n3: usize| plan.m.div_ceil(m3) * plan.n.div_ceil(n3);
        while grid(m3, n3) < threads && m3 > mc {
            m3 = (m3 / mc).div_ceil(2).max(1) * mc;
        }
        while grid(m3, n3) < threads && n3 > nc {
            n3 = (n3 / nc).div_ceil(2).max(1) * nc;
        }
        lp.m3 = m3;
        lp.n3 = n3;
    }
    let (m3, n3) = super::executor::super_band_extents(&lp);
    let n_i3 = plan.m.div_ceil(m3);
    let n_j3 = plan.n.div_ceil(n3);
    let n_sb = n_i3 * n_j3;
    let workers = threads.min(n_sb);
    let arena_len = bufs.arena.len();
    let plan = &plan;
    let lp = &lp;
    let next = AtomicUsize::new(0);
    let row_packs = AtomicU64::new(0);
    let col_packs = AtomicU64::new(0);
    let arena_ptr = SendPtr(bufs.arena.as_mut_ptr());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let next = &next;
            let row_packs = &row_packs;
            let col_packs = &col_packs;
            let arena_ptr = &arena_ptr;
            scope.spawn(move || {
                // thread-local pack buffers: the claimed band's row slice
                // and column bands are packed (and re-used) here, never
                // shared with another worker
                let mut rows = PackedRows::<T>::new();
                let mut cols = PackedCols::<T>::new();
                let (mut rp, mut cp) = (0u64, 0u64);
                loop {
                    let b = next.fetch_add(1, Ordering::Relaxed);
                    if b >= n_sb {
                        break;
                    }
                    let i3 = (b % n_i3) * m3;
                    let j3 = (b / n_i3) * n3;
                    let m3c = m3.min(plan.m - i3);
                    let n3c = n3.min(plan.n - j3);
                    // SAFETY: super-bands are disjoint output element
                    // sets (row range × column range through an injective
                    // output map, checked above) and the inputs are
                    // read-only during the run, so each arena element is
                    // written by at most one thread.
                    let arena: &mut [T] =
                        unsafe { std::slice::from_raw_parts_mut(arena_ptr.0, arena_len) };
                    let (r, c) = match T::nr(micro) {
                        4 => super::executor::run_super_band::<T, 4>(
                            arena, plan, lp, &mut rows, &mut cols, (i3, m3c), (j3, n3c),
                        ),
                        6 => super::executor::run_super_band::<T, 6>(
                            arena, plan, lp, &mut rows, &mut cols, (i3, m3c), (j3, n3c),
                        ),
                        8 => super::executor::run_super_band::<T, 8>(
                            arena, plan, lp, &mut rows, &mut cols, (i3, m3c), (j3, n3c),
                        ),
                        12 => super::executor::run_super_band::<T, 12>(
                            arena, plan, lp, &mut rows, &mut cols, (i3, m3c), (j3, n3c),
                        ),
                        w => unreachable!("unsupported register-tile width {w}"),
                    };
                    rp += r;
                    cp += c;
                }
                row_packs.fetch_add(rp, Ordering::Relaxed);
                col_packs.fetch_add(cp, Ordering::Relaxed);
            });
        }
    });
    ParallelMacroStats {
        super_bands: n_sb,
        workers,
        row_slice_packs: row_packs.load(Ordering::Relaxed),
        col_band_packs: col_packs.load(Ordering::Relaxed),
    }
}

/// The pre-packed serve nest ([`run_macro_prepacked_cols`]) under the
/// super-band parallel scheduler: workers claim `m3×n3` super-bands of
/// the column prefix `[0, n_used)` from an atomic queue, read whole
/// mc-block subranges of the caller's **shared, resident** row slices
/// (packed once at startup — never re-packed, never duplicated per
/// worker), and pack only their own column bands into thread-local
/// buffers. This is the coalesced native serve path's route for batches
/// whose widened column extent spans more than one super-band: the
/// schedule per band is identical to the serial pre-packed nest, so
/// serial and parallel dispatch produce bit-identical outputs.
///
/// `kernel` must be the GEMM-form kernel `plan` was built from — its
/// output map is checked injective per (row, column), which is what makes
/// the concurrent band writes disjoint. `lp` and `rows` must match as in
/// [`run_macro_prepacked_cols`]. Returns the schedule counters; the
/// resident row slices contribute zero `row_slice_packs` by construction.
///
/// [`run_macro_prepacked_cols`]: super::executor::run_macro_prepacked_cols
#[allow(clippy::too_many_arguments)]
pub fn run_parallel_macro_prepacked<T: Scalar>(
    arena: &mut [T],
    kernel: &Kernel,
    plan: &RunPlan,
    lp: &LevelPlan,
    micro: MicroShape,
    rows: &[PackedRows<T>],
    threads: usize,
    n_used: usize,
) -> ParallelMacroStats {
    assert!(threads >= 1);
    assert!(n_used <= plan.n, "column prefix exceeds the plan");
    if plan.m == 0 || n_used == 0 || plan.k == 0 {
        return ParallelMacroStats::default();
    }
    if super::executor::is_dot_plan(plan) {
        super::executor::run_dot(arena, plan);
        return ParallelMacroStats {
            super_bands: 1,
            workers: 1,
            ..ParallelMacroStats::default()
        };
    }
    let kc = lp.kc.max(1);
    assert_eq!(
        rows.len(),
        plan.k.div_ceil(kc),
        "pre-packed slices do not match the macro shape"
    );
    let gf = GemmForm::of(kernel).expect("prepacked parallel path needs a GEMM-form kernel");
    let views = kernel_views(kernel);
    assert!(
        gf.output_injective(&views, kernel.extents()),
        "prepacked parallel bands need an injective output map"
    );
    let (m3, n3) = super::executor::super_band_extents(lp);
    let n_i3 = plan.m.div_ceil(m3);
    let n_j3 = n_used.div_ceil(n3);
    let n_sb = n_i3 * n_j3;
    let workers = threads.min(n_sb);
    let arena_len = arena.len();
    let next = AtomicUsize::new(0);
    let col_packs = AtomicU64::new(0);
    let arena_ptr = SendPtr(arena.as_mut_ptr());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let next = &next;
            let col_packs = &col_packs;
            let arena_ptr = &arena_ptr;
            scope.spawn(move || {
                // thread-local column bands; the resident row slices are
                // shared read-only across all workers
                let mut cols = PackedCols::<T>::new();
                let mut cp = 0u64;
                loop {
                    let b = next.fetch_add(1, Ordering::Relaxed);
                    if b >= n_sb {
                        break;
                    }
                    let i3 = (b % n_i3) * m3;
                    let j3 = (b / n_i3) * n3;
                    let m3c = m3.min(plan.m - i3);
                    let n3c = n3.min(n_used - j3);
                    // SAFETY: super-bands are disjoint output element
                    // sets (row range × column range through an injective
                    // output map, checked above) and the inputs are
                    // read-only during the run, so each arena element is
                    // written by at most one thread.
                    let arena: &mut [T] =
                        unsafe { std::slice::from_raw_parts_mut(arena_ptr.0, arena_len) };
                    cp += match T::nr(micro) {
                        4 => super::executor::run_super_band_prepacked::<T, 4>(
                            arena, plan, lp, rows, &mut cols, (i3, m3c), (j3, n3c),
                        ),
                        6 => super::executor::run_super_band_prepacked::<T, 6>(
                            arena, plan, lp, rows, &mut cols, (i3, m3c), (j3, n3c),
                        ),
                        8 => super::executor::run_super_band_prepacked::<T, 8>(
                            arena, plan, lp, rows, &mut cols, (i3, m3c), (j3, n3c),
                        ),
                        12 => super::executor::run_super_band_prepacked::<T, 12>(
                            arena, plan, lp, rows, &mut cols, (i3, m3c), (j3, n3c),
                        ),
                        w => unreachable!("unsupported register-tile width {w}"),
                    };
                }
                col_packs.fetch_add(cp, Ordering::Relaxed);
            });
        }
    });
    ParallelMacroStats {
        super_bands: n_sb,
        workers,
        row_slice_packs: 0,
        col_band_packs: col_packs.load(Ordering::Relaxed),
    }
}

struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::executor::{max_abs_diff, KernelBuffers};
    use crate::domain::ops;
    use crate::lattice::IMat;
    use crate::tiling::TileBasis;

    #[test]
    fn parallel_matches_reference_rect() {
        let k = ops::matmul(24, 20, 28, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        for threads in [1, 2, 4] {
            let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
            let want = bufs.reference();
            run_parallel(&mut bufs, &k, &s, threads, 1);
            assert!(
                max_abs_diff(&want, &bufs.output()) < 1e-9,
                "threads={threads}"
            );
        }
    }

    #[test]
    fn parallel_matches_reference_rect_non_multiple() {
        // extents not multiples of the tile → boundary tiles exercise the
        // edge microkernel in every dimension
        let k = ops::matmul(23, 19, 17, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        let want = bufs.reference();
        run_parallel(&mut bufs, &k, &s, 3, 1);
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    }

    #[test]
    fn parallel_matches_reference_lattice() {
        let k = ops::matmul(16, 16, 16, 8, 0);
        let basis = TileBasis::from_cols(IMat::from_rows(&[
            &[3, 0, 1],
            &[0, 4, 0],
            &[1, 0, 4],
        ]));
        let s = TiledSchedule::new(basis);
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        let want = bufs.reference();
        run_parallel(&mut bufs, &k, &s, 4, 1);
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    }

    #[test]
    fn parallel_row_partition_takes_tile_path() {
        // partitioning over the row axis (i): groups are row bands, each
        // tile box runs through the per-tile packed engine
        let k = ops::matmul(25, 14, 18, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 6, 7]));
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        let want = bufs.reference();
        run_parallel(&mut bufs, &k, &s, 3, 0);
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    }

    #[test]
    fn parallel_reduction_output_degrades_serially() {
        // convolution's output is a scalar: any partition var has output
        // weight 0, so the group path must degrade to one worker and
        // still be exact
        let k = ops::convolution(57, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8]));
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        let want = bufs.reference();
        run_parallel(&mut bufs, &k, &s, 4, 0);
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    }

    #[test]
    fn parallel_macro_explicit_shape_matches_reference() {
        // multiple macro blocks in every dimension, bands narrower than
        // the L1 tile, super-band extents dividing neither m nor n,
        // threads > super-bands (2×3 grid, 8 threads)
        let k = ops::matmul(29, 23, 26, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let lp = LevelPlan {
            l1_tile: (8, 8, 8),
            mc: 12,
            kc: 7,
            nc: 5,
            m3: 24,
            n3: 10,
        };
        for threads in [1, 3, 8] {
            for micro in [MicroShape::Mr8Nr4, MicroShape::Mr8Nr6] {
                let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
                let want = bufs.reference();
                run_parallel_macro(&mut bufs, &k, &s, threads, Some(lp), micro);
                assert!(
                    max_abs_diff(&want, &bufs.output()) < 1e-9,
                    "threads={threads} micro={micro:?}"
                );
            }
        }
    }

    #[test]
    fn parallel_macro_f32_both_widths_matches_reference() {
        // the f32 band path at both width classes (8×8 and 8×12 panels),
        // bitwise against the integer-filled oracle
        let k = ops::matmul(29, 23, 26, 4, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let lp = LevelPlan {
            l1_tile: (8, 8, 8),
            mc: 12,
            kc: 7,
            nc: 9,
            m3: 12,
            n3: 18,
        };
        for threads in [1, 3] {
            for micro in [MicroShape::Mr8Nr4, MicroShape::Mr8Nr6] {
                let mut bufs = KernelBuffers::<f32>::from_kernel(&k);
                bufs.fill_ints(3, 0x32F);
                let want = bufs.reference();
                run_parallel_macro(&mut bufs, &k, &s, threads, Some(lp), micro);
                assert_eq!(
                    bufs.output(),
                    want,
                    "threads={threads} micro={micro:?} (f32)"
                );
            }
        }
    }

    #[test]
    fn parallel_macro_dot_short_circuits_without_packing() {
        // the degenerate m = n = 1 form must take the dot microkernel
        // directly — no pack buffers, no worker threads
        for kernel in [ops::convolution(57, 8, 0), ops::scalar_product(41, 8, 0)] {
            let s = TiledSchedule::new(TileBasis::rect(&[8]));
            let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
            let want = bufs.reference();
            let stats =
                run_parallel_macro_stats(&mut bufs, &kernel, &s, 4, None, MicroShape::Mr8Nr4);
            assert_eq!(stats.row_slice_packs, 0, "dot path must not pack rows");
            assert_eq!(stats.col_band_packs, 0, "dot path must not pack columns");
            assert_eq!((stats.super_bands, stats.workers), (1, 1));
            assert!(
                max_abs_diff(&want, &bufs.output()) < 1e-9,
                "{}",
                kernel.name()
            );
        }
    }

    #[test]
    fn parallel_macro_pack_counts_independent_of_threads() {
        // the pack-discipline invariant: each claimed super-band's row
        // slice is packed exactly once per kc step by its owning worker,
        // each column band once per (band, kc step) — totals must not
        // depend on the thread count, including oversubscription
        let k = ops::matmul(40, 14, 22, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let lp = LevelPlan {
            l1_tile: (8, 8, 8),
            mc: 8,
            kc: 7,
            nc: 5,
            m3: 16,
            n3: 10,
        };
        let kslices = 2u64; // ceil(14 / 7)
        let (n_i3, n_j3) = (3usize, 3usize); // ceil(40/16) × ceil(22/10)
        let col_bands_per_band: u64 = 2 + 2 + 1; // ceil(10/5), ceil(10/5), ceil(2/5)
        for threads in [1usize, 2, 5, 16] {
            let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
            bufs.fill_ints(3, 0x51);
            let want = bufs.reference();
            let stats =
                run_parallel_macro_stats(&mut bufs, &k, &s, threads, Some(lp), MicroShape::Mr8Nr4);
            assert_eq!(stats.super_bands, n_i3 * n_j3);
            assert_eq!(stats.workers, threads.min(n_i3 * n_j3));
            assert_eq!(
                stats.row_slice_packs,
                (n_i3 * n_j3) as u64 * kslices,
                "row-slice pack discipline broken at threads={threads}"
            );
            assert_eq!(
                stats.col_band_packs,
                col_bands_per_band * n_i3 as u64 * kslices,
                "column-band pack discipline broken at threads={threads}"
            );
            assert_eq!(bufs.output(), want, "threads={threads}");
        }
    }

    #[test]
    fn derived_plan_refines_grain_for_threads() {
        // 192×256×64 f64: the derived heuristic gives mc = 64 and one
        // 192-row super-band — serial. With 4 threads the grain guard
        // must refine the rows down to mc, yielding the maximal 3-band
        // grid (ceil(192/64) × 1) and 3 workers
        let k = ops::matmul(192, 256, 64, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        let want = bufs.reference();
        let stats = run_parallel_macro_stats(&mut bufs, &k, &s, 4, None, MicroShape::Mr8Nr4);
        assert!(
            stats.super_bands >= 3,
            "derived grid must refine for the thread count: {stats:?}"
        );
        assert!(stats.workers >= 3, "{stats:?}");
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    }

    #[test]
    fn single_super_band_degenerates_to_flat_schedule() {
        // a plan with no super-band level (m3/n3 ≥ the GEMM extents) must
        // claim exactly one band on one worker and walk the identical
        // schedule as the serial macro-kernel — bitwise
        use crate::codegen::executor::run_macro;
        let k = ops::matmul(33, 17, 21, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let flat = LevelPlan::flat((8, 8, 8), 12, 6, 7);
        let mut par = KernelBuffers::<f64>::from_kernel(&k);
        par.fill_ints(3, 0x5F);
        let mut ser = par.clone();
        let want = par.reference();
        let stats = run_parallel_macro_stats(&mut par, &k, &s, 4, Some(flat), MicroShape::Mr8Nr4);
        assert_eq!(stats.super_bands, 1, "flat plan must be a single super-band");
        assert_eq!(stats.workers, 1);
        let gf = GemmForm::of(&k).unwrap();
        let plan = gf.plan_box(&kernel_views(&k), &[0, 0, 0], k.extents());
        run_macro(
            &mut ser.arena,
            &plan,
            &flat,
            MicroShape::Mr8Nr4,
            &mut PackedRows::new(),
            &mut PackedCols::new(),
        );
        assert_eq!(par.output(), want);
        assert_eq!(
            ser.output(),
            par.output(),
            "single-band parallel run must be bitwise the serial schedule"
        );
    }

    #[test]
    fn unaligned_super_band_extents_are_normalized() {
        // m3/n3 that are not mc/nc multiples are aligned down, never up:
        // the schedule stays correct and the grid reflects the aligned
        // extents (m3 19→16 with mc=8, n3 7→5 with nc=5)
        let k = ops::matmul(30, 11, 13, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let lp = LevelPlan {
            l1_tile: (8, 8, 8),
            mc: 8,
            kc: 6,
            nc: 5,
            m3: 19,
            n3: 7,
        };
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        let want = bufs.reference();
        let stats = run_parallel_macro_stats(&mut bufs, &k, &s, 3, Some(lp), MicroShape::Mr8Nr4);
        assert_eq!(stats.super_bands, 30usize.div_ceil(16) * 13usize.div_ceil(5));
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    }

    #[test]
    fn parallel_macro_runs_kronecker() {
        let k = ops::kronecker(5, 4, 6, 3, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[2, 2, 4, 3]));
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        let want = bufs.reference();
        run_parallel_macro(&mut bufs, &k, &s, 3, None, MicroShape::Mr8Nr4);
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
        // via run_parallel: loop axis 0 (i) is a GEMM column axis for
        // Kronecker, so this takes the band path
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        run_parallel(&mut bufs, &k, &s, 4, 0);
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    }

    #[test]
    fn non_injective_output_degrades_serially() {
        // out[i+j] += in1[i] · in2[j]: GEMM-classified, but the output
        // map collides across (i, j) — the band path must be refused and
        // the group path must degrade to one worker instead of racing
        use crate::domain::access::AffineAccess;
        use crate::domain::{Kernel, OpRole, Operand};
        use crate::index::{Layout, Table};
        let n = 6i64;
        let a = Table::new("A", &[2 * n - 1], Layout::ColumnMajor, 8, 0);
        let b = Table::new("B", &[n], Layout::ColumnMajor, 8, (2 * n - 1) as usize * 8);
        let c = Table::new("C", &[n], Layout::ColumnMajor, 8, (3 * n - 1) as usize * 8);
        let kernel = Kernel::new(
            "outer_sum",
            vec![n, n],
            vec![
                Operand {
                    table: a,
                    access: AffineAccess::new(vec![vec![1, 1]], vec![0]),
                    role: OpRole::ReadWrite,
                },
                Operand {
                    table: b,
                    access: AffineAccess::select(2, &[0]),
                    role: OpRole::Read,
                },
                Operand {
                    table: c,
                    access: AffineAccess::select(2, &[1]),
                    role: OpRole::Read,
                },
            ],
        );
        assert!(GemmForm::of(&kernel).is_some());
        assert!(!GemmForm::of(&kernel)
            .unwrap()
            .output_injective(&kernel_views(&kernel), kernel.extents()));
        let s = TiledSchedule::new(TileBasis::rect(&[2, 2]));
        for pv in [0usize, 1] {
            let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
            let want = bufs.reference();
            run_parallel(&mut bufs, &kernel, &s, 4, pv);
            assert!(max_abs_diff(&want, &bufs.output()) < 1e-9, "pv={pv}");
        }
    }

    #[test]
    fn parallel_prepacked_matches_serial_prefix_bitwise() {
        // the coalesced-serve contract: resident rows packed once at
        // startup are shared read-only across workers, and the parallel
        // column-prefix dispatch is bit-identical to the serial
        // pre-packed nest at every batch width and thread count
        use crate::codegen::executor::{pack_row_slices, run_macro_prepacked_cols};
        let k = ops::matmul(26, 19, 36, 8, 0);
        let views = kernel_views(&k);
        let gf = GemmForm::of(&k).unwrap();
        let plan = gf.plan_box(&views, &[0, 0, 0], k.extents());
        let lp = LevelPlan {
            l1_tile: (8, 8, 8),
            mc: 12,
            kc: 7,
            nc: 9,
            m3: 24,
            n3: 18,
        };
        let kslices = 3u64; // ceil(19 / 7)
        for n_used in [9usize, 20, 36] {
            // serial prefix run as the bitwise oracle
            let mut serial = KernelBuffers::<f64>::from_kernel(&k);
            serial.fill_ints(5, 0x9A7);
            let s_rows = pack_row_slices(&serial.arena, &plan, &lp);
            let mut s_cols = PackedCols::<f64>::new();
            run_macro_prepacked_cols(
                &mut serial.arena,
                &plan,
                &lp,
                MicroShape::Mr8Nr4,
                &s_rows,
                &mut s_cols,
                n_used,
            );
            let want = serial.output();
            for threads in [1usize, 2, 5, 16] {
                let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
                bufs.fill_ints(5, 0x9A7);
                let rows = pack_row_slices(&bufs.arena, &plan, &lp);
                let packed: u64 = rows.iter().map(|r| r.pack_count()).sum();
                let stats = run_parallel_macro_prepacked(
                    &mut bufs.arena,
                    &k,
                    &plan,
                    &lp,
                    MicroShape::Mr8Nr4,
                    &rows,
                    threads,
                    n_used,
                );
                assert_eq!(
                    bufs.output(),
                    want,
                    "n_used={n_used} threads={threads}: parallel prefix must be bitwise serial"
                );
                // shared resident rows: never packed by workers
                let repacked: u64 = rows.iter().map(|r| r.pack_count()).sum();
                assert_eq!(packed, repacked, "workers must not repack resident rows");
                assert_eq!(stats.row_slice_packs, 0);
                let n_j3 = n_used.div_ceil(18);
                assert_eq!(stats.super_bands, 2 * n_j3); // ceil(26/24) = 2 row bands
                assert_eq!(stats.workers, threads.min(2 * n_j3));
                // one column-band pack per (row band, kc slice, nc band)
                let nc_bands: u64 = (0..n_used as u64)
                    .step_by(18)
                    .map(|j3| (n_used as u64 - j3).min(18).div_ceil(9))
                    .sum();
                assert_eq!(
                    stats.col_band_packs,
                    2 * kslices * nc_bands,
                    "n_used={n_used} threads={threads}"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "coupled")]
    fn coupled_partition_var_rejected() {
        let k = ops::matmul(8, 8, 8, 8, 0);
        // tile couples j with i
        let basis = TileBasis::from_cols(IMat::from_rows(&[
            &[2, 1, 0],
            &[1, 4, 0],
            &[0, 0, 2],
        ]));
        let s = TiledSchedule::new(basis);
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        run_parallel(&mut bufs, &k, &s, 2, 1);
    }
}
