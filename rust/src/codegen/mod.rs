//! Loop-nest execution ("code generation") — DESIGN.md S9, S11.
//!
//! The paper generates C code with CLooG and compiles it; we execute the
//! same traversals directly: [`executor`] walks a schedule and performs
//! the matmul (optionally instrumented against the cache simulator),
//! [`parallel`] adds the OpenMP-analog threaded execution over tile
//! footpoints.

pub mod executor;
pub mod parallel;

pub use executor::{
    max_abs_diff, run_instrumented, run_schedule, run_trace_only, tiled_executor,
    MatmulBuffers, TiledExecutor,
};
pub use parallel::run_parallel;
