//! Loop-nest execution ("code generation") — DESIGN.md S9, S11.
//!
//! The paper generates C code with CLooG and compiles it; we execute the
//! same traversals directly, at the code quality the paper's CLooG+gcc
//! pipeline emits. The executor pipeline is **kernel-agnostic** and
//! **element-generic**: every Table-1 kernel (scalar product,
//! convolution, matmul, Kronecker) lowers through the same four stages,
//! at either supported storage precision (`T: Scalar`, f32 or f64 — the
//! [`scalar`] layer), under any register-tile geometry of the 2-D
//! `(MR, NR)` grid, and in any of the three serve precision modes
//! ([`Precision`]: pure f32, pure f64, or `f32acc64` — f32 storage with
//! f64 register accumulation):
//!
//! ```text
//!   buffers  →  RunPlan  →  pack once  →  micro/macro dispatch
//! ```
//!
//! * **buffers** — [`runplan::KernelBuffers`]`<T>` lays one `T` arena out
//!   by the kernel's tables (element index × [`Scalar::ELEM`] =
//!   simulator byte address, so an f32 arena legitimately packs twice the
//!   elements per cacheline) and derives one [`runplan::OperandView`] per
//!   operand: the composed affine map `φ ∘ access` on the loop variables,
//!   carrying its table's element size. No executor hardcodes an operand
//!   geometry — the former matmul-only `MatmulBuffers` layer is retired,
//!   and with the `Scalar` refactor the last matmul-era assumption — that
//!   "element" means 8 bytes — is gone too. The kernel-semantic scalar
//!   oracle ([`KernelBuffers::reference`](runplan::KernelBuffers::reference))
//!   survives as the differential-test baseline at both precisions.
//! * **RunPlan** — [`runplan::GemmForm`] classifies the loop axes into
//!   GEMM row/column/reduction groups from the access maps (matmul is
//!   `{i}×{j}×{kk}`; Kronecker the reduction-free outer product with
//!   swapped inputs; convolution and scalar product the degenerate
//!   `1×1×{k}` dot), and [`GemmForm::plan_box`](runplan::GemmForm::plan_box)
//!   lowers any clipped loop-space box to a [`runplan::RunPlan`]:
//!   maximal unit-stride runs along the rows plus explicit per-column and
//!   per-reduction-step offset tables. Tiles, macro blocks and whole
//!   domains are all the same IR, for either dtype.
//! * **pack once** — [`pack`] copies RunPlan rows into `mr`-row panels
//!   (unit-stride `memcpy` per run segment) and columns into `NRW`-column
//!   panels (gathers through the offset tables — convolution's reversed
//!   operand packs into a forward-streaming panel). Both panel axes are
//!   geometry parameters now: the row-panel height `mr` is the
//!   dispatched [`MicroShape`]'s MR class (8 or [`MR_TALL`] = 16 rows,
//!   carried at runtime on [`pack::PackedRows`] /
//!   [`pack::PackBuffers`]), and `NRW` is per-dtype — the narrow/wide
//!   width classes resolve to 4/6 columns at f64 and 8/12 at f32
//!   ([`Scalar::nr`]), with the tall 16-row classes keeping the 4/6
//!   widths at both dtypes so register pressure stays bounded. Per macro block
//!   each operand is packed exactly once: [`pack::PackedRows`] holds
//!   the `mc`-row blocks of the current reduction slice of a row range
//!   (a super-band's rows; **thread-local** in the parallel path),
//!   [`pack::PackedCols`] the band of the current output columns.
//!   [`pack::PackBuffers`] is the per-tile packer for the single-level
//!   engine and the parallel per-tile path; its cache keys carry the
//!   source identity *and* element size so reuse across arenas or dtypes
//!   can never replay stale panels.
//! * **micro/macro dispatch** — [`executor::run_macro`] walks the
//!   **three-level schedule**: `m3×n3` L3 super-bands (mc-aligned row
//!   ranges × nc-aligned column ranges sized against the L3 slice)
//!   partition the output, and inside each band reduction slices ×
//!   column bands × row blocks ([`pack::run_macro_block`] drives the L1
//!   tiles straight from the panels) dispatch the `MR×NRW` FMA register
//!   tile ([`microkernel::mkernel_full_at`]) with **per-column output
//!   bases** — which is what lets kernels without a uniform output
//!   column stride (Kronecker) use the same register tiles. The
//!   super-band level bounds the packed row slice to `m3×kc` so
//!   L3-exceeding row extents stop thrashing the last-level cache, and
//!   it is the parallel unit: [`parallel::run_parallel_macro`] hands
//!   whole super-bands to workers from a claim board with sticky
//!   worker↔band affinity, each worker packing its **own** row slice and
//!   column bands (nothing packed is shared), so serial and parallel
//!   traces walk one schedule. The serve engine's variant
//!   ([`parallel::run_parallel_macro_prepacked`]) flips exactly one of
//!   those rules: workers share the startup-resident [`pack::PackedRows`]
//!   read-only (weights are packed once per process, not once per band)
//!   and still own their column bands; with
//!   [`executor::run_macro_prepacked_cols`] it also executes a **column
//!   prefix** of the plan, which is how a partially full coalesced batch
//!   runs the m·B-wide serve kernel without replanning. The
//!   startup autotuner ([`autotune::calibrate_dtype`]) races the full
//!   **2-D (MR, NR) candidate grid** at the dtype's resolved dimensions
//!   (8×4 / 8×6 / 16×4 / 16×6 at f64, 8×8 / 8×12 / 16×4 / 16×6 at f32)
//!   under the deterministic [`autotune::pick_winner`] rule — the
//!   default keeps ties, a challenger needs a >5% win — and the engine
//!   dispatches whichever geometry the
//!   [`Registry`](crate::runtime::Registry) recorded *for that dtype*:
//!   `pack::dispatch_block` is the single const-dispatch point that
//!   maps the runtime `(mr, acc64)` pair onto the six instantiated
//!   `(MRH, NRW)` kernel arms. Mixed precision threads through the same
//!   point: with `acc64` set (the `f32acc64` serve mode,
//!   [`Precision::wide_acc`]), the register tiles instantiate with
//!   `A = f64` ([`scalar::Accum`]) — products of f32 panels are exact in
//!   f64, each `kc` slice's tile accumulates unrounded and rounds
//!   **once** on store, so a reduction that fits one `kc` slice is the
//!   correctly-rounded-sum-of-exact-products of its inputs.
//!   Degenerate `m = n = 1` forms (scalar product, convolution) skip
//!   packing entirely and run the dot microkernel
//!   ([`microkernel::dot_update`]) straight from the arena — on the
//!   serial *and* parallel entry points. Boundary blocks write back
//!   through the clipped edge kernel; skewed lattice bases replay their
//!   prototile's unit-stride runs through the dtype's `NR`-column axpy
//!   kernel per tile ([`executor::ReplayPlan`]); kernels outside the
//!   GEMM class fall back to exact per-point evaluation through the
//!   views.
//!
//! ## The double-buffered pack-ahead pipeline
//!
//! Inside one claimed super-band the parallel engine default is a
//! **two-stage software pipeline** ([`parallel::ParallelTuning`]): each
//! worker owns two [`pack::PackStage`] buffer sets and a companion pack
//! thread, and whole stage sets circulate between them through a channel
//! pair — requests carry an inert set to the packer, results bring it
//! back holding stage `k0`'s panels, stamped with the
//! [`pack::StageKey`] the worker asked for (the rotation replay guard).
//! Ownership at every instant is total and exclusive:
//!
//! ```text
//!             worker (compute)                companion (pack)
//!             ────────────────                ────────────────
//!   stage A   streaming k0      ◄── done ──   (handed back, packed k0)
//!   stage B   (sent away)       ── req k0+kc ►  filling k0+kc panels
//!
//!   next kc step: A and B swap roles — A refills k0+2kc while B streams
//! ```
//!
//! A buffer set is therefore *either* being streamed by the worker *or*
//! being filled by the packer, never both — the handoff is move-based, so
//! there is no shared aliasing to reason about, and the packer needs only
//! a **read-only** arena view (packing touches input-operand bytes,
//! which nothing writes during a run). In steady state the `k0+kc`
//! panels are already waiting when the worker finishes streaming `k0`
//! ([`parallel::ParallelMacroStats::pack_ahead_hits`] counts exactly
//! those non-stalling steps), so pack latency leaves the critical path.
//!
//! **Why accumulation order is untouched:** the pipeline reorders
//! *packing* — stage `k0+kc`'s copies may run concurrently with (even
//! before) stage `k0`'s FMAs — but the worker still *streams* stages
//! strictly in ascending `k0`, and within a stage walks the identical
//! `j0 → bi` band/block order as the synchronous nest. Every output
//! element accumulates its `kc` slices in exactly the serial sequence,
//! so pipelined results are bitwise identical to the serial macro-kernel
//! (the differential suite pins this per dtype). The same argument
//! covers sub-band **work stealing**: when the claim board drains, an
//! idle worker takes the tail half of a busy worker's remaining
//! `mc`-row blocks at a `kc` *stage boundary* — the stolen rows have
//! completed every stage below the boundary and continue ascending from
//! it on the thief, so each element's reduction order is still the
//! serial one. Stealing does re-pack the stolen rows' panels on the
//! thief, which is why pack *totals* are exact schedule invariants only
//! under [`parallel::ParallelTuning::deterministic`] (pipeline on,
//! stealing off — the serve default).
//!
//! The element size also flows *upward* from here: the tile selectors
//! ([`crate::tiling::level_plan`], [`LevelPlan::heuristic`]) take it into
//! their working-set math, so an f32 plan legitimately selects a wider
//! footprint than an f64 plan for the same shape — and since the
//! kernel-aware selector refactor they read each kernel's own
//! [`GemmForm`] (convolution and scalar product block their degenerate
//! `1×1×k` dot form, Kronecker its reduction-free outer product) instead
//! of reusing matmul's candidate geometry. Dtype and kernel form both
//! reach the model, not just the kernels.
//!
//! [`executor`] also provides the instrumented point-wise executors
//! (simulator-faithful traversals for any kernel, at the kernel's
//! declared element size), and [`parallel`] adds the OpenMP-analog
//! threaded execution — L3 super-bands per worker with thread-local
//! packing for rect schedules, footpoint groups for skewed ones.
//!
//! [`LevelPlan::heuristic`]: crate::tiling::LevelPlan::heuristic

pub mod autotune;
pub mod executor;
pub mod microkernel;
pub mod pack;
pub mod parallel;
pub mod runplan;
pub mod scalar;

/// The execution options of one packed-engine dispatch, collapsed into a
/// single params struct: the register-tile geometry to dispatch, the
/// wide-accumulation flag of the precision mode, and the parallel
/// pipeline tuning (ignored by the serial entry points). Replaces the
/// old `_acc`/`_tuned` suffix ladder — every `*_with` entry point takes
/// one `ExecOpts`, and the thin suffix-free wrappers (`run_macro`,
/// `run_parallel_macro`, …) forward defaults into it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecOpts {
    /// Register-tile geometry class to dispatch (the dtype's autotuned
    /// winner on serve paths; the compile-time 8×4 default otherwise).
    pub micro: autotune::MicroShape,
    /// Accumulate register tiles in f64 (`Precision::wide_acc` of the
    /// execution's precision mode). Meaningless at f64 storage.
    pub acc64: bool,
    /// Pipeline/steal tuning for the parallel macro entry points; the
    /// serial nests ignore it.
    pub tuning: parallel::ParallelTuning,
}

impl Default for ExecOpts {
    fn default() -> ExecOpts {
        ExecOpts::new(autotune::MicroShape::Mr8Nr4)
    }
}

impl ExecOpts {
    /// Options at one explicit geometry, pure storage-precision
    /// accumulation, default parallel tuning.
    pub fn new(micro: autotune::MicroShape) -> ExecOpts {
        ExecOpts {
            micro,
            acc64: false,
            tuning: parallel::ParallelTuning::default(),
        }
    }

    pub fn with_acc64(mut self, acc64: bool) -> ExecOpts {
        self.acc64 = acc64;
        self
    }

    pub fn with_tuning(mut self, tuning: parallel::ParallelTuning) -> ExecOpts {
        self.tuning = tuning;
        self
    }

    /// The serve path's options: explicit geometry and precision with
    /// the deterministic pipeline (pack-ahead on, stealing off), so
    /// pack totals stay exact schedule invariants.
    pub fn serving(micro: autotune::MicroShape, acc64: bool) -> ExecOpts {
        ExecOpts {
            micro,
            acc64,
            tuning: parallel::ParallelTuning::deterministic(),
        }
    }
}

pub use autotune::{
    calibrate, calibrate_dtype, calibrate_strategies, measure_plan_rate, pick_winner,
    race_strategies_over, race_strategy_rates, MicroShape,
};
pub use executor::{
    box_key, max_abs_diff, pack_row_slices, pack_row_slices_mr, run_instrumented, run_macro,
    run_macro_acc, run_macro_prepacked, run_macro_prepacked_cols, run_macro_prepacked_with,
    run_macro_with, run_rect_box_with, run_schedule, run_trace_only, scan_rect_tiles,
    tiled_executor, ReplayPlan, ReplayScratch, TiledExecutor,
};
pub use microkernel::{dot_update, dot_update_acc, MR, MR_TALL, NR, NR_WIDE};
pub use pack::{
    run_macro_block, PackBuffers, PackStage, PackedBlock, PackedCols, PackedRows, StageKey,
};
pub use parallel::{
    run_parallel, run_parallel_macro, run_parallel_macro_prepacked,
    run_parallel_macro_prepacked_with, run_parallel_macro_stats, run_parallel_macro_tuned,
    run_parallel_macro_with, run_parallel_micro, run_parallel_micro_with, ParallelMacroStats,
    ParallelTuning,
};
pub use runplan::{
    kernel_views, view_injective, GemmForm, KernelBuffers, OperandView, Run, RowPanel, RunPlan,
};
pub use scalar::{Accum, DType, Precision, Scalar};
