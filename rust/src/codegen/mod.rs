//! Loop-nest execution ("code generation") — DESIGN.md S9, S11.
//!
//! The paper generates C code with CLooG and compiles it; we execute the
//! same traversals directly, at the code quality the paper's CLooG+gcc
//! pipeline emits. The executor pipeline is a **two-level nest**
//!
//! ```text
//!   macro-block  →  pack once  →  micro-tiles  →  clip fallback
//! ```
//!
//! * **macro-block** — rect schedules are partitioned into L2/L3-sized
//!   `mc×kc×nc` blocks ([`crate::tiling::LevelPlan`]): `k` is sliced by
//!   `kc`, rows by `mc` (the packed B block streams from L2), output
//!   columns by `nc` (the packed C block sits in an L3 slice).
//!   [`executor::run_macro_matmul`] walks the blocks `k0 → j0 → block`.
//! * **pack once** — per macro block, each operand is packed exactly
//!   once: [`pack::PackedB`] holds every `mc×kc` B block of the current
//!   k slice (shared **read-only** across threads in the parallel path),
//!   [`pack::PackedC`] the `kc×nc` C block of the current column band.
//!   [`pack::PackBuffers`] remains the per-tile packer for the
//!   single-level engine (`TiledExecutor::run_l1_only`) and the skewed
//!   replay path; its block cache keys carry the source identity so
//!   reuse across arenas can never replay stale panels.
//! * **micro-tiles** — [`pack::run_macro_block`] drives all L1 tiles of
//!   one macro block straight from the packed panels: the `MR×NR` FMA
//!   register tile ([`microkernel`]) for full blocks, with the C
//!   micro-panel of each L1 tile reused L1-resident across the tile's B
//!   panels. Skewed lattice tiles replay their unit-stride runs through
//!   the `NR`-column axpy kernel per tile, as before. All unchecked
//!   indexing is encapsulated in [`microkernel`] behind length-asserted
//!   safe entry points. [`autotune`] calibrates the register-tile shape
//!   (8×4 vs 8×6) once at startup and records the winner.
//! * **clip fallback** — boundary blocks write back through the clipped
//!   edge kernel; tile bases that couple the `j` dimension (which no
//!   planner in this crate emits) drop to exact scalar run replay.
//!
//! [`executor`] also provides the instrumented point-wise executors
//! (simulator-faithful traversals), and [`parallel`] adds the OpenMP-analog
//! threaded execution — whole `nc` column bands per worker over the shared
//! packed B slice for rect schedules, footpoint groups for skewed ones.

pub mod autotune;
pub mod executor;
pub mod microkernel;
pub mod pack;
pub mod parallel;

pub use autotune::{calibrate, MicroShape};
pub use executor::{
    max_abs_diff, run_instrumented, run_macro_matmul, run_rect_box, run_schedule,
    run_trace_only, tiled_executor, MatmulBuffers, MatmulGeom, ReplayScratch, TiledExecutor,
};
pub use microkernel::{MR, NR, NR_WIDE};
pub use pack::{run_macro_block, PackBuffers, PackedB, PackedC};
pub use parallel::{run_parallel, run_parallel_macro};
