//! Loop-nest execution ("code generation") — DESIGN.md S9, S11.
//!
//! The paper generates C code with CLooG and compiles it; we execute the
//! same traversals directly, at the code quality the paper's CLooG+gcc
//! pipeline emits. The executor pipeline is
//!
//! ```text
//!   scan  →  pack  →  microkernel  →  clip fallback
//! ```
//!
//! * **scan** — [`executor::TiledExecutor`] walks tile footpoints
//!   ([`crate::tiling::TiledSchedule`]); every tile, interior or
//!   boundary, is the translated prototile clipped to the domain box.
//! * **pack** — [`pack::PackBuffers`] copies each tile's B and C operands
//!   into contiguous, `MR`/`NR`-strided zero-padded panels, amortized
//!   across the tile's k-loop and reused across tiles (thread-local in
//!   the parallel path).
//! * **microkernel** — [`microkernel`] holds the register-blocked f64
//!   kernels: the `MR×NR` FMA register tile for rectangular tiles and the
//!   `NR`-column axpy panel kernel replaying the unit-stride runs of
//!   skewed lattice tiles. All unchecked indexing is encapsulated there
//!   behind length-asserted safe entry points.
//! * **clip fallback** — boundary blocks write back through the clipped
//!   edge kernel; tile bases that couple the `j` dimension (which no
//!   planner in this crate emits) drop to exact scalar run replay.
//!
//! [`executor`] also provides the instrumented point-wise executors
//! (simulator-faithful traversals), and [`parallel`] adds the OpenMP-analog
//! threaded execution over tile footpoints on the same engine.

pub mod executor;
pub mod microkernel;
pub mod pack;
pub mod parallel;

pub use executor::{
    max_abs_diff, run_instrumented, run_rect_box, run_schedule, run_trace_only,
    tiled_executor, MatmulBuffers, MatmulGeom, ReplayScratch, TiledExecutor,
};
pub use microkernel::{MR, NR};
pub use pack::PackBuffers;
pub use parallel::run_parallel;
