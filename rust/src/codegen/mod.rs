//! Loop-nest execution ("code generation") — DESIGN.md S9, S11.
//!
//! The paper generates C code with CLooG and compiles it; we execute the
//! same traversals directly, at the code quality the paper's CLooG+gcc
//! pipeline emits. The executor pipeline is **kernel-agnostic**: every
//! Table-1 kernel (scalar product, convolution, matmul, Kronecker) lowers
//! through the same four stages
//!
//! ```text
//!   buffers  →  RunPlan  →  pack once  →  micro/macro dispatch
//! ```
//!
//! * **buffers** — [`runplan::KernelBuffers`] lays one f64 arena out by
//!   the kernel's tables (element index × 8 = simulator byte address) and
//!   derives one [`runplan::OperandView`] per operand: the composed
//!   affine map `φ ∘ access` on the loop variables. No executor hardcodes
//!   an operand geometry — the former matmul-only `MatmulBuffers` /
//!   `MatmulGeom` layer (and its `a_idx`/`b_idx`/`c_idx` indexing) is
//!   retired; the kernel-semantic scalar oracle
//!   ([`KernelBuffers::reference`](runplan::KernelBuffers::reference))
//!   survives as the differential-test baseline.
//! * **RunPlan** — [`runplan::GemmForm`] classifies the loop axes into
//!   GEMM row/column/reduction groups from the access maps (matmul is
//!   `{i}×{j}×{kk}`; Kronecker the reduction-free outer product with
//!   swapped inputs; convolution and scalar product the degenerate
//!   `1×1×{k}` dot), and [`GemmForm::plan_box`](runplan::GemmForm::plan_box)
//!   lowers any clipped loop-space box to a [`runplan::RunPlan`]:
//!   maximal unit-stride runs along the rows plus explicit per-column and
//!   per-reduction-step offset tables. Tiles, macro blocks and whole
//!   domains are all the same IR.
//! * **pack once** — [`pack`] copies RunPlan rows into `MR`-row panels
//!   (unit-stride `memcpy` per run segment) and columns into `NRW`-column
//!   panels (gathers through the offset tables — convolution's reversed
//!   operand packs into a forward-streaming panel). Per macro block each
//!   operand is packed exactly once: [`pack::PackedRows`] holds every
//!   `mc`-row block of the current reduction slice (shared **read-only**
//!   across threads in the parallel path), [`pack::PackedCols`] the
//!   band of the current output columns. [`pack::PackBuffers`] is the
//!   per-tile packer for the single-level engine and the parallel
//!   per-tile path; its cache keys carry the source identity so reuse
//!   across arenas can never replay stale panels.
//! * **micro/macro dispatch** — [`executor::run_macro`] walks reduction
//!   slices × column bands × row blocks ([`pack::run_macro_block`]
//!   drives the L1 tiles straight from the panels), dispatching the
//!   `MR×NRW` FMA register tile ([`microkernel::mkernel_full_at`]) with
//!   **per-column output bases** — which is what lets kernels without a
//!   uniform output column stride (Kronecker) use the same register
//!   tiles. `NRW` is const-generic: the startup autotuner ([`autotune`])
//!   times 8×4 vs 8×6 and the engine dispatches whichever shape the
//!   [`Registry`](crate::runtime::Registry) recorded. Boundary blocks
//!   write back through the clipped edge kernel; skewed lattice bases
//!   replay their prototile's unit-stride runs through the `NR`-column
//!   axpy kernel per tile ([`executor::ReplayPlan`]); kernels outside
//!   the GEMM class fall back to exact per-point evaluation through the
//!   views.
//!
//! [`executor`] also provides the instrumented point-wise executors
//! (simulator-faithful traversals for any kernel), and [`parallel`] adds
//! the OpenMP-analog threaded execution — whole column bands per worker
//! over the shared packed rows for rect schedules, footpoint groups for
//! skewed ones.

pub mod autotune;
pub mod executor;
pub mod microkernel;
pub mod pack;
pub mod parallel;
pub mod runplan;

pub use autotune::{calibrate, MicroShape};
pub use executor::{
    box_key, max_abs_diff, run_instrumented, run_macro, run_rect_box, run_schedule,
    run_trace_only, scan_rect_tiles, tiled_executor, ReplayPlan, ReplayScratch, TiledExecutor,
};
pub use microkernel::{MR, NR, NR_WIDE};
pub use pack::{run_macro_block, PackBuffers, PackedBlock, PackedCols, PackedRows};
pub use parallel::{run_parallel, run_parallel_macro, run_parallel_micro};
pub use runplan::{
    kernel_views, view_injective, GemmForm, KernelBuffers, OperandView, Run, RowPanel, RunPlan,
};
