//! One-shot startup calibration of the register-tile geometry (ROADMAP:
//! "Autotune MR×NR at startup"), per dtype — a **2-D grid race** over
//! the (MR-class, NR-class) candidates.
//!
//! The packed-panel layouts are geometry-specific, so every candidate
//! [`MicroShape`] is a separate kernel instantiation; the calibrator
//! times each on an L1-resident packed panel at the dtype's resolved
//! `(MR, NR)` (8×4 / 8×6 / 16×4 / 16×6 at f64, 8×8 / 8×12 / 16×4 / 16×6
//! at f32) and reports the winner. Winner selection is split from
//! measurement ([`pick_winner`]) and is **deterministic given the
//! measured rates**: the compile-time default (8×4) wins unless a
//! challenger beats it by more than 5%, and exact ties between
//! challengers keep the earlier candidate in
//! [`MicroShape::CANDIDATES`] order — so calibration can only ever
//! *upgrade*, and repeated races over identical rates agree.
//!
//! The measured choices are recorded per dtype in the registry
//! ([`crate::runtime::Registry::set_micro_shape_for`]) and the packed
//! engine **dispatches them**: the planner threads the dtype's winner
//! into [`Plan`](crate::coordinator::Plan), and
//! [`TiledExecutor::with_micro_shape`](crate::codegen::TiledExecutor::with_micro_shape)
//! / [`run_parallel_macro`](crate::codegen::run_parallel_macro) select
//! the const-generic `(MRH, NRW)` panel path.
//!
//! There is deliberately **no silent fallback arm**: candidate dispatch
//! matches exactly the six `(MR, NR)` pairs the kernel instantiates,
//! closed over both sealed dtypes (pinned by a scalar-layer test), and
//! anything else panics loudly instead of quietly reporting 8×4.

use std::time::Instant;

use super::microkernel::{mkernel_full_at, MR, MR_TALL};
use super::scalar::Scalar;

pub use super::scalar::MicroShape;

/// Rate threshold a challenger must clear over the default shape: >5%
/// faster, so noise-level wins never flap the dispatched geometry.
const UPGRADE_MARGIN: f64 = 1.05;

/// Time both width classes at f64 and return the winner — the legacy
/// entry point; see [`calibrate_dtype`] for the per-dtype grid race.
pub fn calibrate(reps: u64) -> MicroShape {
    calibrate_dtype::<f64>(reps)
}

/// Race every candidate register-tile geometry at `T`'s resolved
/// dimensions on a tiny packed panel and return the shape with the
/// highest FMA rate, under the deterministic [`pick_winner`] rule (the
/// default keeps ties; a challenger needs a >5% win). Takes a few ms at
/// the default serving `reps`; the work per candidate is identical and
/// deterministic, so repeated calls agree on a quiet machine.
pub fn calibrate_dtype<T: Scalar>(reps: u64) -> MicroShape {
    let rates: Vec<(MicroShape, f64)> = MicroShape::CANDIDATES
        .iter()
        .map(|&micro| (micro, measure_rate::<T>(micro, reps)))
        .collect();
    pick_winner(&rates)
}

/// The deterministic winner rule of the grid race, split from
/// measurement so it can be pinned by tests: the first candidate in
/// `rates` is the incumbent default; a challenger replaces the current
/// best only with a rate strictly above both `default · 1.05` and the
/// best so far. Identical `rates` slices always produce the same
/// winner.
pub fn pick_winner(rates: &[(MicroShape, f64)]) -> MicroShape {
    let (default, base) = rates[0];
    let mut best = (default, base);
    for &(micro, rate) in &rates[1..] {
        if rate > base * UPGRADE_MARGIN && rate > best.1 {
            best = (micro, rate);
        }
    }
    best.0
}

/// Time one candidate at `T`'s resolved `(MR, NR)`. The match is the
/// closed set of const kernel arms — six `(MRH, NRW)` pairs; a geometry
/// outside it is a bug upstream (the grid and the kernel arms drifted),
/// and panicking beats silently timing the wrong kernel.
fn measure_rate<T: Scalar>(micro: MicroShape, reps: u64) -> f64 {
    match (micro.mr(), T::nr(micro)) {
        (MR, 4) => measure_impl::<T, MR, 4>(reps),
        (MR, 6) => measure_impl::<T, MR, 6>(reps),
        (MR, 8) => measure_impl::<T, MR, 8>(reps),
        (MR, 12) => measure_impl::<T, MR, 12>(reps),
        (MR_TALL, 4) => measure_impl::<T, MR_TALL, 4>(reps),
        (MR_TALL, 6) => measure_impl::<T, MR_TALL, 6>(reps),
        (h, w) => unreachable!("no register-tile kernel arm at {h}x{w}"),
    }
}

fn measure_impl<T: Scalar, const MRH: usize, const NRW: usize>(reps: u64) -> f64 {
    let kc = 128usize;
    let bp = vec![T::from_f64(1.000_000_1); kc * MRH];
    let cp = vec![T::from_f64(0.999_999_9); kc * NRW];
    let mut a = vec![T::ZERO; (NRW - 1) * MRH + MRH];
    let bases: [usize; NRW] = std::array::from_fn(|jc| jc * MRH);
    // warm the code path and the panel lines
    mkernel_full_at::<T, T, MRH, NRW>(kc, &bp, &cp, &mut a, &bases);
    let t = Instant::now();
    for _ in 0..reps {
        mkernel_full_at::<T, T, MRH, NRW>(kc, &bp, &cp, &mut a, &bases);
    }
    // keep the optimizer honest about the accumulators
    assert!(a[0].to_f64().is_finite());
    (reps * (kc * MRH * NRW) as u64) as f64 / t.elapsed().as_secs_f64().max(1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::microkernel::{NR, NR_WIDE};
    use crate::codegen::DType;

    #[test]
    fn calibrate_returns_a_candidate_quickly() {
        let shape = calibrate(50);
        assert!(MicroShape::CANDIDATES.contains(&shape));
        let (mr, nr) = shape.dims();
        assert!(mr == MR || mr == MR_TALL);
        assert!(nr == NR || nr == NR_WIDE);
        assert!(!shape.name().is_empty());
    }

    #[test]
    fn calibrate_runs_the_full_grid_at_both_dtypes() {
        for shape in [calibrate_dtype::<f32>(50), calibrate_dtype::<f64>(50)] {
            assert!(MicroShape::CANDIDATES.contains(&shape));
        }
        // an f32 winner resolves to a legal f32 register tile: wide
        // columns on 8-row classes, f64 widths on 16-row classes
        let s32 = calibrate_dtype::<f32>(20);
        let nr32 = s32.nr_for(DType::F32);
        match s32.mr() {
            MR => assert!(nr32 >= 8),
            _ => assert!(nr32 == NR || nr32 == NR_WIDE),
        }
    }

    #[test]
    fn winner_rule_is_deterministic_and_keeps_the_default_on_ties() {
        use MicroShape::*;
        let base = 100.0;
        // nothing clears the 5% margin → the default survives
        let rates = [(Mr8Nr4, base), (Mr8Nr6, 104.9), (Mr16Nr4, base), (Mr16Nr6, 90.0)];
        assert_eq!(pick_winner(&rates), Mr8Nr4);
        // one clear challenger wins
        let rates = [(Mr8Nr4, base), (Mr8Nr6, 106.0), (Mr16Nr4, base), (Mr16Nr6, 90.0)];
        assert_eq!(pick_winner(&rates), Mr8Nr6);
        // exact tie between challengers → the earlier candidate keeps it
        let rates = [(Mr8Nr4, base), (Mr8Nr6, 120.0), (Mr16Nr4, 120.0), (Mr16Nr6, 120.0)];
        assert_eq!(pick_winner(&rates), Mr8Nr6);
        // the best rate wins regardless of position
        let rates = [(Mr8Nr4, base), (Mr8Nr6, 110.0), (Mr16Nr4, 130.0), (Mr16Nr6, 120.0)];
        assert_eq!(pick_winner(&rates), Mr16Nr4);
        // same rates → same winner, every time
        for _ in 0..8 {
            assert_eq!(pick_winner(&rates), Mr16Nr4);
        }
    }

    #[test]
    fn measure_covers_every_candidate_without_a_fallback() {
        // every (dtype, candidate) cell of the grid must resolve to a
        // real kernel arm and time successfully — the old code silently
        // mapped unknown cells to 8×4; now they would panic here
        for micro in MicroShape::CANDIDATES {
            assert!(measure_rate::<f32>(micro, 2) > 0.0, "{micro:?} (f32)");
            assert!(measure_rate::<f64>(micro, 2) > 0.0, "{micro:?} (f64)");
        }
    }
}
