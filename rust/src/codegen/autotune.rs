//! One-shot startup calibration of the register-tile shape (ROADMAP:
//! "Autotune MR×NR at startup"), per dtype.
//!
//! The packed-panel layouts are width-specific, so the candidate shapes
//! are separate kernel instantiations (the dtype's narrow vs wide
//! [`MicroShape`]); the calibrator times both on an L1-resident packed
//! panel and reports the winner. [`calibrate_dtype`] runs the race at any
//! [`Scalar`] type's own widths (8×4 vs 8×6 at f64, 8×8 vs 8×12 at f32);
//! the measured choices are recorded per dtype in the registry
//! ([`crate::runtime::Registry::set_micro_shape_for`]) and the packed
//! engine **dispatches them**: the planner threads the dtype's winner
//! into [`Plan`](crate::coordinator::Plan), and
//! [`TiledExecutor::with_micro_shape`](crate::codegen::TiledExecutor::with_micro_shape)
//! / [`run_parallel_macro`](crate::codegen::run_parallel_macro) select
//! the const-generic `NRW` panel path. The narrow shape remains the
//! default when no calibration has run.

use std::time::Instant;

use super::microkernel::{mkernel_full_at, MR};
use super::scalar::Scalar;

pub use super::scalar::MicroShape;

/// Time both width classes at f64 and return the winner — the legacy
/// entry point; see [`calibrate_dtype`] for the per-dtype race.
pub fn calibrate(reps: u64) -> MicroShape {
    calibrate_dtype::<f64>(reps)
}

/// Time both of `T`'s register-tile widths on a tiny packed panel and
/// return the shape with the higher FMA rate. Ties (within 5%) keep the
/// compile-time default, so calibration can only ever *upgrade*. Takes
/// ~1 ms at the default serving `reps`; the work is deterministic so
/// repeated calls agree on a quiet machine.
pub fn calibrate_dtype<T: Scalar>(reps: u64) -> MicroShape {
    match (T::NR, T::NR_WIDE) {
        (4, 6) => calibrate_impl::<T, 4, 6>(reps),
        (8, 12) => calibrate_impl::<T, 8, 12>(reps),
        // unreachable for the sealed dtypes; keep the default rather
        // than panic in a startup path
        _ => MicroShape::Mr8Nr4,
    }
}

fn calibrate_impl<T: Scalar, const N: usize, const W: usize>(reps: u64) -> MicroShape {
    let kc = 128usize;
    let bp = vec![T::from_f64(1.000_000_1); kc * MR];
    let cpn = vec![T::from_f64(0.999_999_9); kc * N];
    let cpw = vec![T::from_f64(0.999_999_9); kc * W];
    let mut an = vec![T::ZERO; (N - 1) * MR + MR];
    let mut aw = vec![T::ZERO; (W - 1) * MR + MR];
    let bases_n: [usize; N] = std::array::from_fn(|jc| jc * MR);
    let bases_w: [usize; W] = std::array::from_fn(|jc| jc * MR);
    // warm both code paths and the panel lines
    mkernel_full_at::<T, N>(kc, &bp, &cpn, &mut an, &bases_n);
    mkernel_full_at::<T, W>(kc, &bp, &cpw, &mut aw, &bases_w);
    let tn = Instant::now();
    for _ in 0..reps {
        mkernel_full_at::<T, N>(kc, &bp, &cpn, &mut an, &bases_n);
    }
    let rate_n =
        (reps * (kc * MR * N) as u64) as f64 / tn.elapsed().as_secs_f64().max(1e-9);
    let tw = Instant::now();
    for _ in 0..reps {
        mkernel_full_at::<T, W>(kc, &bp, &cpw, &mut aw, &bases_w);
    }
    let rate_w =
        (reps * (kc * MR * W) as u64) as f64 / tw.elapsed().as_secs_f64().max(1e-9);
    // keep the optimizer honest about the accumulators
    assert!(an[0].to_f64().is_finite() && aw[0].to_f64().is_finite());
    if rate_w > rate_n * 1.05 {
        MicroShape::Mr8Nr6
    } else {
        MicroShape::Mr8Nr4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::microkernel::{NR, NR_WIDE};

    #[test]
    fn calibrate_returns_a_candidate_quickly() {
        let shape = calibrate(50);
        assert!(matches!(shape, MicroShape::Mr8Nr4 | MicroShape::Mr8Nr6));
        let (mr, nr) = shape.dims();
        assert_eq!(mr, MR);
        assert!(nr == NR || nr == NR_WIDE);
        assert!(!shape.name().is_empty());
    }

    #[test]
    fn calibrate_runs_at_both_dtypes() {
        for shape in [calibrate_dtype::<f32>(50), calibrate_dtype::<f64>(50)] {
            assert!(matches!(shape, MicroShape::Mr8Nr4 | MicroShape::Mr8Nr6));
        }
        // the f32 winner names an f32-wide register tile
        let s32 = calibrate_dtype::<f32>(20);
        assert!(s32.nr_for(crate::codegen::DType::F32) >= 8);
    }
}
