//! One-shot startup calibration of the register-tile geometry (ROADMAP:
//! "Autotune MR×NR at startup"), per dtype — a **2-D grid race** over
//! the (MR-class, NR-class) candidates.
//!
//! The packed-panel layouts are geometry-specific, so every candidate
//! [`MicroShape`] is a separate kernel instantiation; the calibrator
//! times each on an L1-resident packed panel at the dtype's resolved
//! `(MR, NR)` (8×4 / 8×6 / 16×4 / 16×6 at f64, 8×8 / 8×12 / 16×4 / 16×6
//! at f32) and reports the winner. Winner selection is split from
//! measurement ([`pick_winner`]) and is **deterministic given the
//! measured rates**: the compile-time default (8×4) wins unless a
//! challenger beats it by more than 5%, and exact ties between
//! challengers keep the earlier candidate in
//! [`MicroShape::CANDIDATES`] order — so calibration can only ever
//! *upgrade*, and repeated races over identical rates agree.
//!
//! The measured choices are recorded per dtype in the registry
//! ([`crate::runtime::Registry::set_micro_shape_for`]) and the packed
//! engine **dispatches them**: the planner threads the dtype's winner
//! into [`Plan`](crate::coordinator::Plan), and
//! [`TiledExecutor::with_micro_shape`](crate::codegen::TiledExecutor::with_micro_shape)
//! / [`run_parallel_macro`](crate::codegen::run_parallel_macro) select
//! the const-generic `(MRH, NRW)` panel path.
//!
//! There is deliberately **no silent fallback arm**: candidate dispatch
//! matches exactly the six `(MR, NR)` pairs the kernel instantiates,
//! closed over both sealed dtypes (pinned by a scalar-layer test), and
//! anything else panics loudly instead of quietly reporting 8×4.
//!
//! Alongside the register-geometry grid the calibrator also races the
//! registered **tiling strategies** ([`race_strategy_rates`]): every
//! [`TilingStrategy`]'s proposed [`LevelPlan`] for a kernel is timed on
//! the real packed macro-kernel, the same [`pick_winner`] rule picks the
//! winner (the lattice selector is the incumbent — rivals need a >5%
//! win), and callers record it per (kernel, dtype, shape-class) in the
//! registry ([`crate::runtime::Registry::set_strategy_for`]). A strategy
//! that panics mid-race scores 0 and can never win, so the race degrades
//! to the lattice default instead of propagating the panic.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Instant;

use crate::cache::CacheSpec;
use crate::domain::Kernel;
use crate::tiling::strategy::{raced_strategies, StrategyKind, TilingStrategy};
use crate::tiling::LevelPlan;

use super::executor::run_macro_with;
use super::microkernel::{mkernel_full_at, MR, MR_TALL};
use super::pack::{PackedCols, PackedRows};
use super::runplan::{kernel_views, GemmForm, KernelBuffers};
use super::scalar::Scalar;
use super::ExecOpts;

pub use super::scalar::MicroShape;

/// Rate threshold a challenger must clear over the default shape: >5%
/// faster, so noise-level wins never flap the dispatched geometry.
const UPGRADE_MARGIN: f64 = 1.05;

/// Time both width classes at f64 and return the winner — the legacy
/// entry point; see [`calibrate_dtype`] for the per-dtype grid race.
pub fn calibrate(reps: u64) -> MicroShape {
    calibrate_dtype::<f64>(reps)
}

/// Race every candidate register-tile geometry at `T`'s resolved
/// dimensions on a tiny packed panel and return the shape with the
/// highest FMA rate, under the deterministic [`pick_winner`] rule (the
/// default keeps ties; a challenger needs a >5% win). Takes a few ms at
/// the default serving `reps`; the work per candidate is identical and
/// deterministic, so repeated calls agree on a quiet machine.
pub fn calibrate_dtype<T: Scalar>(reps: u64) -> MicroShape {
    let rates: Vec<(MicroShape, f64)> = MicroShape::CANDIDATES
        .iter()
        .map(|&micro| (micro, measure_rate::<T>(micro, reps)))
        .collect();
    pick_winner(&rates)
}

/// The deterministic winner rule of every calibration race (register
/// geometries *and* tiling strategies), split from measurement so it
/// can be pinned by tests: the first candidate in `rates` is the
/// incumbent default; a challenger replaces the current best only with
/// a rate strictly above both `default · 1.05` and the best so far.
/// Identical `rates` slices always produce the same winner.
pub fn pick_winner<C: Copy>(rates: &[(C, f64)]) -> C {
    let (default, base) = rates[0];
    let mut best = (default, base);
    for &(cand, rate) in &rates[1..] {
        if rate > base * UPGRADE_MARGIN && rate > best.1 {
            best = (cand, rate);
        }
    }
    best.0
}

/// The fixed L1 tile the strategy race plans under: the strategies being
/// compared differ at the macro (`mc/kc/nc/m3/n3`) level, so every
/// proposal is measured over the same register-adjacent tile.
const RACE_L1: (usize, usize, usize) = (8, 8, 8);

/// Race an explicit strategy list over one kernel: each strategy
/// proposes its [`LevelPlan`] (against the Haswell L2/L3 model specs —
/// strategies are free to ignore them) and the proposal is timed on the
/// real packed macro-kernel over deterministic integer data. Returns
/// `(kind, effective FLOP rate)` per strategy in input order, so the
/// caller feeds it straight to [`pick_winner`] — put the incumbent
/// first. A strategy that **panics** while proposing scores `0.0`
/// (a zero rate can never clear the upgrade margin), so a broken rival
/// degrades the race to the incumbent instead of unwinding through it.
pub fn race_strategies_over<T: Scalar>(
    strategies: &[&dyn TilingStrategy],
    kernel: &Kernel,
    micro: MicroShape,
    sample_classes: usize,
    reps: usize,
) -> Vec<(StrategyKind, f64)> {
    let extents = match GemmForm::of(kernel) {
        Some(gf) => (gf.m, gf.n, gf.k),
        // outside the GEMM class there is nothing to block — every
        // strategy scores 0 and the incumbent keeps the slot
        None => return strategies.iter().map(|s| (s.kind(), 0.0)).collect(),
    };
    strategies
        .iter()
        .map(|s| {
            let proposal = catch_unwind(AssertUnwindSafe(|| {
                s.propose(
                    kernel,
                    extents,
                    RACE_L1,
                    &CacheSpec::HASWELL_L2,
                    Some(&CacheSpec::HASWELL_L3_SLICE),
                    sample_classes,
                )
            }));
            let rate = match proposal {
                Ok(lp) => measure_plan_rate::<T>(kernel, &lp, micro, reps),
                Err(_) => 0.0,
            };
            (s.kind(), rate)
        })
        .collect()
}

/// Race every registered strategy ([`raced_strategies`] — lattice first,
/// as the incumbent of the winner rule) over one kernel at dtype `T`.
pub fn race_strategy_rates<T: Scalar>(
    kernel: &Kernel,
    micro: MicroShape,
    sample_classes: usize,
    reps: usize,
) -> Vec<(StrategyKind, f64)> {
    race_strategies_over::<T>(&raced_strategies(), kernel, micro, sample_classes, reps)
}

/// One-shot strategy calibration for a kernel at dtype `T`: race all
/// registered strategies and return the [`pick_winner`] winner. The
/// caller records it under the kernel's shape class
/// ([`crate::runtime::Registry::set_strategy_for`]).
pub fn calibrate_strategies<T: Scalar>(
    kernel: &Kernel,
    micro: MicroShape,
    sample_classes: usize,
    reps: usize,
) -> StrategyKind {
    pick_winner(&race_strategy_rates::<T>(kernel, micro, sample_classes, reps))
}

/// Time one proposed macro blocking on the packed engine: fresh buffers
/// with deterministic integer fills, one warm pass, then `reps` timed
/// passes of [`run_macro_with`]. The rate is effective FLOPs/s of the
/// kernel's GEMM form — comparable *within* one race (same kernel, same
/// data), which is all [`pick_winner`] needs.
pub fn measure_plan_rate<T: Scalar>(
    kernel: &Kernel,
    lp: &LevelPlan,
    micro: MicroShape,
    reps: usize,
) -> f64 {
    let views = kernel_views(kernel);
    let gf = match GemmForm::of(kernel) {
        Some(gf) => gf,
        None => return 0.0,
    };
    let lo = vec![0i64; kernel.extents().len()];
    let plan = gf.plan_box(&views, &lo, kernel.extents());
    let mut bufs = KernelBuffers::<T>::from_kernel(kernel);
    bufs.fill_ints(3, 0x57A7);
    let mut rows = PackedRows::<T>::new();
    let mut cols = PackedCols::<T>::new();
    let opts = ExecOpts::new(micro);
    run_macro_with(&mut bufs.arena, &plan, lp, &mut rows, &mut cols, opts); // warm
    let flops = 2.0 * gf.m as f64 * gf.n as f64 * gf.k.max(1) as f64;
    let t = Instant::now();
    for _ in 0..reps.max(1) {
        run_macro_with(&mut bufs.arena, &plan, lp, &mut rows, &mut cols, opts);
    }
    flops * reps.max(1) as f64 / t.elapsed().as_secs_f64().max(1e-9)
}

/// Time one candidate at `T`'s resolved `(MR, NR)`. The match is the
/// closed set of const kernel arms — six `(MRH, NRW)` pairs; a geometry
/// outside it is a bug upstream (the grid and the kernel arms drifted),
/// and panicking beats silently timing the wrong kernel.
fn measure_rate<T: Scalar>(micro: MicroShape, reps: u64) -> f64 {
    match (micro.mr(), T::nr(micro)) {
        (MR, 4) => measure_impl::<T, MR, 4>(reps),
        (MR, 6) => measure_impl::<T, MR, 6>(reps),
        (MR, 8) => measure_impl::<T, MR, 8>(reps),
        (MR, 12) => measure_impl::<T, MR, 12>(reps),
        (MR_TALL, 4) => measure_impl::<T, MR_TALL, 4>(reps),
        (MR_TALL, 6) => measure_impl::<T, MR_TALL, 6>(reps),
        (h, w) => unreachable!("no register-tile kernel arm at {h}x{w}"),
    }
}

fn measure_impl<T: Scalar, const MRH: usize, const NRW: usize>(reps: u64) -> f64 {
    let kc = 128usize;
    let bp = vec![T::from_f64(1.000_000_1); kc * MRH];
    let cp = vec![T::from_f64(0.999_999_9); kc * NRW];
    let mut a = vec![T::ZERO; (NRW - 1) * MRH + MRH];
    let bases: [usize; NRW] = std::array::from_fn(|jc| jc * MRH);
    // warm the code path and the panel lines
    mkernel_full_at::<T, T, MRH, NRW>(kc, &bp, &cp, &mut a, &bases);
    let t = Instant::now();
    for _ in 0..reps {
        mkernel_full_at::<T, T, MRH, NRW>(kc, &bp, &cp, &mut a, &bases);
    }
    // keep the optimizer honest about the accumulators
    assert!(a[0].to_f64().is_finite());
    (reps * (kc * MRH * NRW) as u64) as f64 / t.elapsed().as_secs_f64().max(1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::microkernel::{NR, NR_WIDE};
    use crate::codegen::DType;

    #[test]
    fn calibrate_returns_a_candidate_quickly() {
        let shape = calibrate(50);
        assert!(MicroShape::CANDIDATES.contains(&shape));
        let (mr, nr) = shape.dims();
        assert!(mr == MR || mr == MR_TALL);
        assert!(nr == NR || nr == NR_WIDE);
        assert!(!shape.name().is_empty());
    }

    #[test]
    fn calibrate_runs_the_full_grid_at_both_dtypes() {
        for shape in [calibrate_dtype::<f32>(50), calibrate_dtype::<f64>(50)] {
            assert!(MicroShape::CANDIDATES.contains(&shape));
        }
        // an f32 winner resolves to a legal f32 register tile: wide
        // columns on 8-row classes, f64 widths on 16-row classes
        let s32 = calibrate_dtype::<f32>(20);
        let nr32 = s32.nr_for(DType::F32);
        match s32.mr() {
            MR => assert!(nr32 >= 8),
            _ => assert!(nr32 == NR || nr32 == NR_WIDE),
        }
    }

    #[test]
    fn winner_rule_is_deterministic_and_keeps_the_default_on_ties() {
        use MicroShape::*;
        let base = 100.0;
        // nothing clears the 5% margin → the default survives
        let rates = [(Mr8Nr4, base), (Mr8Nr6, 104.9), (Mr16Nr4, base), (Mr16Nr6, 90.0)];
        assert_eq!(pick_winner(&rates), Mr8Nr4);
        // one clear challenger wins
        let rates = [(Mr8Nr4, base), (Mr8Nr6, 106.0), (Mr16Nr4, base), (Mr16Nr6, 90.0)];
        assert_eq!(pick_winner(&rates), Mr8Nr6);
        // exact tie between challengers → the earlier candidate keeps it
        let rates = [(Mr8Nr4, base), (Mr8Nr6, 120.0), (Mr16Nr4, 120.0), (Mr16Nr6, 120.0)];
        assert_eq!(pick_winner(&rates), Mr8Nr6);
        // the best rate wins regardless of position
        let rates = [(Mr8Nr4, base), (Mr8Nr6, 110.0), (Mr16Nr4, 130.0), (Mr16Nr6, 120.0)];
        assert_eq!(pick_winner(&rates), Mr16Nr4);
        // same rates → same winner, every time
        for _ in 0..8 {
            assert_eq!(pick_winner(&rates), Mr16Nr4);
        }
    }

    #[test]
    fn strategy_race_keeps_the_lattice_incumbent_on_ties() {
        use StrategyKind::*;
        // the generic winner rule applies unchanged to strategy rates:
        // nothing clears the 5% margin → the lattice incumbent survives
        let rates = [(Lattice, 100.0), (Oblivious, 104.9), (Latency, 100.0)];
        assert_eq!(pick_winner(&rates), Lattice);
        let rates = [(Lattice, 100.0), (Oblivious, 106.0), (Latency, 106.0)];
        // exact tie between challengers → the earlier strategy keeps it
        assert_eq!(pick_winner(&rates), Oblivious);
        for _ in 0..8 {
            assert_eq!(pick_winner(&rates), Oblivious);
        }
    }

    #[test]
    fn strategy_race_measures_every_strategy_with_lattice_first() {
        let k = crate::domain::ops::matmul(48, 32, 40, 4, 0);
        let rates = race_strategy_rates::<f32>(&k, MicroShape::Mr8Nr4, 8, 1);
        let kinds: Vec<StrategyKind> = rates.iter().map(|r| r.0).collect();
        assert_eq!(kinds, StrategyKind::RACED.to_vec());
        for (kind, rate) in &rates {
            assert!(*rate > 0.0, "{kind:?} did not measure");
        }
        let winner = calibrate_strategies::<f32>(&k, MicroShape::Mr8Nr4, 8, 1);
        assert!(StrategyKind::RACED.contains(&winner));
    }

    #[test]
    fn panicking_strategy_scores_zero_and_the_incumbent_wins() {
        struct Panicky;
        impl crate::tiling::TilingStrategy for Panicky {
            fn kind(&self) -> StrategyKind {
                StrategyKind::Oblivious
            }
            fn propose(
                &self,
                _kernel: &Kernel,
                _extents: (usize, usize, usize),
                _l1_tile: (usize, usize, usize),
                _l2: &CacheSpec,
                _l3: Option<&CacheSpec>,
                _sample_classes: usize,
            ) -> LevelPlan {
                panic!("injected strategy fault");
            }
        }
        let k = crate::domain::ops::matmul(32, 16, 24, 8, 0);
        let lattice = crate::tiling::Lattice;
        let rates = race_strategies_over::<f64>(
            &[&lattice, &Panicky],
            &k,
            MicroShape::Mr8Nr4,
            8,
            1,
        );
        assert_eq!(rates.len(), 2);
        assert!(rates[0].1 > 0.0);
        assert_eq!(rates[1], (StrategyKind::Oblivious, 0.0));
        assert_eq!(pick_winner(&rates), StrategyKind::Lattice);
    }

    #[test]
    fn non_gemm_kernels_race_to_the_incumbent_without_measuring() {
        // a kernel outside the GEMM class has nothing to block: every
        // strategy scores 0 and the lattice default keeps the slot
        let k = crate::domain::ops::matmul_padded(8, 8, 8, 11, 11, 11, 8, 0);
        if GemmForm::of(&k).is_none() {
            let rates = race_strategy_rates::<f64>(&k, MicroShape::Mr8Nr4, 8, 1);
            assert!(rates.iter().all(|r| r.1 == 0.0));
            assert_eq!(pick_winner(&rates), StrategyKind::Lattice);
        }
    }

    #[test]
    fn measure_covers_every_candidate_without_a_fallback() {
        // every (dtype, candidate) cell of the grid must resolve to a
        // real kernel arm and time successfully — the old code silently
        // mapped unknown cells to 8×4; now they would panic here
        for micro in MicroShape::CANDIDATES {
            assert!(measure_rate::<f32>(micro, 2) > 0.0, "{micro:?} (f32)");
            assert!(measure_rate::<f64>(micro, 2) > 0.0, "{micro:?} (f64)");
        }
    }
}
