//! One-shot startup calibration of the register-tile shape (ROADMAP:
//! "Autotune MR×NR at startup").
//!
//! The packed-panel layouts are width-specific, so the candidate shapes
//! are separate kernels ([`mkernel_full`] 8×4 and [`mkernel_full_8x6`]
//! 8×6); the calibrator times both on an L1-resident packed panel and
//! reports the winner. The measured choice is recorded in the registry
//! ([`crate::runtime::Registry::set_micro_shape`]) and the packed
//! engine **dispatches it**: the planner threads it into
//! [`Plan`](crate::coordinator::Plan), and
//! [`TiledExecutor::with_micro_shape`](crate::codegen::TiledExecutor::with_micro_shape)
//! / [`run_parallel_macro`](crate::codegen::run_parallel_macro) select
//! the const-generic `NRW` panel path. `8×4` remains the default when no
//! calibration has run.

use std::time::Instant;

use super::microkernel::{mkernel_full, mkernel_full_8x6, MR, NR, NR_WIDE};

/// A register-tile shape candidate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MicroShape {
    /// The compile-time default 8×4.
    Mr8Nr4,
    /// The wide-vector candidate 8×6.
    Mr8Nr6,
}

impl MicroShape {
    /// `(MR, NR)` of the shape.
    pub fn dims(self) -> (usize, usize) {
        match self {
            MicroShape::Mr8Nr4 => (MR, NR),
            MicroShape::Mr8Nr6 => (MR, NR_WIDE),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            MicroShape::Mr8Nr4 => "8x4",
            MicroShape::Mr8Nr6 => "8x6",
        }
    }
}

/// Time both candidates on a tiny packed panel and return the shape with
/// the higher FMA rate. Ties (within 5%) keep the compile-time default,
/// so calibration can only ever *upgrade*. Takes ~1 ms at the default
/// serving `reps`; the work is deterministic so repeated calls agree on
/// a quiet machine.
pub fn calibrate(reps: u64) -> MicroShape {
    let kc = 128usize;
    let bp = vec![1.000_000_1f64; kc * MR];
    let cp4 = vec![0.999_999_9f64; kc * NR];
    let cp6 = vec![0.999_999_9f64; kc * NR_WIDE];
    let mut a4 = vec![0f64; (NR - 1) * MR + MR];
    let mut a6 = vec![0f64; (NR_WIDE - 1) * MR + MR];
    // warm both code paths and the panel lines
    mkernel_full(kc, &bp, &cp4, &mut a4, MR);
    mkernel_full_8x6(kc, &bp, &cp6, &mut a6, MR);
    let t4 = Instant::now();
    for _ in 0..reps {
        mkernel_full(kc, &bp, &cp4, &mut a4, MR);
    }
    let rate4 =
        (reps * (kc * MR * NR) as u64) as f64 / t4.elapsed().as_secs_f64().max(1e-9);
    let t6 = Instant::now();
    for _ in 0..reps {
        mkernel_full_8x6(kc, &bp, &cp6, &mut a6, MR);
    }
    let rate6 =
        (reps * (kc * MR * NR_WIDE) as u64) as f64 / t6.elapsed().as_secs_f64().max(1e-9);
    // keep the optimizer honest about the accumulators
    assert!(a4[0].is_finite() && a6[0].is_finite());
    if rate6 > rate4 * 1.05 {
        MicroShape::Mr8Nr6
    } else {
        MicroShape::Mr8Nr4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibrate_returns_a_candidate_quickly() {
        let shape = calibrate(50);
        assert!(matches!(shape, MicroShape::Mr8Nr4 | MicroShape::Mr8Nr6));
        let (mr, nr) = shape.dims();
        assert_eq!(mr, MR);
        assert!(nr == NR || nr == NR_WIDE);
        assert!(!shape.name().is_empty());
    }
}
