//! The element-type layer of the packed engine: the sealed [`Scalar`]
//! trait (f32/f64), the per-dtype register-tile geometry, and the
//! storage/accumulation precision split.
//!
//! The paper's associativity-lattice model is parameterized by cache
//! geometry in *elements per line*, so the element size must flow through
//! every layer — halving it doubles the elements per line (the conflict
//! lattice period divides differently) and doubles the natural register
//! tile width. [`Scalar`] carries exactly that: the element size the
//! selectors feed into working-set math ([`Scalar::ELEM`]), the
//! per-dtype register-tile column counts ([`Scalar::NR`] /
//! [`Scalar::NR_WIDE`] — f32 doubles f64's widths), and the ULP-scaled
//! differential-test tolerance ([`Scalar::ulp_tol`]).
//!
//! [`MicroShape`] names a point on the 2-D register-tile geometry grid —
//! an (MR-class, NR-class) pair, not an absolute shape: the startup
//! autotuner ([`super::autotune::calibrate_dtype`]) races the whole grid
//! per dtype and the engine resolves the winner to the dtype's actual
//! `(MR, NR)` at dispatch ([`MicroShape::dims_for`]). The 8-row classes
//! keep the per-dtype width doubling (8×4/8×6 f64 → 8×8/8×12 f32); the
//! 16-row classes trade width for height and keep the f64 column counts
//! at both dtypes (16×4/16×6), which is where an FMA-rich f32 target
//! earns its throughput without blowing the panel working set.
//!
//! [`Precision`] is the kubecl-style storage/accumulation *pair*: packs
//! and stores at `store`, accumulates each register tile at `acc`. The
//! mixed `f32acc64` mode keeps f32 panel bandwidth but runs every FMA in
//! f64 and rounds once per store — [`Accum`] is the accumulator-element
//! abstraction the microkernel is generic over, with the identity
//! blanket impl (acc == store) and the widening `f64`-for-`f32` impl.

use super::microkernel::{MR, MR_TALL, NR, NR_WIDE};

/// Runtime tag of a supported element type — what the registry keys its
/// per-dtype autotune winners by and the CLI parses from `--dtype`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F64,
}

impl DType {
    /// Element size in bytes.
    pub fn elem(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::F64 => 8,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F64 => "f64",
        }
    }

    /// Dense index for per-dtype tables (e.g. the registry's autotune
    /// winners).
    pub fn index(self) -> usize {
        match self {
            DType::F32 => 0,
            DType::F64 => 1,
        }
    }

    /// The dtype of a kernel built with `elem`-byte elements.
    pub fn from_elem(elem: usize) -> Option<DType> {
        match elem {
            4 => Some(DType::F32),
            8 => Some(DType::F64),
            _ => None,
        }
    }

    /// Parse a CLI spelling (`f32`/`f64`).
    pub fn parse(s: &str) -> Option<DType> {
        match s {
            "f32" => Some(DType::F32),
            "f64" => Some(DType::F64),
            _ => None,
        }
    }
}

/// The storage/accumulation precision pair of one execution (after
/// kubecl's `MatmulPrecision`: precision is a *pair* of element types,
/// not a scalar). `store` is the dtype of the arena, the packed panels
/// and the outputs; `acc` the dtype each register tile accumulates at
/// before the single rounding store. The two supported pure modes have
/// `acc == store`; the mixed mode is f32 storage with f64 accumulation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Precision {
    pub store: DType,
    pub acc: DType,
}

impl Precision {
    /// Pure f32: f32 panels, f32 accumulators.
    pub const F32: Precision = Precision {
        store: DType::F32,
        acc: DType::F32,
    };
    /// Pure f64.
    pub const F64: Precision = Precision {
        store: DType::F64,
        acc: DType::F64,
    };
    /// Mixed serve mode: f32 panels (full f32 pack bandwidth), f64
    /// register-tile accumulation, one rounding per store.
    pub const F32ACC64: Precision = Precision {
        store: DType::F32,
        acc: DType::F64,
    };

    /// The pure (acc == store) precision of a dtype.
    pub fn of(dtype: DType) -> Precision {
        Precision {
            store: dtype,
            acc: dtype,
        }
    }

    /// Parse a CLI spelling: `f32`, `f64`, or `f32acc64`.
    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "f32" => Some(Precision::F32),
            "f64" => Some(Precision::F64),
            "f32acc64" => Some(Precision::F32ACC64),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match (self.store, self.acc) {
            (DType::F32, DType::F32) => "f32",
            (DType::F64, DType::F64) => "f64",
            (DType::F32, DType::F64) => "f32acc64",
            // no mode narrows the accumulator below storage
            (DType::F64, DType::F32) => "f64acc32(unsupported)",
        }
    }

    /// True when the accumulator is wider than storage (the `f32acc64`
    /// register-tile path).
    pub fn wide_acc(self) -> bool {
        self.acc != self.store
    }
}

/// A point on the 2-D register-tile geometry grid: an (MR-class,
/// NR-class) pair. The resolved `(MR, NR)` is per-dtype
/// ([`MicroShape::dims_for`]): the 8-row classes double their column
/// count at f32 (twice as many elements fit one vector register /
/// cacheline), the 16-row classes spend those lanes on rows instead and
/// keep the f64 column counts at both dtypes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MicroShape {
    /// The compile-time default: 8×4 at f64, 8×8 at f32.
    Mr8Nr4,
    /// The wide-vector candidate: 8×6 at f64, 8×12 at f32.
    Mr8Nr6,
    /// The tall candidate: 16×4 at both dtypes.
    Mr16Nr4,
    /// The tall wide candidate: 16×6 at both dtypes (the f32 16×6 tile).
    Mr16Nr6,
}

impl MicroShape {
    /// Every point of the per-dtype autotune grid, in the deterministic
    /// race order ([`super::autotune::calibrate_dtype`]). The same four
    /// classes are raced at each dtype; resolution differs
    /// ([`MicroShape::dims_for`]).
    pub const CANDIDATES: [MicroShape; 4] = [
        MicroShape::Mr8Nr4,
        MicroShape::Mr8Nr6,
        MicroShape::Mr16Nr4,
        MicroShape::Mr16Nr6,
    ];

    /// Register-tile rows of this shape — dtype-independent (rows are
    /// the packed panel height, not a vector-lane count).
    pub fn mr(self) -> usize {
        match self {
            MicroShape::Mr8Nr4 | MicroShape::Mr8Nr6 => MR,
            MicroShape::Mr16Nr4 | MicroShape::Mr16Nr6 => MR_TALL,
        }
    }

    /// `(MR, NR)` of the shape at f64 (the legacy accessor; use
    /// [`MicroShape::dims_for`] for dtype-aware reporting).
    pub fn dims(self) -> (usize, usize) {
        self.dims_for(DType::F64)
    }

    /// Register-tile columns of this shape at `dtype`.
    pub fn nr_for(self, dtype: DType) -> usize {
        match (self, dtype) {
            (MicroShape::Mr8Nr4, DType::F64) => NR,
            (MicroShape::Mr8Nr6, DType::F64) => NR_WIDE,
            (MicroShape::Mr8Nr4, DType::F32) => 2 * NR,
            (MicroShape::Mr8Nr6, DType::F32) => 2 * NR_WIDE,
            // tall shapes spend the lanes on rows: f64 column counts at
            // both dtypes
            (MicroShape::Mr16Nr4, _) => NR,
            (MicroShape::Mr16Nr6, _) => NR_WIDE,
        }
    }

    /// `(MR, NR)` of this shape at `dtype`.
    pub fn dims_for(self, dtype: DType) -> (usize, usize) {
        (self.mr(), self.nr_for(dtype))
    }

    /// Human-readable `MRxNR` at f64 (legacy; see
    /// [`MicroShape::label_for`]).
    pub fn name(self) -> &'static str {
        match self {
            MicroShape::Mr8Nr4 => "8x4",
            MicroShape::Mr8Nr6 => "8x6",
            MicroShape::Mr16Nr4 => "16x4",
            MicroShape::Mr16Nr6 => "16x6",
        }
    }

    /// Human-readable `MRxNR` at `dtype` (what [`Plan::describe`]
    /// reports).
    ///
    /// [`Plan::describe`]: crate::coordinator::Plan::describe
    pub fn label_for(self, dtype: DType) -> String {
        format!("{}x{}", self.mr(), self.nr_for(dtype))
    }
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for f64 {}
}

/// A packed-engine element type. Sealed to f32/f64: the microkernels,
/// packers, executors and buffers are generic over `T: Scalar`, and every
/// geometry-dispatch site enumerates exactly the `(MR, NR)` pairs these
/// two types resolve the grid to.
pub trait Scalar:
    sealed::Sealed
    + Copy
    + Default
    + PartialEq
    + PartialOrd
    + Send
    + Sync
    + std::fmt::Debug
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::AddAssign
    + 'static
{
    const ZERO: Self;
    const ONE: Self;
    /// Element size in bytes — drives byte addresses
    /// ([`OperandView::addr`](super::runplan::OperandView::addr)) and the
    /// selectors' working-set math.
    const ELEM: usize;
    /// Runtime tag of this type.
    const DTYPE: DType;
    /// Register-tile columns of the narrow (default) width class.
    const NR: usize;
    /// Register-tile columns of the wide autotune candidate.
    const NR_WIDE: usize;
    /// Machine epsilon, as f64.
    const EPS: f64;
    /// The widened accumulator element for this storage type (the
    /// `acc64` register-tile path): f64 for f32 storage, f64 (identity)
    /// for f64 storage.
    type Acc: Accum<Self>;

    fn from_f64(v: f64) -> Self;
    fn to_f64(self) -> f64;

    /// The register-tile column count this type dispatches for a
    /// geometry class.
    fn nr(micro: MicroShape) -> usize {
        micro.nr_for(Self::DTYPE)
    }

    /// ULP-scaled differential-test tolerance for a depth-`depth`
    /// reduction of order-1 values, *per unit of result magnitude*:
    /// two correct summation orders of `depth` terms differ by at most
    /// O(depth · ε · max|partial sum|). Callers multiply by the result's
    /// magnitude scale. Integer fills need no tolerance at all — they are
    /// exact at either precision.
    fn ulp_tol(depth: usize) -> f64 {
        depth.max(1) as f64 * 8.0 * Self::EPS
    }
}

/// A register-tile accumulator element over storage type `T`: the
/// microkernels accumulate `[[A; MR]; NR]` tiles at `A`'s precision and
/// fold into the `T` output with a single rounding per element
/// ([`Accum::fold`]). The identity blanket impl (`A == T`) is the pure
/// path; `f64` over `f32` is the mixed `f32acc64` path — each product is
/// formed exactly in f64 (a product of two f32 values is exactly
/// representable in f64), summed in f64, and rounded once at the store.
pub trait Accum<T: Scalar>: Copy + Send + Sync + 'static {
    const ZERO: Self;
    /// One FMA step at the accumulator's precision: `self += b·c`.
    fn fma(&mut self, b: T, c: T);
    /// Sum two accumulator lanes at the accumulator's precision (the
    /// unrolled dot kernel's lane combine).
    fn add(self, other: Self) -> Self;
    /// Fold the accumulated sum into a stored element: `prev + self` at
    /// the accumulator's precision, rounded once to `T`.
    fn fold(self, prev: T) -> T;
}

/// Pure path: accumulate at storage precision.
impl<T: Scalar> Accum<T> for T {
    const ZERO: T = T::ZERO;

    #[inline(always)]
    fn fma(&mut self, b: T, c: T) {
        *self += b * c;
    }

    #[inline(always)]
    fn add(self, other: T) -> T {
        self + other
    }

    #[inline(always)]
    fn fold(self, prev: T) -> T {
        prev + self
    }
}

/// Mixed path: f64 accumulation over f32 panels. Each f32·f32 product is
/// exact in f64; the previous stored value is widened before the add, so
/// the entire update rounds exactly once (at the final `as f32`).
impl Accum<f32> for f64 {
    const ZERO: f64 = 0.0;

    #[inline(always)]
    fn fma(&mut self, b: f32, c: f32) {
        *self += (b as f64) * (c as f64);
    }

    #[inline(always)]
    fn add(self, other: f64) -> f64 {
        self + other
    }

    #[inline(always)]
    fn fold(self, prev: f32) -> f32 {
        ((prev as f64) + self) as f32
    }
}

impl Scalar for f64 {
    const ZERO: f64 = 0.0;
    const ONE: f64 = 1.0;
    const ELEM: usize = 8;
    const DTYPE: DType = DType::F64;
    const NR: usize = super::microkernel::NR;
    const NR_WIDE: usize = super::microkernel::NR_WIDE;
    const EPS: f64 = f64::EPSILON;
    // f64 has no wider accumulator: the acc64 path degenerates to the
    // identity (pure f64)
    type Acc = f64;

    fn from_f64(v: f64) -> f64 {
        v
    }
    fn to_f64(self) -> f64 {
        self
    }
}

impl Scalar for f32 {
    const ZERO: f32 = 0.0;
    const ONE: f32 = 1.0;
    const ELEM: usize = 4;
    const DTYPE: DType = DType::F32;
    // half-size elements → twice the vector lanes → twice the panel width
    const NR: usize = 2 * super::microkernel::NR;
    const NR_WIDE: usize = 2 * super::microkernel::NR_WIDE;
    const EPS: f64 = f32::EPSILON as f64;
    type Acc = f64;

    fn from_f64(v: f64) -> f32 {
        v as f32
    }
    fn to_f64(self) -> f64 {
        self as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_doubles_register_tile_width() {
        assert_eq!(MicroShape::Mr8Nr4.nr_for(DType::F64), 4);
        assert_eq!(MicroShape::Mr8Nr6.nr_for(DType::F64), 6);
        assert_eq!(MicroShape::Mr8Nr4.nr_for(DType::F32), 8);
        assert_eq!(MicroShape::Mr8Nr6.nr_for(DType::F32), 12);
        assert_eq!(<f32 as Scalar>::nr(MicroShape::Mr8Nr4), 8);
        assert_eq!(<f64 as Scalar>::nr(MicroShape::Mr8Nr6), 6);
        assert_eq!(MicroShape::Mr8Nr6.label_for(DType::F32), "8x12");
        assert_eq!(MicroShape::Mr8Nr4.label_for(DType::F64), "8x4");
    }

    #[test]
    fn tall_shapes_keep_f64_widths_at_both_dtypes() {
        for dtype in [DType::F32, DType::F64] {
            assert_eq!(MicroShape::Mr16Nr4.dims_for(dtype), (16, 4));
            assert_eq!(MicroShape::Mr16Nr6.dims_for(dtype), (16, 6));
        }
        assert_eq!(MicroShape::Mr16Nr6.label_for(DType::F32), "16x6");
        assert_eq!(MicroShape::Mr16Nr4.name(), "16x4");
        assert_eq!(MicroShape::Mr16Nr4.mr(), 16);
        assert_eq!(MicroShape::Mr8Nr6.mr(), 8);
    }

    /// The grid resolves to exactly the six `(MR, NR)` pairs the const
    /// dispatch sites instantiate — a new variant or dtype that resolves
    /// elsewhere must extend the kernel arms, and this pins it.
    #[test]
    fn grid_resolution_is_closed_over_the_kernel_arms() {
        const ARMS: [(usize, usize); 6] =
            [(8, 4), (8, 6), (8, 8), (8, 12), (16, 4), (16, 6)];
        for shape in MicroShape::CANDIDATES {
            for dtype in [DType::F32, DType::F64] {
                let dims = shape.dims_for(dtype);
                assert!(
                    ARMS.contains(&dims),
                    "{shape:?} at {} resolves to {dims:?}, outside the \
                     instantiated kernel arms",
                    dtype.name()
                );
            }
        }
    }

    #[test]
    fn dtype_roundtrips() {
        for d in [DType::F32, DType::F64] {
            assert_eq!(DType::from_elem(d.elem()), Some(d));
            assert_eq!(DType::parse(d.name()), Some(d));
        }
        assert_eq!(DType::from_elem(2), None);
        assert_eq!(DType::parse("f16"), None);
        assert_ne!(DType::F32.index(), DType::F64.index());
    }

    #[test]
    fn precision_parses_and_names_all_three_modes() {
        for (s, p) in [
            ("f32", Precision::F32),
            ("f64", Precision::F64),
            ("f32acc64", Precision::F32ACC64),
        ] {
            assert_eq!(Precision::parse(s), Some(p));
            assert_eq!(p.name(), s);
        }
        assert_eq!(Precision::parse("f64acc32"), None);
        assert!(Precision::F32ACC64.wide_acc());
        assert!(!Precision::F32.wide_acc());
        assert!(!Precision::F64.wide_acc());
        assert_eq!(Precision::of(DType::F32), Precision::F32);
        assert_eq!(Precision::of(DType::F64), Precision::F64);
    }

    /// The widening accumulator's contract: products exact, one rounding
    /// at the fold.
    #[test]
    fn f64_accumulator_over_f32_rounds_once() {
        // 1 + 2^-12 is exact in f32; its square is not — the pure-f32
        // accumulator rounds each product, the f64 accumulator keeps it
        let b = 1.0f32 + 2.0f32.powi(-12);
        let mut wide = <f64 as Accum<f32>>::ZERO;
        wide.fma(b, b);
        assert_eq!(wide, (b as f64) * (b as f64));
        let mut pure = <f32 as Accum<f32>>::ZERO;
        pure.fma(b, b);
        assert_eq!(pure, b * b);
        // fold: one rounding of (prev_f64 + acc)
        let prev = 3.5f32;
        assert_eq!(wide.fold(prev), ((prev as f64) + wide) as f32);
        // identity impl at f64
        let mut id = <f64 as Accum<f64>>::ZERO;
        id.fma(2.0, 3.0);
        assert_eq!(id.fold(1.0), 7.0);
    }

    #[test]
    fn ulp_tol_scales_with_depth_and_precision() {
        assert!(f32::ulp_tol(100) > f64::ulp_tol(100));
        assert!(f32::ulp_tol(200) > f32::ulp_tol(10));
        assert!(f64::ulp_tol(0) > 0.0);
    }
}
