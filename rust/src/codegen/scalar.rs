//! The element-type layer of the packed engine: the sealed [`Scalar`]
//! trait (f32/f64) and the per-dtype register-tile geometry.
//!
//! The paper's associativity-lattice model is parameterized by cache
//! geometry in *elements per line*, so the element size must flow through
//! every layer — halving it doubles the elements per line (the conflict
//! lattice period divides differently) and doubles the natural register
//! tile width. [`Scalar`] carries exactly that: the element size the
//! selectors feed into working-set math ([`Scalar::ELEM`]), the
//! per-dtype register-tile column counts ([`Scalar::NR`] /
//! [`Scalar::NR_WIDE`] — f32 doubles f64's widths), and the ULP-scaled
//! differential-test tolerance ([`Scalar::ulp_tol`]).
//!
//! [`MicroShape`] names a register-tile *width class* (narrow/wide), not
//! an absolute column count: the startup autotuner
//! ([`super::autotune::calibrate_dtype`]) picks one winner per dtype and
//! the engine resolves the class to the dtype's actual width at dispatch
//! ([`Scalar::nr`]). The trait is sealed: the packed panel layouts and
//! the dispatch matches below enumerate exactly these two types.

use super::microkernel::{MR, NR, NR_WIDE};

/// Runtime tag of a supported element type — what the registry keys its
/// per-dtype autotune winners by and the CLI parses from `--dtype`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F64,
}

impl DType {
    /// Element size in bytes.
    pub fn elem(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::F64 => 8,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F64 => "f64",
        }
    }

    /// Dense index for per-dtype tables (e.g. the registry's autotune
    /// winners).
    pub fn index(self) -> usize {
        match self {
            DType::F32 => 0,
            DType::F64 => 1,
        }
    }

    /// The dtype of a kernel built with `elem`-byte elements.
    pub fn from_elem(elem: usize) -> Option<DType> {
        match elem {
            4 => Some(DType::F32),
            8 => Some(DType::F64),
            _ => None,
        }
    }

    /// Parse a CLI spelling (`f32`/`f64`).
    pub fn parse(s: &str) -> Option<DType> {
        match s {
            "f32" => Some(DType::F32),
            "f64" => Some(DType::F64),
            _ => None,
        }
    }
}

/// A register-tile width class. The column count is per-dtype
/// ([`MicroShape::nr_for`]): f32 panels are twice as wide as f64 panels
/// for the same class, because twice as many elements fit one vector
/// register / cacheline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MicroShape {
    /// The compile-time default: 8×4 at f64, 8×8 at f32.
    Mr8Nr4,
    /// The wide-vector candidate: 8×6 at f64, 8×12 at f32.
    Mr8Nr6,
}

impl MicroShape {
    /// `(MR, NR)` of the shape at f64 (the legacy accessor; use
    /// [`MicroShape::dims_for`] for dtype-aware reporting).
    pub fn dims(self) -> (usize, usize) {
        self.dims_for(DType::F64)
    }

    /// Register-tile columns of this width class at `dtype`.
    pub fn nr_for(self, dtype: DType) -> usize {
        match (self, dtype) {
            (MicroShape::Mr8Nr4, DType::F64) => NR,
            (MicroShape::Mr8Nr6, DType::F64) => NR_WIDE,
            (MicroShape::Mr8Nr4, DType::F32) => 2 * NR,
            (MicroShape::Mr8Nr6, DType::F32) => 2 * NR_WIDE,
        }
    }

    /// `(MR, NR)` of this width class at `dtype`.
    pub fn dims_for(self, dtype: DType) -> (usize, usize) {
        (MR, self.nr_for(dtype))
    }

    /// Human-readable `MRxNR` at f64 (legacy; see
    /// [`MicroShape::label_for`]).
    pub fn name(self) -> &'static str {
        match self {
            MicroShape::Mr8Nr4 => "8x4",
            MicroShape::Mr8Nr6 => "8x6",
        }
    }

    /// Human-readable `MRxNR` at `dtype` (what [`Plan::describe`]
    /// reports).
    ///
    /// [`Plan::describe`]: crate::coordinator::Plan::describe
    pub fn label_for(self, dtype: DType) -> String {
        format!("{}x{}", MR, self.nr_for(dtype))
    }
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for f64 {}
}

/// A packed-engine element type. Sealed to f32/f64: the microkernels,
/// packers, executors and buffers are generic over `T: Scalar`, and every
/// width-dispatch site enumerates exactly the widths these two types
/// declare.
pub trait Scalar:
    sealed::Sealed
    + Copy
    + Default
    + PartialEq
    + PartialOrd
    + Send
    + Sync
    + std::fmt::Debug
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::AddAssign
    + 'static
{
    const ZERO: Self;
    const ONE: Self;
    /// Element size in bytes — drives byte addresses
    /// ([`OperandView::addr`](super::runplan::OperandView::addr)) and the
    /// selectors' working-set math.
    const ELEM: usize;
    /// Runtime tag of this type.
    const DTYPE: DType;
    /// Register-tile columns of the narrow (default) width class.
    const NR: usize;
    /// Register-tile columns of the wide autotune candidate.
    const NR_WIDE: usize;
    /// Machine epsilon, as f64.
    const EPS: f64;

    fn from_f64(v: f64) -> Self;
    fn to_f64(self) -> f64;

    /// The register-tile column count this type dispatches for a width
    /// class.
    fn nr(micro: MicroShape) -> usize {
        match micro {
            MicroShape::Mr8Nr4 => Self::NR,
            MicroShape::Mr8Nr6 => Self::NR_WIDE,
        }
    }

    /// ULP-scaled differential-test tolerance for a depth-`depth`
    /// reduction of order-1 values, *per unit of result magnitude*:
    /// two correct summation orders of `depth` terms differ by at most
    /// O(depth · ε · max|partial sum|). Callers multiply by the result's
    /// magnitude scale. Integer fills need no tolerance at all — they are
    /// exact at either precision.
    fn ulp_tol(depth: usize) -> f64 {
        depth.max(1) as f64 * 8.0 * Self::EPS
    }
}

impl Scalar for f64 {
    const ZERO: f64 = 0.0;
    const ONE: f64 = 1.0;
    const ELEM: usize = 8;
    const DTYPE: DType = DType::F64;
    const NR: usize = super::microkernel::NR;
    const NR_WIDE: usize = super::microkernel::NR_WIDE;
    const EPS: f64 = f64::EPSILON;

    fn from_f64(v: f64) -> f64 {
        v
    }
    fn to_f64(self) -> f64 {
        self
    }
}

impl Scalar for f32 {
    const ZERO: f32 = 0.0;
    const ONE: f32 = 1.0;
    const ELEM: usize = 4;
    const DTYPE: DType = DType::F32;
    // half-size elements → twice the vector lanes → twice the panel width
    const NR: usize = 2 * super::microkernel::NR;
    const NR_WIDE: usize = 2 * super::microkernel::NR_WIDE;
    const EPS: f64 = f32::EPSILON as f64;

    fn from_f64(v: f64) -> f32 {
        v as f32
    }
    fn to_f64(self) -> f64 {
        self as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_doubles_register_tile_width() {
        assert_eq!(MicroShape::Mr8Nr4.nr_for(DType::F64), 4);
        assert_eq!(MicroShape::Mr8Nr6.nr_for(DType::F64), 6);
        assert_eq!(MicroShape::Mr8Nr4.nr_for(DType::F32), 8);
        assert_eq!(MicroShape::Mr8Nr6.nr_for(DType::F32), 12);
        assert_eq!(<f32 as Scalar>::nr(MicroShape::Mr8Nr4), 8);
        assert_eq!(<f64 as Scalar>::nr(MicroShape::Mr8Nr6), 6);
        assert_eq!(MicroShape::Mr8Nr6.label_for(DType::F32), "8x12");
        assert_eq!(MicroShape::Mr8Nr4.label_for(DType::F64), "8x4");
    }

    #[test]
    fn dtype_roundtrips() {
        for d in [DType::F32, DType::F64] {
            assert_eq!(DType::from_elem(d.elem()), Some(d));
            assert_eq!(DType::parse(d.name()), Some(d));
        }
        assert_eq!(DType::from_elem(2), None);
        assert_eq!(DType::parse("f16"), None);
        assert_ne!(DType::F32.index(), DType::F64.index());
    }

    #[test]
    fn ulp_tol_scales_with_depth_and_precision() {
        assert!(f32::ulp_tol(100) > f64::ulp_tol(100));
        assert!(f32::ulp_tol(200) > f32::ulp_tol(10));
        assert!(f64::ulp_tol(0) > 0.0);
    }
}
