//! Register-blocked f64 microkernels — the tile-interior code quality the
//! paper gets from CLooG+gcc, written out by hand.
//!
//! Two kernels, both operating on *packed*, unit-stride panels (built by
//! [`super::pack`]) so the inner loops carry no bounds logic and no
//! strided loads:
//!
//! * [`mkernel_full`] — an `MR×NR` register tile: `MR·NR` accumulators
//!   held live across the whole k-loop (one store per output element per
//!   tile, instead of one per k step), fed by `MR + NR` packed loads per
//!   k step. [`mkernel_edge`] is the clipped variant for boundary blocks;
//!   packed panels are zero-padded so it can accumulate the full block
//!   and write back only the live `mr×nr` corner.
//! * [`axpy_block`] — the panel-replay kernel for skewed lattice tiles:
//!   one packed unit-stride run of B updates `NR` output columns at once,
//!   so each B element is loaded once per `NR` FMAs.
//!
//! All `get_unchecked` indexing is encapsulated here, behind length
//! asserts at entry — callers hand in plain slices.

/// Microkernel register-tile rows (unit-stride output dimension).
pub const MR: usize = 8;

/// Microkernel register-tile columns.
pub const NR: usize = 4;

/// Register-tile columns of the wide autotune candidate
/// ([`mkernel_full_8x6`]). The packed panel layouts are `NR`-specific, so
/// the wide shape is a separate kernel; `8×4` stays the compile-time
/// default and the startup calibrator ([`super::autotune`]) only records
/// which shape wins on the host core.
pub const NR_WIDE: usize = 6;

/// Full `MR×NR` register-tiled block over packed panels:
///
/// `a[r + cs·c] += Σ_t bp[t·MR + r] · cp[t·NR + c]`
///
/// for `r < MR`, `c < NR`, `t < kc`. `bp` is an MR-row B panel, `cp` an
/// NR-column C panel (layouts per [`super::pack::PackBuffers`]); `a` is
/// the output window starting at the block's top-left element with column
/// stride `cs`.
pub fn mkernel_full(kc: usize, bp: &[f64], cp: &[f64], a: &mut [f64], cs: usize) {
    assert!(bp.len() >= kc * MR, "B panel too short");
    assert!(cp.len() >= kc * NR, "C panel too short");
    assert!(cs >= MR, "output columns overlap");
    assert!(a.len() >= (NR - 1) * cs + MR, "output window too small");
    let mut acc = [[0f64; MR]; NR];
    // SAFETY: the asserts above bound every index used below.
    unsafe {
        for t in 0..kc {
            let b = bp.get_unchecked(t * MR..t * MR + MR);
            let c = cp.get_unchecked(t * NR..t * NR + NR);
            for (jc, accj) in acc.iter_mut().enumerate() {
                let cv = *c.get_unchecked(jc);
                for (r, av) in accj.iter_mut().enumerate() {
                    *av += *b.get_unchecked(r) * cv;
                }
            }
        }
        for (jc, accj) in acc.iter().enumerate() {
            let base = jc * cs;
            for (r, &v) in accj.iter().enumerate() {
                *a.get_unchecked_mut(base + r) += v;
            }
        }
    }
}

/// The `MR×NR_WIDE` (8×6) register tile — identical contract to
/// [`mkernel_full`] but over `NR_WIDE`-column C panels
/// (`cp[t·NR_WIDE + c]`). Only the startup autotuner times it today; the
/// execution engine stays on the 8×4 default.
pub fn mkernel_full_8x6(kc: usize, bp: &[f64], cp: &[f64], a: &mut [f64], cs: usize) {
    assert!(bp.len() >= kc * MR, "B panel too short");
    assert!(cp.len() >= kc * NR_WIDE, "C panel too short");
    assert!(cs >= MR, "output columns overlap");
    assert!(a.len() >= (NR_WIDE - 1) * cs + MR, "output window too small");
    let mut acc = [[0f64; MR]; NR_WIDE];
    // SAFETY: the asserts above bound every index used below.
    unsafe {
        for t in 0..kc {
            let b = bp.get_unchecked(t * MR..t * MR + MR);
            let c = cp.get_unchecked(t * NR_WIDE..t * NR_WIDE + NR_WIDE);
            for (jc, accj) in acc.iter_mut().enumerate() {
                let cv = *c.get_unchecked(jc);
                for (r, av) in accj.iter_mut().enumerate() {
                    *av += *b.get_unchecked(r) * cv;
                }
            }
        }
        for (jc, accj) in acc.iter().enumerate() {
            let base = jc * cs;
            for (r, &v) in accj.iter().enumerate() {
                *a.get_unchecked_mut(base + r) += v;
            }
        }
    }
}

/// Clipped `mr×nr` boundary block (`mr ≤ MR`, `nr ≤ NR`) over the same
/// packed panels. The panels are zero-padded past the live rows/columns,
/// so the accumulation runs the full register tile and only the write-back
/// is clipped.
pub fn mkernel_edge(
    mr: usize,
    nr: usize,
    kc: usize,
    bp: &[f64],
    cp: &[f64],
    a: &mut [f64],
    cs: usize,
) {
    assert!((1..=MR).contains(&mr) && (1..=NR).contains(&nr));
    assert!(bp.len() >= kc * MR, "B panel too short");
    assert!(cp.len() >= kc * NR, "C panel too short");
    assert!(a.len() >= (nr - 1) * cs + mr, "output window too small");
    let mut acc = [[0f64; MR]; NR];
    for t in 0..kc {
        let b = &bp[t * MR..t * MR + MR];
        let c = &cp[t * NR..t * NR + NR];
        for (jc, accj) in acc.iter_mut().enumerate() {
            let cv = c[jc];
            for (r, av) in accj.iter_mut().enumerate() {
                *av += b[r] * cv;
            }
        }
    }
    for (jc, accj) in acc.iter().enumerate().take(nr) {
        for (r, &v) in accj.iter().enumerate().take(mr) {
            a[jc * cs + r] += v;
        }
    }
}

/// Panel-replay kernel: one packed unit-stride run of B values updates up
/// to `NR` output columns at once:
///
/// `a[r + cs·col] += b[r] · c[col]`
///
/// for `r < b.len()`, `col < c.len()` (`c.len() ≤ NR`). `b` is a packed
/// (contiguous) run, `a` the output window at the run's first row of the
/// first column. The NR-wide case is unrolled; narrower boundary blocks
/// take the generic column loop.
pub fn axpy_block(a: &mut [f64], cs: usize, b: &[f64], c: &[f64]) {
    let len = b.len();
    let ncols = c.len();
    assert!((1..=NR).contains(&ncols), "column block of 1..=NR");
    assert!(len <= cs, "run longer than the output column stride");
    assert!(a.len() >= (ncols - 1) * cs + len, "output window too small");
    if ncols == NR {
        let (c0, c1, c2, c3) = (c[0], c[1], c[2], c[3]);
        // SAFETY: the asserts above bound every index used below.
        unsafe {
            for r in 0..len {
                let bv = *b.get_unchecked(r);
                *a.get_unchecked_mut(r) += bv * c0;
                *a.get_unchecked_mut(r + cs) += bv * c1;
                *a.get_unchecked_mut(r + 2 * cs) += bv * c2;
                *a.get_unchecked_mut(r + 3 * cs) += bv * c3;
            }
        }
    } else {
        for (col, &cv) in c.iter().enumerate() {
            let base = col * cs;
            // SAFETY: base + len ≤ (ncols-1)·cs + len ≤ a.len().
            unsafe {
                for r in 0..len {
                    *a.get_unchecked_mut(base + r) += *b.get_unchecked(r) * cv;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(len: usize, seed: u64) -> Vec<f64> {
        let mut rng = crate::testutil::Rng::new(seed);
        (0..len).map(|_| rng.f64_unit() - 0.5).collect()
    }

    #[test]
    fn full_kernel_matches_naive() {
        let kc = 13;
        let bp = fill(kc * MR, 1);
        let cp = fill(kc * NR, 2);
        let cs = MR + 3;
        let mut a = fill((NR - 1) * cs + MR, 3);
        let orig = a.clone();
        mkernel_full(kc, &bp, &cp, &mut a, cs);
        for jc in 0..NR {
            for r in 0..MR {
                let want: f64 = (0..kc).map(|t| bp[t * MR + r] * cp[t * NR + jc]).sum();
                let got = a[jc * cs + r] - orig[jc * cs + r];
                assert!((got - want).abs() < 1e-12, "({r},{jc})");
            }
        }
    }

    #[test]
    fn wide_kernel_matches_naive() {
        let kc = 11;
        let bp = fill(kc * MR, 4);
        let cp = fill(kc * NR_WIDE, 5);
        let cs = MR + 2;
        let mut a = fill((NR_WIDE - 1) * cs + MR, 6);
        let orig = a.clone();
        mkernel_full_8x6(kc, &bp, &cp, &mut a, cs);
        for jc in 0..NR_WIDE {
            for r in 0..MR {
                let want: f64 = (0..kc)
                    .map(|t| bp[t * MR + r] * cp[t * NR_WIDE + jc])
                    .sum();
                let got = a[jc * cs + r] - orig[jc * cs + r];
                assert!((got - want).abs() < 1e-12, "({r},{jc})");
            }
        }
    }

    #[test]
    fn edge_kernel_writes_only_live_corner() {
        let kc = 5;
        let (mr, nr) = (3usize, 2usize);
        // zero-pad the dead rows/cols as the packer does
        let mut bp = vec![0f64; kc * MR];
        let mut cp = vec![0f64; kc * NR];
        for t in 0..kc {
            for r in 0..mr {
                bp[t * MR + r] = (t * MR + r) as f64 * 0.25 - 1.0;
            }
            for c in 0..nr {
                cp[t * NR + c] = (t * NR + c) as f64 * 0.5 - 2.0;
            }
        }
        let cs = MR;
        let mut a = vec![7.0; (NR - 1) * cs + MR];
        let sentinel = a.clone();
        mkernel_edge(mr, nr, kc, &bp, &cp, &mut a, cs);
        for jc in 0..NR {
            for r in 0..MR {
                let idx = jc * cs + r;
                if r < mr && jc < nr {
                    let want: f64 =
                        (0..kc).map(|t| bp[t * MR + r] * cp[t * NR + jc]).sum();
                    assert!((a[idx] - 7.0 - want).abs() < 1e-12, "({r},{jc})");
                } else {
                    assert_eq!(a[idx], sentinel[idx], "dead element ({r},{jc}) written");
                }
            }
        }
    }

    #[test]
    fn axpy_block_all_widths() {
        let len = 11;
        let cs = 16;
        let b = fill(len, 9);
        for ncols in 1..=NR {
            let c = fill(ncols, 10);
            let mut a = fill((ncols - 1) * cs + len, 11);
            let orig = a.clone();
            axpy_block(&mut a, cs, &b, &c);
            for (col, &cv) in c.iter().enumerate() {
                for r in 0..len {
                    let want = orig[col * cs + r] + b[r] * cv;
                    assert!((a[col * cs + r] - want).abs() < 1e-12, "ncols={ncols}");
                }
            }
        }
    }
}
