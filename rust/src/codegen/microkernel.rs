//! Register-blocked microkernels — the tile-interior code quality the
//! paper gets from CLooG+gcc, written out by hand. Element-generic: every
//! kernel is `T: Scalar` (f32 or f64); f32 panels are twice as wide
//! ([`Scalar::NR`]) because twice as many elements fit a vector register.
//!
//! All kernels operate on *packed*, unit-stride panels (built by
//! [`super::pack`] from a [`RunPlan`](super::runplan::RunPlan)) so the
//! inner loops carry no bounds logic and no strided loads:
//!
//! * [`mkernel_full_at`] — an `MRH×NRW` register tile. Both dimensions
//!   are const generics: `MRH` is the row class ([`MR`] = 8 or
//!   [`MR_TALL`] = 16, matching the packed panel height) and `NRW` the
//!   dtype-resolved column count ([`Scalar::nr`]), giving the six
//!   instantiated arms 8×{4,6,8,12} and 16×{4,6}. `MRH·NRW`
//!   accumulators are held live across the whole k-loop (one store per
//!   output element per tile, instead of one per k step), fed by
//!   `MRH + NRW` packed loads per k step. The accumulator element is a
//!   third generic, `A:`[`Accum`]`<T>`: `A = T` is the pure path, and
//!   `A = f64` over `T = f32` is the mixed `f32acc64` path — every FMA
//!   runs in f64 and each output element rounds exactly once at the
//!   fold into the f32 arena. Output columns are addressed by
//!   **per-column base offsets**, so kernels whose output columns are
//!   not uniformly strided (e.g. Kronecker) dispatch the same register
//!   tile. [`mkernel_edge_at`] is the clipped variant for boundary
//!   blocks; packed panels are zero-padded so it can accumulate the full
//!   block and write back only the live `mr×nr` corner.
//! * [`mkernel_full`] / [`mkernel_full_8x6`] / [`mkernel_edge`] — the
//!   f64 uniform-stride wrappers (column stride `cs`), kept for the
//!   packed single-block callers and the legacy autotune entry point;
//!   they lower onto the `_at` kernels at `MR` rows with the identity
//!   accumulator.
//! * [`axpy_block`] — the panel-replay kernel for skewed lattice tiles:
//!   one packed unit-stride run of the row operand updates up to
//!   [`AXPY_MAX_COLS`] output columns at once, so each packed element is
//!   loaded once per column block. (Replay accumulates in the arena
//!   across calls, so it stays at storage precision — the `f32acc64`
//!   scope is the packed register-tile paths and the dot kernel.)
//! * [`dot_update`] — the degenerate `m = n = 1` path (scalar product,
//!   convolution): a 4-way-unrolled dot over the plan's reduction offset
//!   tables, straight from the arena. Packing a 1-row, 1-column problem
//!   into `MRH×NRW` zero-padded panels would waste `MRH·NRW − 1` of
//!   every register tile; the dot kernel skips packing entirely.
//!
//! All `get_unchecked` indexing is encapsulated here, behind length
//! asserts at entry — callers hand in plain slices.

use super::scalar::{Accum, Scalar};

/// Microkernel register-tile rows of the default (narrow) row class,
/// shared by both dtypes — also the panel height every legacy `MR`-fixed
/// entry point packs at.
pub const MR: usize = 8;

/// Register-tile rows of the tall row class (the 16×{4,6} grid points):
/// twice the panel height, f64 column counts at both dtypes.
pub const MR_TALL: usize = 16;

/// f64 register-tile columns of the default (narrow) shape. Per-dtype
/// widths live on [`Scalar::NR`]; f32 doubles this.
pub const NR: usize = 4;

/// f64 register-tile columns of the wide autotune candidate. The packed
/// panel layouts are width-specific, so the engine packs with whichever
/// width the startup calibrator ([`super::autotune`]) selected for the
/// dtype.
pub const NR_WIDE: usize = 6;

/// Upper bound on the column-block width [`axpy_block`] accepts — large
/// enough for the widest *narrow* replay width (f32's `NR = 8`).
pub const AXPY_MAX_COLS: usize = 8;

/// Full `MRH×NRW` register-tiled block over packed panels, with
/// per-column output bases:
///
/// `a[bases[c] + r] += Σ_t bp[t·MRH + r] · cp[t·NRW + c]`
///
/// for `r < MRH`, `c < NRW`, `t < kc`, accumulated at `A`'s precision
/// and folded into `a` with one rounding per element ([`Accum::fold`]).
/// `bp` is an MRH-row panel of the row operand, `cp` an NRW-column panel
/// of the column operand (layouts per [`super::pack`], packed at the
/// same `MRH`); `a` is the whole output arena. Callers guarantee the
/// `NRW` column windows `[bases[c], bases[c] + MRH)` are disjoint (true
/// whenever the kernel's output map is injective).
pub fn mkernel_full_at<T: Scalar, A: Accum<T>, const MRH: usize, const NRW: usize>(
    kc: usize,
    bp: &[T],
    cp: &[T],
    a: &mut [T],
    bases: &[usize; NRW],
) {
    assert!(bp.len() >= kc * MRH, "B panel too short");
    assert!(cp.len() >= kc * NRW, "C panel too short");
    for &b in bases {
        assert!(b + MRH <= a.len(), "output window too small");
    }
    let mut acc = [[A::ZERO; MRH]; NRW];
    // SAFETY: the asserts above bound every index used below.
    unsafe {
        for t in 0..kc {
            let b = bp.get_unchecked(t * MRH..t * MRH + MRH);
            let c = cp.get_unchecked(t * NRW..t * NRW + NRW);
            for (jc, accj) in acc.iter_mut().enumerate() {
                let cv = *c.get_unchecked(jc);
                for (r, av) in accj.iter_mut().enumerate() {
                    av.fma(*b.get_unchecked(r), cv);
                }
            }
        }
        for (jc, accj) in acc.iter().enumerate() {
            let base = *bases.get_unchecked(jc);
            for (r, &v) in accj.iter().enumerate() {
                let slot = a.get_unchecked_mut(base + r);
                *slot = v.fold(*slot);
            }
        }
    }
}

/// Clipped `mr×nr` boundary block (`mr ≤ MRH`, `nr ≤ NRW`) over the same
/// packed panels, with per-column output bases (`bases.len() ≥ nr`). The
/// panels are zero-padded past the live rows/columns, so the accumulation
/// runs the full register tile and only the write-back is clipped.
pub fn mkernel_edge_at<T: Scalar, A: Accum<T>, const MRH: usize, const NRW: usize>(
    mr: usize,
    nr: usize,
    kc: usize,
    bp: &[T],
    cp: &[T],
    a: &mut [T],
    bases: &[usize],
) {
    assert!((1..=MRH).contains(&mr) && (1..=NRW).contains(&nr));
    assert!(bp.len() >= kc * MRH, "B panel too short");
    assert!(cp.len() >= kc * NRW, "C panel too short");
    assert!(bases.len() >= nr, "missing column bases");
    for &b in &bases[..nr] {
        assert!(b + mr <= a.len(), "output window too small");
    }
    let mut acc = [[A::ZERO; MRH]; NRW];
    for t in 0..kc {
        let b = &bp[t * MRH..t * MRH + MRH];
        let c = &cp[t * NRW..t * NRW + NRW];
        for (jc, accj) in acc.iter_mut().enumerate() {
            let cv = c[jc];
            for (r, av) in accj.iter_mut().enumerate() {
                av.fma(b[r], cv);
            }
        }
    }
    for (jc, accj) in acc.iter().enumerate().take(nr) {
        let base = bases[jc];
        for (r, &v) in accj.iter().enumerate().take(mr) {
            a[base + r] = v.fold(a[base + r]);
        }
    }
}

/// Uniform-stride wrapper: full f64 `MR×NR` register tile with output
/// column stride `cs` — `a[r + cs·c] += Σ_t bp[t·MR + r] · cp[t·NR + c]`,
/// `a` starting at the block's top-left element.
pub fn mkernel_full(kc: usize, bp: &[f64], cp: &[f64], a: &mut [f64], cs: usize) {
    assert!(cs >= MR, "output columns overlap");
    let mut bases = [0usize; NR];
    for (jc, b) in bases.iter_mut().enumerate() {
        *b = jc * cs;
    }
    mkernel_full_at::<f64, f64, MR, NR>(kc, bp, cp, a, &bases);
}

/// Uniform-stride wrapper for the f64 `MR×NR_WIDE` (8×6) register tile —
/// identical contract to [`mkernel_full`] but over `NR_WIDE`-column C
/// panels (`cp[t·NR_WIDE + c]`).
pub fn mkernel_full_8x6(kc: usize, bp: &[f64], cp: &[f64], a: &mut [f64], cs: usize) {
    assert!(cs >= MR, "output columns overlap");
    let mut bases = [0usize; NR_WIDE];
    for (jc, b) in bases.iter_mut().enumerate() {
        *b = jc * cs;
    }
    mkernel_full_at::<f64, f64, MR, NR_WIDE>(kc, bp, cp, a, &bases);
}

/// Uniform-stride wrapper: clipped f64 `mr×nr` boundary block (`mr ≤ MR`,
/// `nr ≤ NR`) with output column stride `cs`.
pub fn mkernel_edge(
    mr: usize,
    nr: usize,
    kc: usize,
    bp: &[f64],
    cp: &[f64],
    a: &mut [f64],
    cs: usize,
) {
    let mut bases = [0usize; NR];
    for (jc, b) in bases.iter_mut().enumerate() {
        *b = jc * cs;
    }
    mkernel_edge_at::<f64, f64, MR, NR>(mr, nr, kc, bp, cp, a, &bases[..nr]);
}

/// Panel-replay kernel: one packed unit-stride run of row-operand values
/// updates up to [`AXPY_MAX_COLS`] output columns at once:
///
/// `a[r + cs·col] += b[r] · c[col]`
///
/// for `r < b.len()`, `col < c.len()`. `b` is a packed (contiguous) run,
/// `a` the output window at the run's first row of the first column. The
/// full-width cases — 4 columns (f64's narrow replay width) and 8
/// columns (f32's) — are unrolled; boundary widths take the generic
/// column loop.
pub fn axpy_block<T: Scalar>(a: &mut [T], cs: usize, b: &[T], c: &[T]) {
    let len = b.len();
    let ncols = c.len();
    assert!(
        (1..=AXPY_MAX_COLS).contains(&ncols),
        "column block of 1..=AXPY_MAX_COLS"
    );
    assert!(len <= cs, "run longer than the output column stride");
    assert!(a.len() >= (ncols - 1) * cs + len, "output window too small");
    if ncols == 4 {
        let (c0, c1, c2, c3) = (c[0], c[1], c[2], c[3]);
        // SAFETY: the asserts above bound every index used below.
        unsafe {
            for r in 0..len {
                let bv = *b.get_unchecked(r);
                *a.get_unchecked_mut(r) += bv * c0;
                *a.get_unchecked_mut(r + cs) += bv * c1;
                *a.get_unchecked_mut(r + 2 * cs) += bv * c2;
                *a.get_unchecked_mut(r + 3 * cs) += bv * c3;
            }
        }
    } else if ncols == 8 {
        let (c0, c1, c2, c3) = (c[0], c[1], c[2], c[3]);
        let (c4, c5, c6, c7) = (c[4], c[5], c[6], c[7]);
        // SAFETY: the asserts above bound every index used below.
        unsafe {
            for r in 0..len {
                let bv = *b.get_unchecked(r);
                *a.get_unchecked_mut(r) += bv * c0;
                *a.get_unchecked_mut(r + cs) += bv * c1;
                *a.get_unchecked_mut(r + 2 * cs) += bv * c2;
                *a.get_unchecked_mut(r + 3 * cs) += bv * c3;
                *a.get_unchecked_mut(r + 4 * cs) += bv * c4;
                *a.get_unchecked_mut(r + 5 * cs) += bv * c5;
                *a.get_unchecked_mut(r + 6 * cs) += bv * c6;
                *a.get_unchecked_mut(r + 7 * cs) += bv * c7;
            }
        }
    } else {
        for (col, &cv) in c.iter().enumerate() {
            let base = col * cs;
            // SAFETY: base + len ≤ (ncols-1)·cs + len ≤ a.len().
            unsafe {
                for r in 0..len {
                    *a.get_unchecked_mut(base + r) += *b.get_unchecked(r) * cv;
                }
            }
        }
    }
}

/// Degenerate `m = n = 1` GEMM form (scalar product, convolution): a
/// 4-way-unrolled dot over the plan's reduction offset tables —
///
/// `a[out] += Σ_t a[(row + red_row[t])] · a[(col + red_col[t])]`
///
/// straight from the arena, no packing — accumulated at `A`'s precision
/// with one rounding at the final store (the degenerate forms' `acc64`
/// path). `row`/`col` are the absolute row-/column-operand element bases
/// of the box ([`Run::row`] / [`RunPlan::col_in`]).
///
/// [`Run::row`]: super::runplan::Run::row
/// [`RunPlan::col_in`]: super::runplan::RunPlan::col_in
pub fn dot_update_acc<T: Scalar, A: Accum<T>>(
    a: &mut [T],
    out: usize,
    row: i64,
    col: i64,
    red_row: &[i64],
    red_col: &[i64],
) {
    let kc = red_row.len();
    assert_eq!(red_col.len(), kc, "reduction tables differ in length");
    assert!(out < a.len(), "output index out of the arena");
    let mut acc = [A::ZERO; 4];
    for (t, (&rr, &rc)) in red_row.iter().zip(red_col).enumerate() {
        let b = a[(row + rr) as usize];
        let c = a[(col + rc) as usize];
        acc[t & 3].fma(b, c);
    }
    // pairwise-combine the four lanes at A's precision, then fold once
    let total = acc[0].add(acc[1]).add(acc[2].add(acc[3]));
    a[out] = total.fold(a[out]);
}

/// [`dot_update_acc`] at storage precision (`A = T`) — the legacy entry
/// point every pure-precision path dispatches.
pub fn dot_update<T: Scalar>(
    a: &mut [T],
    out: usize,
    row: i64,
    col: i64,
    red_row: &[i64],
    red_col: &[i64],
) {
    dot_update_acc::<T, T>(a, out, row, col, red_row, red_col);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(len: usize, seed: u64) -> Vec<f64> {
        let mut rng = crate::testutil::Rng::new(seed);
        (0..len).map(|_| rng.f64_unit() - 0.5).collect()
    }

    #[test]
    fn full_kernel_matches_naive() {
        let kc = 13;
        let bp = fill(kc * MR, 1);
        let cp = fill(kc * NR, 2);
        let cs = MR + 3;
        let mut a = fill((NR - 1) * cs + MR, 3);
        let orig = a.clone();
        mkernel_full(kc, &bp, &cp, &mut a, cs);
        for jc in 0..NR {
            for r in 0..MR {
                let want: f64 = (0..kc).map(|t| bp[t * MR + r] * cp[t * NR + jc]).sum();
                let got = a[jc * cs + r] - orig[jc * cs + r];
                assert!((got - want).abs() < 1e-12, "({r},{jc})");
            }
        }
    }

    #[test]
    fn wide_kernel_matches_naive() {
        let kc = 11;
        let bp = fill(kc * MR, 4);
        let cp = fill(kc * NR_WIDE, 5);
        let cs = MR + 2;
        let mut a = fill((NR_WIDE - 1) * cs + MR, 6);
        let orig = a.clone();
        mkernel_full_8x6(kc, &bp, &cp, &mut a, cs);
        for jc in 0..NR_WIDE {
            for r in 0..MR {
                let want: f64 = (0..kc)
                    .map(|t| bp[t * MR + r] * cp[t * NR_WIDE + jc])
                    .sum();
                let got = a[jc * cs + r] - orig[jc * cs + r];
                assert!((got - want).abs() < 1e-12, "({r},{jc})");
            }
        }
    }

    #[test]
    fn f32_wide_panel_matches_naive() {
        // f32's narrow width (8 columns): exact with small integer fills
        const W: usize = 8;
        let kc = 9usize;
        let bp: Vec<f32> = (0..kc * MR).map(|i| (i % 7) as f32 - 3.0).collect();
        let cp: Vec<f32> = (0..kc * W).map(|i| (i % 5) as f32 - 2.0).collect();
        let cs = MR + 1;
        let mut a = vec![1.0f32; (W - 1) * cs + MR];
        let orig = a.clone();
        let mut bases = [0usize; W];
        for (jc, b) in bases.iter_mut().enumerate() {
            *b = jc * cs;
        }
        mkernel_full_at::<f32, f32, MR, W>(kc, &bp, &cp, &mut a, &bases);
        for jc in 0..W {
            for r in 0..MR {
                let want: f32 = (0..kc).map(|t| bp[t * MR + r] * cp[t * W + jc]).sum();
                assert_eq!(a[jc * cs + r] - orig[jc * cs + r], want, "({r},{jc})");
            }
        }
    }

    /// The tall row class: a 16×6 tile over MR_TALL-row panels, exact
    /// with integer fills at both dtypes.
    #[test]
    fn tall_kernel_matches_naive_both_dtypes() {
        fn case<T: Scalar>() {
            const H: usize = MR_TALL;
            const W: usize = NR_WIDE;
            let kc = 7usize;
            let bp: Vec<T> =
                (0..kc * H).map(|i| T::from_f64((i % 7) as f64 - 3.0)).collect();
            let cp: Vec<T> =
                (0..kc * W).map(|i| T::from_f64((i % 5) as f64 - 2.0)).collect();
            let cs = H + 2;
            let mut a = vec![T::ONE; (W - 1) * cs + H];
            let orig = a.clone();
            let mut bases = [0usize; W];
            for (jc, b) in bases.iter_mut().enumerate() {
                *b = jc * cs;
            }
            mkernel_full_at::<T, T, H, W>(kc, &bp, &cp, &mut a, &bases);
            for jc in 0..W {
                for r in 0..H {
                    let want: f64 = (0..kc)
                        .map(|t| bp[t * H + r].to_f64() * cp[t * W + jc].to_f64())
                        .sum();
                    let got = (a[jc * cs + r] - orig[jc * cs + r]).to_f64();
                    assert_eq!(got, want, "({r},{jc}) elem={}", T::ELEM);
                }
            }
        }
        case::<f64>();
        case::<f32>();
    }

    /// The mixed-precision tile: f32 panels, f64 accumulators, one
    /// rounding per output element — equal to the f64 oracle rounded
    /// once, and at least as close to it as the pure-f32 tile on a
    /// cancellation-heavy fill.
    #[test]
    fn acc64_tile_matches_f64_oracle_rounded_once() {
        const W: usize = NR;
        let kc = 64usize;
        // mixed-sign near-cancelling fill: the pure f32 running sum
        // rounds every step, the widened accumulator only at the fold
        let bp: Vec<f32> = (0..kc * MR)
            .map(|i| if i % 2 == 0 { 1.0 + 2.0f32.powi(-12) } else { -1.0 })
            .collect();
        let cp: Vec<f32> = (0..kc * W)
            .map(|i| if i % 3 == 0 { 1.0 - 2.0f32.powi(-11) } else { 1.0 })
            .collect();
        let mut bases = [0usize; W];
        let cs = MR;
        for (jc, b) in bases.iter_mut().enumerate() {
            *b = jc * cs;
        }
        let mut wide = vec![0.5f32; (W - 1) * cs + MR];
        let orig = wide.clone();
        mkernel_full_at::<f32, f64, MR, W>(kc, &bp, &cp, &mut wide, &bases);
        let mut pure = orig.clone();
        mkernel_full_at::<f32, f32, MR, W>(kc, &bp, &cp, &mut pure, &bases);
        for jc in 0..W {
            for r in 0..MR {
                let exact: f64 = (0..kc)
                    .map(|t| bp[t * MR + r] as f64 * cp[t * W + jc] as f64)
                    .sum();
                let idx = jc * cs + r;
                let want = (orig[idx] as f64 + exact) as f32;
                assert_eq!(wide[idx], want, "({r},{jc}): not a single rounding");
                let werr = (wide[idx] as f64 - (orig[idx] as f64 + exact)).abs();
                let perr = (pure[idx] as f64 - (orig[idx] as f64 + exact)).abs();
                assert!(werr <= perr, "({r},{jc}): acc64 worse than pure f32");
            }
        }
    }

    #[test]
    fn full_at_kernel_scattered_columns() {
        // non-uniform column bases (the Kronecker case): columns placed
        // out of order with uneven gaps
        let kc = 7;
        let bp = fill(kc * MR, 10);
        let cp = fill(kc * NR, 11);
        let bases = [40usize, 0, 96, 16];
        let mut a = fill(128, 12);
        let orig = a.clone();
        mkernel_full_at::<f64, f64, MR, NR>(kc, &bp, &cp, &mut a, &bases);
        for (jc, &base) in bases.iter().enumerate() {
            for r in 0..MR {
                let want: f64 = (0..kc).map(|t| bp[t * MR + r] * cp[t * NR + jc]).sum();
                let got = a[base + r] - orig[base + r];
                assert!((got - want).abs() < 1e-12, "({r},{jc})");
            }
        }
        // untouched elements stay untouched
        let touched: std::collections::HashSet<usize> = bases
            .iter()
            .flat_map(|&b| (b..b + MR).collect::<Vec<_>>())
            .collect();
        for (i, (&x, &o)) in a.iter().zip(&orig).enumerate() {
            if !touched.contains(&i) {
                assert_eq!(x, o, "element {i} written");
            }
        }
    }

    #[test]
    fn edge_kernel_writes_only_live_corner() {
        let kc = 5;
        let (mr, nr) = (3usize, 2usize);
        // zero-pad the dead rows/cols as the packer does
        let mut bp = vec![0f64; kc * MR];
        let mut cp = vec![0f64; kc * NR];
        for t in 0..kc {
            for r in 0..mr {
                bp[t * MR + r] = (t * MR + r) as f64 * 0.25 - 1.0;
            }
            for c in 0..nr {
                cp[t * NR + c] = (t * NR + c) as f64 * 0.5 - 2.0;
            }
        }
        let cs = MR;
        let mut a = vec![7.0; (NR - 1) * cs + MR];
        let sentinel = a.clone();
        mkernel_edge(mr, nr, kc, &bp, &cp, &mut a, cs);
        for jc in 0..NR {
            for r in 0..MR {
                let idx = jc * cs + r;
                if r < mr && jc < nr {
                    let want: f64 =
                        (0..kc).map(|t| bp[t * MR + r] * cp[t * NR + jc]).sum();
                    assert!((a[idx] - 7.0 - want).abs() < 1e-12, "({r},{jc})");
                } else {
                    assert_eq!(a[idx], sentinel[idx], "dead element ({r},{jc}) written");
                }
            }
        }
    }

    #[test]
    fn edge_at_wide_panel_clips() {
        // NR_WIDE panel, clipped write-back at scattered bases
        let kc = 4;
        let (mr, nr) = (5usize, 3usize);
        let mut bp = vec![0f64; kc * MR];
        let mut cp = vec![0f64; kc * NR_WIDE];
        for t in 0..kc {
            for r in 0..mr {
                bp[t * MR + r] = (t + r) as f64 - 2.0;
            }
            for c in 0..nr {
                cp[t * NR_WIDE + c] = (t * 2 + c) as f64 * 0.5;
            }
        }
        let bases = [20usize, 0, 40];
        let mut a = vec![1.0f64; 64];
        let sentinel = a.clone();
        mkernel_edge_at::<f64, f64, MR, NR_WIDE>(mr, nr, kc, &bp, &cp, &mut a, &bases);
        for (jc, &base) in bases.iter().enumerate() {
            for r in 0..mr {
                let want: f64 = (0..kc)
                    .map(|t| bp[t * MR + r] * cp[t * NR_WIDE + jc])
                    .sum();
                assert!((a[base + r] - 1.0 - want).abs() < 1e-12, "({r},{jc})");
            }
            for r in mr..MR {
                assert_eq!(a[base + r], sentinel[base + r]);
            }
        }
    }

    /// The tall edge kernel clips rows past MR (a live row count between
    /// 8 and 16 is exactly the case the narrow arms cannot express).
    #[test]
    fn tall_edge_clips_past_narrow_height() {
        const H: usize = MR_TALL;
        let kc = 3;
        let (mr, nr) = (11usize, 2usize);
        let mut bp = vec![0f64; kc * H];
        let mut cp = vec![0f64; kc * NR];
        for t in 0..kc {
            for r in 0..mr {
                bp[t * H + r] = (t + 2 * r) as f64 - 4.0;
            }
            for c in 0..nr {
                cp[t * NR + c] = (t + c) as f64 * 0.5 - 1.0;
            }
        }
        let bases = [0usize, 24];
        let mut a = vec![2.0f64; 48];
        let sentinel = a.clone();
        mkernel_edge_at::<f64, f64, H, NR>(mr, nr, kc, &bp, &cp, &mut a, &bases);
        for (jc, &base) in bases.iter().enumerate() {
            for r in 0..mr {
                let want: f64 =
                    (0..kc).map(|t| bp[t * H + r] * cp[t * NR + jc]).sum();
                assert!((a[base + r] - 2.0 - want).abs() < 1e-12, "({r},{jc})");
            }
            for r in mr..H {
                assert_eq!(a[base + r], sentinel[base + r], "row {r} written");
            }
        }
    }

    #[test]
    fn axpy_block_all_widths() {
        let len = 11;
        let cs = 16;
        let b = fill(len, 9);
        for ncols in 1..=AXPY_MAX_COLS {
            let c = fill(ncols, 10);
            let mut a = fill((ncols - 1) * cs + len, 11);
            let orig = a.clone();
            axpy_block(&mut a, cs, &b, &c);
            for (col, &cv) in c.iter().enumerate() {
                for r in 0..len {
                    let want = orig[col * cs + r] + b[r] * cv;
                    assert!((a[col * cs + r] - want).abs() < 1e-12, "ncols={ncols}");
                }
            }
        }
    }

    #[test]
    fn dot_update_matches_naive_both_dtypes() {
        // scattered reduction offsets, including a reversed (negative
        // stride) column walk like convolution's
        let n = 13i64;
        let red_row: Vec<i64> = (0..n).collect();
        let red_col: Vec<i64> = (0..n).map(|t| -t).collect();
        let (row, col, out) = (2i64, (2 + n + n - 1) as i64, 40usize);
        let mut a64: Vec<f64> = (0..48).map(|i| ((i * 7) % 11) as f64 - 5.0).collect();
        let want: f64 = (0..n)
            .map(|t| a64[(row + t) as usize] * a64[(col - t) as usize])
            .sum::<f64>()
            + a64[out];
        dot_update(&mut a64, out, row, col, &red_row, &red_col);
        assert_eq!(a64[out], want, "f64 dot");
        let mut a32: Vec<f32> = (0..48).map(|i| ((i * 7) % 11) as f32 - 5.0).collect();
        let want32: f32 = (0..n)
            .map(|t| a32[(row + t) as usize] * a32[(col - t) as usize])
            .sum::<f32>()
            + a32[out];
        dot_update(&mut a32, out, row, col, &red_row, &red_col);
        assert_eq!(a32[out], want32, "f32 dot");
    }
}
