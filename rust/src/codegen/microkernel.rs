//! Register-blocked f64 microkernels — the tile-interior code quality the
//! paper gets from CLooG+gcc, written out by hand.
//!
//! All kernels operate on *packed*, unit-stride panels (built by
//! [`super::pack`] from a [`RunPlan`](super::runplan::RunPlan)) so the
//! inner loops carry no bounds logic and no strided loads:
//!
//! * [`mkernel_full_at`] — an `MR×NRW` register tile (`NRW` a const
//!   generic: 4 for the default shape, 6 for the autotuned wide shape):
//!   `MR·NRW` accumulators held live across the whole k-loop (one store
//!   per output element per tile, instead of one per k step), fed by
//!   `MR + NRW` packed loads per k step. Output columns are addressed by
//!   **per-column base offsets**, so kernels whose output columns are not
//!   uniformly strided (e.g. Kronecker) dispatch the same register tile.
//!   [`mkernel_edge_at`] is the clipped variant for boundary blocks;
//!   packed panels are zero-padded so it can accumulate the full block
//!   and write back only the live `mr×nr` corner.
//! * [`mkernel_full`] / [`mkernel_full_8x6`] / [`mkernel_edge`] — the
//!   uniform-stride wrappers (column stride `cs`), kept for the packed
//!   single-block callers and the startup autotuner
//!   ([`super::autotune`]); they lower onto the `_at` kernels.
//! * [`axpy_block`] — the panel-replay kernel for skewed lattice tiles:
//!   one packed unit-stride run of the row operand updates `NR` output
//!   columns at once, so each packed element is loaded once per `NR`
//!   FMAs.
//!
//! All `get_unchecked` indexing is encapsulated here, behind length
//! asserts at entry — callers hand in plain slices.

/// Microkernel register-tile rows (unit-stride output dimension).
pub const MR: usize = 8;

/// Microkernel register-tile columns of the default shape.
pub const NR: usize = 4;

/// Register-tile columns of the wide autotune candidate. The packed panel
/// layouts are width-specific, so the engine packs with whichever width
/// the startup calibrator ([`super::autotune`]) selected.
pub const NR_WIDE: usize = 6;

/// Full `MR×NRW` register-tiled block over packed panels, with per-column
/// output bases:
///
/// `a[bases[c] + r] += Σ_t bp[t·MR + r] · cp[t·NRW + c]`
///
/// for `r < MR`, `c < NRW`, `t < kc`. `bp` is an MR-row panel of the row
/// operand, `cp` an NRW-column panel of the column operand (layouts per
/// [`super::pack`]); `a` is the whole output arena. Callers guarantee the
/// `NRW` column windows `[bases[c], bases[c] + MR)` are disjoint (true
/// whenever the kernel's output map is injective).
pub fn mkernel_full_at<const NRW: usize>(
    kc: usize,
    bp: &[f64],
    cp: &[f64],
    a: &mut [f64],
    bases: &[usize; NRW],
) {
    assert!(bp.len() >= kc * MR, "B panel too short");
    assert!(cp.len() >= kc * NRW, "C panel too short");
    for &b in bases {
        assert!(b + MR <= a.len(), "output window too small");
    }
    let mut acc = [[0f64; MR]; NRW];
    // SAFETY: the asserts above bound every index used below.
    unsafe {
        for t in 0..kc {
            let b = bp.get_unchecked(t * MR..t * MR + MR);
            let c = cp.get_unchecked(t * NRW..t * NRW + NRW);
            for (jc, accj) in acc.iter_mut().enumerate() {
                let cv = *c.get_unchecked(jc);
                for (r, av) in accj.iter_mut().enumerate() {
                    *av += *b.get_unchecked(r) * cv;
                }
            }
        }
        for (jc, accj) in acc.iter().enumerate() {
            let base = *bases.get_unchecked(jc);
            for (r, &v) in accj.iter().enumerate() {
                *a.get_unchecked_mut(base + r) += v;
            }
        }
    }
}

/// Clipped `mr×nr` boundary block (`mr ≤ MR`, `nr ≤ NRW`) over the same
/// packed panels, with per-column output bases (`bases.len() ≥ nr`). The
/// panels are zero-padded past the live rows/columns, so the accumulation
/// runs the full register tile and only the write-back is clipped.
pub fn mkernel_edge_at<const NRW: usize>(
    mr: usize,
    nr: usize,
    kc: usize,
    bp: &[f64],
    cp: &[f64],
    a: &mut [f64],
    bases: &[usize],
) {
    assert!((1..=MR).contains(&mr) && (1..=NRW).contains(&nr));
    assert!(bp.len() >= kc * MR, "B panel too short");
    assert!(cp.len() >= kc * NRW, "C panel too short");
    assert!(bases.len() >= nr, "missing column bases");
    for &b in &bases[..nr] {
        assert!(b + mr <= a.len(), "output window too small");
    }
    let mut acc = [[0f64; MR]; NRW];
    for t in 0..kc {
        let b = &bp[t * MR..t * MR + MR];
        let c = &cp[t * NRW..t * NRW + NRW];
        for (jc, accj) in acc.iter_mut().enumerate() {
            let cv = c[jc];
            for (r, av) in accj.iter_mut().enumerate() {
                *av += b[r] * cv;
            }
        }
    }
    for (jc, accj) in acc.iter().enumerate().take(nr) {
        let base = bases[jc];
        for (r, &v) in accj.iter().enumerate().take(mr) {
            a[base + r] += v;
        }
    }
}

/// Uniform-stride wrapper: full `MR×NR` register tile with output column
/// stride `cs` — `a[r + cs·c] += Σ_t bp[t·MR + r] · cp[t·NR + c]`, `a`
/// starting at the block's top-left element.
pub fn mkernel_full(kc: usize, bp: &[f64], cp: &[f64], a: &mut [f64], cs: usize) {
    assert!(cs >= MR, "output columns overlap");
    let mut bases = [0usize; NR];
    for (jc, b) in bases.iter_mut().enumerate() {
        *b = jc * cs;
    }
    mkernel_full_at::<NR>(kc, bp, cp, a, &bases);
}

/// Uniform-stride wrapper for the `MR×NR_WIDE` (8×6) register tile —
/// identical contract to [`mkernel_full`] but over `NR_WIDE`-column C
/// panels (`cp[t·NR_WIDE + c]`).
pub fn mkernel_full_8x6(kc: usize, bp: &[f64], cp: &[f64], a: &mut [f64], cs: usize) {
    assert!(cs >= MR, "output columns overlap");
    let mut bases = [0usize; NR_WIDE];
    for (jc, b) in bases.iter_mut().enumerate() {
        *b = jc * cs;
    }
    mkernel_full_at::<NR_WIDE>(kc, bp, cp, a, &bases);
}

/// Uniform-stride wrapper: clipped `mr×nr` boundary block (`mr ≤ MR`,
/// `nr ≤ NR`) with output column stride `cs`.
pub fn mkernel_edge(
    mr: usize,
    nr: usize,
    kc: usize,
    bp: &[f64],
    cp: &[f64],
    a: &mut [f64],
    cs: usize,
) {
    let mut bases = [0usize; NR];
    for (jc, b) in bases.iter_mut().enumerate() {
        *b = jc * cs;
    }
    mkernel_edge_at::<NR>(mr, nr, kc, bp, cp, a, &bases[..nr]);
}

/// Panel-replay kernel: one packed unit-stride run of row-operand values
/// updates up to `NR` output columns at once:
///
/// `a[r + cs·col] += b[r] · c[col]`
///
/// for `r < b.len()`, `col < c.len()` (`c.len() ≤ NR`). `b` is a packed
/// (contiguous) run, `a` the output window at the run's first row of the
/// first column. The NR-wide case is unrolled; narrower boundary blocks
/// take the generic column loop.
pub fn axpy_block(a: &mut [f64], cs: usize, b: &[f64], c: &[f64]) {
    let len = b.len();
    let ncols = c.len();
    assert!((1..=NR).contains(&ncols), "column block of 1..=NR");
    assert!(len <= cs, "run longer than the output column stride");
    assert!(a.len() >= (ncols - 1) * cs + len, "output window too small");
    if ncols == NR {
        let (c0, c1, c2, c3) = (c[0], c[1], c[2], c[3]);
        // SAFETY: the asserts above bound every index used below.
        unsafe {
            for r in 0..len {
                let bv = *b.get_unchecked(r);
                *a.get_unchecked_mut(r) += bv * c0;
                *a.get_unchecked_mut(r + cs) += bv * c1;
                *a.get_unchecked_mut(r + 2 * cs) += bv * c2;
                *a.get_unchecked_mut(r + 3 * cs) += bv * c3;
            }
        }
    } else {
        for (col, &cv) in c.iter().enumerate() {
            let base = col * cs;
            // SAFETY: base + len ≤ (ncols-1)·cs + len ≤ a.len().
            unsafe {
                for r in 0..len {
                    *a.get_unchecked_mut(base + r) += *b.get_unchecked(r) * cv;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(len: usize, seed: u64) -> Vec<f64> {
        let mut rng = crate::testutil::Rng::new(seed);
        (0..len).map(|_| rng.f64_unit() - 0.5).collect()
    }

    #[test]
    fn full_kernel_matches_naive() {
        let kc = 13;
        let bp = fill(kc * MR, 1);
        let cp = fill(kc * NR, 2);
        let cs = MR + 3;
        let mut a = fill((NR - 1) * cs + MR, 3);
        let orig = a.clone();
        mkernel_full(kc, &bp, &cp, &mut a, cs);
        for jc in 0..NR {
            for r in 0..MR {
                let want: f64 = (0..kc).map(|t| bp[t * MR + r] * cp[t * NR + jc]).sum();
                let got = a[jc * cs + r] - orig[jc * cs + r];
                assert!((got - want).abs() < 1e-12, "({r},{jc})");
            }
        }
    }

    #[test]
    fn wide_kernel_matches_naive() {
        let kc = 11;
        let bp = fill(kc * MR, 4);
        let cp = fill(kc * NR_WIDE, 5);
        let cs = MR + 2;
        let mut a = fill((NR_WIDE - 1) * cs + MR, 6);
        let orig = a.clone();
        mkernel_full_8x6(kc, &bp, &cp, &mut a, cs);
        for jc in 0..NR_WIDE {
            for r in 0..MR {
                let want: f64 = (0..kc)
                    .map(|t| bp[t * MR + r] * cp[t * NR_WIDE + jc])
                    .sum();
                let got = a[jc * cs + r] - orig[jc * cs + r];
                assert!((got - want).abs() < 1e-12, "({r},{jc})");
            }
        }
    }

    #[test]
    fn full_at_kernel_scattered_columns() {
        // non-uniform column bases (the Kronecker case): columns placed
        // out of order with uneven gaps
        let kc = 7;
        let bp = fill(kc * MR, 10);
        let cp = fill(kc * NR, 11);
        let bases = [40usize, 0, 96, 16];
        let mut a = fill(128, 12);
        let orig = a.clone();
        mkernel_full_at::<NR>(kc, &bp, &cp, &mut a, &bases);
        for (jc, &base) in bases.iter().enumerate() {
            for r in 0..MR {
                let want: f64 = (0..kc).map(|t| bp[t * MR + r] * cp[t * NR + jc]).sum();
                let got = a[base + r] - orig[base + r];
                assert!((got - want).abs() < 1e-12, "({r},{jc})");
            }
        }
        // untouched elements stay untouched
        let touched: std::collections::HashSet<usize> = bases
            .iter()
            .flat_map(|&b| (b..b + MR).collect::<Vec<_>>())
            .collect();
        for (i, (&x, &o)) in a.iter().zip(&orig).enumerate() {
            if !touched.contains(&i) {
                assert_eq!(x, o, "element {i} written");
            }
        }
    }

    #[test]
    fn edge_kernel_writes_only_live_corner() {
        let kc = 5;
        let (mr, nr) = (3usize, 2usize);
        // zero-pad the dead rows/cols as the packer does
        let mut bp = vec![0f64; kc * MR];
        let mut cp = vec![0f64; kc * NR];
        for t in 0..kc {
            for r in 0..mr {
                bp[t * MR + r] = (t * MR + r) as f64 * 0.25 - 1.0;
            }
            for c in 0..nr {
                cp[t * NR + c] = (t * NR + c) as f64 * 0.5 - 2.0;
            }
        }
        let cs = MR;
        let mut a = vec![7.0; (NR - 1) * cs + MR];
        let sentinel = a.clone();
        mkernel_edge(mr, nr, kc, &bp, &cp, &mut a, cs);
        for jc in 0..NR {
            for r in 0..MR {
                let idx = jc * cs + r;
                if r < mr && jc < nr {
                    let want: f64 =
                        (0..kc).map(|t| bp[t * MR + r] * cp[t * NR + jc]).sum();
                    assert!((a[idx] - 7.0 - want).abs() < 1e-12, "({r},{jc})");
                } else {
                    assert_eq!(a[idx], sentinel[idx], "dead element ({r},{jc}) written");
                }
            }
        }
    }

    #[test]
    fn edge_at_wide_panel_clips() {
        // NR_WIDE panel, clipped write-back at scattered bases
        let kc = 4;
        let (mr, nr) = (5usize, 3usize);
        let mut bp = vec![0f64; kc * MR];
        let mut cp = vec![0f64; kc * NR_WIDE];
        for t in 0..kc {
            for r in 0..mr {
                bp[t * MR + r] = (t + r) as f64 - 2.0;
            }
            for c in 0..nr {
                cp[t * NR_WIDE + c] = (t * 2 + c) as f64 * 0.5;
            }
        }
        let bases = [20usize, 0, 40];
        let mut a = vec![1.0f64; 64];
        let sentinel = a.clone();
        mkernel_edge_at::<NR_WIDE>(mr, nr, kc, &bp, &cp, &mut a, &bases);
        for (jc, &base) in bases.iter().enumerate() {
            for r in 0..mr {
                let want: f64 = (0..kc)
                    .map(|t| bp[t * MR + r] * cp[t * NR_WIDE + jc])
                    .sum();
                assert!((a[base + r] - 1.0 - want).abs() < 1e-12, "({r},{jc})");
            }
            for r in mr..MR {
                assert_eq!(a[base + r], sentinel[base + r]);
            }
        }
    }

    #[test]
    fn axpy_block_all_widths() {
        let len = 11;
        let cs = 16;
        let b = fill(len, 9);
        for ncols in 1..=NR {
            let c = fill(ncols, 10);
            let mut a = fill((ncols - 1) * cs + len, 11);
            let orig = a.clone();
            axpy_block(&mut a, cs, &b, &c);
            for (col, &cv) in c.iter().enumerate() {
                for r in 0..len {
                    let want = orig[col * cs + r] + b[r] * cv;
                    assert!((a[col * cs + r] - want).abs() < 1e-12, "ncols={ncols}");
                }
            }
        }
    }
}
