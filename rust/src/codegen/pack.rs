//! Operand packing for the microkernel execution engine — kernel-neutral
//! *and* element-generic: every packer consumes a [`RunPlan`]
//! (unit-stride runs + column / reduction offset tables) instead of a
//! hardcoded matmul geometry, and packs `T: Scalar` panels (f32 panels
//! are twice as wide per [`Scalar::NR`]).
//!
//! Panel layouts (identical for every kernel and dtype, parameterized by
//! the dispatched register geometry):
//!
//! * **row panels** — [`RunPlan::row_panels_mr`] chops the plan's runs
//!   into panels of up to `mr` consecutive rows (`mr` = the geometry's
//!   row class, [`MR`] = 8 or [`MR_TALL`] = 16); panel `p` stores element
//!   `(t, r)` (reduction step `t`, row `r`) at `p·kc·mr + t·mr + r`, so
//!   each k step of the microkernel reads one contiguous `mr`-vector.
//!   Because panels never straddle run boundaries, every copy is a
//!   unit-stride `memcpy` from the arena.
//! * **column panels** — `⌈nc/NRW⌉` panels of `NRW` consecutive columns
//!   (`NRW` = the dtype-resolved column count of the geometry); panel `q`
//!   stores `(t, c)` at `q·kc·NRW + t·NRW + c`, gathered through the
//!   plan's `col_in` / `red_col` tables (which is how convolution's
//!   reversed operand packs into a forward-streaming panel).
//!
//! [`dispatch_block`] is the engine's one geometry-dispatch point: the
//! runtime `(mr, acc64)` pair — panel height recorded on the packed
//! buffers, wide-accumulation flag from the execution's
//! [`Precision`](super::scalar::Precision) — selects the const
//! `(MRH, A)` microkernel instantiation, so every executor above it
//! threads plain runtime values and only this match names the const
//! arms.
//!
//! Rows past a panel's live count / columns past `nc` are zero-filled so
//! boundary blocks can run the full register tile and clip only the
//! write-back ([`super::microkernel::mkernel_edge_at`]).
//!
//! The packing cost is `O(m·kc + kc·nc)` per block against `O(m·kc·nc)`
//! microkernel work, i.e. amortized across the reduction loop exactly as
//! in a blocked BLAS. Buffers are reused across tiles (and are
//! thread-local in the parallel executor) so steady-state packing
//! performs no allocation.
//!
//! Two granularities:
//!
//! * [`PackBuffers`] — per-tile packer for the single-level engine and
//!   the parallel per-tile path; its block cache keys carry the source
//!   identity *and* the element size so reuse across arenas or dtypes can
//!   never replay stale panels.
//! * [`PackedRows`] / [`PackedCols`] — macro-kernel granularity:
//!   [`PackedRows`] holds the `mc`-row blocks of one reduction slice of
//!   a caller-chosen row range ([`PackedRows::pack_slice_range`] — an
//!   L3 super-band's rows in the three-level schedule; both buffers are
//!   thread-local in the parallel path, so packed panels stay on the
//!   worker that streams them), [`PackedCols`] one `kc×nc` column band,
//!   and [`run_macro_block`] drives the register-tiled micro-engine
//!   over all L1 tiles of one macro block straight from those panels —
//!   each operand block is packed exactly once per macro block.

use super::microkernel::{mkernel_edge_at, mkernel_full_at, MR, MR_TALL};
use super::runplan::{RowPanel, RunPlan};
use super::scalar::{Accum, Scalar};

/// Pack a list of row panels into `buf` (layout `p·kc·mr + t·mr + r`,
/// zero-padded): the one copy loop shared by the per-tile and macro
/// packers. `mr` is the panel height the panels were decomposed at
/// ([`RunPlan::row_panels_mr`]) — every `p.rows ≤ mr`.
fn pack_row_panels<T: Scalar>(
    buf: &mut Vec<T>,
    arena: &[T],
    panels: &[RowPanel],
    red_row: &[i64],
    mr: usize,
) {
    let kc = red_row.len();
    buf.clear();
    buf.resize(panels.len() * kc * mr, T::ZERO);
    for (pi, p) in panels.iter().enumerate() {
        debug_assert!(p.rows <= mr, "panel taller than its height class");
        let base = pi * kc * mr;
        for (t, &rr) in red_row.iter().enumerate() {
            let src = (p.row + rr) as usize;
            let dst = base + t * mr;
            buf[dst..dst + p.rows].copy_from_slice(&arena[src..src + p.rows]);
        }
    }
}

/// Pack one column band `[j0, j0+nc)` into NRW panels (layout
/// `q·kc·NRW + t·NRW + c`, zero-padded), gathering through the plan's
/// offset tables.
fn pack_col_panels<T: Scalar, const NRW: usize>(
    buf: &mut Vec<T>,
    arena: &[T],
    plan: &RunPlan,
    k0: usize,
    kc: usize,
    j0: usize,
    nc: usize,
) {
    let panels = nc.div_ceil(NRW);
    buf.clear();
    buf.resize(panels * kc * NRW, T::ZERO);
    for q in 0..panels {
        let cols = NRW.min(nc - q * NRW);
        let base = q * kc * NRW;
        for c in 0..cols {
            let ci = plan.col_in[j0 + q * NRW + c];
            for t in 0..kc {
                buf[base + t * NRW + c] = arena[(ci + plan.red_col[k0 + t]) as usize];
            }
        }
    }
}

/// Dispatch all `(column panel, row panel)` register blocks of one packed
/// block against the arena, `tj`/`ti`-grouped so the column micro-panel
/// of an L1 tile is reused L1-resident across the tile's row panels.
///
/// The engine's single geometry-dispatch point: the runtime `(mr, acc64)`
/// pair selects the const `(MRH, A)` microkernel instantiation — `mr` is
/// the panel height the rows were packed at, `acc64` the
/// wide-accumulation flag of the execution's precision
/// ([`Precision::wide_acc`](super::scalar::Precision::wide_acc); the
/// identity accumulator at f64 storage, so `acc64` is a no-op there).
///
/// `col_out` is the output-offset table of the band's columns (length ≥
/// `nc`); `panels[pi]`'s data lives at `rows_buf[pi·kc·mr ..]`.
#[allow(clippy::too_many_arguments)]
fn dispatch_block<T: Scalar, const NRW: usize>(
    arena: &mut [T],
    rows_buf: &[T],
    panels: &[RowPanel],
    cols_buf: &[T],
    nc: usize,
    kc: usize,
    (ti, tj): (usize, usize),
    col_out: &[i64],
    mr: usize,
    acc64: bool,
) {
    match (mr, acc64) {
        (MR, false) => dispatch_block_impl::<T, T, MR, NRW>(
            arena, rows_buf, panels, cols_buf, nc, kc, (ti, tj), col_out,
        ),
        (MR_TALL, false) => dispatch_block_impl::<T, T, MR_TALL, NRW>(
            arena, rows_buf, panels, cols_buf, nc, kc, (ti, tj), col_out,
        ),
        (MR, true) => dispatch_block_impl::<T, T::Acc, MR, NRW>(
            arena, rows_buf, panels, cols_buf, nc, kc, (ti, tj), col_out,
        ),
        (MR_TALL, true) => dispatch_block_impl::<T, T::Acc, MR_TALL, NRW>(
            arena, rows_buf, panels, cols_buf, nc, kc, (ti, tj), col_out,
        ),
        (other, _) => unreachable!("no register-tile arm at panel height {other}"),
    }
}

#[allow(clippy::too_many_arguments)]
fn dispatch_block_impl<T: Scalar, A: Accum<T>, const MRH: usize, const NRW: usize>(
    arena: &mut [T],
    rows_buf: &[T],
    panels: &[RowPanel],
    cols_buf: &[T],
    nc: usize,
    kc: usize,
    (ti, tj): (usize, usize),
    col_out: &[i64],
) {
    if panels.is_empty() || nc == 0 || kc == 0 {
        return;
    }
    let cpanels = nc.div_ceil(NRW);
    debug_assert!(rows_buf.len() >= panels.len() * kc * MRH);
    debug_assert!(cols_buf.len() >= cpanels * kc * NRW);
    // L1 tile extents in panel units
    let pt = ti.div_ceil(MRH).max(1);
    let qt = tj.div_ceil(NRW).max(1);
    for q0 in (0..cpanels).step_by(qt) {
        let q_hi = cpanels.min(q0 + qt);
        for p0 in (0..panels.len()).step_by(pt) {
            let p_hi = panels.len().min(p0 + pt);
            for q in q0..q_hi {
                let nr = NRW.min(nc - q * NRW);
                let cpq = &cols_buf[q * kc * NRW..(q + 1) * kc * NRW];
                for (pi, p) in panels.iter().enumerate().take(p_hi).skip(p0) {
                    let bp = &rows_buf[pi * kc * MRH..(pi + 1) * kc * MRH];
                    let mut bases = [0usize; NRW];
                    for (jc, b) in bases.iter_mut().enumerate().take(nr) {
                        let o = p.out + col_out[q * NRW + jc];
                        debug_assert!(o >= 0);
                        *b = o as usize;
                    }
                    if p.rows == MRH && nr == NRW {
                        mkernel_full_at::<T, A, MRH, NRW>(kc, bp, cpq, arena, &bases);
                    } else {
                        mkernel_edge_at::<T, A, MRH, NRW>(
                            p.rows,
                            nr,
                            kc,
                            bp,
                            cpq,
                            arena,
                            &bases[..nr],
                        );
                    }
                }
            }
        }
    }
}

/// Cache key of a packed block: source identity (arena pointer + element
/// size) + the caller-supplied box coordinates. The source identity
/// guards against replaying stale panels when one `PackBuffers` is reused
/// across kernels, arenas or dtypes whose box coordinates happen to
/// coincide.
type PackKey = (usize, usize, Vec<i64>);

/// Reusable per-tile pack buffers + the plan geometry of the tile they
/// currently hold.
///
/// The `pack_*_cached` packers skip the copy when the requested box is
/// the one already packed — keyed by source identity *and* box
/// coordinates (see [`PackKey`]) — valid while the source operand bytes
/// are unchanged, which holds for the executors: inputs are read-only
/// during a run. Callers that mutate the source between runs must call
/// [`PackBuffers::invalidate`] first.
#[derive(Clone, Debug)]
pub struct PackBuffers<T: Scalar = f64> {
    rows_buf: Vec<T>,
    panels: Vec<RowPanel>,
    cols_buf: Vec<T>,
    kc_rows: usize,
    kc_cols: usize,
    nc: usize,
    nrw: usize,
    mr: usize,
    row_key: Option<PackKey>,
    col_key: Option<PackKey>,
}

impl<T: Scalar> Default for PackBuffers<T> {
    fn default() -> PackBuffers<T> {
        PackBuffers {
            rows_buf: Vec::new(),
            panels: Vec::new(),
            cols_buf: Vec::new(),
            kc_rows: 0,
            kc_cols: 0,
            nc: 0,
            nrw: 0,
            mr: MR,
            row_key: None,
            col_key: None,
        }
    }
}

impl<T: Scalar> PackBuffers<T> {
    pub fn new() -> PackBuffers<T> {
        PackBuffers::default()
    }

    /// Forget the cached box keys, forcing the next `*_cached` call to
    /// repack. Call at run entry whenever the source bytes may have
    /// changed since the buffers were last used.
    pub fn invalidate(&mut self) {
        self.row_key = None;
        self.col_key = None;
    }

    /// Set the row-panel height for subsequent packs (the dispatched
    /// geometry's `micro.mr()`; [`MR`] by default). A height change
    /// invalidates the cached row panels.
    pub fn set_mr(&mut self, mr: usize) {
        if self.mr != mr {
            self.mr = mr;
            self.row_key = None;
        }
    }

    /// Pack all rows × reduction steps of `plan` into mr-row panels.
    /// `key` identifies the packed row/reduction sub-box (cache tag); the
    /// plan's own operand offsets are folded in, so reusing one
    /// `PackBuffers` across kernels or operand layouts whose box
    /// coordinates coincide can never replay stale panels (the PR 2
    /// regression, generalized). The panel height is folded in too, so a
    /// geometry switch can never replay panels of the other height.
    pub fn pack_rows_cached(&mut self, arena: &[T], plan: &RunPlan, mut key: Vec<i64>) {
        key.extend([
            plan.m as i64,
            plan.k as i64,
            self.mr as i64,
            plan.runs.first().map_or(-1, |r| r.row),
            plan.runs.first().map_or(-1, |r| r.out),
            plan.red_row.first().copied().unwrap_or(-1),
            plan.red_row.last().copied().unwrap_or(-1),
        ]);
        let full = (arena.as_ptr() as usize, T::ELEM, key);
        if self.row_key.as_ref() == Some(&full) {
            return;
        }
        self.panels = plan.row_panels_mr(0, plan.m, self.mr);
        pack_row_panels(&mut self.rows_buf, arena, &self.panels, &plan.red_row, self.mr);
        self.kc_rows = plan.k;
        self.row_key = Some(full);
    }

    /// Pack all columns × reduction steps of `plan` into NRW panels (same
    /// source-identity key discipline as [`PackBuffers::pack_rows_cached`]).
    pub fn pack_cols_cached<const NRW: usize>(
        &mut self,
        arena: &[T],
        plan: &RunPlan,
        mut key: Vec<i64>,
    ) {
        key.extend([
            plan.n as i64,
            plan.k as i64,
            plan.col_in.first().copied().unwrap_or(-1),
            plan.col_out.first().copied().unwrap_or(-1),
            plan.red_col.first().copied().unwrap_or(-1),
            plan.red_col.last().copied().unwrap_or(-1),
        ]);
        let full = (arena.as_ptr() as usize, T::ELEM, key);
        if self.nrw == NRW && self.col_key.as_ref() == Some(&full) {
            return;
        }
        pack_col_panels::<T, NRW>(&mut self.cols_buf, arena, plan, 0, plan.k, 0, plan.n);
        self.kc_cols = plan.k;
        self.nc = plan.n;
        self.nrw = NRW;
        self.col_key = Some(full);
    }

    /// Run the packed box: dispatch every register block of the packed
    /// panels against the arena, at storage precision.
    pub fn run_box<const NRW: usize>(&self, arena: &mut [T], plan: &RunPlan) {
        self.run_box_acc::<NRW>(arena, plan, false);
    }

    /// [`PackBuffers::run_box`] with the wide-accumulation flag (the
    /// `f32acc64` per-tile path).
    pub fn run_box_acc<const NRW: usize>(&self, arena: &mut [T], plan: &RunPlan, acc64: bool) {
        assert_eq!(
            self.kc_rows, self.kc_cols,
            "rows and columns packed with different reduction depths"
        );
        assert_eq!(self.nrw, NRW, "column panels packed with a different width");
        dispatch_block::<T, NRW>(
            arena,
            &self.rows_buf,
            &self.panels,
            &self.cols_buf,
            self.nc,
            self.kc_rows,
            (self.panels.len() * self.mr, self.nc), // per-tile engine: one L1 tile
            &plan.col_out,
            self.mr,
            acc64,
        );
    }

    /// The packed row panels (tests).
    pub fn row_panel_data(&self) -> (&[RowPanel], &[T]) {
        (&self.panels, &self.rows_buf)
    }

    /// The packed column panels (tests).
    pub fn col_panel_data(&self) -> &[T] {
        &self.cols_buf
    }
}

/// The `mc`-row blocks of one reduction slice of a row range, packed
/// once into the microkernel panel layout. In the parallel macro-kernel
/// each worker owns one of these and packs its claimed super-band's row
/// range into it ([`PackedRows::pack_slice_range`]) — packed panels are
/// never shared across threads.
///
/// Block `bi` covers the `bi`-th `mc`-row chunk of the packed range
/// (clipped at the range end); its panels never straddle run boundaries,
/// so blocks of kernels with segmented rows (Kronecker) simply carry
/// more, shorter panels.
#[derive(Clone, Debug)]
pub struct PackedRows<T: Scalar = f64> {
    buf: Vec<T>,
    panels: Vec<RowPanel>,
    /// Per block: (first panel index, panel count).
    blocks: Vec<(usize, usize)>,
    kc: usize,
    mr: usize,
    packs: u64,
}

impl<T: Scalar> Default for PackedRows<T> {
    fn default() -> PackedRows<T> {
        PackedRows {
            buf: Vec::new(),
            panels: Vec::new(),
            blocks: Vec::new(),
            kc: 0,
            mr: MR,
            packs: 0,
        }
    }
}

/// Read-only view of one packed row block: `panels[i]`'s data lives at
/// `data[i·kc·mr .. (i+1)·kc·mr]`, `mr` being the panel height the block
/// was packed at.
#[derive(Clone, Copy, Debug)]
pub struct PackedBlock<'a, T: Scalar = f64> {
    pub panels: &'a [RowPanel],
    pub data: &'a [T],
    pub kc: usize,
    pub mr: usize,
}

impl<T: Scalar> PackedRows<T> {
    pub fn new() -> PackedRows<T> {
        PackedRows::default()
    }

    /// Set the row-panel height for subsequent packs (the dispatched
    /// geometry's `micro.mr()`; [`MR`] by default). Takes effect at the
    /// next `pack_slice*` call — blocks already packed keep the height
    /// they were packed at until then.
    pub fn set_mr(&mut self, mr: usize) {
        self.mr = mr;
    }

    /// The panel height of the packed blocks.
    pub fn mr(&self) -> usize {
        self.mr
    }

    /// Pack every `mc`-row block of the plan's rows at reduction slice
    /// `[k0, k0+kc)`.
    pub fn pack_slice(&mut self, arena: &[T], plan: &RunPlan, mc: usize, k0: usize, kc: usize) {
        self.pack_slice_range(arena, plan, mc, 0, plan.m, k0, kc);
    }

    /// Pack the `mc`-row blocks of plan rows `[r0, r0+rows)` at reduction
    /// slice `[k0, k0+kc)` — the super-band entry point: each parallel
    /// worker (and each serial super-band) packs only its own row range,
    /// so the packed panels stay local to the worker that streams them.
    #[allow(clippy::too_many_arguments)]
    pub fn pack_slice_range(
        &mut self,
        arena: &[T],
        plan: &RunPlan,
        mc: usize,
        r0: usize,
        rows: usize,
        k0: usize,
        kc: usize,
    ) {
        assert!(kc >= 1 && k0 + kc <= plan.k);
        assert!(r0 + rows <= plan.m);
        let mc = mc.clamp(1, rows.max(1));
        self.kc = kc;
        self.panels.clear();
        self.blocks.clear();
        let red_row = &plan.red_row[k0..k0 + kc];
        let r1 = r0 + rows;
        let mut r = r0;
        while r < r1 {
            let mcc = mc.min(r1 - r);
            let start = self.panels.len();
            self.panels.extend(plan.row_panels_mr(r, mcc, self.mr));
            self.blocks.push((start, self.panels.len() - start));
            self.packs += 1;
            r += mcc;
        }
        pack_row_panels(&mut self.buf, arena, &self.panels, red_row, self.mr);
    }

    /// Number of row blocks in the packed slice.
    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Panel view of block `bi`.
    pub fn block(&self, bi: usize) -> PackedBlock<'_, T> {
        let (start, count) = self.blocks[bi];
        PackedBlock {
            panels: &self.panels[start..start + count],
            data: &self.buf
                [start * self.kc * self.mr..(start + count) * self.kc * self.mr],
            kc: self.kc,
            mr: self.mr,
        }
    }

    /// The packed reduction depth of the current slice.
    pub fn kc(&self) -> usize {
        self.kc
    }

    /// How many row blocks have been packed over this buffer's lifetime
    /// (each macro block counts once — the pack-amortization invariant
    /// the tests pin).
    pub fn pack_count(&self) -> u64 {
        self.packs
    }
}

/// One `kc×nc` column-operand band packed into NRW-column panels — the
/// macro-kernel's thread-local counterpart of [`PackedRows`] (each thread
/// owns the band of its output column range).
#[derive(Clone, Debug, Default)]
pub struct PackedCols<T: Scalar = f64> {
    buf: Vec<T>,
    kc: usize,
    nc: usize,
    packs: u64,
}

impl<T: Scalar> PackedCols<T> {
    pub fn new() -> PackedCols<T> {
        PackedCols::default()
    }

    /// Pack columns `[j0, j0+nc)` at reduction slice `[k0, k0+kc)`.
    pub fn pack_band<const NRW: usize>(
        &mut self,
        arena: &[T],
        plan: &RunPlan,
        k0: usize,
        kc: usize,
        j0: usize,
        nc: usize,
    ) {
        assert!(nc >= 1 && kc >= 1);
        assert!(j0 + nc <= plan.n && k0 + kc <= plan.k);
        self.kc = kc;
        self.nc = nc;
        pack_col_panels::<T, NRW>(&mut self.buf, arena, plan, k0, kc, j0, nc);
        self.packs += 1;
    }

    /// The packed NRW-column panels.
    pub fn panels(&self) -> &[T] {
        &self.buf
    }

    /// `(kc, nc)` of the currently packed band.
    pub fn shape(&self) -> (usize, usize) {
        (self.kc, self.nc)
    }

    /// How many bands have been packed over this buffer's lifetime.
    pub fn pack_count(&self) -> u64 {
        self.packs
    }
}

/// Identity of the panels a [`PackStage`] currently holds: the reduction
/// slice `[k0, k0+kcc)`, plan row range `[r0, r0+rows)` and output
/// column range `[j3, j3+n3c)` they were packed for, plus the resident
/// slice index `si` (prepacked nests; 0 otherwise). The pipelined
/// scheduler rotates two stages per worker between the pack-ahead and
/// compute roles; the compute side asserts the key of the stage it is
/// about to stream equals the schedule step it expects, so a rotated
/// buffer set can never replay a stale stage's panels (the macro-level
/// analogue of [`PackBuffers`]' source-identity cache keys).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StageKey {
    /// First reduction step of the stage's `kc` slice.
    pub k0: usize,
    /// Clipped depth of the slice.
    pub kcc: usize,
    /// First plan row of the packed row range.
    pub r0: usize,
    /// Row count of the packed row range.
    pub rows: usize,
    /// First output column of the stage's column bands.
    pub j3: usize,
    /// Column count covered by the stage's bands.
    pub n3c: usize,
    /// Resident row-slice index (`k0 / kc`) for prepacked nests.
    pub si: usize,
}

/// One software-pipeline stage's packed operands: the row slice and all
/// `nc` column bands of one `kc` step of one super-band, owned as a unit
/// so the pack-ahead path can fill stage `k0+kc` while the microkernel
/// streams stage `k0`. Each pipelined worker owns **two** of these and
/// rotates them between the roles; buffers are reused across stages and
/// bands, so steady-state packing performs no allocation. A stage is
/// inert (no key) until a pack fills it, and invalidated before refill —
/// see [`StageKey`] for the replay guard.
#[derive(Clone, Debug, Default)]
pub struct PackStage<T: Scalar = f64> {
    /// The stage's row slice (unused when the nest reads resident rows).
    pub(crate) rows: PackedRows<T>,
    /// One packed band per `nc` column band of the stage (reused slots;
    /// only the first `bands.len()` are live).
    pub(crate) cols: Vec<PackedCols<T>>,
    /// `(j0, ncc)` of each live column band.
    pub(crate) bands: Vec<(usize, usize)>,
    key: Option<StageKey>,
}

impl<T: Scalar> PackStage<T> {
    pub fn new() -> PackStage<T> {
        PackStage::default()
    }

    /// The key of the currently packed stage, `None` while inert.
    pub fn key(&self) -> Option<&StageKey> {
        self.key.as_ref()
    }

    /// Drop the stage identity (entering a refill).
    pub(crate) fn invalidate(&mut self) {
        self.key = None;
        self.bands.clear();
    }

    /// Stamp the stage as holding `key`'s panels (leaving a refill).
    pub(crate) fn set_key(&mut self, key: StageKey) {
        self.key = Some(key);
    }

    /// Total packs performed through this stage's buffers over its
    /// lifetime: (`mc`-row block packs — [`PackedRows::pack_count`]'s
    /// granularity — and column-band packs).
    pub fn pack_counts(&self) -> (u64, u64) {
        (
            self.rows.pack_count(),
            self.cols.iter().map(|c| c.pack_count()).sum(),
        )
    }
}

/// Drive the `mr×NRW` micro-engine over all L1 tiles of one macro block,
/// straight from packed panels: `block` is one [`PackedRows`] block,
/// `cols` one [`PackedCols`] band of `nc` live columns starting at plan
/// column `j0`, both `kc` deep. `(ti, tj)` is the L1 tile footprint in
/// GEMM row/column units — rounded up to `mr`/`NRW` panel multiples so L1
/// tiles partition the register-block grid.
///
/// The loop nest is `column-tile → row-tile → q → p`: the column
/// micro-panel of an L1 tile (`kc×NRW`, L1-resident) is reused across all
/// of the tile's row panels, while the row block streams from the
/// outer-level cache — no packing happens here at all.
#[allow(clippy::too_many_arguments)]
pub fn run_macro_block<T: Scalar, const NRW: usize>(
    block: PackedBlock<'_, T>,
    cols: &PackedCols<T>,
    plan: &RunPlan,
    j0: usize,
    (ti, tj): (usize, usize),
    arena: &mut [T],
) {
    run_macro_block_acc::<T, NRW>(block, cols, plan, j0, (ti, tj), arena, false);
}

/// [`run_macro_block`] with the wide-accumulation flag: `acc64` selects
/// the widened-accumulator kernel arms (the `f32acc64` macro path; a
/// no-op at f64 storage, whose accumulator is already f64).
#[allow(clippy::too_many_arguments)]
pub fn run_macro_block_acc<T: Scalar, const NRW: usize>(
    block: PackedBlock<'_, T>,
    cols: &PackedCols<T>,
    plan: &RunPlan,
    j0: usize,
    (ti, tj): (usize, usize),
    arena: &mut [T],
    acc64: bool,
) {
    let (kc, nc) = cols.shape();
    assert_eq!(block.kc, kc, "row and column panels differ in depth");
    dispatch_block::<T, NRW>(
        arena,
        block.data,
        block.panels,
        &cols.buf,
        nc,
        kc,
        (ti, tj),
        &plan.col_out[j0..j0 + nc],
        block.mr,
        acc64,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::runplan::{kernel_views, GemmForm, KernelBuffers};
    use crate::domain::ops;

    fn matmul_plan(
        m: i64,
        k: i64,
        n: i64,
    ) -> (crate::domain::Kernel, KernelBuffers, RunPlan) {
        let kernel = ops::matmul_padded(m, k, n, m + 2, m + 1, k + 3, 8, 0);
        let bufs = KernelBuffers::<f64>::from_kernel(&kernel);
        let gf = GemmForm::of(&kernel).unwrap();
        let plan = gf.plan_box(&kernel_views(&kernel), &[0, 0, 0], kernel.extents());
        (kernel, bufs, plan)
    }

    #[test]
    fn row_panels_pack_layout_and_zero_fill() {
        let (_, bufs, plan) = matmul_plan(11, 5, 3);
        let mut packs = PackBuffers::<f64>::new();
        packs.pack_rows_cached(&bufs.arena, &plan, vec![0]);
        let (panels, buf) = packs.row_panel_data();
        assert_eq!(panels.len(), 11usize.div_ceil(MR));
        assert_eq!(buf.len(), panels.len() * plan.k * MR);
        for (pi, p) in panels.iter().enumerate() {
            for t in 0..plan.k {
                for r in 0..MR {
                    let got = buf[pi * plan.k * MR + t * MR + r];
                    if r < p.rows {
                        let src = (p.row + plan.red_row[t]) as usize + r;
                        assert_eq!(got, bufs.arena[src]);
                    } else {
                        assert_eq!(got, 0.0, "padding must be zero");
                    }
                }
            }
        }
    }

    #[test]
    fn col_panels_pack_layout_and_zero_fill() {
        use crate::codegen::microkernel::NR;
        let (_, bufs, plan) = matmul_plan(6, 5, 7);
        let mut packs = PackBuffers::<f64>::new();
        packs.pack_cols_cached::<NR>(&bufs.arena, &plan, vec![0]);
        let buf = packs.col_panel_data();
        let panels = plan.n.div_ceil(NR);
        assert_eq!(buf.len(), panels * plan.k * NR);
        for q in 0..panels {
            for t in 0..plan.k {
                for c in 0..NR {
                    let got = buf[q * plan.k * NR + t * NR + c];
                    if q * NR + c < plan.n {
                        let src =
                            (plan.col_in[q * NR + c] + plan.red_col[t]) as usize;
                        assert_eq!(got, bufs.arena[src]);
                    } else {
                        assert_eq!(got, 0.0, "padding must be zero");
                    }
                }
            }
        }
    }

    #[test]
    fn packed_box_matches_scalar_oracle() {
        use crate::codegen::microkernel::NR;
        // whole-domain "tile", non-multiple extents, padded lda
        for (m, k, n) in [(1i64, 1i64, 1i64), (7, 5, 3), (17, 9, 13), (8, 8, 4)] {
            let (_, mut bufs, plan) = matmul_plan(m, k, n);
            let want = bufs.reference();
            let mut packs = PackBuffers::<f64>::new();
            packs.pack_rows_cached(&bufs.arena, &plan, vec![0]);
            packs.pack_cols_cached::<NR>(&bufs.arena, &plan, vec![0]);
            packs.run_box::<NR>(&mut bufs.arena, &plan);
            let got = bufs.output();
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-12, "({m},{k},{n}) flat {i}");
            }
        }
    }

    #[test]
    fn f32_packed_box_matches_scalar_oracle() {
        // the same engine at half the element size: f32 kernel, f32
        // buffers, f32's narrow (8-wide) panels — exact with integer fills
        const W: usize = 8;
        let kernel = ops::matmul_padded(13, 6, 9, 15, 14, 9, 4, 0);
        let mut bufs = KernelBuffers::<f32>::from_kernel(&kernel);
        bufs.fill_ints(3, 0xF32);
        let gf = GemmForm::of(&kernel).unwrap();
        let plan = gf.plan_box(&kernel_views(&kernel), &[0, 0, 0], kernel.extents());
        let want = bufs.reference();
        let mut packs = PackBuffers::<f32>::new();
        packs.pack_rows_cached(&bufs.arena, &plan, vec![0]);
        packs.pack_cols_cached::<W>(&bufs.arena, &plan, vec![0]);
        packs.run_box::<W>(&mut bufs.arena, &plan);
        assert_eq!(bufs.output(), want, "f32 packed box differs bitwise");
    }

    #[test]
    fn cached_pack_keys_include_source_identity() {
        use crate::codegen::microkernel::NR;
        // regression: same box coordinates, different arena — a
        // coordinates-only key would replay stale panels here
        let (_, bufs, plan) = matmul_plan(8, 4, 4);
        let mut other = bufs.clone();
        for v in other.arena.iter_mut() {
            *v += 1.0;
        }
        let mut packs = PackBuffers::<f64>::new();
        packs.pack_rows_cached(&bufs.arena, &plan, vec![7, 7, 7]);
        let first = packs.row_panel_data().1[0];
        packs.pack_rows_cached(&other.arena, &plan, vec![7, 7, 7]);
        assert_eq!(
            packs.row_panel_data().1[0],
            first + 1.0,
            "stale row panel replayed across arenas"
        );
        packs.pack_cols_cached::<NR>(&bufs.arena, &plan, vec![1, 2]);
        let c_first = packs.col_panel_data()[0];
        packs.pack_cols_cached::<NR>(&other.arena, &plan, vec![1, 2]);
        assert_eq!(
            packs.col_panel_data()[0],
            c_first + 1.0,
            "stale column panel replayed across arenas"
        );
        // same arena, same caller key, different *operand* (shifted plan
        // offsets — the generalization of PR 2's off/ld regression): the
        // plan fingerprint folded into the key must force a repack
        let mut shifted = plan.clone();
        for r in shifted.runs.iter_mut() {
            r.row += 1;
        }
        packs.invalidate();
        packs.pack_rows_cached(&bufs.arena, &plan, vec![7, 7, 7]);
        let v_plain = packs.row_panel_data().1[0];
        packs.pack_rows_cached(&bufs.arena, &shifted, vec![7, 7, 7]);
        assert_eq!(
            packs.row_panel_data().1[0],
            bufs.arena[(shifted.runs[0].row + plan.red_row[0]) as usize],
            "stale row panel replayed across operands in one arena"
        );
        let _ = v_plain;
        let mut shifted_cols = plan.clone();
        for c in shifted_cols.col_in.iter_mut() {
            *c += 1;
        }
        packs.pack_cols_cached::<NR>(&bufs.arena, &plan, vec![5]);
        let c_plain = packs.col_panel_data()[0];
        packs.pack_cols_cached::<NR>(&bufs.arena, &shifted_cols, vec![5]);
        assert_eq!(
            packs.col_panel_data()[0],
            bufs.arena[(shifted_cols.col_in[0] + plan.red_col[0]) as usize],
            "stale column panel replayed across operands in one arena"
        );
        let _ = c_plain;
        // same arena, same key: cached (values unchanged after mutation)…
        let mut src = bufs.clone();
        packs.invalidate();
        packs.pack_rows_cached(&src.arena, &plan, vec![3]);
        let v0 = packs.row_panel_data().1[0];
        src.arena[(plan.runs[0].row + plan.red_row[0]) as usize] = v0 + 9.0;
        packs.pack_rows_cached(&src.arena, &plan, vec![3]);
        assert_eq!(packs.row_panel_data().1[0], v0);
        // …until the caller invalidates
        packs.invalidate();
        packs.pack_rows_cached(&src.arena, &plan, vec![3]);
        assert_eq!(packs.row_panel_data().1[0], v0 + 9.0);
    }

    #[test]
    fn packed_rows_slice_blocks_and_counts() {
        let (_, bufs, plan) = matmul_plan(21, 6, 4);
        let (mc, k0, kc) = (9usize, 1usize, 5usize);
        let mut pr = PackedRows::<f64>::new();
        pr.pack_slice(&bufs.arena, &plan, mc, k0, kc);
        assert_eq!(pr.n_blocks(), 3); // 9 + 9 + 3
        assert_eq!(pr.pack_count(), 3);
        let mut r0 = 0usize;
        for bi in 0..pr.n_blocks() {
            let block = pr.block(bi);
            let mcc = mc.min(plan.m - r0);
            assert_eq!(block.panels.iter().map(|p| p.rows).sum::<usize>(), mcc);
            for (pi, p) in block.panels.iter().enumerate() {
                for (t, &rr) in plan.red_row[k0..k0 + kc].iter().enumerate() {
                    for r in 0..MR {
                        let got = block.data[pi * kc * MR + t * MR + r];
                        if r < p.rows {
                            assert_eq!(got, bufs.arena[(p.row + rr) as usize + r]);
                        } else {
                            assert_eq!(got, 0.0, "padding must be zero");
                        }
                    }
                }
            }
            r0 += mcc;
        }
    }

    #[test]
    fn packed_rows_range_matches_full_slice_blocks() {
        let (_, bufs, plan) = matmul_plan(21, 6, 4);
        let (mc, k0, kc) = (8usize, 1usize, 4usize);
        // the range pack of rows [8, 21) must hold exactly the blocks the
        // full-m pack holds past its first block
        let mut full = PackedRows::<f64>::new();
        full.pack_slice(&bufs.arena, &plan, mc, k0, kc);
        assert_eq!(full.n_blocks(), 3); // 8 + 8 + 5
        let mut range = PackedRows::<f64>::new();
        range.pack_slice_range(&bufs.arena, &plan, mc, 8, 13, k0, kc);
        assert_eq!(range.n_blocks(), 2);
        for bi in 0..range.n_blocks() {
            let a = range.block(bi);
            let b = full.block(bi + 1);
            assert_eq!(a.panels, b.panels, "block {bi} panels differ");
            assert_eq!(a.data, b.data, "block {bi} data differs");
        }
        // an mc-unaligned range still packs exactly its own rows
        let mut odd = PackedRows::<f64>::new();
        odd.pack_slice_range(&bufs.arena, &plan, mc, 3, 10, k0, kc);
        assert_eq!(odd.n_blocks(), 2); // 8 + 2
        let live: usize = (0..odd.n_blocks())
            .flat_map(|bi| odd.block(bi).panels.to_vec())
            .map(|p| p.rows)
            .sum();
        assert_eq!(live, 10);
        assert_eq!(odd.block(0).panels[0].row, plan.runs[0].row + 3);
    }

    #[test]
    fn macro_block_matches_scalar_oracle() {
        use crate::codegen::microkernel::NR;
        // one macro block over the whole (padded) problem, L1 tiles that
        // divide nothing evenly
        for (m, k, n, ti, tj) in [
            (17i64, 9i64, 13i64, 5usize, 3usize),
            (8, 8, 4, 8, 4),
            (1, 1, 1, 1, 1),
            (23, 7, 19, 16, 32),
        ] {
            let (_, mut bufs, plan) = matmul_plan(m, k, n);
            let want = bufs.reference();
            let mut pr = PackedRows::<f64>::new();
            pr.pack_slice(&bufs.arena, &plan, plan.m, 0, plan.k);
            let mut pc = PackedCols::<f64>::new();
            pc.pack_band::<NR>(&bufs.arena, &plan, 0, plan.k, 0, plan.n);
            // split borrows: clone the packed handles out of the arena
            let block = pr.block(0);
            let panels: Vec<RowPanel> = block.panels.to_vec();
            let data: Vec<f64> = block.data.to_vec();
            let block = PackedBlock {
                panels: &panels,
                data: &data,
                kc: plan.k,
                mr: MR,
            };
            run_macro_block::<f64, NR>(block, &pc, &plan, 0, (ti, tj), &mut bufs.arena);
            let got = bufs.output();
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (a - b).abs() < 1e-12,
                    "({m},{k},{n}) tile ({ti},{tj}) flat {i}"
                );
            }
        }
    }

    #[test]
    fn tall_packed_box_matches_scalar_oracle() {
        use crate::codegen::microkernel::{MR_TALL, NR, NR_WIDE};
        // the 16-row panel height through the per-tile engine, at both
        // tall widths, m spanning none/one/partial second tall panel
        for (m, k, n) in [(7i64, 5i64, 9i64), (16, 6, 11), (21, 9, 13)] {
            let (_, mut bufs, plan) = matmul_plan(m, k, n);
            let want = bufs.reference();
            let mut packs = PackBuffers::<f64>::new();
            packs.set_mr(MR_TALL);
            packs.pack_rows_cached(&bufs.arena, &plan, vec![0]);
            packs.pack_cols_cached::<NR>(&bufs.arena, &plan, vec![0]);
            packs.run_box::<NR>(&mut bufs.arena, &plan);
            let got = bufs.output();
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-12, "16x4 ({m},{k},{n}) flat {i}");
            }
            let (_, mut bufs, plan) = matmul_plan(m, k, n);
            let want = bufs.reference();
            let mut packs = PackBuffers::<f64>::new();
            packs.set_mr(MR_TALL);
            packs.pack_rows_cached(&bufs.arena, &plan, vec![0]);
            packs.pack_cols_cached::<NR_WIDE>(&bufs.arena, &plan, vec![0]);
            packs.run_box::<NR_WIDE>(&mut bufs.arena, &plan);
            let got = bufs.output();
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-12, "16x6 ({m},{k},{n}) flat {i}");
            }
        }
    }

    #[test]
    fn set_mr_invalidates_cached_row_panels() {
        use crate::codegen::microkernel::MR_TALL;
        let (_, bufs, plan) = matmul_plan(20, 4, 4);
        let mut packs = PackBuffers::<f64>::new();
        packs.pack_rows_cached(&bufs.arena, &plan, vec![0]);
        assert_eq!(packs.row_panel_data().0.len(), 20usize.div_ceil(MR));
        // same arena, same key — only the height changed
        packs.set_mr(MR_TALL);
        packs.pack_rows_cached(&bufs.arena, &plan, vec![0]);
        assert_eq!(
            packs.row_panel_data().0.len(),
            20usize.div_ceil(MR_TALL),
            "stale 8-row panels replayed after a height switch"
        );
    }

    #[test]
    fn acc64_box_is_single_rounding_per_element() {
        // f32 storage, f64 accumulation through the full packed engine:
        // every output must equal the f64 reference rounded once
        const W: usize = 8;
        let kernel = ops::matmul_padded(13, 30, 9, 15, 14, 31, 4, 0);
        let mut bufs = KernelBuffers::<f32>::from_kernel(&kernel);
        // mixed-sign, non-representable-sum fill
        for (i, v) in bufs.arena.iter_mut().enumerate() {
            *v = if i % 2 == 0 {
                1.0 + 2.0f32.powi(-12)
            } else {
                -1.0 + (i % 17) as f32 * 2.0f32.powi(-10)
            };
        }
        let gf = GemmForm::of(&kernel).unwrap();
        let plan = gf.plan_box(&kernel_views(&kernel), &[0, 0, 0], kernel.extents());
        // f64 oracle over the same f32 inputs
        let mut oracle = vec![0.0f64; plan.m * plan.n];
        for (ri, run) in plan.runs.iter().enumerate() {
            assert_eq!(ri, 0, "matmul plan has one run");
            for r in 0..run.len {
                for (c, (&co, &ci)) in plan.col_out.iter().zip(&plan.col_in).enumerate() {
                    let mut acc = 0.0f64;
                    for (&rr, &rc) in plan.red_row.iter().zip(&plan.red_col) {
                        let b = bufs.arena[(run.row + rr) as usize + r] as f64;
                        let cv = bufs.arena[(ci + rc) as usize] as f64;
                        acc += b * cv;
                    }
                    let out = (run.out + co) as usize + r;
                    oracle[r * plan.n + c] =
                        bufs.arena[out] as f64 + acc;
                }
            }
        }
        let mut packs = PackBuffers::<f32>::new();
        packs.pack_rows_cached(&bufs.arena, &plan, vec![0]);
        packs.pack_cols_cached::<W>(&bufs.arena, &plan, vec![0]);
        packs.run_box_acc::<W>(&mut bufs.arena, &plan, true);
        for r in 0..plan.m {
            for c in 0..plan.n {
                let out = (plan.runs[0].out + plan.col_out[c]) as usize + r;
                assert_eq!(
                    bufs.arena[out],
                    oracle[r * plan.n + c] as f32,
                    "({r},{c}): acc64 box not a single rounding"
                );
            }
        }
    }

    #[test]
    fn kronecker_packs_segmented_runs() {
        use crate::codegen::microkernel::NR;
        let kernel = ops::kronecker(3, 2, 4, 5, 8, 0);
        let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
        let gf = GemmForm::of(&kernel).unwrap();
        let plan = gf.plan_box(&kernel_views(&kernel), &[0; 4], kernel.extents());
        let want = bufs.reference();
        let mut packs = PackBuffers::<f64>::new();
        packs.pack_rows_cached(&bufs.arena, &plan, vec![0]);
        packs.pack_cols_cached::<NR>(&bufs.arena, &plan, vec![0]);
        packs.run_box::<NR>(&mut bufs.arena, &plan);
        let got = bufs.output();
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-12, "kronecker flat {i}");
        }
    }
}
