//! Operand packing for the microkernel execution engine.
//!
//! [`PackBuffers`] copies the B and C operands of one tile into
//! contiguous, microkernel-strided buffers:
//!
//! * **B panels** — `⌈mc/MR⌉` panels of `MR` consecutive rows; panel `p`
//!   stores element `(t, r)` (k step `t`, row `r`) at
//!   `p·kc·MR + t·MR + r`, so each k step of the microkernel reads one
//!   contiguous `MR`-vector.
//! * **C panels** — `⌈nc/NR⌉` panels of `NR` consecutive columns; panel
//!   `q` stores `(t, c)` at `q·kc·NR + t·NR + c`.
//!
//! Rows past `mc` / columns past `nc` are zero-filled so boundary blocks
//! can run the full register tile and clip only the write-back
//! ([`super::microkernel::mkernel_edge`]).
//!
//! The packing cost is `O(mc·kc + kc·nc)` per tile against `O(mc·kc·nc)`
//! microkernel work, i.e. amortized across the k-loop exactly as in a
//! blocked BLAS. Buffers are reused across tiles (and are thread-local in
//! the parallel executor) so steady-state packing performs no allocation.
//!
//! The macro-kernel layer packs at L2/L3 block granularity instead:
//! [`PackedB`] holds *every* `mc×kc` B block of one k-depth slice in the
//! same panel layout (a read-only handle shared across threads in the
//! parallel executor), [`PackedC`] one `kc×nc` C block, and
//! [`run_macro_block`] drives the register-tiled micro-engine over all L1
//! tiles of one macro block straight from those panels — each operand
//! block is packed exactly once per macro block.

use super::microkernel::{mkernel_edge, mkernel_full, MR, NR};

/// Cache key of a packed block: source identity (pointer, element offset,
/// leading dim) + block coordinates. The source identity guards against
/// replaying stale panels when one `PackBuffers` is reused across kernels
/// or arenas whose block coordinates happen to coincide.
type PackKey = (usize, usize, usize, usize, usize, usize, usize);

/// Reusable pack buffers + the geometry of the tile they currently hold.
///
/// The `*_cached` packers skip the copy when the requested block is the
/// one already packed — keyed by source identity *and* block coordinates
/// (see [`PackKey`]) — valid while the source operand bytes are
/// unchanged, which holds for the executors: B and C are read-only during
/// a run. Callers that mutate the source between runs must call
/// [`PackBuffers::invalidate`] first.
#[derive(Clone, Debug, Default)]
pub struct PackBuffers {
    bp: Vec<f64>,
    cp: Vec<f64>,
    kc_b: usize,
    kc_c: usize,
    mc: usize,
    nc: usize,
    b_key: Option<PackKey>,
    c_key: Option<PackKey>,
}

impl PackBuffers {
    pub fn new() -> PackBuffers {
        PackBuffers::default()
    }

    /// Forget the cached block keys, forcing the next `*_cached` call to
    /// repack. Call at run entry whenever the source bytes may have
    /// changed since the buffers were last used.
    pub fn invalidate(&mut self) {
        self.b_key = None;
        self.c_key = None;
    }

    /// Pack `mc` rows × `kc` k-steps of B (column-major, leading dim
    /// `ldb`, rows starting at `i0`, k starting at `k0`) into MR panels.
    #[allow(clippy::too_many_arguments)]
    pub fn pack_b(
        &mut self,
        src: &[f64],
        b_off: usize,
        ldb: usize,
        i0: usize,
        mc: usize,
        k0: usize,
        kc: usize,
    ) {
        assert!(mc >= 1 && kc >= 1);
        self.kc_b = kc;
        self.mc = mc;
        self.b_key = Some((src.as_ptr() as usize, b_off, ldb, i0, mc, k0, kc));
        let panels = mc.div_ceil(MR);
        self.bp.clear();
        self.bp.resize(panels * kc * MR, 0.0);
        for p in 0..panels {
            let rows = MR.min(mc - p * MR);
            let base = p * kc * MR;
            for t in 0..kc {
                let srow = b_off + i0 + p * MR + ldb * (k0 + t);
                let dst = base + t * MR;
                self.bp[dst..dst + rows].copy_from_slice(&src[srow..srow + rows]);
            }
        }
    }

    /// Pack `kc` k-steps × `nc` columns of C (column-major, leading dim
    /// `ldc`, k starting at `k0`, columns starting at `j0`) into NR
    /// panels.
    #[allow(clippy::too_many_arguments)]
    pub fn pack_c(
        &mut self,
        src: &[f64],
        c_off: usize,
        ldc: usize,
        k0: usize,
        kc: usize,
        j0: usize,
        nc: usize,
    ) {
        assert!(nc >= 1 && kc >= 1);
        self.kc_c = kc;
        self.nc = nc;
        self.c_key = Some((src.as_ptr() as usize, c_off, ldc, k0, kc, j0, nc));
        let panels = nc.div_ceil(NR);
        self.cp.clear();
        self.cp.resize(panels * kc * NR, 0.0);
        for q in 0..panels {
            let cols = NR.min(nc - q * NR);
            let base = q * kc * NR;
            for c in 0..cols {
                let col = c_off + k0 + ldc * (j0 + q * NR + c);
                for t in 0..kc {
                    self.cp[base + t * NR + c] = src[col + t];
                }
            }
        }
    }

    /// As [`PackBuffers::pack_b`], but a no-op when the same B block is
    /// already packed.
    #[allow(clippy::too_many_arguments)]
    pub fn pack_b_cached(
        &mut self,
        src: &[f64],
        b_off: usize,
        ldb: usize,
        i0: usize,
        mc: usize,
        k0: usize,
        kc: usize,
    ) {
        if self.b_key != Some((src.as_ptr() as usize, b_off, ldb, i0, mc, k0, kc)) {
            self.pack_b(src, b_off, ldb, i0, mc, k0, kc);
        }
    }

    /// As [`PackBuffers::pack_c`], but a no-op when the same C block is
    /// already packed.
    #[allow(clippy::too_many_arguments)]
    pub fn pack_c_cached(
        &mut self,
        src: &[f64],
        c_off: usize,
        ldc: usize,
        k0: usize,
        kc: usize,
        j0: usize,
        nc: usize,
    ) {
        if self.c_key != Some((src.as_ptr() as usize, c_off, ldc, k0, kc, j0, nc)) {
            self.pack_c(src, c_off, ldc, k0, kc, j0, nc);
        }
    }

    /// Run the packed tile: `A[i0+r, j0+c] += Σ_t B·C` over the packed
    /// `mc×kc` × `kc×nc` panels, dispatching full `MR×NR` blocks to the
    /// register-tiled microkernel and clipped boundary blocks to the edge
    /// kernel. `a` is the whole arena slice; `a_off`/`lda` locate the
    /// output table.
    pub fn run_tile(&self, a: &mut [f64], a_off: usize, lda: usize, i0: usize, j0: usize) {
        assert_eq!(self.kc_b, self.kc_c, "B and C packed with different k depths");
        let kc = self.kc_b;
        let bpanels = self.mc.div_ceil(MR);
        let cpanels = self.nc.div_ceil(NR);
        for q in 0..cpanels {
            let nr = NR.min(self.nc - q * NR);
            let cp = &self.cp[q * kc * NR..(q + 1) * kc * NR];
            for p in 0..bpanels {
                let mr = MR.min(self.mc - p * MR);
                let bp = &self.bp[p * kc * MR..(p + 1) * kc * MR];
                let a_base = a_off + i0 + p * MR + lda * (j0 + q * NR);
                if mr == MR && nr == NR {
                    mkernel_full(kc, bp, cp, &mut a[a_base..], lda);
                } else {
                    mkernel_edge(mr, nr, kc, bp, cp, &mut a[a_base..], lda);
                }
            }
        }
    }
}

/// Every `mc×kc` B block of one k-depth slice, packed once into the
/// microkernel panel layout and shared **read-only** across threads in
/// the parallel macro-kernel.
///
/// Block `bi` covers rows `[bi·mc, bi·mc + mcc)` (clipped at `m`) and
/// holds `⌈mcc/MR⌉` MR-row panels of depth `kc`, zero-padded past the
/// live rows; all blocks share the stride of a full block so block
/// lookup is O(1).
#[derive(Clone, Debug, Default)]
pub struct PackedB {
    buf: Vec<f64>,
    m: usize,
    mc: usize,
    kc: usize,
    block_stride: usize,
    packs: u64,
}

impl PackedB {
    pub fn new() -> PackedB {
        PackedB::default()
    }

    /// Pack every `mc`-row block of B rows `[0, m)` at k slice
    /// `[k0, k0+kc)` (column-major source, leading dim `ldb`).
    #[allow(clippy::too_many_arguments)]
    pub fn pack_slice(
        &mut self,
        src: &[f64],
        b_off: usize,
        ldb: usize,
        m: usize,
        mc: usize,
        k0: usize,
        kc: usize,
    ) {
        assert!(m >= 1 && mc >= 1 && kc >= 1);
        let mc = mc.min(m);
        self.m = m;
        self.mc = mc;
        self.kc = kc;
        let panels_per_block = mc.div_ceil(MR);
        self.block_stride = panels_per_block * kc * MR;
        let n_blocks = m.div_ceil(mc);
        self.buf.clear();
        self.buf.resize(n_blocks * self.block_stride, 0.0);
        for bi in 0..n_blocks {
            let i0 = bi * mc;
            let mcc = mc.min(m - i0);
            let base = bi * self.block_stride;
            for p in 0..mcc.div_ceil(MR) {
                let rows = MR.min(mcc - p * MR);
                let pbase = base + p * kc * MR;
                for t in 0..kc {
                    let srow = b_off + i0 + p * MR + ldb * (k0 + t);
                    let dst = pbase + t * MR;
                    self.buf[dst..dst + rows].copy_from_slice(&src[srow..srow + rows]);
                }
            }
            self.packs += 1;
        }
    }

    /// Number of row blocks in the packed slice.
    pub fn n_blocks(&self) -> usize {
        self.m.div_ceil(self.mc)
    }

    /// Panel view of block `bi`: `(panels, i0, mcc)` — the packed panels,
    /// the block's first absolute row, and its live row count.
    pub fn block(&self, bi: usize) -> (&[f64], usize, usize) {
        assert!(bi < self.n_blocks());
        let i0 = bi * self.mc;
        let mcc = self.mc.min(self.m - i0);
        (
            &self.buf[bi * self.block_stride..(bi + 1) * self.block_stride],
            i0,
            mcc,
        )
    }

    /// The packed k depth of the current slice.
    pub fn kc(&self) -> usize {
        self.kc
    }

    /// How many B blocks have been packed over this buffer's lifetime
    /// (each macro block counts once — the pack-amortization invariant
    /// the tests pin).
    pub fn pack_count(&self) -> u64 {
        self.packs
    }
}

/// One `kc×nc` C block packed into NR-column panels — the macro-kernel's
/// thread-local counterpart of [`PackedB`] (each thread owns the C block
/// of its output column band).
#[derive(Clone, Debug, Default)]
pub struct PackedC {
    buf: Vec<f64>,
    kc: usize,
    nc: usize,
    packs: u64,
}

impl PackedC {
    pub fn new() -> PackedC {
        PackedC::default()
    }

    /// Pack `kc` k-steps × `nc` columns of C (column-major, leading dim
    /// `ldc`, k starting at `k0`, columns starting at `j0`).
    #[allow(clippy::too_many_arguments)]
    pub fn pack_block(
        &mut self,
        src: &[f64],
        c_off: usize,
        ldc: usize,
        k0: usize,
        kc: usize,
        j0: usize,
        nc: usize,
    ) {
        assert!(nc >= 1 && kc >= 1);
        self.kc = kc;
        self.nc = nc;
        let panels = nc.div_ceil(NR);
        self.buf.clear();
        self.buf.resize(panels * kc * NR, 0.0);
        for q in 0..panels {
            let cols = NR.min(nc - q * NR);
            let base = q * kc * NR;
            for c in 0..cols {
                let col = c_off + k0 + ldc * (j0 + q * NR + c);
                for t in 0..kc {
                    self.buf[base + t * NR + c] = src[col + t];
                }
            }
        }
        self.packs += 1;
    }

    /// The packed NR-column panels.
    pub fn panels(&self) -> &[f64] {
        &self.buf
    }

    /// `(kc, nc)` of the currently packed block.
    pub fn shape(&self) -> (usize, usize) {
        (self.kc, self.nc)
    }

    /// How many C blocks have been packed over this buffer's lifetime.
    pub fn pack_count(&self) -> u64 {
        self.packs
    }
}

/// Drive the `MR×NR` micro-engine over all L1 tiles of one macro block,
/// straight from packed panels: `bp` is one [`PackedB`] block (`mcc` live
/// rows), `cp` one [`PackedC`] block (`ncc` live columns), both `kc`
/// deep. `(ti, tj)` is the L1 tile footprint — rounded up to `MR`/`NR`
/// multiples here so L1 tiles partition the register-block grid — and
/// `(i0, j0)` the block's top-left element of the output table at
/// `a_off`/`lda` inside `a`.
///
/// The loop nest is `jt → it → q → p`: the C micro-panel of an L1 tile
/// (`kc×NR`, L1-resident) is reused across all of the tile's B panels,
/// while the B block streams from the outer-level cache — no packing
/// happens here at all.
#[allow(clippy::too_many_arguments)]
pub fn run_macro_block(
    bp: &[f64],
    mcc: usize,
    cp: &[f64],
    ncc: usize,
    kc: usize,
    (ti, tj): (usize, usize),
    a: &mut [f64],
    a_off: usize,
    lda: usize,
    i0: usize,
    j0: usize,
) {
    assert!(mcc >= 1 && ncc >= 1 && kc >= 1);
    let ti = ti.div_ceil(MR).max(1) * MR;
    let tj = tj.div_ceil(NR).max(1) * NR;
    let bpanels = mcc.div_ceil(MR);
    let cpanels = ncc.div_ceil(NR);
    assert!(bp.len() >= bpanels * kc * MR, "B block panels too short");
    assert!(cp.len() >= cpanels * kc * NR, "C block panels too short");
    for jt in (0..ncc).step_by(tj) {
        let q_hi = cpanels.min((jt + tj) / NR);
        for it in (0..mcc).step_by(ti) {
            let p_hi = bpanels.min((it + ti) / MR);
            for q in (jt / NR)..q_hi {
                let nr = NR.min(ncc - q * NR);
                let cpq = &cp[q * kc * NR..(q + 1) * kc * NR];
                for p in (it / MR)..p_hi {
                    let mr = MR.min(mcc - p * MR);
                    let bpp = &bp[p * kc * MR..(p + 1) * kc * MR];
                    let a_base = a_off + i0 + p * MR + lda * (j0 + q * NR);
                    if mr == MR && nr == NR {
                        mkernel_full(kc, bpp, cpq, &mut a[a_base..], lda);
                    } else {
                        mkernel_edge(mr, nr, kc, bpp, cpq, &mut a[a_base..], lda);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(len: usize, seed: u64) -> Vec<f64> {
        let mut rng = crate::testutil::Rng::new(seed);
        (0..len).map(|_| rng.f64_unit() - 0.5).collect()
    }

    #[test]
    fn pack_b_layout_and_zero_fill() {
        let (m, k, ldb) = (11usize, 5usize, 13usize);
        let src = fill(ldb * k, 7);
        let mut packs = PackBuffers::new();
        packs.pack_b(&src, 0, ldb, 2, m - 2, 1, k - 1);
        let (mc, kc) = (m - 2, k - 1);
        let panels = mc.div_ceil(MR);
        assert_eq!(packs.bp.len(), panels * kc * MR);
        for p in 0..panels {
            for t in 0..kc {
                for r in 0..MR {
                    let got = packs.bp[p * kc * MR + t * MR + r];
                    if p * MR + r < mc {
                        assert_eq!(got, src[2 + p * MR + r + ldb * (1 + t)]);
                    } else {
                        assert_eq!(got, 0.0, "padding must be zero");
                    }
                }
            }
        }
    }

    #[test]
    fn pack_c_layout_and_zero_fill() {
        let (k, n, ldc) = (6usize, 7usize, 9usize);
        let src = fill(ldc * n, 8);
        let mut packs = PackBuffers::new();
        packs.pack_c(&src, 0, ldc, 1, k - 1, 2, n - 2);
        let (kc, nc) = (k - 1, n - 2);
        let panels = nc.div_ceil(NR);
        assert_eq!(packs.cp.len(), panels * kc * NR);
        for q in 0..panels {
            for t in 0..kc {
                for c in 0..NR {
                    let got = packs.cp[q * kc * NR + t * NR + c];
                    if q * NR + c < nc {
                        assert_eq!(got, src[1 + t + ldc * (2 + q * NR + c)]);
                    } else {
                        assert_eq!(got, 0.0, "padding must be zero");
                    }
                }
            }
        }
    }

    #[test]
    fn packed_tile_matches_naive_gemm() {
        // whole-matrix "tile", non-multiple extents, padded lda
        for (m, k, n) in [(1usize, 1usize, 1usize), (7, 5, 3), (17, 9, 13), (8, 8, 4)] {
            let (lda, ldb, ldc) = (m + 2, m + 1, k + 3);
            let b = fill(ldb * k, 21);
            let c = fill(ldc * n, 22);
            let mut a = vec![0f64; lda * n];
            let mut packs = PackBuffers::new();
            packs.pack_b(&b, 0, ldb, 0, m, 0, k);
            packs.pack_c(&c, 0, ldc, 0, k, 0, n);
            packs.run_tile(&mut a, 0, lda, 0, 0);
            for j in 0..n {
                for i in 0..m {
                    let want: f64 = (0..k).map(|t| b[i + ldb * t] * c[t + ldc * j]).sum();
                    assert!(
                        (a[i + lda * j] - want).abs() < 1e-12,
                        "({m},{k},{n}) at ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn cached_pack_keys_include_source_identity() {
        // regression: same block coordinates, different arena/operand —
        // the old (i0, mc, k0, kc)-only key replayed stale panels here
        let (m, k, ldb) = (8usize, 4usize, 8usize);
        let a1 = vec![1.0f64; ldb * k];
        let a2 = vec![2.0f64; ldb * k];
        let mut packs = PackBuffers::new();
        packs.pack_b_cached(&a1, 0, ldb, 0, m, 0, k);
        assert_eq!(packs.bp[0], 1.0);
        packs.pack_b_cached(&a2, 0, ldb, 0, m, 0, k);
        assert_eq!(packs.bp[0], 2.0, "stale B panel replayed across arenas");
        // same arena, different operand offset/ld must also repack
        let big = fill(2 * ldb * k, 5);
        packs.pack_b_cached(&big, 0, ldb, 0, m, 0, k);
        let first = packs.bp[0];
        packs.pack_b_cached(&big, ldb * k, ldb, 0, m, 0, k);
        assert_eq!(packs.bp[0], big[ldb * k]);
        assert_ne!(packs.bp[0], first);
        // C side: different arenas with equal coordinates
        let c1 = vec![3.0f64; k * 4];
        let c2 = vec![4.0f64; k * 4];
        packs.pack_c_cached(&c1, 0, k, 0, k, 0, 4);
        assert_eq!(packs.cp[0], 3.0);
        packs.pack_c_cached(&c2, 0, k, 0, k, 0, 4);
        assert_eq!(packs.cp[0], 4.0, "stale C panel replayed across arenas");
    }

    #[test]
    fn invalidate_forces_repack_of_mutated_source() {
        let (m, k, ldb) = (8usize, 4usize, 8usize);
        let mut src = vec![3.0f64; ldb * k];
        let mut packs = PackBuffers::new();
        packs.pack_b_cached(&src, 0, ldb, 0, m, 0, k);
        src[0] = 9.0;
        // same source + coordinates: documented to stay cached...
        packs.pack_b_cached(&src, 0, ldb, 0, m, 0, k);
        assert_eq!(packs.bp[0], 3.0);
        // ...until the caller invalidates
        packs.invalidate();
        packs.pack_b_cached(&src, 0, ldb, 0, m, 0, k);
        assert_eq!(packs.bp[0], 9.0);
    }

    #[test]
    fn packed_b_slice_layout_and_blocking() {
        let (m, k, ldb) = (21usize, 6usize, 23usize);
        let src = fill(ldb * k, 31);
        let (mc, k0, kc) = (9usize, 1usize, k - 1);
        let mut pb = PackedB::new();
        pb.pack_slice(&src, 0, ldb, m, mc, k0, kc);
        assert_eq!(pb.n_blocks(), 3); // 9 + 9 + 3
        assert_eq!(pb.pack_count(), 3);
        for bi in 0..pb.n_blocks() {
            let (panels, i0, mcc) = pb.block(bi);
            assert_eq!(i0, bi * mc);
            assert_eq!(mcc, mc.min(m - i0));
            for p in 0..mcc.div_ceil(MR) {
                for t in 0..kc {
                    for r in 0..MR {
                        let got = panels[p * kc * MR + t * MR + r];
                        if p * MR + r < mcc {
                            assert_eq!(got, src[i0 + p * MR + r + ldb * (k0 + t)]);
                        } else {
                            assert_eq!(got, 0.0, "padding must be zero");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn macro_block_matches_naive_gemm() {
        // one macro block over the whole (padded) problem, L1 tiles that
        // divide nothing evenly
        for (m, k, n, ti, tj) in [
            (17usize, 9usize, 13usize, 5usize, 3usize),
            (8, 8, 4, 8, 4),
            (1, 1, 1, 1, 1),
            (23, 7, 19, 16, 32),
        ] {
            let (lda, ldb, ldc) = (m + 2, m + 1, k + 3);
            let b = fill(ldb * k, 41);
            let c = fill(ldc * n, 42);
            let mut a = vec![0f64; lda * n];
            let mut pb = PackedB::new();
            pb.pack_slice(&b, 0, ldb, m, m, 0, k);
            let mut pc = PackedC::new();
            pc.pack_block(&c, 0, ldc, 0, k, 0, n);
            let (panels, i0, mcc) = pb.block(0);
            run_macro_block(panels, mcc, pc.panels(), n, k, (ti, tj), &mut a, 0, lda, i0, 0);
            for j in 0..n {
                for i in 0..m {
                    let want: f64 = (0..k).map(|t| b[i + ldb * t] * c[t + ldc * j]).sum();
                    assert!(
                        (a[i + lda * j] - want).abs() < 1e-12,
                        "({m},{k},{n}) tile ({ti},{tj}) at ({i},{j})"
                    );
                }
            }
        }
    }
}
