//! Operand packing for the microkernel execution engine.
//!
//! [`PackBuffers`] copies the B and C operands of one tile into
//! contiguous, microkernel-strided buffers:
//!
//! * **B panels** — `⌈mc/MR⌉` panels of `MR` consecutive rows; panel `p`
//!   stores element `(t, r)` (k step `t`, row `r`) at
//!   `p·kc·MR + t·MR + r`, so each k step of the microkernel reads one
//!   contiguous `MR`-vector.
//! * **C panels** — `⌈nc/NR⌉` panels of `NR` consecutive columns; panel
//!   `q` stores `(t, c)` at `q·kc·NR + t·NR + c`.
//!
//! Rows past `mc` / columns past `nc` are zero-filled so boundary blocks
//! can run the full register tile and clip only the write-back
//! ([`super::microkernel::mkernel_edge`]).
//!
//! The packing cost is `O(mc·kc + kc·nc)` per tile against `O(mc·kc·nc)`
//! microkernel work, i.e. amortized across the k-loop exactly as in a
//! blocked BLAS. Buffers are reused across tiles (and are thread-local in
//! the parallel executor) so steady-state packing performs no allocation.

use super::microkernel::{mkernel_edge, mkernel_full, MR, NR};

/// Reusable pack buffers + the geometry of the tile they currently hold.
///
/// The `*_cached` packers skip the copy when the requested block is the
/// one already packed (keys `(i0, mc, k0, kc)` / `(k0, kc, j0, nc)`) —
/// valid while the source operand bytes are unchanged, which holds for
/// the executors: B and C are read-only during a run.
#[derive(Clone, Debug, Default)]
pub struct PackBuffers {
    bp: Vec<f64>,
    cp: Vec<f64>,
    kc_b: usize,
    kc_c: usize,
    mc: usize,
    nc: usize,
    b_key: Option<(usize, usize, usize, usize)>,
    c_key: Option<(usize, usize, usize, usize)>,
}

impl PackBuffers {
    pub fn new() -> PackBuffers {
        PackBuffers::default()
    }

    /// Pack `mc` rows × `kc` k-steps of B (column-major, leading dim
    /// `ldb`, rows starting at `i0`, k starting at `k0`) into MR panels.
    #[allow(clippy::too_many_arguments)]
    pub fn pack_b(
        &mut self,
        src: &[f64],
        b_off: usize,
        ldb: usize,
        i0: usize,
        mc: usize,
        k0: usize,
        kc: usize,
    ) {
        assert!(mc >= 1 && kc >= 1);
        self.kc_b = kc;
        self.mc = mc;
        self.b_key = Some((i0, mc, k0, kc));
        let panels = mc.div_ceil(MR);
        self.bp.clear();
        self.bp.resize(panels * kc * MR, 0.0);
        for p in 0..panels {
            let rows = MR.min(mc - p * MR);
            let base = p * kc * MR;
            for t in 0..kc {
                let srow = b_off + i0 + p * MR + ldb * (k0 + t);
                let dst = base + t * MR;
                self.bp[dst..dst + rows].copy_from_slice(&src[srow..srow + rows]);
            }
        }
    }

    /// Pack `kc` k-steps × `nc` columns of C (column-major, leading dim
    /// `ldc`, k starting at `k0`, columns starting at `j0`) into NR
    /// panels.
    #[allow(clippy::too_many_arguments)]
    pub fn pack_c(
        &mut self,
        src: &[f64],
        c_off: usize,
        ldc: usize,
        k0: usize,
        kc: usize,
        j0: usize,
        nc: usize,
    ) {
        assert!(nc >= 1 && kc >= 1);
        self.kc_c = kc;
        self.nc = nc;
        self.c_key = Some((k0, kc, j0, nc));
        let panels = nc.div_ceil(NR);
        self.cp.clear();
        self.cp.resize(panels * kc * NR, 0.0);
        for q in 0..panels {
            let cols = NR.min(nc - q * NR);
            let base = q * kc * NR;
            for c in 0..cols {
                let col = c_off + k0 + ldc * (j0 + q * NR + c);
                for t in 0..kc {
                    self.cp[base + t * NR + c] = src[col + t];
                }
            }
        }
    }

    /// As [`PackBuffers::pack_b`], but a no-op when the same B block is
    /// already packed.
    #[allow(clippy::too_many_arguments)]
    pub fn pack_b_cached(
        &mut self,
        src: &[f64],
        b_off: usize,
        ldb: usize,
        i0: usize,
        mc: usize,
        k0: usize,
        kc: usize,
    ) {
        if self.b_key != Some((i0, mc, k0, kc)) {
            self.pack_b(src, b_off, ldb, i0, mc, k0, kc);
        }
    }

    /// As [`PackBuffers::pack_c`], but a no-op when the same C block is
    /// already packed.
    #[allow(clippy::too_many_arguments)]
    pub fn pack_c_cached(
        &mut self,
        src: &[f64],
        c_off: usize,
        ldc: usize,
        k0: usize,
        kc: usize,
        j0: usize,
        nc: usize,
    ) {
        if self.c_key != Some((k0, kc, j0, nc)) {
            self.pack_c(src, c_off, ldc, k0, kc, j0, nc);
        }
    }

    /// Run the packed tile: `A[i0+r, j0+c] += Σ_t B·C` over the packed
    /// `mc×kc` × `kc×nc` panels, dispatching full `MR×NR` blocks to the
    /// register-tiled microkernel and clipped boundary blocks to the edge
    /// kernel. `a` is the whole arena slice; `a_off`/`lda` locate the
    /// output table.
    pub fn run_tile(&self, a: &mut [f64], a_off: usize, lda: usize, i0: usize, j0: usize) {
        assert_eq!(self.kc_b, self.kc_c, "B and C packed with different k depths");
        let kc = self.kc_b;
        let bpanels = self.mc.div_ceil(MR);
        let cpanels = self.nc.div_ceil(NR);
        for q in 0..cpanels {
            let nr = NR.min(self.nc - q * NR);
            let cp = &self.cp[q * kc * NR..(q + 1) * kc * NR];
            for p in 0..bpanels {
                let mr = MR.min(self.mc - p * MR);
                let bp = &self.bp[p * kc * MR..(p + 1) * kc * MR];
                let a_base = a_off + i0 + p * MR + lda * (j0 + q * NR);
                if mr == MR && nr == NR {
                    mkernel_full(kc, bp, cp, &mut a[a_base..], lda);
                } else {
                    mkernel_edge(mr, nr, kc, bp, cp, &mut a[a_base..], lda);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(len: usize, seed: u64) -> Vec<f64> {
        let mut rng = crate::testutil::Rng::new(seed);
        (0..len).map(|_| rng.f64_unit() - 0.5).collect()
    }

    #[test]
    fn pack_b_layout_and_zero_fill() {
        let (m, k, ldb) = (11usize, 5usize, 13usize);
        let src = fill(ldb * k, 7);
        let mut packs = PackBuffers::new();
        packs.pack_b(&src, 0, ldb, 2, m - 2, 1, k - 1);
        let (mc, kc) = (m - 2, k - 1);
        let panels = mc.div_ceil(MR);
        assert_eq!(packs.bp.len(), panels * kc * MR);
        for p in 0..panels {
            for t in 0..kc {
                for r in 0..MR {
                    let got = packs.bp[p * kc * MR + t * MR + r];
                    if p * MR + r < mc {
                        assert_eq!(got, src[2 + p * MR + r + ldb * (1 + t)]);
                    } else {
                        assert_eq!(got, 0.0, "padding must be zero");
                    }
                }
            }
        }
    }

    #[test]
    fn pack_c_layout_and_zero_fill() {
        let (k, n, ldc) = (6usize, 7usize, 9usize);
        let src = fill(ldc * n, 8);
        let mut packs = PackBuffers::new();
        packs.pack_c(&src, 0, ldc, 1, k - 1, 2, n - 2);
        let (kc, nc) = (k - 1, n - 2);
        let panels = nc.div_ceil(NR);
        assert_eq!(packs.cp.len(), panels * kc * NR);
        for q in 0..panels {
            for t in 0..kc {
                for c in 0..NR {
                    let got = packs.cp[q * kc * NR + t * NR + c];
                    if q * NR + c < nc {
                        assert_eq!(got, src[1 + t + ldc * (2 + q * NR + c)]);
                    } else {
                        assert_eq!(got, 0.0, "padding must be zero");
                    }
                }
            }
        }
    }

    #[test]
    fn packed_tile_matches_naive_gemm() {
        // whole-matrix "tile", non-multiple extents, padded lda
        for (m, k, n) in [(1usize, 1usize, 1usize), (7, 5, 3), (17, 9, 13), (8, 8, 4)] {
            let (lda, ldb, ldc) = (m + 2, m + 1, k + 3);
            let b = fill(ldb * k, 21);
            let c = fill(ldc * n, 22);
            let mut a = vec![0f64; lda * n];
            let mut packs = PackBuffers::new();
            packs.pack_b(&b, 0, ldb, 0, m, 0, k);
            packs.pack_c(&c, 0, ldc, 0, k, 0, n);
            packs.run_tile(&mut a, 0, lda, 0, 0);
            for j in 0..n {
                for i in 0..m {
                    let want: f64 = (0..k).map(|t| b[i + ldb * t] * c[t + ldc * j]).sum();
                    assert!(
                        (a[i + lda * j] - want).abs() < 1e-12,
                        "({m},{k},{n}) at ({i},{j})"
                    );
                }
            }
        }
    }
}
