//! Schedule-faithful executors — the stand-in for the paper's
//! CLooG-generated loop nests (DESIGN.md S9).
//!
//! [`MatmulBuffers`] owns the operand storage laid out exactly as the
//! kernel's [`Table`](crate::index::Table)s describe (padding, base
//! offsets); executors walk a [`Scanner`] (plain or tiled schedule) and
//! perform `A[i,j] += B[i,kk] · C[kk,j]` per visited point, optionally
//! touching a [`CacheSim`] with the three byte addresses — so simulated
//! miss counts correspond 1:1 to the executed schedule.

use crate::cache::CacheSim;
use crate::domain::order::Scanner;
use crate::domain::{Kernel, OpRole};
use crate::tiling::{TileBasis, TiledSchedule};

/// Operand storage for a matmul kernel built by [`crate::domain::ops`]:
/// one arena indexed by byte address / 8, so executor addresses equal
/// simulator addresses.
#[derive(Clone, Debug)]
pub struct MatmulBuffers {
    pub m: i64,
    pub k: i64,
    pub n: i64,
    /// Arena of f64 covering all three tables (indexed in elements).
    pub arena: Vec<f64>,
    /// Element offsets and leading dims of A, B, C.
    pub a_off: usize,
    pub b_off: usize,
    pub c_off: usize,
    pub lda: usize,
    pub ldb: usize,
    pub ldc: usize,
}

impl MatmulBuffers {
    /// Allocate and deterministically initialize from a matmul kernel
    /// (B, C pseudorandom; A zero).
    pub fn from_kernel(kernel: &Kernel) -> MatmulBuffers {
        assert_eq!(kernel.name(), "matmul");
        let (m, n, k) = (
            kernel.extents()[0],
            kernel.extents()[1],
            kernel.extents()[2],
        );
        let ops = kernel.operands();
        let elem = ops[0].table.elem();
        assert_eq!(elem, 8, "f64 only");
        let end = ops
            .iter()
            .map(|o| o.table.base() + o.table.bytes())
            .max()
            .unwrap();
        let mut arena = vec![0f64; end / 8];
        // deterministic xorshift fill for the inputs
        let mut state = 0x9E3779B97F4A7C15u64;
        let mut rnd = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            ((state >> 11) as f64 / (1u64 << 53) as f64) - 0.5
        };
        for op in &ops[1..=2] {
            let t = &op.table;
            for j in 0..t.dims()[1] {
                for i in 0..t.dims()[0] {
                    arena[t.addr(&[i, j]) / 8] = rnd();
                }
            }
        }
        MatmulBuffers {
            m,
            k,
            n,
            arena,
            a_off: ops[0].table.base() / 8,
            b_off: ops[1].table.base() / 8,
            c_off: ops[2].table.base() / 8,
            lda: ops[0].table.map().weights()[1] as usize,
            ldb: ops[1].table.map().weights()[1] as usize,
            ldc: ops[2].table.map().weights()[1] as usize,
        }
    }

    #[inline(always)]
    pub fn a_idx(&self, i: i64, j: i64) -> usize {
        self.a_off + i as usize + self.lda * j as usize
    }

    #[inline(always)]
    pub fn b_idx(&self, i: i64, kk: i64) -> usize {
        self.b_off + i as usize + self.ldb * kk as usize
    }

    #[inline(always)]
    pub fn c_idx(&self, kk: i64, j: i64) -> usize {
        self.c_off + kk as usize + self.ldc * j as usize
    }

    /// Reset the output to zero (between schedule runs).
    pub fn reset_output(&mut self) {
        for j in 0..self.n {
            for i in 0..self.m {
                let idx = self.a_idx(i, j);
                self.arena[idx] = 0.0;
            }
        }
    }

    /// Copy of the output matrix (column-major m×n).
    pub fn output(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity((self.m * self.n) as usize);
        for j in 0..self.n {
            for i in 0..self.m {
                out.push(self.arena[self.a_idx(i, j)]);
            }
        }
        out
    }

    /// Reference result computed by the naive oracle (fresh buffers).
    pub fn reference(&self) -> Vec<f64> {
        let mut out = vec![0f64; (self.m * self.n) as usize];
        for j in 0..self.n {
            for kk in 0..self.k {
                let ckj = self.arena[self.c_idx(kk, j)];
                for i in 0..self.m {
                    out[(i + self.m * j) as usize] += self.arena[self.b_idx(i, kk)] * ckj;
                }
            }
        }
        out
    }
}

/// Execute the matmul following `scanner`'s visit order. Returns nothing;
/// the result accumulates into `bufs.arena`.
pub fn run_schedule(bufs: &mut MatmulBuffers, kernel: &Kernel, scanner: &dyn Scanner) {
    let arena = &mut bufs.arena;
    let (a_off, b_off, c_off) = (bufs.a_off, bufs.b_off, bufs.c_off);
    let (lda, ldb, ldc) = (bufs.lda, bufs.ldb, bufs.ldc);
    scanner.scan_points(kernel.extents(), &mut |f: &[i64]| {
        let (i, j, kk) = (f[0] as usize, f[1] as usize, f[2] as usize);
        let b = arena[b_off + i + ldb * kk];
        let c = arena[c_off + kk + ldc * j];
        arena[a_off + i + lda * j] += b * c;
    });
}

/// Execute while feeding every touched byte address through the cache
/// simulator, in operand order A, B, C per point (write-allocate, i.e. the
/// output is touched like a read-modify-write).
pub fn run_instrumented(
    bufs: &mut MatmulBuffers,
    kernel: &Kernel,
    scanner: &dyn Scanner,
    sim: &mut CacheSim,
) {
    let a_base = kernel.operand(0).table.base();
    let b_base = kernel.operand(1).table.base();
    let c_base = kernel.operand(2).table.base();
    let arena = &mut bufs.arena;
    let (a_off, b_off, c_off) = (bufs.a_off, bufs.b_off, bufs.c_off);
    let (lda, ldb, ldc) = (bufs.lda, bufs.ldb, bufs.ldc);
    scanner.scan_points(kernel.extents(), &mut |f: &[i64]| {
        let (i, j, kk) = (f[0] as usize, f[1] as usize, f[2] as usize);
        sim.access(a_base + 8 * (i + lda * j));
        sim.access(b_base + 8 * (i + ldb * kk));
        sim.access(c_base + 8 * (kk + ldc * j));
        let b = arena[b_off + i + ldb * kk];
        let c = arena[c_off + kk + ldc * j];
        arena[a_off + i + lda * j] += b * c;
    });
}

/// Trace-only variant: feed addresses to the simulator without computing
/// (for pure miss-count sweeps; ~3× faster than instrumented execution).
pub fn run_trace_only(kernel: &Kernel, scanner: &dyn Scanner, sim: &mut CacheSim) {
    let bases: Vec<usize> = kernel.operands().iter().map(|o| o.table.base()).collect();
    let lds: Vec<usize> = kernel
        .operands()
        .iter()
        .map(|o| o.table.map().weights()[1] as usize)
        .collect();
    let ranks_ok = kernel.operands().iter().all(|o| o.table.rank() == 2);
    assert!(ranks_ok, "run_trace_only expects 2-D operands (matmul)");
    scanner.scan_points(kernel.extents(), &mut |f: &[i64]| {
        let (i, j, kk) = (f[0] as usize, f[1] as usize, f[2] as usize);
        sim.access(bases[0] + 8 * (i + lds[0] * j));
        sim.access(bases[1] + 8 * (i + lds[1] * kk));
        sim.access(bases[2] + 8 * (kk + lds[2] * j));
    });
}

/// Fast tiled executor: walks footpoints, replays a precomputed prototile
/// point list for interior tiles (the lattice tiling's "miss regularity"
/// made operational — every interior tile is the same point pattern
/// shifted), and falls back to clipped scanning at the boundary.
pub struct TiledExecutor {
    schedule: TiledSchedule,
    /// Integer points of the prototile (footpoint 0), lexicographic.
    proto: Vec<Vec<i64>>,
    /// The prototile decomposed into maximal unit-stride runs along dim 0
    /// (`i`): `(i0, rest…, len)` — the vectorizable inner loops of the
    /// "generated code". 3-D only: (i0, j, kk, len).
    runs: Vec<(i64, i64, i64, i64)>,
}

impl TiledExecutor {
    pub fn new(schedule: TiledSchedule) -> TiledExecutor {
        if schedule.basis().is_rect() {
            // the rect fast path in run() needs neither the prototile nor
            // the run list
            return TiledExecutor {
                schedule,
                proto: Vec::new(),
                runs: Vec::new(),
            };
        }
        let proto = prototile_points(schedule.basis());
        let runs = if schedule.basis().dim() == 3 {
            // group by (j, kk), merge consecutive i
            let mut pts: Vec<(i64, i64, i64)> =
                proto.iter().map(|p| (p[1], p[2], p[0])).collect();
            pts.sort_unstable();
            let mut runs = Vec::new();
            let mut iter = pts.into_iter();
            if let Some((mut j, mut kk, mut i0)) = iter.next() {
                let mut len = 1i64;
                for (pj, pkk, pi) in iter {
                    if pj == j && pkk == kk && pi == i0 + len {
                        len += 1;
                    } else {
                        runs.push((i0, j, kk, len));
                        j = pj;
                        kk = pkk;
                        i0 = pi;
                        len = 1;
                    }
                }
                runs.push((i0, j, kk, len));
            }
            runs
        } else {
            Vec::new()
        };
        TiledExecutor {
            schedule,
            proto,
            runs,
        }
    }

    pub fn schedule(&self) -> &TiledSchedule {
        &self.schedule
    }

    pub fn prototile(&self) -> &[Vec<i64>] {
        &self.proto
    }

    /// The prototile's unit-stride run decomposition (3-D skewed bases).
    pub fn runs(&self) -> &[(i64, i64, i64, i64)] {
        &self.runs
    }

    /// Execute matmul with interior-tile replay: interior tiles run the
    /// precomputed unit-stride runs (vectorizable inner loops — this is
    /// the quality of code the paper's CLooG+gcc pipeline emits), boundary
    /// tiles fall back to clipped point scanning.
    pub fn run(&self, bufs: &mut MatmulBuffers, kernel: &Kernel) {
        let extents = kernel.extents();
        let basis = self.schedule.basis();
        let arena = &mut bufs.arena;
        let (a_off, b_off, c_off) = (bufs.a_off, bufs.b_off, bufs.c_off);
        let (lda, ldb, ldc) = (bufs.lda, bufs.ldb, bufs.ldc);
        if basis.is_rect() {
            // generated-code quality for rectangular tiles: a direct
            // 6-deep blocked loop nest with unit-stride inner loop
            let (ti, tj, tk) = (
                basis.basis()[(0, 0)] as usize,
                basis.basis()[(1, 1)] as usize,
                basis.basis()[(2, 2)] as usize,
            );
            let (m, n, k) = (
                extents[0] as usize,
                extents[1] as usize,
                extents[2] as usize,
            );
            for j0 in (0..n).step_by(tj) {
                let jn = (j0 + tj).min(n);
                for k0 in (0..k).step_by(tk) {
                    let kn = (k0 + tk).min(k);
                    for i0 in (0..m).step_by(ti) {
                        let im = (i0 + ti).min(m);
                        for j in j0..jn {
                            for kk in k0..kn {
                                let c = arena[c_off + kk + ldc * j];
                                let b_base = b_off + ldb * kk;
                                let a_base = a_off + lda * j;
                                for i in i0..im {
                                    let bv = arena[b_base + i];
                                    arena[a_base + i] += bv * c;
                                }
                            }
                        }
                    }
                }
            }
            return;
        }
        // Skewed tiles: every tile (interior or boundary) is the translated
        // prototile clipped to the domain box, so clipped run replay is
        // exact — no per-point footpoint filtering anywhere.
        let (m, n, k) = (extents[0], extents[1], extents[2]);
        self.schedule.scan_feet(extents, |foot| {
            let origin: Vec<i128> = basis.basis().mul_vec(foot);
            let (oi, oj, ok) = (origin[0] as i64, origin[1] as i64, origin[2] as i64);
            for &(i0, j, kk, len) in &self.runs {
                let jj = oj + j;
                let kkk = ok + kk;
                if jj < 0 || jj >= n || kkk < 0 || kkk >= k {
                    continue;
                }
                let lo = (oi + i0).max(0);
                let hi = (oi + i0 + len).min(m);
                if lo >= hi {
                    continue;
                }
                let (jj, kkk) = (jj as usize, kkk as usize);
                let c = arena[c_off + kkk + ldc * jj];
                let b_base = b_off + ldb * kkk;
                let a_base = a_off + lda * jj;
                for i in lo as usize..hi as usize {
                    let bv = arena[b_base + i];
                    arena[a_base + i] += bv * c;
                }
            }
        });
    }
}

/// Enumerate the integer points of the prototile (footpoint 0) of a tile
/// basis, lexicographically sorted. Prototile points can have negative
/// coordinates for skewed bases, so this scans the bounding box of
/// `P·[0,1]^d` without clipping.
pub fn prototile_points(basis: &TileBasis) -> Vec<Vec<i64>> {
    let d = basis.dim();
    if basis.is_rect() {
        // the prototile of diag(s) is the box [0,s) — no scan needed
        let sizes: Vec<i64> = (0..d).map(|i| basis.basis()[(i, i)] as i64).collect();
        let mut out = Vec::with_capacity(basis.volume() as usize);
        let mut x = vec![0i64; d];
        'outer: loop {
            out.push(x.clone());
            let mut j = d;
            loop {
                if j == 0 {
                    break 'outer;
                }
                j -= 1;
                x[j] += 1;
                if x[j] < sizes[j] {
                    continue 'outer;
                }
                x[j] = 0;
            }
        }
        return out;
    }
    let mut lo = vec![i128::MAX; d];
    let mut hi = vec![i128::MIN; d];
    for mask in 0..(1usize << d) {
        let corner: Vec<i128> = (0..d).map(|i| ((mask >> i) & 1) as i128).collect();
        let v = basis.basis().mul_vec(&corner);
        for j in 0..d {
            lo[j] = lo[j].min(v[j]);
            hi[j] = hi[j].max(v[j]);
        }
    }
    let mut proto = Vec::new();
    let mut cur = lo.clone();
    let mut x = vec![0i64; d];
    'outer: loop {
        for j in 0..d {
            x[j] = cur[j] as i64;
        }
        if basis.in_prototile(&x) {
            proto.push(x.clone());
        }
        let mut j = d;
        loop {
            if j == 0 {
                break 'outer;
            }
            j -= 1;
            cur[j] += 1;
            if cur[j] <= hi[j] {
                continue 'outer;
            }
            cur[j] = lo[j];
        }
    }
    proto.sort();
    assert_eq!(proto.len() as i128, basis.volume());
    proto
}

/// Convenience: make a `TiledExecutor` from a tile basis.
pub fn tiled_executor(basis: TileBasis) -> TiledExecutor {
    TiledExecutor::new(TiledSchedule::new(basis))
}

/// Max |a−b| over two equal-length slices.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

/// Did the kernel declare a writable first operand? (sanity helper)
pub fn writes_first_operand(kernel: &Kernel) -> bool {
    matches!(
        kernel.operand(0).role,
        OpRole::Write | OpRole::ReadWrite
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domain::ops;
    use crate::domain::IterOrder;
    use crate::lattice::IMat;

    fn check_correct(kernel: &Kernel, scanner: &dyn Scanner) {
        let mut bufs = MatmulBuffers::from_kernel(kernel);
        let want = bufs.reference();
        run_schedule(&mut bufs, kernel, scanner);
        let got = bufs.output();
        assert!(
            max_abs_diff(&want, &got) < 1e-9,
            "schedule result mismatch"
        );
    }

    #[test]
    fn naive_orders_correct() {
        let k = ops::matmul(13, 7, 9, 8, 0);
        for o in IterOrder::all(3) {
            check_correct(&k, &o);
        }
    }

    #[test]
    fn rect_tiled_correct() {
        let k = ops::matmul(17, 11, 13, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[4, 5, 3]));
        check_correct(&k, &s);
    }

    #[test]
    fn lattice_tiled_correct() {
        let k = ops::matmul(16, 16, 16, 8, 0);
        // skewed tile on (i, kk), rect on j
        let basis = TileBasis::from_cols(IMat::from_rows(&[
            &[3, 0, 1],
            &[0, 4, 0],
            &[1, 0, 4],
        ]));
        let s = TiledSchedule::new(basis);
        check_correct(&k, &s);
    }

    #[test]
    fn padded_buffers_correct() {
        let k = ops::matmul_padded(9, 8, 7, 12, 11, 10, 8, 256);
        check_correct(&k, &IterOrder::lex(3));
    }

    #[test]
    fn tiled_executor_matches_schedule_run() {
        let k = ops::matmul(20, 18, 22, 8, 0);
        let basis = TileBasis::from_cols(IMat::from_rows(&[
            &[5, 0, 2],
            &[0, 6, 0],
            &[-1, 0, 4],
        ]));
        let exec = TiledExecutor::new(TiledSchedule::new(basis));
        let mut b1 = MatmulBuffers::from_kernel(&k);
        let want = b1.reference();
        exec.run(&mut b1, &k);
        assert!(max_abs_diff(&want, &b1.output()) < 1e-9);
    }

    #[test]
    fn prototile_size_is_volume() {
        let basis = TileBasis::from_cols(IMat::from_rows(&[&[3, 1], &[1, 4]]));
        let exec = TiledExecutor::new(TiledSchedule::new(basis));
        assert_eq!(exec.prototile().len(), 11);
    }

    #[test]
    fn instrumented_counts_accesses() {
        use crate::cache::{CacheSim, CacheSpec, Policy};
        let k = ops::matmul(8, 8, 8, 8, 0);
        let mut bufs = MatmulBuffers::from_kernel(&k);
        let mut sim = CacheSim::new(CacheSpec::HASWELL_L1D, Policy::Lru);
        run_instrumented(&mut bufs, &k, &IterOrder::lex(3), &mut sim);
        assert_eq!(sim.stats().accesses, 3 * 8 * 8 * 8);
        // result still correct
        assert!(max_abs_diff(&bufs.reference(), &bufs.output()) < 1e-9);
    }

    #[test]
    fn trace_only_equals_instrumented_misses() {
        use crate::cache::{CacheSim, CacheSpec, Policy};
        let k = ops::matmul(10, 10, 10, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[4, 4, 4]));
        let mut sim1 = CacheSim::new(CacheSpec::FIG1_TOY, Policy::Lru);
        let mut sim2 = CacheSim::new(CacheSpec::FIG1_TOY, Policy::Lru);
        let mut bufs = MatmulBuffers::from_kernel(&k);
        run_instrumented(&mut bufs, &k, &s, &mut sim1);
        run_trace_only(&k, &s, &mut sim2);
        assert_eq!(sim1.stats().misses(), sim2.stats().misses());
    }
}
