//! Schedule-faithful executors — the stand-in for the paper's
//! CLooG-generated loop nests (DESIGN.md S9), kernel-agnostic since the
//! `RunPlan` refactor and element-generic since the `Scalar` refactor.
//!
//! [`KernelBuffers`] owns the operand storage laid out exactly as the
//! kernel's [`Table`](crate::index::Table)s describe (padding, base
//! offsets, element size); the point-wise executors walk a [`Scanner`]
//! (plain or tiled schedule) and perform
//! `out[π₀(f)] += in1[π₁(f)] · in2[π₂(f)]` per visited point through the
//! composed [`OperandView`]s, optionally touching a [`CacheSim`] with the
//! three byte addresses — so simulated miss counts correspond 1:1 to the
//! executed schedule, for *any* Table-1 kernel at either precision.
//!
//! [`TiledExecutor`] is the fast path: tile interiors run through the
//! packing + register-blocked microkernel engine ([`super::pack`],
//! [`super::microkernel`]) driven by the [`RunPlan`] IR instead of
//! per-point callbacks — see the pipeline overview in [`super`].

use crate::cache::{CacheSim, CacheSpec};
use crate::domain::order::Scanner;
use crate::domain::{Kernel, OpRole};
use crate::tiling::{LevelPlan, TileBasis, TiledSchedule};

use super::autotune::MicroShape;
use super::microkernel::{axpy_block, dot_update, MR, AXPY_MAX_COLS};
use super::pack::{
    run_macro_block_acc, PackBuffers, PackStage, PackedCols, PackedRows, StageKey,
};
use super::runplan::{kernel_views, GemmForm, OperandView, RunPlan};
use super::scalar::{Precision, Scalar};
use super::ExecOpts;

pub use super::runplan::KernelBuffers;

/// Execute the kernel following `scanner`'s visit order. Returns nothing;
/// the result accumulates into `bufs.arena`.
pub fn run_schedule<T: Scalar>(
    bufs: &mut KernelBuffers<T>,
    kernel: &Kernel,
    scanner: &dyn Scanner,
) {
    let views = kernel_views(kernel);
    let (v0, v1, v2) = (&views[0], &views[1], &views[2]);
    let arena = &mut bufs.arena;
    scanner.scan_points(kernel.extents(), &mut |f: &[i64]| {
        let prod = arena[v1.idx(f)] * arena[v2.idx(f)];
        arena[v0.idx(f)] += prod;
    });
}

/// Execute while feeding every touched byte address through the cache
/// simulator, in operand order (out, in1, in2) per point (write-allocate,
/// i.e. the output is touched like a read-modify-write).
pub fn run_instrumented<T: Scalar>(
    bufs: &mut KernelBuffers<T>,
    kernel: &Kernel,
    scanner: &dyn Scanner,
    sim: &mut CacheSim,
) {
    let views = kernel_views(kernel);
    let (v0, v1, v2) = (&views[0], &views[1], &views[2]);
    let arena = &mut bufs.arena;
    scanner.scan_points(kernel.extents(), &mut |f: &[i64]| {
        sim.access(v0.addr(f));
        sim.access(v1.addr(f));
        sim.access(v2.addr(f));
        let prod = arena[v1.idx(f)] * arena[v2.idx(f)];
        arena[v0.idx(f)] += prod;
    });
}

/// Trace-only variant: feed addresses to the simulator without computing
/// (for pure miss-count sweeps; ~3× faster than instrumented execution).
/// Addresses scale with the kernel's declared element size, so f32
/// kernels legitimately see twice the elements per line.
pub fn run_trace_only(kernel: &Kernel, scanner: &dyn Scanner, sim: &mut CacheSim) {
    let views = kernel_views(kernel);
    scanner.scan_points(kernel.extents(), &mut |f: &[i64]| {
        for v in &views {
            sim.access(v.addr(f));
        }
    });
}

/// Reusable per-thread scratch for the panel-replay path: the packed
/// row-operand runs of the current tile and their clipped extents.
/// Allocation-free in steady state.
#[derive(Clone, Debug, Default)]
pub struct ReplayScratch<T: Scalar = f64> {
    /// Contiguous copy of the tile's clipped row-operand runs.
    bpack: Vec<T>,
    /// Per run: (offset into `bpack`, length, absolute red coord,
    /// absolute row lo).
    clipped: Vec<(usize, usize, i64, i64)>,
}

/// The 3-D GEMM axes of a skewed replay (loop dims of the row, column and
/// reduction axes) plus the output column stride.
#[derive(Clone, Copy, Debug)]
struct ReplayAxes {
    row: usize,
    col: usize,
    red: usize,
    /// Output element stride per column step.
    cs: i64,
}

/// Precomputed per-(kernel, schedule) state for executing *skewed* tiles:
/// the prototile's unit-stride run decomposition in GEMM axes, the
/// operand views, and the panel-replay cross-section. Built once per run
/// (or once before spawning workers in the parallel executor) and shared
/// read-only.
///
/// Three execution strategies, chosen at construction:
///
/// * **panel replay** (`panel_replay()`): 3-D GEMM-form kernels whose
///   basis leaves the column axis decoupled — every tile replays the
///   prototile's packed unit-stride runs through the dtype's
///   `NR`-column axpy microkernel.
/// * **scalar run replay**: 3-D GEMM-form kernels with a coupled column
///   axis — exact clipped scalar replay of the prototile runs.
/// * **point fallback** (`axes = None`): everything else (non-3-D or
///   non-GEMM kernels under skewed bases) — exact per-point evaluation
///   via [`TileBasis::scan_tile`] through the operand views.
pub struct ReplayPlan {
    basis: TileBasis,
    views: Vec<OperandView>,
    axes: Option<ReplayAxes>,
    /// Integer points of the prototile (footpoint 0), lexicographic —
    /// only computed for the run-replay strategies.
    proto: Vec<Vec<i64>>,
    /// Prototile runs in GEMM axes: `(row0, col, red, len)`.
    runs: Vec<(i64, i64, i64, i64)>,
    /// Tile extent along the column axis when decoupled (0 otherwise).
    tj: i64,
    /// The `col = 0` cross-section of `runs` — `(row0, red, len)`; valid
    /// for every column of the tile because the prototile factorizes.
    jruns: Vec<(i64, i64, i64)>,
}

impl ReplayPlan {
    pub fn new(kernel: &Kernel, schedule: &TiledSchedule) -> ReplayPlan {
        let basis = schedule.basis().clone();
        let views = kernel_views(kernel);
        let d = basis.dim();
        assert_eq!(d, kernel.n_free(), "schedule/kernel dimension mismatch");
        // replay needs the 3-D GEMM normal form with one axis per group
        // and unit stride on the row axis for both the output and the row
        // operand
        let axes = GemmForm::of(kernel).and_then(|gf| {
            if d != 3 || gf.row_axes.len() != 1 || gf.col_axes.len() != 1 {
                return None;
            }
            let (row, col) = (gf.row_axes[0], gf.col_axes[0]);
            let red = (0..3).find(|t| *t != row && *t != col).unwrap();
            let (vo, vr, _) = gf.role_views(&views);
            if vo.w[row] != 1 || vr.w[row] != 1 {
                return None;
            }
            let mut v = views.clone();
            if gf.swap {
                v.swap(1, 2);
            }
            Some((ReplayAxes { row, col, red, cs: views[0].w[col] }, v))
        });
        let (axes, views) = match axes {
            Some((a, v)) => (Some(a), v),
            None => (None, views),
        };
        let (proto, runs) = if axes.is_some() && !basis.is_rect() {
            let proto = prototile_points(&basis);
            let ax = axes.unwrap();
            // group by (col, red), merge consecutive rows
            let mut pts: Vec<(i64, i64, i64)> = proto
                .iter()
                .map(|p| (p[ax.col], p[ax.red], p[ax.row]))
                .collect();
            pts.sort_unstable();
            let mut runs: Vec<(i64, i64, i64, i64)> = Vec::new();
            let mut iter = pts.into_iter();
            if let Some((mut c, mut r, mut i0)) = iter.next() {
                let mut len = 1i64;
                for (pc, pr, pi) in iter {
                    if pc == c && pr == r && pi == i0 + len {
                        len += 1;
                    } else {
                        runs.push((i0, c, r, len));
                        c = pc;
                        r = pr;
                        i0 = pi;
                        len = 1;
                    }
                }
                runs.push((i0, c, r, len));
            }
            (proto, runs)
        } else {
            (Vec::new(), Vec::new())
        };
        // Panel replay needs the column axis decoupled: the prototile then
        // factorizes as [0, tj) × (2-D prototile in the (row, red) plane),
        // so the col = 0 run cross-section is valid for every column.
        let (tj, jruns) = match axes {
            Some(ax) if !basis.is_rect() => {
                let b = basis.basis();
                let decoupled = (0..3)
                    .all(|t| t == ax.col || (b[(ax.col, t)] == 0 && b[(t, ax.col)] == 0))
                    && b[(ax.col, ax.col)] > 0;
                if decoupled {
                    let jr: Vec<(i64, i64, i64)> = runs
                        .iter()
                        .filter(|r| r.1 == 0)
                        .map(|r| (r.0, r.2, r.3))
                        .collect();
                    (b[(ax.col, ax.col)] as i64, jr)
                } else {
                    (0, Vec::new())
                }
            }
            _ => (0, Vec::new()),
        };
        ReplayPlan {
            basis,
            views,
            axes,
            proto,
            runs,
            tj,
            jruns,
        }
    }

    /// Does this plan take the packed panel-replay path (skewed with a
    /// decoupled column axis), as opposed to a scalar fallback?
    pub fn panel_replay(&self) -> bool {
        self.tj > 0
    }

    /// The prototile's integer points (empty for the point-fallback and
    /// rect strategies).
    pub fn prototile(&self) -> &[Vec<i64>] {
        &self.proto
    }

    /// The prototile's unit-stride run decomposition in GEMM axes:
    /// `(row0, col, red, len)`.
    pub fn runs(&self) -> &[(i64, i64, i64, i64)] {
        &self.runs
    }

    /// Execute one (possibly boundary) tile at footpoint `foot`: pack the
    /// tile's clipped row-operand runs contiguously, then stream the
    /// dtype's `NR` output columns at a time through the axpy
    /// microkernel; coupled bases fall back to scalar run replay,
    /// non-GEMM kernels to exact per-point evaluation. Shared by the
    /// serial and parallel executors (`scratch` is thread-local in the
    /// latter).
    pub fn run_tile<T: Scalar>(
        &self,
        arena: &mut [T],
        extents: &[i64],
        foot: &[i128],
        scratch: &mut ReplayScratch<T>,
    ) {
        let Some(ax) = self.axes else {
            // exact per-point fallback through the views
            let (v0, v1, v2) = (&self.views[0], &self.views[1], &self.views[2]);
            self.basis.scan_tile(foot, extents, |x| {
                let prod = arena[v1.idx(x)] * arena[v2.idx(x)];
                arena[v0.idx(x)] += prod;
            });
            return;
        };
        let (vo, vr, vc) = (&self.views[0], &self.views[1], &self.views[2]);
        let (m, n, kext) = (extents[ax.row], extents[ax.col], extents[ax.red]);
        let origin = self.basis.basis().mul_vec(foot);
        let (oi, oj, ok) = (
            origin[ax.row] as i64,
            origin[ax.col] as i64,
            origin[ax.red] as i64,
        );
        if self.tj > 0 {
            let jlo = oj.max(0);
            let jhi = (oj + self.tj).min(n);
            if jlo >= jhi {
                return;
            }
            // pack: clip each prototile run once and copy its row-operand
            // values into one contiguous buffer (amortized across the
            // tile's whole column extent)
            scratch.bpack.clear();
            scratch.clipped.clear();
            for &(i0, kk, len) in &self.jruns {
                let kkk = ok + kk;
                if kkk < 0 || kkk >= kext {
                    continue;
                }
                let lo = (oi + i0).max(0);
                let hi = (oi + i0 + len).min(m);
                if lo >= hi {
                    continue;
                }
                let pos = scratch.bpack.len();
                let src = (vr.off + vr.w[ax.red] * kkk + lo) as usize;
                scratch
                    .bpack
                    .extend_from_slice(&arena[src..src + (hi - lo) as usize]);
                scratch.clipped.push((pos, (hi - lo) as usize, kkk, lo));
            }
            if scratch.clipped.is_empty() {
                return;
            }
            // replay: the dtype's narrow register width of output columns
            // per pass shares every packed load
            let ncw = T::NR.min(AXPY_MAX_COLS);
            let (mut j, jhi) = (jlo, jhi);
            while j < jhi {
                let ncols = ((jhi - j) as usize).min(ncw);
                for &(pos, len, kkk, lo) in &scratch.clipped {
                    let mut cvals = [T::ZERO; AXPY_MAX_COLS];
                    for (c, cv) in cvals.iter_mut().enumerate().take(ncols) {
                        let ci = vc.off + vc.w[ax.red] * kkk + vc.w[ax.col] * (j + c as i64);
                        *cv = arena[ci as usize];
                    }
                    let a_base = (vo.off + lo + ax.cs * j) as usize;
                    axpy_block(
                        &mut arena[a_base..],
                        ax.cs as usize,
                        &scratch.bpack[pos..pos + len],
                        &cvals[..ncols],
                    );
                }
                j += ncw as i64;
            }
            return;
        }
        // fallback for coupled bases: exact clipped scalar run replay
        for &(i0, jr, kk, len) in &self.runs {
            let jj = oj + jr;
            let kkk = ok + kk;
            if jj < 0 || jj >= n || kkk < 0 || kkk >= kext {
                continue;
            }
            let lo = (oi + i0).max(0);
            let hi = (oi + i0 + len).min(m);
            if lo >= hi {
                continue;
            }
            let cv = arena[(vc.off + vc.w[ax.red] * kkk + vc.w[ax.col] * jj) as usize];
            let b_base = vr.off + vr.w[ax.red] * kkk;
            let a_base = vo.off + ax.cs * jj;
            for i in lo..hi {
                let prod = arena[(b_base + i) as usize] * cv;
                arena[(a_base + i) as usize] += prod;
            }
        }
    }
}

/// Fast tiled executor: executes any Table-1 kernel under a tiled
/// schedule through the packing + microkernel engine, at the kernel's
/// declared element type (`KernelBuffers<f32>` or `KernelBuffers<f64>`).
///
/// * **Rectangular bases, GEMM-form kernels** run the two-level
///   macro-kernel ([`run_macro`]): L2/L3-sized `mc×kc×nc` blocks packed
///   once from the whole-domain [`RunPlan`], L1 tiles driven inside from
///   the packed panels. Degenerate `m = n = 1` forms (scalar product,
///   convolution) skip packing entirely and run the dot microkernel.
/// * **Skewed lattice bases with a decoupled column axis** (every basis
///   this crate's planners emit) replay the prototile's unit-stride runs
///   ([`ReplayPlan`]): per tile the clipped runs are packed contiguously
///   once, then streamed through the dtype's `NR`-column axpy
///   microkernel — the lattice tiling's "miss regularity" made
///   operational.
/// * **Everything else** (coupled bases, non-GEMM kernels) falls back to
///   exact scalar replay, still tile-ordered.
pub struct TiledExecutor {
    schedule: TiledSchedule,
    /// Explicit L2/L3 macro-block shape for the rect path (None = derive
    /// a capacity heuristic from the Haswell L2 + L3-slice specs and the
    /// element size).
    level: Option<LevelPlan>,
    /// Register-tile geometry class for the packed paths (the startup
    /// autotuner's per-dtype winner when the caller wires it through;
    /// the 8×narrow default otherwise).
    micro: MicroShape,
    /// Accumulate register tiles one precision wider than storage (the
    /// `f32acc64` mode; a no-op at f64 storage).
    acc64: bool,
}

impl TiledExecutor {
    pub fn new(schedule: TiledSchedule) -> TiledExecutor {
        TiledExecutor {
            schedule,
            level: None,
            micro: MicroShape::Mr8Nr4,
            acc64: false,
        }
    }

    /// Override the derived L2/L3 macro-block shape (rect bases only;
    /// skewed bases ignore it and replay per tile).
    pub fn with_level_plan(mut self, level: LevelPlan) -> TiledExecutor {
        self.level = Some(level);
        self
    }

    /// Select the register-tile geometry class (e.g. the dtype's autotuned
    /// winner recorded in
    /// [`Registry::micro_shape_for`](crate::runtime::Registry::micro_shape_for)).
    pub fn with_micro_shape(mut self, micro: MicroShape) -> TiledExecutor {
        self.micro = micro;
        self
    }

    /// Select the storage/accumulation precision pair: a wide-accumulator
    /// precision ([`Precision::wide_acc`]) routes the packed register-tile
    /// and dot paths through the widened-accumulator kernels. The storage
    /// dtype itself is the `KernelBuffers` element type — this only sets
    /// the accumulation side.
    pub fn with_precision(mut self, precision: Precision) -> TiledExecutor {
        self.acc64 = precision.wide_acc();
        self
    }

    /// The explicit macro-block shape, if one was set.
    pub fn level_plan(&self) -> Option<&LevelPlan> {
        self.level.as_ref()
    }

    /// The selected register-tile geometry class.
    pub fn micro_shape(&self) -> MicroShape {
        self.micro
    }

    /// Is the wide-accumulation (`f32acc64`) path selected?
    pub fn wide_acc(&self) -> bool {
        self.acc64
    }

    pub fn schedule(&self) -> &TiledSchedule {
        &self.schedule
    }

    /// Build the skewed-tile replay state for `kernel` (shared read-only
    /// across workers in the parallel executor).
    pub fn replay(&self, kernel: &Kernel) -> ReplayPlan {
        ReplayPlan::new(kernel, &self.schedule)
    }

    /// Execute the kernel over the whole domain (see the type docs for
    /// the strategy per basis/kernel class).
    pub fn run<T: Scalar>(&self, bufs: &mut KernelBuffers<T>, kernel: &Kernel) {
        let extents = kernel.extents();
        let basis = self.schedule.basis();
        if basis.is_rect() {
            if let Some(gf) = GemmForm::of(kernel) {
                let views = kernel_views(kernel);
                let lo = vec![0i64; extents.len()];
                let plan = gf.plan_box(&views, &lo, extents);
                let lp = self.level.unwrap_or_else(|| {
                    LevelPlan::heuristic(
                        gf.l1_tile(basis),
                        (gf.m, gf.n, gf.k),
                        T::ELEM,
                        &CacheSpec::HASWELL_L2,
                        Some(&CacheSpec::HASWELL_L3_SLICE),
                    )
                });
                run_macro_with(
                    &mut bufs.arena,
                    &plan,
                    &lp,
                    &mut PackedRows::<T>::new(),
                    &mut PackedCols::<T>::new(),
                    ExecOpts::new(self.micro).with_acc64(self.acc64),
                );
                return;
            }
        }
        // Skewed tiles (and rect tiles of non-GEMM kernels): every tile is
        // the translated prototile clipped to the domain box, so clipped
        // replay is exact — no per-point footpoint filtering anywhere.
        let rp = self.replay(kernel);
        let arena: &mut [T] = &mut bufs.arena;
        let mut scratch = ReplayScratch::<T>::default();
        self.schedule.scan_feet(extents, |foot| {
            rp.run_tile(arena, extents, foot, &mut scratch);
        });
    }

    /// Execute with single-level blocking only: the per-tile pack +
    /// microkernel nest (the engine before the macro-kernel layer), kept
    /// for A/B comparison in the benches and two-level tests. Skewed
    /// bases behave exactly like [`TiledExecutor::run`].
    pub fn run_l1_only<T: Scalar>(&self, bufs: &mut KernelBuffers<T>, kernel: &Kernel) {
        let extents = kernel.extents();
        let basis = self.schedule.basis();
        if basis.is_rect() {
            if let Some(gf) = GemmForm::of(kernel) {
                // a blocked nest packing each tile's operands, then MR×NR
                // register tiles; only boundary blocks clip. Reduction
                // axes outermost keep the per-element reduction order
                // ascending; rows above columns let the packed row block
                // (the larger pack) survive the column sweep.
                let views = kernel_views(kernel);
                let d = extents.len();
                let order: Vec<usize> = gf
                    .red_axes
                    .iter()
                    .chain(gf.row_axes.iter())
                    .chain(gf.col_axes.iter())
                    .copied()
                    .collect();
                let sizes: Vec<i64> = (0..d)
                    .map(|t| basis.basis()[(t, t)].max(1) as i64)
                    .collect();
                let row_red: Vec<usize> = gf
                    .row_axes
                    .iter()
                    .chain(gf.red_axes.iter())
                    .copied()
                    .collect();
                let col_red: Vec<usize> = gf
                    .col_axes
                    .iter()
                    .chain(gf.red_axes.iter())
                    .copied()
                    .collect();
                let opts = ExecOpts::new(self.micro).with_acc64(self.acc64);
                let mut packs = PackBuffers::<T>::new();
                // scratch plan reused across tiles: the per-tile loop is
                // allocation-free in steady state
                let mut plan = RunPlan::default();
                let arena: &mut [T] = &mut bufs.arena;
                scan_rect_tiles(&order, &sizes, extents, |lo, hi| {
                    gf.plan_box_into(&views, lo, hi, &mut plan);
                    run_rect_box_with(
                        arena,
                        &plan,
                        &mut packs,
                        box_key(&row_red, lo, hi),
                        box_key(&col_red, lo, hi),
                        opts,
                    );
                });
                return;
            }
        }
        let rp = self.replay(kernel);
        let arena: &mut [T] = &mut bufs.arena;
        let mut scratch = ReplayScratch::<T>::default();
        self.schedule.scan_feet(extents, |foot| {
            rp.run_tile(arena, extents, foot, &mut scratch);
        });
    }
}

/// Cache tag of a box along a subset of axes: `lo‖hi` restricted to the
/// axes the packed operand actually depends on — so e.g. a column-box
/// advance leaves the row pack cached.
pub fn box_key(axes: &[usize], lo: &[i64], hi: &[i64]) -> Vec<i64> {
    axes.iter()
        .flat_map(|&t| [lo[t], hi[t]])
        .collect()
}

/// Odometer over rectangular loop-space tiles: visit every clipped box of
/// the grid `sizes` covering `[0, extents)`, iterating `order[0]`
/// outermost and the last axis of `order` fastest. Yields `(lo, hi)`.
pub fn scan_rect_tiles<F: FnMut(&[i64], &[i64])>(
    order: &[usize],
    sizes: &[i64],
    extents: &[i64],
    mut f: F,
) {
    let d = extents.len();
    assert_eq!(order.len(), d);
    assert_eq!(sizes.len(), d);
    if extents.iter().any(|&e| e <= 0) {
        return;
    }
    let mut lo = vec![0i64; d];
    let mut hi: Vec<i64> = (0..d).map(|t| sizes[t].min(extents[t])).collect();
    'outer: loop {
        f(&lo, &hi);
        let mut idx = order.len();
        loop {
            if idx == 0 {
                break 'outer;
            }
            idx -= 1;
            let t = order[idx];
            lo[t] += sizes[t];
            if lo[t] < extents[t] {
                hi[t] = (lo[t] + sizes[t]).min(extents[t]);
                continue 'outer;
            }
            lo[t] = 0;
            hi[t] = sizes[t].min(extents[t]);
        }
    }
}

/// Is this plan the degenerate `m = n = 1` GEMM form (scalar product,
/// convolution, any fully-reduced box)? Those run the dot microkernel
/// straight from the arena — `MR×NRW` panels would be `1/(MR·NRW)` live.
pub(crate) fn is_dot_plan(plan: &RunPlan) -> bool {
    plan.m == 1 && plan.n == 1
}

/// Run a degenerate plan through [`dot_update`] (shared with the
/// parallel executor's `m = n = 1` short-circuit).
pub(crate) fn run_dot<T: Scalar>(arena: &mut [T], plan: &RunPlan) {
    run_dot_acc(arena, plan, false);
}

/// [`run_dot`] with the wide-accumulation flag (the degenerate forms'
/// `f32acc64` path).
pub(crate) fn run_dot_acc<T: Scalar>(arena: &mut [T], plan: &RunPlan, acc64: bool) {
    // a 1-row box always lowers to exactly one run today; assert for real
    // (not debug) so a future multi-run degenerate form fails loudly
    // instead of silently dropping runs past the first
    assert!(is_dot_plan(plan) && plan.runs.len() == 1);
    let out = (plan.runs[0].out + plan.col_out[0]) as usize;
    if acc64 {
        super::microkernel::dot_update_acc::<T, T::Acc>(
            arena,
            out,
            plan.runs[0].row,
            plan.col_in[0],
            &plan.red_row,
            &plan.red_col,
        );
    } else {
        dot_update(
            arena,
            out,
            plan.runs[0].row,
            plan.col_in[0],
            &plan.red_row,
            &plan.red_col,
        );
    }
}

/// Execute the whole kernel as the three-level macro/micro nest (the
/// BLIS-style macro-kernel under an L3 super-band partition) over its
/// whole-domain [`RunPlan`]:
///
/// ```text
///   for i3 by m3:                L3 super-band rows (mc-aligned)
///     for j3 by n3:              L3 super-band columns (nc-aligned)
///       for k0 by kc:            pack the band's mc-row blocks once
///         for j0 by nc in band:  pack the kc×nc column band once
///           for each row block:  run all L1 tiles from the packed panels
/// ```
///
/// Within one super-band each row block is packed exactly once per
/// reduction slice and each column band once per `(k0, j0)` — the arena
/// is streamed a number of times independent of the L1 tile size. The
/// super-band level bounds the packed row slice to `m3×kc` (an L3-slice
/// quarter under the heuristic plans) so L3-exceeding row extents stop
/// thrashing the last-level cache, and it is the exact schedule the
/// parallel executor hands out: one super-band = one worker claim, so
/// serial and parallel traces coincide per band. The packed buffers are
/// caller-owned so tests can assert the pack counts.
///
/// Degenerate `m = n = 1` plans (scalar product, convolution) skip the
/// pack/block machinery and stream both operands once through the dot
/// microkernel — the packed buffers stay untouched.
pub fn run_macro<T: Scalar>(
    arena: &mut [T],
    plan: &RunPlan,
    lp: &LevelPlan,
    micro: MicroShape,
    rows: &mut PackedRows<T>,
    cols: &mut PackedCols<T>,
) {
    run_macro_with(arena, plan, lp, rows, cols, ExecOpts::new(micro));
}

/// [`run_macro`] with the wide-accumulation flag — the precision-aware
/// wrapper (`acc64` = [`Precision::wide_acc`] of the execution's
/// precision pair).
#[allow(clippy::too_many_arguments)]
pub fn run_macro_acc<T: Scalar>(
    arena: &mut [T],
    plan: &RunPlan,
    lp: &LevelPlan,
    micro: MicroShape,
    rows: &mut PackedRows<T>,
    cols: &mut PackedCols<T>,
    acc64: bool,
) {
    run_macro_with(
        arena,
        plan,
        lp,
        rows,
        cols,
        ExecOpts::new(micro).with_acc64(acc64),
    );
}

/// The serial macro-kernel's canonical entry point: [`run_macro`]'s nest
/// under one [`ExecOpts`] params struct (geometry + precision; the
/// parallel tuning field is ignored here).
pub fn run_macro_with<T: Scalar>(
    arena: &mut [T],
    plan: &RunPlan,
    lp: &LevelPlan,
    rows: &mut PackedRows<T>,
    cols: &mut PackedCols<T>,
    opts: ExecOpts,
) {
    let (micro, acc64) = (opts.micro, opts.acc64);
    if plan.m == 0 || plan.n == 0 || plan.k == 0 {
        return;
    }
    if is_dot_plan(plan) {
        run_dot_acc(arena, plan, acc64);
        return;
    }
    rows.set_mr(micro.mr());
    match T::nr(micro) {
        4 => run_macro_impl::<T, 4>(arena, plan, lp, rows, cols, acc64),
        6 => run_macro_impl::<T, 6>(arena, plan, lp, rows, cols, acc64),
        8 => run_macro_impl::<T, 8>(arena, plan, lp, rows, cols, acc64),
        12 => run_macro_impl::<T, 12>(arena, plan, lp, rows, cols, acc64),
        w => unreachable!("unsupported register-tile width {w}"),
    }
}

/// Normalize a plan's super-band extents: `m3` aligned down to a
/// non-zero multiple of `mc`, `n3` to a multiple of `nc`. The mc/nc
/// alignment keeps super-band boundaries on whole row blocks / column
/// bands, which is what lets the pre-packed serve path select block
/// subranges of full-width packed slices.
pub(crate) fn super_band_extents(lp: &LevelPlan) -> (usize, usize) {
    let mc = lp.mc.max(1);
    let nc = lp.nc.max(1);
    ((lp.m3 / mc).max(1) * mc, (lp.n3 / nc).max(1) * nc)
}

fn run_macro_impl<T: Scalar, const NRW: usize>(
    arena: &mut [T],
    plan: &RunPlan,
    lp: &LevelPlan,
    rows: &mut PackedRows<T>,
    cols: &mut PackedCols<T>,
    acc64: bool,
) {
    let (m3, n3) = super_band_extents(lp);
    for i3 in (0..plan.m).step_by(m3) {
        let m3c = m3.min(plan.m - i3);
        for j3 in (0..plan.n).step_by(n3) {
            let n3c = n3.min(plan.n - j3);
            run_super_band::<T, NRW>(
                arena,
                plan,
                lp,
                rows,
                cols,
                (i3, m3c),
                (j3, n3c),
                acc64,
            );
        }
    }
}

/// One `m3×n3` L3 super-band of the three-level nest: rows
/// `[i3, i3+m3c)` × output columns `[j3, j3+n3c)`, full reduction. Per
/// `kc` step the band's own row slice is packed once into the
/// caller-owned buffers and every column band inside the range is driven
/// from it — the inner nest shared by the serial executor and by one
/// parallel worker's claimed super-band. Returns
/// `(row_slice_packs, col_band_packs)`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_super_band<T: Scalar, const NRW: usize>(
    arena: &mut [T],
    plan: &RunPlan,
    lp: &LevelPlan,
    rows: &mut PackedRows<T>,
    cols: &mut PackedCols<T>,
    (i3, m3c): (usize, usize),
    (j3, n3c): (usize, usize),
    acc64: bool,
) -> (u64, u64) {
    let mc = lp.mc.max(1);
    let kc = lp.kc.max(1);
    let nc = lp.nc.max(1);
    let l1 = (lp.l1_tile.0, lp.l1_tile.1);
    let (mut row_packs, mut col_packs) = (0u64, 0u64);
    for k0 in (0..plan.k).step_by(kc) {
        let kcc = (k0 + kc).min(plan.k) - k0;
        rows.pack_slice_range(arena, plan, mc, i3, m3c, k0, kcc);
        row_packs += 1;
        for j0 in (j3..j3 + n3c).step_by(nc) {
            let ncc = (j0 + nc).min(j3 + n3c) - j0;
            // chaos hook: a scoped fault schedule may panic here to model
            // a failure mid-pack (no-op unless test/fault-injection)
            crate::coordinator::faults::raise_if(crate::coordinator::faults::FaultPoint::Pack);
            cols.pack_band::<NRW>(arena, plan, k0, kcc, j0, ncc);
            col_packs += 1;
            for bi in 0..rows.n_blocks() {
                run_macro_block_acc::<T, NRW>(
                    rows.block(bi),
                    cols,
                    plan,
                    j0,
                    l1,
                    arena,
                    acc64,
                );
            }
        }
    }
    (row_packs, col_packs)
}

/// Pack one pipeline stage — `key`'s row slice (unless the nest reads
/// resident rows: `pack_rows = false`) plus every `nc` column band of
/// `key`'s column range — into `stage`. This is [`run_super_band`]'s
/// per-`kc`-step packing half, split out so the pipelined scheduler can
/// run it on the pack-ahead path (filling stage `k0+kc` while the
/// microkernel streams stage `k0`) against a **read-only** view of the
/// arena: it touches input-operand bytes only, which no thread writes
/// during a run. Returns `(row_slice_packs, col_band_packs)` with the
/// same per-call accounting as [`run_super_band`].
pub(crate) fn pack_super_band_stage<T: Scalar, const NRW: usize>(
    arena: &[T],
    plan: &RunPlan,
    lp: &LevelPlan,
    stage: &mut PackStage<T>,
    key: StageKey,
    pack_rows: bool,
    mr: usize,
) -> (u64, u64) {
    let mc = lp.mc.max(1);
    let nc = lp.nc.max(1);
    let (mut row_packs, mut col_packs) = (0u64, 0u64);
    stage.invalidate();
    if pack_rows {
        stage.rows.set_mr(mr);
        stage
            .rows
            .pack_slice_range(arena, plan, mc, key.r0, key.rows, key.k0, key.kcc);
        row_packs += 1;
    }
    let mut slot = 0usize;
    for j0 in (key.j3..key.j3 + key.n3c).step_by(nc) {
        let ncc = (j0 + nc).min(key.j3 + key.n3c) - j0;
        // chaos hook: a scoped fault schedule may panic here to model a
        // failure mid-pack (no-op unless test/fault-injection)
        crate::coordinator::faults::raise_if(crate::coordinator::faults::FaultPoint::Pack);
        if stage.cols.len() == slot {
            stage.cols.push(PackedCols::new());
        }
        stage.cols[slot].pack_band::<NRW>(arena, plan, key.k0, key.kcc, j0, ncc);
        stage.bands.push((j0, ncc));
        col_packs += 1;
        slot += 1;
    }
    stage.set_key(key);
    (row_packs, col_packs)
}

/// Stream one packed pipeline stage through the microkernel —
/// [`run_super_band`]'s compute half. `key` names the schedule step the
/// caller expects; it must equal the stage's packed key (the rotation
/// replay guard). `resident` switches the row source: `Some(rows)` reads
/// whole-extent resident slices (`rows[key.si]`, blocks
/// `[blocks.start, blocks.end)` absolute — the prepacked nest), `None`
/// reads the stage's own row slice (blocks relative to the packed
/// range). The band → block order is exactly the synchronous nest's
/// `j0 → bi` order, so every output element accumulates its `kc` slices
/// in the same ascending-`k0` sequence as the serial schedule — the
/// pipeline reorders packing, never accumulation.
#[allow(clippy::too_many_arguments)]
pub(crate) fn compute_super_band_stage<T: Scalar, const NRW: usize>(
    arena: &mut [T],
    plan: &RunPlan,
    lp: &LevelPlan,
    stage: &PackStage<T>,
    key: &StageKey,
    resident: Option<&[PackedRows<T>]>,
    blocks: std::ops::Range<usize>,
    acc64: bool,
) {
    assert_eq!(
        stage.key(),
        Some(key),
        "pipeline stage panels do not match the schedule step"
    );
    let l1 = (lp.l1_tile.0, lp.l1_tile.1);
    for (slot, &(j0, _ncc)) in stage.bands.iter().enumerate() {
        let band = &stage.cols[slot];
        for bi in blocks.clone() {
            let block = match resident {
                Some(rows) => rows[key.si].block(bi),
                None => stage.rows.block(bi),
            };
            run_macro_block_acc::<T, NRW>(block, band, plan, j0, l1, arena, acc64);
        }
    }
}

/// Pre-pack every `kc` reduction slice of the plan's row operand — for
/// callers whose row operand is **constant across runs** (the native
/// serve backend's resident weights): pay the row-panel copies once,
/// then drive [`run_macro_prepacked`] per run. Slices follow exactly the
/// `k0` stepping of [`run_macro`] under the same `lp`.
pub fn pack_row_slices<T: Scalar>(
    arena: &[T],
    plan: &RunPlan,
    lp: &LevelPlan,
) -> Vec<PackedRows<T>> {
    pack_row_slices_mr(arena, plan, lp, MR)
}

/// [`pack_row_slices`] at an explicit panel height — the dispatched
/// geometry's `micro.mr()`, so resident slices match the shape the serve
/// path will stream them with.
pub fn pack_row_slices_mr<T: Scalar>(
    arena: &[T],
    plan: &RunPlan,
    lp: &LevelPlan,
    mr: usize,
) -> Vec<PackedRows<T>> {
    let mc = lp.mc.max(1);
    let kc = lp.kc.max(1);
    (0..plan.k)
        .step_by(kc)
        .map(|k0| {
            let kcc = (k0 + kc).min(plan.k) - k0;
            let mut pr = PackedRows::new();
            pr.set_mr(mr);
            pr.pack_slice(arena, plan, mc, k0, kcc);
            pr
        })
        .collect()
}

/// [`run_macro`] over row slices packed ahead of time by
/// [`pack_row_slices`] (same plan, same `lp`): only the column operand
/// is packed per call, so a serve loop with resident weights never
/// re-copies them. The pre-packed slices span the full row extent; the
/// super-band nest selects whole mc-row block subranges of each slice
/// (super-band boundaries are mc-aligned by [`super_band_extents`]), so
/// the serve path follows the same three-level schedule as [`run_macro`]
/// without duplicating the resident panels. Like the serial and
/// parallel nests, a plan with several row super-bands re-packs each
/// column band once per row band — the deliberate locality price that
/// keeps the streamed row panels L3-resident on shapes big enough to
/// split (single-band plans, the common serve case, pack each band
/// exactly once). The row-operand bytes must be unchanged since the
/// slices were packed; degenerate `m = n = 1` plans take the dot path
/// and ignore `rows`.
pub fn run_macro_prepacked<T: Scalar>(
    arena: &mut [T],
    plan: &RunPlan,
    lp: &LevelPlan,
    micro: MicroShape,
    rows: &[PackedRows<T>],
    cols: &mut PackedCols<T>,
) {
    let _ = run_macro_prepacked_cols(arena, plan, lp, micro, rows, cols, plan.n);
}

/// The pre-packed nest's canonical entry point:
/// [`run_macro_prepacked_cols`] under one [`ExecOpts`] params struct
/// (geometry + precision; the parallel tuning field is ignored here) —
/// the serve path's precision-aware column-prefix dispatch.
pub fn run_macro_prepacked_with<T: Scalar>(
    arena: &mut [T],
    plan: &RunPlan,
    lp: &LevelPlan,
    rows: &[PackedRows<T>],
    cols: &mut PackedCols<T>,
    n_used: usize,
    opts: ExecOpts,
) -> u64 {
    let (micro, acc64) = (opts.micro, opts.acc64);
    assert!(n_used <= plan.n, "column prefix exceeds the plan");
    if plan.m == 0 || n_used == 0 || plan.k == 0 {
        return 0;
    }
    if is_dot_plan(plan) {
        run_dot_acc(arena, plan, acc64);
        return 0;
    }
    let kc = lp.kc.max(1);
    assert_eq!(
        rows.len(),
        plan.k.div_ceil(kc),
        "pre-packed slices do not match the macro shape"
    );
    assert!(
        rows.iter().all(|r| r.mr() == micro.mr()),
        "pre-packed slices were packed at a different panel height than \
         the dispatched geometry"
    );
    match T::nr(micro) {
        4 => run_macro_prepacked_impl::<T, 4>(arena, plan, lp, rows, cols, n_used, acc64),
        6 => run_macro_prepacked_impl::<T, 6>(arena, plan, lp, rows, cols, n_used, acc64),
        8 => run_macro_prepacked_impl::<T, 8>(arena, plan, lp, rows, cols, n_used, acc64),
        12 => {
            run_macro_prepacked_impl::<T, 12>(arena, plan, lp, rows, cols, n_used, acc64)
        }
        w => unreachable!("unsupported register-tile width {w}"),
    }
}

/// [`run_macro_prepacked`] restricted to the **column prefix**
/// `[0, n_used)` of the plan — the serve coalescer's partial-batch entry
/// point. The plan's per-column offset tables (`col_out`/`col_in`) are
/// indexed by absolute column, so executing a prefix of a wide plan
/// touches exactly the same offsets a narrower plan would: a batch of
/// `B < max_batch` jobs runs the first `B·m` columns of the
/// `max_batch`-wide plan, with the pre-packed row slices (which depend
/// only on rows × reduction, never on the column extent) shared as-is.
/// `n_used = plan.n` is exactly [`run_macro_prepacked`]. Returns the
/// number of column-band packs performed — the serve layer's
/// pack-discipline tests pin it to exactly one per (row super-band, `kc`
/// slice, `nc` band), independent of the batch width.
pub fn run_macro_prepacked_cols<T: Scalar>(
    arena: &mut [T],
    plan: &RunPlan,
    lp: &LevelPlan,
    micro: MicroShape,
    rows: &[PackedRows<T>],
    cols: &mut PackedCols<T>,
    n_used: usize,
) -> u64 {
    run_macro_prepacked_with(arena, plan, lp, rows, cols, n_used, ExecOpts::new(micro))
}

#[allow(clippy::too_many_arguments)]
fn run_macro_prepacked_impl<T: Scalar, const NRW: usize>(
    arena: &mut [T],
    plan: &RunPlan,
    lp: &LevelPlan,
    rows: &[PackedRows<T>],
    cols: &mut PackedCols<T>,
    n_used: usize,
    acc64: bool,
) -> u64 {
    let (m3, n3) = super_band_extents(lp);
    let mut col_packs = 0u64;
    for i3 in (0..plan.m).step_by(m3) {
        let m3c = m3.min(plan.m - i3);
        for j3 in (0..n_used).step_by(n3) {
            let n3c = n3.min(n_used - j3);
            col_packs += run_super_band_prepacked::<T, NRW>(
                arena,
                plan,
                lp,
                rows,
                cols,
                (i3, m3c),
                (j3, n3c),
                acc64,
            );
        }
    }
    col_packs
}

/// One L3 super-band of the pre-packed nest: like [`run_super_band`] but
/// reading whole mc-block subranges of the caller's full-width resident
/// row slices instead of packing a row slice per `kc` step (`m3` is an
/// mc multiple by [`super_band_extents`], so a super-band's rows are
/// whole blocks). Only the column bands are packed; returns how many.
/// Shared by the serial pre-packed nest and by one parallel worker's
/// claimed super-band, so both walk one schedule.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_super_band_prepacked<T: Scalar, const NRW: usize>(
    arena: &mut [T],
    plan: &RunPlan,
    lp: &LevelPlan,
    rows: &[PackedRows<T>],
    cols: &mut PackedCols<T>,
    (i3, m3c): (usize, usize),
    (j3, n3c): (usize, usize),
    acc64: bool,
) -> u64 {
    let mc = lp.mc.max(1);
    let kc = lp.kc.max(1);
    let nc = lp.nc.max(1);
    let l1 = (lp.l1_tile.0, lp.l1_tile.1);
    let b0 = i3 / mc;
    let b1 = (i3 + m3c).div_ceil(mc);
    let mut col_packs = 0u64;
    for (si, k0) in (0..plan.k).step_by(kc).enumerate() {
        let kcc = (k0 + kc).min(plan.k) - k0;
        for j0 in (j3..j3 + n3c).step_by(nc) {
            let ncc = (j0 + nc).min(j3 + n3c) - j0;
            // chaos hook: a scoped fault schedule may panic here to model
            // a failure mid-pack (no-op unless test/fault-injection)
            crate::coordinator::faults::raise_if(crate::coordinator::faults::FaultPoint::Pack);
            cols.pack_band::<NRW>(arena, plan, k0, kcc, j0, ncc);
            col_packs += 1;
            for bi in b0..b1 {
                run_macro_block_acc::<T, NRW>(
                    rows[si].block(bi),
                    cols,
                    plan,
                    j0,
                    l1,
                    arena,
                    acc64,
                );
            }
        }
    }
    col_packs
}

/// Execute one clipped box through the pack + microkernel engine — the
/// per-tile rect dispatch shared by the serial and parallel executors.
/// Packed blocks are reused across consecutive calls via the caller's
/// box keys (see [`box_key`]). Degenerate `m = n = 1` boxes run the dot
/// microkernel without packing.
pub fn run_rect_box_with<T: Scalar>(
    arena: &mut [T],
    plan: &RunPlan,
    packs: &mut PackBuffers<T>,
    row_key: Vec<i64>,
    col_key: Vec<i64>,
    opts: ExecOpts,
) {
    let (micro, acc64) = (opts.micro, opts.acc64);
    if plan.m == 0 || plan.n == 0 || plan.k == 0 {
        return;
    }
    if is_dot_plan(plan) {
        run_dot_acc(arena, plan, acc64);
        return;
    }
    packs.set_mr(micro.mr());
    packs.pack_rows_cached(arena, plan, row_key);
    match T::nr(micro) {
        4 => {
            packs.pack_cols_cached::<4>(arena, plan, col_key);
            packs.run_box_acc::<4>(arena, plan, acc64);
        }
        6 => {
            packs.pack_cols_cached::<6>(arena, plan, col_key);
            packs.run_box_acc::<6>(arena, plan, acc64);
        }
        8 => {
            packs.pack_cols_cached::<8>(arena, plan, col_key);
            packs.run_box_acc::<8>(arena, plan, acc64);
        }
        12 => {
            packs.pack_cols_cached::<12>(arena, plan, col_key);
            packs.run_box_acc::<12>(arena, plan, acc64);
        }
        w => unreachable!("unsupported register-tile width {w}"),
    }
}

/// Enumerate the integer points of the prototile (footpoint 0) of a tile
/// basis, lexicographically sorted. Prototile points can have negative
/// coordinates for skewed bases, so this scans the bounding box of
/// `P·[0,1]^d` without clipping.
pub fn prototile_points(basis: &TileBasis) -> Vec<Vec<i64>> {
    let d = basis.dim();
    if basis.is_rect() {
        // the prototile of diag(s) is the box [0,s) — no scan needed
        let sizes: Vec<i64> = (0..d).map(|i| basis.basis()[(i, i)] as i64).collect();
        let mut out = Vec::with_capacity(basis.volume() as usize);
        let mut x = vec![0i64; d];
        'outer: loop {
            out.push(x.clone());
            let mut j = d;
            loop {
                if j == 0 {
                    break 'outer;
                }
                j -= 1;
                x[j] += 1;
                if x[j] < sizes[j] {
                    continue 'outer;
                }
                x[j] = 0;
            }
        }
        return out;
    }
    let mut lo = vec![i128::MAX; d];
    let mut hi = vec![i128::MIN; d];
    for mask in 0..(1usize << d) {
        let corner: Vec<i128> = (0..d).map(|i| ((mask >> i) & 1) as i128).collect();
        let v = basis.basis().mul_vec(&corner);
        for j in 0..d {
            lo[j] = lo[j].min(v[j]);
            hi[j] = hi[j].max(v[j]);
        }
    }
    let mut proto = Vec::new();
    let mut cur = lo.clone();
    let mut x = vec![0i64; d];
    'outer: loop {
        for j in 0..d {
            x[j] = cur[j] as i64;
        }
        if basis.in_prototile(&x) {
            proto.push(x.clone());
        }
        let mut j = d;
        loop {
            if j == 0 {
                break 'outer;
            }
            j -= 1;
            cur[j] += 1;
            if cur[j] <= hi[j] {
                continue 'outer;
            }
            cur[j] = lo[j];
        }
    }
    proto.sort();
    assert_eq!(proto.len() as i128, basis.volume());
    proto
}

/// Convenience: make a `TiledExecutor` from a tile basis.
pub fn tiled_executor(basis: TileBasis) -> TiledExecutor {
    TiledExecutor::new(TiledSchedule::new(basis))
}

/// Max |a−b| over two equal-length scalar slices, as f64.
pub fn max_abs_diff<T: Scalar>(a: &[T], b: &[T]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x.to_f64() - y.to_f64()).abs())
        .fold(0.0, f64::max)
}

/// Did the kernel declare a writable first operand? (sanity helper)
pub fn writes_first_operand(kernel: &Kernel) -> bool {
    matches!(
        kernel.operand(0).role,
        OpRole::Write | OpRole::ReadWrite
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domain::access::AffineAccess;
    use crate::domain::ops;
    use crate::domain::{IterOrder, Operand};
    use crate::index::{Layout, Table};
    use crate::lattice::IMat;

    fn check_correct(kernel: &Kernel, scanner: &dyn Scanner) {
        let mut bufs = KernelBuffers::<f64>::from_kernel(kernel);
        let want = bufs.reference();
        run_schedule(&mut bufs, kernel, scanner);
        let got = bufs.output();
        assert!(
            max_abs_diff(&want, &got) < 1e-9,
            "schedule result mismatch"
        );
    }

    fn check_executor(kernel: &Kernel, basis: TileBasis) {
        let exec = TiledExecutor::new(TiledSchedule::new(basis));
        let mut bufs = KernelBuffers::<f64>::from_kernel(kernel);
        let want = bufs.reference();
        exec.run(&mut bufs, kernel);
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    }

    #[test]
    fn naive_orders_correct() {
        let k = ops::matmul(13, 7, 9, 8, 0);
        for o in IterOrder::all(3) {
            check_correct(&k, &o);
        }
    }

    #[test]
    fn rect_tiled_correct() {
        let k = ops::matmul(17, 11, 13, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[4, 5, 3]));
        check_correct(&k, &s);
    }

    #[test]
    fn lattice_tiled_correct() {
        let k = ops::matmul(16, 16, 16, 8, 0);
        // skewed tile on (i, kk), rect on j
        let basis = TileBasis::from_cols(IMat::from_rows(&[
            &[3, 0, 1],
            &[0, 4, 0],
            &[1, 0, 4],
        ]));
        let s = TiledSchedule::new(basis);
        check_correct(&k, &s);
    }

    #[test]
    fn padded_buffers_correct() {
        let k = ops::matmul_padded(9, 8, 7, 12, 11, 10, 8, 256);
        check_correct(&k, &IterOrder::lex(3));
    }

    #[test]
    fn tiled_executor_matches_schedule_run() {
        let k = ops::matmul(20, 18, 22, 8, 0);
        let basis = TileBasis::from_cols(IMat::from_rows(&[
            &[5, 0, 2],
            &[0, 6, 0],
            &[-1, 0, 4],
        ]));
        check_executor(&k, basis);
    }

    #[test]
    fn rect_executor_packs_non_multiple_extents() {
        // extents not multiples of the tile, tile not a multiple of MR/NR
        let k = ops::matmul(21, 9, 11, 8, 0);
        check_executor(&k, TileBasis::rect(&[10, 6, 5]));
        // tile bigger than the whole domain
        let k = ops::matmul(5, 3, 2, 8, 0);
        check_executor(&k, TileBasis::rect(&[16, 16, 16]));
    }

    #[test]
    fn rect_executor_handles_padded_layouts() {
        let k = ops::matmul_padded(13, 7, 9, 17, 15, 11, 8, 64);
        check_executor(&k, TileBasis::rect(&[8, 4, 4]));
    }

    #[test]
    fn rect_executor_runs_convolution_and_kronecker() {
        check_executor(&ops::convolution(37, 8, 0), TileBasis::rect(&[8]));
        check_executor(&ops::scalar_product(29, 8, 16), TileBasis::rect(&[16]));
        check_executor(
            &ops::kronecker(5, 3, 7, 4, 8, 0),
            TileBasis::rect(&[2, 2, 4, 3]),
        );
    }

    #[test]
    fn f32_executor_matches_reference() {
        // the same engine at f32: rect macro path, skewed replay path and
        // the degenerate dot path, against the f32 oracle
        for (kernel, basis) in [
            (ops::matmul(21, 9, 11, 4, 0), TileBasis::rect(&[10, 6, 5])),
            (ops::convolution(37, 4, 0), TileBasis::rect(&[8])),
            (
                ops::kronecker(5, 3, 7, 4, 4, 0),
                TileBasis::rect(&[2, 2, 4, 3]),
            ),
        ] {
            let exec = TiledExecutor::new(TiledSchedule::new(basis));
            let mut bufs = KernelBuffers::<f32>::from_kernel(&kernel);
            bufs.fill_ints(3, 0x32);
            let want = bufs.reference();
            exec.run(&mut bufs, &kernel);
            assert_eq!(bufs.output(), want, "{} f32", kernel.name());
        }
        // skewed f32 matmul through the panel-replay path
        let k = ops::matmul(16, 16, 16, 4, 0);
        let basis = TileBasis::from_cols(IMat::from_rows(&[
            &[3, 0, 1],
            &[0, 4, 0],
            &[1, 0, 4],
        ]));
        let exec = TiledExecutor::new(TiledSchedule::new(basis));
        assert!(exec.replay(&k).panel_replay());
        let mut bufs = KernelBuffers::<f32>::from_kernel(&k);
        bufs.fill_ints(3, 0x33);
        let want = bufs.reference();
        exec.run(&mut bufs, &k);
        assert_eq!(bufs.output(), want, "f32 skewed replay");
    }

    #[test]
    fn macro_run_matches_l1_only_run() {
        let k = ops::matmul(33, 21, 27, 8, 0);
        let exec = TiledExecutor::new(TiledSchedule::new(TileBasis::rect(&[10, 6, 5])))
            .with_level_plan(LevelPlan {
                l1_tile: (10, 6, 5),
                mc: 14,
                kc: 9,
                nc: 11,
                m3: 28,
                n3: 22,
            });
        let mut macro_bufs = KernelBuffers::<f64>::from_kernel(&k);
        exec.run(&mut macro_bufs, &k);
        let mut l1_bufs = KernelBuffers::<f64>::from_kernel(&k);
        exec.run_l1_only(&mut l1_bufs, &k);
        assert!(max_abs_diff(&macro_bufs.output(), &l1_bufs.output()) < 1e-9);
        assert!(max_abs_diff(&macro_bufs.reference(), &macro_bufs.output()) < 1e-9);
    }

    #[test]
    fn super_band_schedule_matches_flat_schedule_bitwise() {
        // the three-level nest re-orders only whole super-bands (disjoint
        // output element sets, same per-element reduction order): with
        // integer fills the flat and super-band schedules must agree bit
        // for bit — and both with the oracle
        let k = ops::matmul(37, 23, 29, 8, 0);
        let views = kernel_views(&k);
        let gf = GemmForm::of(&k).unwrap();
        let plan = gf.plan_box(&views, &[0, 0, 0], k.extents());
        let flat = LevelPlan::flat((8, 8, 8), 10, 7, 6);
        let sup = LevelPlan {
            m3: 20,
            n3: 12,
            ..flat
        };
        let mut a = KernelBuffers::<f64>::from_kernel(&k);
        a.fill_ints(3, 0x3B);
        let mut b = a.clone();
        let want = a.reference();
        run_macro(
            &mut a.arena,
            &plan,
            &flat,
            MicroShape::Mr8Nr4,
            &mut PackedRows::new(),
            &mut PackedCols::new(),
        );
        run_macro(
            &mut b.arena,
            &plan,
            &sup,
            MicroShape::Mr8Nr4,
            &mut PackedRows::new(),
            &mut PackedCols::new(),
        );
        assert_eq!(a.output(), want, "flat schedule diverged");
        assert_eq!(b.output(), want, "super-band schedule diverged");
    }

    #[test]
    fn super_band_nest_packs_per_band_per_slice() {
        // the pack discipline of the three-level nest, counted: each
        // super-band packs its own row blocks once per reduction slice
        // (duplicated across column super-bands — the locality price the
        // schedule pays deliberately), each column band once per
        // (super-band, slice)
        let (m, k, n) = (40usize, 14, 22);
        let kernel = ops::matmul(m as i64, k as i64, n as i64, 8, 0);
        let lp = LevelPlan {
            l1_tile: (8, 8, 8),
            mc: 8,
            kc: 7,
            nc: 5,
            m3: 16,
            n3: 10,
        };
        let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
        let want = bufs.reference();
        let gf = GemmForm::of(&kernel).unwrap();
        let plan = gf.plan_box(&kernel_views(&kernel), &[0, 0, 0], kernel.extents());
        let mut pr = PackedRows::<f64>::new();
        let mut pc = PackedCols::<f64>::new();
        run_macro(&mut bufs.arena, &plan, &lp, MicroShape::Mr8Nr4, &mut pr, &mut pc);
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
        let kslices = k.div_ceil(lp.kc) as u64; // 2
        // row bands: 16, 16, 8 rows → 2 + 2 + 1 mc-blocks, repacked per
        // column super-band (3) per slice
        let row_blocks: u64 = [16u64, 16, 8].iter().map(|r| r.div_ceil(8)).sum();
        let n_j3 = (n as u64).div_ceil(lp.n3 as u64); // 3
        assert_eq!(pr.pack_count(), row_blocks * n_j3 * kslices);
        // column bands per column super-band: 10, 10, 2 cols → 2 + 2 + 1,
        // once per row super-band (3) per slice
        let col_bands: u64 = [10u64, 10, 2].iter().map(|c| c.div_ceil(5)).sum();
        let n_i3 = (m as u64).div_ceil(lp.m3 as u64); // 3
        assert_eq!(pc.pack_count(), col_bands * n_i3 * kslices);
    }

    #[test]
    fn wide_micro_shape_matches_default() {
        let k = ops::matmul(26, 17, 23, 8, 0);
        let sched = TiledSchedule::new(TileBasis::rect(&[8, 12, 6]));
        let mut narrow = KernelBuffers::<f64>::from_kernel(&k);
        TiledExecutor::new(sched.clone()).run(&mut narrow, &k);
        for micro in [MicroShape::Mr8Nr6, MicroShape::Mr16Nr4, MicroShape::Mr16Nr6] {
            let mut other = KernelBuffers::<f64>::from_kernel(&k);
            TiledExecutor::new(sched.clone())
                .with_micro_shape(micro)
                .run(&mut other, &k);
            assert!(max_abs_diff(&narrow.output(), &other.output()) < 1e-9, "{micro:?}");
            assert!(max_abs_diff(&narrow.reference(), &other.output()) < 1e-9, "{micro:?}");
        }
    }

    #[test]
    fn wide_acc_executor_is_single_rounding_per_element() {
        use super::super::scalar::Precision;
        // f32acc64 through the full tiled executor: equals the f64
        // product-sum over the same f32 inputs, rounded once per element
        let k = ops::matmul(22, 37, 18, 4, 0);
        let sched = TiledSchedule::new(TileBasis::rect(&[8, 8, 8]));
        let mut bufs = KernelBuffers::<f32>::from_kernel(&k);
        // cancellation-heavy mixed-sign fill
        for (i, v) in bufs.arena.iter_mut().enumerate() {
            *v = if i % 2 == 0 {
                1.0 + ((i % 13) as f32) * 2.0f32.powi(-12)
            } else {
                -1.0 + ((i % 7) as f32) * 2.0f32.powi(-11)
            };
        }
        bufs.reset_output();
        let gf = GemmForm::of(&k).unwrap();
        let plan = gf.plan_box(&kernel_views(&k), &[0, 0, 0], k.extents());
        // f64 oracle over the widened f32 inputs
        let run = plan.runs[0];
        let mut want = vec![0.0f32; plan.m * plan.n];
        for r in 0..plan.m {
            for c in 0..plan.n {
                let mut acc = 0.0f64;
                for (&rr, &rc) in plan.red_row.iter().zip(&plan.red_col) {
                    acc += bufs.arena[(run.row + rr) as usize + r] as f64
                        * bufs.arena[(plan.col_in[c] + rc) as usize] as f64;
                }
                want[c * plan.m + r] = acc as f32;
            }
        }
        // one kc slice spanning the whole reduction: each element then
        // accumulates in exactly one register-tile call, so the widened
        // accumulator's single-rounding contract holds end to end
        TiledExecutor::new(sched)
            .with_level_plan(LevelPlan {
                l1_tile: (8, 8, 8),
                mc: 12,
                kc: 37,
                nc: 9,
                m3: 24,
                n3: 18,
            })
            .with_precision(Precision::F32ACC64)
            .run(&mut bufs, &k);
        assert_eq!(bufs.output(), want, "acc64 executor not single-rounding");
    }

    #[test]
    fn prepacked_macro_matches_run_macro_and_never_repacks() {
        // the serve path's steady state: rows packed once, then many runs
        // against changing column-operand data
        let k = ops::matmul(26, 19, 23, 8, 0);
        let views = kernel_views(&k);
        let gf = GemmForm::of(&k).unwrap();
        let plan = gf.plan_box(&views, &[0, 0, 0], k.extents());
        // super-band extents that split both the rows (24 < 26) and the
        // columns (18 < 19): the prepacked path must select whole block
        // subranges of the full-width pre-packed slices
        let lp = LevelPlan {
            l1_tile: (8, 8, 8),
            mc: 12,
            kc: 7,
            nc: 9,
            m3: 24,
            n3: 18,
        };
        for micro in [
            MicroShape::Mr8Nr4,
            MicroShape::Mr8Nr6,
            MicroShape::Mr16Nr4,
            MicroShape::Mr16Nr6,
        ] {
            let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
            let want = bufs.reference();
            let rows = pack_row_slices_mr(&bufs.arena, &plan, &lp, micro.mr());
            let packed: u64 = rows.iter().map(|r| r.pack_count()).sum();
            let mut cols = PackedCols::<f64>::new();
            run_macro_prepacked(&mut bufs.arena, &plan, &lp, micro, &rows, &mut cols);
            assert!(max_abs_diff(&want, &bufs.output()) < 1e-9, "{micro:?}");
            // a second run with mutated column-operand data: rows stay as
            // packed (the resident-weights contract), result tracks the
            // fresh oracle
            let (c_start, c_len) = bufs.operand_range(2);
            for v in &mut bufs.arena[c_start..c_start + c_len] {
                *v += 1.0;
            }
            bufs.reset_output();
            let want2 = bufs.reference();
            run_macro_prepacked(&mut bufs.arena, &plan, &lp, micro, &rows, &mut cols);
            assert!(max_abs_diff(&want2, &bufs.output()) < 1e-9, "{micro:?} rerun");
            let repacked: u64 = rows.iter().map(|r| r.pack_count()).sum();
            assert_eq!(packed, repacked, "pre-packed rows must never repack");
        }
    }

    #[test]
    fn prepacked_column_prefix_matches_narrow_kernel() {
        // the batching identity behind the coalesced serve path: a batch
        // of B jobs is the column prefix [0, B·m) of a max_batch-wide
        // plan, and executing that prefix must produce exactly what a
        // kernel of the prefix width would — with the full-width resident
        // row slices shared untouched and the tail columns left at zero
        let (mg, kg, n_wide) = (26usize, 19, 36);
        let wide_kernel = ops::matmul(mg as i64, kg as i64, n_wide as i64, 8, 0);
        let plan = GemmForm::of(&wide_kernel).unwrap().plan_box(
            &kernel_views(&wide_kernel),
            &[0, 0, 0],
            wide_kernel.extents(),
        );
        let lp = LevelPlan {
            l1_tile: (8, 8, 8),
            mc: 12,
            kc: 7,
            nc: 9,
            m3: 24,
            n3: 18,
        };
        let mut wide = KernelBuffers::<f64>::from_kernel(&wide_kernel);
        wide.fill_ints(6, 0xC0A1);
        let rows = pack_row_slices(&wide.arena, &plan, &lp);
        let startup_packs: u64 = rows.iter().map(|r| r.pack_count()).sum();
        let mut cols = PackedCols::<f64>::new();
        for n_used in [9usize, 20, n_wide] {
            // a narrow kernel over the same leading data is the oracle
            let narrow_kernel = ops::matmul(mg as i64, kg as i64, n_used as i64, 8, 0);
            let mut narrow = KernelBuffers::<f64>::from_kernel(&narrow_kernel);
            let (bs, bl) = wide.operand_range(1);
            narrow.operand_mut(1).copy_from_slice(&wide.arena[bs..bs + bl]);
            let (cs, _) = wide.operand_range(2);
            narrow
                .operand_mut(2)
                .copy_from_slice(&wide.arena[cs..cs + kg * n_used]);
            let want = narrow.reference();
            wide.reset_output();
            run_macro_prepacked_cols(
                &mut wide.arena,
                &plan,
                &lp,
                MicroShape::Mr8Nr4,
                &rows,
                &mut cols,
                n_used,
            );
            let out = wide.output();
            // integer fills → exact arithmetic → bitwise equality
            assert_eq!(&out[..mg * n_used], &want[..], "prefix n_used={n_used}");
            assert!(
                out[mg * n_used..].iter().all(|&v| v == 0.0),
                "columns past the prefix must stay zero (n_used={n_used})"
            );
        }
        let after: u64 = rows.iter().map(|r| r.pack_count()).sum();
        assert_eq!(startup_packs, after, "resident slices must never repack");
    }

    #[test]
    fn degenerate_dot_skips_packing() {
        // conv/scalar product plans are m = n = 1: the macro path must
        // take the dot kernel and leave the packed buffers untouched
        for kernel in [ops::convolution(57, 8, 0), ops::scalar_product(41, 8, 0)] {
            let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
            let want = bufs.reference();
            let gf = GemmForm::of(&kernel).unwrap();
            let plan =
                gf.plan_box(&kernel_views(&kernel), &[0], kernel.extents());
            assert!(is_dot_plan(&plan), "{}", kernel.name());
            let mut rows = PackedRows::<f64>::new();
            let mut cols = PackedCols::<f64>::new();
            let lp = LevelPlan {
                l1_tile: (1, 1, 8),
                mc: 1,
                kc: 8,
                nc: 1,
                m3: 1,
                n3: 1,
            };
            run_macro(
                &mut bufs.arena,
                &plan,
                &lp,
                MicroShape::Mr8Nr4,
                &mut rows,
                &mut cols,
            );
            assert_eq!(rows.pack_count(), 0, "dot path must not pack rows");
            assert_eq!(cols.pack_count(), 0, "dot path must not pack columns");
            assert!(max_abs_diff(&want, &bufs.output()) < 1e-9, "{}", kernel.name());
        }
    }

    #[test]
    fn panel_replay_detection() {
        let k = ops::matmul(16, 16, 16, 8, 0);
        let decoupled = TileBasis::from_cols(IMat::from_rows(&[
            &[3, 0, 1],
            &[0, 4, 0],
            &[1, 0, 4],
        ]));
        let exec = TiledExecutor::new(TiledSchedule::new(decoupled));
        assert!(exec.replay(&k).panel_replay());
        let coupled = TileBasis::from_cols(IMat::from_rows(&[
            &[3, 1, 0],
            &[1, 4, 0],
            &[0, 0, 2],
        ]));
        let exec = TiledExecutor::new(TiledSchedule::new(coupled));
        assert!(!exec.replay(&k).panel_replay());
    }

    #[test]
    fn coupled_j_basis_falls_back_and_is_correct() {
        let k = ops::matmul(14, 15, 13, 8, 0);
        // column axis coupled with rows: panel replay unavailable, scalar
        // replay exact
        let basis = TileBasis::from_cols(IMat::from_rows(&[
            &[3, 1, 0],
            &[1, 4, 0],
            &[0, 0, 2],
        ]));
        check_executor(&k, basis);
    }

    /// A kernel outside the GEMM class (one axis shared by the output and
    /// *both* inputs): must take the exact per-point fallback on both
    /// rect and skewed bases.
    fn elementwise_square(n: i64) -> Kernel {
        let a = Table::new("A", &[n], Layout::ColumnMajor, 8, 0);
        let b = Table::new("B", &[n], Layout::ColumnMajor, 8, n as usize * 8);
        Kernel::new(
            "elementwise_square",
            vec![n],
            vec![
                Operand {
                    table: a,
                    access: AffineAccess::select(1, &[0]),
                    role: OpRole::ReadWrite,
                },
                Operand {
                    table: b.clone(),
                    access: AffineAccess::select(1, &[0]),
                    role: OpRole::Read,
                },
                Operand {
                    table: b,
                    access: AffineAccess::select(1, &[0]),
                    role: OpRole::Read,
                },
            ],
        )
    }

    #[test]
    fn non_gemm_kernel_takes_point_fallback() {
        let k = elementwise_square(23);
        assert!(GemmForm::of(&k).is_none());
        check_executor(&k, TileBasis::rect(&[5]));
    }

    #[test]
    fn prototile_size_is_volume() {
        let basis = TileBasis::from_cols(IMat::from_rows(&[&[3, 1], &[1, 4]]));
        assert_eq!(prototile_points(&basis).len(), 11);
    }

    #[test]
    fn scan_rect_tiles_covers_domain_in_order() {
        // 2-D: order (1, 0) means axis 1 outermost, axis 0 fastest
        let mut boxes = Vec::new();
        scan_rect_tiles(&[1, 0], &[3, 4], &[7, 6], |lo, hi| {
            boxes.push((lo.to_vec(), hi.to_vec()));
        });
        assert_eq!(boxes.len(), 3 * 2);
        assert_eq!(boxes[0], (vec![0, 0], vec![3, 4]));
        assert_eq!(boxes[1], (vec![3, 0], vec![6, 4]));
        assert_eq!(boxes[2], (vec![6, 0], vec![7, 4]));
        assert_eq!(boxes[3], (vec![0, 4], vec![3, 6]));
        let total: i64 = boxes
            .iter()
            .map(|(lo, hi)| (hi[0] - lo[0]) * (hi[1] - lo[1]))
            .sum();
        assert_eq!(total, 42);
    }

    #[test]
    fn instrumented_counts_accesses() {
        use crate::cache::{CacheSim, CacheSpec, Policy};
        let k = ops::matmul(8, 8, 8, 8, 0);
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        let mut sim = CacheSim::new(CacheSpec::HASWELL_L1D, Policy::Lru);
        run_instrumented(&mut bufs, &k, &IterOrder::lex(3), &mut sim);
        assert_eq!(sim.stats().accesses, 3 * 8 * 8 * 8);
        // result still correct
        assert!(max_abs_diff(&bufs.reference(), &bufs.output()) < 1e-9);
    }

    #[test]
    fn trace_only_equals_instrumented_misses() {
        use crate::cache::{CacheSim, CacheSpec, Policy};
        let k = ops::matmul(10, 10, 10, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[4, 4, 4]));
        let mut sim1 = CacheSim::new(CacheSpec::FIG1_TOY, Policy::Lru);
        let mut sim2 = CacheSim::new(CacheSpec::FIG1_TOY, Policy::Lru);
        let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
        run_instrumented(&mut bufs, &k, &s, &mut sim1);
        run_trace_only(&k, &s, &mut sim2);
        assert_eq!(sim1.stats().misses(), sim2.stats().misses());
    }

    #[test]
    fn trace_only_works_for_all_table1_kernels() {
        use crate::cache::{CacheSim, CacheSpec, Policy};
        for k in [
            ops::convolution(12, 8, 0),
            ops::scalar_product(12, 8, 0),
            ops::kronecker(2, 3, 4, 2, 8, 0),
        ] {
            let mut sim = CacheSim::new(CacheSpec::HASWELL_L1D, Policy::Lru);
            run_trace_only(&k, &IterOrder::lex(k.n_free()), &mut sim);
            assert_eq!(sim.stats().accesses, 3 * k.domain_size() as u64);
        }
    }

    #[test]
    fn f32_addresses_halve_the_span() {
        // the f32 kernel's trace touches half the byte span of the f64
        // kernel's — elements per line really doubled
        use crate::cache::{CacheSim, CacheSpec, Policy};
        let k64 = ops::matmul(16, 16, 16, 8, 0);
        let k32 = ops::matmul(16, 16, 16, 4, 0);
        let v64 = kernel_views(&k64);
        let v32 = kernel_views(&k32);
        let f = [15i64, 15, 15];
        assert_eq!(v32[0].addr(&f) * 2, v64[0].addr(&f));
        // and produces no more misses under the same spec
        let mut s64 = CacheSim::new(CacheSpec::HASWELL_L1D, Policy::Lru);
        let mut s32 = CacheSim::new(CacheSpec::HASWELL_L1D, Policy::Lru);
        run_trace_only(&k64, &IterOrder::lex(3), &mut s64);
        run_trace_only(&k32, &IterOrder::lex(3), &mut s32);
        assert!(s32.stats().misses() <= s64.stats().misses());
    }
}
