//! Schedule-faithful executors — the stand-in for the paper's
//! CLooG-generated loop nests (DESIGN.md S9).
//!
//! [`MatmulBuffers`] owns the operand storage laid out exactly as the
//! kernel's [`Table`](crate::index::Table)s describe (padding, base
//! offsets); executors walk a [`Scanner`] (plain or tiled schedule) and
//! perform `A[i,j] += B[i,kk] · C[kk,j]` per visited point, optionally
//! touching a [`CacheSim`] with the three byte addresses — so simulated
//! miss counts correspond 1:1 to the executed schedule.
//!
//! [`TiledExecutor`] is the fast path: tile interiors run through the
//! packing + register-blocked microkernel engine
//! ([`super::pack`], [`super::microkernel`]) instead of per-point
//! callbacks — see the pipeline overview in [`super`].

use crate::cache::{CacheSim, CacheSpec};
use crate::domain::order::Scanner;
use crate::domain::{Kernel, OpRole};
use crate::tiling::{LevelPlan, TileBasis, TiledSchedule};

use super::microkernel::{axpy_block, NR};
use super::pack::{run_macro_block, PackBuffers, PackedB, PackedC};

/// Operand storage for a matmul kernel built by [`crate::domain::ops`]:
/// one arena indexed by byte address / 8, so executor addresses equal
/// simulator addresses.
#[derive(Clone, Debug)]
pub struct MatmulBuffers {
    pub m: i64,
    pub k: i64,
    pub n: i64,
    /// Arena of f64 covering all three tables (indexed in elements).
    pub arena: Vec<f64>,
    /// Element offsets and leading dims of A, B, C.
    pub a_off: usize,
    pub b_off: usize,
    pub c_off: usize,
    pub lda: usize,
    pub ldb: usize,
    pub ldc: usize,
}

/// Element offsets and leading dimensions of the three operands inside
/// one arena — the geometry the executors thread through the packing and
/// microkernel layers.
#[derive(Clone, Copy, Debug)]
pub struct MatmulGeom {
    pub a_off: usize,
    pub b_off: usize,
    pub c_off: usize,
    pub lda: usize,
    pub ldb: usize,
    pub ldc: usize,
}

impl MatmulBuffers {
    /// Allocate and deterministically initialize from a matmul kernel
    /// (B, C pseudorandom; A zero).
    pub fn from_kernel(kernel: &Kernel) -> MatmulBuffers {
        assert_eq!(kernel.name(), "matmul");
        let (m, n, k) = (
            kernel.extents()[0],
            kernel.extents()[1],
            kernel.extents()[2],
        );
        let ops = kernel.operands();
        let elem = ops[0].table.elem();
        assert_eq!(elem, 8, "f64 only");
        let end = ops
            .iter()
            .map(|o| o.table.base() + o.table.bytes())
            .max()
            .unwrap();
        let mut arena = vec![0f64; end / 8];
        // deterministic xorshift fill for the inputs
        let mut state = 0x9E3779B97F4A7C15u64;
        let mut rnd = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            ((state >> 11) as f64 / (1u64 << 53) as f64) - 0.5
        };
        for op in &ops[1..=2] {
            let t = &op.table;
            for j in 0..t.dims()[1] {
                for i in 0..t.dims()[0] {
                    arena[t.addr(&[i, j]) / 8] = rnd();
                }
            }
        }
        MatmulBuffers {
            m,
            k,
            n,
            arena,
            a_off: ops[0].table.base() / 8,
            b_off: ops[1].table.base() / 8,
            c_off: ops[2].table.base() / 8,
            lda: ops[0].table.map().weights()[1] as usize,
            ldb: ops[1].table.map().weights()[1] as usize,
            ldc: ops[2].table.map().weights()[1] as usize,
        }
    }

    /// The operand geometry (offsets + leading dims) of this arena.
    pub fn geom(&self) -> MatmulGeom {
        MatmulGeom {
            a_off: self.a_off,
            b_off: self.b_off,
            c_off: self.c_off,
            lda: self.lda,
            ldb: self.ldb,
            ldc: self.ldc,
        }
    }

    #[inline(always)]
    pub fn a_idx(&self, i: i64, j: i64) -> usize {
        self.a_off + i as usize + self.lda * j as usize
    }

    #[inline(always)]
    pub fn b_idx(&self, i: i64, kk: i64) -> usize {
        self.b_off + i as usize + self.ldb * kk as usize
    }

    #[inline(always)]
    pub fn c_idx(&self, kk: i64, j: i64) -> usize {
        self.c_off + kk as usize + self.ldc * j as usize
    }

    /// Reset the output to zero (between schedule runs).
    pub fn reset_output(&mut self) {
        for j in 0..self.n {
            for i in 0..self.m {
                let idx = self.a_idx(i, j);
                self.arena[idx] = 0.0;
            }
        }
    }

    /// Copy of the output matrix (column-major m×n).
    pub fn output(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity((self.m * self.n) as usize);
        for j in 0..self.n {
            for i in 0..self.m {
                out.push(self.arena[self.a_idx(i, j)]);
            }
        }
        out
    }

    /// Reference result computed by the naive oracle (fresh buffers).
    pub fn reference(&self) -> Vec<f64> {
        let mut out = vec![0f64; (self.m * self.n) as usize];
        for j in 0..self.n {
            for kk in 0..self.k {
                let ckj = self.arena[self.c_idx(kk, j)];
                for i in 0..self.m {
                    out[(i + self.m * j) as usize] += self.arena[self.b_idx(i, kk)] * ckj;
                }
            }
        }
        out
    }
}

/// Execute the matmul following `scanner`'s visit order. Returns nothing;
/// the result accumulates into `bufs.arena`.
pub fn run_schedule(bufs: &mut MatmulBuffers, kernel: &Kernel, scanner: &dyn Scanner) {
    let arena = &mut bufs.arena;
    let (a_off, b_off, c_off) = (bufs.a_off, bufs.b_off, bufs.c_off);
    let (lda, ldb, ldc) = (bufs.lda, bufs.ldb, bufs.ldc);
    scanner.scan_points(kernel.extents(), &mut |f: &[i64]| {
        let (i, j, kk) = (f[0] as usize, f[1] as usize, f[2] as usize);
        let b = arena[b_off + i + ldb * kk];
        let c = arena[c_off + kk + ldc * j];
        arena[a_off + i + lda * j] += b * c;
    });
}

/// Execute while feeding every touched byte address through the cache
/// simulator, in operand order A, B, C per point (write-allocate, i.e. the
/// output is touched like a read-modify-write).
pub fn run_instrumented(
    bufs: &mut MatmulBuffers,
    kernel: &Kernel,
    scanner: &dyn Scanner,
    sim: &mut CacheSim,
) {
    let a_base = kernel.operand(0).table.base();
    let b_base = kernel.operand(1).table.base();
    let c_base = kernel.operand(2).table.base();
    let arena = &mut bufs.arena;
    let (a_off, b_off, c_off) = (bufs.a_off, bufs.b_off, bufs.c_off);
    let (lda, ldb, ldc) = (bufs.lda, bufs.ldb, bufs.ldc);
    scanner.scan_points(kernel.extents(), &mut |f: &[i64]| {
        let (i, j, kk) = (f[0] as usize, f[1] as usize, f[2] as usize);
        sim.access(a_base + 8 * (i + lda * j));
        sim.access(b_base + 8 * (i + ldb * kk));
        sim.access(c_base + 8 * (kk + ldc * j));
        let b = arena[b_off + i + ldb * kk];
        let c = arena[c_off + kk + ldc * j];
        arena[a_off + i + lda * j] += b * c;
    });
}

/// Trace-only variant: feed addresses to the simulator without computing
/// (for pure miss-count sweeps; ~3× faster than instrumented execution).
pub fn run_trace_only(kernel: &Kernel, scanner: &dyn Scanner, sim: &mut CacheSim) {
    let bases: Vec<usize> = kernel.operands().iter().map(|o| o.table.base()).collect();
    let lds: Vec<usize> = kernel
        .operands()
        .iter()
        .map(|o| o.table.map().weights()[1] as usize)
        .collect();
    let ranks_ok = kernel.operands().iter().all(|o| o.table.rank() == 2);
    assert!(ranks_ok, "run_trace_only expects 2-D operands (matmul)");
    scanner.scan_points(kernel.extents(), &mut |f: &[i64]| {
        let (i, j, kk) = (f[0] as usize, f[1] as usize, f[2] as usize);
        sim.access(bases[0] + 8 * (i + lds[0] * j));
        sim.access(bases[1] + 8 * (i + lds[1] * kk));
        sim.access(bases[2] + 8 * (kk + lds[2] * j));
    });
}

/// Reusable per-thread scratch for the panel-replay path: the packed B
/// runs of the current tile and their clipped extents. Allocation-free in
/// steady state.
#[derive(Clone, Debug, Default)]
pub struct ReplayScratch {
    /// Contiguous copy of the tile's clipped B runs.
    bpack: Vec<f64>,
    /// Per run: (offset into `bpack`, length, absolute kk, absolute i lo).
    clipped: Vec<(usize, usize, usize, usize)>,
}

/// Fast tiled executor: walks footpoints and executes every tile through
/// the packing + microkernel engine.
///
/// * **Rectangular bases** run a blocked loop nest that packs each tile's
///   B and C operands into microkernel panels ([`PackBuffers`]) and
///   dispatches `MR×NR` register-tiled blocks, clipping only boundary
///   blocks.
/// * **Skewed lattice bases with a decoupled `j` dimension** (every basis
///   this crate's planners emit) replay the prototile's unit-stride runs:
///   per tile the clipped B runs are packed contiguously once, then
///   streamed through the `NR`-column axpy microkernel — the lattice
///   tiling's "miss regularity" made operational: every interior tile is
///   the same run pattern shifted.
/// * **Fully coupled bases** fall back to exact clipped scalar run
///   replay.
pub struct TiledExecutor {
    schedule: TiledSchedule,
    /// Explicit L2/L3 macro-block shape for the rect path (None = derive
    /// a capacity heuristic from the Haswell L2 + L3-slice specs).
    level: Option<LevelPlan>,
    /// Integer points of the prototile (footpoint 0), lexicographic.
    proto: Vec<Vec<i64>>,
    /// The prototile decomposed into maximal unit-stride runs along dim 0
    /// (`i`): `(i0, j, kk, len)` — the vectorizable inner loops of the
    /// "generated code". 3-D only.
    runs: Vec<(i64, i64, i64, i64)>,
    /// Tile extent along `j` when the basis leaves `j` decoupled
    /// (0 otherwise — panel replay unavailable).
    tj: i64,
    /// The `j = 0` cross-section of `runs` — `(i0, kk, len)`; valid for
    /// every `j` in `[0, tj)` because the prototile factorizes.
    jruns: Vec<(i64, i64, i64)>,
}

impl TiledExecutor {
    pub fn new(schedule: TiledSchedule) -> TiledExecutor {
        if schedule.basis().is_rect() {
            // the rect fast path in run() needs neither the prototile nor
            // the run list
            return TiledExecutor {
                schedule,
                level: None,
                proto: Vec::new(),
                runs: Vec::new(),
                tj: 0,
                jruns: Vec::new(),
            };
        }
        let proto = prototile_points(schedule.basis());
        let runs = if schedule.basis().dim() == 3 {
            // group by (j, kk), merge consecutive i
            let mut pts: Vec<(i64, i64, i64)> =
                proto.iter().map(|p| (p[1], p[2], p[0])).collect();
            pts.sort_unstable();
            let mut runs = Vec::new();
            let mut iter = pts.into_iter();
            if let Some((mut j, mut kk, mut i0)) = iter.next() {
                let mut len = 1i64;
                for (pj, pkk, pi) in iter {
                    if pj == j && pkk == kk && pi == i0 + len {
                        len += 1;
                    } else {
                        runs.push((i0, j, kk, len));
                        j = pj;
                        kk = pkk;
                        i0 = pi;
                        len = 1;
                    }
                }
                runs.push((i0, j, kk, len));
            }
            runs
        } else {
            Vec::new()
        };
        // Panel replay needs j decoupled: the prototile then factorizes as
        // [0, tj) × (2-D prototile in the (i, kk) plane), so the j = 0 run
        // cross-section is valid for every j of the tile.
        let (tj, jruns) = {
            let b = schedule.basis().basis();
            let decoupled = schedule.basis().dim() == 3
                && (0..3).all(|t| t == 1 || (b[(1, t)] == 0 && b[(t, 1)] == 0))
                && b[(1, 1)] > 0;
            if decoupled {
                let jr: Vec<(i64, i64, i64)> = runs
                    .iter()
                    .filter(|r| r.1 == 0)
                    .map(|r| (r.0, r.2, r.3))
                    .collect();
                (b[(1, 1)] as i64, jr)
            } else {
                (0, Vec::new())
            }
        };
        TiledExecutor {
            schedule,
            level: None,
            proto,
            runs,
            tj,
            jruns,
        }
    }

    /// Override the derived L2/L3 macro-block shape (rect bases only;
    /// skewed bases ignore it and replay per tile).
    pub fn with_level_plan(mut self, level: LevelPlan) -> TiledExecutor {
        self.level = Some(level);
        self
    }

    /// The explicit macro-block shape, if one was set.
    pub fn level_plan(&self) -> Option<&LevelPlan> {
        self.level.as_ref()
    }

    pub fn schedule(&self) -> &TiledSchedule {
        &self.schedule
    }

    pub fn prototile(&self) -> &[Vec<i64>] {
        &self.proto
    }

    /// The prototile's unit-stride run decomposition (3-D skewed bases).
    pub fn runs(&self) -> &[(i64, i64, i64, i64)] {
        &self.runs
    }

    /// Does this basis take the packed panel-replay path (skewed with a
    /// decoupled `j`), as opposed to the scalar run-replay fallback?
    pub fn panel_replay(&self) -> bool {
        self.tj > 0
    }

    /// Execute the matmul over the whole domain. Rect bases run the
    /// two-level macro-kernel ([`run_macro_matmul`]): L2/L3-sized
    /// `mc×kc×nc` blocks packed once, L1 tiles driven inside from the
    /// packed panels. Skewed bases replay every tile via
    /// [`TiledExecutor::run_tile`].
    pub fn run(&self, bufs: &mut MatmulBuffers, kernel: &Kernel) {
        let extents = kernel.extents();
        let basis = self.schedule.basis();
        let geom = bufs.geom();
        if basis.is_rect() {
            let (ti, tj, tk) = (
                basis.basis()[(0, 0)] as usize,
                basis.basis()[(1, 1)] as usize,
                basis.basis()[(2, 2)] as usize,
            );
            let (m, n, k) = (
                extents[0] as usize,
                extents[1] as usize,
                extents[2] as usize,
            );
            let lp = self.level.unwrap_or_else(|| {
                LevelPlan::heuristic(
                    (ti, tj, tk),
                    (m, n, k),
                    &CacheSpec::HASWELL_L2,
                    Some(&CacheSpec::HASWELL_L3_SLICE),
                )
            });
            run_macro_matmul(
                &mut bufs.arena,
                geom,
                (m, n, k),
                &lp,
                &mut PackedB::new(),
                &mut PackedC::new(),
            );
            return;
        }
        // Skewed tiles: every tile (interior or boundary) is the translated
        // prototile clipped to the domain box, so clipped run replay is
        // exact — no per-point footpoint filtering anywhere.
        let arena: &mut [f64] = &mut bufs.arena;
        let mut scratch = ReplayScratch::default();
        self.schedule.scan_feet(extents, |foot| {
            self.run_tile(arena, geom, extents, foot, &mut scratch);
        });
    }

    /// Execute with single-level blocking only: the per-tile pack +
    /// microkernel nest (the engine before the macro-kernel layer), kept
    /// for A/B comparison in the benches and two-level tests. Skewed
    /// bases behave exactly like [`TiledExecutor::run`].
    pub fn run_l1_only(&self, bufs: &mut MatmulBuffers, kernel: &Kernel) {
        let extents = kernel.extents();
        let basis = self.schedule.basis();
        let geom = bufs.geom();
        if basis.is_rect() {
            // a blocked nest packing each tile's operands, then MR×NR
            // register tiles; only boundary blocks clip. k0 outermost
            // keeps the per-element k order ascending; i0 above j0 lets
            // the packed B block (the larger pack) survive the j sweep.
            let (ti, tj, tk) = (
                basis.basis()[(0, 0)] as usize,
                basis.basis()[(1, 1)] as usize,
                basis.basis()[(2, 2)] as usize,
            );
            let (m, n, k) = (
                extents[0] as usize,
                extents[1] as usize,
                extents[2] as usize,
            );
            let arena: &mut [f64] = &mut bufs.arena;
            let mut packs = PackBuffers::new();
            for k0 in (0..k).step_by(tk) {
                let kc = (k0 + tk).min(k) - k0;
                for i0 in (0..m).step_by(ti) {
                    let mc = (i0 + ti).min(m) - i0;
                    for j0 in (0..n).step_by(tj) {
                        let nc = (j0 + tj).min(n) - j0;
                        run_rect_box(arena, geom, (i0, mc), (j0, nc), (k0, kc), &mut packs);
                    }
                }
            }
            return;
        }
        let arena: &mut [f64] = &mut bufs.arena;
        let mut scratch = ReplayScratch::default();
        self.schedule.scan_feet(extents, |foot| {
            self.run_tile(arena, geom, extents, foot, &mut scratch);
        });
    }

    /// Execute one (possibly boundary) tile of a skewed schedule at
    /// footpoint `foot`: pack the tile's clipped B runs contiguously, then
    /// stream `NR` output columns at a time through the axpy microkernel;
    /// bases without a decoupled `j` fall back to scalar run replay.
    /// Shared by the serial and parallel executors (`scratch` is
    /// thread-local in the latter).
    pub fn run_tile(
        &self,
        arena: &mut [f64],
        g: MatmulGeom,
        extents: &[i64],
        foot: &[i128],
        scratch: &mut ReplayScratch,
    ) {
        let basis = self.schedule.basis();
        let (m, n, kext) = (extents[0], extents[1], extents[2]);
        let origin = basis.basis().mul_vec(foot);
        let (oi, oj, ok) = (origin[0] as i64, origin[1] as i64, origin[2] as i64);
        if self.tj > 0 {
            let jlo = oj.max(0);
            let jhi = (oj + self.tj).min(n);
            if jlo >= jhi {
                return;
            }
            // pack: clip each prototile run once and copy its B values
            // into one contiguous buffer (amortized across the tile's
            // whole j extent)
            scratch.bpack.clear();
            scratch.clipped.clear();
            for &(i0, kk, len) in &self.jruns {
                let kkk = ok + kk;
                if kkk < 0 || kkk >= kext {
                    continue;
                }
                let lo = (oi + i0).max(0);
                let hi = (oi + i0 + len).min(m);
                if lo >= hi {
                    continue;
                }
                let pos = scratch.bpack.len();
                let src = g.b_off + g.ldb * kkk as usize + lo as usize;
                scratch.bpack.extend_from_slice(&arena[src..src + (hi - lo) as usize]);
                scratch.clipped.push((pos, (hi - lo) as usize, kkk as usize, lo as usize));
            }
            if scratch.clipped.is_empty() {
                return;
            }
            // replay: NR output columns per pass share every packed B load
            let (mut j, jhi) = (jlo as usize, jhi as usize);
            while j < jhi {
                let ncols = (jhi - j).min(NR);
                for &(pos, len, kkk, lo) in &scratch.clipped {
                    let mut cvals = [0f64; NR];
                    for (c, cv) in cvals.iter_mut().enumerate().take(ncols) {
                        *cv = arena[g.c_off + kkk + g.ldc * (j + c)];
                    }
                    let a_base = g.a_off + lo + g.lda * j;
                    axpy_block(
                        &mut arena[a_base..],
                        g.lda,
                        &scratch.bpack[pos..pos + len],
                        &cvals[..ncols],
                    );
                }
                j += NR;
            }
            return;
        }
        // fallback for fully coupled bases: exact clipped scalar replay
        for &(i0, jr, kk, len) in &self.runs {
            let jj = oj + jr;
            let kkk = ok + kk;
            if jj < 0 || jj >= n || kkk < 0 || kkk >= kext {
                continue;
            }
            let lo = (oi + i0).max(0);
            let hi = (oi + i0 + len).min(m);
            if lo >= hi {
                continue;
            }
            let (jj, kkk) = (jj as usize, kkk as usize);
            let cv = arena[g.c_off + kkk + g.ldc * jj];
            let b_base = g.b_off + g.ldb * kkk;
            let a_base = g.a_off + g.lda * jj;
            for i in lo as usize..hi as usize {
                arena[a_base + i] += arena[b_base + i] * cv;
            }
        }
    }
}

/// Execute the whole matmul as the two-level macro/micro nest (the
/// BLIS-style macro-kernel):
///
/// ```text
///   for k0 by kc:            pack ALL mc×kc B blocks of the slice once
///     for j0 by nc:          pack the kc×nc C block once
///       for each B block:    run all L1 tiles from the packed panels
/// ```
///
/// Each B macro block is packed exactly once (k slices partition k, row
/// blocks partition m) and each C block once per `(k0, j0)` — the arena
/// is streamed a number of times independent of the L1 tile size, which
/// is what makes L2-exceeding shapes run at macro-block speed. The packed
/// buffers are caller-owned so tests can assert the pack counts and the
/// parallel executor can share `packed_b` read-only.
pub fn run_macro_matmul(
    arena: &mut [f64],
    g: MatmulGeom,
    (m, n, k): (usize, usize, usize),
    lp: &LevelPlan,
    packed_b: &mut PackedB,
    packed_c: &mut PackedC,
) {
    let mc = lp.mc.max(1);
    let kc = lp.kc.max(1);
    let nc = lp.nc.max(1);
    for k0 in (0..k).step_by(kc) {
        let kcc = (k0 + kc).min(k) - k0;
        packed_b.pack_slice(arena, g.b_off, g.ldb, m, mc, k0, kcc);
        for j0 in (0..n).step_by(nc) {
            let ncc = (j0 + nc).min(n) - j0;
            packed_c.pack_block(arena, g.c_off, g.ldc, k0, kcc, j0, ncc);
            for bi in 0..packed_b.n_blocks() {
                let (bp, i0, mcc) = packed_b.block(bi);
                run_macro_block(
                    bp,
                    mcc,
                    packed_c.panels(),
                    ncc,
                    kcc,
                    (lp.l1_tile.0, lp.l1_tile.1),
                    arena,
                    g.a_off,
                    g.lda,
                    i0,
                    j0,
                );
            }
        }
    }
}

/// Execute one clipped rectangular tile box `[ilo, ilo+mc) × [jlo, jlo+nc)
/// × [klo, klo+kc)` through the pack + microkernel engine — the per-tile
/// rect dispatch shared by the serial and parallel executors. Packed B/C
/// blocks are reused across consecutive calls via their block keys.
pub fn run_rect_box(
    arena: &mut [f64],
    g: MatmulGeom,
    (ilo, mc): (usize, usize),
    (jlo, nc): (usize, usize),
    (klo, kc): (usize, usize),
    packs: &mut PackBuffers,
) {
    packs.pack_b_cached(arena, g.b_off, g.ldb, ilo, mc, klo, kc);
    packs.pack_c_cached(arena, g.c_off, g.ldc, klo, kc, jlo, nc);
    packs.run_tile(arena, g.a_off, g.lda, ilo, jlo);
}

/// Enumerate the integer points of the prototile (footpoint 0) of a tile
/// basis, lexicographically sorted. Prototile points can have negative
/// coordinates for skewed bases, so this scans the bounding box of
/// `P·[0,1]^d` without clipping.
pub fn prototile_points(basis: &TileBasis) -> Vec<Vec<i64>> {
    let d = basis.dim();
    if basis.is_rect() {
        // the prototile of diag(s) is the box [0,s) — no scan needed
        let sizes: Vec<i64> = (0..d).map(|i| basis.basis()[(i, i)] as i64).collect();
        let mut out = Vec::with_capacity(basis.volume() as usize);
        let mut x = vec![0i64; d];
        'outer: loop {
            out.push(x.clone());
            let mut j = d;
            loop {
                if j == 0 {
                    break 'outer;
                }
                j -= 1;
                x[j] += 1;
                if x[j] < sizes[j] {
                    continue 'outer;
                }
                x[j] = 0;
            }
        }
        return out;
    }
    let mut lo = vec![i128::MAX; d];
    let mut hi = vec![i128::MIN; d];
    for mask in 0..(1usize << d) {
        let corner: Vec<i128> = (0..d).map(|i| ((mask >> i) & 1) as i128).collect();
        let v = basis.basis().mul_vec(&corner);
        for j in 0..d {
            lo[j] = lo[j].min(v[j]);
            hi[j] = hi[j].max(v[j]);
        }
    }
    let mut proto = Vec::new();
    let mut cur = lo.clone();
    let mut x = vec![0i64; d];
    'outer: loop {
        for j in 0..d {
            x[j] = cur[j] as i64;
        }
        if basis.in_prototile(&x) {
            proto.push(x.clone());
        }
        let mut j = d;
        loop {
            if j == 0 {
                break 'outer;
            }
            j -= 1;
            cur[j] += 1;
            if cur[j] <= hi[j] {
                continue 'outer;
            }
            cur[j] = lo[j];
        }
    }
    proto.sort();
    assert_eq!(proto.len() as i128, basis.volume());
    proto
}

/// Convenience: make a `TiledExecutor` from a tile basis.
pub fn tiled_executor(basis: TileBasis) -> TiledExecutor {
    TiledExecutor::new(TiledSchedule::new(basis))
}

/// Max |a−b| over two equal-length slices.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

/// Did the kernel declare a writable first operand? (sanity helper)
pub fn writes_first_operand(kernel: &Kernel) -> bool {
    matches!(
        kernel.operand(0).role,
        OpRole::Write | OpRole::ReadWrite
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domain::ops;
    use crate::domain::IterOrder;
    use crate::lattice::IMat;

    fn check_correct(kernel: &Kernel, scanner: &dyn Scanner) {
        let mut bufs = MatmulBuffers::from_kernel(kernel);
        let want = bufs.reference();
        run_schedule(&mut bufs, kernel, scanner);
        let got = bufs.output();
        assert!(
            max_abs_diff(&want, &got) < 1e-9,
            "schedule result mismatch"
        );
    }

    fn check_executor(kernel: &Kernel, basis: TileBasis) {
        let exec = TiledExecutor::new(TiledSchedule::new(basis));
        let mut bufs = MatmulBuffers::from_kernel(kernel);
        let want = bufs.reference();
        exec.run(&mut bufs, kernel);
        assert!(max_abs_diff(&want, &bufs.output()) < 1e-9);
    }

    #[test]
    fn naive_orders_correct() {
        let k = ops::matmul(13, 7, 9, 8, 0);
        for o in IterOrder::all(3) {
            check_correct(&k, &o);
        }
    }

    #[test]
    fn rect_tiled_correct() {
        let k = ops::matmul(17, 11, 13, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[4, 5, 3]));
        check_correct(&k, &s);
    }

    #[test]
    fn lattice_tiled_correct() {
        let k = ops::matmul(16, 16, 16, 8, 0);
        // skewed tile on (i, kk), rect on j
        let basis = TileBasis::from_cols(IMat::from_rows(&[
            &[3, 0, 1],
            &[0, 4, 0],
            &[1, 0, 4],
        ]));
        let s = TiledSchedule::new(basis);
        check_correct(&k, &s);
    }

    #[test]
    fn padded_buffers_correct() {
        let k = ops::matmul_padded(9, 8, 7, 12, 11, 10, 8, 256);
        check_correct(&k, &IterOrder::lex(3));
    }

    #[test]
    fn tiled_executor_matches_schedule_run() {
        let k = ops::matmul(20, 18, 22, 8, 0);
        let basis = TileBasis::from_cols(IMat::from_rows(&[
            &[5, 0, 2],
            &[0, 6, 0],
            &[-1, 0, 4],
        ]));
        check_executor(&k, basis);
    }

    #[test]
    fn rect_executor_packs_non_multiple_extents() {
        // extents not multiples of the tile, tile not a multiple of MR/NR
        let k = ops::matmul(21, 9, 11, 8, 0);
        check_executor(&k, TileBasis::rect(&[10, 6, 5]));
        // tile bigger than the whole domain
        let k = ops::matmul(5, 3, 2, 8, 0);
        check_executor(&k, TileBasis::rect(&[16, 16, 16]));
    }

    #[test]
    fn rect_executor_handles_padded_layouts() {
        let k = ops::matmul_padded(13, 7, 9, 17, 15, 11, 8, 64);
        check_executor(&k, TileBasis::rect(&[8, 4, 4]));
    }

    #[test]
    fn macro_run_matches_l1_only_run() {
        let k = ops::matmul(33, 21, 27, 8, 0);
        let exec = TiledExecutor::new(TiledSchedule::new(TileBasis::rect(&[10, 6, 5])))
            .with_level_plan(LevelPlan {
                l1_tile: (10, 6, 5),
                mc: 14,
                kc: 9,
                nc: 11,
            });
        let mut macro_bufs = MatmulBuffers::from_kernel(&k);
        exec.run(&mut macro_bufs, &k);
        let mut l1_bufs = MatmulBuffers::from_kernel(&k);
        exec.run_l1_only(&mut l1_bufs, &k);
        assert!(max_abs_diff(&macro_bufs.output(), &l1_bufs.output()) < 1e-9);
        assert!(max_abs_diff(&macro_bufs.reference(), &macro_bufs.output()) < 1e-9);
    }

    #[test]
    fn panel_replay_detection() {
        let decoupled = TileBasis::from_cols(IMat::from_rows(&[
            &[3, 0, 1],
            &[0, 4, 0],
            &[1, 0, 4],
        ]));
        assert!(TiledExecutor::new(TiledSchedule::new(decoupled)).panel_replay());
        let coupled = TileBasis::from_cols(IMat::from_rows(&[
            &[3, 1, 0],
            &[1, 4, 0],
            &[0, 0, 2],
        ]));
        assert!(!TiledExecutor::new(TiledSchedule::new(coupled)).panel_replay());
    }

    #[test]
    fn coupled_j_basis_falls_back_and_is_correct() {
        let k = ops::matmul(14, 15, 13, 8, 0);
        // j coupled with i: panel replay unavailable, scalar replay exact
        let basis = TileBasis::from_cols(IMat::from_rows(&[
            &[3, 1, 0],
            &[1, 4, 0],
            &[0, 0, 2],
        ]));
        check_executor(&k, basis);
    }

    #[test]
    fn prototile_size_is_volume() {
        let basis = TileBasis::from_cols(IMat::from_rows(&[&[3, 1], &[1, 4]]));
        let exec = TiledExecutor::new(TiledSchedule::new(basis));
        assert_eq!(exec.prototile().len(), 11);
    }

    #[test]
    fn instrumented_counts_accesses() {
        use crate::cache::{CacheSim, CacheSpec, Policy};
        let k = ops::matmul(8, 8, 8, 8, 0);
        let mut bufs = MatmulBuffers::from_kernel(&k);
        let mut sim = CacheSim::new(CacheSpec::HASWELL_L1D, Policy::Lru);
        run_instrumented(&mut bufs, &k, &IterOrder::lex(3), &mut sim);
        assert_eq!(sim.stats().accesses, 3 * 8 * 8 * 8);
        // result still correct
        assert!(max_abs_diff(&bufs.reference(), &bufs.output()) < 1e-9);
    }

    #[test]
    fn trace_only_equals_instrumented_misses() {
        use crate::cache::{CacheSim, CacheSpec, Policy};
        let k = ops::matmul(10, 10, 10, 8, 0);
        let s = TiledSchedule::new(TileBasis::rect(&[4, 4, 4]));
        let mut sim1 = CacheSim::new(CacheSpec::FIG1_TOY, Policy::Lru);
        let mut sim2 = CacheSim::new(CacheSpec::FIG1_TOY, Policy::Lru);
        let mut bufs = MatmulBuffers::from_kernel(&k);
        run_instrumented(&mut bufs, &k, &s, &mut sim1);
        run_trace_only(&k, &s, &mut sim2);
        assert_eq!(sim1.stats().misses(), sim2.stats().misses());
    }
}
