//! The kernel-neutral operand layer and the `RunPlan` IR — the bridge
//! between a [`Kernel`]'s affine access maps and the packed micro/macro
//! execution engine.
//!
//! Three layers, each derived from the kernel instead of hardcoded:
//!
//! * [`OperandView`] — the composed affine functional `φ ∘ access` of one
//!   operand on the *loop* variables: one constant element offset plus one
//!   weight per loop variable. Everything downstream (scalar executors,
//!   address tracing, packing) indexes the arena through views, so no
//!   executor ever hardcodes a kernel's `a_idx`/`b_idx`/`c_idx` geometry.
//! * [`GemmForm`] — the GEMM normal form of a Table-1 kernel: every loop
//!   axis classified as a **row** axis (shared by the output and one
//!   input), a **column** axis (shared by the output and the other
//!   input), or a **reduction** axis (absent from the output). The input
//!   sharing the output's unit-stride axis becomes the *row operand* (the
//!   packed-panel side of the microkernel); multiplication commutes, so
//!   the inputs swap roles freely (`swap`). Matmul is `{i} × {j} × {kk}`,
//!   Kronecker the reduction-free outer product `{k,l} × {i,j}`,
//!   convolution and scalar product the degenerate `1 × 1 × {k}` dot.
//! * [`RunPlan`] — the per-box execution IR: the rows of the (sub-)box
//!   decomposed into maximal **unit-stride runs** (consecutive in both
//!   the output and the row operand), plus explicit per-column and
//!   per-reduction-step offset tables. A `RunPlan` is exactly what the
//!   packers consume; tile boxes, macro blocks, and whole domains all
//!   lower to the same IR.
//!
//! [`KernelBuffers`] replaces the former matmul-only `MatmulBuffers`: one
//! `T: Scalar` arena (f32 or f64, matching the kernel's declared element
//! size) laid out by the kernel's [`Table`](crate::index::Table)s — so
//! executor element indices × [`Scalar::ELEM`] equal simulator byte
//! addresses — with a kernel-semantic scalar
//! [`reference`](KernelBuffers::reference) oracle.

use super::scalar::Scalar;
use crate::domain::order::IterOrder;
use crate::domain::{Kernel, Operand};
use crate::tiling::TileBasis;

/// The composed affine map of one operand on the loop variables:
/// arena element index `= off + Σ_j w[j]·f[j]`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct OperandView {
    /// Constant element offset (table base + composed affine constants).
    pub off: i64,
    /// Element weight per loop variable.
    pub w: Vec<i64>,
    /// Element size in bytes (from the operand's table) — scales element
    /// indices to simulator byte addresses.
    pub elem: usize,
}

impl OperandView {
    /// Build the view of one operand (`φ ∘ access` plus the table base).
    pub fn of(op: &Operand) -> OperandView {
        let (w, o) = op
            .access
            .compose_weights(op.table.map().weights(), op.table.map().offset());
        let elem = op.table.elem();
        debug_assert_eq!(op.table.base() % elem, 0, "table base must be elem-aligned");
        OperandView {
            off: (op.table.base() / elem) as i64 + o,
            w,
            elem,
        }
    }

    /// Arena element index at loop point `f`.
    #[inline(always)]
    pub fn idx(&self, f: &[i64]) -> usize {
        let mut v = self.off;
        for (&wj, &fj) in self.w.iter().zip(f) {
            v += wj * fj;
        }
        debug_assert!(v >= 0, "operand index underflow at {f:?}");
        v as usize
    }

    /// Byte address at loop point `f` (element index × element size, so
    /// f32 arenas pack two elements where an f64 arena packs one — the
    /// simulator sees twice the elements per line).
    #[inline(always)]
    pub fn addr(&self, f: &[i64]) -> usize {
        self.elem * self.idx(f)
    }
}

/// Views of all three operands of a kernel, in operand order
/// (output, input 1, input 2).
pub fn kernel_views(kernel: &Kernel) -> Vec<OperandView> {
    kernel.operands().iter().map(OperandView::of).collect()
}

/// Operand storage for any Table-1 kernel: one `T: Scalar` arena indexed
/// by byte address / element size, so executor addresses equal simulator
/// addresses. The kernel's tables must be declared with `T`'s element
/// size (`ops::matmul(m, k, n, 4, 0)` pairs with `KernelBuffers<f32>`).
#[derive(Clone, Debug)]
pub struct KernelBuffers<T: Scalar = f64> {
    /// Arena of `T` covering all operand tables (indexed in elements).
    pub arena: Vec<T>,
    views: Vec<OperandView>,
    extents: Vec<i64>,
    /// Per-operand arena element range `(start, len)` of the (possibly
    /// padded) table span — see [`KernelBuffers::operand_mut`].
    op_ranges: Vec<(usize, usize)>,
    /// Logical dims of the output table (flatten order of `output()`).
    out_dims: Vec<i64>,
    /// Element offset (incl. table base) and per-dim element weights of
    /// the output table's index map — for walking the output in layout
    /// space without the kernel.
    out_elem_off: i64,
    out_elem_w: Vec<i64>,
    /// Composed loop-space weights/offset of the *logical flat* output
    /// index (dim 0 fastest) — the `reference()` oracle's write index.
    flat_w: Vec<i64>,
    flat_off: i64,
}

impl<T: Scalar> KernelBuffers<T> {
    /// Allocate and deterministically initialize from a kernel: inputs
    /// (operands 1, 2) pseudorandom, output zero.
    pub fn from_kernel(kernel: &Kernel) -> KernelBuffers<T> {
        let ops = kernel.operands();
        assert_eq!(ops.len(), 3, "KernelBuffers expects out = in1 ⊙ in2 kernels");
        for op in ops {
            assert_eq!(
                op.table.elem(),
                T::ELEM,
                "kernel declared {}-byte elements, buffers are {}-byte",
                op.table.elem(),
                T::ELEM
            );
        }
        let end = ops
            .iter()
            .map(|o| o.table.base() + o.table.bytes())
            .max()
            .unwrap();
        let mut arena = vec![T::ZERO; end.div_ceil(T::ELEM)];
        // deterministic xorshift fill for the inputs
        let mut state = 0x9E3779B97F4A7C15u64;
        let mut rnd = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            ((state >> 11) as f64 / (1u64 << 53) as f64) - 0.5
        };
        for op in &ops[1..=2] {
            let t = &op.table;
            scan_dims(t.dims(), |x| {
                arena[t.addr(x) / T::ELEM] = T::from_f64(rnd());
            });
        }
        let op_ranges = ops
            .iter()
            .map(|o| (o.table.base() / T::ELEM, o.table.bytes() / T::ELEM))
            .collect();
        let out = &ops[0];
        let out_dims = out.table.dims().to_vec();
        // logical (unpadded) column-major flatten weights of the output
        let mut fw = vec![0i64; out_dims.len()];
        let mut acc = 1i64;
        for (r, w) in fw.iter_mut().enumerate() {
            *w = acc;
            acc *= out_dims[r];
        }
        let (flat_w, flat_off) = out.access.compose_weights(&fw, 0);
        KernelBuffers {
            arena,
            views: kernel_views(kernel),
            extents: kernel.extents().to_vec(),
            op_ranges,
            out_elem_off: (out.table.base() / T::ELEM) as i64 + out.table.map().offset(),
            out_elem_w: out.table.map().weights().to_vec(),
            out_dims,
            flat_w,
            flat_off,
        }
    }

    /// The composed operand views (output, input 1, input 2).
    pub fn views(&self) -> &[OperandView] {
        &self.views
    }

    pub fn view(&self, i: usize) -> &OperandView {
        &self.views[i]
    }

    /// Number of logical output elements.
    pub fn out_len(&self) -> usize {
        self.out_dims.iter().product::<i64>() as usize
    }

    /// Arena element range `(start, len)` of operand `i`'s table span.
    pub fn operand_range(&self, i: usize) -> (usize, usize) {
        self.op_ranges[i]
    }

    /// Mutable view of operand `i`'s table span in the arena — how
    /// callers that own real data (e.g. the native serve backend) load an
    /// operand. For dense unpadded tables the span is exactly the logical
    /// element count in layout order.
    pub fn operand_mut(&mut self, i: usize) -> &mut [T] {
        let (start, len) = self.op_ranges[i];
        &mut self.arena[start..start + len]
    }

    /// Refill the inputs with small *integer-valued* scalars (range
    /// `[-range, range]`), so products and partial sums are exact at
    /// either precision and every summation order yields bit-identical
    /// results — the fill the bit-for-bit differential tests use.
    pub fn fill_ints(&mut self, range: u64, seed: u64) {
        let mut state = seed | 1;
        let span = 2 * range + 1;
        let mut rnd = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state % span) as f64 - range as f64
        };
        // the inputs occupy everything outside the output table; the
        // simplest exact refill walks the whole arena, then re-zeroes the
        // output table (padding values are never read by any executor)
        for v in self.arena.iter_mut() {
            *v = T::from_f64(rnd());
        }
        self.reset_output();
    }

    /// Element index of the output table at logical index `x`.
    #[inline(always)]
    fn out_elem(&self, x: &[i64]) -> usize {
        let mut v = self.out_elem_off;
        for (&wj, &xj) in self.out_elem_w.iter().zip(x) {
            v += wj * xj;
        }
        v as usize
    }

    /// Reset the output table to zero (between schedule runs).
    pub fn reset_output(&mut self) {
        let dims = self.out_dims.clone();
        let off = self.out_elem_off;
        let w = self.out_elem_w.clone();
        let arena = &mut self.arena;
        scan_dims(&dims, |x| {
            let mut e = off;
            for (&wj, &xj) in w.iter().zip(x) {
                e += wj * xj;
            }
            arena[e as usize] = T::ZERO;
        });
    }

    /// Copy of the output table, flattened logically (dim 0 fastest).
    pub fn output(&self) -> Vec<T> {
        let mut out = Vec::with_capacity(self.out_len());
        scan_dims(&self.out_dims, |x| out.push(self.arena[self.out_elem(x)]));
        out
    }

    /// Reference result computed by the kernel-semantic scalar oracle
    /// (`out[π₀(f)] += in1[π₁(f)] · in2[π₂(f)]` over the whole domain in
    /// lexicographic order, accumulating in `T`), into fresh buffers —
    /// the differential-test oracle for every executor path.
    pub fn reference(&self) -> Vec<T> {
        let mut out = vec![T::ZERO; self.out_len()];
        let d = self.extents.len();
        let (v1, v2) = (&self.views[1], &self.views[2]);
        IterOrder::lex(d).scan(&self.extents, |f| {
            let mut o = self.flat_off;
            for (&wj, &fj) in self.flat_w.iter().zip(f) {
                o += wj * fj;
            }
            out[o as usize] += self.arena[v1.idx(f)] * self.arena[v2.idx(f)];
        });
        out
    }
}

/// Odometer over logical table dims, dim 0 fastest (column-major layout
/// order).
fn scan_dims<F: FnMut(&[i64])>(dims: &[i64], mut f: F) {
    if dims.iter().any(|&m| m <= 0) {
        return;
    }
    let d = dims.len();
    let mut x = vec![0i64; d];
    'outer: loop {
        f(&x);
        let mut r = 0;
        loop {
            if r == d {
                break 'outer;
            }
            x[r] += 1;
            if x[r] < dims[r] {
                continue 'outer;
            }
            x[r] = 0;
            r += 1;
        }
    }
}

/// The GEMM normal form of a kernel: loop axes grouped into row, column
/// and reduction dimensions (see the module docs). `m`/`n`/`k` are the
/// products of the group extents — the shape the macro-level
/// [`LevelPlan`](crate::tiling::LevelPlan) blocks against.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GemmForm {
    /// Row axes, unit-stride axis first (may be empty: `m = 1`).
    pub row_axes: Vec<usize>,
    /// Column axes (may be empty: `n = 1`).
    pub col_axes: Vec<usize>,
    /// Reduction axes (absent from the output; may be empty: `k = 1`).
    pub red_axes: Vec<usize>,
    /// Inputs swapped: the *second* input is the row operand.
    pub swap: bool,
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl GemmForm {
    /// Classify `kernel` into GEMM normal form. `None` when an axis is
    /// shared by the output and *both* inputs (or by the output alone) —
    /// those kernels fall back to the exact scalar path.
    pub fn of(kernel: &Kernel) -> Option<GemmForm> {
        if kernel.operands().len() != 3 {
            return None;
        }
        let views = kernel_views(kernel);
        let extents = kernel.extents();
        let d = kernel.n_free();
        let (vo, v1, v2) = (&views[0], &views[1], &views[2]);
        let mut side1 = Vec::new();
        let mut side2 = Vec::new();
        let mut red = Vec::new();
        for t in 0..d {
            let (wo, w1, w2) = (vo.w[t], v1.w[t], v2.w[t]);
            if extents[t] <= 1 || wo == 0 {
                red.push(t);
            } else if w1 != 0 && w2 == 0 {
                side1.push(t);
            } else if w2 != 0 && w1 == 0 {
                side2.push(t);
            } else {
                // coupled (output + both inputs) or output-only axis
                return None;
            }
        }
        let unit1 = side1.iter().position(|&t| vo.w[t] == 1 && v1.w[t] == 1);
        let unit2 = side2.iter().position(|&t| vo.w[t] == 1 && v2.w[t] == 1);
        let front = |mut axes: Vec<usize>, u: usize| -> Vec<usize> {
            let ax = axes.remove(u);
            axes.insert(0, ax);
            axes
        };
        let (row_axes, col_axes, swap) = match (unit1, unit2) {
            // the input sharing the output's unit-stride axis packs as
            // the row operand; the unit axis leads the row group
            (Some(u), _) => (front(side1, u), side2, false),
            (None, Some(u)) => (front(side2, u), side1, true),
            (None, None) => {
                // no unit-stride axis anywhere: keep the row dimension
                // trivial when one side has no axes (runs stay long);
                // otherwise rows degrade to short runs, which is still
                // exact, just slower to pack
                if side1.is_empty() && !side2.is_empty() {
                    (side1, side2, false)
                } else if side2.is_empty() && !side1.is_empty() {
                    (side2, side1, true)
                } else {
                    (side1, side2, false)
                }
            }
        };
        let prod = |axes: &[usize]| -> usize {
            axes.iter()
                .map(|&t| extents[t].max(0) as usize)
                .product::<usize>()
        };
        // extent-1/reduction axes contribute their extents to k so the
        // macro blocking sees the true reduction depth
        let m = prod(&row_axes);
        let n = prod(&col_axes);
        let k = prod(&red);
        Some(GemmForm {
            row_axes,
            col_axes,
            red_axes: red,
            swap,
            m,
            n,
            k,
        })
    }

    /// The views in GEMM roles `(out, row operand, column operand)`.
    pub fn role_views<'a>(
        &self,
        views: &'a [OperandView],
    ) -> (&'a OperandView, &'a OperandView, &'a OperandView) {
        if self.swap {
            (&views[0], &views[2], &views[1])
        } else {
            (&views[0], &views[1], &views[2])
        }
    }

    /// The L1 tile footprint `(ti, tj, tk)` in GEMM space induced by a
    /// rectangular loop-space tile basis: products of the basis diagonal
    /// over each axis group.
    pub fn l1_tile(&self, basis: &TileBasis) -> (usize, usize, usize) {
        assert!(basis.is_rect());
        let prod = |axes: &[usize]| -> usize {
            axes.iter()
                .map(|&t| basis.basis()[(t, t)].max(1) as usize)
                .product::<usize>()
                .max(1)
        };
        (
            prod(&self.row_axes),
            prod(&self.col_axes),
            prod(&self.red_axes),
        )
    }

    /// Build the [`RunPlan`] of the clipped loop-space box
    /// `[lo_t, hi_t)` — the whole domain when `lo = 0`, `hi = extents`.
    pub fn plan_box(&self, views: &[OperandView], lo: &[i64], hi: &[i64]) -> RunPlan {
        let mut plan = RunPlan::default();
        self.plan_box_into(views, lo, hi, &mut plan);
        plan
    }

    /// As [`GemmForm::plan_box`], but refilling a caller-owned plan — the
    /// per-tile executors reuse one scratch plan so the hot loop performs
    /// no allocation in steady state (Vec capacities persist).
    pub fn plan_box_into(
        &self,
        views: &[OperandView],
        lo: &[i64],
        hi: &[i64],
        plan: &mut RunPlan,
    ) {
        let (vo, vr, vc) = self.role_views(views);
        plan.runs.clear();
        plan.col_out.clear();
        plan.col_in.clear();
        plan.red_row.clear();
        plan.red_col.clear();
        // rows: maximal unit-stride runs of (out, row operand)
        let runs = &mut plan.runs;
        let mut m = 0usize;
        scan_axes(&self.row_axes, lo, hi, |coords| {
            m += 1;
            let mut o = vo.off;
            let mut r = vr.off;
            for (p, &t) in self.row_axes.iter().enumerate() {
                o += vo.w[t] * coords[p];
                r += vr.w[t] * coords[p];
            }
            match runs.last_mut() {
                Some(run)
                    if run.out + run.len as i64 == o && run.row + run.len as i64 == r =>
                {
                    run.len += 1;
                }
                _ => runs.push(Run { out: o, row: r, len: 1 }),
            }
        });
        // columns: absolute column-operand offsets, relative output ones
        let col_out = &mut plan.col_out;
        let col_in = &mut plan.col_in;
        scan_axes(&self.col_axes, lo, hi, |coords| {
            let mut o = 0i64;
            let mut c = vc.off;
            for (p, &t) in self.col_axes.iter().enumerate() {
                o += vo.w[t] * coords[p];
                c += vc.w[t] * coords[p];
            }
            col_out.push(o);
            col_in.push(c);
        });
        // reduction steps: relative offsets for both inputs
        let red_row = &mut plan.red_row;
        let red_col = &mut plan.red_col;
        scan_axes(&self.red_axes, lo, hi, |coords| {
            let mut r = 0i64;
            let mut c = 0i64;
            for (p, &t) in self.red_axes.iter().enumerate() {
                r += vr.w[t] * coords[p];
                c += vc.w[t] * coords[p];
            }
            red_row.push(r);
            red_col.push(c);
        });
        plan.m = m;
        plan.n = plan.col_out.len();
        plan.k = plan.red_row.len();
    }

    /// Sufficient (mixed-radix) check that distinct `(row, column)`
    /// positions map to distinct output elements — the invariant the
    /// parallel band decomposition's write-disjointness rests on. True
    /// for every Table-1 kernel; conservatively false when the weights
    /// don't dominate each other's spans.
    pub fn output_injective(&self, views: &[OperandView], extents: &[i64]) -> bool {
        let (vo, _, _) = self.role_views(views);
        let axes: Vec<usize> = self
            .row_axes
            .iter()
            .chain(&self.col_axes)
            .copied()
            .collect();
        view_injective(vo, extents, &axes)
    }
}

/// Sufficient mixed-radix condition that an operand view is injective on
/// the box coordinates of `axes`: sorted by |weight|, every weight must
/// exceed the maximal offset span reachable by all smaller-weight axes
/// together. Conservative (may return false for injective maps), never
/// wrong when it returns true.
pub fn view_injective(v: &OperandView, extents: &[i64], axes: &[usize]) -> bool {
    let mut axes: Vec<usize> = axes.to_vec();
    axes.sort_by_key(|&t| v.w[t].unsigned_abs());
    let mut span: i128 = 0;
    for &t in &axes {
        let w = v.w[t].unsigned_abs() as i128;
        if w <= span {
            return false;
        }
        span += w * ((extents[t].max(1) - 1) as i128);
    }
    true
}

/// Odometer over a subset of loop axes clipped to `[lo, hi)`, first axis
/// fastest. Calls `f` once with empty coords when `axes` is empty; calls
/// it zero times when any clipped range is empty.
fn scan_axes<F: FnMut(&[i64])>(axes: &[usize], lo: &[i64], hi: &[i64], mut f: F) {
    if axes.is_empty() {
        f(&[]);
        return;
    }
    if axes.iter().any(|&t| lo[t] >= hi[t]) {
        return;
    }
    let d = axes.len();
    let mut x: Vec<i64> = axes.iter().map(|&t| lo[t]).collect();
    'outer: loop {
        f(&x);
        let mut p = 0;
        loop {
            if p == d {
                break 'outer;
            }
            x[p] += 1;
            if x[p] < hi[axes[p]] {
                continue 'outer;
            }
            x[p] = lo[axes[p]];
            p += 1;
        }
    }
}

/// One maximal unit-stride run: `len` consecutive output elements
/// starting at element `out`, with the matching row-operand elements
/// starting at `row` — both advancing by +1 — shared by every column and
/// reduction step of the plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Run {
    /// Output element offset of the run's first row (column contribution
    /// excluded — add `col_out[c]`).
    pub out: i64,
    /// Row-operand element offset of the first row (reduction
    /// contribution excluded — add `red_row[t]`).
    pub row: i64,
    pub len: usize,
}

/// The per-box execution IR consumed by the packed engine: unit-stride
/// runs along the row dimension, plus per-column and per-reduction-step
/// offset tables (see the module docs for the offset split).
#[derive(Clone, Debug, Default)]
pub struct RunPlan {
    pub runs: Vec<Run>,
    /// Output element contribution of column `c` (add to `Run::out`).
    pub col_out: Vec<i64>,
    /// Absolute column-operand element offset of column `c` at reduction
    /// contribution zero (add `red_col[t]`).
    pub col_in: Vec<i64>,
    /// Row-operand element contribution of reduction step `t`.
    pub red_row: Vec<i64>,
    /// Column-operand element contribution of reduction step `t`.
    pub red_col: Vec<i64>,
    /// Total rows (Σ run lengths), columns, reduction steps.
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

/// One `mr`-granular packing panel of a row range: up to `mr` live rows
/// (the geometry's register-tile row class — [`MR`] or
/// [`MR_TALL`](super::microkernel::MR_TALL)) starting at absolute output
/// element `out` / row-operand element `row`. Panels never straddle run
/// boundaries, so both offsets are unit-stride across the panel's rows.
///
/// [`MR`]: super::microkernel::MR
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RowPanel {
    pub out: i64,
    pub row: i64,
    pub rows: usize,
}

impl RunPlan {
    /// [`RunPlan::row_panels_mr`] at the default row class
    /// ([`MR`](super::microkernel::MR)).
    pub fn row_panels(&self, r0: usize, rows: usize) -> Vec<RowPanel> {
        self.row_panels_mr(r0, rows, super::microkernel::MR)
    }

    /// Decompose global row positions `[r0, r0 + rows)` into mr-granular
    /// packing panels (shared by the packers and the address-level
    /// tracer, so their layouts can never diverge). `mr` is the packed
    /// panel height of the dispatched register geometry.
    pub fn row_panels_mr(&self, r0: usize, rows: usize, mr: usize) -> Vec<RowPanel> {
        assert!(mr > 0, "panel height must be positive");
        let mut panels = Vec::new();
        let r1 = r0 + rows;
        let mut pos = 0usize;
        for run in &self.runs {
            let lo = pos.max(r0);
            let hi = (pos + run.len).min(r1);
            if lo < hi {
                let base = (lo - pos) as i64;
                let seg_len = hi - lo;
                let mut p = 0usize;
                while p < seg_len {
                    let live = mr.min(seg_len - p);
                    panels.push(RowPanel {
                        out: run.out + base + p as i64,
                        row: run.row + base + p as i64,
                        rows: live,
                    });
                    p += mr;
                }
            }
            pos += run.len;
            if pos >= r1 {
                break;
            }
        }
        panels
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domain::ops;

    #[test]
    fn views_match_pointwise_addresses() {
        // composed views must agree with Kernel::addrs_at everywhere —
        // including 4-byte (f32) kernels, whose addresses advance by 4
        for kernel in [
            ops::matmul_padded(5, 4, 6, 7, 6, 5, 8, 64),
            ops::convolution(9, 8, 16),
            ops::scalar_product(7, 8, 8),
            ops::kronecker(2, 3, 4, 2, 8, 0),
            ops::matmul_padded(5, 4, 6, 7, 6, 5, 4, 64),
            ops::convolution(9, 4, 16),
        ] {
            let views = kernel_views(&kernel);
            IterOrder::lex(kernel.n_free()).scan(kernel.extents(), |f| {
                let addrs = kernel.addrs_at(f);
                for (v, a) in views.iter().zip(&addrs) {
                    assert_eq!(v.addr(f), *a, "kernel {} at {f:?}", kernel.name());
                }
            });
        }
    }

    #[test]
    fn gemm_form_matmul() {
        let k = ops::matmul(8, 6, 10, 8, 0);
        let gf = GemmForm::of(&k).unwrap();
        assert_eq!(gf.row_axes, vec![0]);
        assert_eq!(gf.col_axes, vec![1]);
        assert_eq!(gf.red_axes, vec![2]);
        assert!(!gf.swap);
        assert_eq!((gf.m, gf.n, gf.k), (8, 10, 6));
    }

    #[test]
    fn gemm_form_convolution_and_scalar() {
        for k in [ops::convolution(12, 8, 0), ops::scalar_product(12, 8, 0)] {
            let gf = GemmForm::of(&k).unwrap();
            assert!(gf.row_axes.is_empty(), "{}", k.name());
            assert!(gf.col_axes.is_empty());
            assert_eq!(gf.red_axes, vec![0]);
            assert_eq!((gf.m, gf.n, gf.k), (1, 1, 12));
        }
    }

    #[test]
    fn gemm_form_kronecker_swaps_inputs() {
        let k = ops::kronecker(3, 4, 5, 2, 8, 0);
        let gf = GemmForm::of(&k).unwrap();
        // C (operand 2) shares the output's unit-stride axis k (loop 2)
        assert!(gf.swap);
        assert_eq!(gf.row_axes, vec![2, 3]);
        assert_eq!(gf.col_axes, vec![0, 1]);
        assert!(gf.red_axes.is_empty());
        assert_eq!((gf.m, gf.n, gf.k), (5 * 2, 3 * 4, 1));
    }

    #[test]
    fn plan_box_offsets_match_views_matmul() {
        let kernel = ops::matmul_padded(9, 5, 7, 11, 10, 6, 8, 16);
        let views = kernel_views(&kernel);
        let gf = GemmForm::of(&kernel).unwrap();
        let lo = [2i64, 1, 0];
        let hi = [7i64, 6, 5];
        let plan = gf.plan_box(&views, &lo, &hi);
        assert_eq!((plan.m, plan.n, plan.k), (5, 5, 5));
        // exhaustive check: every (row, col, red) offset triple equals the
        // view-computed element indices
        let mut r = 0usize;
        for run in &plan.runs {
            for i in 0..run.len {
                for (c, (&co, &ci)) in plan.col_out.iter().zip(&plan.col_in).enumerate() {
                    for (t, (&rr, &rc)) in plan.red_row.iter().zip(&plan.red_col).enumerate()
                    {
                        let f = [
                            lo[0] + (r + i) as i64,
                            lo[1] + c as i64,
                            lo[2] + t as i64,
                        ];
                        assert_eq!((run.out + i as i64 + co) as usize, views[0].idx(&f));
                        assert_eq!((run.row + i as i64 + rr) as usize, views[1].idx(&f));
                        assert_eq!((ci + rc) as usize, views[2].idx(&f));
                    }
                }
            }
            r += run.len;
        }
        // matmul rows are one unit-stride run per box
        assert_eq!(plan.runs.len(), 1);
    }

    #[test]
    fn plan_box_kronecker_runs_have_inner_extent() {
        let kernel = ops::kronecker(3, 2, 4, 5, 8, 0);
        let views = kernel_views(&kernel);
        let gf = GemmForm::of(&kernel).unwrap();
        let lo = vec![0i64; 4];
        let hi: Vec<i64> = kernel.extents().to_vec();
        let plan = gf.plan_box(&views, &lo, &hi);
        assert_eq!(plan.m, 20);
        assert_eq!(plan.n, 6);
        assert_eq!(plan.k, 1);
        // the output jumps every m1c = 4 rows (lda = 12 > 4)
        assert_eq!(plan.runs.len(), 5);
        assert!(plan.runs.iter().all(|r| r.len == 4));
        // the row operand (C) is fully contiguous across runs
        for w in plan.runs.windows(2) {
            assert_eq!(w[0].row + w[0].len as i64, w[1].row);
        }
    }

    #[test]
    fn plan_box_convolution_reverses_column_operand() {
        let n = 10i64;
        let kernel = ops::convolution(n, 8, 0);
        let views = kernel_views(&kernel);
        let gf = GemmForm::of(&kernel).unwrap();
        let plan = gf.plan_box(&views, &[0], &[n]);
        assert_eq!((plan.m, plan.n, plan.k), (1, 1, 10));
        // red_col must walk C backwards: C_{n-1-t}
        for t in 0..plan.k {
            let f = [t as i64];
            assert_eq!(
                (plan.col_in[0] + plan.red_col[t]) as usize,
                views[2].idx(&f)
            );
            assert_eq!(
                (plan.runs[0].row + plan.red_row[t]) as usize,
                views[1].idx(&f)
            );
        }
    }

    #[test]
    fn row_panels_never_straddle_runs() {
        use crate::codegen::microkernel::MR;
        let kernel = ops::kronecker(3, 2, 4, 5, 8, 0);
        let views = kernel_views(&kernel);
        let gf = GemmForm::of(&kernel).unwrap();
        let plan = gf.plan_box(&views, &[0, 0, 0, 0], kernel.extents());
        let panels = plan.row_panels(0, plan.m);
        let total: usize = panels.iter().map(|p| p.rows).sum();
        assert_eq!(total, plan.m);
        // runs are 4 long, MR = 8: every panel is a whole 4-row run
        assert!(panels.iter().all(|p| p.rows <= MR));
        // sub-range request clips
        let sub = plan.row_panels(2, 7);
        assert_eq!(sub.iter().map(|p| p.rows).sum::<usize>(), 7);
        assert_eq!(sub[0].out, plan.runs[0].out + 2);
    }

    #[test]
    fn output_injectivity_holds_for_table1_and_rejects_collisions() {
        for kernel in [
            ops::matmul_padded(9, 5, 7, 11, 10, 6, 8, 16),
            ops::kronecker(3, 4, 5, 2, 8, 0),
            ops::convolution(12, 8, 0),
            ops::scalar_product(12, 8, 0),
        ] {
            let gf = GemmForm::of(&kernel).unwrap();
            assert!(
                gf.output_injective(&kernel_views(&kernel), kernel.extents()),
                "{}",
                kernel.name()
            );
        }
        // a colliding map: out = i + j over i, j ∈ [0, 4) is not injective
        let v = OperandView {
            off: 0,
            w: vec![1, 1],
            elem: 8,
        };
        assert!(!view_injective(&v, &[4, 4], &[0, 1]));
        // dominating weights are accepted
        let v = OperandView {
            off: 0,
            w: vec![1, 4],
            elem: 8,
        };
        assert!(view_injective(&v, &[4, 4], &[0, 1]));
        assert!(view_injective(&v, &[4, 4], &[1, 0]), "order-insensitive");
    }

    #[test]
    fn plan_box_into_reuses_scratch() {
        let kernel = ops::matmul(10, 6, 8, 8, 0);
        let views = kernel_views(&kernel);
        let gf = GemmForm::of(&kernel).unwrap();
        let mut scratch = RunPlan::default();
        gf.plan_box_into(&views, &[0, 0, 0], kernel.extents(), &mut scratch);
        let full = gf.plan_box(&views, &[0, 0, 0], kernel.extents());
        assert_eq!(scratch.runs, full.runs);
        assert_eq!((scratch.m, scratch.n, scratch.k), (full.m, full.n, full.k));
        // refill with a smaller box: stale state must be fully replaced
        gf.plan_box_into(&views, &[2, 1, 1], &[5, 4, 3], &mut scratch);
        assert_eq!((scratch.m, scratch.n, scratch.k), (3, 3, 2));
        assert_eq!(scratch.col_out.len(), 3);
        assert_eq!(scratch.red_row.len(), 2);
    }

    #[test]
    fn buffers_reference_matches_legacy_matmul_oracle() {
        let kernel = ops::matmul_padded(7, 5, 6, 9, 8, 7, 8, 32);
        let bufs = KernelBuffers::<f64>::from_kernel(&kernel);
        // legacy oracle (j, kk, i nesting) on the same arena
        let views = kernel_views(&kernel);
        let (m, n, k) = (7usize, 6, 5);
        let mut want = vec![0f64; m * n];
        for j in 0..n {
            for kk in 0..k {
                for i in 0..m {
                    let f = [i as i64, j as i64, kk as i64];
                    want[i + m * j] +=
                        bufs.arena[views[1].idx(&f)] * bufs.arena[views[2].idx(&f)];
                }
            }
        }
        let got = bufs.reference();
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn buffers_output_and_reset_roundtrip() {
        let kernel = ops::kronecker(2, 3, 3, 2, 8, 0);
        let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
        assert_eq!(bufs.out_len(), 36);
        assert!(bufs.output().iter().all(|&v| v == 0.0));
        let e = bufs.view(0).idx(&[0, 0, 0, 0]);
        bufs.arena[e] = 3.5;
        assert_eq!(bufs.output()[0], 3.5);
        bufs.reset_output();
        assert!(bufs.output().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn fill_ints_is_integer_valued() {
        let kernel = ops::matmul(6, 5, 4, 8, 0);
        let mut bufs = KernelBuffers::<f64>::from_kernel(&kernel);
        bufs.fill_ints(2, 0xF00D);
        for &v in &bufs.arena {
            assert_eq!(v, v.trunc());
            assert!(v.abs() <= 2.0);
        }
        assert!(bufs.output().iter().all(|&v| v == 0.0));
    }
}
