//! Reference GEMM oracle and an optimized blocked GEMM — the paper checks
//! its generated code against a BLAS library (§4); these are our
//! deterministic stand-ins (DESIGN.md S14).

/// Naive column-major `A += B·C` (`A` m×n, `B` m×k, `C` k×n), jki order —
/// the correctness oracle. Deterministic, no blocking, no vectorization
/// hints.
#[allow(clippy::too_many_arguments)]
pub fn gemm_naive(
    m: usize,
    k: usize,
    n: usize,
    a: &mut [f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    c: &[f64],
    ldc: usize,
) {
    for j in 0..n {
        for kk in 0..k {
            let ckj = c[kk + ldc * j];
            for i in 0..m {
                a[i + lda * j] += b[i + ldb * kk] * ckj;
            }
        }
    }
}

/// Cache-blocked, register-tiled GEMM — the "aggressively optimized
/// compiler output" analog (icc/gcc −O3 class). Column-major; blocking
/// BM×BK×BN with a 4-column micro-kernel over unit-stride `i`.
#[allow(clippy::too_many_arguments)]
pub fn gemm_blocked(
    m: usize,
    k: usize,
    n: usize,
    a: &mut [f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    c: &[f64],
    ldc: usize,
) {
    const BM: usize = 64;
    const BK: usize = 64;
    const BN: usize = 64;
    for j0 in (0..n).step_by(BN) {
        let jn = (j0 + BN).min(n);
        for k0 in (0..k).step_by(BK) {
            let kn = (k0 + BK).min(k);
            for i0 in (0..m).step_by(BM) {
                let im = (i0 + BM).min(m);
                // micro-kernel: 4 columns of C at a time
                let mut j = j0;
                while j + 4 <= jn {
                    for kk in k0..kn {
                        let c0 = c[kk + ldc * j];
                        let c1 = c[kk + ldc * (j + 1)];
                        let c2 = c[kk + ldc * (j + 2)];
                        let c3 = c[kk + ldc * (j + 3)];
                        let bcol = &b[ldb * kk..];
                        for i in i0..im {
                            let bv = bcol[i];
                            a[i + lda * j] += bv * c0;
                            a[i + lda * (j + 1)] += bv * c1;
                            a[i + lda * (j + 2)] += bv * c2;
                            a[i + lda * (j + 3)] += bv * c3;
                        }
                    }
                    j += 4;
                }
                while j < jn {
                    for kk in k0..kn {
                        let cj = c[kk + ldc * j];
                        let bcol = &b[ldb * kk..];
                        for i in i0..im {
                            a[i + lda * j] += bcol[i] * cj;
                        }
                    }
                    j += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(len: usize, seed: u64) -> Vec<f64> {
        let mut s = seed | 1;
        (0..len)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                ((s >> 11) as f64 / (1u64 << 53) as f64) - 0.5
            })
            .collect()
    }

    #[test]
    fn blocked_matches_naive() {
        for (m, k, n) in [(7usize, 9, 5), (64, 64, 64), (65, 33, 129), (100, 1, 3)] {
            let b = fill(m * k, 42);
            let c = fill(k * n, 43);
            let mut a1 = vec![0f64; m * n];
            let mut a2 = vec![0f64; m * n];
            gemm_naive(m, k, n, &mut a1, m, &b, m, &c, k);
            gemm_blocked(m, k, n, &mut a2, m, &b, m, &c, k);
            let diff = a1
                .iter()
                .zip(&a2)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0, f64::max);
            assert!(diff < 1e-9, "({m},{k},{n}) diff={diff}");
        }
    }

    #[test]
    fn padded_lda_supported() {
        let (m, k, n) = (5usize, 6, 4);
        let (lda, ldb, ldc) = (8usize, 7, 9);
        let b = fill(ldb * k, 1);
        let c = fill(ldc * n, 2);
        let mut a1 = vec![0f64; lda * n];
        let mut a2 = vec![0f64; lda * n];
        gemm_naive(m, k, n, &mut a1, lda, &b, ldb, &c, ldc);
        gemm_blocked(m, k, n, &mut a2, lda, &b, ldb, &c, ldc);
        assert_eq!(a1, a2);
    }
}
