//! Baselines: the reference GEMM oracle and the compiler-analog
//! scheduling strategies of Figure 4 (DESIGN.md S10, S14).

pub mod refblas;
pub mod strategies;

pub use refblas::{gemm_blocked, gemm_naive};
pub use strategies::{AnalogSchedule, CompilerAnalog};
