//! Compiler-analog scheduling strategies (DESIGN.md S10).
//!
//! Figure 4 compares lattice tiling against gcc (−O0/−O2/−O3 + graphite),
//! Intel icc and pgi. Those binaries are not available (and their exact
//! behavior is not the point); each flag set is modeled by the loop
//! transformation it is documented to perform, applied to the same matmul
//! executor so miss counts and wallclock are directly comparable. These
//! are *analogs*, clearly labeled as such — see DESIGN.md §3 "compiler
//! substitution".

use crate::domain::{IterOrder, Kernel};
use crate::tiling::{TileBasis, TiledSchedule};

use super::refblas;
use crate::codegen::executor::KernelBuffers;

/// The baseline set of Figure 4.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CompilerAnalog {
    /// `gcc -O0`: the literal source loop nest, ijk, no transformation,
    /// scalar code.
    GccO0,
    /// `gcc -O2`: loop-invariant motion + the canonical interchange to
    /// jki (unit-stride inner loop for column-major), still untiled.
    GccO2,
    /// `gcc -O3`: interchange + blocked + 4-wide micro-kernel
    /// (vectorizer analog).
    GccO3,
    /// `gcc -floop-* (graphite)`: fixed-heuristic rectangular tiling
    /// (64³) with jki intra-tile order.
    GccGraphite,
    /// `icc -O3`: aggressive blocked + micro-kernel (same class as O3;
    /// the paper found icc ≈ lattice tiling).
    IccO3,
    /// `pgi`: interchange only, no tiling (the paper found pgi could not
    /// tile this code).
    Pgi,
}

impl CompilerAnalog {
    pub const ALL: [CompilerAnalog; 6] = [
        CompilerAnalog::GccO0,
        CompilerAnalog::GccO2,
        CompilerAnalog::GccO3,
        CompilerAnalog::GccGraphite,
        CompilerAnalog::IccO3,
        CompilerAnalog::Pgi,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            CompilerAnalog::GccO0 => "gcc-O0(analog)",
            CompilerAnalog::GccO2 => "gcc-O2(analog)",
            CompilerAnalog::GccO3 => "gcc-O3(analog)",
            CompilerAnalog::GccGraphite => "gcc-graphite(analog)",
            CompilerAnalog::IccO3 => "icc-O3(analog)",
            CompilerAnalog::Pgi => "pgi(analog)",
        }
    }

    /// The traversal order this analog uses — this is what the cache
    /// simulator measures. Loop vars are (i, j, kk); column-major data.
    pub fn schedule(&self, kernel: &Kernel) -> AnalogSchedule {
        match self {
            // source order: i outer, j, k inner
            CompilerAnalog::GccO0 => AnalogSchedule::Loops(IterOrder::permuted(&[0, 1, 2])),
            // interchange to j, k, i (unit stride inner)
            CompilerAnalog::GccO2 | CompilerAnalog::Pgi => {
                AnalogSchedule::Loops(IterOrder::permuted(&[1, 2, 0]))
            }
            CompilerAnalog::GccO3 | CompilerAnalog::IccO3 => {
                let t: Vec<i64> = kernel.extents().iter().map(|&e| e.min(64)).collect();
                AnalogSchedule::Tiled(TiledSchedule::new(TileBasis::rect(&t)))
            }
            CompilerAnalog::GccGraphite => {
                let t: Vec<i64> = kernel.extents().iter().map(|&e| e.min(64)).collect();
                AnalogSchedule::Tiled(
                    TiledSchedule::new(TileBasis::rect(&t))
                        .with_foot_order(IterOrder::permuted(&[1, 2, 0])),
                )
            }
        }
    }

    /// Execute the analog on real buffers (wallclock benches) with the
    /// code quality the flag set actually emits: hand-written loop nests
    /// for the untiled analogs (compilers emit real loops, not
    /// point-callbacks), the tuned blocked GEMM for the O3/icc class, and
    /// the run-replaying tiled executor for graphite.
    pub fn execute(&self, bufs: &mut KernelBuffers, kernel: &Kernel) {
        // the analogs are matmul-specific by design (they model compiler
        // output for the paper's GEMM benchmark): read the column-major
        // geometry straight off the kernel's tables
        assert_eq!(kernel.name(), "matmul");
        let extents = kernel.extents();
        let (m, n, k) = (
            extents[0] as usize,
            extents[1] as usize,
            extents[2] as usize,
        );
        let tab = |i: usize| kernel.operand(i).table.clone();
        let (a, b, c) = (tab(0), tab(1), tab(2));
        let (a_off, b_off, c_off) = (a.base() / 8, b.base() / 8, c.base() / 8);
        let (lda, ldb, ldc) = (
            a.map().weights()[1] as usize,
            b.map().weights()[1] as usize,
            c.map().weights()[1] as usize,
        );
        match self {
            CompilerAnalog::GccO3 | CompilerAnalog::IccO3 => {
                // split the arena to get simultaneous &mut a, &b, &c —
                // operands are packed A | B | C
                assert!(a_off < b_off && b_off < c_off);
                let (a_part, rest) = bufs.arena.split_at_mut(b_off);
                let (b_part, c_part) = rest.split_at_mut(c_off - b_off);
                refblas::gemm_blocked(
                    m,
                    k,
                    n,
                    &mut a_part[a_off..],
                    lda,
                    b_part,
                    ldb,
                    c_part,
                    ldc,
                );
            }
            CompilerAnalog::GccO0 => {
                // literal source order i, j, k — strided inner loop,
                // no vectorization possible
                let arena = &mut bufs.arena;
                for i in 0..m {
                    for j in 0..n {
                        let mut acc = arena[a_off + i + lda * j];
                        for kk in 0..k {
                            acc += arena[b_off + i + ldb * kk] * arena[c_off + kk + ldc * j];
                        }
                        arena[a_off + i + lda * j] = acc;
                    }
                }
            }
            CompilerAnalog::GccO2 | CompilerAnalog::Pgi => {
                // interchanged j, k, i — unit-stride inner loop
                let arena = &mut bufs.arena;
                for j in 0..n {
                    for kk in 0..k {
                        let c = arena[c_off + kk + ldc * j];
                        for i in 0..m {
                            let b = arena[b_off + i + ldb * kk];
                            arena[a_off + i + lda * j] += b * c;
                        }
                    }
                }
            }
            CompilerAnalog::GccGraphite => {
                if let AnalogSchedule::Tiled(t) = self.schedule(kernel) {
                    crate::codegen::TiledExecutor::new(t).run(bufs, kernel);
                }
            }
        }
    }
}

/// A baseline's traversal order.
#[derive(Clone, Debug)]
pub enum AnalogSchedule {
    Loops(IterOrder),
    Tiled(TiledSchedule),
}

impl AnalogSchedule {
    pub fn as_scanner(&self) -> &dyn crate::domain::order::Scanner {
        match self {
            AnalogSchedule::Loops(o) => o,
            AnalogSchedule::Tiled(t) => t,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::executor::max_abs_diff;
    use crate::domain::ops;

    #[test]
    fn all_analogs_compute_correct_result() {
        let k = ops::matmul(33, 29, 31, 8, 0);
        for analog in CompilerAnalog::ALL {
            let mut bufs = KernelBuffers::<f64>::from_kernel(&k);
            let want = bufs.reference();
            analog.execute(&mut bufs, &k);
            assert!(
                max_abs_diff(&want, &bufs.output()) < 1e-9,
                "{} wrong",
                analog.name()
            );
        }
    }

    #[test]
    fn analogs_have_distinct_miss_profiles() {
        use crate::cache::{CacheSim, CacheSpec, Policy};
        use crate::codegen::run_trace_only;
        // n=96: big enough that 64³ tiles differ from the full nest and
        // lda=96 is non-pathological (at n=128 the fixed 64³ rect tile
        // thrashes — which is the paper's whole point; see benches).
        let k = ops::matmul(96, 96, 96, 8, 0);
        let mut misses = std::collections::HashMap::new();
        for analog in [
            CompilerAnalog::GccO0,
            CompilerAnalog::GccO2,
            CompilerAnalog::GccO3,
        ] {
            let mut sim =
                CacheSim::new(CacheSpec::HASWELL_L1D, Policy::Lru).without_classification();
            let s = analog.schedule(&k);
            run_trace_only(&k, s.as_scanner(), &mut sim);
            misses.insert(analog.name(), sim.stats().misses());
        }
        // O0 (ijk, strided inner) must miss more than O2 (jki, unit
        // stride); O3 (tiled) must beat O2 at this size.
        assert!(misses["gcc-O0(analog)"] > misses["gcc-O2(analog)"]);
        assert!(misses["gcc-O3(analog)"] < misses["gcc-O2(analog)"]);
    }
}
