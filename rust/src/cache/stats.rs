//! Access statistics for the simulator, with the traditional 3-C
//! classification kept *alongside* the paper's unified conflict-only view
//! so the two can be compared experimentally (§1.1.2–§1.1.3).

/// Miss taxonomy. The paper argues cold and capacity misses are both
/// special cases of associativity conflicts; we record the traditional
/// split so benchmarks can demonstrate exactly that claim.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MissKind {
    /// Line never resided in the cache before (compulsory).
    Cold,
    /// A fully-associative LRU cache of the same capacity would also have
    /// missed — the traditional "capacity" category.
    Capacity,
    /// The fully-associative shadow would have hit: the miss exists only
    /// because of set-mapping conflicts. The paper's protagonist.
    Conflict,
}

/// Aggregate counters for one cache level.
#[derive(Clone, Debug, Default)]
pub struct CacheStats {
    pub accesses: u64,
    pub hits: u64,
    pub cold: u64,
    pub capacity: u64,
    pub conflict: u64,
    /// Per-set miss counters — the paper's per-set perspective (§1.1.3):
    /// non-uniform usage across sets is exactly what makes "capacity" a
    /// misleading aggregate.
    pub per_set_misses: Vec<u64>,
    pub per_set_accesses: Vec<u64>,
}

impl CacheStats {
    pub fn new(n_sets: usize) -> CacheStats {
        CacheStats {
            per_set_misses: vec![0; n_sets],
            per_set_accesses: vec![0; n_sets],
            ..Default::default()
        }
    }

    pub fn misses(&self) -> u64 {
        self.cold + self.capacity + self.conflict
    }

    pub fn miss_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.misses() as f64 / self.accesses as f64
        }
    }

    pub fn record(&mut self, set: usize, kind: Option<MissKind>) {
        self.accesses += 1;
        self.per_set_accesses[set] += 1;
        match kind {
            None => self.hits += 1,
            Some(k) => {
                self.per_set_misses[set] += 1;
                match k {
                    MissKind::Cold => self.cold += 1,
                    MissKind::Capacity => self.capacity += 1,
                    MissKind::Conflict => self.conflict += 1,
                }
            }
        }
    }

    /// Coefficient of variation of per-set miss counts — a direct measure
    /// of the set-usage non-uniformity the paper highlights.
    pub fn set_imbalance(&self) -> f64 {
        let n = self.per_set_misses.len() as f64;
        if n == 0.0 {
            return 0.0;
        }
        let mean = self.per_set_misses.iter().sum::<u64>() as f64 / n;
        if mean == 0.0 {
            return 0.0;
        }
        let var = self
            .per_set_misses
            .iter()
            .map(|&m| (m as f64 - mean).powi(2))
            .sum::<f64>()
            / n;
        var.sqrt() / mean
    }

    pub fn merge(&mut self, other: &CacheStats) {
        self.accesses += other.accesses;
        self.hits += other.hits;
        self.cold += other.cold;
        self.capacity += other.capacity;
        self.conflict += other.conflict;
        for (a, b) in self.per_set_misses.iter_mut().zip(&other.per_set_misses) {
            *a += b;
        }
        for (a, b) in self
            .per_set_accesses
            .iter_mut()
            .zip(&other.per_set_accesses)
        {
            *a += b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_rates() {
        let mut s = CacheStats::new(4);
        s.record(0, None);
        s.record(1, Some(MissKind::Cold));
        s.record(1, Some(MissKind::Conflict));
        s.record(2, Some(MissKind::Capacity));
        assert_eq!(s.accesses, 4);
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses(), 3);
        assert!((s.miss_rate() - 0.75).abs() < 1e-12);
        assert_eq!(s.per_set_misses, vec![0, 2, 1, 0]);
    }

    #[test]
    fn imbalance_zero_when_uniform() {
        let mut s = CacheStats::new(2);
        s.record(0, Some(MissKind::Cold));
        s.record(1, Some(MissKind::Cold));
        assert!(s.set_imbalance() < 1e-12);
        s.record(0, Some(MissKind::Conflict));
        assert!(s.set_imbalance() > 0.0);
    }

    #[test]
    fn merge_adds() {
        let mut a = CacheStats::new(2);
        a.record(0, Some(MissKind::Cold));
        let mut b = CacheStats::new(2);
        b.record(1, None);
        a.merge(&b);
        assert_eq!(a.accesses, 2);
        assert_eq!(a.hits, 1);
    }
}
