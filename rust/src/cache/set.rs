//! A single cache set with LRU or tree-PLRU replacement.
//!
//! The paper's whole argument (§1.1.3) is that cache protocols "assume the
//! perspective of a single cache set" — this type *is* that perspective:
//! `K` ways holding line tags, an eviction policy, hit/miss accounting.

use super::spec::Policy;

/// One K-way cache set. Tags are opaque `u64` line identifiers.
#[derive(Clone, Debug)]
pub struct CacheSet {
    ways: usize,
    policy: Policy,
    /// Occupied slots: `slots[i] = Some(tag)`.
    slots: Vec<Option<u64>>,
    /// LRU: `order[i]` is the recency rank of slot `i` (0 = most recent).
    order: Vec<u32>,
    /// PLRU: tree bits, `ways - 1` internal nodes (heap layout, root = 0).
    tree: Vec<bool>,
}

/// Result of one access to a set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SetAccess {
    Hit { way: usize },
    /// Miss that filled an empty way.
    MissFill { way: usize },
    /// Miss that evicted `victim` from `way`.
    MissEvict { way: usize, victim: u64 },
}

impl SetAccess {
    pub fn is_hit(&self) -> bool {
        matches!(self, SetAccess::Hit { .. })
    }
}

impl CacheSet {
    pub fn new(ways: usize, policy: Policy) -> CacheSet {
        assert!(ways > 0);
        if policy == Policy::PLru {
            assert!(ways.is_power_of_two(), "tree-PLRU requires power-of-two ways");
        }
        CacheSet {
            ways,
            policy,
            slots: vec![None; ways],
            order: (0..ways as u32).collect(),
            tree: vec![false; ways.saturating_sub(1)],
        }
    }

    pub fn ways(&self) -> usize {
        self.ways
    }

    /// Is `tag` currently resident?
    pub fn probe(&self, tag: u64) -> Option<usize> {
        self.slots.iter().position(|s| *s == Some(tag))
    }

    /// Access `tag`: update replacement state, fill/evict on miss.
    pub fn access(&mut self, tag: u64) -> SetAccess {
        if let Some(way) = self.probe(tag) {
            self.touch(way);
            return SetAccess::Hit { way };
        }
        // fill an empty way if available
        if let Some(way) = self.slots.iter().position(|s| s.is_none()) {
            self.slots[way] = Some(tag);
            self.touch(way);
            return SetAccess::MissFill { way };
        }
        // evict per policy
        let way = self.victim();
        let victim = self.slots[way].expect("victim way must be occupied");
        self.slots[way] = Some(tag);
        self.touch(way);
        SetAccess::MissEvict { way, victim }
    }

    /// Replacement victim under the current policy state.
    pub fn victim(&self) -> usize {
        match self.policy {
            Policy::Lru => {
                // highest recency rank = least recently used
                (0..self.ways)
                    .max_by_key(|&i| self.order[i])
                    .expect("nonempty set")
            }
            Policy::PLru => {
                // walk the tree following the bits
                let mut node = 0usize;
                let leaves = self.ways;
                // internal nodes: 0..leaves-1; leaf i corresponds to way i
                while node < leaves - 1 {
                    node = 2 * node + 1 + usize::from(self.tree[node]);
                }
                node - (leaves - 1)
            }
        }
    }

    /// Update recency state after using `way`.
    fn touch(&mut self, way: usize) {
        match self.policy {
            Policy::Lru => {
                let old = self.order[way];
                for r in self.order.iter_mut() {
                    if *r < old {
                        *r += 1;
                    }
                }
                self.order[way] = 0;
            }
            Policy::PLru => {
                // flip bits along the path to point *away* from this leaf
                let leaves = self.ways;
                let mut node = way + (leaves - 1);
                while node > 0 {
                    let parent = (node - 1) / 2;
                    let is_left = node == 2 * parent + 1;
                    // point at the sibling: bit=false means "go left", so if
                    // we used the left child, set bit to true (→right next).
                    self.tree[parent] = is_left;
                    node = parent;
                }
            }
        }
    }

    /// Tags currently resident (for inspection/tests).
    pub fn resident(&self) -> Vec<u64> {
        self.slots.iter().flatten().copied().collect()
    }

    /// Reset to empty.
    pub fn clear(&mut self) {
        for s in self.slots.iter_mut() {
            *s = None;
        }
        self.order = (0..self.ways as u32).collect();
        self.tree.iter_mut().for_each(|b| *b = false);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_evicts_least_recent() {
        let mut s = CacheSet::new(2, Policy::Lru);
        assert!(!s.access(1).is_hit());
        assert!(!s.access(2).is_hit());
        assert!(s.access(1).is_hit()); // order now: 1 recent, 2 old
        let r = s.access(3); // must evict 2
        assert_eq!(r, SetAccess::MissEvict { way: 1, victim: 2 });
        assert!(s.access(1).is_hit());
        assert!(!s.access(2).is_hit());
    }

    #[test]
    fn lru_reuse_distance_k_boundary() {
        // §2.4: a reuse at distance ≤ K hits; at distance > K misses.
        let k = 4;
        let mut s = CacheSet::new(k, Policy::Lru);
        s.access(0);
        for t in 1..=(k as u64 - 1) {
            s.access(t);
        }
        assert!(s.access(0).is_hit(), "distance K-1 must hit");
        let mut s = CacheSet::new(k, Policy::Lru);
        s.access(0);
        for t in 1..=(k as u64) {
            s.access(t);
        }
        assert!(!s.access(0).is_hit(), "distance K+1 must miss");
    }

    #[test]
    fn plru_basic_fill_and_hit() {
        let mut s = CacheSet::new(4, Policy::PLru);
        for t in 0..4 {
            assert!(!s.access(t).is_hit());
        }
        for t in 0..4 {
            assert!(s.access(t).is_hit());
        }
    }

    #[test]
    fn plru_victim_is_not_most_recent() {
        let mut s = CacheSet::new(4, Policy::PLru);
        for t in 0..4 {
            s.access(t);
        }
        let last = 3u64;
        s.access(last);
        let v = s.victim();
        assert_ne!(s.slots[v], Some(last), "PLRU must not evict the MRU line");
    }

    #[test]
    fn plru_differs_from_lru_on_known_sequence() {
        // A classic PLRU anomaly sequence on 4 ways: tree state can evict a
        // line that true LRU would keep. We only assert both policies stay
        // self-consistent and the hit sets eventually diverge for some
        // sequence; concrete divergence: 0 1 2 3 0 4 → LRU evicts 1; PLRU
        // evicts per tree (which after touching 0 points elsewhere).
        let seq = [0u64, 1, 2, 3, 0, 4];
        let mut lru = CacheSet::new(4, Policy::Lru);
        let mut plru = CacheSet::new(4, Policy::PLru);
        for &t in &seq {
            lru.access(t);
            plru.access(t);
        }
        let mut l = lru.resident();
        let mut p = plru.resident();
        l.sort_unstable();
        p.sort_unstable();
        assert_eq!(l, vec![0, 2, 3, 4]); // LRU evicted 1
        assert_eq!(p, vec![0, 1, 3, 4]); // tree-PLRU evicts 2 here
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn plru_rejects_non_pow2() {
        CacheSet::new(3, Policy::PLru);
    }

    #[test]
    fn clear_empties() {
        let mut s = CacheSet::new(2, Policy::Lru);
        s.access(7);
        s.clear();
        assert!(s.resident().is_empty());
        assert!(!s.access(7).is_hit());
    }
}
