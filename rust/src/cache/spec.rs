//! Cache specifications `C = (c, l, K, ρ)` — §1.1.1 of the paper.

/// A single cache level's specification.
///
/// * `capacity` — total bytes the cache can store (`c`)
/// * `line` — bytes fetched per load (`l`)
/// * `ways` — associativity (`K`, lines per set)
/// * `level` — position `ρ` in a `P`-level hierarchy (1 = closest to core)
///
/// Such a cache has `N = c / (l·K)` sets; every `(c/(l·K))`-th cacheline —
/// i.e. every `(c/K)`-th byte — maps to the same set. That striding is the
/// entire mathematical basis of the associativity-lattice model.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CacheSpec {
    pub capacity: usize,
    pub line: usize,
    pub ways: usize,
    pub level: usize,
}

impl CacheSpec {
    pub const fn new(capacity: usize, line: usize, ways: usize, level: usize) -> CacheSpec {
        CacheSpec {
            capacity,
            line,
            ways,
            level,
        }
    }

    /// Number of cache sets `N = c / (l·K)`.
    pub const fn n_sets(&self) -> usize {
        self.capacity / (self.line * self.ways)
    }

    /// Total number of cachelines the cache can hold (`c / l`).
    pub const fn n_lines(&self) -> usize {
        self.capacity / self.line
    }

    /// The set index of a byte address.
    pub const fn set_of_addr(&self, addr: usize) -> usize {
        (addr / self.line) % self.n_sets()
    }

    /// The line index (tag granularity) of a byte address.
    pub const fn line_of_addr(&self, addr: usize) -> usize {
        addr / self.line
    }

    /// Number of *elements* of size `elem` per cacheline.
    pub const fn elems_per_line(&self, elem: usize) -> usize {
        self.line / elem
    }

    /// The set-mapping stride in elements: elements this many apart (in
    /// linearized element index) map to the same set **offset within the
    /// line pattern** — `c / (K · elem)` elements.
    pub const fn set_stride_elems(&self, elem: usize) -> usize {
        self.capacity / (self.ways * elem)
    }

    /// Validate internal consistency (powers of two, divisibility).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.line > 0 && self.ways > 0 && self.capacity > 0);
        anyhow::ensure!(
            self.capacity % (self.line * self.ways) == 0,
            "capacity must be a multiple of line*ways"
        );
        anyhow::ensure!(self.n_sets() > 0, "cache must have at least one set");
        Ok(())
    }

    /// Intel Haswell L1d — the cache the paper tiles for in §4:
    /// 32 KiB, 64-byte lines, 8-way ⇒ 64 sets.
    pub const HASWELL_L1D: CacheSpec = CacheSpec::new(32 * 1024, 64, 8, 1);

    /// Intel Haswell L2: 256 KiB, 64-byte lines, 8-way ⇒ 512 sets.
    pub const HASWELL_L2: CacheSpec = CacheSpec::new(256 * 1024, 64, 8, 2);

    /// Haswell L3 (per-core slice approximation): 2 MiB, 64 B, 16-way.
    pub const HASWELL_L3_SLICE: CacheSpec = CacheSpec::new(2 * 1024 * 1024, 64, 16, 3);

    /// The toy cache of the paper's Figure 1: 2-way, 4 sets, lines of
    /// 2 elements. Expressed in bytes with 8-byte (f64) elements:
    /// line = 16 B, capacity = 4 sets · 2 ways · 16 B = 128 B.
    pub const FIG1_TOY: CacheSpec = CacheSpec::new(128, 16, 2, 1);
}

/// Eviction policy selector — §1.1.4. LRU and tree-PLRU are the two
/// policies modern hardware implements; the paper models both.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Policy {
    Lru,
    /// Tree-based pseudo-LRU (requires `ways` to be a power of two).
    PLru,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn haswell_l1_has_64_sets() {
        assert_eq!(CacheSpec::HASWELL_L1D.n_sets(), 64);
        assert_eq!(CacheSpec::HASWELL_L1D.n_lines(), 512);
        CacheSpec::HASWELL_L1D.validate().unwrap();
    }

    #[test]
    fn fig1_toy_has_4_sets() {
        assert_eq!(CacheSpec::FIG1_TOY.n_sets(), 4);
        assert_eq!(CacheSpec::FIG1_TOY.elems_per_line(8), 2);
        CacheSpec::FIG1_TOY.validate().unwrap();
    }

    #[test]
    fn set_mapping_strides() {
        let c = CacheSpec::HASWELL_L1D;
        // every c/K bytes maps to the same set
        let stride = c.capacity / c.ways;
        for addr in [0usize, 100, 4096] {
            assert_eq!(c.set_of_addr(addr), c.set_of_addr(addr + stride));
        }
        // consecutive lines map to consecutive sets
        assert_eq!(c.set_of_addr(0), 0);
        assert_eq!(c.set_of_addr(64), 1);
        assert_eq!(c.set_of_addr(64 * 64), 0);
    }

    #[test]
    fn invalid_spec_rejected() {
        assert!(CacheSpec::new(100, 64, 8, 1).validate().is_err());
    }
}
