//! The set-associative cache simulator — the testbed substitute for the
//! paper's Haswell measurements (DESIGN.md §3, "testbed substitution").
//!
//! One [`CacheSim`] models one level. It classifies every miss with the
//! traditional 3-C taxonomy by running a fully-associative LRU *shadow*
//! cache of the same capacity alongside the real set-indexed array — the
//! standard simulation technique for separating conflict from capacity
//! misses — so benchmarks can quantify the paper's claim that conflict
//! misses dominate whenever tiling is wrong.

use std::collections::{BTreeMap, HashMap, HashSet};

use super::set::{CacheSet, SetAccess};
use super::spec::{CacheSpec, Policy};
use super::stats::{CacheStats, MissKind};

/// Single-level cache simulator.
#[derive(Clone, Debug)]
pub struct CacheSim {
    spec: CacheSpec,
    policy: Policy,
    sets: Vec<CacheSet>,
    /// Fully-associative LRU shadow used only for miss classification,
    /// hash-indexed for O(log n) touches: `shadow_pos` maps a resident
    /// line tag to its recency stamp, `shadow_order` keeps stamps sorted
    /// so the LRU victim is the first entry. Capacity: `spec.n_lines()`
    /// tags. (The seed kept a `Vec` recency list scanned linearly —
    /// O(n_lines) per access.)
    shadow_pos: HashMap<u64, u64>,
    shadow_order: BTreeMap<u64, u64>,
    shadow_stamp: u64,
    /// Every line tag ever touched (cold-miss detection).
    touched: HashSet<u64>,
    stats: CacheStats,
    /// If false, skip the shadow structures: ~2× faster, misses all count
    /// as `Conflict` (the paper's unified view).
    classify: bool,
}

/// Outcome of a single byte-address access.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Access {
    pub set: usize,
    pub line: u64,
    pub hit: bool,
    pub kind: Option<MissKind>,
}

impl CacheSim {
    pub fn new(spec: CacheSpec, policy: Policy) -> CacheSim {
        spec.validate().expect("invalid cache spec");
        let n = spec.n_sets();
        CacheSim {
            spec,
            policy,
            sets: (0..n).map(|_| CacheSet::new(spec.ways, policy)).collect(),
            shadow_pos: HashMap::new(),
            shadow_order: BTreeMap::new(),
            shadow_stamp: 0,
            touched: HashSet::new(),
            stats: CacheStats::new(n),
            classify: true,
        }
    }

    /// Disable 3-C classification (all misses recorded as `Conflict`) —
    /// the paper's single-category view, and the fast path for benches.
    pub fn without_classification(mut self) -> CacheSim {
        self.classify = false;
        self
    }

    pub fn spec(&self) -> &CacheSpec {
        &self.spec
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    /// Access one byte address (the whole line is loaded on miss).
    pub fn access(&mut self, addr: usize) -> Access {
        let line = self.spec.line_of_addr(addr) as u64;
        self.access_line(line)
    }

    /// Access by line tag directly (addr / line_size precomputed).
    pub fn access_line(&mut self, line: u64) -> Access {
        let set = (line as usize) % self.spec.n_sets();
        let res = self.sets[set].access(line);
        let hit = res.is_hit();

        let kind = if hit {
            if self.classify {
                self.shadow_touch(line);
            }
            None
        } else if !self.classify {
            Some(MissKind::Conflict)
        } else if !self.touched.contains(&line) {
            self.touched.insert(line);
            self.shadow_touch(line);
            Some(MissKind::Cold)
        } else {
            // seen before: capacity if the fully-associative shadow also
            // evicted it, conflict otherwise.
            let in_shadow = self.shadow_pos.contains_key(&line);
            self.shadow_touch(line);
            if in_shadow {
                Some(MissKind::Conflict)
            } else {
                Some(MissKind::Capacity)
            }
        };
        self.stats.record(set, kind);
        let _ = match res {
            SetAccess::MissEvict { victim, .. } => Some(victim),
            _ => None,
        };
        Access {
            set,
            line,
            hit,
            kind,
        }
    }

    fn shadow_touch(&mut self, line: u64) {
        if let Some(old) = self.shadow_pos.get(&line).copied() {
            self.shadow_order.remove(&old);
        } else if self.shadow_pos.len() == self.spec.n_lines() {
            // evict the least recently used tag (smallest stamp)
            if let Some((_, victim)) = self.shadow_order.pop_first() {
                self.shadow_pos.remove(&victim);
            }
        }
        self.shadow_stamp += 1;
        self.shadow_pos.insert(line, self.shadow_stamp);
        self.shadow_order.insert(self.shadow_stamp, line);
    }

    /// Run a whole address trace; returns total misses.
    pub fn run_trace<I: IntoIterator<Item = usize>>(&mut self, addrs: I) -> u64 {
        let before = self.stats.misses();
        for a in addrs {
            self.access(a);
        }
        self.stats.misses() - before
    }

    /// Is this line currently resident?
    pub fn probe(&self, addr: usize) -> bool {
        let line = self.spec.line_of_addr(addr) as u64;
        let set = (line as usize) % self.spec.n_sets();
        self.sets[set].probe(line).is_some()
    }

    /// Flush contents and statistics.
    pub fn reset(&mut self) {
        for s in self.sets.iter_mut() {
            s.clear();
        }
        self.shadow_pos.clear();
        self.shadow_order.clear();
        self.shadow_stamp = 0;
        self.touched.clear();
        self.stats = CacheStats::new(self.spec.n_sets());
    }
}

/// A multi-level inclusive hierarchy: every access walks L1 → L2 → … until
/// it hits; lower levels are only consulted (and filled) on upper misses.
#[derive(Clone, Debug)]
pub struct Hierarchy {
    levels: Vec<CacheSim>,
}

impl Hierarchy {
    pub fn new(levels: Vec<CacheSim>) -> Hierarchy {
        assert!(!levels.is_empty());
        for w in levels.windows(2) {
            assert!(
                w[0].spec().level < w[1].spec().level,
                "levels must be ordered by ρ"
            );
        }
        Hierarchy { levels }
    }

    /// Haswell L1d + L2 with a shared policy.
    pub fn haswell(policy: Policy) -> Hierarchy {
        Hierarchy::new(vec![
            CacheSim::new(CacheSpec::HASWELL_L1D, policy),
            CacheSim::new(CacheSpec::HASWELL_L2, policy),
        ])
    }

    /// Haswell L1d + L2 + L3 slice — the three-level hierarchy the
    /// super-band schedule is sized against (`level(2)` is the L3 slice).
    pub fn haswell_l3(policy: Policy) -> Hierarchy {
        Hierarchy::new(vec![
            CacheSim::new(CacheSpec::HASWELL_L1D, policy),
            CacheSim::new(CacheSpec::HASWELL_L2, policy),
            CacheSim::new(CacheSpec::HASWELL_L3_SLICE, policy),
        ])
    }

    /// Access an address; returns the level that hit (1-based), or
    /// `levels.len() + 1` meaning DRAM.
    pub fn access(&mut self, addr: usize) -> usize {
        for (i, level) in self.levels.iter_mut().enumerate() {
            if level.access(addr).hit {
                return i + 1;
            }
        }
        self.levels.len() + 1
    }

    pub fn level(&self, i: usize) -> &CacheSim {
        &self.levels[i]
    }

    pub fn levels(&self) -> &[CacheSim] {
        &self.levels
    }

    pub fn reset(&mut self) {
        for l in self.levels.iter_mut() {
            l.reset();
        }
    }

    /// Total access cost in cycles with a simple per-level latency model
    /// (L1 hit 4, L2 hit 12, DRAM ~200 — Haswell-like).
    pub fn cost_model(&self) -> u64 {
        const LAT: [u64; 4] = [4, 12, 40, 200];
        let mut cost = 0u64;
        let mut remaining: u64 = 0;
        for (i, l) in self.levels.iter().enumerate() {
            let hits = l.stats().hits;
            cost += hits * LAT[i.min(3)];
            remaining = l.stats().misses();
        }
        cost + remaining * LAT[3.min(LAT.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_then_hit() {
        let mut c = CacheSim::new(CacheSpec::FIG1_TOY, Policy::Lru);
        let a = c.access(0);
        assert_eq!(a.kind, Some(MissKind::Cold));
        let a = c.access(8); // same 16-byte line
        assert!(a.hit);
        assert_eq!(c.stats().accesses, 2);
        assert_eq!(c.stats().misses(), 1);
    }

    #[test]
    fn conflict_vs_capacity_classification() {
        // FIG1_TOY: 4 sets, 2 ways, 16B lines, 8 lines total.
        // Thrash one set with 3 lines that all map to set 0:
        // line stride to same set = n_sets * line = 64 bytes.
        let mut c = CacheSim::new(CacheSpec::FIG1_TOY, Policy::Lru);
        let s0 = [0usize, 64, 128];
        for _ in 0..3 {
            for &a in &s0 {
                c.access(a);
            }
        }
        // only 3 distinct lines — far below the 8-line capacity, so every
        // non-cold miss must be classified Conflict.
        assert_eq!(c.stats().cold, 3);
        assert_eq!(c.stats().capacity, 0);
        assert!(c.stats().conflict > 0);
    }

    #[test]
    fn capacity_miss_when_working_set_exceeds_cache() {
        // Stream 16 distinct lines (2× capacity) twice, touching *all* sets
        // uniformly: second pass misses are capacity, not conflict.
        let mut c = CacheSim::new(CacheSpec::FIG1_TOY, Policy::Lru);
        for pass in 0..2 {
            for i in 0..16usize {
                c.access(i * 16);
            }
            if pass == 0 {
                assert_eq!(c.stats().cold, 16);
            }
        }
        assert_eq!(c.stats().capacity, 16);
        assert_eq!(c.stats().conflict, 0);
    }

    #[test]
    fn fig1_subarray_cannot_fit() {
        // Paper Figure 1: 8×5 column-major f64 array, lines of 2 elements,
        // 2-way, 4 sets. The upper 2×5 sub-array cannot be resident without
        // conflict misses even though it is only 10 elements (5 lines) in an
        // 8-line cache.
        //
        // NOTE: the paper's *figure* uses a nonstandard mapping
        // (set = (line div K) mod N — K consecutive lines share a set),
        // under which the sub-array has 3 lines in set 0 and 2 in set 2.
        // The paper's *text* formula ("every (c/(lK))-th cacheline maps to
        // the same set") is the standard hardware mapping set = line mod N,
        // which we implement. Under it the effect is even stronger: all 5
        // sub-array lines map to set 0. The qualitative claim — the
        // sub-array thrashes a 2-way set despite fitting in capacity —
        // holds under both; we assert the standard-mapping version.
        let spec = CacheSpec::FIG1_TOY;
        let mut c = CacheSim::new(spec, Policy::Lru);
        let elem = 8usize; // f64
        let m1 = 8usize; // rows
        let addr = |i: usize, j: usize| (i + m1 * j) * elem;
        // standard map, column 0: rows 0..8 → sets 0,0,1,1,2,2,3,3
        for i in 0..8 {
            assert_eq!(spec.set_of_addr(addr(i, 0)), i / 2);
        }
        // sub-array rows {0,1} × cols {0..5}: count distinct lines per set
        let mut lines_per_set: [HashSet<usize>; 4] = Default::default();
        for j in 0..5 {
            for i in 0..2 {
                let a = addr(i, j);
                lines_per_set[spec.set_of_addr(a)].insert(spec.line_of_addr(a));
            }
        }
        // every column's rows {0,1} land in set 0: 5 lines > K=2 ways
        assert_eq!(lines_per_set[0].len(), 5);
        assert!(lines_per_set[0].len() > spec.ways);
        // traverse the sub-array repeatedly: steady-state misses persist
        for _ in 0..4 {
            for j in 0..5 {
                for i in 0..2 {
                    c.access(addr(i, j));
                }
            }
        }
        let warm = c.stats().misses();
        for j in 0..5 {
            for i in 0..2 {
                c.access(addr(i, j));
            }
        }
        assert!(
            c.stats().misses() > warm,
            "paper's Fig.1 claims steady-state conflict misses"
        );
        assert_eq!(c.stats().capacity, 0, "all non-cold misses are conflicts");
    }

    #[test]
    fn hierarchy_l2_catches_l1_conflicts() {
        let mut h = Hierarchy::haswell(Policy::Lru);
        // two lines conflicting in L1 (stride = 32KiB/8 = 4KiB apart ⇒ same
        // L1 set) but NOT in L2 (256KiB/8 = 32KiB stride)
        let (a, b) = (0usize, 4096usize);
        assert_eq!(
            CacheSpec::HASWELL_L1D.set_of_addr(a),
            CacheSpec::HASWELL_L1D.set_of_addr(b)
        );
        for _ in 0..20 {
            h.access(a);
            h.access(b);
        }
        // both fit easily in 8-way L1 — all hits after the 2 colds
        assert_eq!(h.level(0).stats().misses(), 2);
        // now thrash the L1 set with 9 conflicting lines
        h.reset();
        for _ in 0..10 {
            for k in 0..9usize {
                h.access(k * 4096);
            }
        }
        assert!(h.level(0).stats().misses() > 9);
        // L2 absorbs them: 9 lines map to *different* L2 sets
        assert_eq!(h.level(1).stats().misses(), 9);
    }

    #[test]
    fn policy_changes_miss_counts() {
        // Deterministic divergence: 4-way cache, 4 sets, all accesses to
        // set 0 (line-tag stride = n_sets). After 0 1 2 3 0 4, LRU holds
        // {0,2,3,4} (evicted 1) while tree-PLRU holds {0,1,3,4} (evicted 2);
        // the subsequent access to 2 hits under LRU, misses under PLRU.
        let spec = CacheSpec::new(4 * 4 * 16, 16, 4, 1); // 4 sets, 4 ways
        let mut lru = CacheSim::new(spec, Policy::Lru);
        let mut plru = CacheSim::new(spec, Policy::PLru);
        let set_stride = spec.n_sets() * spec.line; // bytes between same-set lines
        let trace: Vec<usize> = [0usize, 1, 2, 3, 0, 4, 2]
            .iter()
            .map(|&t| t * set_stride)
            .collect();
        let ml = lru.run_trace(trace.iter().copied());
        let mp = plru.run_trace(trace.iter().copied());
        assert_eq!(ml, 5, "LRU: 5 cold/conflict misses");
        assert_eq!(mp, 6, "PLRU: extra miss on the re-access of 2");
    }

    #[test]
    fn hash_shadow_matches_reference_recency_list() {
        // The 3-C classification must be identical to the seed's linear
        // recency-list shadow, replayed here as the reference, over a
        // trace mixing short/long reuse distances on two specs.
        for spec in [CacheSpec::FIG1_TOY, CacheSpec::new(16 * 4 * 16, 16, 4, 1)] {
            let mut sim = CacheSim::new(spec, Policy::Lru);
            let mut rng = crate::testutil::Rng::new(0x1234_5678);
            let span = spec.n_lines() as u64 * spec.line as u64 * 12;
            let mut trace: Vec<usize> =
                (0..6000).map(|_| (rng.next_u64() % span) as usize).collect();
            // deterministic tail: thrash one set with ways+1 lines (all
            // shadow-resident) so conflict misses provably occur
            let set_stride = spec.n_sets() * spec.line;
            for _ in 0..3 {
                for t in 0..=spec.ways {
                    trace.push(t * set_stride);
                }
            }
            let mut shadow: Vec<u64> = Vec::new();
            let mut touched = HashSet::new();
            let (mut cold, mut capacity, mut conflict) = (0u64, 0u64, 0u64);
            for &addr in &trace {
                let acc = sim.access(addr);
                let line = acc.line;
                let expect = if acc.hit {
                    None
                } else if touched.insert(line) {
                    Some(MissKind::Cold)
                } else if shadow.contains(&line) {
                    Some(MissKind::Conflict)
                } else {
                    Some(MissKind::Capacity)
                };
                assert_eq!(acc.kind, expect, "addr {addr}");
                match acc.kind {
                    Some(MissKind::Cold) => cold += 1,
                    Some(MissKind::Capacity) => capacity += 1,
                    Some(MissKind::Conflict) => conflict += 1,
                    None => {}
                }
                // reference recency-list touch (the seed implementation)
                if let Some(pos) = shadow.iter().position(|&l| l == line) {
                    shadow.remove(pos);
                } else if shadow.len() == spec.n_lines() {
                    shadow.pop();
                }
                shadow.insert(0, line);
            }
            assert_eq!(sim.stats().cold, cold);
            assert_eq!(sim.stats().capacity, capacity);
            assert_eq!(sim.stats().conflict, conflict);
            assert!(capacity > 0 && conflict > 0, "trace must exercise both");
        }
    }

    #[test]
    fn unclassified_mode_counts_same_total() {
        let trace: Vec<usize> = (0..500).map(|i| (i * 97) % 8192).collect();
        let mut a = CacheSim::new(CacheSpec::FIG1_TOY, Policy::Lru);
        let mut b =
            CacheSim::new(CacheSpec::FIG1_TOY, Policy::Lru).without_classification();
        let ma = a.run_trace(trace.iter().copied());
        let mb = b.run_trace(trace.iter().copied());
        assert_eq!(ma, mb);
        assert_eq!(b.stats().cold + b.stats().capacity, 0);
    }

    use std::collections::HashSet;
}
