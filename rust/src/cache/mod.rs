//! K-way set-associative cache simulation (DESIGN.md S1).
//!
//! The paper's evaluation ran on Intel Haswell; this module is the
//! simulated testbed that stands in for it: exact set-indexed caches with
//! LRU and tree-PLRU replacement (§1.1.4), a fully-associative shadow for
//! traditional 3-C miss classification (so the paper's "everything is a
//! conflict miss" thesis is *checkable*, §1.1.2), per-set statistics
//! (§1.1.3's one-set perspective), and a simple multi-level hierarchy.

pub mod set;
pub mod sim;
pub mod spec;
pub mod stats;

pub use set::{CacheSet, SetAccess};
pub use sim::{Access, CacheSim, Hierarchy};
pub use spec::{CacheSpec, Policy};
pub use stats::{CacheStats, MissKind};
