//! Dense exact integer matrices over `i128`.
//!
//! Column-oriented: lattice bases are stored as matrices whose **columns**
//! are the basis vectors, matching the paper's `(p_1 ⋯ p_d)` notation in
//! §3.2. Everything is exact; sizes are tiny (d ≤ 6) so O(d³) algorithms
//! with arbitrary clarity win over cleverness.

use super::rational::Rat;
use std::fmt;

/// A dense `rows × cols` integer matrix, row-major storage.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct IMat {
    rows: usize,
    cols: usize,
    data: Vec<i128>,
}

impl IMat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> IMat {
        IMat {
            rows,
            cols,
            data: vec![0; rows * cols],
        }
    }

    /// Identity.
    pub fn identity(n: usize) -> IMat {
        let mut m = IMat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1;
        }
        m
    }

    /// From row-major nested slices.
    pub fn from_rows(rows: &[&[i128]]) -> IMat {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut m = IMat::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            for (j, &v) in row.iter().enumerate() {
                m[(i, j)] = v;
            }
        }
        m
    }

    /// Matrix whose columns are the given vectors.
    pub fn from_cols(cols: &[Vec<i128>]) -> IMat {
        let c = cols.len();
        let r = if c == 0 { 0 } else { cols[0].len() };
        let mut m = IMat::zeros(r, c);
        for (j, col) in cols.iter().enumerate() {
            assert_eq!(col.len(), r, "ragged cols");
            for (i, &v) in col.iter().enumerate() {
                m[(i, j)] = v;
            }
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn col(&self, j: usize) -> Vec<i128> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn row(&self, i: usize) -> Vec<i128> {
        (0..self.cols).map(|j| self[(i, j)]).collect()
    }

    pub fn set_col(&mut self, j: usize, v: &[i128]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    pub fn swap_cols(&mut self, a: usize, b: usize) {
        for i in 0..self.rows {
            self.data.swap(i * self.cols + a, i * self.cols + b);
        }
    }

    /// `col[a] += k * col[b]` — an elementary unimodular column operation.
    pub fn add_col_mul(&mut self, a: usize, b: usize, k: i128) {
        for i in 0..self.rows {
            let add = k
                .checked_mul(self[(i, b)])
                .expect("add_col_mul overflow");
            self[(i, a)] = self[(i, a)].checked_add(add).expect("add_col_mul overflow");
        }
    }

    pub fn neg_col(&mut self, a: usize) {
        for i in 0..self.rows {
            self[(i, a)] = -self[(i, a)];
        }
    }

    /// Matrix product.
    pub fn mul(&self, o: &IMat) -> IMat {
        assert_eq!(self.cols, o.rows, "dim mismatch in mul");
        let mut out = IMat::zeros(self.rows, o.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0 {
                    continue;
                }
                for j in 0..o.cols {
                    out[(i, j)] = out[(i, j)]
                        .checked_add(a.checked_mul(o[(k, j)]).expect("mul overflow"))
                        .expect("mul overflow");
                }
            }
        }
        out
    }

    /// Matrix-vector product.
    pub fn mul_vec(&self, v: &[i128]) -> Vec<i128> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|i| {
                (0..self.cols)
                    .map(|j| self[(i, j)] * v[j])
                    .sum::<i128>()
            })
            .collect()
    }

    /// Exact determinant via Bareiss fraction-free elimination. Square only.
    pub fn det(&self) -> i128 {
        assert_eq!(self.rows, self.cols, "det of non-square matrix");
        let n = self.rows;
        if n == 0 {
            return 1;
        }
        let mut m = self.data.clone();
        let idx = |i: usize, j: usize| i * n + j;
        let mut sign = 1i128;
        let mut prev = 1i128;
        for k in 0..n - 1 {
            // pivot
            if m[idx(k, k)] == 0 {
                let Some(p) = (k + 1..n).find(|&i| m[idx(i, k)] != 0) else {
                    return 0;
                };
                for j in 0..n {
                    m.swap(idx(k, j), idx(p, j));
                }
                sign = -sign;
            }
            for i in k + 1..n {
                for j in k + 1..n {
                    let num = m[idx(i, j)]
                        .checked_mul(m[idx(k, k)])
                        .and_then(|a| {
                            m[idx(i, k)]
                                .checked_mul(m[idx(k, j)])
                                .and_then(|b| a.checked_sub(b))
                        })
                        .expect("det overflow");
                    m[idx(i, j)] = num / prev; // exact by Bareiss
                }
                m[idx(i, k)] = 0;
            }
            prev = m[idx(k, k)];
        }
        sign * m[idx(n - 1, n - 1)]
    }

    /// Exact inverse as a rational matrix. Panics if singular.
    pub fn inverse(&self) -> RMat {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        // Gauss-Jordan over rationals.
        let mut a: Vec<Rat> = self.data.iter().map(|&v| Rat::int(v)).collect();
        let mut inv: Vec<Rat> = IMat::identity(n).data.iter().map(|&v| Rat::int(v)).collect();
        let idx = |i: usize, j: usize| i * n + j;
        for col in 0..n {
            let piv = (col..n)
                .find(|&i| !a[idx(i, col)].is_zero())
                .expect("inverse of singular matrix");
            if piv != col {
                for j in 0..n {
                    a.swap(idx(col, j), idx(piv, j));
                    inv.swap(idx(col, j), idx(piv, j));
                }
            }
            let p = a[idx(col, col)];
            for j in 0..n {
                a[idx(col, j)] = a[idx(col, j)] / p;
                inv[idx(col, j)] = inv[idx(col, j)] / p;
            }
            for i in 0..n {
                if i == col {
                    continue;
                }
                let f = a[idx(i, col)];
                if f.is_zero() {
                    continue;
                }
                for j in 0..n {
                    a[idx(i, j)] = a[idx(i, j)] - f * a[idx(col, j)];
                    inv[idx(i, j)] = inv[idx(i, j)] - f * inv[idx(col, j)];
                }
            }
        }
        RMat {
            rows: n,
            cols: n,
            data: inv,
        }
    }

    /// Transpose.
    pub fn transpose(&self) -> IMat {
        let mut out = IMat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }
}

impl std::ops::Index<(usize, usize)> for IMat {
    type Output = i128;
    fn index(&self, (i, j): (usize, usize)) -> &i128 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for IMat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut i128 {
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for IMat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "IMat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows {
            writeln!(f, "  {:?}", self.row(i))?;
        }
        write!(f, "]")
    }
}

/// A dense rational matrix — the inverse tile matrix `H` of §3.2 lives here.
#[derive(Clone, PartialEq, Eq)]
pub struct RMat {
    rows: usize,
    cols: usize,
    data: Vec<Rat>,
}

impl RMat {
    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `H·x` for an integer vector `x` — exact.
    pub fn mul_ivec(&self, v: &[i128]) -> Vec<Rat> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|i| {
                (0..self.cols).fold(Rat::ZERO, |acc, j| {
                    acc + self[(i, j)] * Rat::int(v[j])
                })
            })
            .collect()
    }
}

impl std::ops::Index<(usize, usize)> for RMat {
    type Output = Rat;
    fn index(&self, (i, j): (usize, usize)) -> &Rat {
        &self.data[i * self.cols + j]
    }
}

impl fmt::Debug for RMat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "RMat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows {
            let row: Vec<String> = (0..self.cols).map(|j| format!("{}", self[(i, j)])).collect();
            writeln!(f, "  [{}]", row.join(", "))?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_mul() {
        let i3 = IMat::identity(3);
        let m = IMat::from_rows(&[&[1, 2, 3], &[4, 5, 6], &[7, 8, 10]]);
        assert_eq!(i3.mul(&m), m);
        assert_eq!(m.mul(&i3), m);
    }

    #[test]
    fn det_small() {
        assert_eq!(IMat::identity(4).det(), 1);
        let m = IMat::from_rows(&[&[1, 2], &[3, 4]]);
        assert_eq!(m.det(), -2);
        let s = IMat::from_rows(&[&[1, 2, 3], &[4, 5, 6], &[7, 8, 9]]);
        assert_eq!(s.det(), 0);
        // the paper's Figure 3 lattice generator
        let g = IMat::from_rows(&[&[5, 7], &[61, -17]]);
        assert_eq!(g.det().abs(), 512);
    }

    #[test]
    fn det_pivot_swap() {
        let m = IMat::from_rows(&[&[0, 1], &[1, 0]]);
        assert_eq!(m.det(), -1);
        let m = IMat::from_rows(&[&[0, 2, 1], &[3, 0, 0], &[0, 0, 4]]);
        assert_eq!(m.det(), -24);
    }

    #[test]
    fn inverse_roundtrip() {
        let m = IMat::from_rows(&[&[5, 7], &[61, -17]]);
        let inv = m.inverse();
        // inv * m = I
        for i in 0..2 {
            for j in 0..2 {
                let mut acc = Rat::ZERO;
                for k in 0..2 {
                    acc = acc + inv[(i, k)] * Rat::int(m[(k, j)]);
                }
                assert_eq!(acc, if i == j { Rat::ONE } else { Rat::ZERO });
            }
        }
    }

    #[test]
    fn mul_vec_works() {
        let m = IMat::from_rows(&[&[1, 2], &[3, 4]]);
        assert_eq!(m.mul_vec(&[1, 1]), vec![3, 7]);
    }

    #[test]
    #[should_panic(expected = "singular")]
    fn inverse_singular_panics() {
        IMat::from_rows(&[&[1, 2], &[2, 4]]).inverse();
    }
}
