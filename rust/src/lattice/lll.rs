//! LLL lattice basis reduction with exact rational arithmetic.
//!
//! The paper's tile-selection heuristic (§4.0.4) starts from a *reduced*
//! basis of the operand's conflict lattice `L(C, φ)` — short, nearly
//! orthogonal basis vectors make compact, well-shaped parallelepiped tiles.
//! The paper used NTL's LLL; we implement the classic Lenstra–Lenstra–Lovász
//! algorithm with δ = 3/4 over exact rationals (`Rat`), which is plenty fast
//! for the d ≤ 4 lattices arising from array index maps.

use super::mat::IMat;
use super::rational::Rat;

/// LLL parameter δ; 3/4 is the textbook choice.
const DELTA: (i128, i128) = (3, 4);

/// Gram–Schmidt orthogonalization over rationals.
///
/// Returns `(mu, b_star_norm2)` where `mu[i][j]` (j < i) are the GS
/// coefficients and `b_star_norm2[i] = ‖b*_i‖²` as exact rationals.
fn gram_schmidt(basis: &[Vec<i128>]) -> (Vec<Vec<Rat>>, Vec<Rat>) {
    let n = basis.len();
    let dim = basis[0].len();
    // b*_i stored as rational vectors
    let mut bstar: Vec<Vec<Rat>> = Vec::with_capacity(n);
    let mut mu = vec![vec![Rat::ZERO; n]; n];
    let mut norm2 = vec![Rat::ZERO; n];
    for i in 0..n {
        let mut v: Vec<Rat> = basis[i].iter().map(|&x| Rat::int(x)).collect();
        for j in 0..i {
            // mu_ij = <b_i, b*_j> / ||b*_j||^2
            let mut dot = Rat::ZERO;
            for k in 0..dim {
                dot = dot + Rat::int(basis[i][k]) * bstar[j][k];
            }
            let m = if norm2[j].is_zero() { Rat::ZERO } else { dot / norm2[j] };
            mu[i][j] = m;
            for k in 0..dim {
                v[k] = v[k] - m * bstar[j][k];
            }
        }
        let mut n2 = Rat::ZERO;
        for k in 0..dim {
            n2 = n2 + v[k] * v[k];
        }
        norm2[i] = n2;
        bstar.push(v);
    }
    (mu, norm2)
}

/// LLL-reduce the columns of `basis_mat` (columns = basis vectors).
/// Returns a new matrix with the same column lattice, LLL-reduced.
///
/// Panics if the columns are linearly dependent.
pub fn lll_reduce(basis_mat: &IMat) -> IMat {
    let n = basis_mat.cols();
    let mut b: Vec<Vec<i128>> = (0..n).map(|j| basis_mat.col(j)).collect();
    assert!(n > 0);

    let delta = Rat::new(DELTA.0, DELTA.1);
    let (mut mu, mut norm2) = gram_schmidt(&b);
    for v in &norm2 {
        assert!(!v.is_zero(), "LLL input basis is linearly dependent");
    }

    let mut k = 1usize;
    let mut guard = 0usize;
    while k < n {
        guard += 1;
        assert!(guard < 100_000, "LLL failed to converge");
        // size-reduce b_k against b_{k-1} ... b_0
        for j in (0..k).rev() {
            let r = mu[k][j].round();
            if r != 0 {
                for t in 0..b[k].len() {
                    b[k][t] -= r * b[j][t];
                }
                let (m2, n2) = gram_schmidt(&b);
                mu = m2;
                norm2 = n2;
            }
        }
        // Lovász condition
        let lhs = norm2[k];
        let rhs = (delta - mu[k][k - 1] * mu[k][k - 1]) * norm2[k - 1];
        if lhs >= rhs {
            k += 1;
        } else {
            b.swap(k, k - 1);
            let (m2, n2) = gram_schmidt(&b);
            mu = m2;
            norm2 = n2;
            k = k.max(2) - 1;
        }
    }
    IMat::from_cols(&b)
}

/// Squared Euclidean norm of an integer vector.
pub fn norm2(v: &[i128]) -> i128 {
    v.iter().map(|&x| x * x).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn is_same_lattice(a: &IMat, b: &IMat) -> bool {
        // same det and mutual membership of columns
        if a.det().abs() != b.det().abs() {
            return false;
        }
        let la = crate::lattice::Lattice::from_basis(a.clone());
        let lb = crate::lattice::Lattice::from_basis(b.clone());
        (0..b.cols()).all(|j| la.contains(&b.col(j)))
            && (0..a.cols()).all(|j| lb.contains(&a.col(j)))
    }

    #[test]
    fn lll_identity_fixed() {
        let i = IMat::identity(3);
        let r = lll_reduce(&i);
        assert_eq!(r.det().abs(), 1);
    }

    #[test]
    fn lll_classic_example() {
        // A standard textbook case: the reduced basis of [[1,1,1],[−1,0,2],[3,5,6]]
        let b = IMat::from_cols(&[vec![1, 1, 1], vec![-1, 0, 2], vec![3, 5, 6]]);
        let r = lll_reduce(&b);
        assert!(is_same_lattice(&b, &r));
        // all reduced vectors should be short
        for j in 0..3 {
            assert!(norm2(&r.col(j)) <= 9, "vector {j} too long: {:?}", r.col(j));
        }
    }

    #[test]
    fn lll_paper_fig3_lattice() {
        // generator (5,61),(7,-17) — det 512. LLL should find short vectors.
        let b = IMat::from_cols(&[vec![5, 61], vec![7, -17]]);
        let r = lll_reduce(&b);
        assert!(is_same_lattice(&b, &r));
        assert_eq!(r.det().abs(), 512);
        // shortest vector in this lattice has norm2 well under the original 5^2+61^2
        assert!(norm2(&r.col(0)) < 5 * 5 + 61 * 61);
    }

    #[test]
    fn lll_skewed_2d() {
        // highly skewed basis of Z^2
        let b = IMat::from_cols(&[vec![1, 0], vec![1000, 1]]);
        let r = lll_reduce(&b);
        assert!(is_same_lattice(&b, &r));
        assert!(norm2(&r.col(0)) <= 2);
        assert!(norm2(&r.col(1)) <= 2);
    }

    #[test]
    fn lll_preserves_det() {
        let b = IMat::from_cols(&[vec![12, 2], vec![13, 4]]);
        let r = lll_reduce(&b);
        assert_eq!(r.det().abs(), b.det().abs());
    }
}
