//! Integer-lattice machinery — the paper's NTL substitute (DESIGN.md S2).
//!
//! The central object is [`Lattice`], a full-rank sublattice of `Z^d` given
//! by a column basis. The associativity analysis of §2.3 produces such
//! lattices as `L(C, φ) = {x ∈ Z^d : φ(x) ≡ 0 (mod N)}` for an affine index
//! map `φ` and a cache with `N` sets; see [`Lattice::from_congruence`].

pub mod hnf;
pub mod lll;
pub mod mat;
pub mod rational;

pub use hnf::{basis_from_generators, column_hnf, kernel_of_row};
pub use lll::{lll_reduce, norm2};
pub use mat::{IMat, RMat};
pub use rational::{ext_gcd, gcd, lcm, Rat};

/// A full-rank integer lattice `L ⊆ Z^d`, stored as a column basis together
/// with its exact rational inverse (for membership tests and the tiling
/// transform of §3.2).
#[derive(Clone, Debug)]
pub struct Lattice {
    basis: IMat,
    inv: RMat,
    det: i128,
}

impl Lattice {
    /// Build from a (full-rank, square) column basis.
    pub fn from_basis(basis: IMat) -> Lattice {
        assert_eq!(basis.rows(), basis.cols(), "lattice basis must be square");
        let det = basis.det();
        assert!(det != 0, "lattice basis is singular");
        let inv = basis.inverse();
        Lattice { basis, inv, det }
    }

    /// Build from an arbitrary generating set (columns of `gens`); computes
    /// the HNF basis. Panics if not full rank.
    pub fn from_generators(gens: &IMat) -> Lattice {
        Lattice::from_basis(basis_from_generators(gens, true))
    }

    /// The conflict lattice `L(C, φ)` of §2.3 for a linear index map
    /// `φ(x) = Σ w_r x_r` and a cache with `n_sets` sets:
    ///
    /// `L = {x ∈ Z^d : w·x ≡ 0 (mod n_sets)}`.
    ///
    /// Constructed **without any lattice-point counting** (one of the
    /// paper's selling points): `L` is the projection onto the `x`
    /// coordinates of the kernel of the integer row `[w | n_sets]`, which we
    /// get in closed form from an extended-gcd elimination.
    pub fn from_congruence(weights: &[i128], n_sets: i128) -> Lattice {
        assert!(n_sets > 0, "need a positive number of cache sets");
        let d = weights.len();
        assert!(d > 0);
        // kernel of the row [w_1 ... w_d N] in Z^{d+1}
        let mut row: Vec<i128> = weights.to_vec();
        row.push(n_sets);
        let k = kernel_of_row(&row); // (d+1) x d
        // project to first d coordinates; (x, t) ↦ x is injective on the
        // kernel because t = −(w·x)/N is determined.
        let cols: Vec<Vec<i128>> = (0..k.cols()).map(|j| k.col(j)[..d].to_vec()).collect();
        let gens = IMat::from_cols(&cols);
        Lattice::from_generators(&gens)
    }

    pub fn dim(&self) -> usize {
        self.basis.rows()
    }

    /// Column basis `(p_1 ⋯ p_d)`.
    pub fn basis(&self) -> &IMat {
        &self.basis
    }

    /// Exact inverse basis — the `H` matrix of §3.2 when this lattice's
    /// basis is used as the tile parallelepiped.
    pub fn inverse_basis(&self) -> &RMat {
        &self.inv
    }

    /// |det(basis)| — the volume of the fundamental parallelepiped, and the
    /// index `[Z^d : L]`.
    pub fn det_abs(&self) -> i128 {
        self.det.abs()
    }

    /// Lattice membership: `v ∈ L` iff `B⁻¹ v` is integral.
    pub fn contains(&self, v: &[i128]) -> bool {
        self.inv.mul_ivec(v).iter().all(|c| c.is_integer())
    }

    /// The coordinates of `v` in the basis, if `v ∈ L`.
    pub fn coordinates(&self, v: &[i128]) -> Option<Vec<i128>> {
        let c = self.inv.mul_ivec(v);
        if c.iter().all(|x| x.is_integer()) {
            Some(c.iter().map(|x| x.floor()).collect())
        } else {
            None
        }
    }

    /// Reduce `v` into the half-open fundamental parallelepiped
    /// `{B·t : 0 ≤ t < 1}`: returns `(footpoint, residue)` with
    /// `v = B·footpoint + residue` — exactly the `r(x)` transform of §3.2.
    pub fn reduce(&self, v: &[i128]) -> (Vec<i128>, Vec<i128>) {
        let coords = self.inv.mul_ivec(v);
        let foot: Vec<i128> = coords.iter().map(|c| c.floor()).collect();
        let back = self.basis.mul_vec(&foot);
        let residue: Vec<i128> = v.iter().zip(&back).map(|(a, b)| a - b).collect();
        (foot, residue)
    }

    /// Return an LLL-reduced copy (same lattice, short basis).
    pub fn lll(&self) -> Lattice {
        Lattice::from_basis(lll_reduce(&self.basis))
    }

    /// A new lattice whose basis is this basis with column `j` scaled by
    /// `k ≥ 1` — used to grow tiles to hold a chosen number of lattice
    /// points (§4.0.4: tiles with `K−1` interior points).
    pub fn scale_col(&self, j: usize, k: i128) -> Lattice {
        assert!(k >= 1);
        let mut b = self.basis.clone();
        for i in 0..b.rows() {
            b[(i, j)] *= k;
        }
        Lattice::from_basis(b)
    }

    /// Scale every basis column by `k`.
    pub fn scale(&self, k: i128) -> Lattice {
        assert!(k >= 1);
        let mut b = self.basis.clone();
        for i in 0..b.rows() {
            for j in 0..b.cols() {
                b[(i, j)] *= k;
            }
        }
        Lattice::from_basis(b)
    }

    /// Enumerate all lattice points inside the axis-aligned half-open box
    /// `[0, bounds_i)` — used only by tests and validation (the production
    /// tiling path never counts points; that is the point of the paper).
    pub fn points_in_box(&self, bounds: &[i128]) -> Vec<Vec<i128>> {
        assert_eq!(bounds.len(), self.dim());
        // Enumerate coefficient vectors within a conservative range derived
        // from the inverse basis: for each basis coordinate t_j, the range of
        // H·x over the box corners bounds t_j.
        let d = self.dim();
        let corners: Vec<Vec<i128>> = (0..(1usize << d))
            .map(|mask| {
                (0..d)
                    .map(|i| if mask >> i & 1 == 1 { bounds[i] } else { 0 })
                    .collect()
            })
            .collect();
        let mut lo = vec![i128::MAX; d];
        let mut hi = vec![i128::MIN; d];
        for c in &corners {
            let t = self.inv.mul_ivec(c);
            for j in 0..d {
                lo[j] = lo[j].min(t[j].floor());
                hi[j] = hi[j].max(t[j].ceil());
            }
        }
        let mut out = Vec::new();
        let mut coeff = lo.clone();
        'outer: loop {
            let p = self.basis.mul_vec(&coeff);
            if p.iter().zip(bounds).all(|(&x, &b)| x >= 0 && x < b) {
                out.push(p);
            }
            // odometer increment
            for j in 0..d {
                coeff[j] += 1;
                if coeff[j] <= hi[j] {
                    continue 'outer;
                }
                coeff[j] = lo[j];
            }
            break;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn congruence_1d() {
        // L = {x : 1·x ≡ 0 mod 8} = 8Z
        let l = Lattice::from_congruence(&[1], 8);
        assert_eq!(l.det_abs(), 8);
        assert!(l.contains(&[16]));
        assert!(!l.contains(&[12]));
    }

    #[test]
    fn congruence_2d_column_major() {
        // column-major m1 x m2 table: φ(i,j) = i + m1*j. m1 = 8, N = 4.
        // L = {(i,j) : i + 8j ≡ 0 mod 4} = {(i,j) : i ≡ 0 mod 4}
        let l = Lattice::from_congruence(&[1, 8], 4);
        assert_eq!(l.det_abs(), 4);
        assert!(l.contains(&[4, 0]));
        assert!(l.contains(&[0, 1])); // 8 ≡ 0 mod 4
        assert!(l.contains(&[4, 3]));
        assert!(!l.contains(&[2, 0]));
        assert!(!l.contains(&[1, 1]));
    }

    #[test]
    fn congruence_det_is_index() {
        // det = N / gcd(gcd(w), N)
        for (w, n, want) in [
            (vec![1i128, 100], 64i128, 64i128),
            (vec![2, 100], 64, 32),
            (vec![4, 8], 16, 4),
            (vec![3, 5], 7, 7),
        ] {
            let l = Lattice::from_congruence(&w, n);
            assert_eq!(l.det_abs(), want, "w={w:?} N={n}");
        }
    }

    #[test]
    fn congruence_membership_matches_definition() {
        let w = vec![1i128, 17]; // 17-row column major
        let n = 8;
        let l = Lattice::from_congruence(&w, n);
        for i in -10i128..10 {
            for j in -10i128..10 {
                let in_def = (w[0] * i + w[1] * j).rem_euclid(n) == 0;
                assert_eq!(l.contains(&[i, j]), in_def, "({i},{j})");
            }
        }
    }

    #[test]
    fn reduce_roundtrip() {
        let l = Lattice::from_basis(IMat::from_cols(&[vec![5, 61], vec![7, -17]]));
        for v in [[0i128, 0], [3, 4], [100, -55], [5, 61], [-7, 17]] {
            let (foot, res) = l.reduce(&v);
            let back = l.basis().mul_vec(&foot);
            for k in 0..2 {
                assert_eq!(back[k] + res[k], v[k]);
            }
            // residue is in the half-open fundamental region: 0 ≤ H·res < 1
            let t = l.inverse_basis().mul_ivec(&res);
            for c in t {
                assert!(c >= Rat::ZERO && c < Rat::ONE, "residue outside tile");
            }
        }
    }

    #[test]
    fn points_in_box_counts_match_volume() {
        // For a large box, #lattice points ≈ volume / det.
        let l = Lattice::from_congruence(&[1, 64], 64);
        let pts = l.points_in_box(&[64, 64]);
        assert_eq!(pts.len() as i128, 64 * 64 / l.det_abs());
    }

    #[test]
    fn fig3_lattice_det_512() {
        let l = Lattice::from_basis(IMat::from_cols(&[vec![5, 61], vec![7, -17]]));
        assert_eq!(l.det_abs(), 512);
        // fundamental region of volume 512 holds exactly one lattice point
        // per 512 cells on average
        let pts = l.points_in_box(&[512, 512]);
        assert_eq!(pts.len() as i128, 512 * 512 / 512);
    }

    #[test]
    fn scale_multiplies_det() {
        let l = Lattice::from_congruence(&[1, 8], 4);
        assert_eq!(l.scale(3).det_abs(), l.det_abs() * 9);
        assert_eq!(l.scale_col(0, 3).det_abs(), l.det_abs() * 3);
    }
}
