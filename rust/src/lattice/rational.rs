//! Exact rational arithmetic over `i128`.
//!
//! The lattice machinery (Gram–Schmidt inside LLL, the inverse tile matrix
//! `H = (p_1 ⋯ p_d)^{-1}` of §3.2) needs exact rationals: floating point
//! would mis-classify boundary points of half-open tiles. Our lattices are
//! low-dimensional (d ≤ 6) with entries bounded by table sizes, so `i128`
//! numerators/denominators never overflow in practice; all operations are
//! checked and panic loudly rather than wrap.

use std::cmp::Ordering;
use std::fmt;
use std::ops::{Add, Div, Mul, Neg, Sub};

/// Greatest common divisor (non-negative result, `gcd(0,0) = 0`).
pub fn gcd(a: i128, b: i128) -> i128 {
    let (mut a, mut b) = (a.abs(), b.abs());
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

/// Extended Euclid: returns `(g, x, y)` with `a·x + b·y = g = gcd(a, b)`,
/// `g ≥ 0`.
pub fn ext_gcd(a: i128, b: i128) -> (i128, i128, i128) {
    if b == 0 {
        if a < 0 {
            (-a, -1, 0)
        } else {
            (a, 1, 0)
        }
    } else {
        let (g, x, y) = ext_gcd(b, a % b);
        (g, y, x - (a / b) * y)
    }
}

/// Least common multiple.
pub fn lcm(a: i128, b: i128) -> i128 {
    if a == 0 || b == 0 {
        return 0;
    }
    (a / gcd(a, b)).checked_mul(b).expect("lcm overflow").abs()
}

/// An exact rational number `num/den` with `den > 0` and `gcd(num,den) = 1`.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Rat {
    num: i128,
    den: i128,
}

impl Rat {
    pub const ZERO: Rat = Rat { num: 0, den: 1 };
    pub const ONE: Rat = Rat { num: 1, den: 1 };

    /// Construct and normalize. Panics on zero denominator.
    pub fn new(num: i128, den: i128) -> Rat {
        assert!(den != 0, "Rat with zero denominator");
        let g = gcd(num, den);
        let (mut num, mut den) = if g == 0 { (0, 1) } else { (num / g, den / g) };
        if den < 0 {
            num = -num;
            den = -den;
        }
        Rat { num, den }
    }

    pub fn int(v: i128) -> Rat {
        Rat { num: v, den: 1 }
    }

    pub fn num(&self) -> i128 {
        self.num
    }

    pub fn den(&self) -> i128 {
        self.den
    }

    pub fn is_zero(&self) -> bool {
        self.num == 0
    }

    pub fn is_integer(&self) -> bool {
        self.den == 1
    }

    /// Floor to the nearest integer below or equal (exact; this is the `⌊Hx⌋`
    /// of the tiling transform `r`).
    pub fn floor(&self) -> i128 {
        self.num.div_euclid(self.den)
    }

    /// Ceiling.
    pub fn ceil(&self) -> i128 {
        -((-self.num).div_euclid(self.den))
    }

    /// Round to the nearest integer (ties toward +∞) — used by LLL
    /// size-reduction.
    pub fn round(&self) -> i128 {
        // floor(x + 1/2)
        (2 * self.num + self.den).div_euclid(2 * self.den)
    }

    pub fn abs(&self) -> Rat {
        Rat {
            num: self.num.abs(),
            den: self.den,
        }
    }

    pub fn to_f64(&self) -> f64 {
        self.num as f64 / self.den as f64
    }

    fn check(num: i128, den: i128) -> Rat {
        Rat::new(num, den)
    }
}

impl Add for Rat {
    type Output = Rat;
    fn add(self, o: Rat) -> Rat {
        Rat::check(
            self.num
                .checked_mul(o.den)
                .and_then(|a| o.num.checked_mul(self.den).and_then(|b| a.checked_add(b)))
                .expect("Rat add overflow"),
            self.den.checked_mul(o.den).expect("Rat add overflow"),
        )
    }
}

impl Sub for Rat {
    type Output = Rat;
    fn sub(self, o: Rat) -> Rat {
        self + (-o)
    }
}

impl Mul for Rat {
    type Output = Rat;
    fn mul(self, o: Rat) -> Rat {
        // cross-reduce first to keep magnitudes small
        let g1 = gcd(self.num, o.den).max(1);
        let g2 = gcd(o.num, self.den).max(1);
        Rat::check(
            (self.num / g1)
                .checked_mul(o.num / g2)
                .expect("Rat mul overflow"),
            (self.den / g2)
                .checked_mul(o.den / g1)
                .expect("Rat mul overflow"),
        )
    }
}

impl Div for Rat {
    type Output = Rat;
    fn div(self, o: Rat) -> Rat {
        assert!(!o.is_zero(), "Rat division by zero");
        self * Rat::check(o.den, o.num)
    }
}

impl Neg for Rat {
    type Output = Rat;
    fn neg(self) -> Rat {
        Rat {
            num: -self.num,
            den: self.den,
        }
    }
}

impl PartialOrd for Rat {
    fn partial_cmp(&self, o: &Rat) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}

impl Ord for Rat {
    fn cmp(&self, o: &Rat) -> Ordering {
        (self.num * o.den).cmp(&(o.num * self.den))
    }
}

impl fmt::Debug for Rat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.den == 1 {
            write!(f, "{}", self.num)
        } else {
            write!(f, "{}/{}", self.num, self.den)
        }
    }
}

impl fmt::Display for Rat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}

impl From<i128> for Rat {
    fn from(v: i128) -> Rat {
        Rat::int(v)
    }
}

impl From<i64> for Rat {
    fn from(v: i64) -> Rat {
        Rat::int(v as i128)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gcd_basic() {
        assert_eq!(gcd(12, 18), 6);
        assert_eq!(gcd(-12, 18), 6);
        assert_eq!(gcd(0, 5), 5);
        assert_eq!(gcd(0, 0), 0);
        assert_eq!(gcd(7, 13), 1);
    }

    #[test]
    fn ext_gcd_bezout() {
        for (a, b) in [(12i128, 18), (-5, 3), (7, 0), (0, 7), (240, 46)] {
            let (g, x, y) = ext_gcd(a, b);
            assert_eq!(a * x + b * y, g, "bezout for ({a},{b})");
            assert_eq!(g, gcd(a, b));
        }
    }

    #[test]
    fn rat_normalization() {
        assert_eq!(Rat::new(2, 4), Rat::new(1, 2));
        assert_eq!(Rat::new(-2, -4), Rat::new(1, 2));
        assert_eq!(Rat::new(2, -4), Rat::new(-1, 2));
        assert_eq!(Rat::new(0, 5), Rat::ZERO);
    }

    #[test]
    fn rat_arith() {
        let a = Rat::new(1, 2);
        let b = Rat::new(1, 3);
        assert_eq!(a + b, Rat::new(5, 6));
        assert_eq!(a - b, Rat::new(1, 6));
        assert_eq!(a * b, Rat::new(1, 6));
        assert_eq!(a / b, Rat::new(3, 2));
        assert_eq!(-a, Rat::new(-1, 2));
    }

    #[test]
    fn rat_floor_ceil_round() {
        assert_eq!(Rat::new(7, 2).floor(), 3);
        assert_eq!(Rat::new(-7, 2).floor(), -4);
        assert_eq!(Rat::new(7, 2).ceil(), 4);
        assert_eq!(Rat::new(-7, 2).ceil(), -3);
        assert_eq!(Rat::new(7, 2).round(), 4); // tie toward +inf
        assert_eq!(Rat::new(-7, 2).round(), -3);
        assert_eq!(Rat::new(5, 3).round(), 2);
        assert_eq!(Rat::new(4, 3).round(), 1);
        assert_eq!(Rat::int(5).floor(), 5);
    }

    #[test]
    fn rat_order() {
        assert!(Rat::new(1, 3) < Rat::new(1, 2));
        assert!(Rat::new(-1, 2) < Rat::new(-1, 3));
        assert!(Rat::int(2) > Rat::new(3, 2));
    }
}
