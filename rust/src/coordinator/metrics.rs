//! Service metrics: exact latency quantiles, batch-size histogram, and
//! the queue-wait vs compute split.
//!
//! Latency percentiles are computed from a **uniform reservoir** of raw
//! samples (Algorithm R, deterministic replacement stream), not from
//! fixed bucket boundaries: `percentile_us` sorts the reservoir and
//! reads the order statistic, so p50/p99 are exact over the retained
//! sample (and exact over *all* jobs until the reservoir fills at
//! [`RESERVOIR_CAP`]). Every job also lands in the aggregate counters
//! (jobs, errors, total/max latency, queue-wait and compute time), which
//! are never sampled — throughput and the wait/compute split cover the
//! full population even when the reservoir subsamples.

use std::time::Duration;

/// Raw latency samples retained for exact quantiles. 4096 × u64 is 32 KiB
/// — small enough to keep resident next to the serve loop, large enough
/// that the p99 order statistic is stable under subsampling.
pub const RESERVOIR_CAP: usize = 4096;

/// Serve-loop metrics: exact-quantile latency reservoir, batch-size
/// histogram, queue-wait vs compute attribution, error counting.
#[derive(Clone, Debug)]
pub struct Metrics {
    /// Uniform reservoir of per-job latencies, in µs.
    samples_us: Vec<u64>,
    /// Jobs offered to the reservoir so far (Algorithm R's stream index).
    seen: u64,
    /// Deterministic xorshift state for reservoir replacement.
    rng: u64,
    /// Batch-size histogram: `batch_sizes[s]` counts batches of exactly
    /// `s` jobs (index 0 unused; grown on demand).
    batch_sizes: Vec<u64>,
    pub jobs: u64,
    pub batches: u64,
    /// Jobs whose execution returned an error. Errored jobs still count
    /// in `jobs`, the latency reservoir, and the queue/compute split —
    /// they consumed the same queue and worker time as successes.
    pub errors: u64,
    pub total_latency: Duration,
    pub max_latency: Duration,
    /// Total time jobs spent queued before their batch was dispatched.
    pub queue_wait: Duration,
    /// Total worker time spent executing batches.
    pub compute: Duration,
    pub flops: u64,
    /// Jobs shed before compute because their queue wait exceeded the
    /// configured deadline. Shed jobs count in `jobs` (they consumed
    /// queue capacity and a client waited on them) but **not** in
    /// `errors` — the shed-vs-served split is `served()` vs `timeouts`.
    pub timeouts: u64,
    /// Plans served by the parameter-free flat fallback because the
    /// model-driven planner failed (`Planner::plan_or_fallback`).
    pub fallback_plans: u64,
    /// Name of the tiling strategy that produced the **served** plan
    /// (`Plan::strategy`: `lattice`/`oblivious`/`latency`, or
    /// `flat-fallback` when the planner degraded) — so the strategy-race
    /// win-rate report and the fault-path accounting agree on which
    /// selector actually served. Empty until a plan is resolved.
    pub plan_strategy: String,
    /// Times the supervisor caught a worker-loop panic and respawned the
    /// worker over the same resident backend state.
    pub worker_restarts: u64,
    /// Retry attempts across the degradation ladder: failed-batch jobs
    /// re-run one at a time, plus client-side `submit_with_retry`
    /// re-admissions after `QueueFull`.
    pub retries: u64,
    /// Resident prepacked weight row-panel count on the native backend —
    /// recorded at worker start and after every successful batch, so the
    /// chaos suite can pin pack discipline across worker respawns.
    pub resident_packs: u64,
    /// Set by `Service::stop` when the supervisor thread itself died
    /// (a panic escaped containment). Stop still returns this snapshot —
    /// the typed replacement for the old double-panic on join.
    pub worker_poisoned: bool,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            samples_us: Vec::new(),
            seen: 0,
            rng: 0x9E3779B97F4A7C15,
            batch_sizes: Vec::new(),
            jobs: 0,
            batches: 0,
            errors: 0,
            total_latency: Duration::ZERO,
            max_latency: Duration::ZERO,
            queue_wait: Duration::ZERO,
            compute: Duration::ZERO,
            flops: 0,
            timeouts: 0,
            fallback_plans: 0,
            plan_strategy: String::new(),
            worker_restarts: 0,
            retries: 0,
            resident_packs: 0,
            worker_poisoned: false,
        }
    }

    fn next_rng(&mut self) -> u64 {
        let mut s = self.rng;
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        self.rng = s;
        s
    }

    fn sample(&mut self, latency: Duration) {
        let us = latency.as_micros() as u64;
        self.seen += 1;
        if self.samples_us.len() < RESERVOIR_CAP {
            self.samples_us.push(us);
        } else {
            // Algorithm R: element `seen` replaces a resident sample with
            // probability cap/seen — uniform over the whole stream.
            let slot = self.next_rng() % self.seen;
            if (slot as usize) < RESERVOIR_CAP {
                self.samples_us[slot as usize] = us;
            }
        }
    }

    /// A completed job: `latency` is submit→response, `queue_wait` the
    /// submit→dispatch share of it, `flops` the useful work it carried.
    pub fn record_job(&mut self, latency: Duration, queue_wait: Duration, flops: u64) {
        self.jobs += 1;
        self.flops += flops;
        self.total_latency += latency;
        self.max_latency = self.max_latency.max(latency);
        self.queue_wait += queue_wait;
        self.sample(latency);
    }

    /// A job whose execution failed. It still occupied the queue and the
    /// worker, so it counts everywhere a success does — plus `errors`.
    pub fn record_error(&mut self, latency: Duration, queue_wait: Duration) {
        self.record_job(latency, queue_wait, 0);
        self.errors += 1;
    }

    /// A job shed before compute because its queue wait blew through the
    /// deadline. It occupied the queue like any job (so it counts in
    /// `jobs`, latency, and queue wait) but did no work and is not an
    /// execution error — it lands in `timeouts`, the shed side of the
    /// shed-vs-served split.
    pub fn record_shed(&mut self, latency: Duration, queue_wait: Duration) {
        self.record_job(latency, queue_wait, 0);
        self.timeouts += 1;
    }

    /// The served side of the shed-vs-served split: jobs that completed
    /// successfully (neither errored nor shed on deadline).
    pub fn served(&self) -> u64 {
        self.jobs.saturating_sub(self.errors).saturating_sub(self.timeouts)
    }

    /// A dispatched batch of `size` coalesced jobs that took `compute`
    /// of worker time (packing + GEMM + response fan-out).
    pub fn record_batch(&mut self, size: usize, compute: Duration) {
        self.batches += 1;
        self.compute += compute;
        if self.batch_sizes.len() <= size {
            self.batch_sizes.resize(size + 1, 0);
        }
        self.batch_sizes[size] += 1;
    }

    pub fn mean_latency(&self) -> Duration {
        if self.jobs == 0 {
            Duration::ZERO
        } else {
            self.total_latency / self.jobs as u32
        }
    }

    /// Mean jobs per dispatched batch — the realized coalescing width.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.jobs as f64 / self.batches as f64
        }
    }

    /// Batches dispatched with exactly `size` jobs.
    pub fn batches_of_size(&self, size: usize) -> u64 {
        self.batch_sizes.get(size).copied().unwrap_or(0)
    }

    /// Exact `p`-quantile latency in µs over the retained reservoir
    /// (nearest-rank on the sorted samples).
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.samples_us.is_empty() {
            return 0;
        }
        let mut sorted = self.samples_us.clone();
        sorted.sort_unstable();
        let rank = ((sorted.len() as f64 * p).ceil() as usize).clamp(1, sorted.len());
        sorted[rank - 1]
    }

    pub fn report(&self, wall: Duration) -> String {
        let thr = if wall.as_secs_f64() > 0.0 {
            self.jobs as f64 / wall.as_secs_f64()
        } else {
            0.0
        };
        let gflops = if wall.as_secs_f64() > 0.0 {
            self.flops as f64 / wall.as_secs_f64() / 1e9
        } else {
            0.0
        };
        format!(
            "jobs={} batches={} errors={} throughput={:.1} jobs/s {:.2} GFLOP/s \
             mean={:?} p50={}µs p99={}µs max={:?} \
             queue-wait={:?} compute={:?} mean-batch={:.2} \
             served={} shed={} timeouts={} retries={} restarts={} fallback-plans={} \
             plan-strategy={}{}",
            self.jobs,
            self.batches,
            self.errors,
            thr,
            gflops,
            self.mean_latency(),
            self.percentile_us(0.50),
            self.percentile_us(0.99),
            self.max_latency,
            self.queue_wait,
            self.compute,
            self.mean_batch_size(),
            self.served(),
            self.timeouts,
            self.timeouts,
            self.retries,
            self.worker_restarts,
            self.fallback_plans,
            if self.plan_strategy.is_empty() {
                "-"
            } else {
                &self.plan_strategy
            },
            if self.worker_poisoned {
                " WORKER-POISONED"
            } else {
                ""
            }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_percentiles_below_reservoir_cap() {
        let mut m = Metrics::new();
        // 1..=100 µs in scrambled order: p50 and p99 are exact order
        // statistics, not bucket bounds
        for i in 0..100u64 {
            let us = (i * 37) % 100 + 1;
            m.record_job(Duration::from_micros(us), Duration::ZERO, 1000);
        }
        assert_eq!(m.jobs, 100);
        assert_eq!(m.percentile_us(0.50), 50);
        assert_eq!(m.percentile_us(0.99), 99);
        assert_eq!(m.percentile_us(1.0), 100);
        assert_eq!(m.flops, 100_000);
    }

    #[test]
    fn reservoir_stays_bounded_and_in_range() {
        let mut m = Metrics::new();
        for i in 0..3 * RESERVOIR_CAP as u64 {
            m.record_job(Duration::from_micros(100 + i % 50), Duration::ZERO, 0);
        }
        assert_eq!(m.samples_us.len(), RESERVOIR_CAP);
        let p99 = m.percentile_us(0.99);
        assert!((100..150).contains(&p99), "p99={p99}");
    }

    #[test]
    fn batch_histogram_and_split() {
        let mut m = Metrics::new();
        for _ in 0..6 {
            m.record_job(Duration::from_micros(300), Duration::from_micros(100), 1000);
        }
        m.record_batch(4, Duration::from_micros(500));
        m.record_batch(2, Duration::from_micros(300));
        assert_eq!(m.batches, 2);
        assert_eq!(m.batches_of_size(4), 1);
        assert_eq!(m.batches_of_size(2), 1);
        assert_eq!(m.batches_of_size(8), 0);
        assert_eq!(m.mean_batch_size(), 3.0);
        assert_eq!(m.queue_wait, Duration::from_micros(600));
        assert_eq!(m.compute, Duration::from_micros(800));
        let r = m.report(Duration::from_secs(1));
        assert!(r.contains("queue-wait="), "{r}");
        assert!(r.contains("mean-batch=3.00"), "{r}");
    }

    #[test]
    fn errors_count_as_jobs() {
        let mut m = Metrics::new();
        m.record_job(Duration::from_micros(10), Duration::ZERO, 100);
        m.record_error(Duration::from_micros(20), Duration::from_micros(5));
        assert_eq!(m.jobs, 2);
        assert_eq!(m.errors, 1);
        assert_eq!(m.flops, 100);
        assert_eq!(m.percentile_us(1.0), 20);
    }

    #[test]
    fn shed_vs_served_split_and_extended_report() {
        let mut m = Metrics::new();
        for _ in 0..3 {
            m.record_job(Duration::from_micros(40), Duration::from_micros(10), 100);
        }
        m.record_error(Duration::from_micros(50), Duration::from_micros(20));
        m.record_shed(Duration::from_micros(90), Duration::from_micros(90));
        m.retries = 2;
        m.worker_restarts = 1;
        m.fallback_plans = 1;
        assert_eq!(m.jobs, 5);
        assert_eq!(m.errors, 1);
        assert_eq!(m.timeouts, 1);
        assert_eq!(m.served(), 3);
        // no plan resolved yet → the report shows a placeholder
        assert!(m.report(Duration::from_secs(1)).contains("plan-strategy=-"));
        m.plan_strategy = "flat-fallback".to_string();
        let r = m.report(Duration::from_secs(1));
        for needle in [
            "served=3",
            "shed=1",
            "timeouts=1",
            "retries=2",
            "restarts=1",
            "fallback-plans=1",
            "plan-strategy=flat-fallback",
        ] {
            assert!(r.contains(needle), "missing {needle} in {r}");
        }
        assert!(!r.contains("WORKER-POISONED"), "{r}");
        m.worker_poisoned = true;
        assert!(m.report(Duration::from_secs(1)).contains("WORKER-POISONED"));
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert_eq!(m.percentile_us(0.99), 0);
        assert_eq!(m.mean_latency(), Duration::ZERO);
        assert_eq!(m.mean_batch_size(), 0.0);
        let _ = m.report(Duration::from_secs(1));
    }
}
