//! Service metrics: latency histogram + throughput counters.

use std::time::Duration;

/// Fixed-boundary latency histogram (µs buckets) plus aggregates.
#[derive(Clone, Debug)]
pub struct Metrics {
    bounds_us: Vec<u64>,
    counts: Vec<u64>,
    pub jobs: u64,
    pub batches: u64,
    pub total_latency: Duration,
    pub max_latency: Duration,
    pub flops: u64,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        let bounds_us = vec![
            50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000,
        ];
        let counts = vec![0; bounds_us.len() + 1];
        Metrics {
            bounds_us,
            counts,
            jobs: 0,
            batches: 0,
            total_latency: Duration::ZERO,
            max_latency: Duration::ZERO,
            flops: 0,
        }
    }

    pub fn record_job(&mut self, latency: Duration, flops: u64) {
        self.jobs += 1;
        self.flops += flops;
        self.total_latency += latency;
        self.max_latency = self.max_latency.max(latency);
        let us = latency.as_micros() as u64;
        let idx = self
            .bounds_us
            .iter()
            .position(|&b| us <= b)
            .unwrap_or(self.bounds_us.len());
        self.counts[idx] += 1;
    }

    pub fn record_batch(&mut self) {
        self.batches += 1;
    }

    pub fn mean_latency(&self) -> Duration {
        if self.jobs == 0 {
            Duration::ZERO
        } else {
            self.total_latency / self.jobs as u32
        }
    }

    /// Approximate percentile from the histogram (returns an upper bucket
    /// boundary in µs).
    pub fn percentile_us(&self, p: f64) -> u64 {
        let total: u64 = self.counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * p).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self
                    .bounds_us
                    .get(i)
                    .copied()
                    .unwrap_or(self.max_latency.as_micros() as u64);
            }
        }
        self.max_latency.as_micros() as u64
    }

    pub fn report(&self, wall: Duration) -> String {
        let thr = if wall.as_secs_f64() > 0.0 {
            self.jobs as f64 / wall.as_secs_f64()
        } else {
            0.0
        };
        let gflops = if wall.as_secs_f64() > 0.0 {
            self.flops as f64 / wall.as_secs_f64() / 1e9
        } else {
            0.0
        };
        format!(
            "jobs={} batches={} throughput={:.1} jobs/s {:.2} GFLOP/s \
             mean={:?} p50≤{}µs p99≤{}µs max={:?}",
            self.jobs,
            self.batches,
            thr,
            gflops,
            self.mean_latency(),
            self.percentile_us(0.50),
            self.percentile_us(0.99),
            self.max_latency
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let mut m = Metrics::new();
        for us in [10u64, 20, 30, 40, 60, 80, 200, 400, 2_000, 80_000] {
            m.record_job(Duration::from_micros(us), 1000);
        }
        assert_eq!(m.jobs, 10);
        assert!(m.percentile_us(0.5) <= 100);
        assert!(m.percentile_us(0.99) >= 50_000);
        assert_eq!(m.flops, 10_000);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert_eq!(m.percentile_us(0.99), 0);
        assert_eq!(m.mean_latency(), Duration::ZERO);
        let _ = m.report(Duration::from_secs(1));
    }
}
