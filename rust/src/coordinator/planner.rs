//! The planner: lattice-model tile selection mapped onto shipped kernels.
//!
//! For each job shape the planner runs the paper's selector (§4.0.4: K−1
//! lattice rule + model-driven search) against the configured cache spec,
//! derives a preferred tile shape, and resolves the nearest AOT kernel
//! variant from the [`Registry`]. Plans are cached per shape — selection
//! runs once, off the hot path.

use std::collections::HashMap;

use crate::cache::CacheSpec;
use crate::domain::ops;
use crate::runtime::Registry;
use crate::tiling;

/// A resolved execution plan for one matmul shape.
#[derive(Clone, Debug)]
pub struct Plan {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Tile shape the lattice model preferred (loop-space extents).
    pub model_tile: (usize, usize, usize),
    /// Two-level macro/micro blocking: the L1 tile above driven inside
    /// L2/L3-sized `mc×kc×nc` macro blocks, selected per level
    /// ([`tiling::level_plan`] against the Haswell L2 + L3-slice specs).
    pub level: tiling::LevelPlan,
    /// Name of the AOT artifact chosen to realize it.
    pub artifact: String,
    /// Predicted misses (sampled model) for the chosen schedule.
    pub predicted_misses: u64,
    /// Human-readable description of the winning plan.
    pub plan_name: String,
}

impl Plan {
    /// One-line report of the plan including the multi-level block shape.
    pub fn describe(&self) -> String {
        format!(
            "{} ({}x{}x{}): tile {:?}, macro mc={} kc={} nc={}, artifact {}",
            self.plan_name,
            self.m,
            self.k,
            self.n,
            self.model_tile,
            self.level.mc,
            self.level.kc,
            self.level.nc,
            self.artifact
        )
    }
}

/// Shape-keyed plan cache around the selector.
pub struct Planner {
    spec: CacheSpec,
    cache: HashMap<(usize, usize, usize), Plan>,
    sample_classes: usize,
}

impl Planner {
    pub fn new(spec: CacheSpec) -> Planner {
        Planner {
            spec,
            cache: HashMap::new(),
            sample_classes: 8,
        }
    }

    pub fn with_sample_classes(mut self, s: usize) -> Planner {
        self.sample_classes = s;
        self
    }

    pub fn spec(&self) -> &CacheSpec {
        &self.spec
    }

    /// Plan for an `m×k×n` matmul, resolving against `registry`.
    pub fn plan(&mut self, registry: &Registry, m: usize, k: usize, n: usize) -> Plan {
        if let Some(p) = self.cache.get(&(m, k, n)) {
            return p.clone();
        }
        // Model selection runs on a proportional small instance when the
        // real size would make even the sampled model slow; the conflict
        // lattice depends on the leading dimension, which we preserve.
        let (sm, sk, sn) = shrink(m, k, n);
        let kernel = ops::matmul_padded(
            sm as i64,
            sk as i64,
            sn as i64,
            m as i64, // preserve true leading dims → true conflict lattice
            m as i64,
            k as i64,
            8,
            0,
        );
        let ranked = tiling::select(&kernel, &self.spec, self.sample_classes);
        let best = ranked.first();
        let (tile, l1_tile, name, predicted) = match best {
            Some(p) => {
                let b = p.schedule.basis();
                let ext = |i: usize| -> usize {
                    (0..b.dim())
                        .map(|j| b.basis()[(i, j)].unsigned_abs() as usize)
                        .sum()
                };
                (
                    (ext(0), ext(2), ext(1)),
                    (ext(0), ext(1), ext(2)),
                    p.name.clone(),
                    p.predicted.as_ref().map(|c| c.misses).unwrap_or(0),
                )
            }
            None => ((64, 64, 64), (64, 64, 64), "fallback rect 64".to_string(), 0),
        };
        // per-level selection: run the selector against the L2 spec to
        // seed the macro block, nc from the L3 slice — against the *true*
        // (m, n, k), not the shrunk model instance
        let level = tiling::level_plan(
            &kernel,
            (m, n, k),
            l1_tile,
            &CacheSpec::HASWELL_L2,
            Some(&CacheSpec::HASWELL_L3_SLICE),
            self.sample_classes,
        );
        let artifact = registry
            .closest_variant(m, k, n, tile)
            .map(|a| a.name.clone())
            .unwrap_or_else(|| format!("<no artifact for {m}x{k}x{n}>"));
        let plan = Plan {
            m,
            k,
            n,
            model_tile: tile,
            level,
            artifact,
            predicted_misses: predicted,
            plan_name: name,
        };
        self.cache.insert((m, k, n), plan.clone());
        plan
    }

    pub fn cached_plans(&self) -> usize {
        self.cache.len()
    }
}

/// Shrink a problem size for model evaluation (keep ≤ 48³ points),
/// preserving divisibility structure where possible.
fn shrink(m: usize, k: usize, n: usize) -> (usize, usize, usize) {
    let cap = 64usize;
    (m.min(cap), k.min(cap), n.min(cap))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn planner_caches_and_resolves() {
        if !artifacts_dir().join("manifest.tsv").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let reg = Registry::load(&artifacts_dir()).unwrap();
        let mut planner = Planner::new(CacheSpec::HASWELL_L1D);
        let p1 = planner.plan(&reg, 256, 256, 256);
        assert!(p1.artifact.starts_with("matmul_256x256x256"));
        let p2 = planner.plan(&reg, 256, 256, 256);
        assert_eq!(p1.artifact, p2.artifact);
        assert_eq!(planner.cached_plans(), 1);
    }

    #[test]
    fn planner_works_without_artifacts() {
        let reg = Registry::default();
        let mut planner = Planner::new(CacheSpec::HASWELL_L1D);
        let p = planner.plan(&reg, 64, 64, 64);
        assert!(p.artifact.contains("no artifact"));
        assert!(p.model_tile.0 > 0);
    }

    #[test]
    fn plans_carry_and_report_macro_shape() {
        use crate::codegen::{MR, NR};
        let reg = Registry::default();
        let mut planner = Planner::new(CacheSpec::HASWELL_L1D);
        let p = planner.plan(&reg, 512, 512, 512);
        assert_eq!(p.level.mc % MR, 0);
        assert_eq!(p.level.nc % NR, 0);
        assert!(p.level.kc >= 1 && p.level.kc <= 512);
        // the packed B block targets L2 (half capacity + MR-row slack)
        assert!(p.level.mc * p.level.kc * 8 <= CacheSpec::HASWELL_L2.capacity / 2 + MR * p.level.kc * 8);
        let d = p.describe();
        assert!(d.contains("macro mc="), "{d}");
    }
}
