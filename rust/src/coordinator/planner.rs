//! The planner: lattice-model tile selection mapped onto shipped kernels.
//!
//! For each job the planner runs the paper's selector (§4.0.4: K−1
//! lattice rule + model-driven search) against the configured cache spec,
//! derives a preferred tile shape, and resolves the nearest AOT kernel
//! variant from the [`Registry`]. Since the `RunPlan` refactor the
//! planner is kernel-agnostic, and since the `Scalar` refactor it is
//! dtype-aware: [`Planner::plan_kernel`] plans **any** registered Table-1
//! kernel at the kernel's own element size (selection, GEMM normal form,
//! two-level macro shape, per-dtype register-tile width);
//! [`Planner::plan`] keeps the matmul serving entry point (model
//! evaluation on a size-capped instance with the true leading dimensions,
//! at the requested [`DType`] — the PJRT serve path is f32, so its plans
//! legitimately get 2× the elements per line). Plans are cached per
//! (shape, dtype) — selection runs once, off the hot path.
//!
//! The cache is **sharded**: plans hash by (kernel, element size,
//! log₂-bucketed shape class) onto [`N_SHARDS`] independently locked
//! maps, and a `Planner` clone shares the shards — concurrent planners
//! (one per serve worker or client thread) contend only when planning
//! shapes of the same class, not on one global map. Selection itself
//! runs *outside* any shard lock; two racing planners may both model the
//! same new shape, but the first inserted plan wins and both return it.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex};

use super::faults::{self, FaultMode, FaultPoint, Faults};
use super::lock_unpoisoned;
use crate::cache::CacheSpec;
use crate::codegen::{DType, GemmForm, MicroShape, Precision};
use crate::domain::{ops, Kernel};
use crate::runtime::Registry;
use crate::tiling;

/// A resolved execution plan for one kernel shape.
#[derive(Clone, Debug)]
pub struct Plan {
    /// Kernel name (`matmul`, `convolution`, `kronecker`, …).
    pub kernel: String,
    /// Element type the plan was modelled (and will execute) at — the
    /// **storage** dtype of [`Plan::precision`].
    pub dtype: DType,
    /// Storage/accumulation precision pair of the execution. Pure modes
    /// have `acc == store == dtype`; the `f32acc64` serve mode keeps f32
    /// storage (so the cache model, packing and plan shapes are the f32
    /// ones) but accumulates register tiles in f64.
    pub precision: Precision,
    /// GEMM-normal dimensions of the planned shape (rows, reduction,
    /// columns — for matmul exactly `m`, `k`, `n`).
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Tile shape the lattice model preferred, in GEMM-normal order
    /// (rows, reduction, columns).
    pub model_tile: (usize, usize, usize),
    /// Three-level macro/micro blocking: the L1 tile above driven inside
    /// L2-sized `mc×kc×nc` macro blocks, themselves partitioned into
    /// `m3×n3` L3 super-bands (the parallel scheduler's work unit),
    /// proposed by the dispatched tiling strategy (the lattice selector
    /// [`tiling::level_plan`] by default, or whichever rival the startup
    /// strategy race recorded for this (kernel, dtype, shape-class) —
    /// see [`Plan::strategy`]).
    pub level: tiling::LevelPlan,
    /// Name of the tiling strategy that produced [`Plan::level`]
    /// (`lattice`/`oblivious`/`latency`, or `flat-fallback` for the
    /// parameter-free degraded plan).
    pub strategy: &'static str,
    /// Register-tile geometry class the engine dispatches (the dtype's
    /// startup 2-D (MR, NR) grid-race winner when the registry recorded
    /// one; 8×4 otherwise). Resolves to 8×4/8×6/16×4/16×6 at f64 and
    /// 8×8/8×12/16×4/16×6 at f32 ([`MicroShape::dims_for`]).
    pub micro: MicroShape,
    /// Name of the AOT artifact chosen to realize it (matmul shapes), or
    /// the in-process packed engine for other kernels.
    pub artifact: String,
    /// Predicted misses (sampled model) for the chosen schedule.
    pub predicted_misses: u64,
    /// Human-readable description of the winning plan.
    pub plan_name: String,
}

impl Plan {
    /// One-line report of the plan including the precision mode, the
    /// multi-level block shape (macro blocks + L3 super-band), the
    /// dispatched tiling strategy and the per-dtype register-tile
    /// geometry. Pure modes print the dtype (`/f64`); the mixed mode
    /// prints `/f32acc64`.
    pub fn describe(&self) -> String {
        format!(
            "{} [{}/{}] ({}x{}x{}): tile {:?}, macro mc={} kc={} nc={}, super m3={} n3={}, \
             strategy {}, micro {}, artifact {}",
            self.plan_name,
            self.kernel,
            self.precision.name(),
            self.m,
            self.k,
            self.n,
            self.model_tile,
            self.level.mc,
            self.level.kc,
            self.level.nc,
            self.level.m3,
            self.level.n3,
            self.strategy,
            self.micro.label_for(self.dtype),
            self.artifact
        )
    }
}

/// Independently locked plan-cache shards; see the module docs.
pub const N_SHARDS: usize = 16;

type Shard = Mutex<HashMap<(String, Vec<i64>), Plan>>;

/// Shape-keyed, shard-locked plan cache around the selector. `Clone`
/// shares the shards: hand each serve worker or client thread its own
/// clone and they plan concurrently against one cache.
#[derive(Clone)]
pub struct Planner {
    spec: CacheSpec,
    shards: Arc<Vec<Shard>>,
    sample_classes: usize,
    strategy: tiling::StrategyChoice,
}

impl Planner {
    pub fn new(spec: CacheSpec) -> Planner {
        Planner {
            spec,
            shards: Arc::new((0..N_SHARDS).map(|_| Mutex::new(HashMap::new())).collect()),
            sample_classes: 8,
            strategy: tiling::StrategyChoice::Auto,
        }
    }

    pub fn with_sample_classes(mut self, s: usize) -> Planner {
        self.sample_classes = s;
        self
    }

    /// Pin or restore the tiling-strategy choice: `Auto` (the default)
    /// dispatches the registry-recorded race winner per (kernel, dtype,
    /// shape-class), falling back to the lattice selector when no race
    /// has run; `Fixed(kind)` forces one strategy (the CLI `--strategy`
    /// override). Fixed-choice plans cache under their own namespace, so
    /// an override never poisons the auto cache shared with clones.
    pub fn with_strategy(mut self, strategy: tiling::StrategyChoice) -> Planner {
        self.strategy = strategy;
        self
    }

    pub fn strategy(&self) -> tiling::StrategyChoice {
        self.strategy
    }

    pub fn spec(&self) -> &CacheSpec {
        &self.spec
    }

    /// Cache namespace of this planner's strategy choice: auto shares
    /// the base namespace, a fixed override gets its own slots.
    fn strategy_ns(&self, base: String) -> String {
        match self.strategy {
            tiling::StrategyChoice::Auto => base,
            tiling::StrategyChoice::Fixed(kind) => format!("{base}#strat={}", kind.name()),
        }
    }

    /// Shard for a cache key: the kernel/dtype namespace string plus the
    /// log₂ shape class of each dimension, so e.g. all ~256-wide matmul
    /// plans of one dtype contend on one lock and everything else on
    /// others.
    fn shard(&self, key: &(String, Vec<i64>)) -> &Shard {
        let mut h = DefaultHasher::new();
        key.0.hash(&mut h);
        for &d in &key.1 {
            (64 - d.max(1).leading_zeros()).hash(&mut h);
        }
        &self.shards[h.finish() as usize % N_SHARDS]
    }

    /// Cached-plan lookup and first-writer-wins insert around `compute`,
    /// which runs the selector with no shard lock held.
    fn cached_or_plan(
        &self,
        key: (String, Vec<i64>),
        compute: impl FnOnce(&Planner) -> Plan,
    ) -> Plan {
        let shard = self.shard(&key);
        if let Some(p) = lock_unpoisoned(shard).get(&key) {
            return p.clone();
        }
        let plan = compute(self);
        lock_unpoisoned(shard).entry(key).or_insert(plan).clone()
    }

    /// Plan for an `m×k×n` matmul at `dtype`, resolving against
    /// `registry`. Model selection runs on a proportional small instance
    /// when the real size would make even the sampled model slow; the
    /// conflict lattice depends on the leading dimension *and* the
    /// element size, both of which are preserved.
    pub fn plan(&self, registry: &Registry, m: usize, k: usize, n: usize, dtype: DType) -> Plan {
        self.plan_with_precision(registry, m, k, n, Precision::of(dtype))
    }

    /// [`Planner::plan`] at an explicit storage/accumulation precision
    /// pair: the plan is modelled at the **storage** dtype (the arena,
    /// packed panels and cache footprints are storage-sized), and the
    /// precision rides the plan into the execution layer, which widens
    /// register-tile accumulation when `precision.wide_acc()`. Mixed and
    /// pure plans of the same shape occupy distinct cache slots.
    pub fn plan_with_precision(
        &self,
        registry: &Registry,
        m: usize,
        k: usize,
        n: usize,
        precision: Precision,
    ) -> Plan {
        let dtype = precision.store;
        // distinct cache namespace from `plan_kernel` — the two entry
        // points resolve different artifacts for the same matmul extents
        let key = (
            self.strategy_ns(format!("matmul#aot#{}", precision.name())),
            vec![m as i64, n as i64, k as i64],
        );
        self.cached_or_plan(key, |this| {
            let (sm, sk, sn) = shrink(m, k, n);
            let kernel = ops::matmul_padded(
                sm as i64,
                sk as i64,
                sn as i64,
                m as i64, // preserve true leading dims → true conflict lattice
                m as i64,
                k as i64,
                dtype.elem(),
                0,
            );
            let mut plan = this.plan_shape(registry, &kernel, (m, n, k), dtype);
            plan.precision = precision;
            // resolve the AOT artifact against the *true* shape
            plan.artifact = registry
                .closest_variant(m, k, n, plan.model_tile)
                .map(|a| a.name.clone())
                .unwrap_or_else(|| format!("<no artifact for {m}x{k}x{n}>"));
            plan
        })
    }

    /// Plan any registered Table-1 kernel at the kernel's own element
    /// size: selector + GEMM normal form + per-level macro shape,
    /// executed by the in-process packed engine. Model selection runs on
    /// a size-capped instance of the same op when the real domain would
    /// make even the sampled model slow (the same guard `plan` applies to
    /// matmul).
    pub fn plan_kernel(&self, registry: &Registry, kernel: &Kernel) -> Plan {
        let elem = kernel.operand(0).table.elem();
        let dtype = DType::from_elem(elem)
            .unwrap_or_else(|| panic!("no supported dtype for {elem}-byte elements"));
        let mut key_dims = kernel.extents().to_vec();
        key_dims.push(elem as i64); // f32/f64 instances are distinct plans
        let key = (self.strategy_ns(kernel.name().to_string()), key_dims);
        self.cached_or_plan(key, |this| {
            let dims = GemmForm::of(kernel)
                .map(|gf| (gf.m, gf.n, gf.k))
                .unwrap_or_else(|| (kernel.domain_size().max(1) as usize, 1, 1));
            let shrunk = shrink_kernel(kernel);
            let model_kernel = shrunk.as_ref().unwrap_or(kernel);
            let mut plan = this.plan_shape(registry, model_kernel, dims, dtype);
            plan.kernel = kernel.name().to_string();
            plan.artifact = format!("<packed-engine {}>", kernel.name());
            plan
        })
    }

    /// Shared planning core: run the selector on `kernel`, lift the
    /// winning tile into GEMM-normal shape `(m, n, k)`, and derive the
    /// two-level macro shape against the true extents (at the model
    /// kernel's element size, which matches `dtype`).
    fn plan_shape(
        &self,
        registry: &Registry,
        kernel: &Kernel,
        (m, n, k): (usize, usize, usize),
        dtype: DType,
    ) -> Plan {
        let ranked = tiling::select(kernel, &self.spec, self.sample_classes);
        let best = ranked.first();
        let gf = GemmForm::of(kernel);
        let (tile, l1_tile, name, predicted) = match best {
            Some(p) => {
                let b = p.schedule.basis();
                let ext = |i: usize| -> usize {
                    (0..b.dim())
                        .map(|j| b.basis()[(i, j)].unsigned_abs() as usize)
                        .sum::<usize>()
                        .max(1)
                };
                let group = |axes: &[usize]| -> usize {
                    axes.iter().map(|&t| ext(t)).product::<usize>().max(1)
                };
                let (ti, tj, tk) = match &gf {
                    Some(gf) => (
                        group(&gf.row_axes),
                        group(&gf.col_axes),
                        group(&gf.red_axes),
                    ),
                    None => {
                        let d = b.dim();
                        (ext(0), if d > 1 { ext(1) } else { 1 }, if d > 2 { ext(2) } else { 1 })
                    }
                };
                (
                    (ti, tk, tj),
                    (ti, tj, tk),
                    p.name.clone(),
                    p.predicted.as_ref().map(|c| c.misses).unwrap_or(0),
                )
            }
            None => ((64, 64, 64), (64, 64, 64), "fallback rect 64".to_string(), 0),
        };
        // per-level selection is **strategy-dispatched**: resolve the
        // tiling strategy for this (kernel, dtype, shape-class) — the
        // registry-recorded race winner under `Auto` (lattice until a
        // race has run), or the pinned override — and let it propose the
        // macro blocking against the *true* (m, n, k), not the shrunk
        // model instance; the element size flows from the kernel's own
        // tables
        let class = tiling::ShapeClass::of((m, n, k));
        let strat = match self.strategy {
            tiling::StrategyChoice::Fixed(kind) => kind,
            tiling::StrategyChoice::Auto => registry
                .strategy_for(dtype, kernel.name(), class)
                .unwrap_or(tiling::StrategyKind::Lattice),
        };
        let level = tiling::strategy_impl(strat).propose(
            kernel,
            (m, n, k),
            l1_tile,
            &CacheSpec::HASWELL_L2,
            Some(&CacheSpec::HASWELL_L3_SLICE),
            self.sample_classes,
        );
        Plan {
            kernel: kernel.name().to_string(),
            dtype,
            precision: Precision::of(dtype),
            m,
            k,
            n,
            model_tile: tile,
            level,
            strategy: strat.name(),
            micro: registry.micro_shape_for(dtype).unwrap_or(MicroShape::Mr8Nr4),
            artifact: String::new(),
            predicted_misses: predicted,
            plan_name: name,
        }
    }

    pub fn cached_plans(&self) -> usize {
        self.shards.iter().map(|s| lock_unpoisoned(s).len()).sum()
    }

    /// [`plan_kernel`](Planner::plan_kernel) with the model-driven path
    /// contained: a selector panic (or an injected [`FaultPoint::Plan`])
    /// degrades to the parameter-free flat fallback plan instead of
    /// taking down `Service::start`. Returns the plan and whether the
    /// fallback was used (callers count it into
    /// `Metrics::fallback_plans`). Fallback plans are **not** cached —
    /// a transient planner failure must not pin a degraded plan for the
    /// shape's lifetime.
    pub fn plan_or_fallback(
        &self,
        registry: &Registry,
        kernel: &Kernel,
        faults: &Faults,
    ) -> (Plan, bool) {
        let attempt = catch_unwind(AssertUnwindSafe(|| {
            match faults.check(FaultPoint::Plan) {
                Some(FaultMode::Error) => return None,
                Some(FaultMode::Panic) => faults::inject_panic(FaultPoint::Plan),
                None => {}
            }
            Some(self.plan_kernel(registry, kernel))
        }));
        match attempt {
            Ok(Some(plan)) => (plan, false),
            Ok(None) | Err(_) => (self.fallback_plan(registry, kernel), true),
        }
    }

    /// Parameter-free degraded plan in the spirit of cache-oblivious
    /// tiling: fixed 8³ L1 tiles inside fixed 64×64×48 macro blocks and
    /// no L3 super-band partitioning ([`tiling::LevelPlan::flat`]),
    /// chosen without consulting the cache model at all. mc=64 is an MR
    /// multiple and nc=48 divides by every register-tile width
    /// (4/6/8/12), so the shape is executable at any dtype.
    fn fallback_plan(&self, registry: &Registry, kernel: &Kernel) -> Plan {
        let dtype = DType::from_elem(kernel.operand(0).table.elem()).unwrap_or(DType::F64);
        let (m, n, k) = GemmForm::of(kernel)
            .map(|gf| (gf.m, gf.n, gf.k))
            .unwrap_or((kernel.domain_size().max(1) as usize, 1, 1));
        Plan {
            kernel: kernel.name().to_string(),
            dtype,
            precision: Precision::of(dtype),
            m,
            k,
            n,
            model_tile: (8, 8, 8),
            level: tiling::LevelPlan::flat((8, 8, 8), 64, 64, 48),
            // named so metrics and the strategy-race accounting can tell
            // a degraded serve apart from any raced strategy's plan
            strategy: "flat-fallback",
            micro: registry.micro_shape_for(dtype).unwrap_or(MicroShape::Mr8Nr4),
            artifact: format!("<packed-engine {} fallback>", kernel.name()),
            predicted_misses: 0,
            plan_name: "parameter-free flat fallback".to_string(),
        }
    }
}

/// Shrink a problem size for model evaluation (keep ≤ 64³ points),
/// preserving divisibility structure where possible.
fn shrink(m: usize, k: usize, n: usize) -> (usize, usize, usize) {
    let cap = 64usize;
    (m.min(cap), k.min(cap), n.min(cap))
}

/// Size-capped model instance of a registered Table-1 kernel, or `None`
/// when the real domain is already small enough for the sampled model.
/// Matmul preserves the true leading dimensions (the conflict lattice
/// depends on them); every op preserves the source kernel's element size
/// (the lattice period depends on it too); for the non-matmul ops the
/// capped instance's layout is a proportional approximation.
fn shrink_kernel(kernel: &Kernel) -> Option<Kernel> {
    const CAP: i64 = 1 << 18;
    if kernel.domain_size() <= CAP {
        return None;
    }
    let e = kernel.extents();
    let elem = kernel.operand(0).table.elem();
    match kernel.name() {
        "convolution" => Some(ops::convolution(e[0].min(1 << 16), elem, 0)),
        "scalar_product" => Some(ops::scalar_product(e[0].min(1 << 16), elem, 0)),
        "kronecker" => Some(ops::kronecker(
            e[0].min(16),
            e[1].min(16),
            e[2].min(24),
            e[3].min(24),
            elem,
            0,
        )),
        // matmul extents are (m, n, k): shrink like `plan`, true lds
        "matmul" => Some(ops::matmul_padded(
            e[0].min(64),
            e[2].min(64),
            e[1].min(64),
            e[0],
            e[0],
            e[2],
            elem,
            0,
        )),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn planner_caches_and_resolves() {
        if !artifacts_dir().join("manifest.tsv").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let reg = Registry::load(&artifacts_dir()).unwrap();
        let planner = Planner::new(CacheSpec::HASWELL_L1D);
        let p1 = planner.plan(&reg, 256, 256, 256, DType::F32);
        assert!(p1.artifact.starts_with("matmul_256x256x256"));
        let p2 = planner.plan(&reg, 256, 256, 256, DType::F32);
        assert_eq!(p1.artifact, p2.artifact);
        assert_eq!(planner.cached_plans(), 1);
    }

    #[test]
    fn planner_works_without_artifacts() {
        let reg = Registry::default();
        let planner = Planner::new(CacheSpec::HASWELL_L1D);
        let p = planner.plan(&reg, 64, 64, 64, DType::F64);
        assert!(p.artifact.contains("no artifact"));
        assert!(p.model_tile.0 > 0);
        assert_eq!(p.kernel, "matmul");
        assert_eq!(p.dtype, DType::F64);
    }

    #[test]
    fn plans_carry_and_report_macro_shape() {
        use crate::codegen::{MR, NR};
        let reg = Registry::default();
        let planner = Planner::new(CacheSpec::HASWELL_L1D);
        let p = planner.plan(&reg, 512, 512, 512, DType::F64);
        assert_eq!(p.level.mc % MR, 0);
        assert_eq!(p.level.nc % NR, 0);
        assert!(p.level.kc >= 1 && p.level.kc <= 512);
        // the packed B block targets L2 (half capacity + MR-row slack)
        let half_l2 = CacheSpec::HASWELL_L2.capacity / 2;
        assert!(p.level.mc * p.level.kc * 8 <= half_l2 + MR * p.level.kc * 8);
        // the L3 super-band is mc/nc-aligned and its packed row slice
        // targets a quarter of the L3 slice
        assert_eq!(p.level.m3 % p.level.mc, 0);
        assert_eq!(p.level.n3 % p.level.nc, 0);
        let quarter_l3 = CacheSpec::HASWELL_L3_SLICE.capacity / 4;
        assert!(p.level.m3 * p.level.kc * 8 <= quarter_l3 + p.level.mc * p.level.kc * 8);
        let d = p.describe();
        assert!(d.contains("macro mc="), "{d}");
        assert!(d.contains("super m3="), "{d}");
        assert!(d.contains("micro 8x"), "{d}");
        assert!(d.contains("/f64"), "{d}");
    }

    #[test]
    fn planner_plans_any_table1_kernel() {
        let reg = Registry::default();
        let planner = Planner::new(CacheSpec::HASWELL_L1D);
        let conv = planner.plan_kernel(&reg, &ops::convolution(4096, 8, 0));
        assert_eq!(conv.kernel, "convolution");
        assert_eq!((conv.m, conv.n), (1, 1));
        assert_eq!(conv.k, 4096);
        assert!(conv.artifact.contains("packed-engine"));
        assert!(conv.level.kc >= 1);
        // kernel-aware selection: the degenerate dot form blocks its unit
        // dimensions at 1 instead of padding to matmul's MR/NR quanta
        assert_eq!((conv.level.mc, conv.level.nc), (1, 1), "{:?}", conv.level);
        assert_eq!((conv.level.m3, conv.level.n3), (1, 1), "{:?}", conv.level);
        let kron = planner.plan_kernel(&reg, &ops::kronecker(16, 16, 24, 24, 8, 0));
        assert_eq!(kron.kernel, "kronecker");
        assert_eq!(kron.m, 24 * 24);
        assert_eq!(kron.n, 16 * 16);
        assert_eq!(kron.k, 1);
        // kernel-aware selection: the reduction-free outer product has no
        // reduction depth to block
        assert_eq!(kron.level.kc, 1, "{:?}", kron.level);
        let d = kron.describe();
        assert!(d.contains("kronecker"), "{d}");
        // plans are cached per kernel/extents
        planner.plan_kernel(&reg, &ops::convolution(4096, 8, 0));
        assert_eq!(planner.cached_plans(), 2);
    }

    #[test]
    fn plan_entry_points_do_not_share_cache_slots() {
        // plan() resolves AOT artifacts, plan_kernel() the packed engine:
        // identical matmul extents must not collide in the cache
        let reg = Registry::default();
        let planner = Planner::new(CacheSpec::HASWELL_L1D);
        let generic = planner.plan_kernel(&reg, &crate::domain::ops::matmul(64, 64, 64, 8, 0));
        assert!(generic.artifact.contains("packed-engine"));
        let served = planner.plan(&reg, 64, 64, 64, DType::F64);
        assert!(
            served.artifact.contains("no artifact") || !served.artifact.contains("packed-engine"),
            "plan() returned plan_kernel()'s cached artifact: {}",
            served.artifact
        );
        assert_eq!(planner.cached_plans(), 2);
    }

    #[test]
    fn plan_kernel_shrinks_oversized_models() {
        // a 64⁴ Kronecker domain (~16.8M points) must not reach the
        // sampled model at full size; planning stays fast and the GEMM
        // dims still reflect the *true* shape
        let reg = Registry::default();
        let planner = Planner::new(CacheSpec::HASWELL_L1D);
        let p = planner.plan_kernel(&reg, &crate::domain::ops::kronecker(64, 64, 64, 64, 8, 0));
        assert_eq!(p.m, 64 * 64);
        assert_eq!(p.n, 64 * 64);
        assert_eq!(p.k, 1);
    }

    #[test]
    fn plan_reports_recorded_micro_shape() {
        let reg = Registry::default();
        reg.set_micro_shape_for(DType::F64, MicroShape::Mr8Nr6);
        let planner = Planner::new(CacheSpec::HASWELL_L1D);
        let p = planner.plan(&reg, 64, 64, 64, DType::F64);
        assert_eq!(p.micro, MicroShape::Mr8Nr6);
        assert!(p.describe().contains("micro 8x6"));
    }

    #[test]
    fn plans_name_their_strategy_and_fixed_overrides_get_their_own_slots() {
        use crate::tiling::{StrategyChoice, StrategyKind};
        let reg = Registry::default();
        let planner = Planner::new(CacheSpec::HASWELL_L1D).with_sample_classes(4);
        let kern = ops::matmul(96, 64, 80, 8, 0);
        // no race recorded → auto resolves the lattice default
        let auto = planner.plan_kernel(&reg, &kern);
        assert_eq!(auto.strategy, "lattice");
        assert!(auto.describe().contains("strategy lattice"), "{}", auto.describe());
        // a fixed override shares the shards but not the cache slots
        let forced = planner
            .clone()
            .with_strategy(StrategyChoice::Fixed(StrategyKind::Oblivious));
        assert_eq!(forced.strategy(), StrategyChoice::Fixed(StrategyKind::Oblivious));
        let p = forced.plan_kernel(&reg, &kern);
        assert_eq!(p.strategy, "oblivious");
        assert!(p.describe().contains("strategy oblivious"), "{}", p.describe());
        assert_eq!(
            planner.cached_plans(),
            2,
            "the override must not collide with the auto slot"
        );
        // auto still serves its own (lattice) plan afterwards
        assert_eq!(planner.plan_kernel(&reg, &kern).strategy, "lattice");
    }

    #[test]
    fn auto_dispatches_the_recorded_race_winner_per_shape_class() {
        use crate::tiling::{ShapeClass, StrategyKind};
        let reg = Registry::default();
        let kern = ops::matmul(96, 64, 80, 4, 0);
        reg.set_strategy_for(
            DType::F32,
            "matmul",
            ShapeClass::of_kernel(&kern),
            StrategyKind::Latency,
        );
        let planner = Planner::new(CacheSpec::HASWELL_L1D).with_sample_classes(4);
        let p = planner.plan_kernel(&reg, &kern);
        assert_eq!(p.strategy, "latency");
        assert!(p.describe().contains("strategy latency"), "{}", p.describe());
        // other shape classes and dtypes still default to the lattice
        let other = planner.plan_kernel(&reg, &ops::matmul(512, 64, 80, 4, 0));
        assert_eq!(other.strategy, "lattice");
        let f64_plan = planner.plan_kernel(&reg, &ops::matmul(96, 64, 80, 8, 0));
        assert_eq!(f64_plan.strategy, "lattice");
    }

    #[test]
    fn f32_plan_is_wider_and_reports_its_own_micro_shape() {
        // the acceptance invariant: for the same 512³ matmul, the f32
        // plan must select a strictly larger macro footprint than the f64
        // plan (element size reaches the selector), carry dtype F32, and
        // report the *f32* autotune winner (8×12, not 8×6)
        let reg = Registry::default();
        reg.set_micro_shape_for(DType::F64, MicroShape::Mr8Nr4);
        reg.set_micro_shape_for(DType::F32, MicroShape::Mr8Nr6);
        let planner = Planner::new(CacheSpec::HASWELL_L1D);
        let p64 = planner.plan_kernel(&reg, &ops::matmul(512, 512, 512, 8, 0));
        let p32 = planner.plan_kernel(&reg, &ops::matmul(512, 512, 512, 4, 0));
        assert_eq!(p32.dtype, DType::F32);
        assert_eq!(p64.dtype, DType::F64);
        assert_eq!(planner.cached_plans(), 2, "dtypes must not share a slot");
        assert!(
            p32.level.mc * p32.level.kc > p64.level.mc * p64.level.kc,
            "f32 macro footprint {:?} not wider than f64 {:?}",
            p32.level,
            p64.level
        );
        assert!(p32.describe().contains("/f32"), "{}", p32.describe());
        assert!(
            p32.describe().contains("micro 8x12"),
            "f32 wide class must report 8x12: {}",
            p32.describe()
        );
        assert!(p64.describe().contains("micro 8x4"), "{}", p64.describe());
    }

    #[test]
    fn concurrent_planner_clones_share_one_sharded_cache() {
        // 4 threads × one planner clone each, all planning the same set
        // of distinct shapes: the shared shards must end up with exactly
        // one plan per (kernel, dtype, shape) and every thread must see
        // identical resolved plans
        let reg = Registry::default();
        let planner = Planner::new(CacheSpec::HASWELL_L1D).with_sample_classes(4);
        let shapes: Vec<(usize, usize, usize)> =
            vec![(32, 24, 40), (64, 64, 64), (48, 96, 32), (96, 32, 48)];
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let planner = planner.clone();
                let reg = &reg;
                let shapes = &shapes;
                scope.spawn(move || {
                    for &(m, k, n) in shapes {
                        let a = planner.plan(reg, m, k, n, DType::F32);
                        let kern = ops::matmul(m as i64, k as i64, n as i64, 8, 0);
                        let b = planner.plan_kernel(reg, &kern);
                        assert_eq!((a.m, a.k, a.n), (m, k, n));
                        assert_eq!((b.m, b.k, b.n), (m, k, n));
                    }
                });
            }
        });
        // one AOT-namespace plan and one packed-engine plan per shape,
        // regardless of how many planners raced
        assert_eq!(planner.cached_plans(), 2 * shapes.len());
        // a fresh lookup returns the cached plan without re-modelling
        let again = planner.plan(&reg, 32, 24, 40, DType::F32);
        assert_eq!(again.plan_name, planner.plan(&reg, 32, 24, 40, DType::F32).plan_name);
    }

    #[test]
    fn plan_or_fallback_degrades_and_does_not_cache() {
        let reg = Registry::default();
        let planner = Planner::new(CacheSpec::HASWELL_L1D).with_sample_classes(4);
        let kern = ops::matmul(48, 32, 40, 4, 0);
        // both fault modes degrade to the flat plan
        for mode in [FaultMode::Error, FaultMode::Panic] {
            let f = Faults::seeded(5).fail_n(FaultPoint::Plan, mode, 1).build();
            let (p, fell_back) = planner.plan_or_fallback(&reg, &kern, &f);
            assert!(fell_back, "{mode:?} must trigger the fallback");
            assert_eq!(p.plan_name, "parameter-free flat fallback");
            assert_eq!((p.m, p.k, p.n), (48, 32, 40));
            assert_eq!((p.level.mc, p.level.kc, p.level.nc), (64, 64, 48));
            assert_eq!((p.level.m3, p.level.n3), (usize::MAX, usize::MAX));
            assert_eq!(p.dtype, DType::F32);
            assert_eq!(planner.cached_plans(), 0, "fallbacks must not be cached");
        }
        // with the budget spent, the same call heals to a modelled plan
        let f = Faults::seeded(5)
            .fail_n(FaultPoint::Plan, FaultMode::Error, 0)
            .build();
        let (p, fell_back) = planner.plan_or_fallback(&reg, &kern, &f);
        assert!(!fell_back);
        assert_ne!(p.plan_name, "parameter-free flat fallback");
        assert_eq!(planner.cached_plans(), 1);
    }

    #[test]
    fn plan_with_precision_carries_the_mixed_mode() {
        // the f32acc64 plan models at f32 storage (same shapes as the
        // pure f32 plan), reports the mixed mode, and occupies its own
        // cache slot
        let reg = Registry::default();
        let planner = Planner::new(CacheSpec::HASWELL_L1D);
        let pure = planner.plan(&reg, 64, 64, 64, DType::F32);
        let mixed = planner.plan_with_precision(&reg, 64, 64, 64, Precision::F32ACC64);
        assert_eq!(mixed.dtype, DType::F32);
        assert_eq!(mixed.precision, Precision::F32ACC64);
        assert!(mixed.precision.wide_acc());
        assert_eq!(pure.precision, Precision::F32);
        assert!(!pure.precision.wide_acc());
        // identical storage dtype → identical modelled shapes
        assert_eq!(mixed.level, pure.level);
        assert_eq!(mixed.model_tile, pure.model_tile);
        assert!(mixed.describe().contains("/f32acc64"), "{}", mixed.describe());
        assert!(!pure.describe().contains("acc64"), "{}", pure.describe());
        assert_eq!(planner.cached_plans(), 2, "precisions must not share a slot");
    }

    #[test]
    fn plan_reports_tall_grid_winners() {
        // a recorded 16-row grid winner must be dispatched and described
        let reg = Registry::default();
        reg.set_micro_shape_for(DType::F64, MicroShape::Mr16Nr6);
        let planner = Planner::new(CacheSpec::HASWELL_L1D);
        let p = planner.plan(&reg, 64, 64, 64, DType::F64);
        assert_eq!(p.micro, MicroShape::Mr16Nr6);
        assert!(p.describe().contains("micro 16x6"), "{}", p.describe());
    }

    #[test]
    fn plan_dtype_namespaces_do_not_collide() {
        let reg = Registry::default();
        let planner = Planner::new(CacheSpec::HASWELL_L1D);
        let a = planner.plan(&reg, 64, 64, 64, DType::F64);
        let b = planner.plan(&reg, 64, 64, 64, DType::F32);
        assert_eq!(planner.cached_plans(), 2);
        assert_eq!(a.dtype, DType::F64);
        assert_eq!(b.dtype, DType::F32);
    }
}
