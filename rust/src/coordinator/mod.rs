//! The L3 coordinator (DESIGN.md S12): planner, coalescing job service,
//! metrics.
//!
//! This is the request path of the system: clients submit matmul jobs
//! through a **bounded queue** — at most [`ServiceConfig::queue_cap`]
//! jobs in flight, with over-capacity submissions rejected at the door
//! by a typed [`SubmitError::QueueFull`] rather than buffered without
//! limit ([`Service::submit`] / cloneable [`ServiceClient`] handles for
//! concurrent clients). The planner (paper's §4.0.4 selector, cached per
//! shape and dtype in a **sharded, concurrently shareable** cache)
//! resolves each shape to an AOT kernel variant or the in-process packed
//! engine; the service **coalesces** shape-compatible jobs inside a
//! batch window that starts at the first job's arrival and dispatches
//! them through PJRT ([`service::Backend::Pjrt`]) or serves f32 directly
//! through the packed macro-kernel ([`service::Backend::Native`]). On
//! the native path a B-job batch is **one GEMM**: the transpose lowering
//! makes each job an m-column block of the right operand, so the batch
//! is the same kernel with its column axis widened from m to m·B over
//! the startup-prepacked weight panels — no extra copies, no replanning
//! for partial batches (they run a column prefix of the
//! `max_batch`-wide plan). [`Metrics`] attributes every job's latency
//! into queue wait vs compute and reports exact reservoir p50/p99 plus a
//! batch-size histogram. Python never runs here.
//!
//! [`ServiceConfig::queue_cap`]: service::ServiceConfig::queue_cap
//! [`SubmitError::QueueFull`]: service::SubmitError::QueueFull
//! [`Service::submit`]: service::Service::submit
//! [`ServiceClient`]: service::ServiceClient
//! [`Metrics`]: metrics::Metrics

pub mod metrics;
pub mod planner;
pub mod service;

pub use metrics::Metrics;
pub use planner::{Plan, Planner};
pub use service::{Backend, Service, ServiceClient, ServiceConfig, SubmitError};
