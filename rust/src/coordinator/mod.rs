//! The L3 coordinator (DESIGN.md S12): planner, batching job service,
//! metrics.
//!
//! This is the request path of the system: clients submit matmul jobs;
//! the planner (paper's §4.0.4 selector, cached per shape and dtype)
//! resolves each shape to an AOT kernel variant or the in-process packed
//! engine; the service batches jobs and dispatches them through PJRT
//! ([`service::Backend::Pjrt`]) or serves f32 directly through the
//! packed macro-kernel ([`service::Backend::Native`]). Python never runs
//! here.

pub mod metrics;
pub mod planner;
pub mod service;

pub use metrics::Metrics;
pub use planner::{Plan, Planner};
pub use service::{Backend, Service, ServiceConfig};
