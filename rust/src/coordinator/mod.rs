//! The L3 coordinator (DESIGN.md S12): planner, coalescing job service,
//! metrics, and deterministic fault injection.
//!
//! This is the request path of the system: clients submit matmul jobs
//! through a **bounded queue** — at most [`ServiceConfig::queue_cap`]
//! jobs in flight, with over-capacity submissions rejected at the door
//! by a typed [`SubmitError::QueueFull`] rather than buffered without
//! limit ([`Service::submit`] / cloneable [`ServiceClient`] handles for
//! concurrent clients). The planner (paper's §4.0.4 selector, cached per
//! shape and dtype in a **sharded, concurrently shareable** cache)
//! resolves each shape to an AOT kernel variant or the in-process packed
//! engine; the service **coalesces** shape-compatible jobs inside a
//! batch window that starts at the first job's arrival and dispatches
//! them through PJRT ([`service::Backend::Pjrt`]) or serves f32 directly
//! through the packed macro-kernel ([`service::Backend::Native`]). On
//! the native path a B-job batch is **one GEMM**: the transpose lowering
//! makes each job an m-column block of the right operand, so the batch
//! is the same kernel with its column axis widened from m to m·B over
//! the startup-prepacked weight panels — no extra copies, no replanning
//! for partial batches (they run a column prefix of the
//! `max_batch`-wide plan). [`Metrics`] attributes every job's latency
//! into queue wait vs compute and reports exact reservoir p50/p99 plus a
//! batch-size histogram. Python never runs here.
//!
//! # Failure model
//!
//! The serving runtime is built so that **no submitted job's receiver
//! ever hangs**: every accepted job resolves with `Ok(output)` or a
//! typed [`JobError`], under any contained failure.
//!
//! * **Contained panics.** Each worker-loop iteration runs under
//!   `catch_unwind`; batch execution is additionally caught per dispatch.
//!   When a panic escapes a batch, every in-flight job receives
//!   [`JobError::WorkerPanicked`], `Metrics::worker_restarts` is bumped,
//!   and the supervisor re-enters the worker loop over the same backend —
//!   the resident prepacked weight panels are immutable after startup and
//!   survive the respawn; only the per-batch column pack is rebuilt.
//!   [`Service::stop`] never re-panics: metrics live behind a shared
//!   `Arc<Mutex<_>>`, so even a poisoned worker yields a final snapshot
//!   (flagged `Metrics::worker_poisoned`).
//! * **Degradation ladder.** A failed multi-job batch is retried one job
//!   at a time (one poisoned job cannot take down its batchmates); a lone
//!   job failing twice in a row escalates to a worker respawn. Planner
//!   failures degrade to a parameter-free flat plan
//!   ([`Planner::plan_or_fallback`], counted in
//!   `Metrics::fallback_plans`) instead of failing `Service::start`, and
//!   [`ServiceClient::submit_with_retry`] heals transient
//!   [`SubmitError::QueueFull`] rejections with bounded, deterministic,
//!   jittered backoff.
//! * **Deadlines and drain.** With [`ServiceConfig::deadline`] set, jobs
//!   whose queue wait exceeds it are shed before compute with
//!   [`JobError::DeadlineExceeded`] (the shed side of the metrics
//!   shed-vs-served split). [`Service::stop`] drains gracefully: new
//!   submissions are rejected with [`SubmitError::Stopped`], queued work
//!   is finished, and a hard [`ServiceConfig::drain_timeout`] bounds the
//!   wait — jobs still queued at the bound resolve with
//!   [`JobError::Stopped`].
//! * **Deterministic chaos.** [`faults`] injects failures at named
//!   points (`FaultPoint::{BatchCompute, Pack, Plan, QueueAccept}`) on a
//!   seeded xorshift schedule with no wall-clock dependence; the hooks
//!   compile to no-ops unless `cfg(test)` or `--features
//!   fault-injection`. Run the chaos suite with
//!   `cargo test --features fault-injection` (CI runs it in debug and
//!   release), or demo it end to end with
//!   `cargo run --features fault-injection -- serve --backend native
//!   --inject-faults`.
//!
//! [`ServiceConfig::queue_cap`]: service::ServiceConfig::queue_cap
//! [`ServiceConfig::deadline`]: service::ServiceConfig::deadline
//! [`ServiceConfig::drain_timeout`]: service::ServiceConfig::drain_timeout
//! [`SubmitError::QueueFull`]: service::SubmitError::QueueFull
//! [`SubmitError::Stopped`]: service::SubmitError::Stopped
//! [`Service::submit`]: service::Service::submit
//! [`Service::stop`]: service::Service::stop
//! [`ServiceClient`]: service::ServiceClient
//! [`ServiceClient::submit_with_retry`]: service::ServiceClient::submit_with_retry
//! [`JobError`]: service::JobError
//! [`JobError::WorkerPanicked`]: service::JobError::WorkerPanicked
//! [`JobError::DeadlineExceeded`]: service::JobError::DeadlineExceeded
//! [`JobError::Stopped`]: service::JobError::Stopped
//! [`Planner::plan_or_fallback`]: planner::Planner::plan_or_fallback
//! [`Metrics`]: metrics::Metrics

// The coordinator is the fault-containment boundary: unwraps/expects are
// exactly the panic sites the supervisor exists to not need. Tests opt
// back in locally.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::sync::{Mutex, MutexGuard, PoisonError};

pub mod faults;
pub mod metrics;
pub mod planner;
pub mod service;

pub use faults::{FaultMode, FaultPoint, Faults};
pub use metrics::Metrics;
pub use planner::{Plan, Planner};
pub use service::{
    Backend, Health, JobError, ResultReceiver, Service, ServiceClient, ServiceConfig, SubmitError,
};

/// Lock a mutex, recovering the guard if a previous holder panicked.
/// Coordinator state behind mutexes (metrics counters, plan caches,
/// fault schedules) stays internally consistent across an unwind — each
/// holder's critical sections are short and idempotent — so poisoning is
/// noise here, and propagating it would turn one contained worker panic
/// into a crash at every later lock site.
pub(crate) fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}
