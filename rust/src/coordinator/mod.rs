//! The L3 coordinator (DESIGN.md S12): planner, batching job service,
//! metrics.
//!
//! This is the request path of the system: clients submit matmul jobs;
//! the planner (paper's §4.0.4 selector, cached per shape) resolves each
//! shape to an AOT kernel variant; the service batches jobs and dispatches
//! them through PJRT. Python never runs here.

pub mod metrics;
pub mod planner;
pub mod service;

pub use metrics::Metrics;
pub use planner::{Plan, Planner};
pub use service::{Service, ServiceConfig};
