//! The serving coordinator: bounded job queue → coalescing batcher →
//! supervised backend dispatch.
//!
//! One [`Service`] hosts one weight matrix `y` (k×n) and serves matmul
//! jobs `x·y` for m×k left operands, the way an inference router serves
//! a fixed model. The front is a **bounded async queue with admission
//! control**: at most `queue_cap` jobs may be in flight (accepted but
//! not yet answered), and an over-capacity [`submit`] is rejected
//! immediately with [`SubmitError::QueueFull`] instead of buffering
//! without limit — under overload the caller finds out at the door, not
//! by timeout ([`ServiceClient::submit_with_retry`] turns that rejection
//! into bounded, deterministic, jittered backoff for callers that prefer
//! to wait). Clone [`Service::client`] handles into as many threads as
//! you like; they share the same queue and the same capacity.
//!
//! Accepted jobs coalesce into batches. The **batch window starts when
//! the first job of a batch arrives** (idle time never consumes it), and
//! a batch closes at `max_batch` jobs or when the window elapses,
//! whichever is first. Only shape-compatible jobs coalesce — one service
//! serves one (m, k, n), and [`submit`] rejects any other `x` length
//! with [`SubmitError::ShapeMismatch`] before it can reach a batch. With
//! [`ServiceConfig::deadline`] set, jobs whose queue wait exceeds it are
//! shed at dispatch with [`JobError::DeadlineExceeded`] instead of
//! burning compute on an answer the caller has given up on.
//!
//! Batches dispatch through one of two backends:
//!
//! * [`Backend::Pjrt`] — the AOT-compiled JAX/Pallas artifacts via PJRT
//!   (vmapped batched variant when shipped, padding partial batches with
//!   zeros; single-shape kernel otherwise).
//! * [`Backend::Native`] — the in-process **f32 packed macro-kernel**,
//!   which executes a B-job batch as **one widened GEMM**. The transpose
//!   lowering makes coalescing free: each job's `x` (row-major m×k) is
//!   bit-identically the column-major k×m operand `C = xᵀ`, so B jobs
//!   written side by side are the k×(m·B) operand of the same GEMM with
//!   its column axis widened from m to m·B — no layout copies beyond the
//!   per-job `copy_from_slice` already paid, and the startup-prepacked
//!   `y` row panels plus each `kc` step's column bands are streamed once
//!   **per batch** instead of once per job. Partial batches run the
//!   column prefix `[0, B·m)` of the `max_batch`-wide plan
//!   ([`run_macro_prepacked_cols`]); batches whose widened shape spans
//!   several L3 super-bands can route through the parallel super-band
//!   scheduler ([`run_parallel_macro_prepacked`]) with the resident row
//!   panels shared read-only across workers. The native path serves two
//!   precision modes ([`ServiceConfig::precision`]): pure `f32`, and
//!   `f32acc64` — f32 storage and panels, f64 register accumulation
//!   with one rounding per `kc` slice.
//!
//! The worker thread runs under a **supervisor** ([`supervise`]): each
//! loop iteration and each batch execution is wrapped in `catch_unwind`,
//! so a panic anywhere in the dispatch path resolves every in-flight
//! receiver with a typed [`JobError::WorkerPanicked`] and respawns the
//! loop over the same resident backend state — no client ever blocks
//! forever, and [`Service::stop`] returns a metrics snapshot even when
//! the worker died (see the failure model in [`crate::coordinator`]).
//! A failed multi-job batch degrades to one-at-a-time retries before any
//! job is errored, so one poisoned job cannot take down its batchmates.
//!
//! Either way the worker thread runs a one-shot startup autotune per
//! dtype and records the winners in the registry, so plans report the
//! register-tile shape the engine actually dispatches. [`Metrics`]
//! attributes each job's latency into queue wait (submit → batch
//! dispatch) and compute, with exact reservoir p50/p99 and a batch-size
//! histogram.
//!
//! [`submit`]: Service::submit
//! [`run_macro_prepacked_cols`]: crate::codegen::run_macro_prepacked_cols
//! [`run_parallel_macro_prepacked`]: crate::codegen::run_parallel_macro_prepacked

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::cache::CacheSpec;
use crate::codegen::executor::{pack_row_slices_mr, run_macro_prepacked_with, super_band_extents};
use crate::codegen::parallel::run_parallel_macro_prepacked_with;
use crate::codegen::{
    autotune, kernel_views, DType, ExecOpts, GemmForm, KernelBuffers, MicroShape, PackedCols,
    PackedRows, Precision, RunPlan,
};
use crate::domain::{ops, Kernel};
use crate::runtime::{ArtifactKind, Engine, Registry};
use crate::tiling::{LevelPlan, ShapeClass, StrategyChoice};

use super::faults::{self, FaultMode, FaultPoint, Faults};
use super::lock_unpoisoned;
use super::metrics::Metrics;
use super::planner::{Plan, Planner};

/// Which execution engine serves the jobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Backend {
    /// AOT PJRT artifacts (requires `make artifacts`).
    #[default]
    Pjrt,
    /// The in-process f32 packed macro-kernel (no artifacts needed).
    Native,
}

/// Typed admission-control rejection from [`Service::submit`] /
/// [`ServiceClient::submit`]. Rejections happen before the job enters
/// the queue — a rejected job consumes no capacity and no worker time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded queue already holds `cap` in-flight jobs.
    QueueFull { cap: usize },
    /// `x` does not match the served m×k shape — it could never coalesce
    /// with this service's batches.
    ShapeMismatch { got: usize, want: usize },
    /// The service is stopping or stopped; no new work is accepted.
    Stopped,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { cap } => {
                write!(f, "submission queue full (capacity {cap})")
            }
            SubmitError::ShapeMismatch { got, want } => {
                write!(f, "x has {got} elements, served shape needs {want}")
            }
            SubmitError::Stopped => write!(f, "service stopped"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Typed per-job failure delivered through a [`ResultReceiver`]. Every
/// accepted job resolves with `Ok(output)` or exactly one of these —
/// the containment contract is that no receiver ever hangs.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobError {
    /// The worker panicked while this job was in flight. The supervisor
    /// delivered this error, bumped `Metrics::worker_restarts`, and
    /// respawned the worker over the same resident backend state.
    WorkerPanicked { detail: String },
    /// The job's queue wait exceeded [`ServiceConfig::deadline`]; it was
    /// shed before compute (counted in `Metrics::timeouts`, not
    /// `errors`).
    DeadlineExceeded { waited: Duration, deadline: Duration },
    /// The backend returned an execution error (after the degradation
    /// ladder's one-at-a-time retry also failed).
    Backend { detail: String },
    /// The service stopped before the job completed (drain-timeout
    /// stragglers, or the worker vanished).
    Stopped,
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JobError::WorkerPanicked { detail } => {
                write!(f, "worker panicked while serving this job: {detail}")
            }
            JobError::DeadlineExceeded { waited, deadline } => {
                write!(f, "job shed after waiting {waited:?} (deadline {deadline:?})")
            }
            JobError::Backend { detail } => write!(f, "backend execution failed: {detail}"),
            JobError::Stopped => write!(f, "service stopped before the job completed"),
        }
    }
}

impl std::error::Error for JobError {}

struct Job {
    x: Vec<f32>,
    resp: Sender<Result<Vec<f32>, JobError>>,
    submitted: Instant,
    /// Per-job queue-wait deadline, overriding the service-wide
    /// [`ServiceConfig::deadline`] when set (see
    /// [`ServiceClient::submit_with_deadline`]).
    deadline: Option<Duration>,
}

enum Msg {
    Job(Job),
    Stop,
}

/// Receiver for one submitted job's m×n row-major result. Resolution is
/// guaranteed: if the worker vanishes without answering (its sender
/// dropped), `recv` reports [`JobError::Stopped`] instead of an opaque
/// channel error — a receiver never observes a hang as its steady state.
pub struct ResultReceiver {
    rx: Receiver<Result<Vec<f32>, JobError>>,
}

impl ResultReceiver {
    /// Block until the job resolves.
    pub fn recv(&self) -> Result<Vec<f32>, JobError> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(JobError::Stopped),
        }
    }

    /// Block up to `timeout`; `None` means the job has not resolved yet
    /// (a disconnected worker resolves as [`JobError::Stopped`], not
    /// `None`).
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Result<Vec<f32>, JobError>> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => Some(r),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => Some(Err(JobError::Stopped)),
        }
    }
}

/// Handle to a running coordinator thread.
pub struct Service {
    tx: Sender<Msg>,
    depth: Arc<AtomicUsize>,
    stopped: Arc<AtomicBool>,
    queue_cap: usize,
    metrics: Arc<Mutex<Metrics>>,
    handle: std::thread::JoinHandle<Duration>,
    faults: Faults,
    retry_seq: Arc<AtomicU64>,
    m: usize,
    k: usize,
    n: usize,
    plan: Plan,
}

/// A cloneable submission handle onto a running [`Service`] — hand one
/// to each client thread. Clones share the service's queue, its
/// admission capacity, and its metrics.
#[derive(Clone)]
pub struct ServiceClient {
    tx: Sender<Msg>,
    depth: Arc<AtomicUsize>,
    stopped: Arc<AtomicBool>,
    faults: Faults,
    metrics: Arc<Mutex<Metrics>>,
    retry_seq: Arc<AtomicU64>,
    queue_cap: usize,
    m: usize,
    k: usize,
}

/// Admission control shared by [`Service::submit`] and the client
/// handles. On rejection the job's `x` buffer is handed back so retry
/// loops can resubmit without a copy.
#[allow(clippy::too_many_arguments)]
fn admit_and_send(
    tx: &Sender<Msg>,
    depth: &AtomicUsize,
    stopped: &AtomicBool,
    faults: &Faults,
    cap: usize,
    want: usize,
    x: Vec<f32>,
    deadline: Option<Duration>,
) -> Result<ResultReceiver, (SubmitError, Vec<f32>)> {
    if stopped.load(Ordering::SeqCst) {
        return Err((SubmitError::Stopped, x));
    }
    if x.len() != want {
        let got = x.len();
        return Err((SubmitError::ShapeMismatch { got, want }, x));
    }
    // injected transient overload: manifests as an ordinary QueueFull —
    // exactly the rejection submit_with_retry's backoff is for
    if faults.check(FaultPoint::QueueAccept).is_some() {
        return Err((SubmitError::QueueFull { cap }, x));
    }
    // in-flight accounting: a slot is held from here until the worker
    // has *answered* the job, so capacity bounds queued and executing
    // work together
    if depth.fetch_add(1, Ordering::SeqCst) >= cap {
        depth.fetch_sub(1, Ordering::SeqCst);
        return Err((SubmitError::QueueFull { cap }, x));
    }
    let (rtx, rrx) = channel();
    let job = Job {
        x,
        resp: rtx,
        submitted: Instant::now(),
        deadline,
    };
    if let Err(send_err) = tx.send(Msg::Job(job)) {
        depth.fetch_sub(1, Ordering::SeqCst);
        let x = match send_err.0 {
            Msg::Job(j) => j.x,
            Msg::Stop => Vec::new(),
        };
        return Err((SubmitError::Stopped, x));
    }
    Ok(ResultReceiver { rx: rrx })
}

impl ServiceClient {
    /// Submit a job; returns the receiver for the m×n row-major result,
    /// or a typed rejection if the queue is full / the shape is wrong /
    /// the service is stopping.
    pub fn submit(&self, x: Vec<f32>) -> Result<ResultReceiver, SubmitError> {
        admit_and_send(
            &self.tx,
            &self.depth,
            &self.stopped,
            &self.faults,
            self.queue_cap,
            self.m * self.k,
            x,
            None,
        )
        .map_err(|(e, _)| e)
    }

    /// [`submit`](ServiceClient::submit) with a per-job queue-wait
    /// deadline overriding the service-wide [`ServiceConfig::deadline`]
    /// for this job only (tighter or looser — the job's own bound wins
    /// either way). A job still queued past its effective deadline at a
    /// dispatch boundary resolves [`JobError::DeadlineExceeded`] and
    /// counts under the existing `Metrics::timeouts`, exactly like a
    /// service-wide shed.
    pub fn submit_with_deadline(
        &self,
        x: Vec<f32>,
        deadline: Duration,
    ) -> Result<ResultReceiver, SubmitError> {
        admit_and_send(
            &self.tx,
            &self.depth,
            &self.stopped,
            &self.faults,
            self.queue_cap,
            self.m * self.k,
            x,
            Some(deadline),
        )
        .map_err(|(e, _)| e)
    }

    /// [`submit`](ServiceClient::submit) with bounded, deterministic,
    /// jittered exponential backoff on [`SubmitError::QueueFull`]: up to
    /// `max_attempts` admissions, sleeping `base_backoff` (doubling each
    /// retry, capped at 100ms) plus an xorshift jitter between them.
    /// Only transient overload is retried — `ShapeMismatch` and
    /// `Stopped` return immediately. Each re-admission counts in
    /// `Metrics::retries`.
    pub fn submit_with_retry(
        &self,
        x: Vec<f32>,
        max_attempts: usize,
        base_backoff: Duration,
    ) -> Result<ResultReceiver, SubmitError> {
        let max_attempts = max_attempts.max(1);
        let mut backoff = base_backoff;
        // per-call deterministic jitter stream: seeded from a process-wide
        // call counter, never wall-clock — concurrent retriers decorrelate
        // without losing replayability
        let mut s = 0x9E37_79B9_7F4A_7C15u64
            ^ (((self.retry_seq.fetch_add(1, Ordering::Relaxed) + 1) << 1) | 1);
        let mut x = x;
        for attempt in 1..=max_attempts {
            match admit_and_send(
                &self.tx,
                &self.depth,
                &self.stopped,
                &self.faults,
                self.queue_cap,
                self.m * self.k,
                x,
                None,
            ) {
                Ok(rx) => return Ok(rx),
                Err((SubmitError::QueueFull { cap }, recovered)) => {
                    if attempt == max_attempts {
                        return Err(SubmitError::QueueFull { cap });
                    }
                    x = recovered;
                    lock_unpoisoned(&self.metrics).retries += 1;
                    s ^= s << 13;
                    s ^= s >> 7;
                    s ^= s << 17;
                    let span_us = backoff.as_micros() as u64;
                    let jitter = if span_us == 0 { 0 } else { s % span_us };
                    std::thread::sleep(backoff + Duration::from_micros(jitter));
                    backoff = (backoff * 2).min(Duration::from_millis(100));
                }
                Err((e, _)) => return Err(e),
            }
        }
        // the loop always returns on its last attempt
        Err(SubmitError::QueueFull { cap: self.queue_cap })
    }
}

impl Service {
    /// The served output shape (m, n) per job.
    pub fn output_shape(&self) -> (usize, usize) {
        (self.m, self.n)
    }

    /// The plan chosen for the served shape — carries the dtype, the
    /// two-level `mc×kc×nc` macro-block decision and the per-dtype
    /// autotuned register-tile width alongside the L1 tile (report with
    /// [`Plan::describe`]).
    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// Point-in-time health/readiness probe — cheap enough for a tight
    /// poll loop (two atomic loads, one uncontended lock). Load-balancer
    /// semantics: [`Health::ready`] means new submissions have a live
    /// worker and at least one free queue slot *right now*; a probe
    /// taken during a supervisor respawn window still reports the
    /// worker alive (the thread is running its recovery path), with
    /// `worker_restarts` counting how many respawns the supervisor has
    /// performed since start.
    pub fn health(&self) -> Health {
        Health {
            worker_alive: !self.handle.is_finished(),
            stopping: self.stopped.load(Ordering::SeqCst),
            queue_depth: self.depth.load(Ordering::SeqCst),
            queue_cap: self.queue_cap,
            worker_restarts: lock_unpoisoned(&self.metrics).worker_restarts,
        }
    }

    /// A cloneable submission handle for client threads.
    pub fn client(&self) -> ServiceClient {
        ServiceClient {
            tx: self.tx.clone(),
            depth: self.depth.clone(),
            stopped: self.stopped.clone(),
            faults: self.faults.clone(),
            metrics: self.metrics.clone(),
            retry_seq: self.retry_seq.clone(),
            queue_cap: self.queue_cap,
            m: self.m,
            k: self.k,
        }
    }
}

/// One [`Service::health`] probe: worker liveness, queue pressure and
/// the supervisor's restart count. Render with `to_string()` for a
/// one-line status (the `serve` CLI prints it alongside the metrics
/// report).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Health {
    /// The supervised worker thread is running (a respawn after a
    /// contained panic keeps it alive; `false` means the thread itself
    /// exited — stopping, or something the supervisor could not catch).
    pub worker_alive: bool,
    /// [`Service::stop`] has begun; new submissions are rejected.
    pub stopping: bool,
    /// Jobs currently in flight (accepted, not yet answered).
    pub queue_depth: usize,
    /// The admission bound ([`ServiceConfig::queue_cap`]).
    pub queue_cap: usize,
    /// Worker respawns the supervisor has performed since start
    /// (`Metrics::worker_restarts`, sampled live).
    pub worker_restarts: u64,
}

impl Health {
    /// Readiness: a submission made right now would find a live worker
    /// and a free queue slot. Restarts do not affect readiness — a
    /// respawned worker serves over the same resident state.
    pub fn ready(&self) -> bool {
        self.worker_alive && !self.stopping && self.queue_depth < self.queue_cap
    }
}

impl std::fmt::Display for Health {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "worker={} queue={}/{} restarts={} ready={}",
            if self.worker_alive { "alive" } else { "dead" },
            self.queue_depth,
            self.queue_cap,
            self.worker_restarts,
            self.ready()
        )
    }
}

/// Configuration for [`Service::start`].
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// How long the batcher waits to fill a batch, measured from the
    /// arrival of the batch's first job.
    pub batch_window: Duration,
    /// Most jobs one dispatch may coalesce (the native backend plans its
    /// widened GEMM for exactly this width at startup; the PJRT backend
    /// is capped by the shipped batched artifact instead).
    pub max_batch: usize,
    /// Most in-flight jobs (accepted, not yet answered) before
    /// [`Service::submit`] rejects with [`SubmitError::QueueFull`].
    pub queue_cap: usize,
    /// Worker threads for the native backend's batch GEMM: batches whose
    /// widened shape spans several L3 super-bands route through the
    /// parallel super-band scheduler. 1 = always serial.
    pub threads: usize,
    /// Cache spec the planner models (tile selection).
    pub spec: CacheSpec,
    /// Execution engine: PJRT artifacts or the native packed kernel.
    pub backend: Backend,
    /// Serving precision. Storage must be f32 (job buffers are `f32`);
    /// [`Precision::F32ACC64`] keeps the f32 panels and plan geometry
    /// but accumulates every register tile in f64, rounding once per
    /// `kc` slice — native backend only (the PJRT artifacts compute
    /// pure f32).
    pub precision: Precision,
    /// Tiling-strategy policy for the serve plans: `Auto` (the default)
    /// races the registered strategies once at startup and dispatches
    /// each shape class's recorded winner; `Fixed` pins one strategy
    /// (the CLI's `--strategy {lattice,oblivious,latency}` override).
    pub strategy: StrategyChoice,
    /// Per-request queue-wait deadline: jobs still queued past it are
    /// shed at dispatch with [`JobError::DeadlineExceeded`] instead of
    /// computed. `None` (the default) never sheds.
    pub deadline: Option<Duration>,
    /// Hard bound on [`Service::stop`]'s graceful drain: queued jobs
    /// still unanswered at the bound resolve with [`JobError::Stopped`].
    pub drain_timeout: Duration,
    /// Fault-injection schedule ([`Faults::none`] in production; armed
    /// handles exist only under `cfg(test)` / `--features
    /// fault-injection`).
    pub faults: Faults,
}

impl Default for ServiceConfig {
    fn default() -> ServiceConfig {
        ServiceConfig {
            m: 128,
            k: 128,
            n: 128,
            batch_window: Duration::from_millis(2),
            max_batch: 8,
            queue_cap: 256,
            threads: 1,
            spec: CacheSpec::HASWELL_L1D,
            backend: Backend::Pjrt,
            precision: Precision::F32,
            strategy: StrategyChoice::Auto,
            deadline: None,
            drain_timeout: Duration::from_secs(5),
            faults: Faults::none(),
        }
    }
}

/// The serve level for a coalescing-width plan pair: row/reduction-side
/// blocking (`l1_tile`, `mc`, `kc`, `m3`) pinned from the single-job
/// plan, column-side geometry (`nc`, `n3`) from the `max_batch`-wide
/// plan. The split is what makes results **bitwise independent of
/// `max_batch`**: the microkernel accumulates each `kc` reduction slice
/// in registers and adds the slice sums in ascending-`k0` order, so the
/// `kc` partition is the only blocking parameter that changes an output
/// element's floating-point grouping — `mc`/`m3`/`l1` only regroup which
/// elements run together and `nc`/`n3` only partition the widened column
/// axis. Pinning the whole row/reduction side to the width-independent
/// single-job plan keeps every element's accumulation order fixed while
/// the column side still scales its bands to the widened batch extent.
/// Cap per raced GEMM axis: the startup strategy race measures a capped
/// model of the served shape (same kernel name, same op family) so the
/// race costs milliseconds even for wide coalescing extents, while the
/// winner is recorded under the **true** shape's class key.
const STRATEGY_RACE_CAP: usize = 128;

/// Race the registered tiling strategies once for the served GEMM shape
/// `m×k×n` (in serve coordinates — the raced kernel is the same
/// transpose lowering the native engine executes) and record the winner
/// in the registry under the true shape's (kernel, dtype, class) key.
/// Already-recorded classes are kept — restarts and multi-service setups
/// race each class at most once per registry.
fn race_serving_strategy(registry: &Registry, m: usize, k: usize, n: usize, micro: MicroShape) {
    let kernel = NativeMatmul::kernel_for(m, k, n);
    let class = ShapeClass::of_kernel(&kernel);
    if registry.strategy_for(DType::F32, kernel.name(), class).is_some() {
        return;
    }
    let capped = NativeMatmul::kernel_for(
        m.min(STRATEGY_RACE_CAP),
        k.min(STRATEGY_RACE_CAP),
        n.min(STRATEGY_RACE_CAP),
    );
    let winner = autotune::calibrate_strategies::<f32>(&capped, micro, 8, 2);
    registry.set_strategy_for(DType::F32, kernel.name(), class, winner);
}

fn serving_level(job: &LevelPlan, wide: &LevelPlan) -> LevelPlan {
    LevelPlan {
        l1_tile: job.l1_tile,
        mc: job.mc,
        kc: job.kc,
        m3: job.m3,
        nc: wide.nc,
        n3: wide.n3,
    }
}

impl Service {
    /// Start the coordinator: loads the registry (optional for the
    /// native backend), plans the shape at the serving dtype (f32), warms
    /// the chosen executables **before spawning** (a missing PJRT runtime
    /// or artifact fails `start()` with a diagnosable error instead of
    /// aborting the worker thread), then spawns the supervised worker
    /// that owns the engine.
    pub fn start(artifact_dir: &Path, y: Vec<f32>, cfg: ServiceConfig) -> Result<Service> {
        anyhow::ensure!(
            cfg.precision.store == DType::F32,
            "serving stores f32 job buffers; --dtype {} cannot be served",
            cfg.precision.name()
        );
        anyhow::ensure!(
            cfg.backend == Backend::Native || !cfg.precision.wide_acc(),
            "f32acc64 needs the native backend (PJRT artifacts compute pure f32)"
        );
        let registry = match cfg.backend {
            Backend::Pjrt => Registry::load(artifact_dir)?,
            // the native engine needs no artifacts; keep whatever loads
            // so mixed deployments can still resolve PJRT names
            Backend::Native => Registry::load(artifact_dir).unwrap_or_default(),
        };
        // one-shot startup autotune (ROADMAP), per dtype: record each
        // precision's winning register-tile width class; the narrow shape
        // stays the compile-time default
        registry.set_micro_shape_for(DType::F64, autotune::calibrate_dtype::<f64>(2_000));
        registry.set_micro_shape_for(DType::F32, autotune::calibrate_dtype::<f32>(2_000));
        anyhow::ensure!(
            y.len() == cfg.k * cfg.n,
            "y must be k×n = {}",
            cfg.k * cfg.n
        );
        let planner = Planner::new(cfg.spec).with_strategy(cfg.strategy);
        let (tx, rx) = channel::<Msg>();
        let depth = Arc::new(AtomicUsize::new(0));
        let stopped = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let faults = cfg.faults.clone();
        let m = cfg.m;
        let k = cfg.k;
        let n = cfg.n;
        let queue_cap = cfg.queue_cap.max(1);
        let shared = WorkerShared {
            rx,
            depth: depth.clone(),
            metrics: metrics.clone(),
            flops_per_job: (2 * m * k * n) as u64,
            m,
            k,
            n,
            window: cfg.batch_window,
            deadline: cfg.deadline,
            drain_timeout: cfg.drain_timeout,
        };
        let (plan, backend) = match cfg.backend {
            Backend::Pjrt => {
                // the PJRT artifacts compute in f32 — plan at f32 so the
                // model sees the true elements-per-line
                let plan = planner.plan(&registry, m, k, n, DType::F32);
                let single = registry
                    .by_name(&plan.artifact)
                    .with_context(|| format!("planned artifact {} missing", plan.artifact))?
                    .name
                    .clone();
                // batched variant with the same problem shape, if shipped
                let batched = registry
                    .artifacts()
                    .iter()
                    .find(|a| {
                        a.kind == ArtifactKind::PallasTiledMatmulBatched
                            && a.m == m
                            && a.k == k
                            && a.n == n
                    })
                    .map(|a| (a.name.clone(), a.batch));
                // warm the executables on the caller's thread: a broken
                // runtime is a typed start() error, never a worker abort
                let mut engine = Engine::new(registry)
                    .context("pjrt engine init failed (is the PJRT runtime available?)")?;
                engine
                    .prepare(&single)
                    .with_context(|| format!("prepare single artifact {single}"))?;
                if let Some((name, _)) = &batched {
                    engine
                        .prepare(name)
                        .with_context(|| format!("prepare batched artifact {name}"))?;
                }
                let backend = WorkerBackend::Pjrt {
                    engine,
                    single,
                    batched,
                    y,
                };
                (plan, backend)
            }
            Backend::Native => {
                let max_batch = cfg.max_batch.max(1);
                let threads = cfg.threads.max(1);
                // plan the kernel the native engine actually executes —
                // the f32 column-major transpose lowering — twice: once at
                // the single-job width (the numerics anchor) and once at
                // the full coalescing width m·max_batch (the geometry the
                // resident arena is laid out for); see `serving_level`.
                // Planner failures degrade to the parameter-free flat
                // fallback instead of failing start()
                // one-shot startup strategy race (auto policy only): race
                // the registered tiling strategies on a capped model of
                // each served GEMM shape and record the winner under the
                // true shape's class, so the planner's auto dispatch
                // below resolves it; fixed overrides skip the race
                if cfg.strategy == StrategyChoice::Auto {
                    let race_micro = registry
                        .micro_shape_for(DType::F32)
                        .unwrap_or(MicroShape::Mr8Nr4);
                    race_serving_strategy(&registry, m, k, n, race_micro);
                    race_serving_strategy(&registry, m * max_batch, k, n, race_micro);
                }
                let (job_plan, fb_job) = planner.plan_or_fallback(
                    &registry,
                    &NativeMatmul::kernel_for(m, k, n),
                    &faults,
                );
                let wide_kernel = NativeMatmul::kernel_for(m * max_batch, k, n);
                let (wide_plan, fb_wide) =
                    planner.plan_or_fallback(&registry, &wide_kernel, &faults);
                lock_unpoisoned(&metrics).fallback_plans = fb_job as u64 + fb_wide as u64;
                let level = serving_level(&job_plan.level, &wide_plan.level);
                let mut plan = job_plan;
                plan.level = level;
                // the serve precision rides on the plan: same f32 storage,
                // shapes and geometry either way, but describe() and the
                // dispatch below see the accumulate mode
                plan.precision = cfg.precision;
                // the executed kernel is the transpose lowering (GEMM rows
                // = serve columns); surface the serve shape and the
                // coalescing width so plan lines are readable next to the
                // PJRT backend's
                plan.plan_name = format!(
                    "{} (serving {m}x{k}x{n} via transpose, coalescing <= {max_batch})",
                    plan.plan_name
                );
                let native = NativeMatmul::new(
                    m,
                    k,
                    n,
                    &y,
                    level,
                    plan.micro,
                    plan.precision.wide_acc(),
                    max_batch,
                    threads,
                    faults.clone(),
                )?;
                (plan, WorkerBackend::Native(Box::new(native)))
            }
        };
        // which tiling strategy produced the served plan — the strategy
        // race's win-rate report and the fault-path accounting read the
        // same name (the flat fallback reports itself here too)
        lock_unpoisoned(&metrics).plan_strategy = plan.strategy.to_string();
        let handle = std::thread::spawn(move || supervise(backend, shared));
        Ok(Service {
            tx,
            depth,
            stopped,
            queue_cap,
            metrics,
            handle,
            faults,
            retry_seq: Arc::new(AtomicU64::new(0)),
            m,
            k,
            n,
            plan,
        })
    }

    /// Submit a job; returns the receiver for the m×n row-major result,
    /// or a typed rejection if the bounded queue is at capacity / the
    /// shape is wrong / the service is stopping.
    pub fn submit(&self, x: Vec<f32>) -> Result<ResultReceiver, SubmitError> {
        admit_and_send(
            &self.tx,
            &self.depth,
            &self.stopped,
            &self.faults,
            self.queue_cap,
            self.m * self.k,
            x,
            None,
        )
        .map_err(|(e, _)| e)
    }

    /// Stop gracefully and collect metrics (+ total wall time of the
    /// worker): new submissions are rejected with
    /// [`SubmitError::Stopped`], queued work is finished (bounded by
    /// [`ServiceConfig::drain_timeout`]), the worker joins. Never
    /// re-panics: if the worker thread itself died, the snapshot comes
    /// back with `Metrics::worker_poisoned` set and a zero wall time.
    pub fn stop(self) -> (Metrics, Duration) {
        self.stopped.store(true, Ordering::SeqCst);
        let Service {
            tx,
            metrics,
            handle,
            ..
        } = self;
        let _ = tx.send(Msg::Stop);
        drop(tx);
        let (wall, poisoned) = match handle.join() {
            Ok(wall) => (wall, false),
            Err(_) => (Duration::ZERO, true),
        };
        let mut snapshot = lock_unpoisoned(&metrics).clone();
        snapshot.worker_poisoned = poisoned;
        (snapshot, wall)
    }
}

/// The f32 packed-macro-kernel serve engine, planned for a coalesced
/// batch: one resident [`KernelBuffers<f32>`] arena laid out for the
/// `max_batch`-wide GEMM, holding `y` — whose row panels really are
/// packed once, at startup ([`pack_row_slices`]) — and up to `max_batch`
/// jobs' `x` operands side by side.
///
/// Row-major serving lowers onto the column-major engine via the
/// transpose identity `(x·y)ᵀ = yᵀ·xᵀ`: the kernel computes the
/// column-major product `A(n×m·B) = B(n×k)·C(k×m·B)`, and the row-major
/// buffers are *bit-identical* reinterpretations — `y` row-major k×n is
/// exactly `B = yᵀ` column-major n×k, each job's `x` row-major m×k is
/// exactly an m-column block of `C` column-major, and the output table
/// read in layout order is the batch's row-major m×n results
/// concatenated. No transposition copies anywhere, so coalescing B jobs
/// is *free*: the batch is one GEMM whose column axis widened from m to
/// m·B, and a partial batch executes the column prefix `[0, B·m)` of the
/// same plan ([`run_macro_prepacked_cols`] — the per-column offset
/// tables make the prefix exactly the narrower GEMM). Per batch only the
/// `x` column bands are packed; the weight panels are reused as-is, and
/// when the widened shape spans several L3 super-bands and `threads > 1`
/// the batch routes through [`run_parallel_macro_prepacked`] with those
/// resident panels shared read-only across workers.
///
/// Fault containment: the resident row panels are immutable after
/// startup, so a panic mid-batch cannot corrupt them — [`recover`]
/// (called by the supervisor and the degradation ladder) only resets the
/// per-batch column-pack scratch, whose caching keys could otherwise go
/// stale across an unwind.
///
/// [`recover`]: NativeMatmul::recover
struct NativeMatmul {
    /// The `max_batch`-wide kernel (the parallel path re-checks its
    /// output map is injective before sharing the arena across workers).
    kernel: Kernel,
    plan: RunPlan,
    level: LevelPlan,
    micro: MicroShape,
    /// Wide-accumulation serve mode (`f32acc64`): register tiles
    /// accumulate in f64 over the same f32 panels.
    acc64: bool,
    bufs: KernelBuffers<f32>,
    /// `y`'s row panels, one [`PackedRows`] per reduction slice — packed
    /// once at startup, shared by every batch (`y` never changes).
    rows: Vec<PackedRows<f32>>,
    cols: PackedCols<f32>,
    faults: Faults,
    m: usize,
    k: usize,
    n: usize,
    max_batch: usize,
    threads: usize,
}

impl NativeMatmul {
    /// The f32 kernel the native backend executes for an m×k×n serve
    /// shape (see the type docs for the transpose lowering) — pass
    /// `m·max_batch` as `m` for the coalesced-batch kernel.
    fn kernel_for(m: usize, k: usize, n: usize) -> Kernel {
        ops::matmul(n as i64, k as i64, m as i64, DType::F32.elem(), 0)
    }

    #[allow(clippy::too_many_arguments)]
    fn new(
        m: usize,
        k: usize,
        n: usize,
        y: &[f32],
        level: LevelPlan,
        micro: MicroShape,
        acc64: bool,
        max_batch: usize,
        threads: usize,
        faults: Faults,
    ) -> Result<NativeMatmul> {
        let max_batch = max_batch.max(1);
        let kernel = NativeMatmul::kernel_for(m * max_batch, k, n);
        let mut bufs = KernelBuffers::<f32>::from_kernel(&kernel);
        // operand 1 is B = yᵀ (n×k column-major) — the same linear bytes
        // as y (k×n row-major)
        bufs.operand_mut(1).copy_from_slice(y);
        let gf = GemmForm::of(&kernel).context("native serve kernel must be GEMM-form")?;
        let lo = vec![0i64; kernel.n_free()];
        let plan = gf.plan_box(&kernel_views(&kernel), &lo, kernel.extents());
        // y is resident for the service's lifetime: pack its row panels
        // exactly once, here, at the dispatched geometry's panel height —
        // they depend only on rows × reduction × mr, so one set serves
        // every batch width (a 16-row autotune winner needs 16-row
        // panels: the prepacked entry points reject a height mismatch)
        let rows = pack_row_slices_mr(&bufs.arena, &plan, &level, micro.mr());
        Ok(NativeMatmul {
            kernel,
            plan,
            level,
            micro,
            acc64,
            bufs,
            rows,
            cols: PackedCols::new(),
            faults,
            m,
            k,
            n,
            max_batch,
            threads,
        })
    }

    /// Serve a coalesced batch as one widened GEMM: load the jobs' `x`
    /// operands side by side, zero the output, run the column prefix
    /// `[0, B·m)` over the pre-packed weight panels (parallel across L3
    /// super-bands when configured and profitable), slice the output per
    /// job in row-major order. Returns the per-job results and the
    /// number of column-band packs the batch performed (the resident row
    /// panels are packed zero times here — test-pinned).
    fn run_batch(&mut self, xs: &[&[f32]]) -> Result<(Vec<Vec<f32>>, u64), JobError> {
        match self.faults.check(FaultPoint::BatchCompute) {
            Some(FaultMode::Error) => {
                return Err(JobError::Backend {
                    detail: "injected fault at BatchCompute".to_string(),
                })
            }
            Some(FaultMode::Panic) => faults::inject_panic(FaultPoint::BatchCompute),
            None => {}
        }
        let b = xs.len();
        assert!(
            (1..=self.max_batch).contains(&b),
            "batch exceeds planned width"
        );
        self.bufs.reset_output();
        let job = self.m * self.k;
        let op2 = self.bufs.operand_mut(2);
        for (i, x) in xs.iter().enumerate() {
            op2[i * job..(i + 1) * job].copy_from_slice(x);
        }
        let n_used = self.m * b;
        let (m3, n3) = super_band_extents(&self.level);
        let grid = self.plan.m.div_ceil(m3) * n_used.div_ceil(n3);
        // scope the fault schedule for the executor's deep Pack hook
        // (clone first: the closure needs exclusive access to self)
        let scope_faults = self.faults.clone();
        let opts = ExecOpts::serving(self.micro, self.acc64);
        let col_packs = faults::with_scope(&scope_faults, || {
            if self.threads > 1 && grid > 1 {
                run_parallel_macro_prepacked_with(
                    &mut self.bufs.arena,
                    &self.kernel,
                    &self.plan,
                    &self.level,
                    &self.rows,
                    self.threads,
                    n_used,
                    opts,
                )
                .col_band_packs
            } else {
                run_macro_prepacked_with(
                    &mut self.bufs.arena,
                    &self.plan,
                    &self.level,
                    &self.rows,
                    &mut self.cols,
                    n_used,
                    opts,
                )
            }
        });
        let out = self.bufs.output();
        let per = self.m * self.n;
        let outs = (0..b)
            .map(|i| out[i * per..(i + 1) * per].to_vec())
            .collect();
        Ok((outs, col_packs))
    }

    /// Reset per-batch scratch after a contained failure: the column-pack
    /// buffer may hold a half-written band (its caching key would lie),
    /// so drop it. The resident row panels are immutable and stay.
    fn recover(&mut self) {
        self.cols = PackedCols::new();
    }

    /// Total pack operations the resident row panels have absorbed —
    /// constant after startup; the chaos suite pins it across respawns.
    fn resident_packs(&self) -> u64 {
        self.rows.iter().map(|r| r.pack_count()).sum()
    }
}

enum WorkerBackend {
    Pjrt {
        engine: Engine,
        single: String,
        batched: Option<(String, usize)>,
        y: Vec<f32>,
    },
    Native(Box<NativeMatmul>),
}

impl WorkerBackend {
    /// How many jobs one dispatch can carry.
    fn batch_cap(&self) -> usize {
        match self {
            WorkerBackend::Pjrt {
                batched: Some((_, b)),
                ..
            } => *b,
            WorkerBackend::Pjrt { .. } => 1,
            WorkerBackend::Native(native) => native.max_batch,
        }
    }

    /// Reset per-batch scratch after a contained failure (no-op for
    /// PJRT, whose per-dispatch state lives on the engine side).
    fn recover(&mut self) {
        if let WorkerBackend::Native(native) = self {
            native.recover();
        }
    }

    /// Resident prepacked weight-panel pack count (native only).
    fn resident_packs(&self) -> Option<u64> {
        match self {
            WorkerBackend::Native(native) => Some(native.resident_packs()),
            WorkerBackend::Pjrt { .. } => None,
        }
    }
}

/// Everything the worker loop shares with the service handle: the job
/// channel, the in-flight counter, and the metrics sink.
struct WorkerShared {
    rx: Receiver<Msg>,
    depth: Arc<AtomicUsize>,
    metrics: Arc<Mutex<Metrics>>,
    flops_per_job: u64,
    m: usize,
    k: usize,
    n: usize,
    window: Duration,
    deadline: Option<Duration>,
    drain_timeout: Duration,
}

/// Worker-loop state that must survive a panic: jobs pulled off the
/// channel but not yet answered, plus the drain bookkeeping. Lives in
/// the supervisor's frame so an unwound loop iteration cannot strand a
/// job — whatever is still here when a panic is caught gets a typed
/// [`JobError::WorkerPanicked`].
struct WorkerState {
    pending: Vec<Job>,
    stopping: bool,
    drain_until: Option<Instant>,
}

/// The supervisor: runs [`worker_loop`] under `catch_unwind`, and on a
/// caught panic resolves every stranded job with
/// [`JobError::WorkerPanicked`], bumps `Metrics::worker_restarts`,
/// resets the backend's per-batch scratch, and re-enters the loop over
/// the same resident state (the prepacked weight panels survive — pinned
/// by `Metrics::resident_packs`). Returns the worker's total wall time.
fn supervise(mut backend: WorkerBackend, sh: WorkerShared) -> Duration {
    let started = Instant::now();
    if let Some(packs) = backend.resident_packs() {
        lock_unpoisoned(&sh.metrics).resident_packs = packs;
    }
    let mut st = WorkerState {
        pending: Vec::new(),
        stopping: false,
        drain_until: None,
    };
    loop {
        match catch_unwind(AssertUnwindSafe(|| worker_loop(&mut backend, &sh, &mut st))) {
            Ok(()) => break,
            Err(payload) => {
                let detail = panic_detail(payload);
                {
                    let mut mg = lock_unpoisoned(&sh.metrics);
                    mg.worker_restarts += 1;
                    for j in &st.pending {
                        let waited = j.submitted.elapsed();
                        mg.record_error(waited, waited);
                    }
                }
                for j in st.pending.drain(..) {
                    let _ = j.resp.send(Err(JobError::WorkerPanicked {
                        detail: detail.clone(),
                    }));
                    sh.depth.fetch_sub(1, Ordering::SeqCst);
                }
                backend.recover();
                // respawn: re-enter the loop over the same resident backend
            }
        }
    }
    if let Some(packs) = backend.resident_packs() {
        lock_unpoisoned(&sh.metrics).resident_packs = packs;
    }
    started.elapsed()
}

/// Extract a human-readable panic message from a caught unwind payload.
fn panic_detail(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else {
        "worker panicked".to_string()
    }
}

fn worker_loop(backend: &mut WorkerBackend, sh: &WorkerShared, st: &mut WorkerState) {
    loop {
        let cap = backend.batch_cap().max(1);
        if st.pending.is_empty() && !st.stopping {
            // idle: block for the batch's first job — the window must
            // not start (or tick) until it lands
            match sh.rx.recv() {
                Ok(Msg::Job(j)) => st.pending.push(j),
                Ok(Msg::Stop) | Err(_) => st.stopping = true,
            }
        }
        if !st.pending.is_empty() && !st.stopping {
            // the batch window runs from the first job's arrival
            let window_end = Instant::now() + sh.window;
            while st.pending.len() < cap {
                let timeout = window_end.saturating_duration_since(Instant::now());
                if timeout.is_zero() {
                    break;
                }
                match sh.rx.recv_timeout(timeout) {
                    Ok(Msg::Job(j)) => st.pending.push(j),
                    Ok(Msg::Stop) => {
                        st.stopping = true;
                        break;
                    }
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        st.stopping = true;
                        break;
                    }
                }
            }
        }
        if st.stopping && st.pending.is_empty() {
            // graceful drain: accepted jobs may still be in the channel —
            // keep serving until the in-flight count hits zero or the
            // hard drain bound expires
            if drain_done(sh, st) {
                return;
            }
            continue;
        }
        if st.pending.is_empty() {
            continue;
        }
        let dispatch = Instant::now();
        shed_expired(sh, st, dispatch);
        if st.pending.is_empty() {
            continue;
        }
        let take = cap.min(st.pending.len());
        dispatch_batch(backend, sh, st, take, dispatch);
    }
}

/// One drain step while stopping with nothing pending. Returns true when
/// the worker may exit: every accepted job answered (`depth == 0`), the
/// hard bound expired (stragglers resolve [`JobError::Stopped`]), or the
/// channel fully disconnected.
fn drain_done(sh: &WorkerShared, st: &mut WorkerState) -> bool {
    let until = *st
        .drain_until
        .get_or_insert_with(|| Instant::now() + sh.drain_timeout);
    if sh.depth.load(Ordering::SeqCst) == 0 {
        return true;
    }
    let left = until.saturating_duration_since(Instant::now());
    if left.is_zero() {
        // hard bound: whatever is still queued resolves typed, never hangs
        while let Ok(msg) = sh.rx.try_recv() {
            if let Msg::Job(j) = msg {
                let waited = j.submitted.elapsed();
                lock_unpoisoned(&sh.metrics).record_error(waited, waited);
                let _ = j.resp.send(Err(JobError::Stopped));
                sh.depth.fetch_sub(1, Ordering::SeqCst);
            }
        }
        return true;
    }
    // short receive slices so the depth check re-runs promptly: a client
    // that raced admission against stop() may still be mid-send
    match sh.rx.recv_timeout(left.min(Duration::from_millis(2))) {
        Ok(Msg::Job(j)) => st.pending.push(j),
        Ok(Msg::Stop) | Err(RecvTimeoutError::Timeout) => {}
        Err(RecvTimeoutError::Disconnected) => return true,
    }
    false
}

/// Shed every pending job whose queue wait exceeds its **effective**
/// deadline — the job's own submit-time bound when set
/// ([`ServiceClient::submit_with_deadline`]), else the service-wide
/// [`ServiceConfig::deadline`]; jobs with neither never expire. Sheds
/// resolve [`JobError::DeadlineExceeded`], count in `Metrics::timeouts`
/// (the shed side of shed-vs-served), and free the queue slot.
fn shed_expired(sh: &WorkerShared, st: &mut WorkerState, now: Instant) {
    let mut i = 0;
    while i < st.pending.len() {
        let Some(deadline) = st.pending[i].deadline.or(sh.deadline) else {
            i += 1;
            continue;
        };
        let waited = now.saturating_duration_since(st.pending[i].submitted);
        if waited > deadline {
            let j = st.pending.remove(i);
            lock_unpoisoned(&sh.metrics).record_shed(waited, waited);
            let _ = j.resp.send(Err(JobError::DeadlineExceeded { waited, deadline }));
            sh.depth.fetch_sub(1, Ordering::SeqCst);
        } else {
            i += 1;
        }
    }
}

fn dispatch_batch(
    backend: &mut WorkerBackend,
    sh: &WorkerShared,
    st: &mut WorkerState,
    take: usize,
    dispatch: Instant,
) {
    match backend {
        WorkerBackend::Native(native) => dispatch_native(native, sh, st, take, dispatch),
        WorkerBackend::Pjrt {
            engine,
            single,
            batched,
            y,
        } => dispatch_pjrt(engine, single, batched, y, sh, st, take, dispatch),
    }
}

/// Run one native batch with panics contained: an unwind anywhere in the
/// packed engine (including an injected `Pack` fault) comes back as a
/// typed [`JobError::WorkerPanicked`] instead of unwinding the worker.
fn run_native_batch(native: &mut NativeMatmul, xs: &[&[f32]]) -> Result<Vec<Vec<f32>>, JobError> {
    match catch_unwind(AssertUnwindSafe(|| native.run_batch(xs))) {
        Ok(Ok((outs, _col_packs))) => Ok(outs),
        Ok(Err(e)) => Err(e),
        Err(payload) => Err(JobError::WorkerPanicked {
            detail: panic_detail(payload),
        }),
    }
}

/// Native dispatch with the degradation ladder: try the coalesced batch;
/// on failure retry the jobs one at a time (one poisoned job cannot take
/// down its batchmates); a lone job failing twice back-to-back escalates
/// to the supervisor for a worker respawn.
fn dispatch_native(
    native: &mut NativeMatmul,
    sh: &WorkerShared,
    st: &mut WorkerState,
    take: usize,
    dispatch: Instant,
) {
    let waits: Vec<Duration> = st.pending[..take]
        .iter()
        .map(|j| dispatch.saturating_duration_since(j.submitted))
        .collect();
    let attempt = {
        let xs: Vec<&[f32]> = st.pending[..take].iter().map(|j| j.x.as_slice()).collect();
        run_native_batch(native, &xs)
    };
    match attempt {
        Ok(outs) => {
            let batch: Vec<Job> = st.pending.drain(..take).collect();
            let resident = native.resident_packs();
            {
                let mut mg = lock_unpoisoned(&sh.metrics);
                mg.record_batch(take, dispatch.elapsed());
                mg.resident_packs = resident;
                for (j, wait) in batch.iter().zip(&waits) {
                    mg.record_job(j.submitted.elapsed(), *wait, sh.flops_per_job);
                }
            }
            for (j, out) in batch.into_iter().zip(outs) {
                let _ = j.resp.send(Ok(out));
                sh.depth.fetch_sub(1, Ordering::SeqCst);
            }
        }
        Err(first) if take == 1 => {
            // a lone job failed — one contained retry, then escalate:
            // two consecutive failures with no batchmates to blame means
            // the worker itself is suspect
            native.recover();
            lock_unpoisoned(&sh.metrics).retries += 1;
            let retry = {
                let xs: Vec<&[f32]> = st.pending[..1].iter().map(|j| j.x.as_slice()).collect();
                run_native_batch(native, &xs)
            };
            let j = st.pending.remove(0);
            match retry {
                Ok(mut outs) => {
                    let resident = native.resident_packs();
                    {
                        let mut mg = lock_unpoisoned(&sh.metrics);
                        mg.record_batch(1, dispatch.elapsed());
                        mg.resident_packs = resident;
                        mg.record_job(j.submitted.elapsed(), waits[0], sh.flops_per_job);
                    }
                    let _ = j.resp.send(Ok(outs.swap_remove(0)));
                    sh.depth.fetch_sub(1, Ordering::SeqCst);
                }
                Err(second) => {
                    lock_unpoisoned(&sh.metrics).record_error(j.submitted.elapsed(), waits[0]);
                    let _ = j.resp.send(Err(second));
                    sh.depth.fetch_sub(1, Ordering::SeqCst);
                    native.recover();
                    // escalate to the supervisor: respawn the worker
                    resume_unwind(Box::new(format!(
                        "native worker failing repeatedly: {first}"
                    )));
                }
            }
        }
        Err(_) => {
            // the coalesced batch failed — degrade to one job at a time
            // so one poisoned job cannot take down its batchmates
            let batch: Vec<Job> = st.pending.drain(..take).collect();
            native.recover();
            for (j, wait) in batch.into_iter().zip(waits) {
                let t1 = Instant::now();
                lock_unpoisoned(&sh.metrics).retries += 1;
                let r = run_native_batch(native, &[j.x.as_slice()]);
                match r {
                    Ok(mut outs) => {
                        let resident = native.resident_packs();
                        {
                            let mut mg = lock_unpoisoned(&sh.metrics);
                            mg.record_batch(1, t1.elapsed());
                            mg.resident_packs = resident;
                            mg.record_job(j.submitted.elapsed(), wait, sh.flops_per_job);
                        }
                        let _ = j.resp.send(Ok(outs.swap_remove(0)));
                    }
                    Err(e) => {
                        native.recover();
                        lock_unpoisoned(&sh.metrics).record_error(j.submitted.elapsed(), wait);
                        let _ = j.resp.send(Err(e));
                    }
                }
                sh.depth.fetch_sub(1, Ordering::SeqCst);
            }
        }
    }
}

/// PJRT dispatch: batched artifact when shipped and the batch is wide,
/// with a ladder of single-kernel retries if the batched run fails;
/// single-shape kernel otherwise.
#[allow(clippy::too_many_arguments)]
fn dispatch_pjrt(
    engine: &mut Engine,
    single: &str,
    batched: &Option<(String, usize)>,
    y: &[f32],
    sh: &WorkerShared,
    st: &mut WorkerState,
    take: usize,
    dispatch: Instant,
) {
    let waits: Vec<Duration> = st.pending[..take]
        .iter()
        .map(|j| dispatch.saturating_duration_since(j.submitted))
        .collect();
    let batch: Vec<Job> = st.pending.drain(..take).collect();
    if batch.len() > 1 {
        if let Some((name, bcap)) = batched {
            // pad to the full batch with zeros
            let mut xs = vec![0f32; *bcap * sh.m * sh.k];
            for (i, j) in batch.iter().enumerate() {
                xs[i * sh.m * sh.k..(i + 1) * sh.m * sh.k].copy_from_slice(&j.x);
            }
            let run = engine.run_matmul(name, &xs, y);
            lock_unpoisoned(&sh.metrics).record_batch(batch.len(), dispatch.elapsed());
            match run {
                Ok(out) => {
                    for ((i, j), wait) in batch.into_iter().enumerate().zip(waits) {
                        let slice = out[i * sh.m * sh.n..(i + 1) * sh.m * sh.n].to_vec();
                        lock_unpoisoned(&sh.metrics).record_job(
                            j.submitted.elapsed(),
                            wait,
                            sh.flops_per_job,
                        );
                        let _ = j.resp.send(Ok(slice));
                        sh.depth.fetch_sub(1, Ordering::SeqCst);
                    }
                }
                Err(batch_err) => {
                    // degradation ladder: the batched artifact failed —
                    // retry each job through the single-shape kernel
                    // before erroring it
                    let detail = format!("{batch_err:#}");
                    for (j, wait) in batch.into_iter().zip(waits) {
                        let t1 = Instant::now();
                        lock_unpoisoned(&sh.metrics).retries += 1;
                        let r = engine.run_matmul(single, &j.x, y);
                        let mut mg = lock_unpoisoned(&sh.metrics);
                        mg.record_batch(1, t1.elapsed());
                        match r {
                            Ok(out) => {
                                mg.record_job(j.submitted.elapsed(), wait, sh.flops_per_job);
                                drop(mg);
                                let _ = j.resp.send(Ok(out));
                            }
                            Err(e2) => {
                                mg.record_error(j.submitted.elapsed(), wait);
                                drop(mg);
                                let _ = j.resp.send(Err(JobError::Backend {
                                    detail: format!(
                                        "batched: {detail}; single retry: {e2:#}"
                                    ),
                                }));
                            }
                        }
                        sh.depth.fetch_sub(1, Ordering::SeqCst);
                    }
                }
            }
            return;
        }
        // batch_cap() is 1 without a batched artifact, so a multi-job
        // batch can't reach here — but if it ever does, the singles loop
        // below still answers every job
    }
    for (j, wait) in batch.into_iter().zip(waits) {
        let r = engine.run_matmul(single, &j.x, y);
        let mut mg = lock_unpoisoned(&sh.metrics);
        match &r {
            Ok(_) => mg.record_job(j.submitted.elapsed(), wait, sh.flops_per_job),
            Err(_) => mg.record_error(j.submitted.elapsed(), wait),
        }
        drop(mg);
        let _ = j.resp.send(r.map_err(|e| JobError::Backend {
            detail: format!("{e:#}"),
        }));
        sh.depth.fetch_sub(1, Ordering::SeqCst);
    }
    lock_unpoisoned(&sh.metrics).record_batch(take, dispatch.elapsed());
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn rowmajor_matmul(m: usize, k: usize, n: usize, x: &[f32], y: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let xv = x[i * k + kk];
                for j in 0..n {
                    out[i * n + j] += xv * y[kk * n + j];
                }
            }
        }
        out
    }

    fn xorshift_f32(seed: u64) -> impl FnMut() -> f32 {
        let mut s = seed | 1;
        move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            ((s % 1000) as f32 / 1000.0) - 0.5
        }
    }

    fn native_config(m: usize, k: usize, n: usize, window: Duration) -> ServiceConfig {
        ServiceConfig {
            m,
            k,
            n,
            batch_window: window,
            backend: Backend::Native,
            ..ServiceConfig::default()
        }
    }

    fn max_abs_diff(got: &[f32], want: &[f32]) -> f32 {
        got.iter()
            .zip(want)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max)
    }

    #[test]
    fn service_serves_correct_results() {
        if !artifacts_dir().join("manifest.tsv").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let (m, k, n) = (128usize, 128, 128);
        let mut rnd = xorshift_f32(7);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let svc = Service::start(
            &artifacts_dir(),
            y.clone(),
            ServiceConfig {
                m,
                k,
                n,
                batch_window: Duration::from_millis(1),
                ..ServiceConfig::default()
            },
        )
        .unwrap();

        println!("serving with {}", svc.plan().describe());
        let xs: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..m * k).map(|_| rnd()).collect())
            .collect();
        let rxs: Vec<_> = xs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
        for (x, rx) in xs.iter().zip(rxs) {
            let got = rx.recv().unwrap();
            let want = rowmajor_matmul(m, k, n, x, &y);
            let maxd = max_abs_diff(&got, &want);
            assert!(maxd < 1e-2, "serve result off by {maxd}");
        }
        let (metrics, wall) = svc.stop();
        assert_eq!(metrics.jobs, 5);
        assert!(metrics.batches >= 1);
        println!("serve test: {}", metrics.report(wall));
    }

    #[test]
    fn native_backend_serves_f32_matmul_without_artifacts() {
        // the acceptance path: f32 matmul jobs through the packed
        // macro-kernel, no PJRT artifacts anywhere; non-multiple shape so
        // edge register blocks are exercised on the serve path
        let (m, k, n) = (45usize, 33, 52);
        let mut rnd = xorshift_f32(0xA11CE);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let svc = Service::start(
            Path::new("definitely-no-artifacts-here"),
            y.clone(),
            native_config(m, k, n, Duration::from_millis(1)),
        )
        .expect("native service must start without artifacts");
        let plan = svc.plan().clone();
        assert_eq!(plan.dtype, DType::F32, "{}", plan.describe());
        assert!(plan.artifact.contains("packed-engine"), "{}", plan.describe());
        // the served plan carries (and reports) the L3 super-band shape
        // the prepacked engine threads through the coalesced batch GEMM
        assert!(plan.describe().contains("super m3="), "{}", plan.describe());
        assert_eq!(plan.level.m3 % plan.level.mc, 0, "{}", plan.describe());
        assert_eq!(plan.level.n3 % plan.level.nc, 0, "{}", plan.describe());
        let xs: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..m * k).map(|_| rnd()).collect())
            .collect();
        let rxs: Vec<_> = xs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
        for (x, rx) in xs.iter().zip(rxs) {
            let got = rx.recv().unwrap();
            let want = rowmajor_matmul(m, k, n, x, &y);
            assert_eq!(got.len(), want.len());
            let maxd = max_abs_diff(&got, &want);
            assert!(maxd < 1e-3, "native serve result off by {maxd}");
        }
        let (metrics, _) = svc.stop();
        assert_eq!(metrics.jobs, 4);
    }

    #[test]
    fn wide_accumulation_serves_and_tightens_the_error() {
        // --dtype f32acc64 end to end: same f32 job buffers, same plan
        // geometry, f64 register accumulation. The serve results must be
        // correct, the plan must report the mixed mode, and against an
        // all-f64 oracle the wide path must be at least as accurate as
        // the pure-f32 service on the same jobs
        let (m, k, n) = (45usize, 33, 52);
        let mut rnd = xorshift_f32(0xACC5);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let xs: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..m * k).map(|_| rnd()).collect())
            .collect();
        // f64 oracle over the f32 inputs
        let oracle = |x: &[f32]| -> Vec<f64> {
            let mut out = vec![0f64; m * n];
            for i in 0..m {
                for kk in 0..k {
                    let xv = x[i * k + kk] as f64;
                    for j in 0..n {
                        out[i * n + j] += xv * y[kk * n + j] as f64;
                    }
                }
            }
            out
        };
        let serve = |precision: Precision| -> Vec<Vec<f32>> {
            let svc = Service::start(
                Path::new("no-artifacts"),
                y.clone(),
                ServiceConfig {
                    precision,
                    ..native_config(m, k, n, Duration::from_millis(1))
                },
            )
            .unwrap();
            let plan = svc.plan().clone();
            assert_eq!(plan.precision, precision, "{}", plan.describe());
            assert!(
                plan.describe().contains(precision.name()),
                "{}",
                plan.describe()
            );
            let rxs: Vec<_> = xs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
            let outs = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
            svc.stop();
            outs
        };
        let pure = serve(Precision::F32);
        let wide = serve(Precision::F32ACC64);
        let max_err = |outs: &[Vec<f32>]| -> f64 {
            outs.iter()
                .zip(&xs)
                .flat_map(|(got, x)| {
                    let want = oracle(x);
                    got.iter()
                        .zip(want)
                        .map(|(g, w)| (*g as f64 - w).abs())
                        .collect::<Vec<f64>>()
                })
                .fold(0f64, f64::max)
        };
        let (perr, werr) = (max_err(&pure), max_err(&wide));
        assert!(perr < 1e-3, "pure f32 serve off by {perr}");
        assert!(werr < 1e-3, "f32acc64 serve off by {werr}");
        assert!(
            werr <= perr,
            "wide accumulation must not lose accuracy: f32acc64 err {werr} vs f32 err {perr}"
        );
        // rejected combinations fail start() typed, not at dispatch
        assert!(Service::start(
            Path::new("no-artifacts"),
            y.clone(),
            ServiceConfig {
                precision: Precision::F64,
                ..native_config(m, k, n, Duration::from_millis(1))
            },
        )
        .is_err());
        assert!(Service::start(
            Path::new("no-artifacts"),
            y.clone(),
            ServiceConfig {
                precision: Precision::F32ACC64,
                backend: Backend::Pjrt,
                ..native_config(m, k, n, Duration::from_millis(1))
            },
        )
        .is_err());
    }

    #[test]
    fn health_probe_tracks_worker_queue_and_restarts() {
        // the readiness satellite: a fresh service is ready; queued jobs
        // show up as depth; a contained worker panic shows up as a
        // restart with the respawned worker still alive and ready
        let (m, k, n) = (16usize, 12, 20);
        let y: Vec<f32> = vec![0.5; k * n];
        let faults = Faults::seeded(0x41EA)
            .fail_n(FaultPoint::BatchCompute, FaultMode::Panic, 1)
            .build();
        let svc = Service::start(
            Path::new("no-artifacts"),
            y,
            ServiceConfig {
                m,
                k,
                n,
                batch_window: Duration::from_millis(60),
                max_batch: 8,
                backend: Backend::Native,
                faults,
                ..ServiceConfig::default()
            },
        )
        .unwrap();
        let h0 = svc.health();
        assert!(h0.worker_alive && !h0.stopping && h0.ready(), "{h0}");
        assert_eq!(
            (h0.queue_depth, h0.queue_cap, h0.worker_restarts),
            (0, 256, 0)
        );
        let rxs: Vec<_> = (0..3).map(|_| svc.submit(vec![0.5; m * k]).unwrap()).collect();
        let h1 = svc.health();
        assert!(
            (1..=3).contains(&h1.queue_depth),
            "in-flight jobs must show as depth: {h1}"
        );
        for rx in &rxs {
            assert!(matches!(
                rx.recv_timeout(Duration::from_secs(10)),
                Some(Err(JobError::WorkerPanicked { .. }))
            ));
        }
        // the last depth decrement races the receiver resolution — poll
        let deadline = Instant::now() + Duration::from_secs(5);
        while svc.health().queue_depth != 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        let h2 = svc.health();
        assert!(h2.worker_alive, "respawned worker must probe alive: {h2}");
        assert!(h2.ready(), "{h2}");
        assert_eq!(h2.worker_restarts, 1, "{h2}");
        assert_eq!(h2.queue_depth, 0, "{h2}");
        let line = h2.to_string();
        assert!(
            line.contains("worker=alive")
                && line.contains("queue=0/256")
                && line.contains("restarts=1")
                && line.contains("ready=true"),
            "{line}"
        );
        svc.stop();
    }

    #[test]
    fn native_backend_matches_pjrt_differentially() {
        // when artifacts are shipped, the two backends must agree on the
        // existing batching workload — the native engine is the PJRT
        // path's differential baseline and vice versa
        if !artifacts_dir().join("manifest.tsv").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let (m, k, n) = (128usize, 128, 128);
        let mut rnd = xorshift_f32(0xD1FF);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let xs: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..m * k).map(|_| rnd()).collect())
            .collect();
        let mut outs: Vec<Vec<Vec<f32>>> = Vec::new();
        for backend in [Backend::Pjrt, Backend::Native] {
            let svc = Service::start(
                &artifacts_dir(),
                y.clone(),
                ServiceConfig {
                    m,
                    k,
                    n,
                    batch_window: Duration::from_millis(1),
                    backend,
                    ..ServiceConfig::default()
                },
            )
            .unwrap();
            let rxs: Vec<_> = xs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
            outs.push(rxs.into_iter().map(|rx| rx.recv().unwrap()).collect());
            svc.stop();
        }
        for (job, (a, b)) in outs[0].iter().zip(&outs[1]).enumerate() {
            let maxd = max_abs_diff(a, b);
            assert!(maxd < 1e-2, "job {job}: backends disagree by {maxd}");
        }
    }

    #[test]
    fn native_backend_batches_under_load() {
        // a wider window than the submit cadence: the batcher must
        // actually coalesce — strictly fewer dispatches than jobs — and
        // every result stays correct
        let (m, k, n) = (32usize, 24, 40);
        let mut rnd = xorshift_f32(0xBA7C4);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let svc = Service::start(
            Path::new("no-artifacts"),
            y.clone(),
            native_config(m, k, n, Duration::from_millis(50)),
        )
        .unwrap();
        let jobs = 8usize;
        let xs: Vec<Vec<f32>> = (0..jobs)
            .map(|_| (0..m * k).map(|_| rnd()).collect())
            .collect();
        let rxs: Vec<_> = xs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
        for (x, rx) in xs.iter().zip(rxs) {
            let got = rx.recv().unwrap();
            let want = rowmajor_matmul(m, k, n, x, &y);
            let maxd = max_abs_diff(&got, &want);
            assert!(maxd < 1e-3, "batched native result off by {maxd}");
        }
        let (metrics, _) = svc.stop();
        assert_eq!(metrics.jobs, jobs as u64);
        assert!(
            metrics.batches < jobs as u64,
            "a 50ms window over back-to-back submits must coalesce: \
             {} batches for {} jobs",
            metrics.batches,
            jobs
        );
        assert!(metrics.mean_batch_size() > 1.0);
        // the batch-size histogram accounts for every job
        let accounted: u64 = (0..=jobs).map(|s| s as u64 * metrics.batches_of_size(s)).sum();
        assert_eq!(accounted, jobs as u64);
    }

    #[test]
    fn bounded_queue_rejects_overflow_with_typed_error() {
        // capacity 2, a window long enough that the worker is still
        // holding both jobs when the third arrives: the third submit must
        // be rejected at the door, and capacity must free once results
        // are delivered
        let (m, k, n) = (16usize, 12, 20);
        let mut rnd = xorshift_f32(0xCA9);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let svc = Service::start(
            Path::new("no-artifacts"),
            y,
            ServiceConfig {
                m,
                k,
                n,
                batch_window: Duration::from_millis(150),
                max_batch: 16,
                queue_cap: 2,
                backend: Backend::Native,
                ..ServiceConfig::default()
            },
        )
        .unwrap();
        let x = || -> Vec<f32> { vec![0.25; m * k] };
        // wrong shape: typed rejection before any queueing
        let bad = svc.submit(vec![0.0; m * k + 1]);
        assert_eq!(
            bad.err(),
            Some(SubmitError::ShapeMismatch {
                got: m * k + 1,
                want: m * k
            })
        );
        let rx1 = svc.submit(x()).unwrap();
        let rx2 = svc.submit(x()).unwrap();
        let over = svc.submit(x());
        assert_eq!(over.err(), Some(SubmitError::QueueFull { cap: 2 }));
        let msg = SubmitError::QueueFull { cap: 2 }.to_string();
        assert!(msg.contains("capacity 2"), "{msg}");
        // both in-flight jobs complete (the window elapses), freeing
        // capacity for a new submission
        rx1.recv().unwrap();
        rx2.recv().unwrap();
        let rx4 = svc.submit(x()).unwrap();
        rx4.recv().unwrap();
        let (metrics, _) = svc.stop();
        assert_eq!(metrics.jobs, 3, "rejected submissions must not count");
        assert_eq!(metrics.errors, 0);
    }

    #[test]
    fn coalesced_results_bitwise_stable_across_max_batch() {
        // the numerics contract of the widened-GEMM coalescer: the same
        // job set served through max_batch 1, 4 and 16 produces
        // bit-identical f32 results — the kc partition (the only blocking
        // parameter that regroups an output element's reduction) is
        // pinned from the single-job plan at every width
        for (m, k, n) in [(45usize, 33usize, 52usize), (8, 96, 40)] {
            let mut rnd = xorshift_f32(0xB17 + ((m as u64) << 3));
            let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
            let jobs = 6usize;
            let xs: Vec<Vec<f32>> = (0..jobs)
                .map(|_| (0..m * k).map(|_| rnd()).collect())
                .collect();
            let mut per_width: Vec<Vec<Vec<f32>>> = Vec::new();
            for max_batch in [1usize, 4, 16] {
                let svc = Service::start(
                    Path::new("no-artifacts"),
                    y.clone(),
                    ServiceConfig {
                        m,
                        k,
                        n,
                        batch_window: Duration::from_millis(10),
                        max_batch,
                        backend: Backend::Native,
                        ..ServiceConfig::default()
                    },
                )
                .unwrap();
                let rxs: Vec<_> = xs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
                let outs: Vec<Vec<f32>> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
                svc.stop();
                per_width.push(outs);
            }
            // bitwise across widths (Vec<f32> equality is exact)
            assert_eq!(
                per_width[0], per_width[1],
                "{m}x{k}x{n}: max_batch 1 vs 4 differ"
            );
            assert_eq!(
                per_width[1], per_width[2],
                "{m}x{k}x{n}: max_batch 4 vs 16 differ"
            );
            // and correct vs the row-major oracle
            for (x, got) in xs.iter().zip(&per_width[2]) {
                let want = rowmajor_matmul(m, k, n, x, &y);
                let maxd = max_abs_diff(got, &want);
                assert!(maxd < 1e-3, "{m}x{k}x{n}: coalesced result off by {maxd}");
            }
        }
    }

    #[test]
    fn coalesced_batch_pack_discipline() {
        // the amortization the tentpole buys, pinned at the counter
        // level: a B-job batch packs the resident y row panels ZERO times
        // and each x column band exactly once — independent of B
        let (m, k, n) = (5usize, 20, 24);
        let max_batch = 8usize;
        let level = LevelPlan {
            l1_tile: (8, 8, 8),
            mc: 16,
            kc: 9,
            nc: 12,
            m3: 32,
            n3: 24,
        };
        let mut rnd = xorshift_f32(0x9ACC);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let mut native = NativeMatmul::new(
            m,
            k,
            n,
            &y,
            level,
            MicroShape::Mr8Nr4,
            false,
            max_batch,
            1,
            Faults::none(),
        )
        .unwrap();
        // GEMM shape: rows = n = 24 (one super-band at m3 = 32),
        // reduction = k = 20 (ceil(20/9) = 3 kc slices), columns = m·B
        let kslices = 3u64;
        assert_eq!(native.rows.len(), kslices as usize);
        let startup_packs: u64 = native.rows.iter().map(|r| r.pack_count()).sum();
        for b in [3usize, 8, 1, 8] {
            let xs: Vec<Vec<f32>> = (0..b)
                .map(|_| (0..m * k).map(|_| rnd()).collect())
                .collect();
            let views: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();
            let (outs, col_packs) = native.run_batch(&views).unwrap();
            // resident panels: packed zero times per batch
            let now: u64 = native.rows.iter().map(|r| r.pack_count()).sum();
            assert_eq!(now, startup_packs, "batch B={b} repacked resident y panels");
            // each x column band packed exactly once: one pack per
            // (kc slice, nc band over the used prefix)
            let n_used = (m * b) as u64;
            let nc_bands: u64 = (0..n_used)
                .step_by(24)
                .map(|j3| (n_used - j3).min(24).div_ceil(12))
                .sum();
            assert_eq!(col_packs, kslices * nc_bands, "B={b}");
            for (x, got) in xs.iter().zip(&outs) {
                let want = rowmajor_matmul(m, k, n, x, &y);
                let maxd = max_abs_diff(got, &want);
                assert!(maxd < 1e-3, "B={b}: batch result off by {maxd}");
            }
        }
    }

    #[test]
    fn many_clients_load_test_reports_percentiles_and_split() {
        // the synthetic many-client load test: concurrent client threads
        // hammer one service through cloned handles; every result checks
        // against the oracle and the metrics report carries exact
        // percentiles plus the queue-wait vs compute attribution and the
        // shed-vs-served robustness counters
        let (m, k, n) = (32usize, 24, 40);
        let clients = 4usize;
        let per_client = 16usize;
        let mut rnd = xorshift_f32(0x10AD);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let svc = Service::start(
            Path::new("no-artifacts"),
            y.clone(),
            ServiceConfig {
                m,
                k,
                n,
                batch_window: Duration::from_millis(1),
                max_batch: 8,
                queue_cap: 512,
                backend: Backend::Native,
                ..ServiceConfig::default()
            },
        )
        .unwrap();
        std::thread::scope(|scope| {
            for c in 0..clients {
                let client = svc.client();
                let y = &y;
                scope.spawn(move || {
                    let mut rnd = xorshift_f32(0xC11E47 + c as u64);
                    for _ in 0..per_client {
                        let x: Vec<f32> = (0..m * k).map(|_| rnd()).collect();
                        let rx = client.submit(x.clone()).unwrap();
                        let got = rx.recv().unwrap();
                        let want = rowmajor_matmul(m, k, n, &x, y);
                        let maxd = max_abs_diff(&got, &want);
                        assert!(maxd < 1e-3, "client {c}: result off by {maxd}");
                    }
                });
            }
        });
        let (metrics, wall) = svc.stop();
        let jobs = (clients * per_client) as u64;
        assert_eq!(metrics.jobs, jobs);
        assert_eq!(metrics.errors, 0);
        assert_eq!(metrics.served(), jobs);
        assert!(!metrics.worker_poisoned);
        assert!(metrics.compute > Duration::ZERO);
        assert!(metrics.percentile_us(0.99) >= metrics.percentile_us(0.50));
        // the histogram accounts for every job, none above the cap
        let accounted: u64 = (0..=8).map(|s| s as u64 * metrics.batches_of_size(s)).sum();
        assert_eq!(accounted, jobs);
        let report = metrics.report(wall);
        for needle in [
            "p50=",
            "p99=",
            "queue-wait=",
            "compute=",
            "mean-batch=",
            "served=64",
            "shed=0",
            "timeouts=0",
            "retries=0",
            "restarts=0",
            "fallback-plans=0",
        ] {
            assert!(report.contains(needle), "report missing {needle}: {report}");
        }
        println!("load test: {report}");
    }

    #[test]
    fn worker_panic_resolves_all_inflight_receivers() {
        // the client-hang regression test: a panic mid-batch with several
        // jobs in flight must resolve EVERY receiver with a typed error
        // within the drain window — never strand a client on recv()
        let (m, k, n) = (16usize, 12, 20);
        let mut rnd = xorshift_f32(0xBAD);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let faults = Faults::seeded(0xFA11)
            .fail(FaultPoint::BatchCompute, FaultMode::Panic, 1, 1)
            .build();
        let svc = Service::start(
            Path::new("no-artifacts"),
            y,
            ServiceConfig {
                m,
                k,
                n,
                batch_window: Duration::from_millis(40),
                max_batch: 8,
                backend: Backend::Native,
                faults,
                ..ServiceConfig::default()
            },
        )
        .unwrap();
        let rxs: Vec<_> = (0..5).map(|_| svc.submit(vec![0.5; m * k]).unwrap()).collect();
        for (i, rx) in rxs.iter().enumerate() {
            match rx.recv_timeout(Duration::from_secs(10)) {
                Some(Err(JobError::WorkerPanicked { detail })) => {
                    assert!(detail.contains("BatchCompute"), "job {i}: {detail}");
                }
                Some(other) => panic!("job {i}: expected WorkerPanicked, got {other:?}"),
                None => panic!("job {i}: receiver hung — the client-hang bug is back"),
            }
        }
        let (metrics, _) = svc.stop();
        assert_eq!(metrics.jobs, 5);
        assert_eq!(metrics.errors, 5);
        assert_eq!(metrics.served(), 0);
        assert!(!metrics.worker_poisoned, "supervisor must keep the worker joinable");
    }

    #[test]
    fn single_job_panic_escalates_and_respawns_worker() {
        // a lone job panicking twice escalates to the supervisor; the
        // respawned worker keeps serving over the SAME resident prepacked
        // weight panels (pinned by resident_packs)
        let (m, k, n) = (16usize, 12, 20);
        let mut rnd = xorshift_f32(0x5EED);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let mk_cfg = |faults: Faults| ServiceConfig {
            m,
            k,
            n,
            batch_window: Duration::from_millis(1),
            max_batch: 1,
            backend: Backend::Native,
            faults,
            ..ServiceConfig::default()
        };
        let faults = Faults::seeded(0x0DD)
            .fail_n(FaultPoint::BatchCompute, FaultMode::Panic, 2)
            .build();
        let svc = Service::start(Path::new("no-artifacts"), y.clone(), mk_cfg(faults)).unwrap();
        let rx = svc.submit(vec![0.5; m * k]).unwrap();
        match rx.recv_timeout(Duration::from_secs(10)) {
            Some(Err(JobError::WorkerPanicked { .. })) => {}
            other => panic!("expected WorkerPanicked, got {other:?}"),
        }
        // the respawned worker serves correctly (faults exhausted)
        for _ in 0..3 {
            let x: Vec<f32> = (0..m * k).map(|_| rnd()).collect();
            let rx = svc.submit(x.clone()).unwrap();
            let got = rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
            let want = rowmajor_matmul(m, k, n, &x, &y);
            let maxd = max_abs_diff(&got, &want);
            assert!(maxd < 1e-3, "post-respawn result off by {maxd}");
        }
        let (metrics, _) = svc.stop();
        assert_eq!(metrics.worker_restarts, 1);
        assert_eq!(metrics.jobs, 4);
        assert_eq!(metrics.errors, 1);
        assert_eq!(metrics.retries, 1, "one contained retry before escalation");
        assert!(metrics.resident_packs > 0);
        // pack discipline across the respawn: identical to a fault-free
        // service of the same shape — the panels were never repacked
        let clean = Service::start(Path::new("no-artifacts"), y, mk_cfg(Faults::none())).unwrap();
        let (clean_metrics, _) = clean.stop();
        assert_eq!(metrics.resident_packs, clean_metrics.resident_packs);
    }

    #[test]
    fn deadline_sheds_stale_jobs_with_typed_error() {
        // a deadline far shorter than the batch window: every job's queue
        // wait exceeds it by dispatch time, so all are shed before
        // compute — typed, counted as timeouts, NOT as errors
        let (m, k, n) = (16usize, 12, 20);
        let y: Vec<f32> = vec![0.5; k * n];
        let deadline = Duration::from_millis(1);
        let svc = Service::start(
            Path::new("no-artifacts"),
            y,
            ServiceConfig {
                m,
                k,
                n,
                batch_window: Duration::from_millis(120),
                max_batch: 16,
                backend: Backend::Native,
                deadline: Some(deadline),
                ..ServiceConfig::default()
            },
        )
        .unwrap();
        let rxs: Vec<_> = (0..3).map(|_| svc.submit(vec![0.5; m * k]).unwrap()).collect();
        for (i, rx) in rxs.iter().enumerate() {
            match rx.recv_timeout(Duration::from_secs(10)) {
                Some(Err(JobError::DeadlineExceeded { waited, deadline: dl })) => {
                    assert!(waited >= deadline, "job {i}: waited {waited:?}");
                    assert_eq!(dl, deadline);
                }
                other => panic!("job {i}: expected DeadlineExceeded, got {other:?}"),
            }
        }
        let (metrics, wall) = svc.stop();
        assert_eq!(metrics.jobs, 3);
        assert_eq!(metrics.timeouts, 3);
        assert_eq!(metrics.errors, 0, "shed jobs are timeouts, not errors");
        assert_eq!(metrics.served(), 0);
        assert!(metrics.report(wall).contains("timeouts=3"));
    }

    #[test]
    fn per_job_deadline_overrides_service_deadline() {
        // no service-wide deadline: a job submitted through
        // submit_with_deadline still sheds on its own bound while a plain
        // submit in the same batch is served — and the shed counts under
        // the same timeouts metric as a service-wide shed
        let (m, k, n) = (16usize, 12, 20);
        let mut rnd = xorshift_f32(0x0D1D);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let svc = Service::start(
            Path::new("no-artifacts"),
            y.clone(),
            ServiceConfig {
                m,
                k,
                n,
                batch_window: Duration::from_millis(120),
                max_batch: 16,
                backend: Backend::Native,
                deadline: None,
                ..ServiceConfig::default()
            },
        )
        .unwrap();
        let client = svc.client();
        let tight = Duration::from_millis(1);
        let x: Vec<f32> = (0..m * k).map(|_| rnd()).collect();
        let doomed = client
            .submit_with_deadline(vec![0.5; m * k], tight)
            .unwrap();
        let served = svc.submit(x.clone()).unwrap();
        match doomed.recv_timeout(Duration::from_secs(10)) {
            Some(Err(JobError::DeadlineExceeded { waited, deadline })) => {
                assert!(waited >= tight, "waited {waited:?}");
                assert_eq!(deadline, tight, "the job's own bound must be reported");
            }
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        let got = served
            .recv_timeout(Duration::from_secs(10))
            .expect("undeadlined job must resolve")
            .expect("undeadlined job must be served");
        let want = rowmajor_matmul(m, k, n, &x, &y);
        assert!(max_abs_diff(&got, &want) < 1e-3);
        let (metrics, _) = svc.stop();
        assert_eq!(metrics.jobs, 2);
        assert_eq!(metrics.timeouts, 1, "per-job shed counts as a timeout");
        assert_eq!(metrics.errors, 0);
        assert_eq!(metrics.served(), 1);
    }

    #[test]
    fn per_job_deadline_can_outlive_service_deadline() {
        // the override works in the loose direction too: with a 1ms
        // service-wide deadline and a long batch window, a plain job
        // sheds but a generous per-job deadline keeps its job alive
        // through the same dispatch boundary
        let (m, k, n) = (16usize, 12, 20);
        let mut rnd = xorshift_f32(0x5EAD);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let svc = Service::start(
            Path::new("no-artifacts"),
            y.clone(),
            ServiceConfig {
                m,
                k,
                n,
                batch_window: Duration::from_millis(120),
                max_batch: 16,
                backend: Backend::Native,
                deadline: Some(Duration::from_millis(1)),
                ..ServiceConfig::default()
            },
        )
        .unwrap();
        let client = svc.client();
        let x: Vec<f32> = (0..m * k).map(|_| rnd()).collect();
        let patient = client
            .submit_with_deadline(x.clone(), Duration::from_secs(60))
            .unwrap();
        let doomed = svc.submit(vec![0.5; m * k]).unwrap();
        assert!(matches!(
            doomed.recv_timeout(Duration::from_secs(10)),
            Some(Err(JobError::DeadlineExceeded { .. }))
        ));
        let got = patient
            .recv_timeout(Duration::from_secs(10))
            .expect("patient job must resolve")
            .expect("patient job must be served");
        let want = rowmajor_matmul(m, k, n, &x, &y);
        assert!(max_abs_diff(&got, &want) < 1e-3);
        let (metrics, _) = svc.stop();
        assert_eq!((metrics.jobs, metrics.timeouts, metrics.served()), (2, 1, 1));
    }

    #[test]
    fn stop_drains_queued_jobs_and_rejects_new_submissions() {
        // graceful shutdown: jobs accepted before stop() are finished
        // (not dropped), submissions after stop() are rejected typed
        let (m, k, n) = (16usize, 12, 20);
        let mut rnd = xorshift_f32(0xD2A1);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let svc = Service::start(
            Path::new("no-artifacts"),
            y.clone(),
            ServiceConfig {
                m,
                k,
                n,
                batch_window: Duration::from_millis(250),
                max_batch: 4,
                backend: Backend::Native,
                ..ServiceConfig::default()
            },
        )
        .unwrap();
        let client = svc.client();
        let xs: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..m * k).map(|_| rnd()).collect())
            .collect();
        let rxs: Vec<_> = xs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
        // stop immediately: the worker is still inside the 250ms batch
        // window holding all four jobs — the drain must finish them
        let (metrics, _) = svc.stop();
        for (x, rx) in xs.iter().zip(rxs) {
            let got = rx
                .recv_timeout(Duration::from_secs(10))
                .expect("drained job must resolve")
                .expect("drained job must succeed");
            let want = rowmajor_matmul(m, k, n, x, &y);
            let maxd = max_abs_diff(&got, &want);
            assert!(maxd < 1e-3, "drained result off by {maxd}");
        }
        assert_eq!(metrics.jobs, 4);
        assert_eq!(metrics.errors, 0);
        assert_eq!(metrics.timeouts, 0);
        // new work after stop: typed rejection from both entry points
        assert_eq!(
            client.submit(vec![0.5; m * k]).err(),
            Some(SubmitError::Stopped)
        );
        assert_eq!(
            client
                .submit_with_retry(vec![0.5; m * k], 4, Duration::from_micros(10))
                .err(),
            Some(SubmitError::Stopped),
            "Stopped must not be retried"
        );
    }

    #[test]
    fn submit_with_retry_heals_transient_queue_full() {
        // three consecutive injected QueueFull rejections: a plain submit
        // fails typed, submit_with_retry backs off and lands the job
        let (m, k, n) = (16usize, 12, 20);
        let mut rnd = xorshift_f32(0x9F);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let faults = Faults::seeded(0x0F11)
            .fail_n(FaultPoint::QueueAccept, FaultMode::Error, 3)
            .build();
        let svc = Service::start(
            Path::new("no-artifacts"),
            y.clone(),
            ServiceConfig {
                m,
                k,
                n,
                batch_window: Duration::from_millis(1),
                backend: Backend::Native,
                faults,
                ..ServiceConfig::default()
            },
        )
        .unwrap();
        let client = svc.client();
        let x: Vec<f32> = (0..m * k).map(|_| rnd()).collect();
        // fault 1 of 3: the plain path surfaces the overload typed
        assert_eq!(
            client.submit(x.clone()).err(),
            Some(SubmitError::QueueFull { cap: 256 })
        );
        // faults 2..3 then success: the retry path heals it
        let rx = client
            .submit_with_retry(x.clone(), 8, Duration::from_micros(50))
            .expect("retry must outlast 2 remaining injected rejections");
        let got = rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        let want = rowmajor_matmul(m, k, n, &x, &y);
        let maxd = max_abs_diff(&got, &want);
        assert!(maxd < 1e-3, "retried result off by {maxd}");
        let (metrics, _) = svc.stop();
        assert_eq!(metrics.jobs, 1);
        assert_eq!(metrics.retries, 2);
    }

    #[test]
    fn batch_failure_retries_jobs_one_at_a_time() {
        // the degradation ladder: one injected batch-level error; every
        // job in the failed batch is retried singly and still succeeds
        let (m, k, n) = (16usize, 12, 20);
        let mut rnd = xorshift_f32(0x1ADD);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let faults = Faults::seeded(0xEBB)
            .fail_n(FaultPoint::BatchCompute, FaultMode::Error, 1)
            .build();
        let svc = Service::start(
            Path::new("no-artifacts"),
            y.clone(),
            ServiceConfig {
                m,
                k,
                n,
                batch_window: Duration::from_millis(40),
                max_batch: 8,
                backend: Backend::Native,
                faults,
                ..ServiceConfig::default()
            },
        )
        .unwrap();
        let xs: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..m * k).map(|_| rnd()).collect())
            .collect();
        let rxs: Vec<_> = xs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
        for (x, rx) in xs.iter().zip(rxs) {
            let got = rx
                .recv_timeout(Duration::from_secs(10))
                .expect("job must resolve")
                .expect("ladder must serve every job despite the batch fault");
            let want = rowmajor_matmul(m, k, n, x, &y);
            let maxd = max_abs_diff(&got, &want);
            assert!(maxd < 1e-3, "laddered result off by {maxd}");
        }
        let (metrics, _) = svc.stop();
        assert_eq!(metrics.jobs, 5);
        assert_eq!(metrics.errors, 0);
        assert!(metrics.retries >= 1, "the failed batch must have retried");
    }

    #[test]
    fn planner_fault_degrades_to_flat_plan_and_serves() {
        // both startup plans (single-job and wide) panic inside the
        // planner: start() must not fail — it degrades to the
        // parameter-free flat plan and still serves correct results
        let (m, k, n) = (45usize, 33, 52);
        let mut rnd = xorshift_f32(0xF1A7);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let faults = Faults::seeded(0xFA11BACC)
            .fail_n(FaultPoint::Plan, FaultMode::Panic, 2)
            .build();
        let svc = Service::start(
            Path::new("no-artifacts"),
            y.clone(),
            ServiceConfig {
                m,
                k,
                n,
                batch_window: Duration::from_millis(1),
                backend: Backend::Native,
                faults,
                ..ServiceConfig::default()
            },
        )
        .expect("planner faults must degrade, not fail start()");
        let plan = svc.plan().clone();
        assert!(plan.plan_name.contains("fallback"), "{}", plan.plan_name);
        assert_eq!(
            (plan.level.mc, plan.level.kc, plan.level.nc),
            (64, 64, 48),
            "flat fallback geometry"
        );
        for _ in 0..3 {
            let x: Vec<f32> = (0..m * k).map(|_| rnd()).collect();
            let rx = svc.submit(x.clone()).unwrap();
            let got = rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
            let want = rowmajor_matmul(m, k, n, &x, &y);
            let maxd = max_abs_diff(&got, &want);
            assert!(maxd < 1e-3, "fallback-plan result off by {maxd}");
        }
        let (metrics, wall) = svc.stop();
        assert_eq!(metrics.fallback_plans, 2);
        assert!(metrics.report(wall).contains("fallback-plans=2"));
    }
}
