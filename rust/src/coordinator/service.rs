//! The serving coordinator: job queue → dynamic batcher → PJRT dispatch.
//!
//! One [`Service`] hosts one weight matrix `y` (k×n) and serves matmul
//! jobs `x·y` for m×k left operands, the way an inference router serves a
//! fixed model. Jobs are accumulated for up to a batching window and
//! dispatched through the vmapped batched artifact when possible (padding
//! partial batches with zeros), falling back to the single-shape kernel.
//! Python is never involved: the executables were AOT-compiled by
//! `make artifacts`.

use std::path::Path;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::cache::CacheSpec;
use crate::codegen::autotune;
use crate::runtime::{ArtifactKind, Engine, Registry};

use super::metrics::Metrics;
use super::planner::{Plan, Planner};

struct Job {
    x: Vec<f32>,
    resp: Sender<Result<Vec<f32>>>,
    submitted: Instant,
}

enum Msg {
    Job(Job),
    Stop,
}

/// Handle to a running coordinator thread.
pub struct Service {
    tx: Sender<Msg>,
    handle: std::thread::JoinHandle<(Metrics, Duration)>,
    m: usize,
    k: usize,
    n: usize,
    plan: Plan,
}

impl Service {
    /// The served output shape (m, n) per job.
    pub fn output_shape(&self) -> (usize, usize) {
        (self.m, self.n)
    }

    /// The plan chosen for the served shape — carries the two-level
    /// `mc×kc×nc` macro-block decision and the autotuned register-tile
    /// width alongside the L1 tile (report with [`Plan::describe`]).
    pub fn plan(&self) -> &Plan {
        &self.plan
    }
}

/// Configuration for [`Service::start`].
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// How long the batcher waits to fill a batch.
    pub batch_window: Duration,
    /// Cache spec the planner models (tile selection).
    pub spec: CacheSpec,
}

impl Default for ServiceConfig {
    fn default() -> ServiceConfig {
        ServiceConfig {
            m: 128,
            k: 128,
            n: 128,
            batch_window: Duration::from_millis(2),
            spec: CacheSpec::HASWELL_L1D,
        }
    }
}

impl Service {
    /// Start the coordinator: loads the registry, plans the shape, warms
    /// the chosen executables, spawns the worker thread that owns the
    /// PJRT engine.
    pub fn start(artifact_dir: &Path, y: Vec<f32>, cfg: ServiceConfig) -> Result<Service> {
        let mut registry = Registry::load(artifact_dir)?;
        // one-shot startup autotune (ROADMAP): record the winning
        // register-tile shape; 8×4 stays the compile-time default
        registry.set_micro_shape(autotune::calibrate(2_000));
        anyhow::ensure!(
            y.len() == cfg.k * cfg.n,
            "y must be k×n = {}",
            cfg.k * cfg.n
        );
        let mut planner = Planner::new(cfg.spec);
        let plan = planner.plan(&registry, cfg.m, cfg.k, cfg.n);
        let single = registry
            .by_name(&plan.artifact)
            .with_context(|| format!("planned artifact {} missing", plan.artifact))?
            .name
            .clone();
        // batched variant with the same problem shape, if shipped
        let batched = registry
            .artifacts()
            .iter()
            .find(|a| {
                a.kind == ArtifactKind::PallasTiledMatmulBatched
                    && a.m == cfg.m
                    && a.k == cfg.k
                    && a.n == cfg.n
            })
            .map(|a| (a.name.clone(), a.batch));

        let (tx, rx) = channel::<Msg>();
        let m = cfg.m;
        let k = cfg.k;
        let n = cfg.n;
        let window = cfg.batch_window;
        let handle = std::thread::spawn(move || {
            let mut engine = Engine::new(registry).expect("pjrt engine");
            engine.prepare(&single).expect("prepare single artifact");
            if let Some((name, _)) = &batched {
                engine.prepare(name).expect("prepare batched artifact");
            }
            worker_loop(&mut engine, rx, y, m, k, n, single, batched, window)
        });
        Ok(Service {
            tx,
            handle,
            m,
            k,
            n,
            plan,
        })
    }

    /// Submit a job; returns the receiver for the m×n row-major result.
    pub fn submit(&self, x: Vec<f32>) -> Result<Receiver<Result<Vec<f32>>>> {
        anyhow::ensure!(x.len() == self.m * self.k, "x must be m×k");
        let (rtx, rrx) = channel();
        self.tx
            .send(Msg::Job(Job {
                x,
                resp: rtx,
                submitted: Instant::now(),
            }))
            .map_err(|_| anyhow::anyhow!("service stopped"))?;
        Ok(rrx)
    }

    /// Stop and collect metrics (+ total wall time of the worker).
    pub fn stop(self) -> (Metrics, Duration) {
        let _ = self.tx.send(Msg::Stop);
        self.handle.join().expect("worker panicked")
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    engine: &mut Engine,
    rx: Receiver<Msg>,
    y: Vec<f32>,
    m: usize,
    k: usize,
    n: usize,
    single: String,
    batched: Option<(String, usize)>,
    window: Duration,
) -> (Metrics, Duration) {
    let started = Instant::now();
    let mut metrics = Metrics::new();
    let flops_per_job = (2 * m * k * n) as u64;
    let mut pending: Vec<Job> = Vec::new();
    let mut stopping = false;

    while !stopping || !pending.is_empty() {
        // fill the batch within the window
        let cap = batched.as_ref().map(|(_, b)| *b).unwrap_or(1);
        let deadline = Instant::now() + window;
        while !stopping && pending.len() < cap {
            let timeout = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(timeout) {
                Ok(Msg::Job(j)) => pending.push(j),
                Ok(Msg::Stop) => stopping = true,
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    stopping = true;
                    break;
                }
            }
            if pending.len() == 1 && window.is_zero() {
                break;
            }
        }
        if pending.is_empty() {
            if stopping {
                break;
            }
            // idle: block for the next message
            match rx.recv() {
                Ok(Msg::Job(j)) => pending.push(j),
                Ok(Msg::Stop) | Err(_) => stopping = true,
            }
            continue;
        }

        metrics.record_batch();
        let batch = std::mem::take(&mut pending);
        match (&batched, batch.len()) {
            (Some((name, cap)), len) if len > 1 => {
                // pad to the full batch with zeros
                let mut xs = vec![0f32; cap * m * k];
                for (i, j) in batch.iter().enumerate() {
                    xs[i * m * k..(i + 1) * m * k].copy_from_slice(&j.x);
                }
                match engine.run_matmul(name, &xs, &y) {
                    Ok(out) => {
                        for (i, j) in batch.into_iter().enumerate() {
                            let slice = out[i * m * n..(i + 1) * m * n].to_vec();
                            metrics.record_job(j.submitted.elapsed(), flops_per_job);
                            let _ = j.resp.send(Ok(slice));
                        }
                    }
                    Err(e) => {
                        for j in batch {
                            let _ = j.resp.send(Err(anyhow::anyhow!("{e:#}")));
                        }
                    }
                }
            }
            _ => {
                for j in batch {
                    let r = engine.run_matmul(&single, &j.x, &y);
                    metrics.record_job(j.submitted.elapsed(), flops_per_job);
                    let _ = j.resp.send(r);
                }
            }
        }
    }
    (metrics, started.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn rowmajor_matmul(m: usize, k: usize, n: usize, x: &[f32], y: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let xv = x[i * k + kk];
                for j in 0..n {
                    out[i * n + j] += xv * y[kk * n + j];
                }
            }
        }
        out
    }

    #[test]
    fn service_serves_correct_results() {
        if !artifacts_dir().join("manifest.tsv").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let (m, k, n) = (128usize, 128, 128);
        let mut s = 7u64;
        let mut rnd = move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            ((s % 1000) as f32 / 1000.0) - 0.5
        };
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let svc = Service::start(
            &artifacts_dir(),
            y.clone(),
            ServiceConfig {
                m,
                k,
                n,
                batch_window: Duration::from_millis(1),
                spec: CacheSpec::HASWELL_L1D,
            },
        )
        .unwrap();

        println!("serving with {}", svc.plan().describe());
        let xs: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..m * k).map(|_| rnd()).collect())
            .collect();
        let rxs: Vec<_> = xs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
        for (x, rx) in xs.iter().zip(rxs) {
            let got = rx.recv().unwrap().unwrap();
            let want = rowmajor_matmul(m, k, n, x, &y);
            let maxd = got
                .iter()
                .zip(&want)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(maxd < 1e-2, "serve result off by {maxd}");
        }
        let (metrics, wall) = svc.stop();
        assert_eq!(metrics.jobs, 5);
        assert!(metrics.batches >= 1);
        println!("serve test: {}", metrics.report(wall));
    }
}
