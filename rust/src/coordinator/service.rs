//! The serving coordinator: bounded job queue → coalescing batcher →
//! backend dispatch.
//!
//! One [`Service`] hosts one weight matrix `y` (k×n) and serves matmul
//! jobs `x·y` for m×k left operands, the way an inference router serves
//! a fixed model. The front is a **bounded async queue with admission
//! control**: at most `queue_cap` jobs may be in flight (accepted but
//! not yet answered), and an over-capacity [`submit`] is rejected
//! immediately with [`SubmitError::QueueFull`] instead of buffering
//! without limit — under overload the caller finds out at the door, not
//! by timeout. Clone [`Service::client`] handles into as many threads as
//! you like; they share the same queue and the same capacity.
//!
//! Accepted jobs coalesce into batches. The **batch window starts when
//! the first job of a batch arrives** (idle time never consumes it), and
//! a batch closes at `max_batch` jobs or when the window elapses,
//! whichever is first. Only shape-compatible jobs coalesce — one service
//! serves one (m, k, n), and [`submit`] rejects any other `x` length
//! with [`SubmitError::ShapeMismatch`] before it can reach a batch.
//!
//! Batches dispatch through one of two backends:
//!
//! * [`Backend::Pjrt`] — the AOT-compiled JAX/Pallas artifacts via PJRT
//!   (vmapped batched variant when shipped, padding partial batches with
//!   zeros; single-shape kernel otherwise).
//! * [`Backend::Native`] — the in-process **f32 packed macro-kernel**,
//!   which executes a B-job batch as **one widened GEMM**. The transpose
//!   lowering makes coalescing free: each job's `x` (row-major m×k) is
//!   bit-identically the column-major k×m operand `C = xᵀ`, so B jobs
//!   written side by side are the k×(m·B) operand of the same GEMM with
//!   its column axis widened from m to m·B — no layout copies beyond the
//!   per-job `copy_from_slice` already paid, and the startup-prepacked
//!   `y` row panels plus each `kc` step's column bands are streamed once
//!   **per batch** instead of once per job. Partial batches run the
//!   column prefix `[0, B·m)` of the `max_batch`-wide plan
//!   ([`run_macro_prepacked_cols`]); batches whose widened shape spans
//!   several L3 super-bands can route through the parallel super-band
//!   scheduler ([`run_parallel_macro_prepacked`]) with the resident row
//!   panels shared read-only across workers.
//!
//! Either way the worker thread runs a one-shot startup autotune per
//! dtype and records the winners in the registry, so plans report the
//! register-tile shape the engine actually dispatches. [`Metrics`]
//! attributes each job's latency into queue wait (submit → batch
//! dispatch) and compute, with exact reservoir p50/p99 and a batch-size
//! histogram.
//!
//! [`submit`]: Service::submit
//! [`run_macro_prepacked_cols`]: crate::codegen::run_macro_prepacked_cols
//! [`run_parallel_macro_prepacked`]: crate::codegen::run_parallel_macro_prepacked

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::cache::CacheSpec;
use crate::codegen::executor::{pack_row_slices, run_macro_prepacked_cols, super_band_extents};
use crate::codegen::parallel::run_parallel_macro_prepacked;
use crate::codegen::{
    autotune, kernel_views, DType, GemmForm, KernelBuffers, MicroShape, PackedCols, PackedRows,
    RunPlan,
};
use crate::domain::{ops, Kernel};
use crate::runtime::{ArtifactKind, Engine, Registry};
use crate::tiling::LevelPlan;

use super::metrics::Metrics;
use super::planner::{Plan, Planner};

/// Which execution engine serves the jobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Backend {
    /// AOT PJRT artifacts (requires `make artifacts`).
    #[default]
    Pjrt,
    /// The in-process f32 packed macro-kernel (no artifacts needed).
    Native,
}

/// Typed admission-control rejection from [`Service::submit`] /
/// [`ServiceClient::submit`]. Rejections happen before the job enters
/// the queue — a rejected job consumes no capacity and no worker time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded queue already holds `cap` in-flight jobs.
    QueueFull { cap: usize },
    /// `x` does not match the served m×k shape — it could never coalesce
    /// with this service's batches.
    ShapeMismatch { got: usize, want: usize },
    /// The worker is gone (the service was stopped).
    Stopped,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { cap } => {
                write!(f, "submission queue full (capacity {cap})")
            }
            SubmitError::ShapeMismatch { got, want } => {
                write!(f, "x has {got} elements, served shape needs {want}")
            }
            SubmitError::Stopped => write!(f, "service stopped"),
        }
    }
}

impl std::error::Error for SubmitError {}

struct Job {
    x: Vec<f32>,
    resp: Sender<Result<Vec<f32>>>,
    submitted: Instant,
}

enum Msg {
    Job(Job),
    Stop,
}

/// Receiver for one submitted job's m×n row-major result.
pub type ResultReceiver = Receiver<Result<Vec<f32>>>;

/// Handle to a running coordinator thread.
pub struct Service {
    tx: Sender<Msg>,
    depth: Arc<AtomicUsize>,
    queue_cap: usize,
    handle: std::thread::JoinHandle<(Metrics, Duration)>,
    m: usize,
    k: usize,
    n: usize,
    plan: Plan,
}

/// A cloneable submission handle onto a running [`Service`] — hand one
/// to each client thread. Clones share the service's queue and its
/// admission capacity.
#[derive(Clone)]
pub struct ServiceClient {
    tx: Sender<Msg>,
    depth: Arc<AtomicUsize>,
    queue_cap: usize,
    m: usize,
    k: usize,
}

fn admit_and_send(
    tx: &Sender<Msg>,
    depth: &AtomicUsize,
    cap: usize,
    want: usize,
    x: Vec<f32>,
) -> Result<ResultReceiver, SubmitError> {
    if x.len() != want {
        return Err(SubmitError::ShapeMismatch { got: x.len(), want });
    }
    // in-flight accounting: a slot is held from here until the worker
    // has *answered* the job, so capacity bounds queued and executing
    // work together
    if depth.fetch_add(1, Ordering::SeqCst) >= cap {
        depth.fetch_sub(1, Ordering::SeqCst);
        return Err(SubmitError::QueueFull { cap });
    }
    let (rtx, rrx) = channel();
    let job = Job {
        x,
        resp: rtx,
        submitted: Instant::now(),
    };
    if tx.send(Msg::Job(job)).is_err() {
        depth.fetch_sub(1, Ordering::SeqCst);
        return Err(SubmitError::Stopped);
    }
    Ok(rrx)
}

impl ServiceClient {
    /// Submit a job; returns the receiver for the m×n row-major result,
    /// or a typed rejection if the queue is full / the shape is wrong.
    pub fn submit(&self, x: Vec<f32>) -> Result<ResultReceiver, SubmitError> {
        admit_and_send(&self.tx, &self.depth, self.queue_cap, self.m * self.k, x)
    }
}

impl Service {
    /// The served output shape (m, n) per job.
    pub fn output_shape(&self) -> (usize, usize) {
        (self.m, self.n)
    }

    /// The plan chosen for the served shape — carries the dtype, the
    /// two-level `mc×kc×nc` macro-block decision and the per-dtype
    /// autotuned register-tile width alongside the L1 tile (report with
    /// [`Plan::describe`]).
    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// A cloneable submission handle for client threads.
    pub fn client(&self) -> ServiceClient {
        ServiceClient {
            tx: self.tx.clone(),
            depth: self.depth.clone(),
            queue_cap: self.queue_cap,
            m: self.m,
            k: self.k,
        }
    }
}

/// Configuration for [`Service::start`].
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// How long the batcher waits to fill a batch, measured from the
    /// arrival of the batch's first job.
    pub batch_window: Duration,
    /// Most jobs one dispatch may coalesce (the native backend plans its
    /// widened GEMM for exactly this width at startup; the PJRT backend
    /// is capped by the shipped batched artifact instead).
    pub max_batch: usize,
    /// Most in-flight jobs (accepted, not yet answered) before
    /// [`Service::submit`] rejects with [`SubmitError::QueueFull`].
    pub queue_cap: usize,
    /// Worker threads for the native backend's batch GEMM: batches whose
    /// widened shape spans several L3 super-bands route through the
    /// parallel super-band scheduler. 1 = always serial.
    pub threads: usize,
    /// Cache spec the planner models (tile selection).
    pub spec: CacheSpec,
    /// Execution engine: PJRT artifacts or the native packed kernel.
    pub backend: Backend,
}

impl Default for ServiceConfig {
    fn default() -> ServiceConfig {
        ServiceConfig {
            m: 128,
            k: 128,
            n: 128,
            batch_window: Duration::from_millis(2),
            max_batch: 8,
            queue_cap: 256,
            threads: 1,
            spec: CacheSpec::HASWELL_L1D,
            backend: Backend::Pjrt,
        }
    }
}

/// The serve level for a coalescing-width plan pair: row/reduction-side
/// blocking (`l1_tile`, `mc`, `kc`, `m3`) pinned from the single-job
/// plan, column-side geometry (`nc`, `n3`) from the `max_batch`-wide
/// plan. The split is what makes results **bitwise independent of
/// `max_batch`**: the microkernel accumulates each `kc` reduction slice
/// in registers and adds the slice sums in ascending-`k0` order, so the
/// `kc` partition is the only blocking parameter that changes an output
/// element's floating-point grouping — `mc`/`m3`/`l1` only regroup which
/// elements run together and `nc`/`n3` only partition the widened column
/// axis. Pinning the whole row/reduction side to the width-independent
/// single-job plan keeps every element's accumulation order fixed while
/// the column side still scales its bands to the widened batch extent.
fn serving_level(job: &LevelPlan, wide: &LevelPlan) -> LevelPlan {
    LevelPlan {
        l1_tile: job.l1_tile,
        mc: job.mc,
        kc: job.kc,
        m3: job.m3,
        nc: wide.nc,
        n3: wide.n3,
    }
}

impl Service {
    /// Start the coordinator: loads the registry (optional for the
    /// native backend), plans the shape at the serving dtype (f32), warms
    /// the chosen executables, spawns the worker thread that owns the
    /// engine.
    pub fn start(artifact_dir: &Path, y: Vec<f32>, cfg: ServiceConfig) -> Result<Service> {
        let mut registry = match cfg.backend {
            Backend::Pjrt => Registry::load(artifact_dir)?,
            // the native engine needs no artifacts; keep whatever loads
            // so mixed deployments can still resolve PJRT names
            Backend::Native => Registry::load(artifact_dir).unwrap_or_default(),
        };
        // one-shot startup autotune (ROADMAP), per dtype: record each
        // precision's winning register-tile width class; the narrow shape
        // stays the compile-time default
        registry.set_micro_shape_for(DType::F64, autotune::calibrate_dtype::<f64>(2_000));
        registry.set_micro_shape_for(DType::F32, autotune::calibrate_dtype::<f32>(2_000));
        anyhow::ensure!(
            y.len() == cfg.k * cfg.n,
            "y must be k×n = {}",
            cfg.k * cfg.n
        );
        let planner = Planner::new(cfg.spec);
        let (tx, rx) = channel::<Msg>();
        let depth = Arc::new(AtomicUsize::new(0));
        let m = cfg.m;
        let k = cfg.k;
        let n = cfg.n;
        let window = cfg.batch_window;
        let queue_cap = cfg.queue_cap.max(1);
        let worker_depth = depth.clone();
        let (plan, handle) = match cfg.backend {
            Backend::Pjrt => {
                // the PJRT artifacts compute in f32 — plan at f32 so the
                // model sees the true elements-per-line
                let plan = planner.plan(&registry, m, k, n, DType::F32);
                let single = registry
                    .by_name(&plan.artifact)
                    .with_context(|| format!("planned artifact {} missing", plan.artifact))?
                    .name
                    .clone();
                // batched variant with the same problem shape, if shipped
                let batched = registry
                    .artifacts()
                    .iter()
                    .find(|a| {
                        a.kind == ArtifactKind::PallasTiledMatmulBatched
                            && a.m == m
                            && a.k == k
                            && a.n == n
                    })
                    .map(|a| (a.name.clone(), a.batch));
                let handle = std::thread::spawn(move || {
                    let mut engine = Engine::new(registry).expect("pjrt engine");
                    engine.prepare(&single).expect("prepare single artifact");
                    if let Some((name, _)) = &batched {
                        engine.prepare(name).expect("prepare batched artifact");
                    }
                    let backend = WorkerBackend::Pjrt {
                        engine,
                        single,
                        batched,
                        y,
                    };
                    worker_loop(backend, rx, worker_depth, m, k, n, window)
                });
                (plan, handle)
            }
            Backend::Native => {
                let max_batch = cfg.max_batch.max(1);
                let threads = cfg.threads.max(1);
                // plan the kernel the native engine actually executes —
                // the f32 column-major transpose lowering — twice: once at
                // the single-job width (the numerics anchor) and once at
                // the full coalescing width m·max_batch (the geometry the
                // resident arena is laid out for); see `serving_level`
                let job_plan = planner.plan_kernel(&registry, &NativeMatmul::kernel_for(m, k, n));
                let wide_kernel = NativeMatmul::kernel_for(m * max_batch, k, n);
                let wide_plan = planner.plan_kernel(&registry, &wide_kernel);
                let level = serving_level(&job_plan.level, &wide_plan.level);
                let mut plan = job_plan;
                plan.level = level;
                // the executed kernel is the transpose lowering (GEMM rows
                // = serve columns); surface the serve shape and the
                // coalescing width so plan lines are readable next to the
                // PJRT backend's
                plan.plan_name = format!(
                    "{} (serving {m}x{k}x{n} via transpose, coalescing <= {max_batch})",
                    plan.plan_name
                );
                let micro = plan.micro;
                let handle = std::thread::spawn(move || {
                    let native = NativeMatmul::new(m, k, n, &y, level, micro, max_batch, threads);
                    let backend = WorkerBackend::Native(Box::new(native));
                    worker_loop(backend, rx, worker_depth, m, k, n, window)
                });
                (plan, handle)
            }
        };
        Ok(Service {
            tx,
            depth,
            queue_cap,
            handle,
            m,
            k,
            n,
            plan,
        })
    }

    /// Submit a job; returns the receiver for the m×n row-major result,
    /// or a typed rejection if the bounded queue is at capacity / the
    /// shape is wrong.
    pub fn submit(&self, x: Vec<f32>) -> Result<ResultReceiver, SubmitError> {
        admit_and_send(&self.tx, &self.depth, self.queue_cap, self.m * self.k, x)
    }

    /// Stop and collect metrics (+ total wall time of the worker).
    pub fn stop(self) -> (Metrics, Duration) {
        let _ = self.tx.send(Msg::Stop);
        self.handle.join().expect("worker panicked")
    }
}

/// The f32 packed-macro-kernel serve engine, planned for a coalesced
/// batch: one resident [`KernelBuffers<f32>`] arena laid out for the
/// `max_batch`-wide GEMM, holding `y` — whose row panels really are
/// packed once, at startup ([`pack_row_slices`]) — and up to `max_batch`
/// jobs' `x` operands side by side.
///
/// Row-major serving lowers onto the column-major engine via the
/// transpose identity `(x·y)ᵀ = yᵀ·xᵀ`: the kernel computes the
/// column-major product `A(n×m·B) = B(n×k)·C(k×m·B)`, and the row-major
/// buffers are *bit-identical* reinterpretations — `y` row-major k×n is
/// exactly `B = yᵀ` column-major n×k, each job's `x` row-major m×k is
/// exactly an m-column block of `C` column-major, and the output table
/// read in layout order is the batch's row-major m×n results
/// concatenated. No transposition copies anywhere, so coalescing B jobs
/// is *free*: the batch is one GEMM whose column axis widened from m to
/// m·B, and a partial batch executes the column prefix `[0, B·m)` of the
/// same plan ([`run_macro_prepacked_cols`] — the per-column offset
/// tables make the prefix exactly the narrower GEMM). Per batch only the
/// `x` column bands are packed; the weight panels are reused as-is, and
/// when the widened shape spans several L3 super-bands and `threads > 1`
/// the batch routes through [`run_parallel_macro_prepacked`] with those
/// resident panels shared read-only across workers.
struct NativeMatmul {
    /// The `max_batch`-wide kernel (the parallel path re-checks its
    /// output map is injective before sharing the arena across workers).
    kernel: Kernel,
    plan: RunPlan,
    level: LevelPlan,
    micro: MicroShape,
    bufs: KernelBuffers<f32>,
    /// `y`'s row panels, one [`PackedRows`] per reduction slice — packed
    /// once at startup, shared by every batch (`y` never changes).
    rows: Vec<PackedRows<f32>>,
    cols: PackedCols<f32>,
    m: usize,
    k: usize,
    n: usize,
    max_batch: usize,
    threads: usize,
}

impl NativeMatmul {
    /// The f32 kernel the native backend executes for an m×k×n serve
    /// shape (see the type docs for the transpose lowering) — pass
    /// `m·max_batch` as `m` for the coalesced-batch kernel.
    fn kernel_for(m: usize, k: usize, n: usize) -> Kernel {
        ops::matmul(n as i64, k as i64, m as i64, DType::F32.elem(), 0)
    }

    #[allow(clippy::too_many_arguments)]
    fn new(
        m: usize,
        k: usize,
        n: usize,
        y: &[f32],
        level: LevelPlan,
        micro: MicroShape,
        max_batch: usize,
        threads: usize,
    ) -> NativeMatmul {
        let max_batch = max_batch.max(1);
        let kernel = NativeMatmul::kernel_for(m * max_batch, k, n);
        let mut bufs = KernelBuffers::<f32>::from_kernel(&kernel);
        // operand 1 is B = yᵀ (n×k column-major) — the same linear bytes
        // as y (k×n row-major)
        bufs.operand_mut(1).copy_from_slice(y);
        let gf = GemmForm::of(&kernel).expect("matmul is GEMM-form");
        let lo = vec![0i64; kernel.n_free()];
        let plan = gf.plan_box(&kernel_views(&kernel), &lo, kernel.extents());
        // y is resident for the service's lifetime: pack its row panels
        // exactly once, here — they depend only on rows × reduction, so
        // one set serves every batch width
        let rows = pack_row_slices(&bufs.arena, &plan, &level);
        NativeMatmul {
            kernel,
            plan,
            level,
            micro,
            bufs,
            rows,
            cols: PackedCols::new(),
            m,
            k,
            n,
            max_batch,
            threads,
        }
    }

    /// Serve a coalesced batch as one widened GEMM: load the jobs' `x`
    /// operands side by side, zero the output, run the column prefix
    /// `[0, B·m)` over the pre-packed weight panels (parallel across L3
    /// super-bands when configured and profitable), slice the output per
    /// job in row-major order. Returns the per-job results and the
    /// number of column-band packs the batch performed (the resident row
    /// panels are packed zero times here — test-pinned).
    fn run_batch(&mut self, xs: &[&[f32]]) -> (Vec<Vec<f32>>, u64) {
        let b = xs.len();
        assert!((1..=self.max_batch).contains(&b), "batch exceeds planned width");
        self.bufs.reset_output();
        let job = self.m * self.k;
        let op2 = self.bufs.operand_mut(2);
        for (i, x) in xs.iter().enumerate() {
            op2[i * job..(i + 1) * job].copy_from_slice(x);
        }
        let n_used = self.m * b;
        let (m3, n3) = super_band_extents(&self.level);
        let grid = self.plan.m.div_ceil(m3) * n_used.div_ceil(n3);
        let col_packs = if self.threads > 1 && grid > 1 {
            run_parallel_macro_prepacked(
                &mut self.bufs.arena,
                &self.kernel,
                &self.plan,
                &self.level,
                self.micro,
                &self.rows,
                self.threads,
                n_used,
            )
            .col_band_packs
        } else {
            run_macro_prepacked_cols(
                &mut self.bufs.arena,
                &self.plan,
                &self.level,
                self.micro,
                &self.rows,
                &mut self.cols,
                n_used,
            )
        };
        let out = self.bufs.output();
        let per = self.m * self.n;
        let outs = (0..b).map(|i| out[i * per..(i + 1) * per].to_vec()).collect();
        (outs, col_packs)
    }
}

enum WorkerBackend {
    Pjrt {
        engine: Engine,
        single: String,
        batched: Option<(String, usize)>,
        y: Vec<f32>,
    },
    Native(Box<NativeMatmul>),
}

impl WorkerBackend {
    /// How many jobs one dispatch can carry.
    fn batch_cap(&self) -> usize {
        match self {
            WorkerBackend::Pjrt {
                batched: Some((_, b)),
                ..
            } => *b,
            WorkerBackend::Pjrt { .. } => 1,
            WorkerBackend::Native(native) => native.max_batch,
        }
    }
}

fn worker_loop(
    mut backend: WorkerBackend,
    rx: Receiver<Msg>,
    depth: Arc<AtomicUsize>,
    m: usize,
    k: usize,
    n: usize,
    window: Duration,
) -> (Metrics, Duration) {
    let started = Instant::now();
    let mut metrics = Metrics::new();
    let flops_per_job = (2 * m * k * n) as u64;
    let mut pending: Vec<Job> = Vec::new();
    let mut stopping = false;

    while !stopping || !pending.is_empty() {
        let cap = backend.batch_cap();
        if pending.is_empty() && !stopping {
            // idle: block for the batch's first job — the window must
            // not start (or tick) until it lands
            match rx.recv() {
                Ok(Msg::Job(j)) => pending.push(j),
                Ok(Msg::Stop) | Err(_) => stopping = true,
            }
        }
        if !pending.is_empty() && !stopping {
            // the batch window runs from the first job's arrival
            let deadline = Instant::now() + window;
            while pending.len() < cap {
                let timeout = deadline.saturating_duration_since(Instant::now());
                if timeout.is_zero() {
                    break;
                }
                match rx.recv_timeout(timeout) {
                    Ok(Msg::Job(j)) => pending.push(j),
                    Ok(Msg::Stop) => {
                        stopping = true;
                        break;
                    }
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        stopping = true;
                        break;
                    }
                }
            }
        }
        if pending.is_empty() {
            continue;
        }

        let take = cap.min(pending.len());
        let batch: Vec<Job> = pending.drain(..take).collect();
        let dispatch = Instant::now();
        let waits: Vec<Duration> = batch
            .iter()
            .map(|j| dispatch.saturating_duration_since(j.submitted))
            .collect();
        match &mut backend {
            WorkerBackend::Native(native) => {
                let xs: Vec<&[f32]> = batch.iter().map(|j| j.x.as_slice()).collect();
                let (outs, _col_packs) = native.run_batch(&xs);
                metrics.record_batch(batch.len(), dispatch.elapsed());
                for ((j, out), wait) in batch.into_iter().zip(outs).zip(waits) {
                    metrics.record_job(j.submitted.elapsed(), wait, flops_per_job);
                    let _ = j.resp.send(Ok(out));
                    depth.fetch_sub(1, Ordering::SeqCst);
                }
            }
            WorkerBackend::Pjrt {
                engine,
                single,
                batched,
                y,
            } => {
                if batch.len() > 1 {
                    let (name, bcap) = batched
                        .as_ref()
                        .expect("multi-job batch without a batched artifact");
                    // pad to the full batch with zeros
                    let mut xs = vec![0f32; *bcap * m * k];
                    for (i, j) in batch.iter().enumerate() {
                        xs[i * m * k..(i + 1) * m * k].copy_from_slice(&j.x);
                    }
                    let run = engine.run_matmul(name, &xs, y);
                    metrics.record_batch(batch.len(), dispatch.elapsed());
                    match run {
                        Ok(out) => {
                            for ((i, j), wait) in batch.into_iter().enumerate().zip(waits) {
                                let slice = out[i * m * n..(i + 1) * m * n].to_vec();
                                metrics.record_job(j.submitted.elapsed(), wait, flops_per_job);
                                let _ = j.resp.send(Ok(slice));
                                depth.fetch_sub(1, Ordering::SeqCst);
                            }
                        }
                        Err(e) => {
                            // failed jobs still count: they held queue
                            // capacity and worker time, and hiding them
                            // would overstate the service's health
                            for (j, wait) in batch.into_iter().zip(waits) {
                                metrics.record_error(j.submitted.elapsed(), wait);
                                let _ = j.resp.send(Err(anyhow::anyhow!("{e:#}")));
                                depth.fetch_sub(1, Ordering::SeqCst);
                            }
                        }
                    }
                } else {
                    for (j, wait) in batch.into_iter().zip(waits) {
                        let r = engine.run_matmul(single, &j.x, y);
                        match &r {
                            Ok(_) => metrics.record_job(j.submitted.elapsed(), wait, flops_per_job),
                            Err(_) => metrics.record_error(j.submitted.elapsed(), wait),
                        }
                        let _ = j.resp.send(r);
                        depth.fetch_sub(1, Ordering::SeqCst);
                    }
                    metrics.record_batch(take, dispatch.elapsed());
                }
            }
        }
    }
    (metrics, started.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn rowmajor_matmul(m: usize, k: usize, n: usize, x: &[f32], y: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let xv = x[i * k + kk];
                for j in 0..n {
                    out[i * n + j] += xv * y[kk * n + j];
                }
            }
        }
        out
    }

    fn xorshift_f32(seed: u64) -> impl FnMut() -> f32 {
        let mut s = seed | 1;
        move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            ((s % 1000) as f32 / 1000.0) - 0.5
        }
    }

    fn native_config(m: usize, k: usize, n: usize, window: Duration) -> ServiceConfig {
        ServiceConfig {
            m,
            k,
            n,
            batch_window: window,
            backend: Backend::Native,
            ..ServiceConfig::default()
        }
    }

    #[test]
    fn service_serves_correct_results() {
        if !artifacts_dir().join("manifest.tsv").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let (m, k, n) = (128usize, 128, 128);
        let mut rnd = xorshift_f32(7);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let svc = Service::start(
            &artifacts_dir(),
            y.clone(),
            ServiceConfig {
                m,
                k,
                n,
                batch_window: Duration::from_millis(1),
                ..ServiceConfig::default()
            },
        )
        .unwrap();

        println!("serving with {}", svc.plan().describe());
        let xs: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..m * k).map(|_| rnd()).collect())
            .collect();
        let rxs: Vec<_> = xs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
        for (x, rx) in xs.iter().zip(rxs) {
            let got = rx.recv().unwrap().unwrap();
            let want = rowmajor_matmul(m, k, n, x, &y);
            let maxd = got
                .iter()
                .zip(&want)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(maxd < 1e-2, "serve result off by {maxd}");
        }
        let (metrics, wall) = svc.stop();
        assert_eq!(metrics.jobs, 5);
        assert!(metrics.batches >= 1);
        println!("serve test: {}", metrics.report(wall));
    }

    #[test]
    fn native_backend_serves_f32_matmul_without_artifacts() {
        // the acceptance path: f32 matmul jobs through the packed
        // macro-kernel, no PJRT artifacts anywhere; non-multiple shape so
        // edge register blocks are exercised on the serve path
        let (m, k, n) = (45usize, 33, 52);
        let mut rnd = xorshift_f32(0xA11CE);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let svc = Service::start(
            Path::new("definitely-no-artifacts-here"),
            y.clone(),
            native_config(m, k, n, Duration::from_millis(1)),
        )
        .expect("native service must start without artifacts");
        let plan = svc.plan().clone();
        assert_eq!(plan.dtype, DType::F32, "{}", plan.describe());
        assert!(plan.artifact.contains("packed-engine"), "{}", plan.describe());
        // the served plan carries (and reports) the L3 super-band shape
        // the prepacked engine threads through the coalesced batch GEMM
        assert!(plan.describe().contains("super m3="), "{}", plan.describe());
        assert_eq!(plan.level.m3 % plan.level.mc, 0, "{}", plan.describe());
        assert_eq!(plan.level.n3 % plan.level.nc, 0, "{}", plan.describe());
        let xs: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..m * k).map(|_| rnd()).collect())
            .collect();
        let rxs: Vec<_> = xs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
        for (x, rx) in xs.iter().zip(rxs) {
            let got = rx.recv().unwrap().unwrap();
            let want = rowmajor_matmul(m, k, n, x, &y);
            assert_eq!(got.len(), want.len());
            let maxd = got
                .iter()
                .zip(&want)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(maxd < 1e-3, "native serve result off by {maxd}");
        }
        let (metrics, _) = svc.stop();
        assert_eq!(metrics.jobs, 4);
    }

    #[test]
    fn native_backend_matches_pjrt_differentially() {
        // when artifacts are shipped, the two backends must agree on the
        // existing batching workload — the native engine is the PJRT
        // path's differential baseline and vice versa
        if !artifacts_dir().join("manifest.tsv").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let (m, k, n) = (128usize, 128, 128);
        let mut rnd = xorshift_f32(0xD1FF);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let xs: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..m * k).map(|_| rnd()).collect())
            .collect();
        let mut outs: Vec<Vec<Vec<f32>>> = Vec::new();
        for backend in [Backend::Pjrt, Backend::Native] {
            let svc = Service::start(
                &artifacts_dir(),
                y.clone(),
                ServiceConfig {
                    m,
                    k,
                    n,
                    batch_window: Duration::from_millis(1),
                    backend,
                    ..ServiceConfig::default()
                },
            )
            .unwrap();
            let rxs: Vec<_> = xs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
            outs.push(rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect());
            svc.stop();
        }
        for (job, (a, b)) in outs[0].iter().zip(&outs[1]).enumerate() {
            let maxd = a
                .iter()
                .zip(b)
                .map(|(x, y)| (x - y).abs())
                .fold(0f32, f32::max);
            assert!(maxd < 1e-2, "job {job}: backends disagree by {maxd}");
        }
    }

    #[test]
    fn native_backend_batches_under_load() {
        // a wider window than the submit cadence: the batcher must
        // actually coalesce — strictly fewer dispatches than jobs — and
        // every result stays correct
        let (m, k, n) = (32usize, 24, 40);
        let mut rnd = xorshift_f32(0xBA7C4);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let svc = Service::start(
            Path::new("no-artifacts"),
            y.clone(),
            native_config(m, k, n, Duration::from_millis(50)),
        )
        .unwrap();
        let jobs = 8usize;
        let xs: Vec<Vec<f32>> = (0..jobs)
            .map(|_| (0..m * k).map(|_| rnd()).collect())
            .collect();
        let rxs: Vec<_> = xs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
        for (x, rx) in xs.iter().zip(rxs) {
            let got = rx.recv().unwrap().unwrap();
            let want = rowmajor_matmul(m, k, n, x, &y);
            let maxd = got
                .iter()
                .zip(&want)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(maxd < 1e-3, "batched native result off by {maxd}");
        }
        let (metrics, _) = svc.stop();
        assert_eq!(metrics.jobs, jobs as u64);
        assert!(
            metrics.batches < jobs as u64,
            "a 50ms window over back-to-back submits must coalesce: \
             {} batches for {} jobs",
            metrics.batches,
            jobs
        );
        assert!(metrics.mean_batch_size() > 1.0);
        // the batch-size histogram accounts for every job
        let accounted: u64 = (0..=jobs).map(|s| s as u64 * metrics.batches_of_size(s)).sum();
        assert_eq!(accounted, jobs as u64);
    }

    #[test]
    fn bounded_queue_rejects_overflow_with_typed_error() {
        // capacity 2, a window long enough that the worker is still
        // holding both jobs when the third arrives: the third submit must
        // be rejected at the door, and capacity must free once results
        // are delivered
        let (m, k, n) = (16usize, 12, 20);
        let mut rnd = xorshift_f32(0xCA9);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let svc = Service::start(
            Path::new("no-artifacts"),
            y,
            ServiceConfig {
                m,
                k,
                n,
                batch_window: Duration::from_millis(150),
                max_batch: 16,
                queue_cap: 2,
                backend: Backend::Native,
                ..ServiceConfig::default()
            },
        )
        .unwrap();
        let x = || -> Vec<f32> { vec![0.25; m * k] };
        // wrong shape: typed rejection before any queueing
        let bad = svc.submit(vec![0.0; m * k + 1]);
        assert_eq!(
            bad.err(),
            Some(SubmitError::ShapeMismatch {
                got: m * k + 1,
                want: m * k
            })
        );
        let rx1 = svc.submit(x()).unwrap();
        let rx2 = svc.submit(x()).unwrap();
        let over = svc.submit(x());
        assert_eq!(over.err(), Some(SubmitError::QueueFull { cap: 2 }));
        let msg = SubmitError::QueueFull { cap: 2 }.to_string();
        assert!(msg.contains("capacity 2"), "{msg}");
        // both in-flight jobs complete (the window elapses), freeing
        // capacity for a new submission
        rx1.recv().unwrap().unwrap();
        rx2.recv().unwrap().unwrap();
        let rx4 = svc.submit(x()).unwrap();
        rx4.recv().unwrap().unwrap();
        let (metrics, _) = svc.stop();
        assert_eq!(metrics.jobs, 3, "rejected submissions must not count");
        assert_eq!(metrics.errors, 0);
    }

    #[test]
    fn coalesced_results_bitwise_stable_across_max_batch() {
        // the numerics contract of the widened-GEMM coalescer: the same
        // job set served through max_batch 1, 4 and 16 produces
        // bit-identical f32 results — the kc partition (the only blocking
        // parameter that regroups an output element's reduction) is
        // pinned from the single-job plan at every width
        for (m, k, n) in [(45usize, 33usize, 52usize), (8, 96, 40)] {
            let mut rnd = xorshift_f32(0xB17 + ((m as u64) << 3));
            let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
            let jobs = 6usize;
            let xs: Vec<Vec<f32>> = (0..jobs)
                .map(|_| (0..m * k).map(|_| rnd()).collect())
                .collect();
            let mut per_width: Vec<Vec<Vec<f32>>> = Vec::new();
            for max_batch in [1usize, 4, 16] {
                let svc = Service::start(
                    Path::new("no-artifacts"),
                    y.clone(),
                    ServiceConfig {
                        m,
                        k,
                        n,
                        batch_window: Duration::from_millis(10),
                        max_batch,
                        backend: Backend::Native,
                        ..ServiceConfig::default()
                    },
                )
                .unwrap();
                let rxs: Vec<_> = xs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
                let outs: Vec<Vec<f32>> =
                    rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
                svc.stop();
                per_width.push(outs);
            }
            // bitwise across widths (Vec<f32> equality is exact)
            assert_eq!(
                per_width[0], per_width[1],
                "{m}x{k}x{n}: max_batch 1 vs 4 differ"
            );
            assert_eq!(
                per_width[1], per_width[2],
                "{m}x{k}x{n}: max_batch 4 vs 16 differ"
            );
            // and correct vs the row-major oracle
            for (x, got) in xs.iter().zip(&per_width[2]) {
                let want = rowmajor_matmul(m, k, n, x, &y);
                let maxd = got
                    .iter()
                    .zip(&want)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0f32, f32::max);
                assert!(maxd < 1e-3, "{m}x{k}x{n}: coalesced result off by {maxd}");
            }
        }
    }

    #[test]
    fn coalesced_batch_pack_discipline() {
        // the amortization the tentpole buys, pinned at the counter
        // level: a B-job batch packs the resident y row panels ZERO times
        // and each x column band exactly once — independent of B
        let (m, k, n) = (5usize, 20, 24);
        let max_batch = 8usize;
        let level = LevelPlan {
            l1_tile: (8, 8, 8),
            mc: 16,
            kc: 9,
            nc: 12,
            m3: 32,
            n3: 24,
        };
        let mut rnd = xorshift_f32(0x9ACC);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let mut native = NativeMatmul::new(m, k, n, &y, level, MicroShape::Mr8Nr4, max_batch, 1);
        // GEMM shape: rows = n = 24 (one super-band at m3 = 32),
        // reduction = k = 20 (ceil(20/9) = 3 kc slices), columns = m·B
        let kslices = 3u64;
        assert_eq!(native.rows.len(), kslices as usize);
        let startup_packs: u64 = native.rows.iter().map(|r| r.pack_count()).sum();
        for b in [3usize, 8, 1, 8] {
            let xs: Vec<Vec<f32>> = (0..b)
                .map(|_| (0..m * k).map(|_| rnd()).collect())
                .collect();
            let views: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();
            let (outs, col_packs) = native.run_batch(&views);
            // resident panels: packed zero times per batch
            let now: u64 = native.rows.iter().map(|r| r.pack_count()).sum();
            assert_eq!(now, startup_packs, "batch B={b} repacked resident y panels");
            // each x column band packed exactly once: one pack per
            // (kc slice, nc band over the used prefix)
            let n_used = (m * b) as u64;
            let nc_bands: u64 = (0..n_used)
                .step_by(24)
                .map(|j3| (n_used - j3).min(24).div_ceil(12))
                .sum();
            assert_eq!(col_packs, kslices * nc_bands, "B={b}");
            for (x, got) in xs.iter().zip(&outs) {
                let want = rowmajor_matmul(m, k, n, x, &y);
                let maxd = got
                    .iter()
                    .zip(&want)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0f32, f32::max);
                assert!(maxd < 1e-3, "B={b}: batch result off by {maxd}");
            }
        }
    }

    #[test]
    fn many_clients_load_test_reports_percentiles_and_split() {
        // the synthetic many-client load test: concurrent client threads
        // hammer one service through cloned handles; every result checks
        // against the oracle and the metrics report carries exact
        // percentiles plus the queue-wait vs compute attribution
        let (m, k, n) = (32usize, 24, 40);
        let clients = 4usize;
        let per_client = 16usize;
        let mut rnd = xorshift_f32(0x10AD);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let svc = Service::start(
            Path::new("no-artifacts"),
            y.clone(),
            ServiceConfig {
                m,
                k,
                n,
                batch_window: Duration::from_millis(1),
                max_batch: 8,
                queue_cap: 512,
                backend: Backend::Native,
                ..ServiceConfig::default()
            },
        )
        .unwrap();
        std::thread::scope(|scope| {
            for c in 0..clients {
                let client = svc.client();
                let y = &y;
                scope.spawn(move || {
                    let mut rnd = xorshift_f32(0xC11E47 + c as u64);
                    for _ in 0..per_client {
                        let x: Vec<f32> = (0..m * k).map(|_| rnd()).collect();
                        let rx = client.submit(x.clone()).unwrap();
                        let got = rx.recv().unwrap().unwrap();
                        let want = rowmajor_matmul(m, k, n, &x, y);
                        let maxd = got
                            .iter()
                            .zip(&want)
                            .map(|(a, b)| (a - b).abs())
                            .fold(0f32, f32::max);
                        assert!(maxd < 1e-3, "client {c}: result off by {maxd}");
                    }
                });
            }
        });
        let (metrics, wall) = svc.stop();
        let jobs = (clients * per_client) as u64;
        assert_eq!(metrics.jobs, jobs);
        assert_eq!(metrics.errors, 0);
        assert!(metrics.compute > Duration::ZERO);
        assert!(metrics.percentile_us(0.99) >= metrics.percentile_us(0.50));
        // the histogram accounts for every job, none above the cap
        let accounted: u64 = (0..=8).map(|s| s as u64 * metrics.batches_of_size(s)).sum();
        assert_eq!(accounted, jobs);
        let report = metrics.report(wall);
        for needle in ["p50=", "p99=", "queue-wait=", "compute=", "mean-batch="] {
            assert!(report.contains(needle), "report missing {needle}: {report}");
        }
        println!("load test: {report}");
    }
}
