//! The serving coordinator: job queue → dynamic batcher → backend
//! dispatch.
//!
//! One [`Service`] hosts one weight matrix `y` (k×n) and serves matmul
//! jobs `x·y` for m×k left operands, the way an inference router serves a
//! fixed model. Jobs are accumulated for up to a batching window and
//! dispatched through one of two backends:
//!
//! * [`Backend::Pjrt`] — the AOT-compiled JAX/Pallas artifacts via PJRT
//!   (vmapped batched variant when shipped, padding partial batches with
//!   zeros; single-shape kernel otherwise). Python is never involved: the
//!   executables were AOT-compiled by `make artifacts`.
//! * [`Backend::Native`] — the in-process **f32 packed macro-kernel**:
//!   the engine that serves every Table-1 kernel now serves the f32
//!   request path directly, with a plan whose element size, macro
//!   footprint and register-tile width were all selected *for f32*
//!   ([`Planner::plan_kernel`] on a 4-byte-element kernel). Needs no
//!   artifacts, and doubles as the differential baseline against the
//!   PJRT path.
//!
//! Either way the worker thread runs a one-shot startup autotune per
//! dtype and records the winners in the registry, so plans report the
//! register-tile shape the engine actually dispatches.

use std::path::Path;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::cache::CacheSpec;
use crate::codegen::executor::{pack_row_slices, run_macro_prepacked};
use crate::codegen::{
    autotune, kernel_views, DType, GemmForm, KernelBuffers, MicroShape, PackedCols, PackedRows,
    RunPlan,
};
use crate::domain::ops;
use crate::runtime::{ArtifactKind, Engine, Registry};
use crate::tiling::LevelPlan;

use super::metrics::Metrics;
use super::planner::{Plan, Planner};

/// Which execution engine serves the jobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Backend {
    /// AOT PJRT artifacts (requires `make artifacts`).
    #[default]
    Pjrt,
    /// The in-process f32 packed macro-kernel (no artifacts needed).
    Native,
}

struct Job {
    x: Vec<f32>,
    resp: Sender<Result<Vec<f32>>>,
    submitted: Instant,
}

enum Msg {
    Job(Job),
    Stop,
}

/// Handle to a running coordinator thread.
pub struct Service {
    tx: Sender<Msg>,
    handle: std::thread::JoinHandle<(Metrics, Duration)>,
    m: usize,
    k: usize,
    n: usize,
    plan: Plan,
}

impl Service {
    /// The served output shape (m, n) per job.
    pub fn output_shape(&self) -> (usize, usize) {
        (self.m, self.n)
    }

    /// The plan chosen for the served shape — carries the dtype, the
    /// two-level `mc×kc×nc` macro-block decision and the per-dtype
    /// autotuned register-tile width alongside the L1 tile (report with
    /// [`Plan::describe`]).
    pub fn plan(&self) -> &Plan {
        &self.plan
    }
}

/// Configuration for [`Service::start`].
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// How long the batcher waits to fill a batch.
    pub batch_window: Duration,
    /// Cache spec the planner models (tile selection).
    pub spec: CacheSpec,
    /// Execution engine: PJRT artifacts or the native packed kernel.
    pub backend: Backend,
}

impl Default for ServiceConfig {
    fn default() -> ServiceConfig {
        ServiceConfig {
            m: 128,
            k: 128,
            n: 128,
            batch_window: Duration::from_millis(2),
            spec: CacheSpec::HASWELL_L1D,
            backend: Backend::Pjrt,
        }
    }
}

impl Service {
    /// Start the coordinator: loads the registry (optional for the
    /// native backend), plans the shape at the serving dtype (f32), warms
    /// the chosen executables, spawns the worker thread that owns the
    /// engine.
    pub fn start(artifact_dir: &Path, y: Vec<f32>, cfg: ServiceConfig) -> Result<Service> {
        let mut registry = match cfg.backend {
            Backend::Pjrt => Registry::load(artifact_dir)?,
            // the native engine needs no artifacts; keep whatever loads
            // so mixed deployments can still resolve PJRT names
            Backend::Native => Registry::load(artifact_dir).unwrap_or_default(),
        };
        // one-shot startup autotune (ROADMAP), per dtype: record each
        // precision's winning register-tile width class; the narrow shape
        // stays the compile-time default
        registry.set_micro_shape_for(DType::F64, autotune::calibrate_dtype::<f64>(2_000));
        registry.set_micro_shape_for(DType::F32, autotune::calibrate_dtype::<f32>(2_000));
        anyhow::ensure!(
            y.len() == cfg.k * cfg.n,
            "y must be k×n = {}",
            cfg.k * cfg.n
        );
        let mut planner = Planner::new(cfg.spec);
        let (tx, rx) = channel::<Msg>();
        let m = cfg.m;
        let k = cfg.k;
        let n = cfg.n;
        let window = cfg.batch_window;
        let (plan, handle) = match cfg.backend {
            Backend::Pjrt => {
                // the PJRT artifacts compute in f32 — plan at f32 so the
                // model sees the true elements-per-line
                let plan = planner.plan(&registry, m, k, n, DType::F32);
                let single = registry
                    .by_name(&plan.artifact)
                    .with_context(|| format!("planned artifact {} missing", plan.artifact))?
                    .name
                    .clone();
                // batched variant with the same problem shape, if shipped
                let batched = registry
                    .artifacts()
                    .iter()
                    .find(|a| {
                        a.kind == ArtifactKind::PallasTiledMatmulBatched
                            && a.m == m
                            && a.k == k
                            && a.n == n
                    })
                    .map(|a| (a.name.clone(), a.batch));
                let handle = std::thread::spawn(move || {
                    let mut engine = Engine::new(registry).expect("pjrt engine");
                    engine.prepare(&single).expect("prepare single artifact");
                    if let Some((name, _)) = &batched {
                        engine.prepare(name).expect("prepare batched artifact");
                    }
                    let backend = WorkerBackend::Pjrt {
                        engine,
                        single,
                        batched,
                        y,
                    };
                    worker_loop(backend, rx, m, k, n, window)
                });
                (plan, handle)
            }
            Backend::Native => {
                // plan the kernel the native engine actually executes: the
                // f32 (4-byte-element) column-major formulation below — so
                // the macro shape and micro width are selected for f32
                let mut plan =
                    planner.plan_kernel(&registry, &NativeMatmul::kernel_for(m, k, n));
                // the executed kernel is the transpose lowering (GEMM rows
                // = serve columns), and the plan's m/n/tile/macro fields
                // describe *that* kernel consistently; surface the serve
                // shape in the name so plan lines are readable next to the
                // PJRT backend's
                plan.plan_name =
                    format!("{} (serving {m}x{k}x{n} via transpose)", plan.plan_name);
                let level = plan.level;
                let micro = plan.micro;
                let handle = std::thread::spawn(move || {
                    let native = NativeMatmul::new(m, k, n, &y, level, micro);
                    worker_loop(WorkerBackend::Native(Box::new(native)), rx, m, k, n, window)
                });
                (plan, handle)
            }
        };
        Ok(Service {
            tx,
            handle,
            m,
            k,
            n,
            plan,
        })
    }

    /// Submit a job; returns the receiver for the m×n row-major result.
    pub fn submit(&self, x: Vec<f32>) -> Result<Receiver<Result<Vec<f32>>>> {
        anyhow::ensure!(x.len() == self.m * self.k, "x must be m×k");
        let (rtx, rrx) = channel();
        self.tx
            .send(Msg::Job(Job {
                x,
                resp: rtx,
                submitted: Instant::now(),
            }))
            .map_err(|_| anyhow::anyhow!("service stopped"))?;
        Ok(rrx)
    }

    /// Stop and collect metrics (+ total wall time of the worker).
    pub fn stop(self) -> (Metrics, Duration) {
        let _ = self.tx.send(Msg::Stop);
        self.handle.join().expect("worker panicked")
    }
}

/// The f32 packed-macro-kernel serve engine: one resident
/// [`KernelBuffers<f32>`] arena holding `y` — whose row panels really
/// are packed once, at startup ([`pack_row_slices`]) — and the per-job
/// `x`, driven by [`run_macro_prepacked`] with the plan's full
/// three-level shape (the `m3×n3` L3 super-band nest selects whole
/// block subranges of the pre-packed slices, so the serve loop follows
/// the same schedule as the batch engine without duplicating the
/// resident panels) and the f32 autotune winner. Per job only the `x`
/// column bands are packed; the weight panels are reused as-is.
///
/// Row-major serving lowers onto the column-major engine via the
/// transpose identity `(x·y)ᵀ = yᵀ·xᵀ`: the kernel computes the
/// column-major product `A(n×m) = B(n×k)·C(k×m)`, and the row-major
/// buffers are *bit-identical* reinterpretations — `y` row-major k×n is
/// exactly `B = yᵀ` column-major n×k, `x` row-major m×k is exactly
/// `C = xᵀ` column-major k×m, and the output table read in layout order
/// is exactly `x·y` row-major m×n. No transposition copies anywhere.
struct NativeMatmul {
    plan: RunPlan,
    level: LevelPlan,
    micro: MicroShape,
    bufs: KernelBuffers<f32>,
    /// `y`'s row panels, one [`PackedRows`] per reduction slice — packed
    /// once at startup, shared by every job (`y` never changes).
    rows: Vec<PackedRows<f32>>,
    cols: PackedCols<f32>,
}

impl NativeMatmul {
    /// The f32 kernel the native backend executes for an m×k×n serve
    /// shape (see the type docs for the transpose lowering).
    fn kernel_for(m: usize, k: usize, n: usize) -> crate::domain::Kernel {
        ops::matmul(n as i64, k as i64, m as i64, DType::F32.elem(), 0)
    }

    fn new(
        m: usize,
        k: usize,
        n: usize,
        y: &[f32],
        level: LevelPlan,
        micro: MicroShape,
    ) -> NativeMatmul {
        let kernel = NativeMatmul::kernel_for(m, k, n);
        let mut bufs = KernelBuffers::<f32>::from_kernel(&kernel);
        // operand 1 is B = yᵀ (n×k column-major) — the same linear bytes
        // as y (k×n row-major)
        bufs.operand_mut(1).copy_from_slice(y);
        let gf = GemmForm::of(&kernel).expect("matmul is GEMM-form");
        let lo = vec![0i64; kernel.n_free()];
        let plan = gf.plan_box(&kernel_views(&kernel), &lo, kernel.extents());
        // y is resident for the service's lifetime: pack its row panels
        // exactly once, here
        let rows = pack_row_slices(&bufs.arena, &plan, &level);
        NativeMatmul {
            plan,
            level,
            micro,
            bufs,
            rows,
            cols: PackedCols::new(),
        }
    }

    /// Serve one job: load `x`, zero the output, run the packed
    /// macro-kernel over the pre-packed weight panels, read the output in
    /// row-major order.
    fn run(&mut self, x: &[f32]) -> Vec<f32> {
        self.bufs.reset_output();
        self.bufs.operand_mut(2).copy_from_slice(x);
        run_macro_prepacked(
            &mut self.bufs.arena,
            &self.plan,
            &self.level,
            self.micro,
            &self.rows,
            &mut self.cols,
        );
        self.bufs.output()
    }
}

enum WorkerBackend {
    Pjrt {
        engine: Engine,
        single: String,
        batched: Option<(String, usize)>,
        y: Vec<f32>,
    },
    Native(Box<NativeMatmul>),
}

impl WorkerBackend {
    /// How many jobs one dispatch can carry.
    fn batch_cap(&self) -> usize {
        match self {
            WorkerBackend::Pjrt {
                batched: Some((_, b)),
                ..
            } => *b,
            _ => 1,
        }
    }

    /// Run a single job.
    fn run_one(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        match self {
            WorkerBackend::Pjrt {
                engine, single, y, ..
            } => engine.run_matmul(single, x, y),
            WorkerBackend::Native(native) => Ok(native.run(x)),
        }
    }
}

fn worker_loop(
    mut backend: WorkerBackend,
    rx: Receiver<Msg>,
    m: usize,
    k: usize,
    n: usize,
    window: Duration,
) -> (Metrics, Duration) {
    let started = Instant::now();
    let mut metrics = Metrics::new();
    let flops_per_job = (2 * m * k * n) as u64;
    let mut pending: Vec<Job> = Vec::new();
    let mut stopping = false;

    while !stopping || !pending.is_empty() {
        // fill the batch within the window
        let cap = backend.batch_cap();
        let deadline = Instant::now() + window;
        while !stopping && pending.len() < cap {
            let timeout = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(timeout) {
                Ok(Msg::Job(j)) => pending.push(j),
                Ok(Msg::Stop) => stopping = true,
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    stopping = true;
                    break;
                }
            }
            if pending.len() == 1 && window.is_zero() {
                break;
            }
        }
        if pending.is_empty() {
            if stopping {
                break;
            }
            // idle: block for the next message
            match rx.recv() {
                Ok(Msg::Job(j)) => pending.push(j),
                Ok(Msg::Stop) | Err(_) => stopping = true,
            }
            continue;
        }

        metrics.record_batch();
        let batch = std::mem::take(&mut pending);
        let use_batched = batch.len() > 1
            && matches!(
                &backend,
                WorkerBackend::Pjrt {
                    batched: Some(_),
                    ..
                }
            );
        if use_batched {
            if let WorkerBackend::Pjrt {
                engine,
                batched: Some((name, cap)),
                y,
                ..
            } = &mut backend
            {
                // pad to the full batch with zeros
                let mut xs = vec![0f32; *cap * m * k];
                for (i, j) in batch.iter().enumerate() {
                    xs[i * m * k..(i + 1) * m * k].copy_from_slice(&j.x);
                }
                match engine.run_matmul(name, &xs, y) {
                    Ok(out) => {
                        for (i, j) in batch.into_iter().enumerate() {
                            let slice = out[i * m * n..(i + 1) * m * n].to_vec();
                            metrics.record_job(j.submitted.elapsed(), flops_per_job);
                            let _ = j.resp.send(Ok(slice));
                        }
                    }
                    Err(e) => {
                        for j in batch {
                            let _ = j.resp.send(Err(anyhow::anyhow!("{e:#}")));
                        }
                    }
                }
            }
        } else {
            for j in batch {
                let r = backend.run_one(&j.x);
                metrics.record_job(j.submitted.elapsed(), flops_per_job);
                let _ = j.resp.send(r);
            }
        }
    }
    (metrics, started.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn rowmajor_matmul(m: usize, k: usize, n: usize, x: &[f32], y: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let xv = x[i * k + kk];
                for j in 0..n {
                    out[i * n + j] += xv * y[kk * n + j];
                }
            }
        }
        out
    }

    fn xorshift_f32(seed: u64) -> impl FnMut() -> f32 {
        let mut s = seed | 1;
        move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            ((s % 1000) as f32 / 1000.0) - 0.5
        }
    }

    #[test]
    fn service_serves_correct_results() {
        if !artifacts_dir().join("manifest.tsv").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let (m, k, n) = (128usize, 128, 128);
        let mut rnd = xorshift_f32(7);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let svc = Service::start(
            &artifacts_dir(),
            y.clone(),
            ServiceConfig {
                m,
                k,
                n,
                batch_window: Duration::from_millis(1),
                spec: CacheSpec::HASWELL_L1D,
                backend: Backend::Pjrt,
            },
        )
        .unwrap();

        println!("serving with {}", svc.plan().describe());
        let xs: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..m * k).map(|_| rnd()).collect())
            .collect();
        let rxs: Vec<_> = xs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
        for (x, rx) in xs.iter().zip(rxs) {
            let got = rx.recv().unwrap().unwrap();
            let want = rowmajor_matmul(m, k, n, x, &y);
            let maxd = got
                .iter()
                .zip(&want)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(maxd < 1e-2, "serve result off by {maxd}");
        }
        let (metrics, wall) = svc.stop();
        assert_eq!(metrics.jobs, 5);
        assert!(metrics.batches >= 1);
        println!("serve test: {}", metrics.report(wall));
    }

    #[test]
    fn native_backend_serves_f32_matmul_without_artifacts() {
        // the acceptance path: f32 matmul jobs through the packed
        // macro-kernel, no PJRT artifacts anywhere; non-multiple shape so
        // edge register blocks are exercised on the serve path
        let (m, k, n) = (45usize, 33, 52);
        let mut rnd = xorshift_f32(0xA11CE);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let svc = Service::start(
            Path::new("definitely-no-artifacts-here"),
            y.clone(),
            ServiceConfig {
                m,
                k,
                n,
                batch_window: Duration::from_millis(1),
                spec: CacheSpec::HASWELL_L1D,
                backend: Backend::Native,
            },
        )
        .expect("native service must start without artifacts");
        let plan = svc.plan().clone();
        assert_eq!(plan.dtype, DType::F32, "{}", plan.describe());
        assert!(plan.artifact.contains("packed-engine"), "{}", plan.describe());
        // the served plan carries (and reports) the L3 super-band shape
        // the prepacked engine threads through run_macro_prepacked
        assert!(plan.describe().contains("super m3="), "{}", plan.describe());
        assert_eq!(plan.level.m3 % plan.level.mc, 0, "{}", plan.describe());
        assert_eq!(plan.level.n3 % plan.level.nc, 0, "{}", plan.describe());
        let xs: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..m * k).map(|_| rnd()).collect())
            .collect();
        let rxs: Vec<_> = xs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
        for (x, rx) in xs.iter().zip(rxs) {
            let got = rx.recv().unwrap().unwrap();
            let want = rowmajor_matmul(m, k, n, x, &y);
            assert_eq!(got.len(), want.len());
            let maxd = got
                .iter()
                .zip(&want)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(maxd < 1e-3, "native serve result off by {maxd}");
        }
        let (metrics, _) = svc.stop();
        assert_eq!(metrics.jobs, 4);
    }

    #[test]
    fn native_backend_matches_pjrt_differentially() {
        // when artifacts are shipped, the two backends must agree on the
        // existing batching workload — the native engine is the PJRT
        // path's differential baseline and vice versa
        if !artifacts_dir().join("manifest.tsv").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let (m, k, n) = (128usize, 128, 128);
        let mut rnd = xorshift_f32(0xD1FF);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let xs: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..m * k).map(|_| rnd()).collect())
            .collect();
        let mut outs: Vec<Vec<Vec<f32>>> = Vec::new();
        for backend in [Backend::Pjrt, Backend::Native] {
            let svc = Service::start(
                &artifacts_dir(),
                y.clone(),
                ServiceConfig {
                    m,
                    k,
                    n,
                    batch_window: Duration::from_millis(1),
                    spec: CacheSpec::HASWELL_L1D,
                    backend,
                },
            )
            .unwrap();
            let rxs: Vec<_> = xs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
            outs.push(rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect());
            svc.stop();
        }
        for (job, (a, b)) in outs[0].iter().zip(&outs[1]).enumerate() {
            let maxd = a
                .iter()
                .zip(b)
                .map(|(x, y)| (x - y).abs())
                .fold(0f32, f32::max);
            assert!(maxd < 1e-2, "job {job}: backends disagree by {maxd}");
        }
    }

    #[test]
    fn native_backend_batches_under_load() {
        // a wider window than the submit cadence: several jobs coalesce
        // into batches and every result stays correct
        let (m, k, n) = (32usize, 24, 40);
        let mut rnd = xorshift_f32(0xBA7C4);
        let y: Vec<f32> = (0..k * n).map(|_| rnd()).collect();
        let svc = Service::start(
            Path::new("no-artifacts"),
            y.clone(),
            ServiceConfig {
                m,
                k,
                n,
                batch_window: Duration::from_millis(5),
                spec: CacheSpec::HASWELL_L1D,
                backend: Backend::Native,
            },
        )
        .unwrap();
        let xs: Vec<Vec<f32>> = (0..8)
            .map(|_| (0..m * k).map(|_| rnd()).collect())
            .collect();
        let rxs: Vec<_> = xs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
        for (x, rx) in xs.iter().zip(rxs) {
            let got = rx.recv().unwrap().unwrap();
            let want = rowmajor_matmul(m, k, n, x, &y);
            let maxd = got
                .iter()
                .zip(&want)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(maxd < 1e-3, "batched native result off by {maxd}");
        }
        let (metrics, _) = svc.stop();
        assert_eq!(metrics.jobs, 8);
        assert!(metrics.batches >= 1);
    }
}
