//! Deterministic fault injection for the serving runtime.
//!
//! A [`Faults`] handle names a set of **fault points** — places in the
//! serve path that can be made to misbehave on purpose — and a
//! deterministic xorshift schedule deciding *which* checks fire. The
//! chaos suite drives every [`FaultPoint`] through a real [`Service`]
//! and asserts the containment contract: every submitted job's receiver
//! resolves (Ok or typed Err), survivors are oracle-correct, and
//! [`Metrics`] accounts for every job exactly once.
//!
//! Design rules:
//!
//! * **Deterministic.** Fire decisions come from a seeded xorshift over
//!   the check stream — never from wall-clock time or OS entropy — so a
//!   failing chaos run replays exactly.
//! * **Scoped, not global.** A schedule lives in a [`Faults`] handle
//!   threaded through [`ServiceConfig`]; concurrent services (and
//!   concurrent tests) cannot see each other's faults. Deep call sites
//!   that cannot carry the handle ([`raise_if`] in the executor's pack
//!   loop) read a thread-local installed by [`with_scope`] for the
//!   duration of one batch — only the worker thread that installed it is
//!   affected.
//! * **Compiled out.** Unless built with `cfg(test)` (unit tests) or
//!   `--features fault-injection` (the chaos CI job, `--inject-faults`
//!   in the CLI), [`Faults`] is a fieldless struct and every check is an
//!   inlined `None`/no-op — release serving pays nothing.
//!
//! [`Service`]: super::service::Service
//! [`ServiceConfig`]: super::service::ServiceConfig
//! [`Metrics`]: super::metrics::Metrics

use std::fmt;

#[cfg(any(test, feature = "fault-injection"))]
use std::cell::RefCell;
#[cfg(any(test, feature = "fault-injection"))]
use std::sync::{Arc, Mutex};

/// Named places in the serve path where a fault can be injected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultPoint {
    /// A whole batch execution in the worker (native `run_batch` entry /
    /// PJRT dispatch). `Panic` unwinds like a kernel bug; `Error` returns
    /// a typed backend failure. Either way the degradation ladder retries
    /// the batch's jobs one at a time before erroring them.
    BatchCompute,
    /// The per-batch column-band packing inside the executor's
    /// pre-packed nest — reached through the [`with_scope`] thread-local,
    /// always manifests as an unwind mid-compute.
    Pack,
    /// Plan-time model evaluation. [`Planner::plan_or_fallback`] turns
    /// it (and any genuine selector panic) into the parameter-free flat
    /// fallback plan instead of a failed `Service::start`.
    ///
    /// [`Planner::plan_or_fallback`]: super::planner::Planner::plan_or_fallback
    Plan,
    /// Queue admission: the submit is rejected with an ordinary
    /// `SubmitError::QueueFull` — a simulated transient overload, which
    /// is exactly what `submit_with_retry`'s backoff is for.
    QueueAccept,
}

impl FaultPoint {
    /// Every fault point, in a fixed order (chaos sweeps iterate this).
    pub const ALL: [FaultPoint; 4] = [
        FaultPoint::BatchCompute,
        FaultPoint::Pack,
        FaultPoint::Plan,
        FaultPoint::QueueAccept,
    ];

    #[cfg(any(test, feature = "fault-injection"))]
    fn idx(self) -> usize {
        match self {
            FaultPoint::BatchCompute => 0,
            FaultPoint::Pack => 1,
            FaultPoint::Plan => 2,
            FaultPoint::QueueAccept => 3,
        }
    }
}

/// How a fired fault manifests at its call site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultMode {
    /// A typed error return (backend failure, admission rejection).
    Error,
    /// An unwind, as if the code at the fault point had panicked.
    Panic,
}

/// A (possibly inert) fault schedule handle. `Clone` shares the
/// schedule state: the service, its clients, and its worker all advance
/// one deterministic check stream.
#[derive(Clone, Default)]
pub struct Faults {
    #[cfg(any(test, feature = "fault-injection"))]
    inner: Option<Arc<Inner>>,
}

impl Faults {
    /// An inert handle: no fault ever fires (the production default).
    pub fn none() -> Faults {
        Faults::default()
    }
}

impl fmt::Debug for Faults {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.active() {
            f.write_str("Faults(armed)")
        } else {
            f.write_str("Faults(none)")
        }
    }
}

#[cfg(any(test, feature = "fault-injection"))]
struct PointCfg {
    mode: FaultMode,
    /// Fire when `xorshift % den < num` …
    num: u64,
    den: u64,
    /// … but never more than this many times in total.
    max_fires: u64,
}

#[cfg(any(test, feature = "fault-injection"))]
struct Inner {
    points: [Option<PointCfg>; 4],
    state: Mutex<State>,
}

#[cfg(any(test, feature = "fault-injection"))]
struct State {
    rng: u64,
    fired: [u64; 4],
}

#[cfg(any(test, feature = "fault-injection"))]
fn lock_state(m: &Mutex<State>) -> std::sync::MutexGuard<'_, State> {
    // fault state is monotone counters + an rng word: a poisoned lock
    // (an injected unwind crossed it) loses nothing
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(any(test, feature = "fault-injection"))]
impl Faults {
    /// Start building an armed schedule from a deterministic seed.
    pub fn seeded(seed: u64) -> FaultsBuilder {
        FaultsBuilder {
            seed,
            points: [None, None, None, None],
        }
    }

    /// Should the check at `point` fire, and how? Advances the
    /// deterministic schedule; inert handles and unarmed points return
    /// `None` without consuming randomness.
    pub fn check(&self, point: FaultPoint) -> Option<FaultMode> {
        let inner = self.inner.as_ref()?;
        let cfg = inner.points[point.idx()].as_ref()?;
        let mut st = lock_state(&inner.state);
        st.rng ^= st.rng << 13;
        st.rng ^= st.rng >> 7;
        st.rng ^= st.rng << 17;
        if st.fired[point.idx()] < cfg.max_fires && st.rng % cfg.den < cfg.num {
            st.fired[point.idx()] += 1;
            return Some(cfg.mode);
        }
        None
    }

    /// How many times `point` has fired so far.
    pub fn fired(&self, point: FaultPoint) -> u64 {
        self.inner
            .as_ref()
            .map(|i| lock_state(&i.state).fired[point.idx()])
            .unwrap_or(0)
    }

    /// Whether any fault point is armed.
    pub fn active(&self) -> bool {
        self.inner.is_some()
    }
}

#[cfg(not(any(test, feature = "fault-injection")))]
impl Faults {
    /// Compiled-out check: always `None`, folds away entirely.
    #[inline(always)]
    pub fn check(&self, _point: FaultPoint) -> Option<FaultMode> {
        None
    }

    /// Compiled-out counter: nothing ever fires.
    #[inline(always)]
    pub fn fired(&self, _point: FaultPoint) -> u64 {
        0
    }

    /// Compiled-out: never armed.
    #[inline(always)]
    pub fn active(&self) -> bool {
        false
    }
}

/// Builder for an armed [`Faults`] schedule (fault-injection builds
/// only).
#[cfg(any(test, feature = "fault-injection"))]
pub struct FaultsBuilder {
    seed: u64,
    points: [Option<PointCfg>; 4],
}

#[cfg(any(test, feature = "fault-injection"))]
impl FaultsBuilder {
    /// Arm `point` to fire with probability `num/den` per check,
    /// indefinitely.
    pub fn fail(mut self, point: FaultPoint, mode: FaultMode, num: u64, den: u64) -> FaultsBuilder {
        self.points[point.idx()] = Some(PointCfg {
            mode,
            num: num.max(1),
            den: den.max(1),
            max_fires: u64::MAX,
        });
        self
    }

    /// Arm `point` to fire on every check until it has fired exactly
    /// `fires` times, then go quiet — the shape for "fail once, then
    /// heal" scenarios.
    pub fn fail_n(mut self, point: FaultPoint, mode: FaultMode, fires: u64) -> FaultsBuilder {
        self.points[point.idx()] = Some(PointCfg {
            mode,
            num: 1,
            den: 1,
            max_fires: fires,
        });
        self
    }

    pub fn build(self) -> Faults {
        Faults {
            inner: Some(Arc::new(Inner {
                points: self.points,
                state: Mutex::new(State {
                    rng: self.seed | 1,
                    fired: [0; 4],
                }),
            })),
        }
    }
}

/// Unwind as an injected fault at `point`. Uses `resume_unwind`, which
/// skips the global panic hook — injected chaos does not spam test
/// output with backtraces; the supervisor still catches it like any
/// panic.
#[cfg(any(test, feature = "fault-injection"))]
pub fn inject_panic(point: FaultPoint) -> ! {
    std::panic::resume_unwind(Box::new(format!("injected fault at {point:?}")))
}

/// Compiled-out variant: nothing can fire, so this is unreachable by
/// construction (callers only reach it behind a `Some` from `check`).
#[cfg(not(any(test, feature = "fault-injection")))]
pub fn inject_panic(point: FaultPoint) -> ! {
    unreachable!("fault injection compiled out ({point:?})")
}

#[cfg(any(test, feature = "fault-injection"))]
thread_local! {
    static CURRENT: RefCell<Option<Faults>> = const { RefCell::new(None) };
}

/// Install `faults` as this thread's scoped schedule for the duration of
/// `body` — deep call sites that cannot carry a handle ([`raise_if`])
/// read it. Restores the previous scope on exit, including by unwind.
#[cfg(any(test, feature = "fault-injection"))]
pub fn with_scope<R>(faults: &Faults, body: impl FnOnce() -> R) -> R {
    struct Restore(Option<Faults>);
    impl Drop for Restore {
        fn drop(&mut self) {
            CURRENT.with(|c| *c.borrow_mut() = self.0.take());
        }
    }
    let prev = CURRENT.with(|c| c.borrow_mut().replace(faults.clone()));
    let _restore = Restore(prev);
    body()
}

/// Compiled-out scope: just runs `body`.
#[cfg(not(any(test, feature = "fault-injection")))]
#[inline(always)]
pub fn with_scope<R>(_faults: &Faults, body: impl FnOnce() -> R) -> R {
    body()
}

/// Snapshot this thread's scoped schedule so it can be re-installed on
/// another thread (the parallel super-band workers: the thread-local
/// stops at `std::thread::scope`, so the spawning thread captures its
/// scope and each worker re-enters it via [`with_scope_opt`]). `Clone`
/// shares the schedule state, so fires on any worker consume the one
/// deterministic budget. `None` outside any scope.
#[cfg(any(test, feature = "fault-injection"))]
pub fn capture_scope() -> Option<Faults> {
    CURRENT.with(|c| c.borrow().clone())
}

/// Compiled-out capture: there is never a scope.
#[cfg(not(any(test, feature = "fault-injection")))]
#[inline(always)]
pub fn capture_scope() -> Option<Faults> {
    None
}

/// [`with_scope`] over a captured (possibly absent) schedule: installs
/// `faults` for the duration of `body` when `Some`, otherwise just runs
/// `body`. The worker-side counterpart of [`capture_scope`].
pub fn with_scope_opt<R>(faults: Option<&Faults>, body: impl FnOnce() -> R) -> R {
    match faults {
        Some(f) => with_scope(f, body),
        None => body(),
    }
}

/// Check the thread-local scoped schedule at `point` and unwind if it
/// fires (both [`FaultMode`]s manifest as an unwind here — a deep call
/// site has no typed error channel). No-op outside a [`with_scope`].
#[cfg(any(test, feature = "fault-injection"))]
#[inline]
pub fn raise_if(point: FaultPoint) {
    let fire = CURRENT.with(|c| c.borrow().as_ref().and_then(|f| f.check(point)));
    if fire.is_some() {
        inject_panic(point);
    }
}

/// Compiled-out check: nothing to do.
#[cfg(not(any(test, feature = "fault-injection")))]
#[inline(always)]
pub fn raise_if(_point: FaultPoint) {}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    fn pattern(f: &Faults, point: FaultPoint, checks: usize) -> Vec<bool> {
        (0..checks).map(|_| f.check(point).is_some()).collect()
    }

    #[test]
    fn inert_handle_never_fires() {
        let f = Faults::none();
        assert!(!f.active());
        for p in FaultPoint::ALL {
            assert_eq!(f.check(p), None);
            assert_eq!(f.fired(p), 0);
        }
    }

    #[test]
    fn unarmed_points_never_fire() {
        let f = Faults::seeded(7)
            .fail(FaultPoint::Pack, FaultMode::Panic, 1, 1)
            .build();
        assert!(f.active());
        assert_eq!(f.check(FaultPoint::Plan), None);
        assert_eq!(f.check(FaultPoint::Pack), Some(FaultMode::Panic));
    }

    #[test]
    fn schedule_is_deterministic_across_instances() {
        let mk = || {
            Faults::seeded(0xDE7E12)
                .fail(FaultPoint::BatchCompute, FaultMode::Error, 1, 3)
                .build()
        };
        let (a, b) = (mk(), mk());
        let pa = pattern(&a, FaultPoint::BatchCompute, 200);
        let pb = pattern(&b, FaultPoint::BatchCompute, 200);
        assert_eq!(pa, pb);
        let fires = pa.iter().filter(|&&x| x).count();
        assert!(fires > 0 && fires < 200, "1/3 ratio fired {fires}/200");
        assert_eq!(a.fired(FaultPoint::BatchCompute), fires as u64);
    }

    #[test]
    fn budget_caps_total_fires() {
        let f = Faults::seeded(3)
            .fail_n(FaultPoint::QueueAccept, FaultMode::Error, 2)
            .build();
        let fired = pattern(&f, FaultPoint::QueueAccept, 50)
            .iter()
            .filter(|&&x| x)
            .count();
        assert_eq!(fired, 2, "budget of 2 must fire exactly twice");
        // and the first two checks fire back to back (num == den)
        let g = Faults::seeded(3)
            .fail_n(FaultPoint::QueueAccept, FaultMode::Error, 2)
            .build();
        assert!(g.check(FaultPoint::QueueAccept).is_some());
        assert!(g.check(FaultPoint::QueueAccept).is_some());
        assert!(g.check(FaultPoint::QueueAccept).is_none());
    }

    #[test]
    fn clones_share_one_schedule() {
        let f = Faults::seeded(9)
            .fail_n(FaultPoint::Plan, FaultMode::Error, 1)
            .build();
        let g = f.clone();
        assert_eq!(g.check(FaultPoint::Plan), Some(FaultMode::Error));
        // the clone's fire consumed the shared budget
        assert_eq!(f.check(FaultPoint::Plan), None);
        assert_eq!(f.fired(FaultPoint::Plan), 1);
    }

    #[test]
    fn captured_scope_crosses_threads_and_shares_budget() {
        let f = Faults::seeded(13)
            .fail_n(FaultPoint::Pack, FaultMode::Panic, 1)
            .build();
        assert!(capture_scope().is_none(), "no ambient scope outside with_scope");
        with_scope(&f, || {
            let captured = capture_scope();
            assert!(captured.is_some(), "capture inside a scope");
            std::thread::scope(|s| {
                s.spawn(|| {
                    // the raw thread-local does not cross the spawn…
                    raise_if(FaultPoint::Pack);
                    assert_eq!(f.fired(FaultPoint::Pack), 0);
                    // …but the captured handle re-enters the scope there
                    let r = std::panic::catch_unwind(|| {
                        with_scope_opt(captured.as_ref(), || raise_if(FaultPoint::Pack));
                    });
                    assert!(r.is_err(), "captured Pack fault must fire on the worker");
                });
            });
        });
        // the worker's fire consumed the one shared budget
        assert_eq!(f.fired(FaultPoint::Pack), 1);
    }

    #[test]
    fn scoped_raise_unwinds_and_restores() {
        let f = Faults::seeded(11)
            .fail_n(FaultPoint::Pack, FaultMode::Panic, 1)
            .build();
        let r = std::panic::catch_unwind(|| {
            with_scope(&f, || raise_if(FaultPoint::Pack));
        });
        assert!(r.is_err(), "scoped Pack fault must unwind");
        assert_eq!(f.fired(FaultPoint::Pack), 1);
        // outside any scope the same call is a no-op even while armed
        raise_if(FaultPoint::Pack);
    }
}
