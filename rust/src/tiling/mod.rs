//! Tiling mechanics and tile selection — §3 (DESIGN.md S7, S8).
//!
//! [`tile`] implements the half-open parallelepiped machinery of §3.2
//! (`P_D(H)`, `T_D(H)`, `r(x)`); [`schedule`] turns a tile basis into a
//! traversal order; [`selection`] chooses tiles — the paper's `K−1`
//! lattice-point rule and the model-driven search of §4.0.4.

pub mod schedule;
pub mod selection;
pub mod tile;

pub use schedule::TiledSchedule;
pub use selection::{
    embed_operand_tile, k_minus_one_plan, level_plan, model_driven_search, plan_with_kappa,
    rect_candidates, scaled_lattice_tile, select, snap_to_microkernel, LevelPlan, TilingPlan,
};
pub use tile::TileBasis;
