//! Tiling mechanics and tile *selection strategies* — §3 (DESIGN.md S7, S8).
//!
//! [`tile`] implements the half-open parallelepiped machinery of §3.2
//! (`P_D(H)`, `T_D(H)`, `r(x)`); [`schedule`] turns a tile basis into a
//! traversal order; [`selection`] holds the paper's selectors — the
//! `K−1` lattice-point rule, the model-driven search of §4.0.4, and the
//! multi-level [`LevelPlan`] machinery.
//!
//! [`strategy`] is the layer above: tile selection is a pluggable
//! [`TilingStrategy`] trait, and the paper's lattice selector
//! ([`strategy::Lattice`], wrapping [`level_plan`]) is the *first
//! implementation rather than the hardwired only path*. Two rivals ship
//! alongside it — [`strategy::CacheOblivious`] (recursive halving, no
//! cache parameters) and [`strategy::LatencyCurve`] (measured latency
//! knees) — and the autotune race
//! ([`crate::codegen::autotune::race_strategy_rates`]) measures all of
//! them on the packed engine, records per-(kernel, dtype, shape-class)
//! winners in the runtime registry, and the planner dispatches the
//! recorded winner (`--strategy {lattice,oblivious,latency,auto}`
//! overrides it). Strategies differ only in *blocking*, never in
//! accumulation order, so their plans are bitwise-interchangeable on
//! exact data.

pub mod schedule;
pub mod selection;
pub mod strategy;
pub mod tile;

pub use schedule::TiledSchedule;
pub use selection::{
    embed_operand_tile, k_minus_one_plan, level_plan, model_driven_search, plan_with_kappa,
    rect_candidates, scaled_lattice_tile, select, snap_to_microkernel, LevelPlan, TilingPlan,
};
pub use strategy::{
    raced_strategies, strategy_impl, CacheOblivious, Lattice, LatencyCurve, ShapeClass,
    StrategyChoice, StrategyKind, TilingStrategy,
};
pub use tile::TileBasis;
